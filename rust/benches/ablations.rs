//! `cargo bench --bench ablations` — thin wrapper over the registered
//! `ablations` suite (SJF-BSBF design-choice ablations); the body lives
//! in `wise_share::perfkit::suites::ablations` so `wise-share bench`
//! records the same cases machine-readably. Perfkit flags pass through:
//! `cargo bench --bench ablations -- --profile quick`.

fn main() -> anyhow::Result<()> {
    wise_share::perfkit::bench_main("ablations")
}

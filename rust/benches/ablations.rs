//! `cargo bench --bench ablations` — ablations over SJF-BSBF's three design
//! choices (DESIGN.md per-experiment index):
//!
//! 1. **Theorem-1 gate** off → accept every memory-feasible share
//!    (isolates the share-or-wait decision from the batch scaling).
//! 2. **Batch-size sweep** off → no gradient accumulation; sharing only
//!    when the full batches jointly fit (isolates Algorithm 2's memory
//!    relief).
//! 3. **Benefit sorting** off → arbitrary partner order (isolates Alg. 1
//!    line 14).
//!
//! Run on the contended 240-job workload; reports avg JCT per variant.

use wise_share::cluster::ClusterConfig;
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::perf::interference::InterferenceModel;
use wise_share::sched::SjfBsbf;
use wise_share::sim::{engine, metrics, Policy};

fn variant(name: &str, mut policy: SjfBsbf, jobs: &[wise_share::jobs::JobSpec]) -> f64 {
    let out = engine::run(
        ClusterConfig::simulation(),
        jobs,
        InterferenceModel::new(),
        &mut policy as &mut dyn Policy,
    )
    .expect("simulation failed");
    let s = metrics::summarize(name, &out.jobs, out.makespan_s);
    println!(
        "{name:<28} avg JCT {:>7.3} hrs   queue {:>6.3} hrs   makespan {:>7.2} hrs",
        s.all.avg_jct_s / 3600.0,
        s.all.avg_queue_s / 3600.0,
        s.makespan_s / 3600.0
    );
    s.all.avg_jct_s
}

fn main() {
    let mut tcfg = TraceConfig::simulation(240, 1);
    tcfg.load_factor = 1.5; // contended: sharing decisions matter
    let jobs = trace::generate(&tcfg);

    println!("SJF-BSBF ablations, 240 jobs @ 1.5x density, 64 GPUs:\n");
    let full = variant("full (paper)", SjfBsbf::default(), &jobs);
    let no_gate = variant(
        "no theorem-1 gate",
        SjfBsbf { theorem1_gate: false, ..SjfBsbf::default() },
        &jobs,
    );
    let no_sweep = variant(
        "no batch-size sweep",
        SjfBsbf { sweep_batches: false, ..SjfBsbf::default() },
        &jobs,
    );
    let no_sort = variant(
        "no benefit sorting",
        SjfBsbf { sort_by_benefit: false, ..SjfBsbf::default() },
        &jobs,
    );

    println!("\ndeltas vs full: gate {:+.1}%, sweep {:+.1}%, sort {:+.1}%",
        (no_gate / full - 1.0) * 100.0,
        (no_sweep / full - 1.0) * 100.0,
        (no_sort / full - 1.0) * 100.0
    );
    assert!(
        no_gate >= full * 0.98,
        "removing the Theorem-1 gate should not improve BSBF materially"
    );
}

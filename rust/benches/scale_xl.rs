//! `cargo bench --bench scale_xl` — thin wrapper over the registered
//! `scale_xl` suite (the million-job event core: 100k-job quick tier for
//! CI's `scale-smoke` leg, a 1M-job / 100k-GPU full tier; events/s and
//! jobs/s recorded as gated metrics); the body lives in
//! `wise_share::perfkit::suites::scale_xl` so `wise-share bench` records
//! the same cases machine-readably. Perfkit flags pass through:
//! `cargo bench --bench scale_xl -- --profile quick --out BENCH_xl.json`.

fn main() -> anyhow::Result<()> {
    wise_share::perfkit::bench_main("scale_xl")
}

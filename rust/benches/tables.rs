//! `cargo bench --bench tables` — thin wrapper over the registered
//! `tables` suite (paper Tables II-IV); the body lives in
//! `wise_share::perfkit::suites::tables` so `wise-share bench` records
//! the same cases machine-readably. Perfkit flags pass through:
//! `cargo bench --bench tables -- --profile quick --out BENCH_tables.json`.

fn main() -> anyhow::Result<()> {
    wise_share::perfkit::bench_main("tables")
}

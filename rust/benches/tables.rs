//! `cargo bench --bench tables` — regenerates every *table* in the paper's
//! evaluation (§VI):
//!
//! * **Table II**  — 30-job physical workload on 4x4 GPUs (simulated here;
//!   the PJRT-executing variant is `examples/physical_cluster.rs`):
//!   makespan + average JCT per policy.
//! * **Table III** — 240-job simulation: all/large/small JCT + queueing.
//! * **Table IV**  — 480-job simulation at 2x arrival density.
//!
//! Each row also reports the wall-clock cost of producing it (the bench
//! half), so regressions in simulator performance are visible.

use wise_share::cluster::ClusterConfig;
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::perf::interference::InterferenceModel;
use wise_share::report;
use wise_share::sched::{self, POLICY_NAMES};
use wise_share::sim::{engine, metrics};
use wise_share::util::bench::bench;

fn table(
    label: &str,
    cluster: ClusterConfig,
    tcfg: &TraceConfig,
    table2_style: bool,
) -> anyhow::Result<()> {
    let jobs = trace::generate(tcfg);
    let mut rows = Vec::new();
    for name in POLICY_NAMES {
        // Physical cluster (16 GPUs) cannot host jobs > 16 GPUs; the trace
        // generator respects the preset, so no clamping needed here.
        let mut summary = None;
        bench(&format!("{label}/{name}"), 3, || {
            let mut p = sched::by_name(name).unwrap();
            let out = engine::run(cluster, &jobs, InterferenceModel::new(), p.as_mut())
                .expect("simulation failed");
            summary = Some(metrics::summarize(name, &out.jobs, out.makespan_s));
        });
        rows.push(summary.unwrap());
    }
    println!("\n=== {label} ===");
    if table2_style {
        println!("{}", report::table2(&rows));
    } else {
        println!("{}", report::table34(&rows));
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // Table II: the physical 30-job mix (simulated; see EXPERIMENTS.md for
    // the recorded PJRT-executing run).
    table(
        "table2/physical-30-jobs",
        ClusterConfig::physical(),
        &TraceConfig::physical(1),
        true,
    )?;
    // Table III: 240 jobs, baseline density.
    table(
        "table3/sim-240-jobs",
        ClusterConfig::simulation(),
        &TraceConfig::simulation(240, 1),
        false,
    )?;
    // Table IV: 480 jobs at double density (same busiest window).
    let mut t4 = TraceConfig::simulation(480, 1);
    t4.load_factor = 2.0;
    table("table4/sim-480-jobs-2x", ClusterConfig::simulation(), &t4, false)?;
    Ok(())
}

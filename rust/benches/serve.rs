//! `cargo bench --bench serve` — thin wrapper over the registered `serve`
//! suite (an in-process daemon fed a live workload-v2 session: measures
//! submissions/sec and per-submit request→decision latency); the body
//! lives in `wise_share::perfkit::suites::serve` so `wise-share bench`
//! records the same cases machine-readably. Perfkit flags pass through:
//! `cargo bench --bench serve -- --profile quick --out BENCH_serve.json`.

fn main() -> anyhow::Result<()> {
    wise_share::perfkit::bench_main("serve")
}

//! `cargo bench --bench scale` — thin wrapper over the registered `scale`
//! suite (10k-20k-job Helios/flood traces on up to 4096-GPU hetero
//! topologies; the quick profile is CI's smoke tier); the body lives in
//! `wise_share::perfkit::suites::scale` so `wise-share bench` records the
//! same cases machine-readably. Perfkit flags pass through:
//! `cargo bench --bench scale -- --profile quick --out BENCH_scale.json`.

fn main() -> anyhow::Result<()> {
    wise_share::perfkit::bench_main("scale")
}

//! `cargo bench --bench figures` — thin wrapper over the registered
//! `figures` suite (paper Figs. 2-6 as CSV series); the body lives in
//! `wise_share::perfkit::suites::figures` so `wise-share bench` records
//! the same cases machine-readably. Perfkit flags pass through:
//! `cargo bench --bench figures -- --profile quick --out BENCH_figures.json`.

fn main() -> anyhow::Result<()> {
    wise_share::perfkit::bench_main("figures")
}

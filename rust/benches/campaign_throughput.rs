//! `cargo bench --bench campaign_throughput` — parallel scenario-sweep
//! throughput: the campaign worker-pool runner vs the old serial loop on
//! the multi-seed policy matrix shape every table/figure sweep uses.
//!
//! The matrix is embarrassingly parallel (fresh trace + policy + cluster
//! state per run), so on an N-core box the pooled runner should approach
//! min(N, runs)× the serial wall-clock; the exact speedup is printed.

use wise_share::campaign::{self, Axes, CampaignSpec};
use wise_share::util::bench::bench;

fn main() {
    let mut spec = CampaignSpec::new("bench");
    spec.policies = vec!["SJF".to_string(), "SJF-BSBF".to_string()];
    spec.axes = Axes {
        load_factors: vec![1.0],
        job_counts: vec![120],
        gpu_counts: Vec::new(),
        topologies: Vec::new(),
        workloads: Vec::new(),
        estimators: Vec::new(),
        seeds: (1..=6).collect(),
        jobs_scale_load_baseline: None,
    };
    let points = campaign::expand(&spec).expect("valid spec");
    let threads = campaign::default_threads();
    println!(
        "matrix: {} runs (2 policies x 6 seeds, 120 jobs), {} worker thread(s)",
        points.len(),
        threads
    );

    let serial = bench("campaign/serial-reference", 3, || {
        let out = campaign::run_serial(&points);
        assert!(out.iter().all(|o| o.summary.is_ok()));
    });
    let parallel = bench("campaign/parallel-pool", 3, || {
        let out = campaign::run_parallel(&points, threads);
        assert!(out.iter().all(|o| o.summary.is_ok()));
    });
    println!(
        "parallel speedup: {:.2}x (serial mean {:.3}s -> parallel mean {:.3}s)",
        serial.mean_s / parallel.mean_s,
        serial.mean_s,
        parallel.mean_s
    );
}

//! `cargo bench --bench campaign_throughput` — thin wrapper over the
//! registered `campaign_throughput` suite (trace-sharing + worker-pool
//! speedups on the sweep matrix); the body lives in
//! `wise_share::perfkit::suites::campaign_throughput` so `wise-share
//! bench` records the same cases machine-readably. Perfkit flags pass
//! through: `cargo bench --bench campaign_throughput -- --profile quick`.

fn main() -> anyhow::Result<()> {
    wise_share::perfkit::bench_main("campaign_throughput")
}

//! `cargo bench --bench runtime_hotpath` — the PJRT execution hot path the
//! physical coordinator drives: artifact compile time (one-off), grad_step
//! latency per micro-batch variant, the accum fold, the apply update, and
//! the full gradient-accumulation iteration at several (batch, s) settings.
//!
//! This is the L3-side profile used in the §Perf pass (EXPERIMENTS.md).
//! Requires `make artifacts`.

use wise_share::runtime::executor::{TrainExecutor, TrainState};
use wise_share::runtime::ArtifactSet;
use wise_share::util::bench::bench;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let set = ArtifactSet::load(ArtifactSet::default_dir())?;
    println!(
        "artifact load+compile (7 executables): {:.2}s (one-off per worker)",
        t0.elapsed().as_secs_f64()
    );
    println!(
        "model: {} params, vocab {}, seq {}",
        set.meta.model.n_params, set.meta.model.vocab, set.meta.model.seq_len
    );

    let mut exec = TrainExecutor::new(&set, 1, 0.1);
    let mut state: TrainState = exec.init_state()?;

    // grad_step latency per compiled micro-batch variant.
    for &mb in &set.meta.micro_batches.clone() {
        let mut st = exec.init_state()?;
        bench(&format!("train_step/batch{mb}/s1"), 20, || {
            exec.train_step(&mut st, mb, 1).unwrap();
        });
    }

    // Full gradient-accumulation iterations: batch 8 at s = 1, 2, 4, 8.
    for &s in &[1u32, 2, 4, 8] {
        bench(&format!("train_step/batch8/s{s}"), 15, || {
            exec.train_step(&mut state, 8, s).unwrap();
        });
    }
    println!(
        "\nnote: s>1 pays (s-1) extra grad_step+accum executions — the Eq. 7\n\
         (s-1)*t_comp(B/s) term the scheduler trades against memory."
    );
    Ok(())
}

//! `cargo bench --bench runtime_hotpath` — thin wrapper over the
//! registered `runtime_hotpath` suite (obskit overhead, always; the PJRT
//! train-step hot path when `make artifacts` ran); the body lives in
//! `wise_share::perfkit::suites::runtime_hotpath` so `wise-share bench`
//! records the same cases machine-readably. Perfkit flags pass through:
//! `cargo bench --bench runtime_hotpath -- --profile quick`.

fn main() -> anyhow::Result<()> {
    wise_share::perfkit::bench_main("runtime_hotpath")
}

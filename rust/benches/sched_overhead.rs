//! `cargo bench --bench sched_overhead` — the paper's §V-4 claim: "the
//! overhead of periodically scheduling those waiting jobs is negligible,
//! averaging below 0.02 seconds for each operation" on a 16-GPU cluster.
//!
//! We measure one SJF-BSBF scheduling pass (the full Algorithm 1 including
//! Algorithm 2 sweeps and the Theorem-1 evaluations) on a *busy* cluster —
//! every GPU holding one job, a full pending queue — for both the paper's
//! 16-GPU testbed and the 64-GPU simulation cluster, plus the decision
//! kernel (Theorem 1) and Algorithm 2 in isolation.

use wise_share::cluster::{Cluster, ClusterConfig};
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::jobs::{JobRecord, JobState};
use wise_share::pair::{batch_size_scaling, best_pair_schedule, PairSide};
use wise_share::perf::interference::InterferenceModel;
use wise_share::perf::profiles::ModelKind;
use wise_share::sched::SjfBsbf;
use wise_share::sim::{Policy, SimState};
use wise_share::util::bench::bench;

/// Build a saturated SimState: every GPU busy with one job + `n_pending`
/// waiting jobs, so a scheduling pass exercises the full sharing search.
fn busy_state(cluster_cfg: ClusterConfig, n_pending: usize) -> SimState {
    let total = cluster_cfg.total_gpus();
    let n_running = total / 4; // 4-GPU gangs fill every slot with one job
    let trace_cfg = TraceConfig::simulation(n_running + n_pending, 9);
    let mut jobs: Vec<JobRecord> = trace::generate(&trace_cfg)
        .into_iter()
        .map(JobRecord::new)
        .collect();
    let mut cluster = Cluster::new(cluster_cfg);
    for (i, job) in jobs.iter_mut().enumerate().take(n_running) {
        job.spec.gpus = 4;
        let gpus: Vec<usize> = (i * 4..i * 4 + 4).collect();
        cluster.allocate(i, &gpus);
        job.state = JobState::Running;
        job.gpus_held = gpus;
        job.spec.arrival_s = 0.0;
    }
    for job in jobs.iter_mut().skip(n_running) {
        job.spec.arrival_s = 0.0; // all pending now
        job.spec.gpus = job.spec.gpus.min(total);
    }
    let n = jobs.len();
    SimState {
        now: 1.0,
        cluster,
        jobs,
        xi: InterferenceModel::new(),
        not_before: vec![0.0; n],
        service_gpu_s: vec![0.0; n],
    }
}

fn main() {
    // The decision kernel: one Theorem-1 evaluation.
    bench("theorem1/single-pair", 10_000, || {
        let s = best_pair_schedule(
            PairSide { iter_time: 0.21, iters: 4000.0, xi: 1.4 },
            PairSide { iter_time: 0.35, iters: 9000.0, xi: 1.7 },
        );
        std::hint::black_box(s.avg_jct);
    });

    // Algorithm 2: full sub-batch sweep for one candidate pair.
    let new = JobRecord::new(wise_share::jobs::JobSpec {
        id: 0,
        model: ModelKind::Bert,
        gpus: 4,
        iterations: 2000,
        batch: 16,
        arrival_s: 0.0,
    });
    let run = JobRecord::new(wise_share::jobs::JobSpec {
        id: 1,
        model: ModelKind::Cifar10,
        gpus: 4,
        iterations: 8000,
        batch: 128,
        arrival_s: 0.0,
    });
    let xi = InterferenceModel::new();
    bench("algorithm2/batch-size-scaling", 10_000, || {
        std::hint::black_box(batch_size_scaling(&new, &run, 4, 11.0, &xi));
    });

    // Full Algorithm 1 pass on the paper's 16-GPU testbed (§V-4 claim).
    let state16 = busy_state(ClusterConfig::physical(), 8);
    let mut policy = SjfBsbf::default();
    let stats = bench("sjf-bsbf/schedule-pass/16-gpu-busy", 200, || {
        std::hint::black_box(policy.schedule(&state16));
    });
    assert!(
        stats.mean_s < 0.02,
        "paper claims < 0.02 s per scheduling op; measured {:.4}s",
        stats.mean_s
    );
    println!(
        "PASS: {:.3} ms mean < 20 ms (paper's §V-4 bound)",
        stats.mean_s * 1e3
    );

    // And on the 64-GPU simulation cluster with a deep queue.
    let state64 = busy_state(ClusterConfig::simulation(), 32);
    let mut policy = SjfBsbf::default();
    bench("sjf-bsbf/schedule-pass/64-gpu-busy", 100, || {
        std::hint::black_box(policy.schedule(&state64));
    });
}

//! `cargo bench --bench sched_overhead` — thin wrapper over the
//! registered `sched_overhead` suite (the paper's §V-4 scheduling-cost
//! claim plus the sched_core machinery at scale); the body lives in
//! `wise_share::perfkit::suites::sched_overhead` so `wise-share bench`
//! records the same cases machine-readably. Perfkit flags pass through:
//! `cargo bench --bench sched_overhead -- --profile quick`.

fn main() -> anyhow::Result<()> {
    wise_share::perfkit::bench_main("sched_overhead")
}

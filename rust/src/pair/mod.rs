//! The paper's analytical core (§V-A):
//!
//! * **Theorem 1** — for one running job B and one new job A that could share
//!   B's GPU set, the average JCT of the pair is minimized at one of the two
//!   endpoints of the insertion time κ: full overlap (κ = 0) or fully
//!   sequential (A starts when B finishes). The objective is affine in κ, so
//!   "evaluating the conditions for the best solution is the same as directly
//!   comparing the fully overlapped time and the fully non-overlapped time".
//! * **Algorithm 2** — sweep the new job's sub-batch b over `{B, B/2, …, 1}`
//!   (gradient accumulation step s = B/b), apply Theorem 1 per candidate,
//!   respect joint GPU-memory feasibility, and return the best
//!   (share?, sub-batch, pair-JCT) configuration.


use crate::jobs::JobRecord;
use crate::perf::interference::InterferenceModel;
use crate::perf::GangSpan;

/// Inputs describing one side of a (running, new) pair on a GPU set.
#[derive(Debug, Clone, Copy)]
pub struct PairSide {
    /// Solo iteration time on the shared gang (Eq. 7 already applied).
    pub iter_time: f64,
    /// Remaining iterations.
    pub iters: f64,
    /// Interference ratio if shared (Eq. 5/6).
    pub xi: f64,
}

/// Outcome of the κ-endpoint comparison for one pair configuration.
#[derive(Debug, Clone, Copy)]
pub struct PairSchedule {
    /// True ⇒ launch the new job immediately (κ = 0); false ⇒ run after.
    pub share: bool,
    /// Mean completion time of the two jobs measured from "now".
    pub avg_jct: f64,
    /// Mean JCT under full overlap (κ = 0).
    pub overlap_avg: f64,
    /// Mean JCT under sequential execution.
    pub sequential_avg: f64,
    /// Completion times (new, running) under the chosen schedule.
    pub finish_new: f64,
    pub finish_running: f64,
}

/// Theorem 1: compare κ = 0 (overlap) against sequential, pick the better.
///
/// `new` is job A (arriving), `running` is job B (already on the GPUs, its
/// remaining work counted from now). Both completion times are measured from
/// now; queueing history does not change the comparison.
pub fn best_pair_schedule(new: PairSide, running: PairSide) -> PairSchedule {
    assert!(new.xi >= 1.0 && running.xi >= 1.0, "ξ must be ≥ 1");
    // --- full overlap, κ = 0 ------------------------------------------------
    let ta_h = new.iter_time * new.xi; // t̂_A
    let tb_h = running.iter_time * running.xi; // t̂_B
    let (ov_new, ov_run) = if ta_h * new.iters <= tb_h * running.iters {
        // A drains first; B finishes the tail solo.
        let t_a = ta_h * new.iters;
        let done_b = t_a / tb_h; // B iterations completed during overlap
        let t_b = t_a + running.iter_time * (running.iters - done_b);
        (t_a, t_b)
    } else {
        // B drains first; A finishes the tail solo.
        let t_b = tb_h * running.iters;
        let done_a = t_b / ta_h;
        let t_a = t_b + new.iter_time * (new.iters - done_a);
        (t_a, t_b)
    };
    let overlap_avg = 0.5 * (ov_new + ov_run);

    // --- sequential: A waits for B ------------------------------------------
    let seq_run = running.iter_time * running.iters;
    let seq_new = seq_run + new.iter_time * new.iters;
    let sequential_avg = 0.5 * (seq_new + seq_run);

    let share = overlap_avg <= sequential_avg;
    let (finish_new, finish_running) =
        if share { (ov_new, ov_run) } else { (seq_new, seq_run) };
    PairSchedule {
        share,
        avg_jct: overlap_avg.min(sequential_avg),
        overlap_avg,
        sequential_avg,
        finish_new,
        finish_running,
    }
}

/// Algorithm 2 result: the best sharing configuration for the new job.
#[derive(Debug, Clone, Copy)]
pub struct SharingConfig {
    /// `SF`: share now (κ = 0)? False ⇒ pair prefers sequential execution.
    pub share: bool,
    /// Chosen sub-batch `b̄` for the new job (accum step = B/b̄).
    pub sub_batch: u32,
    /// Accumulation step s = B / b̄.
    pub accum_step: u32,
    /// Best pair mean JCT `t̄` (the sort key in Alg. 1 line 14).
    pub pair_jct: f64,
    /// The full schedule at the winning configuration.
    pub schedule: PairSchedule,
}

/// Algorithm 2: batch-size scaling with best sharing benefit.
///
/// * `new_job` — the pending job `J_k` (user batch `B_k` fixed).
/// * `running` — the job currently holding the candidate GPU set; its batch
///   and accumulation step are left untouched (paper §V-B3).
/// * `gang` — number of GPUs in the shared set (the new job would run its
///   gang exactly on the running job's GPUs).
/// * `gpu_mem_gb` — per-GPU memory budget; joint footprint must fit.
///
/// Returns `None` if no sub-batch down to 1 fits in memory next to the
/// running job (sharing physically impossible on this gang).
pub fn batch_size_scaling(
    new_job: &JobRecord,
    running: &JobRecord,
    gang: usize,
    gpu_mem_gb: f64,
    xi: &InterferenceModel,
) -> Option<SharingConfig> {
    batch_size_scaling_opts(new_job, running, gang, gpu_mem_gb, xi, true)
}

/// [`batch_size_scaling`] with the sub-batch sweep as a switch: with
/// `sweep_batches = false` only the user's full batch is considered (the
/// "no gradient accumulation" ablation — sharing becomes memory-infeasible
/// whenever the full batches don't jointly fit).
pub fn batch_size_scaling_opts(
    new_job: &JobRecord,
    running: &JobRecord,
    gang: usize,
    gpu_mem_gb: f64,
    xi: &InterferenceModel,
    sweep_batches: bool,
) -> Option<SharingConfig> {
    batch_size_scaling_placed(
        new_job,
        running,
        gang,
        gpu_mem_gb,
        xi,
        sweep_batches,
        &GangSpan::reference(),
        &GangSpan::reference(),
    )
}

/// Locality-true Algorithm 2: both sides' Eq. 7 iteration times are
/// evaluated on the spans their gangs actually occupy — `new_span` for
/// the candidate shared GPU set the new job would land on, `run_span`
/// for the running job's own placement — so the Theorem-1 comparison
/// (and therefore SJF-BSBF's benefit ranking) sees consolidation and
/// heterogeneity instead of assuming the flat reference switch.
/// Reference spans reproduce [`batch_size_scaling_opts`] bit-for-bit.
///
/// Both sides' iteration counts enter as the scheduler's *estimates*
/// ([`JobRecord::estimated_remaining_iters`]): the pair-JCT ranking and
/// the share-or-wait verdict are decisions, and decisions only ever see
/// estimated durations. Under the oracle (`est_factor == 1.0`) the
/// inputs — and therefore every verdict — are bit-identical to the
/// perfect-information paper setting.
#[allow(clippy::too_many_arguments)]
pub fn batch_size_scaling_placed(
    new_job: &JobRecord,
    running: &JobRecord,
    gang: usize,
    gpu_mem_gb: f64,
    xi: &InterferenceModel,
    sweep_batches: bool,
    new_span: &GangSpan,
    run_span: &GangSpan,
) -> Option<SharingConfig> {
    let new_prof = new_job.spec.profile();
    let run_prof = running.spec.profile();
    let run_mem =
        run_prof.mem.mem_gb(running.spec.batch as f64 / running.accum_step as f64);
    let budget = gpu_mem_gb - run_mem;
    let (xi_new, xi_run) = xi.pair(new_job.spec.model, running.spec.model);

    // Running job's solo iteration time on its own gang and placement, at
    // its own accumulation step.
    let run_side_iter = run_prof.perf.iter_time_placed(
        running.spec.batch as f64,
        running.accum_step,
        running.spec.gpus,
        run_span,
    );

    let mut best: Option<SharingConfig> = None;
    let mut b = new_job.spec.batch.max(1);
    loop {
        let s = (new_job.spec.batch as f64 / b as f64).ceil() as u32;
        if new_prof.mem.mem_gb(b as f64) <= budget {
            let new_side = PairSide {
                iter_time: new_prof.perf.iter_time_placed(
                    new_job.spec.batch as f64,
                    s,
                    gang,
                    new_span,
                ),
                iters: new_job.estimated_remaining_iters(),
                xi: xi_new,
            };
            let run_side = PairSide {
                iter_time: run_side_iter,
                iters: running.estimated_remaining_iters(),
                xi: xi_run,
            };
            let sched = best_pair_schedule(new_side, run_side);
            let better = match &best {
                None => true,
                Some(cfg) => sched.avg_jct < cfg.pair_jct,
            };
            if better {
                best = Some(SharingConfig {
                    share: sched.share,
                    sub_batch: b,
                    accum_step: s,
                    pair_jct: sched.avg_jct,
                    schedule: sched,
                });
            }
        }
        if b == 1 || !sweep_batches {
            break;
        }
        b /= 2;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::{JobRecord, JobSpec};
    use crate::perf::profiles::ModelKind;

    fn side(iter_time: f64, iters: f64, xi: f64) -> PairSide {
        PairSide { iter_time, iters, xi }
    }

    #[test]
    fn no_interference_always_shares() {
        // ξ = 1 ⇒ overlap strictly dominates (B unchanged, A earlier).
        let s = best_pair_schedule(side(1.0, 100.0, 1.0), side(1.0, 100.0, 1.0));
        assert!(s.share);
        assert!(s.overlap_avg < s.sequential_avg);
    }

    #[test]
    fn catastrophic_interference_prefers_sequential() {
        // ξ = 4 on both: overlap roughly quadruples both runtimes.
        let s = best_pair_schedule(side(1.0, 100.0, 4.0), side(1.0, 100.0, 4.0));
        assert!(!s.share);
        assert_eq!(s.avg_jct, s.sequential_avg);
    }

    #[test]
    fn overlap_times_match_closed_form_case_new_first() {
        // t̂_A i_A < t̂_B i_B: Eq. 18/19 structure (roles per our naming).
        let a = side(1.0, 10.0, 1.5); // t̂_A i_A = 15
        let b = side(2.0, 20.0, 1.5); // t̂_B i_B = 60
        let s = best_pair_schedule(a, b);
        let t_a = 15.0;
        let done_b = t_a / 3.0; // 5 iters of B during overlap
        let t_b = t_a + 2.0 * (20.0 - done_b);
        assert!((s.overlap_avg - 0.5 * (t_a + t_b)).abs() < 1e-9);
    }

    #[test]
    fn sequential_is_sum_of_solos() {
        let a = side(1.0, 10.0, 3.0);
        let b = side(2.0, 5.0, 3.0);
        let s = best_pair_schedule(a, b);
        assert!((s.sequential_avg - 0.5 * ((10.0 + 10.0) + 10.0)).abs() < 1e-9);
    }

    #[test]
    fn theorem1_endpoints_dominate_interior() {
        // Sample interior κ values and verify neither beats the best
        // endpoint (the affine-in-κ argument of Theorem 1).
        let t_a = 1.0;
        let t_b = 1.3;
        let (i_a, i_b) = (40.0, 70.0);
        for &(xa, xb) in &[(1.2, 1.1), (1.8, 2.2), (1.05, 2.9), (2.5, 1.02)] {
            let best =
                best_pair_schedule(side(t_a, i_a, xa), side(t_b, i_b, xb)).avg_jct;
            for k in 1..10 {
                let kappa = k as f64 / 10.0 * t_b * i_b;
                let avg = interior_avg(t_a, i_a, xa, t_b, i_b, xb, kappa);
                assert!(
                    best <= avg + 1e-9,
                    "interior κ={kappa} beat endpoints: {avg} < {best} (ξ=({xa},{xb}))"
                );
            }
        }
    }

    /// Simulate partial overlap: B runs alone for κ, then both share.
    fn interior_avg(
        t_a: f64,
        i_a: f64,
        xa: f64,
        t_b: f64,
        i_b: f64,
        xb: f64,
        kappa: f64,
    ) -> f64 {
        let mut rem_b = i_b - kappa / t_b;
        if rem_b <= 0.0 {
            // B already done before κ: A runs solo.
            let t_bf = t_b * i_b;
            return 0.5 * ((kappa.max(t_bf) + t_a * i_a) + t_bf);
        }
        let (ta_h, tb_h) = (t_a * xa, t_b * xb);
        let (fin_a, fin_b) = if ta_h * i_a <= tb_h * rem_b {
            let fa = kappa + ta_h * i_a;
            let done_b = (fa - kappa) / tb_h;
            (fa, fa + t_b * (rem_b - done_b))
        } else {
            let fb = kappa + tb_h * rem_b;
            let done_a = (fb - kappa) / ta_h;
            (fb + t_a * (i_a - done_a), fb)
        };
        0.5 * (fin_a + fin_b)
    }

    fn record(model: ModelKind, gpus: usize, iters: u64, batch: u32) -> JobRecord {
        JobRecord::new(JobSpec {
            id: 0,
            model,
            gpus,
            iterations: iters,
            batch,
            arrival_s: 0.0,
            est_factor: 1.0,
        })
    }

    #[test]
    fn alg2_finds_memory_feasible_sub_batch() {
        // New BERT@16 next to a running CIFAR10@128 (4.3 GB resident): the
        // full sub-batch 16 needs 10.3 GB > the 6.7 GB left, so Alg. 2 must
        // shrink the new job via gradient accumulation (b = 4 fits: 5.7 GB).
        let new = record(ModelKind::Bert, 4, 500, 16);
        let run = record(ModelKind::Cifar10, 4, 500, 128);
        let xi = InterferenceModel::new();
        let cfg = batch_size_scaling(&new, &run, 4, 11.0, &xi).unwrap();
        assert!(cfg.sub_batch < 16, "must shrink: {cfg:?}");
        assert_eq!(cfg.accum_step, 16 / cfg.sub_batch);
        let joint = {
            let p = new.spec.profile().mem.mem_gb(cfg.sub_batch as f64);
            let q = run.spec.profile().mem.mem_gb(128.0);
            p + q
        };
        assert!(joint <= 11.0, "joint footprint {joint} GB");
    }

    #[test]
    fn alg2_none_when_bases_collide() {
        // Two BERTs cannot co-reside at all: the running job's footprint
        // leaves less than the new job's 4.2 GB weight/optimizer base.
        let new = record(ModelKind::Bert, 4, 500, 16);
        let run = record(ModelKind::Bert, 4, 500, 16);
        let xi = InterferenceModel::new();
        assert!(batch_size_scaling(&new, &run, 4, 11.0, &xi).is_none());
    }

    #[test]
    fn alg2_none_when_nothing_fits() {
        // Two YoloV3 at batch 16: running uses 3.4+0.42·16 = 10.1 GB,
        // leaving 0.9 GB < base 3.4 GB ⇒ no sub-batch fits.
        let new = record(ModelKind::YoloV3, 4, 500, 16);
        let run = record(ModelKind::YoloV3, 4, 500, 16);
        let xi = InterferenceModel::new();
        assert!(batch_size_scaling(&new, &run, 4, 11.0, &xi).is_none());
    }

    #[test]
    fn alg2_polite_pair_shares() {
        // NCF next to CIFAR10: tiny interference, plenty of memory ⇒ share.
        let new = record(ModelKind::Ncf, 2, 1000, 4096);
        let run = record(ModelKind::Cifar10, 2, 1000, 128);
        let xi = InterferenceModel::new();
        let cfg = batch_size_scaling(&new, &run, 2, 11.0, &xi).unwrap();
        assert!(cfg.share, "{cfg:?}");
    }

    #[test]
    fn alg2_heavy_pair_declines_to_share() {
        // Two network-heavy detectors with room (small batches): ξ ≈ 6 ⇒
        // Theorem 1 should pick sequential (SF = false).
        let new = record(ModelKind::YoloV3, 4, 500, 4);
        let run = record(ModelKind::YoloV3, 4, 500, 4);
        let xi = InterferenceModel::new();
        let cfg = batch_size_scaling(&new, &run, 4, 11.0, &xi).unwrap();
        assert!(!cfg.share, "{cfg:?}");
    }

    #[test]
    fn alg2_placed_reference_span_matches_agnostic_path() {
        let new = record(ModelKind::Ncf, 4, 1000, 4096);
        let run = record(ModelKind::Cifar10, 4, 1000, 128);
        let xi = InterferenceModel::new();
        let a = batch_size_scaling(&new, &run, 4, 11.0, &xi).unwrap();
        let r = GangSpan::reference();
        let b = batch_size_scaling_placed(&new, &run, 4, 11.0, &xi, true, &r, &r).unwrap();
        assert_eq!(a.pair_jct.to_bits(), b.pair_jct.to_bits());
        assert_eq!(a.share, b.share);
        assert_eq!(a.sub_batch, b.sub_batch);
    }

    #[test]
    fn alg2_consolidated_span_improves_pair_jct() {
        // Same pair, same gang width: landing on one NVLink node must
        // yield a strictly better pair JCT than spanning four 10 Gbps
        // nodes (comm shrinks for both sides).
        let new = record(ModelKind::Ncf, 4, 1000, 4096);
        let run = record(ModelKind::ImageNet, 4, 1000, 32);
        let xi = InterferenceModel::new();
        let nvlink = GangSpan {
            nodes: 1,
            bandwidth_gbps: 100.0,
            latency_s: 0.0,
            compute_scale: 1.0,
        };
        let spread = GangSpan {
            nodes: 4,
            bandwidth_gbps: 10.0,
            latency_s: 20e-6,
            compute_scale: 1.0,
        };
        let close = batch_size_scaling_placed(&new, &run, 4, 11.0, &xi, true, &nvlink, &nvlink)
            .unwrap();
        let far = batch_size_scaling_placed(&new, &run, 4, 11.0, &xi, true, &spread, &spread)
            .unwrap();
        assert!(
            close.pair_jct < far.pair_jct,
            "consolidated {:.1}s must beat spread {:.1}s",
            close.pair_jct,
            far.pair_jct
        );
    }

    #[test]
    fn alg2_ranks_on_estimated_durations() {
        // A mispredicted newcomer changes the pair-JCT ranking input:
        // the same pair looks 4x costlier when the new job's estimate is
        // inflated 4x — that is exactly how SJF-BSBF's benefit sort (and
        // potentially its share-or-wait verdict) degrade under
        // misprediction, while the engine still runs the true durations.
        let new = record(ModelKind::Ncf, 2, 1000, 4096);
        let mut inflated = new.clone();
        inflated.spec.est_factor = 4.0;
        let run = record(ModelKind::Cifar10, 2, 1000, 128);
        let xi = InterferenceModel::new();
        let honest = batch_size_scaling(&new, &run, 2, 11.0, &xi).unwrap();
        let skewed = batch_size_scaling(&inflated, &run, 2, 11.0, &xi).unwrap();
        assert!(
            skewed.pair_jct > honest.pair_jct,
            "inflated estimate must raise the pair JCT: {} vs {}",
            skewed.pair_jct,
            honest.pair_jct
        );
    }

    #[test]
    fn alg2_respects_global_xi_override() {
        // Fig. 6b mechanism: ξ = 1.0 everywhere ⇒ always share.
        let new = record(ModelKind::YoloV3, 4, 500, 4);
        let run = record(ModelKind::YoloV3, 4, 500, 4);
        let xi = InterferenceModel::with_global(1.0);
        let cfg = batch_size_scaling(&new, &run, 4, 11.0, &xi).unwrap();
        assert!(cfg.share);
    }
}

//! SJF: shortest-job-first, exclusive GPUs, non-preemptive (§VI-A baseline
//! 2 — "an ideal policy to minimize the average JCT without preemption by
//! prioritizing short-term jobs to overcome HOL blocking. It is impractical
//! as it requires perfect job information").
//!
//! Priority key is the *estimated* remaining solo runtime
//! `L̂_k = t_iter · I_k · est_factor` (Alg. 1 line 1 uses the same key)
//! — with the oracle estimator this is the paper's perfect-information
//! `L_k` exactly; with a `Noisy`/`Percentile` estimator the policy
//! mis-ranks the way a production scheduler would. Shorter(-looking)
//! jobs may start ahead of a blocked longer job whenever they fit.

use crate::cluster::placement;
use crate::sched_core::{Event, Policy, SchedContext, Txn};

#[derive(Debug, Default)]
pub struct Sjf;

impl Policy for Sjf {
    fn name(&self) -> &'static str {
        "SJF"
    }

    fn coalesce_coincident(&self) -> bool {
        true
    }

    fn on_event(&mut self, ctx: &SchedContext, _ev: Event) -> Txn {
        let mut plan = ctx.overlay();
        let mut txn = Txn::new();
        // The shared SJF-family candidate order — estimated remaining
        // solo runtime, ties by id — comes pre-sorted from the context's
        // incrementally maintained pending index: no per-pass re-sort.
        for id in ctx.pending_by_estimate() {
            if plan.free_count() == 0 {
                // Every gang needs ≥ 1 free GPU and the loop has no other
                // side effects, so the remaining candidates are all
                // placement failures — same outcome, skipped.
                break;
            }
            let spec = &ctx.jobs[id].spec;
            let solo_gb = spec.profile().mem.mem_gb(spec.batch as f64);
            if let Some(gpus) =
                placement::consolidated_free_mem(&plan, spec.gpus, solo_gb)
            {
                plan.allocate(id, &gpus);
                txn.start(id, gpus, 1);
            }
        }
        txn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::jobs::JobSpec;
    use crate::perf::interference::InterferenceModel;
    use crate::perf::profiles::ModelKind;
    use crate::sim::engine;

    fn job(id: usize, gpus: usize, iters: u64, arrival: f64) -> JobSpec {
        JobSpec {
            id,
            model: ModelKind::Cifar10,
            gpus,
            iterations: iters,
            batch: 128,
            arrival_s: arrival,
            est_factor: 1.0,
        }
    }

    #[test]
    fn short_job_overtakes_blocked_long_job() {
        // All GPUs busy; a long 16-GPU job waits; a tiny 1-GPU job arrives
        // later and under SJF leapfrogs it as soon as one GPU frees... here
        // GPUs free all at once, but the short job must start first.
        let trace =
            vec![job(0, 16, 1000, 0.0), job(1, 16, 5000, 1.0), job(2, 1, 10, 2.0)];
        let out = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut Sjf,
        )
        .unwrap();
        let s1 = out.jobs[1].first_start_s.unwrap();
        let s2 = out.jobs[2].first_start_s.unwrap();
        assert!(s2 < s1, "SJF must start the tiny job first: {s2} vs {s1}");
    }

    #[test]
    fn sjf_beats_fifo_on_avg_jct_under_contention() {
        use crate::sched::Fifo;
        use crate::sim::metrics;
        // One long job then many short ones, all 16-GPU (forced serial).
        let mut trace = vec![job(0, 16, 4000, 0.0)];
        for i in 1..6 {
            trace.push(job(i, 16, 50, 0.5 + i as f64 * 0.1));
        }
        let fifo = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut Fifo,
        )
        .unwrap();
        let sjf = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut Sjf,
        )
        .unwrap();
        let f = metrics::summarize("FIFO", &fifo.jobs, fifo.makespan_s);
        let s = metrics::summarize("SJF", &sjf.jobs, sjf.makespan_s);
        assert!(
            s.all.avg_jct_s <= f.all.avg_jct_s,
            "SJF {:.1} should beat FIFO {:.1}",
            s.all.avg_jct_s,
            f.all.avg_jct_s
        );
    }
}

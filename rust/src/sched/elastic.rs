//! Pollux-like elastic baseline (§VI-A baseline 5): periodically
//! re-optimizes per-job GPU counts to maximize aggregate goodput.
//!
//! Faithful-in-shape simplification of Pollux (OSDI'21), documented in
//! DESIGN.md: each interval, GPUs are assigned one at a time to the job
//! with the best *marginal speedup per GPU* (diminishing-returns water
//! filling over each job's Eq. 7 speedup curve), bounded by [0, 2×request].
//! Changing a job's allocation costs a restart penalty (checkpoint +
//! rebuild), which is exactly why Pollux excels at low load — re-scaling is
//! cheap and GPUs are plentiful — and degrades under overload (Fig. 6a's
//! crossover; [16], [20]). Unlike the real Pollux we never retune the batch
//! size (the accuracy-degradation concern the paper raises).

use crate::cluster::placement;
use crate::jobs::JobId;
use crate::sched_core::{Event, Policy, SchedContext, Txn};

#[derive(Debug)]
pub struct Elastic {
    /// Reallocation interval (Pollux default: 30 s).
    pub tick_s: f64,
    /// Restart penalty when an allocation changes.
    pub penalty_s: f64,
    /// Allocation cap as a multiple of the requested gang.
    pub cap_factor: f64,
    /// Hysteresis: only shrink/grow a running job if the plan differs by
    /// more than this many GPUs (avoids reallocation thrash).
    pub min_delta: usize,
}

impl Default for Elastic {
    fn default() -> Self {
        Elastic { tick_s: 30.0, penalty_s: 30.0, cap_factor: 2.0, min_delta: 2 }
    }
}

impl Elastic {
    /// Water-filling: distribute `total` GPUs over `jobs` by marginal
    /// throughput gain. Returns the planned GPU count per job.
    fn plan(&self, ctx: &SchedContext, jobs: &[JobId], total: usize) -> Vec<usize> {
        let mut alloc = vec![0usize; jobs.len()];
        let mut remaining = total;
        // Seed: every job would like at least 1 GPU.
        // Greedy: repeatedly give a GPU to the best marginal gain.
        while remaining > 0 {
            let mut best: Option<(usize, f64)> = None;
            for (i, &id) in jobs.iter().enumerate() {
                let spec = &ctx.jobs[id].spec;
                let cap =
                    ((spec.gpus as f64 * self.cap_factor).round() as usize).max(1);
                if alloc[i] >= cap {
                    continue;
                }
                let perf = spec.profile().perf;
                let b = spec.batch as f64;
                let cur = if alloc[i] == 0 {
                    0.0
                } else {
                    perf.throughput(b, 1, alloc[i])
                };
                let nxt = perf.throughput(b, 1, alloc[i] + 1);
                // Normalize by (estimated) remaining work so short jobs
                // are favoured (goodput-weighted fairness surrogate);
                // like the SJF family, the elastic planner only sees the
                // scheduler-visible duration estimate.
                let weight = 1.0 / ctx.estimated_remaining(id).max(1.0);
                let gain = (nxt - cur) * weight;
                if best.map(|(_, g)| gain > g).unwrap_or(true) {
                    best = Some((i, gain));
                }
            }
            match best {
                Some((i, gain)) if gain > 0.0 => {
                    alloc[i] += 1;
                    remaining -= 1;
                }
                _ => break,
            }
        }
        alloc
    }
}

impl Policy for Elastic {
    fn name(&self) -> &'static str {
        "Pollux"
    }

    fn tick_interval(&self) -> Option<f64> {
        Some(self.tick_s)
    }

    fn preemption_penalty(&self) -> f64 {
        self.penalty_s
    }

    fn coalesce_coincident(&self) -> bool {
        true
    }

    fn on_event(&mut self, ctx: &SchedContext, _ev: Event) -> Txn {
        let mut active: Vec<JobId> = ctx.running().to_vec();
        active.extend_from_slice(ctx.pending());
        active.sort_unstable();
        if active.is_empty() {
            return Txn::new();
        }
        let plan = self.plan(ctx, &active, ctx.cluster.total_gpus());

        let mut txn = Txn::new();
        let mut view = ctx.overlay();
        // Phase 1: preempt running jobs whose allocation changes enough
        // (or drops to zero).
        for (i, &id) in active.iter().enumerate() {
            if ctx.jobs[id].state != crate::jobs::JobState::Running {
                continue;
            }
            let held = ctx.jobs[id].gpus_held.len();
            let want = plan[i];
            let delta = held.abs_diff(want);
            if want == 0 || delta > self.min_delta {
                view.release(id);
                txn.preempt(id);
            } else if delta > 0 && ctx.obs().is_enabled() {
                // Hysteresis held the resize: the plan wants a different
                // width but the delta is under min_delta, so we keep the
                // current allocation to avoid reallocation thrash.
                ctx.obs().policy_note(
                    ctx.now(),
                    self.name(),
                    &format!(
                        "holding job {id} at {held} GPUs (plan wants {want}, \
                         delta {delta} <= min_delta {})",
                        self.min_delta
                    ),
                );
            }
        }
        // Phase 2: start eligible pending jobs at their planned width.
        for (i, &id) in active.iter().enumerate() {
            if ctx.jobs[id].state == crate::jobs::JobState::Running {
                continue;
            }
            let want = plan[i].min(ctx.cluster.total_gpus());
            if want == 0 {
                continue;
            }
            let spec = &ctx.jobs[id].spec;
            let solo_gb = spec.profile().mem.mem_gb(spec.batch as f64);
            if let Some(gpus) = placement::consolidated_free_mem(&view, want, solo_gb) {
                view.allocate(id, &gpus);
                txn.start(id, gpus, 1);
            }
        }
        txn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::jobs::JobSpec;
    use crate::perf::interference::InterferenceModel;
    use crate::perf::profiles::ModelKind;
    use crate::sim::engine;

    fn job(id: usize, gpus: usize, iters: u64, arrival: f64) -> JobSpec {
        JobSpec {
            id,
            model: ModelKind::ImageNet,
            gpus,
            iterations: iters,
            batch: 32,
            arrival_s: arrival,
            est_factor: 1.0,
        }
    }

    #[test]
    fn single_job_gets_expanded_allocation() {
        // Alone on the cluster, an elastic job may exceed its request
        // (up to cap) — goodput maximization.
        let trace = vec![job(0, 4, 2000, 0.0)];
        let out = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut Elastic::default(),
        )
        .unwrap();
        let jct = out.jobs[0].jct().unwrap();
        let solo = trace[0].solo_runtime(1);
        assert!(
            jct < solo,
            "elastic expansion should beat the requested gang: {jct} vs {solo}"
        );
    }

    #[test]
    fn all_jobs_finish_under_churn() {
        let trace: Vec<JobSpec> = (0..10)
            .map(|i| job(i, 1 + (i % 4) * 2, 300 + 100 * i as u64, i as f64 * 20.0))
            .collect();
        let out = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut Elastic::default(),
        )
        .unwrap();
        for j in &out.jobs {
            assert_eq!(j.state, crate::jobs::JobState::Finished, "{j:?}");
        }
    }

    #[test]
    fn overload_causes_reallocation_churn() {
        // Many jobs on a small cluster: elastic keeps re-planning, which is
        // exactly its weakness at high load (Fig. 6a).
        let trace: Vec<JobSpec> =
            (0..12).map(|i| job(i, 4, 2000, i as f64 * 5.0)).collect();
        let out = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut Elastic::default(),
        )
        .unwrap();
        assert!(out.preemptions > 0, "overload should trigger reallocation");
    }
}

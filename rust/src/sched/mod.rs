//! The scheduling policies: the six the paper evaluates (§VI-A Baselines)
//! — FIFO, SJF, Tiresias, Pollux-like elastic, SJF-FFS and the
//! contribution, SJF-BSBF — plus SJF-BSBF-k, the k-way sharing-set
//! generalization of DESIGN.md §17. All implement the event-driven
//! [`crate::sched_core::Policy`] — `on_event(&SchedContext, Event) -> Txn`
//! — and run unchanged on the simulator and (for the non-preemptive ones)
//! the physical coordinator, which share the `sched_core` validation and
//! apply path. See DESIGN.md §9 for the policy-author guide.

pub mod elastic;
pub mod fifo;
pub mod sjf;
pub mod sjf_bsbf;
pub mod sjf_bsbf_k;
pub mod sjf_ffs;
pub mod tiresias;

pub use elastic::Elastic;
pub use fifo::Fifo;
pub use sjf::Sjf;
pub use sjf_bsbf::SjfBsbf;
pub use sjf_bsbf_k::SjfBsbfK;
pub use sjf_ffs::SjfFfs;
pub use tiresias::Tiresias;

use crate::sched_core::Policy;

/// All policy names: the paper's table order, then the §17 extension.
pub const POLICY_NAMES: [&str; 7] =
    ["FIFO", "SJF", "Tiresias", "Pollux", "SJF-FFS", "SJF-BSBF", "SJF-BSBF-k"];

/// The six policies of the paper's evaluation tables — what
/// `campaign::CampaignSpec::paper_preset` sweeps. Excludes the k-way
/// extension so the headline reproduction matrix stays the paper's.
pub const PAPER_POLICY_NAMES: [&str; 6] =
    ["FIFO", "SJF", "Tiresias", "Pollux", "SJF-FFS", "SJF-BSBF"];

/// Instantiate a policy by its paper name (CLI / bench entry point).
pub fn by_name(name: &str) -> Option<Box<dyn Policy>> {
    Some(match name {
        "FIFO" => Box::new(Fifo::default()),
        "SJF" => Box::new(Sjf::default()),
        "Tiresias" => Box::new(Tiresias::default()),
        "Pollux" => Box::new(Elastic::default()),
        "SJF-FFS" => Box::new(SjfFfs::default()),
        "SJF-BSBF" => Box::new(SjfBsbf::default()),
        "SJF-BSBF-k" => Box::new(SjfBsbfK::default()),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_name_instantiates() {
        for name in POLICY_NAMES {
            let p = by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(p.name(), name);
        }
        assert!(by_name("bogus").is_none());
    }

    #[test]
    fn paper_names_are_a_prefix_of_all_names() {
        assert_eq!(&POLICY_NAMES[..PAPER_POLICY_NAMES.len()], &PAPER_POLICY_NAMES);
    }
}

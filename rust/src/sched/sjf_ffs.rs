//! SJF-FFS: SJF with **first-fit sharing** (§VI-A baseline 4) — the
//! aggressive-sharing strawman SJF-BSBF is compared against.
//!
//! "similar to SJF-BSBF except that it does not search the best sharing
//! configuration ... but allocates the job to those GPUs that only have one
//! job in a first fit manner if the free GPUs are not sufficient". It
//! always shares when memory allows (κ = 0 unconditionally), picking the
//! largest memory-feasible sub-batch — no Theorem 1, no interference check.
//! Like the whole SJF family it ranks its queue on the *estimated*
//! remaining runtime ([`SchedContext::pending_by_estimate`]); since it
//! never consults durations beyond that order, it is less
//! estimate-sensitive than BSBF.
//!
//! The first fit honors the cluster's share cap C (DESIGN.md §17): with a
//! raised cap it packs onto any GPU with a spare slot whose *summed*
//! resident footprint (Eq. 9) leaves room for at least sub-batch 1. At
//! C = 2 the shareable set is exactly the one-job set and the resident
//! sum has one term, so the paper configuration is bit-for-bit unchanged
//! (pinned by `rust/tests/share_cap.rs`).

use std::collections::HashMap;

use crate::cluster::{placement, AllocView};
use crate::jobs::JobId;
use crate::sched_core::{Event, Policy, SchedContext, Txn};

#[derive(Debug, Default)]
pub struct SjfFfs;

impl Policy for SjfFfs {
    fn name(&self) -> &'static str {
        "SJF-FFS"
    }

    fn coalesce_coincident(&self) -> bool {
        true
    }

    fn on_event(&mut self, ctx: &SchedContext, _ev: Event) -> Txn {
        let mut plan = ctx.overlay();
        let mut txn = Txn::new();
        // Track hypothetical accumulation choices for memory math of jobs
        // we start within this same batch of decisions.
        let mut started_accum: HashMap<JobId, u32> = HashMap::new();

        let cap = plan.max_share();
        for id in ctx.pending_by_estimate() {
            if plan.free_count() == 0
                && plan.one_job_count() == 0
                && (cap <= 2 || plan.shareable_gpus().is_empty())
            {
                // Neither an exclusive start nor a first-fit share can
                // place anything (every gang needs ≥ 1 GPU and the line-9
                // gate rejects before any side effect), so the remaining
                // candidates are all skips — same outcome, cut short. At
                // C = 2 the one-job count answers the share question in
                // O(1); only a raised cap pays the shareable scan.
                break;
            }
            let need = ctx.jobs[id].spec.gpus;
            let prof = ctx.jobs[id].spec.profile();
            let solo_gb = prof.mem.mem_gb(ctx.jobs[id].spec.batch as f64);
            // 1) plain SJF on free GPUs
            if let Some(gpus) = placement::consolidated_free_mem(&plan, need, solo_gb) {
                plan.allocate(id, &gpus);
                started_accum.insert(id, 1);
                txn.start(id, gpus, 1);
                continue;
            }
            // 2) first-fit over GPUs with a spare share slot (exactly the
            //    one-job set at C = 2), memory-checked only.
            let shareable = plan.shareable_gpus();
            if shareable.len() + plan.free_count() < need {
                continue;
            }
            let free = plan.free_gpus();
            // Tightest per-GPU headroom across the GPUs we take (each GPU
            // has its own per-type budget under heterogeneity); the
            // sub-batch must fit next to the *summed* co-runner footprint
            // (Eq. 9 over all residents — one term at C = 2).
            let mut chosen: Vec<usize> = Vec::new();
            let mut min_headroom = f64::INFINITY;
            for &g in &shareable {
                if chosen.len() == need {
                    break;
                }
                let mut headroom = plan.mem_gb(g);
                for other in plan.residents(g) {
                    let orec = &ctx.jobs[other];
                    let o_accum =
                        started_accum.get(&other).copied().unwrap_or(orec.accum_step);
                    headroom -= orec
                        .spec
                        .profile()
                        .mem
                        .mem_gb(orec.spec.batch as f64 / o_accum as f64);
                }
                // Feasible at all? (even sub-batch 1 must fit)
                if prof.mem.mem_gb(1.0) <= headroom {
                    chosen.push(g);
                    min_headroom = min_headroom.min(headroom);
                }
            }
            // Fill the remainder with free GPUs (their whole budget is
            // headroom) — skipping GPUs that cannot hold even sub-batch 1,
            // which would otherwise poison the headroom minimum (a no-op
            // on uniform topologies).
            for &g in &free {
                if chosen.len() == need {
                    break;
                }
                let budget = plan.mem_gb(g);
                if prof.mem.mem_gb(1.0) <= budget {
                    chosen.push(g);
                    min_headroom = min_headroom.min(budget);
                }
            }
            if chosen.len() < need || chosen.is_empty() {
                if ctx.obs().is_enabled() {
                    ctx.obs().policy_note(
                        ctx.now(),
                        self.name(),
                        &format!(
                            "job {id}: first-fit coverage failed \
                             ({}/{need} memory-feasible GPUs)",
                            chosen.len()
                        ),
                    );
                }
                continue;
            }
            let Some(sub) = prof.mem.max_sub_batch(ctx.jobs[id].spec.batch, min_headroom)
            else {
                if ctx.obs().is_enabled() {
                    ctx.obs().policy_note(
                        ctx.now(),
                        self.name(),
                        &format!(
                            "job {id}: no sub-batch fits headroom \
                             {min_headroom:.2} GB"
                        ),
                    );
                }
                continue;
            };
            let accum = (ctx.jobs[id].spec.batch / sub).max(1);
            plan.allocate(id, &chosen);
            started_accum.insert(id, accum);
            txn.start(id, chosen, accum);
        }
        txn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::jobs::JobSpec;
    use crate::perf::interference::InterferenceModel;
    use crate::perf::profiles::ModelKind;
    use crate::sim::engine;

    fn job(
        id: usize,
        model: ModelKind,
        gpus: usize,
        iters: u64,
        batch: u32,
        arrival: f64,
    ) -> JobSpec {
        JobSpec { id, model, gpus, iterations: iters, batch, arrival_s: arrival, est_factor: 1.0 }
    }

    #[test]
    fn shares_aggressively_when_cluster_full() {
        // Fill all 16 GPUs with one CIFAR job, then a second arrives: FFS
        // must co-locate instead of queueing.
        let trace = vec![
            job(0, ModelKind::Cifar10, 16, 3000, 128, 0.0),
            job(1, ModelKind::Cifar10, 16, 100, 128, 1.0),
        ];
        let out = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut SjfFfs,
        )
        .unwrap();
        let q1 = out.jobs[1].queueing_delay().unwrap();
        assert!(q1 < 1.0, "FFS should start immediately via sharing, q={q1}");
    }

    #[test]
    fn shares_even_catastrophic_pairs() {
        // Two YoloV3 at small batch: ξ ≈ 6 but memory fits — FFS shares
        // anyway (that is its defining flaw vs BSBF).
        let trace = vec![
            job(0, ModelKind::YoloV3, 16, 1500, 4, 0.0),
            job(1, ModelKind::YoloV3, 16, 1500, 4, 1.0),
        ];
        let out = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut SjfFfs,
        )
        .unwrap();
        let q1 = out.jobs[1].queueing_delay().unwrap();
        assert!(q1 < 1.0, "FFS shares blindly, q={q1}");
    }

    #[test]
    fn respects_memory_infeasibility() {
        // Two batch-16 YoloV3: resident 10.1 GB leaves < base GB — cannot
        // share; second job must wait for the first to finish.
        let trace = vec![
            job(0, ModelKind::YoloV3, 16, 500, 16, 0.0),
            job(1, ModelKind::YoloV3, 16, 500, 16, 1.0),
        ];
        let out = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut SjfFfs,
        )
        .unwrap();
        let q1 = out.jobs[1].queueing_delay().unwrap();
        assert!(q1 > 1.0, "memory-infeasible share must queue, q={q1}");
    }

    #[test]
    fn packs_a_third_resident_when_cap_raised() {
        // At C = 3 first-fit packs a third CIFAR10 next to two residents
        // (3 × 4.3 GB > 11 GB, but sub-batch halving fits); at the paper's
        // C = 2 the same job must queue.
        let trace = vec![
            job(0, ModelKind::Cifar10, 16, 3000, 128, 0.0),
            job(1, ModelKind::Cifar10, 16, 2000, 128, 1.0),
            job(2, ModelKind::Cifar10, 16, 100, 128, 2.0),
        ];
        let mut cfg = ClusterConfig::physical();
        cfg.max_share = 3;
        let out3 =
            engine::run(cfg, &trace, InterferenceModel::new(), &mut SjfFfs).unwrap();
        assert!(
            out3.jobs[2].queueing_delay().unwrap() < 1.0,
            "C = 3 first-fit must admit the third job: {:?}",
            out3.jobs[2]
        );
        assert!(out3.jobs[2].accum_step > 1, "third resident must shrink its sub-batch");
        let out2 = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut SjfFfs,
        )
        .unwrap();
        assert!(
            out2.jobs[2].queueing_delay().unwrap() > 1.0,
            "C = 2 must queue the third job: {:?}",
            out2.jobs[2]
        );
    }

    #[test]
    fn shrinks_sub_batch_to_fit() {
        // New BERT@16 next to a running CIFAR10@128 must shrink its
        // sub-batch (gradient accumulation) to fit the 11 GB budget.
        let trace = vec![
            job(0, ModelKind::Cifar10, 16, 2000, 128, 0.0),
            job(1, ModelKind::Bert, 16, 200, 16, 1.0),
        ];
        let out = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut SjfFfs,
        )
        .unwrap();
        assert!(out.jobs[1].accum_step > 1, "must gradient-accumulate");
    }
}

//! Tiresias (NSDI'19) baseline: preemptive discretized 2D-LAS (§VI-A
//! baseline 3 — "prioritizes least attained service jobs (consumed GPU
//! numbers and training iterations) ... helps short-term jobs escape from
//! resource starvation without any prior information").
//!
//! Simplification vs the full system (documented in DESIGN.md): two
//! discrete priority queues split at an attained-service threshold
//! (GPU·seconds), FIFO within a queue; reallocation happens at every event
//! plus a periodic tick; demoted/evicted jobs pay a fixed
//! checkpoint/restore penalty before they can restart (the paper's
//! migration overhead).

use crate::cluster::placement;
use crate::jobs::JobId;
use crate::sched_core::{Event, Policy, SchedContext, Txn};

#[derive(Debug)]
pub struct Tiresias {
    /// Attained-service boundary between queue 0 (high) and queue 1 (low).
    pub threshold_gpu_s: f64,
    /// Reallocation tick.
    pub tick_s: f64,
    /// Checkpoint/restore cost charged to a preempted job.
    pub penalty_s: f64,
}

impl Default for Tiresias {
    fn default() -> Self {
        // ~ one hour of single-GPU service, the paper-trace scale knob.
        Tiresias { threshold_gpu_s: 3600.0, tick_s: 60.0, penalty_s: 30.0 }
    }
}

impl Tiresias {
    /// 2D-LAS queue of a job: 0 (high priority) below the
    /// attained-service threshold, 1 (low) at or above it.
    fn queue_of(&self, ctx: &SchedContext, id: JobId) -> u8 {
        u8::from(ctx.attained_service(id) >= self.threshold_gpu_s)
    }
}

impl Policy for Tiresias {
    fn name(&self) -> &'static str {
        "Tiresias"
    }

    fn tick_interval(&self) -> Option<f64> {
        Some(self.tick_s)
    }

    fn preemption_penalty(&self) -> f64 {
        self.penalty_s
    }

    fn coalesce_coincident(&self) -> bool {
        true
    }

    fn on_event(&mut self, ctx: &SchedContext, _ev: Event) -> Txn {
        // Rank everyone active (running + eligible pending) by 2D-LAS
        // priority (queue, arrival, id). Only the running set — bounded
        // by cluster size — is sorted here; the pending backlog comes
        // pre-sorted by (arrival, id) from the context's incremental
        // index and is merged in per queue, so a pass over a deep queue
        // never re-sorts it.
        let mut running: Vec<(u8, f64, JobId)> = ctx
            .running()
            .iter()
            .map(|&id| (self.queue_of(ctx, id), ctx.jobs[id].spec.arrival_s, id))
            .collect();
        running.sort_by(|a, b| {
            a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)).then(a.2.cmp(&b.2))
        });

        // Greedy exclusive admission in priority order. Admission stops
        // outright once the budget hits zero: every gang needs ≥ 1 GPU,
        // so no later candidate could be admitted anyway.
        let total = ctx.cluster.total_gpus();
        let mut budget = total;
        let mut should_run: Vec<JobId> = Vec::new();
        let mut run_iter = running.iter().copied().peekable();
        'admit: for q in 0..2u8 {
            let mut pend = ctx
                .pending_by_arrival()
                .filter(|&id| self.queue_of(ctx, id) == q)
                .peekable();
            loop {
                if budget == 0 {
                    break 'admit;
                }
                let next_run = run_iter.peek().copied().filter(|r| r.0 == q);
                let id = match (next_run, pend.peek().copied()) {
                    (None, None) => break,
                    (Some((_, _, rid)), None) => {
                        run_iter.next();
                        rid
                    }
                    (None, Some(pid)) => {
                        pend.next();
                        pid
                    }
                    (Some((_, ra, rid)), Some(pid)) => {
                        let pa = ctx.jobs[pid].spec.arrival_s;
                        if ra.total_cmp(&pa).then(rid.cmp(&pid)).is_le() {
                            run_iter.next();
                            rid
                        } else {
                            pend.next();
                            pid
                        }
                    }
                };
                let need = ctx.jobs[id].spec.gpus;
                if need <= budget {
                    should_run.push(id);
                    budget -= need;
                }
            }
        }

        let mut txn = Txn::new();
        let mut plan = ctx.overlay();
        // Preempt running jobs that lost their slot.
        for &id in ctx.running() {
            if !should_run.contains(&id) {
                plan.release(id);
                txn.preempt(id);
                // Audit the demotion with its 2D-LAS queue: eviction from
                // queue 1 is the threshold doing its job; from queue 0 it
                // is pure contention.
                if ctx.obs().is_enabled() {
                    let q = self.queue_of(ctx, id);
                    ctx.obs().policy_note(
                        ctx.now(),
                        self.name(),
                        &format!("evicting job {id} from queue {q}"),
                    );
                }
            }
        }
        // Start admitted pending jobs on the freed/free GPUs.
        for &id in &should_run {
            if ctx.jobs[id].state == crate::jobs::JobState::Running {
                continue;
            }
            let spec = &ctx.jobs[id].spec;
            let solo_gb = spec.profile().mem.mem_gb(spec.batch as f64);
            if let Some(gpus) =
                placement::consolidated_free_mem(&plan, spec.gpus, solo_gb)
            {
                plan.allocate(id, &gpus);
                txn.start(id, gpus, 1);
            }
        }
        txn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::jobs::JobSpec;
    use crate::perf::interference::InterferenceModel;
    use crate::perf::profiles::ModelKind;
    use crate::sim::engine;

    fn job(id: usize, gpus: usize, iters: u64, arrival: f64) -> JobSpec {
        JobSpec {
            id,
            model: ModelKind::Cifar10,
            gpus,
            iterations: iters,
            batch: 128,
            arrival_s: arrival,
            est_factor: 1.0,
        }
    }

    #[test]
    fn preempts_long_job_for_newcomer() {
        // A long 16-GPU hog crosses the service threshold; a newcomer with
        // zero attained service must preempt it.
        let trace = vec![job(0, 16, 100_000, 0.0), job(1, 16, 100, 4000.0)];
        let out = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut Tiresias::default(),
        )
        .unwrap();
        assert!(out.preemptions >= 1, "expected at least one preemption");
        // The newcomer should finish long before the hog.
        assert!(out.jobs[1].finish_s.unwrap() < out.jobs[0].finish_s.unwrap());
        // And its queueing is bounded by ~tick + penalty, not the hog's JCT.
        assert!(out.jobs[1].queueing_delay().unwrap() < 200.0);
    }

    #[test]
    fn no_preemption_when_cluster_fits_everyone() {
        let trace = vec![job(0, 4, 500, 0.0), job(1, 4, 500, 1.0), job(2, 8, 500, 2.0)];
        let out = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut Tiresias::default(),
        )
        .unwrap();
        assert_eq!(out.preemptions, 0);
    }

    #[test]
    fn preempted_job_eventually_finishes() {
        let trace = vec![job(0, 16, 20_000, 0.0), job(1, 16, 100, 3700.0)];
        let out = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut Tiresias::default(),
        )
        .unwrap();
        for j in &out.jobs {
            assert_eq!(j.state, crate::jobs::JobState::Finished);
        }
    }
}

//! **SJF-BSBF-k** — SJF-BSBF generalized to k-way sharing sets
//! (DESIGN.md §17): the share cap C comes from the cluster config
//! instead of being hard-wired to pairs.
//!
//! Per pending job, in ascending estimated-remaining-runtime order:
//! 1. enough free GPUs → consolidated exclusive start (Alg. 1 lines 6–7);
//! 2. otherwise, if free + *shareable* GPUs (1 ≤ load < C) cover the
//!    request: score every distinct resident *set* with the generalized
//!    Algorithm 2 ([`share_set_scaling_placed`]) — composed interference
//!    under the configured [`Composition`], Eq. 9 memory feasibility over
//!    all residents, fluid-drain κ endpoints — keep the sets whose best
//!    configuration says *share*, sort them by set JCT ascending and take
//!    their GPUs until the gang is covered, topping up from free GPUs
//!    only when the shared ones do not suffice;
//! 3. if the job's best option is not to share, it stays pending.
//!
//! **C = 2 parity**: with `max_share == 2` every shareable GPU holds
//! exactly one resident, resident-set grouping degenerates to the
//! per-owner grouping of [`super::SjfBsbf`], and the set scorer delegates
//! to [`crate::pair::batch_size_scaling_placed`] — so this policy is
//! bit-for-bit identical to SJF-BSBF on any C = 2 cluster (pinned on the
//! 240-job golden trace by `rust/tests/share_cap.rs`).

use std::collections::{BTreeMap, HashMap};

use crate::cluster::{placement, AllocView, GpuId};
use crate::jobs::{JobId, JobRecord};
use crate::obskit::Alg2Audit;
use crate::perf::interference::Composition;
use crate::perf::share_set::{share_set_scaling_placed, ShareSetConfig};
use crate::perf::GangSpan;
use crate::sched_core::{Event, Policy, SchedContext, Txn};

#[derive(Debug)]
pub struct SjfBsbfK {
    /// Scheduling-op latencies (seconds) for the §V-4 overhead claim.
    pub op_latencies_s: Vec<f64>,
    /// How per-pair ξ factors compose over a resident set.
    pub composition: Composition,
    /// Ablation: sweep sub-batches in the generalized Algorithm 2.
    pub sweep_batches: bool,
    /// Ablation: apply the share-or-wait gate (false = accept every
    /// memory-feasible share).
    pub theorem1_gate: bool,
    /// Ablation: sort candidate sets by set JCT before taking GPUs.
    pub sort_by_benefit: bool,
}

impl Default for SjfBsbfK {
    fn default() -> Self {
        SjfBsbfK {
            op_latencies_s: Vec::new(),
            composition: Composition::MaxDegradation,
            sweep_batches: true,
            theorem1_gate: true,
            sort_by_benefit: true,
        }
    }
}

impl Policy for SjfBsbfK {
    fn name(&self) -> &'static str {
        "SJF-BSBF-k"
    }

    fn coalesce_coincident(&self) -> bool {
        true
    }

    fn on_event(&mut self, ctx: &SchedContext, _ev: Event) -> Txn {
        let t0 = std::time::Instant::now();
        let mut plan = ctx.overlay();
        let cap = plan.max_share();
        let mut txn = Txn::new();
        // Accumulation step + planned gang of jobs started in this batch.
        let mut started: HashMap<JobId, (u32, Vec<GpuId>)> = HashMap::new();

        for id in ctx.pending_by_estimate() {
            if plan.free_count() == 0
                && plan.one_job_count() == 0
                && (cap <= 2 || plan.shareable_gpus().is_empty())
            {
                // Nothing can be placed: no free GPU for an exclusive
                // start and no GPU with a spare share slot. At C = 2 the
                // one-job count answers this in O(1); a raised cap may
                // still have multi-resident GPUs with room, so only then
                // pay the shareable scan.
                break;
            }
            let need = ctx.jobs[id].spec.gpus;
            let prof = ctx.jobs[id].spec.profile();
            let solo_gb = prof.mem.mem_gb(ctx.jobs[id].spec.batch as f64);
            // --- exclusive start on free GPUs
            if let Some(gpus) = placement::consolidated_free_mem(&plan, need, solo_gb) {
                plan.allocate(id, &gpus);
                started.insert(id, (1, gpus.clone()));
                txn.start(id, gpus, 1);
                continue;
            }
            // --- gate: free + shareable GPUs must cover the request
            let shareable = plan.shareable_gpus();
            if shareable.len() + plan.free_count() < need {
                continue;
            }
            let free = plan.free_gpus();
            // --- generalized lines 10-13: score every distinct resident
            // set (BTreeMap over the resident vectors: deterministic
            // iteration; at C = 2 each key is a one-owner vector, so this
            // is exactly SJF-BSBF's per-owner grouping and order).
            let mut sets: BTreeMap<Vec<JobId>, Vec<GpuId>> = BTreeMap::new();
            for &g in &shareable {
                sets.entry(plan.residents(g)).or_default().push(g);
            }
            let mut candidates: Vec<(Vec<GpuId>, ShareSetConfig)> = Vec::new();
            for (residents, gpus) in sets {
                // Residents started in this same pass carry hypothetical
                // accumulation steps and placements; running residents'
                // `remaining_iters` are folded to `now` (lazy ledger).
                let mut orecs: Vec<JobRecord> = Vec::with_capacity(residents.len());
                let mut spans: Vec<GangSpan> = Vec::with_capacity(residents.len());
                for &owner in &residents {
                    let mut orec = ctx.jobs[owner].clone();
                    orec.remaining_iters = ctx.remaining_iters(owner);
                    let run_gpus: &[GpuId] = match started.get(&owner) {
                        Some((a, held)) => {
                            orec.accum_step = *a;
                            held
                        }
                        None => &ctx.jobs[owner].gpus_held,
                    };
                    spans.push(plan.span_of(run_gpus));
                    orecs.push(orec);
                }
                let shared = &gpus[..need.min(gpus.len())];
                let new_span = plan.span_of(shared);
                let budget = shared
                    .iter()
                    .map(|&g| plan.mem_gb(g))
                    .fold(f64::INFINITY, f64::min);
                let Some(cfg) = share_set_scaling_placed(
                    &ctx.jobs[id],
                    &orecs,
                    need,
                    budget,
                    &ctx.xi,
                    self.composition,
                    self.sweep_batches,
                    &new_span,
                    &spans,
                ) else {
                    if ctx.obs().is_enabled() {
                        ctx.obs().alg2_candidate(
                            ctx.now(),
                            &Alg2Audit {
                                job: id,
                                owner: residents[0],
                                accepted: false,
                                reason: "memory-infeasible",
                                accum_step: None,
                                pair_jct_s: None,
                            },
                        );
                    }
                    continue;
                };
                let accepted = cfg.share || !self.theorem1_gate;
                if ctx.obs().is_enabled() {
                    ctx.obs().alg2_candidate(
                        ctx.now(),
                        &Alg2Audit {
                            job: id,
                            owner: residents[0],
                            accepted,
                            reason: if cfg.share {
                                "share"
                            } else if !self.theorem1_gate {
                                "gate-ablated"
                            } else {
                                "exclusive-preferred"
                            },
                            accum_step: Some(cfg.accum_step),
                            pair_jct_s: Some(cfg.set_jct),
                        },
                    );
                }
                if accepted {
                    candidates.push((gpus, cfg));
                }
            }
            // --- best sharing benefit first (stable sort: ties keep the
            // deterministic resident-set order)
            if self.sort_by_benefit {
                candidates.sort_by(|a, b| a.1.set_jct.total_cmp(&b.1.set_jct));
            }
            // --- take GPUs from the best sets
            let mut chosen: Vec<GpuId> = Vec::new();
            let mut accum = 1u32;
            for (gpus, cfg) in &candidates {
                if chosen.len() >= need {
                    break;
                }
                for &g in gpus {
                    if chosen.len() == need {
                        break;
                    }
                    chosen.push(g);
                }
                accum = accum.max(cfg.accum_step);
            }
            if chosen.is_empty() {
                continue; // best benefit is to wait everywhere
            }
            // Top up from free GPUs only if sharing alone cannot cover.
            let sub_gb = prof.mem.mem_gb(ctx.jobs[id].spec.batch as f64 / accum as f64);
            for &g in &free {
                if chosen.len() == need {
                    break;
                }
                if plan.mem_gb(g) + 1e-9 >= sub_gb {
                    chosen.push(g);
                }
            }
            if chosen.len() < need {
                continue;
            }
            plan.allocate(id, &chosen);
            started.insert(id, (accum, chosen.clone()));
            txn.start(id, chosen, accum);
        }
        self.op_latencies_s.push(t0.elapsed().as_secs_f64());
        txn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::jobs::JobSpec;
    use crate::perf::interference::InterferenceModel;
    use crate::perf::profiles::ModelKind;
    use crate::sched::SjfBsbf;
    use crate::sim::engine;

    fn job(
        id: usize,
        model: ModelKind,
        gpus: usize,
        iters: u64,
        batch: u32,
        arrival: f64,
    ) -> JobSpec {
        JobSpec { id, model, gpus, iterations: iters, batch, arrival_s: arrival, est_factor: 1.0 }
    }

    fn polite_mixed_trace() -> Vec<JobSpec> {
        vec![
            job(0, ModelKind::Cifar10, 16, 3000, 128, 0.0),
            job(1, ModelKind::Ncf, 16, 2000, 4096, 1.0),
            job(2, ModelKind::Ncf, 16, 500, 4096, 2.0),
            job(3, ModelKind::Bert, 8, 400, 16, 3.0),
            job(4, ModelKind::YoloV3, 8, 600, 4, 4.0),
        ]
    }

    #[test]
    fn c2_matches_sjf_bsbf_exactly() {
        // With the default C = 2 cluster the k-way policy must reproduce
        // SJF-BSBF bit-for-bit (the full-scale gate lives in
        // rust/tests/share_cap.rs; this is the unit-sized canary).
        let trace = polite_mixed_trace();
        let a = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut SjfBsbf::default(),
        )
        .unwrap();
        let b = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut SjfBsbfK::default(),
        )
        .unwrap();
        assert_eq!(format!("{:?}", a.jobs), format!("{:?}", b.jobs));
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
    }

    #[test]
    fn c3_admits_a_third_polite_resident() {
        // CIFAR10@128 (4.3 GB) + NCF@4096 (3.4 GB) leave 3.3 GB: a second
        // NCF fits at sub-batch 2048 (2.1 GB), and the composed ξ of the
        // polite trio stays ~1.1 — so C = 3 should co-locate the third
        // job immediately while C = 2 must queue it.
        let trace = vec![
            job(0, ModelKind::Cifar10, 16, 3000, 128, 0.0),
            job(1, ModelKind::Ncf, 16, 2000, 4096, 1.0),
            job(2, ModelKind::Ncf, 16, 500, 4096, 2.0),
        ];
        let mut c3 = ClusterConfig::physical();
        c3.max_share = 3;
        let out3 = engine::run(
            c3,
            &trace,
            InterferenceModel::new(),
            &mut SjfBsbfK::default(),
        )
        .unwrap();
        assert!(
            out3.jobs[2].queueing_delay().unwrap() < 1.0,
            "C = 3 must admit the third resident: {:?}",
            out3.jobs[2]
        );
        let out2 = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut SjfBsbfK::default(),
        )
        .unwrap();
        assert!(
            out2.jobs[2].queueing_delay().unwrap() > 1.0,
            "C = 2 must queue the third job: {:?}",
            out2.jobs[2]
        );
    }

    #[test]
    fn still_declines_catastrophic_shares_at_any_cap() {
        // The Theorem-1 gate survives the generalization: two small-batch
        // YoloV3 (ξ ≈ 6) must not co-locate even with spare share slots.
        let trace = vec![
            job(0, ModelKind::YoloV3, 16, 1500, 4, 0.0),
            job(1, ModelKind::YoloV3, 16, 1500, 4, 1.0),
        ];
        let mut c4 = ClusterConfig::physical();
        c4.max_share = 4;
        let out = engine::run(
            c4,
            &trace,
            InterferenceModel::new(),
            &mut SjfBsbfK::default(),
        )
        .unwrap();
        let q1 = out.jobs[1].queueing_delay().unwrap();
        assert!(q1 > 1.0, "toxic share must still be refused, q={q1}");
    }

    #[test]
    fn product_composition_is_more_conservative() {
        // PairwiseProduct inflates composed ξ, so it can only refuse more
        // shares than MaxDegradation — the third job's start must not get
        // *earlier* when switching composition.
        let trace = vec![
            job(0, ModelKind::Cifar10, 16, 3000, 128, 0.0),
            job(1, ModelKind::Ncf, 16, 2000, 4096, 1.0),
            job(2, ModelKind::Ncf, 16, 500, 4096, 2.0),
        ];
        let mut c3 = ClusterConfig::physical();
        c3.max_share = 3;
        let mx = engine::run(
            c3,
            &trace,
            InterferenceModel::new(),
            &mut SjfBsbfK::default(),
        )
        .unwrap();
        let mut prod_policy = SjfBsbfK {
            composition: Composition::PairwiseProduct,
            ..SjfBsbfK::default()
        };
        let prod =
            engine::run(c3, &trace, InterferenceModel::new(), &mut prod_policy).unwrap();
        let q_mx = mx.jobs[2].queueing_delay().unwrap();
        let q_prod = prod.jobs[2].queueing_delay().unwrap();
        assert!(q_prod + 1e-9 >= q_mx, "product must not share more: {q_prod} vs {q_mx}");
    }
}

//! **SJF-BSBF** — Shortest Job First with Best Sharing Benefit First: the
//! paper's contribution (Algorithm 1), built on Theorem 1 + Algorithm 2
//! (`crate::pair`).
//!
//! Per pending job, in ascending remaining-runtime order (line 1):
//! 1. enough free GPUs → consolidated exclusive start (lines 6–7);
//! 2. otherwise, if free + one-job GPUs cover the request (line 9): run
//!    Algorithm 2 against every distinct running job that owns one-job
//!    GPUs, keep the pairs whose best configuration says *share* (SF,
//!    lines 10–13), sort them by pair JCT ascending (line 14) and take
//!    their GPUs until the gang is covered (lines 15–17) — topping up from
//!    free GPUs only when the shared ones do not suffice (the paper keeps
//!    free GPUs for later arrivals since the shared GPUs bound the JCT);
//! 3. if the job's best option is *not* to share, it stays pending — the
//!    wise refusal that separates BSBF from FFS (Fig. 6b).
//!
//! The new job's accumulation step is the *most conservative* (largest s)
//! among the chosen partners so memory fits everywhere.
//!
//! Since workload v2 every decision input is *estimated*: the line-1 SJF
//! order ranks on `SchedContext::estimated_remaining` and Algorithm 2's
//! pair-JCT inputs are the estimated remaining iterations of both sides
//! — with the oracle estimator both are bit-identical to the paper's
//! perfect-information setting, while `simulate --estimator noisy:σ`
//! answers the robustness question (does the sharing benefit survive
//! misprediction?) the paper leaves open.

use std::collections::{BTreeMap, HashMap};

use crate::cluster::{placement, AllocView, GpuId};
use crate::jobs::JobId;
use crate::obskit::Alg2Audit;
use crate::pair::{batch_size_scaling_placed, SharingConfig};
use crate::sched_core::{Event, Policy, SchedContext, Txn};

#[derive(Debug)]
pub struct SjfBsbf {
    /// Scheduling-op latencies (seconds) for the §V-4 overhead claim.
    pub op_latencies_s: Vec<f64>,
    /// Ablation: sweep sub-batches in Algorithm 2 (false = no gradient
    /// accumulation; sharing requires the full batches to jointly fit).
    pub sweep_batches: bool,
    /// Ablation: apply the Theorem-1 share-or-wait gate (false = accept
    /// every memory-feasible share like SJF-FFS, but still batch-scaled).
    pub theorem1_gate: bool,
    /// Ablation: sort candidates by pair JCT (Alg. 1 line 14) before
    /// taking GPUs (false = arbitrary owner order).
    pub sort_by_benefit: bool,
}

impl Default for SjfBsbf {
    fn default() -> Self {
        SjfBsbf {
            op_latencies_s: Vec::new(),
            sweep_batches: true,
            theorem1_gate: true,
            sort_by_benefit: true,
        }
    }
}

impl Policy for SjfBsbf {
    fn name(&self) -> &'static str {
        "SJF-BSBF"
    }

    fn coalesce_coincident(&self) -> bool {
        true
    }

    fn on_event(&mut self, ctx: &SchedContext, _ev: Event) -> Txn {
        let t0 = std::time::Instant::now();
        let mut plan = ctx.overlay();
        let mut txn = Txn::new();
        // Accumulation step + planned gang of jobs started in this batch
        // (their memory footprint and placement matter for later
        // candidates in the same pass).
        let mut started: HashMap<JobId, (u32, Vec<GpuId>)> = HashMap::new();

        for id in ctx.pending_by_estimate() {
            if plan.free_count() == 0 && plan.one_job_count() == 0 {
                // Neither an exclusive start nor a share can place
                // anything (every gang needs ≥ 1 GPU and the line-9 gate
                // rejects before any Algorithm-2 work or audit), so the
                // remaining candidates are all skips — same outcome.
                break;
            }
            let need = ctx.jobs[id].spec.gpus;
            let prof = ctx.jobs[id].spec.profile();
            let solo_gb = prof.mem.mem_gb(ctx.jobs[id].spec.batch as f64);
            // --- lines 6-7: exclusive start on free GPUs
            if let Some(gpus) = placement::consolidated_free_mem(&plan, need, solo_gb) {
                plan.allocate(id, &gpus);
                started.insert(id, (1, gpus.clone()));
                txn.start(id, gpus, 1);
                continue;
            }
            // --- line 9 gate: free + one-job GPUs must cover the request
            if plan.one_job_count() + plan.free_count() < need {
                continue;
            }
            let one_job = plan.one_job_gpus();
            let free = plan.free_gpus();
            // --- lines 10-13: Algorithm 2 per distinct running owner
            // (BTreeMap: owner iteration order — the tiebreak when pair
            // JCTs are equal or the benefit sort is ablated off — is
            // deterministic instead of hash-seeded).
            let mut owners: BTreeMap<JobId, Vec<GpuId>> = BTreeMap::new();
            for &g in &one_job {
                let owner = plan.owner(g).expect("one-job GPU has an owner");
                owners.entry(owner).or_default().push(g);
            }
            let mut candidates: Vec<(JobId, Vec<GpuId>, SharingConfig)> = Vec::new();
            for (owner, gpus) in owners {
                // A job we just started this pass has a hypothetical accum
                // step and placement; respect both. A running owner's
                // stored `remaining_iters` is its value at the last settle
                // (lazy integration) — fold it to `now` for the pair-JCT
                // inputs.
                let mut orec = ctx.jobs[owner].clone();
                orec.remaining_iters = ctx.remaining_iters(owner);
                let run_gpus: &[GpuId] = match started.get(&owner) {
                    Some((a, held)) => {
                        orec.accum_step = *a;
                        held
                    }
                    None => &ctx.jobs[owner].gpus_held,
                };
                // Locality-true Eq. 2/4/7: the gang-assembly below takes
                // at most the first `need` GPUs of each partner, so that
                // prefix — not the owner's whole one-job set — is the
                // placement this candidate is scored on (a multi-owner
                // assembly is still estimated pairwise, as Theorem 1 is);
                // the owner stays where it is. The tightest per-type
                // budget among the shared GPUs bounds the joint footprint.
                let shared = &gpus[..need.min(gpus.len())];
                let new_span = plan.span_of(shared);
                let run_span = plan.span_of(run_gpus);
                let budget = shared
                    .iter()
                    .map(|&g| plan.mem_gb(g))
                    .fold(f64::INFINITY, f64::min);
                let Some(cfg) = batch_size_scaling_placed(
                    &ctx.jobs[id],
                    &orec,
                    need,
                    budget,
                    &ctx.xi,
                    self.sweep_batches,
                    &new_span,
                    &run_span,
                ) else {
                    // Algorithm-2 audit: no sub-batch satisfies Eq. 9 on
                    // this pair's placement.
                    if ctx.obs().is_enabled() {
                        ctx.obs().alg2_candidate(
                            ctx.now(),
                            &Alg2Audit {
                                job: id,
                                owner,
                                accepted: false,
                                reason: "memory-infeasible",
                                accum_step: None,
                                pair_jct_s: None,
                            },
                        );
                    }
                    continue;
                };
                let accepted = cfg.share || !self.theorem1_gate;
                if ctx.obs().is_enabled() {
                    ctx.obs().alg2_candidate(
                        ctx.now(),
                        &Alg2Audit {
                            job: id,
                            owner,
                            accepted,
                            reason: if cfg.share {
                                "share"
                            } else if !self.theorem1_gate {
                                "gate-ablated"
                            } else {
                                "exclusive-preferred"
                            },
                            accum_step: Some(cfg.accum_step),
                            pair_jct_s: Some(cfg.pair_jct),
                        },
                    );
                }
                if accepted {
                    candidates.push((owner, gpus, cfg));
                }
            }
            // --- line 14: best sharing benefit first
            if self.sort_by_benefit {
                candidates.sort_by(|a, b| a.2.pair_jct.total_cmp(&b.2.pair_jct));
            }
            // --- lines 15-17: take GPUs from the best partners
            let mut chosen: Vec<GpuId> = Vec::new();
            let mut accum = 1u32;
            for (_, gpus, cfg) in &candidates {
                if chosen.len() >= need {
                    break;
                }
                for &g in gpus {
                    if chosen.len() == need {
                        break;
                    }
                    chosen.push(g);
                }
                accum = accum.max(cfg.accum_step);
            }
            if chosen.is_empty() {
                continue; // best benefit is to wait (SF = False everywhere)
            }
            // Top up from free GPUs only if sharing alone cannot cover —
            // skipping GPUs whose per-type budget cannot hold the chosen
            // sub-batch (a no-op on uniform topologies).
            let sub_gb = prof.mem.mem_gb(ctx.jobs[id].spec.batch as f64 / accum as f64);
            for &g in &free {
                if chosen.len() == need {
                    break;
                }
                if plan.mem_gb(g) + 1e-9 >= sub_gb {
                    chosen.push(g);
                }
            }
            if chosen.len() < need {
                continue;
            }
            plan.allocate(id, &chosen);
            started.insert(id, (accum, chosen.clone()));
            txn.start(id, chosen, accum);
        }
        self.op_latencies_s.push(t0.elapsed().as_secs_f64());
        txn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::jobs::JobSpec;
    use crate::perf::interference::InterferenceModel;
    use crate::perf::profiles::ModelKind;
    use crate::sim::{engine, metrics};

    fn job(
        id: usize,
        model: ModelKind,
        gpus: usize,
        iters: u64,
        batch: u32,
        arrival: f64,
    ) -> JobSpec {
        JobSpec { id, model, gpus, iterations: iters, batch, arrival_s: arrival, est_factor: 1.0 }
    }

    fn run(trace: &[JobSpec]) -> engine::SimOutcome {
        engine::run(
            ClusterConfig::physical(),
            trace,
            InterferenceModel::new(),
            &mut SjfBsbf::default(),
        )
        .unwrap()
    }

    #[test]
    fn shares_polite_pair_immediately() {
        // NCF next to CIFAR10: low ξ, fits — BSBF should co-locate.
        let trace = vec![
            job(0, ModelKind::Cifar10, 16, 3000, 128, 0.0),
            job(1, ModelKind::Ncf, 16, 500, 4096, 1.0),
        ];
        let out = run(&trace);
        assert!(out.jobs[1].queueing_delay().unwrap() < 1.0);
    }

    #[test]
    fn declines_catastrophic_pair_unlike_ffs() {
        // Two small-batch YoloV3: memory fits but ξ ≈ 6 ⇒ Theorem 1 says
        // sequential; BSBF must queue the second job.
        let trace = vec![
            job(0, ModelKind::YoloV3, 16, 1500, 4, 0.0),
            job(1, ModelKind::YoloV3, 16, 1500, 4, 1.0),
        ];
        let out = run(&trace);
        let q1 = out.jobs[1].queueing_delay().unwrap();
        assert!(q1 > 1.0, "BSBF must refuse the toxic share, q={q1}");
    }

    #[test]
    fn bsbf_beats_ffs_on_toxic_workload() {
        // Workload dominated by interference-heavy pairs: BSBF's refusal
        // to share should win on average JCT (the paper's 9-17% claim).
        let mut trace = Vec::new();
        for i in 0..8 {
            trace.push(job(
                i,
                ModelKind::YoloV3,
                16,
                900,
                4,
                i as f64 * 5.0,
            ));
        }
        let bsbf = run(&trace);
        let ffs = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut super::super::SjfFfs,
        )
        .unwrap();
        let b = metrics::summarize("BSBF", &bsbf.jobs, bsbf.makespan_s);
        let f = metrics::summarize("FFS", &ffs.jobs, ffs.makespan_s);
        assert!(
            b.all.avg_jct_s < f.all.avg_jct_s,
            "BSBF {:.0}s must beat FFS {:.0}s here",
            b.all.avg_jct_s,
            f.all.avg_jct_s
        );
    }

    #[test]
    fn gradient_accumulation_applied_when_sharing_tight_memory() {
        let trace = vec![
            job(0, ModelKind::Bert, 16, 2500, 16, 0.0),
            job(1, ModelKind::Bert, 16, 150, 16, 1.0),
        ];
        let out = run(&trace);
        let j1 = &out.jobs[1];
        // Either it shared with accumulation, or it waited; with BERT's ξ
        // moderate, Theorem 1 favours sharing the short job.
        assert!(
            j1.accum_step > 1 || j1.queueing_delay().unwrap() > 1.0,
            "{j1:?}"
        );
    }

    #[test]
    fn falls_back_to_exclusive_when_free() {
        let trace = vec![job(0, ModelKind::ImageNet, 8, 100, 32, 0.0)];
        let out = run(&trace);
        assert_eq!(out.jobs[0].accum_step, 1);
        assert_eq!(out.jobs[0].queueing_delay().unwrap(), 0.0);
    }

    #[test]
    fn fig6b_mechanism_global_xi_low_shares_everything() {
        // With ξ = 1.1 globally, BSBF behaves like FFS (paper Fig. 6b:
        // identical performance at ξ ≤ 1.25).
        let trace = vec![
            job(0, ModelKind::YoloV3, 16, 1500, 4, 0.0),
            job(1, ModelKind::YoloV3, 16, 1500, 4, 1.0),
        ];
        let out = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::with_global(1.1),
            &mut SjfBsbf::default(),
        )
        .unwrap();
        assert!(out.jobs[1].queueing_delay().unwrap() < 1.0);
    }
}

//! FIFO: arrival-order, exclusive GPUs, non-preemptive (§VI-A baseline 1 —
//! "a traditional but popular policy adopted by Yarn and Kubernetes ...
//! usually performs poor due to its runtime-agnostic paradigm").
//!
//! Strict head-of-line semantics: if the oldest pending job does not fit,
//! nothing behind it starts — exactly the HOL blocking the sharing policies
//! are designed to relieve.

use crate::cluster::placement;
use crate::sched_core::{Event, Policy, SchedContext, Txn};

#[derive(Debug, Default)]
pub struct Fifo;

impl Policy for Fifo {
    fn name(&self) -> &'static str {
        "FIFO"
    }

    fn coalesce_coincident(&self) -> bool {
        true
    }

    fn on_event(&mut self, ctx: &SchedContext, _ev: Event) -> Txn {
        let mut plan = ctx.overlay();
        let mut txn = Txn::new();
        // Arrival order comes pre-sorted from the context's incrementally
        // maintained pending index: no per-pass re-sort.
        for id in ctx.pending_by_arrival() {
            let spec = &ctx.jobs[id].spec;
            let solo_gb = spec.profile().mem.mem_gb(spec.batch as f64);
            match placement::consolidated_free_mem(&plan, spec.gpus, solo_gb) {
                Some(gpus) => {
                    plan.allocate(id, &gpus);
                    txn.start(id, gpus, 1);
                }
                None => {
                    // HOL blocking: note which job holds the line (the
                    // dynamic the sharing policies exist to relieve).
                    if ctx.obs().is_enabled() {
                        ctx.obs().policy_note(
                            ctx.now(),
                            self.name(),
                            &format!("HOL blocked at job {id} ({} GPUs)", spec.gpus),
                        );
                    }
                    break;
                }
            }
        }
        txn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::jobs::JobSpec;
    use crate::perf::interference::InterferenceModel;
    use crate::perf::profiles::ModelKind;
    use crate::sim::engine;

    fn job(id: usize, gpus: usize, iters: u64, arrival: f64) -> JobSpec {
        JobSpec {
            id,
            model: ModelKind::Cifar10,
            gpus,
            iterations: iters,
            batch: 128,
            arrival_s: arrival,
            est_factor: 1.0,
        }
    }

    #[test]
    fn hol_blocking_blocks_small_job_behind_big() {
        // j0 occupies all 16; j1 (16 GPUs) blocks; j2 (1 GPU, tiny) arrives
        // later but must NOT leapfrog under FIFO.
        let trace = vec![job(0, 16, 2000, 0.0), job(1, 16, 100, 1.0), job(2, 1, 10, 2.0)];
        let out = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut Fifo,
        )
        .unwrap();
        // j2 starts only after j1 (FIFO order), so j2.start >= j1.start.
        let s1 = out.jobs[1].first_start_s.unwrap();
        let s2 = out.jobs[2].first_start_s.unwrap();
        assert!(s2 >= s1, "FIFO must not let j2 jump the queue: {s2} < {s1}");
    }

    #[test]
    fn arrival_order_respected() {
        let trace = vec![job(0, 8, 500, 0.0), job(1, 8, 100, 0.5)];
        let out = engine::run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut Fifo,
        )
        .unwrap();
        // Both fit simultaneously: both start at their arrivals.
        assert_eq!(out.jobs[0].queueing_delay().unwrap(), 0.0);
        assert_eq!(out.jobs[1].queueing_delay().unwrap(), 0.0);
    }
}

//! Table/figure emitters: render run summaries as the markdown tables and
//! CSV series the paper reports, so bench output is directly comparable.

use std::fmt::Write as _;

use crate::sim::metrics::Summary;

/// Seconds → hours with 2 decimals (Tables III/IV unit).
pub fn hrs(s: f64) -> f64 {
    (s / 3600.0 * 100.0).round() / 100.0
}

/// Render a Table II-style block (makespan + avg JCT in seconds).
pub fn table2(rows: &[Summary]) -> String {
    let mut out = String::new();
    writeln!(out, "| Policy | Makespan (seconds) | Average JCT (seconds) |").unwrap();
    writeln!(out, "|---|---|---|").unwrap();
    for r in rows {
        writeln!(
            out,
            "| {} | {:.0} | {:.2} |",
            r.policy, r.makespan_s, r.all.avg_jct_s
        )
        .unwrap();
    }
    out
}

/// Render a Table III/IV-style block (hours, all/large/small split).
pub fn table34(rows: &[Summary]) -> String {
    let mut out = String::new();
    writeln!(out, "| Metrics (hrs) | Policy | All Jobs | Large Jobs | Small Jobs |")
        .unwrap();
    writeln!(out, "|---|---|---|---|---|").unwrap();
    for r in rows {
        writeln!(
            out,
            "| Average JCT | {} | {:.2} | {:.2} | {:.2} |",
            r.policy,
            hrs(r.all.avg_jct_s),
            hrs(r.large.avg_jct_s),
            hrs(r.small.avg_jct_s)
        )
        .unwrap();
    }
    for r in rows {
        writeln!(
            out,
            "| Average Queuing Time | {} | {:.2} | {:.2} | {:.2} |",
            r.policy,
            hrs(r.all.avg_queue_s),
            hrs(r.large.avg_queue_s),
            hrs(r.small.avg_queue_s)
        )
        .unwrap();
    }
    out
}

/// Render a generic markdown table — the shared substrate for emitters
/// whose columns are not one of the fixed paper-table layouts (e.g. the
/// campaign confidence-interval table).
pub fn markdown_table(header: &[String], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    writeln!(out, "| {} |", header.join(" | ")).unwrap();
    writeln!(out, "|{}|", vec!["---"; header.len()].join("|")).unwrap();
    for row in rows {
        writeln!(out, "| {} |", row.join(" | ")).unwrap();
    }
    out
}

/// CSV series for a figure: one `name,x,y` row per point.
pub fn csv_series(name: &str, points: &[(f64, f64)]) -> String {
    let mut out = String::new();
    for (x, y) in points {
        writeln!(out, "{name},{x},{y}").unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::metrics::Aggregate;

    fn summary(policy: &str, jct: f64) -> Summary {
        let agg = Aggregate {
            n: 10,
            avg_jct_s: jct,
            avg_queue_s: jct / 3.0,
            p50_jct_s: jct,
            p90_jct_s: jct,
            unfinished: 0,
        };
        Summary { policy: policy.into(), makespan_s: 2.0 * jct, all: agg, large: agg, small: agg }
    }

    #[test]
    fn table2_contains_all_policies() {
        let t = table2(&[summary("FIFO", 662.6), summary("SJF-BSBF", 483.2)]);
        assert!(t.contains("| FIFO | 1325 | 662.60 |"));
        assert!(t.contains("SJF-BSBF"));
    }

    #[test]
    fn table34_has_both_metric_blocks() {
        let t = table34(&[summary("Pollux", 3744.0)]);
        assert_eq!(t.matches("Pollux").count(), 2);
        assert!(t.contains("| Average JCT | Pollux | 1.04 |"));
    }

    #[test]
    fn hrs_rounds() {
        assert_eq!(hrs(3600.0), 1.0);
        assert_eq!(hrs(5400.0), 1.5);
    }

    #[test]
    fn markdown_table_generic_shape() {
        let header: Vec<String> = vec!["A".into(), "B".into()];
        let rows = vec![vec!["1".to_string(), "2".to_string()]];
        let t = markdown_table(&header, &rows);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines, vec!["| A | B |", "|---|---|", "| 1 | 2 |"]);
    }

    #[test]
    fn csv_shape() {
        let s = csv_series("fig6a", &[(120.0, 1.1), (240.0, 2.2)]);
        assert_eq!(s.lines().count(), 2);
        assert!(s.starts_with("fig6a,120,1.1"));
    }
}

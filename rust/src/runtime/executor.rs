//! Training executor: drives a job's SGD loop against the compiled
//! artifacts. The gradient-accumulation schedule — the paper's Algorithm 2
//! knob — lives *here*, in Rust: one `grad_step(sub_batch)` execution per
//! micro-batch, folded with `accum`, then a single `apply` with
//! `hp = [lr, 1/s]`. Changing the sub-batch at schedule time never
//! recompiles anything; it just selects a different pre-compiled variant.

use anyhow::{bail, Context, Result};

use super::ArtifactSet;
use crate::util::rng::Rng;

/// A job's live training state: parameters as host literals that are fed
/// to each PJRT execution and replaced by its outputs.
pub struct TrainState {
    pub params: Vec<xla::Literal>,
    pub step: u64,
    pub last_loss: f32,
}

/// Synthetic next-token corpus: deterministic token stream per seed.
/// (The paper's substitute for per-tenant training data; DESIGN.md §3.)
pub struct SyntheticData {
    rng: Rng,
    vocab: i64,
    seq_len: usize,
}

impl SyntheticData {
    pub fn new(seed: u64, vocab: usize, seq_len: usize) -> Self {
        SyntheticData { rng: Rng::seed_from_u64(seed), vocab: vocab as i64, seq_len }
    }

    /// Sample an (x, y) pair of shape [micro_batch, seq_len], where y is a
    /// learnable function of x (shift-by-one over a fixed permutation), so
    /// the loss actually decreases during training.
    pub fn batch(&mut self, micro_batch: u32) -> (Vec<i32>, Vec<i32>) {
        let n = micro_batch as usize * self.seq_len;
        let mut x = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for _ in 0..micro_batch {
            let mut prev = self.rng.range_i64(0, self.vocab);
            for _ in 0..self.seq_len {
                let cur = self.rng.range_i64(0, self.vocab);
                x.push(cur as i32);
                // Target: deterministic mix of current and previous token.
                y.push(((cur * 7 + prev * 3 + 1) % self.vocab) as i32);
                prev = cur;
            }
        }
        (x, y)
    }
}

/// Executes training steps for one job against a shared [`ArtifactSet`].
pub struct TrainExecutor<'a> {
    set: &'a ArtifactSet,
    data: SyntheticData,
    /// Learning rate for `apply`.
    pub lr: f32,
}

impl<'a> TrainExecutor<'a> {
    pub fn new(set: &'a ArtifactSet, seed: u64, lr: f32) -> Self {
        let m = &set.meta.model;
        TrainExecutor { set, data: SyntheticData::new(seed, m.vocab, m.seq_len), lr }
    }

    pub fn init_state(&self) -> Result<TrainState> {
        Ok(TrainState { params: self.set.init_params()?, step: 0, last_loss: f32::NAN })
    }

    fn tokens_literal(&self, vals: &[i32], micro_batch: u32) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(vals)
            .reshape(&[micro_batch as i64, self.set.meta.model.seq_len as i64])?)
    }

    /// Run one `grad_step` execution; returns (loss, grads).
    fn grad_step(
        &self,
        params: &[xla::Literal],
        micro_batch: u32,
        x: &[i32],
        y: &[i32],
    ) -> Result<(f32, Vec<xla::Literal>)> {
        let exe = self.set.grad_step_exe(micro_batch)?;
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        let xl = self.tokens_literal(x, micro_batch)?;
        let yl = self.tokens_literal(y, micro_batch)?;
        inputs.push(&xl);
        inputs.push(&yl);
        let out = exe.execute::<&xla::Literal>(&inputs)?;
        let tuple = out[0][0].to_literal_sync()?;
        let mut parts = tuple.to_tuple()?;
        if parts.len() != 1 + self.set.meta.n_arrays() {
            bail!("grad_step returned {} outputs", parts.len());
        }
        let grads = parts.split_off(1);
        let loss = parts[0].to_vec::<f32>()?[0];
        Ok((loss, grads))
    }

    /// Fold two gradient sets: `accum(a, b) = a + b` element-wise.
    fn accum(&self, a: &[xla::Literal], b: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut inputs: Vec<&xla::Literal> = a.iter().collect();
        inputs.extend(b.iter());
        let out = self.set.accum_exe()?.execute::<&xla::Literal>(&inputs)?;
        Ok(out[0][0].to_literal_sync()?.to_tuple()?)
    }

    /// SGD update with the accumulated gradients of `s` micro-batches.
    fn apply(
        &self,
        params: &[xla::Literal],
        grads: &[xla::Literal],
        s: u32,
    ) -> Result<Vec<xla::Literal>> {
        let hp = xla::Literal::vec1(&[self.lr, 1.0 / s as f32]);
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.extend(grads.iter());
        inputs.push(&hp);
        let out = self.set.apply_exe()?.execute::<&xla::Literal>(&inputs)?;
        Ok(out[0][0].to_literal_sync()?.to_tuple()?)
    }

    /// One full training iteration at user batch `batch` with accumulation
    /// step `s` (sub-batch `batch/s`, executed as `s` sequential
    /// micro-steps — Eq. 7's schedule). Returns the mean micro-loss.
    pub fn train_step(&mut self, state: &mut TrainState, batch: u32, s: u32) -> Result<f32> {
        if s == 0 || batch % s != 0 {
            bail!("batch {batch} not divisible by accumulation step {s}");
        }
        let sub = batch / s;
        let micro = self
            .set
            .meta
            .best_micro_batch(sub)
            .with_context(|| format!("sub-batch {sub} below smallest artifact"))?;
        // If the exact sub-batch has no artifact, run more micro-steps of
        // the largest variant that divides it.
        let reps = sub / micro * s;
        let mut total_loss = 0.0f32;
        let mut acc: Option<Vec<xla::Literal>> = None;
        for _ in 0..reps {
            let (x, y) = self.data.batch(micro);
            let (loss, grads) = self.grad_step(&state.params, micro, &x, &y)?;
            total_loss += loss;
            acc = Some(match acc {
                None => grads,
                Some(prev) => self.accum(&prev, &grads)?,
            });
        }
        let grads = acc.context("zero accumulation steps")?;
        state.params = self.apply(&state.params, &grads, reps)?;
        state.step += 1;
        state.last_loss = total_loss / reps as f32;
        Ok(state.last_loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_data_shapes_and_range() {
        let mut d = SyntheticData::new(1, 64, 16);
        let (x, y) = d.batch(4);
        assert_eq!(x.len(), 64);
        assert_eq!(y.len(), 64);
        assert!(x.iter().chain(y.iter()).all(|&t| (0..64).contains(&t)));
    }

    /// ArtifactSet is !Sync (Rc inside PjRtClient), so the PJRT checks run
    /// sequentially inside one test against a single compiled set.
    /// Self-skips when `make artifacts` has not run or the PJRT runtime is
    /// the offline stub (DESIGN.md §4); artifact corruption stays loud.
    #[test]
    fn executor_end_to_end_against_artifacts() {
        let dir = ArtifactSet::default_dir();
        if !dir.join("meta.json").exists() {
            eprintln!(
                "skipping executor_end_to_end_against_artifacts: artifacts not built \
                 (run `make artifacts`)"
            );
            return;
        }
        let s = match ArtifactSet::load(dir) {
            Ok(s) => s,
            Err(e) if e.to_string().contains("not available") => {
                eprintln!("skipping executor_end_to_end_against_artifacts: {e:#}");
                return;
            }
            Err(e) => panic!("artifacts exist but failed to load: {e:#}"),
        };

        // 1) plain step: loss finite.
        let mut exec = TrainExecutor::new(&s, 42, 0.1);
        let mut state = exec.init_state().unwrap();
        let loss = exec.train_step(&mut state, 8, 1).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
        assert_eq!(state.step, 1);

        // 2) accumulated step (batch 8, s=4 -> sub-batch 2 artifact × 4).
        let mut exec = TrainExecutor::new(&s, 43, 0.1);
        let mut state = exec.init_state().unwrap();
        assert!(exec.train_step(&mut state, 8, 4).unwrap().is_finite());

        // 3) indivisible accumulation rejected.
        assert!(exec.train_step(&mut state, 8, 3).is_err());

        // 4) training reduces loss over ~40 steps (the e2e signal; same
        //    property pytest asserts in-JAX).
        let mut exec = TrainExecutor::new(&s, 44, 0.5);
        let mut state = exec.init_state().unwrap();
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..40 {
            last = exec.train_step(&mut state, 8, 1).unwrap();
            first.get_or_insert(last);
        }
        let first = first.unwrap();
        assert!(last < first * 0.95, "loss should drop: first={first} last={last}");
    }
}

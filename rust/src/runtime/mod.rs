//! PJRT runtime: load the AOT'd HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the Rust hot path.
//!
//! Python is build-time only — after `make artifacts`, this module gives
//! the coordinator a self-contained training executor:
//!
//! * [`ArtifactSet`] — meta.json + compiled executables per micro-batch,
//! * [`executor::TrainExecutor`] — the paper's gradient-accumulation
//!   loop: `s × grad_step(sub_batch) → accum → apply(lr, 1/s)`.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod executor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Parsed `artifacts/meta.json` — the AOT ABI between L2 and L3.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub model: ModelMeta,
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub micro_batches: Vec<u32>,
    pub artifacts: HashMap<String, String>,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub n_params: usize,
}

impl ArtifactMeta {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let doc = Json::parse(&text).context("parsing meta.json")?;
        let m = doc.req("model")?;
        let usz = |j: &Json, k: &str| -> Result<usize> {
            j.req(k)?.as_usize().with_context(|| format!("{k} must be a number"))
        };
        let model = ModelMeta {
            vocab: usz(m, "vocab")?,
            d_model: usz(m, "d_model")?,
            n_heads: usz(m, "n_heads")?,
            n_layers: usz(m, "n_layers")?,
            d_ff: usz(m, "d_ff")?,
            seq_len: usz(m, "seq_len")?,
            n_params: usz(m, "n_params")?,
        };
        let param_names: Vec<String> = doc
            .req("param_names")?
            .as_arr()
            .context("param_names array")?
            .iter()
            .map(|j| j.as_str().map(str::to_string).context("param name"))
            .collect::<Result<_>>()?;
        let param_shapes: Vec<Vec<usize>> = doc
            .req("param_shapes")?
            .as_arr()
            .context("param_shapes array")?
            .iter()
            .map(|j| {
                j.as_arr()
                    .context("shape array")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<Vec<usize>>>()
            })
            .collect::<Result<_>>()?;
        let micro_batches: Vec<u32> = doc
            .req("micro_batches")?
            .as_arr()
            .context("micro_batches array")?
            .iter()
            .map(|j| j.as_usize().map(|x| x as u32).context("micro batch"))
            .collect::<Result<_>>()?;
        let artifacts: HashMap<String, String> = doc
            .req("artifacts")?
            .as_obj()
            .context("artifacts object")?
            .iter()
            .map(|(k, v)| {
                Ok((k.clone(), v.as_str().context("artifact path")?.to_string()))
            })
            .collect::<Result<_>>()?;
        let meta = ArtifactMeta { model, param_names, param_shapes, micro_batches, artifacts };
        if meta.param_names.len() != meta.param_shapes.len() {
            bail!("meta.json: param name/shape length mismatch");
        }
        Ok(meta)
    }

    /// Number of flat parameter arrays.
    pub fn n_arrays(&self) -> usize {
        self.param_names.len()
    }

    /// Largest micro-batch ≤ `sub_batch` with a compiled grad_step variant.
    pub fn best_micro_batch(&self, sub_batch: u32) -> Option<u32> {
        self.micro_batches.iter().copied().filter(|&b| b <= sub_batch).max()
    }
}

/// Executables for one artifact directory, **compiled lazily per program**:
/// a worker that only ever runs micro-batch 8 pays for 4 compilations
/// (grad_step_mb8, accum, apply, init), not all 7 artifacts. On the
/// single-core CI/testbed this is the difference between ~40 s and ~20 s of
/// XLA compile per worker (§Perf L3 fix #1 in EXPERIMENTS.md).
pub struct ArtifactSet {
    pub meta: ArtifactMeta,
    pub client: xla::PjRtClient,
    dir: PathBuf,
    cache: std::cell::RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )
    .with_context(|| format!("parsing HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

impl ArtifactSet {
    /// Open an artifact directory on a fresh CPU PJRT client. Validates
    /// that every artifact file exists; compilation happens on first use.
    pub fn load(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let meta = ArtifactMeta::load(&dir)?;
        for file in meta.artifacts.values() {
            let path = dir.join(file);
            if !path.exists() {
                bail!("artifact {path:?} missing — run `make artifacts`");
            }
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(ArtifactSet { meta, client, dir, cache: Default::default() })
    }

    /// Default artifact directory: `$CARGO_MANIFEST_DIR/artifacts`.
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Get (compiling on first use) the executable for a named artifact.
    ///
    /// Compilation takes a process-wide gate: on the single-core testbed,
    /// letting N workers interleave their XLA compiles multiplies *every*
    /// worker's time-to-first-step by N; serializing lets the first lead
    /// start training immediately (§Perf L3 fix #2 in EXPERIMENTS.md).
    fn exe(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(std::rc::Rc::clone(e));
        }
        static COMPILE_GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());
        let file = self
            .artifact_file(name)
            .with_context(|| format!("meta.json missing artifact {name}"))?;
        let exe = {
            let _gate = COMPILE_GATE.lock().unwrap_or_else(|e| e.into_inner());
            std::rc::Rc::new(compile(&self.client, &self.dir.join(file))?)
        };
        self.cache.borrow_mut().insert(name.to_string(), std::rc::Rc::clone(&exe));
        Ok(exe)
    }

    fn artifact_file(&self, name: &str) -> Option<&str> {
        self.meta.artifacts.get(name).map(String::as_str)
    }

    /// Number of executables compiled so far (perf instrumentation).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    pub fn grad_step_exe(
        &self,
        micro_batch: u32,
    ) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if !self.meta.micro_batches.contains(&micro_batch) {
            bail!("no grad_step artifact for micro-batch {micro_batch}");
        }
        self.exe(&format!("grad_step_mb{micro_batch}"))
    }

    pub fn accum_exe(&self) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        self.exe("accum")
    }

    pub fn apply_exe(&self) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        self.exe("apply")
    }

    /// Run the seeded init program → fresh parameter literals.
    pub fn init_params(&self) -> Result<Vec<xla::Literal>> {
        let init = self.exe("init_params")?;
        let out = init.execute::<xla::Literal>(&[])?;
        let tuple = out[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.meta.n_arrays() {
            bail!("init returned {} arrays, expected {}", parts.len(), self.meta.n_arrays());
        }
        Ok(parts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Artifact-dependent tests self-skip only when `make artifacts` has
    /// not run — the physical path is optional in the offline build (see
    /// DESIGN.md §4). When the artifacts *do* exist, parse/validation
    /// failures stay loud: corruption must fail the suite, not skip it.
    fn meta_or_skip(test: &str) -> Option<ArtifactMeta> {
        let dir = ArtifactSet::default_dir();
        if !dir.join("meta.json").exists() {
            eprintln!("skipping {test}: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(ArtifactMeta::load(&dir).expect("artifacts exist but meta.json is unloadable"))
    }

    #[test]
    fn meta_parses() {
        let Some(meta) = meta_or_skip("meta_parses") else { return };
        assert_eq!(meta.param_names.len(), meta.param_shapes.len());
        assert!(meta.micro_batches.contains(&1));
        assert!(meta.model.n_params > 100_000);
    }

    #[test]
    fn best_micro_batch_picks_floor() {
        // Synthetic meta: independent of the artifact files on disk.
        let meta = ArtifactMeta {
            model: ModelMeta {
                vocab: 512,
                d_model: 64,
                n_heads: 4,
                n_layers: 2,
                d_ff: 256,
                seq_len: 64,
                n_params: 200_000,
            },
            param_names: vec!["tok_emb".to_string()],
            param_shapes: vec![vec![512, 64]],
            micro_batches: vec![1, 2, 4, 8],
            artifacts: HashMap::new(),
        };
        assert_eq!(meta.best_micro_batch(8), Some(8));
        assert_eq!(meta.best_micro_batch(6), Some(4));
        assert_eq!(meta.best_micro_batch(1), Some(1));
        assert_eq!(meta.best_micro_batch(0), None);
    }

    #[test]
    fn artifacts_compile_lazily_and_init_runs() {
        if meta_or_skip("artifacts_compile_lazily_and_init_runs").is_none() {
            return; // artifacts not built
        }
        let set = match ArtifactSet::load(ArtifactSet::default_dir()) {
            Ok(s) => s,
            // Offline stub: the PJRT client cannot come up. Anything else
            // (missing artifact files, bad meta) is real corruption.
            Err(e) if e.to_string().contains("not available") => {
                eprintln!("skipping artifacts_compile_lazily_and_init_runs: {e:#}");
                return;
            }
            Err(e) => panic!("artifacts exist but failed to load: {e:#}"),
        };
        assert_eq!(set.compiled_count(), 0, "load must not compile anything");
        let params = set.init_params().unwrap();
        assert_eq!(set.compiled_count(), 1, "only init compiled");
        assert_eq!(params.len(), set.meta.n_arrays());
        // First param is the token embedding [vocab, d_model].
        let emb = params[0].to_vec::<f32>().unwrap();
        assert_eq!(emb.len(), set.meta.model.vocab * set.meta.model.d_model);
        assert!(emb.iter().all(|x| x.is_finite()));
        // Cached: second use does not recompile.
        set.init_params().unwrap();
        assert_eq!(set.compiled_count(), 1);
        // Unknown micro-batch is rejected without compiling.
        assert!(set.grad_step_exe(3).is_err());
        assert_eq!(set.compiled_count(), 1);
    }
}

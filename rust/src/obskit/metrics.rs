//! Runtime-metrics sink: a small first-party registry — monotonic
//! counters, raw-sample histograms, and a sim-time-cadence utilization
//! sampler. No external metrics dependency (vendored-only rule); the
//! artifact is a single schema-versioned JSON document written at
//! finish.
//!
//! Histograms keep the *raw* observation vector (policy passes number in
//! the thousands, not millions), so percentiles at emit time are exact —
//! computed with the bench-side ceiling-rank definition from
//! [`crate::util::stats`].

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::Result;

use crate::sched_core::{ApplyReport, Event, Txn};
use crate::util::json::Json;
use crate::util::stats::percentile_ceiling_rank;

use super::{obj, write_file};

/// Schema tag of the emitted metrics document.
pub const METRICS_SCHEMA: &str = "wise-share-metrics-v1";

#[derive(Debug, Clone, Copy)]
struct Sample {
    t: f64,
    busy: usize,
    shared: usize,
    total: usize,
    queue_depth: usize,
    pending: usize,
}

#[derive(Debug)]
pub struct MetricsSink {
    path: Option<PathBuf>,
    counters: BTreeMap<String, u64>,
    hists: BTreeMap<String, Vec<f64>>,
    samples: Vec<Sample>,
    sample_every_s: f64,
    next_sample_s: f64,
}

impl MetricsSink {
    pub fn new(path: Option<PathBuf>, sample_every_s: f64) -> Self {
        MetricsSink {
            path,
            counters: BTreeMap::new(),
            hists: BTreeMap::new(),
            samples: Vec::new(),
            sample_every_s: if sample_every_s > 0.0 { sample_every_s } else { 60.0 },
            next_sample_s: 0.0,
        }
    }

    pub fn add(&mut self, name: &str, delta: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    pub fn observe(&mut self, name: &str, v: f64) {
        self.hists.entry(name.to_string()).or_default().push(v);
    }

    pub fn count_event(&mut self, ev: Event) {
        let name = match ev {
            Event::Arrival { .. } => "events/arrival",
            Event::Completion { .. } => "events/completion",
            Event::RestartEligible { .. } => "events/restart_eligible",
            Event::Tick => "events/tick",
        };
        self.add(name, 1);
    }

    pub fn txn_applied(&mut self, txn: &Txn, report: &ApplyReport) {
        if !txn.is_empty() {
            self.add("txn/applied", 1);
        }
        if report.starts > 0 {
            self.add("txn/starts", report.starts);
        }
        if report.preemptions > 0 {
            self.add("txn/preemptions", report.preemptions);
        }
    }

    pub fn txn_rejected(&mut self) {
        self.add("txn/rejected", 1);
    }

    /// Record a utilization sample if the cadence says one is due;
    /// otherwise drop the call. The next due time is strictly after `t`,
    /// so a burst of same-instant events yields one sample and a long
    /// quiet gap is not back-filled.
    pub fn sample(
        &mut self,
        t: f64,
        busy: usize,
        shared: usize,
        total: usize,
        queue_depth: usize,
        pending: usize,
    ) {
        if t < self.next_sample_s {
            return;
        }
        self.samples.push(Sample { t, busy, shared, total, queue_depth, pending });
        self.next_sample_s = t + self.sample_every_s;
    }

    pub fn samples_of(&self, name: &str) -> Option<Vec<f64>> {
        self.hists.get(name).cloned()
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.get(name).copied()
    }

    fn hist_summary(samples: &[f64]) -> Json {
        let mut sorted = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        let n = sorted.len();
        obj(vec![
            ("n", Json::from(n)),
            ("mean_s", Json::Num(sorted.iter().sum::<f64>() / n as f64)),
            ("min_s", Json::Num(sorted[0])),
            ("p50_s", Json::Num(percentile_ceiling_rank(&sorted, 0.50))),
            ("p95_s", Json::Num(percentile_ceiling_rank(&sorted, 0.95))),
            ("max_s", Json::Num(sorted[n - 1])),
        ])
    }

    /// The full metrics document: counters, summarized histograms, and
    /// the utilization time series with derived `gpu_util` /
    /// `sharing_frac` per sample.
    pub fn render(&self) -> Json {
        let counters = Json::Obj(
            self.counters.iter().map(|(k, &v)| (k.clone(), Json::from(v))).collect(),
        );
        let hists = Json::Obj(
            self.hists
                .iter()
                .filter(|(_, v)| !v.is_empty())
                .map(|(k, v)| (k.clone(), Self::hist_summary(v)))
                .collect(),
        );
        let samples = Json::Arr(
            self.samples
                .iter()
                .map(|s| {
                    let gpu_util =
                        if s.total > 0 { s.busy as f64 / s.total as f64 } else { 0.0 };
                    let sharing_frac =
                        if s.busy > 0 { s.shared as f64 / s.busy as f64 } else { 0.0 };
                    obj(vec![
                        ("t_s", Json::Num(s.t)),
                        ("busy_gpus", Json::from(s.busy)),
                        ("shared_gpus", Json::from(s.shared)),
                        ("total_gpus", Json::from(s.total)),
                        ("queue_depth", Json::from(s.queue_depth)),
                        ("pending", Json::from(s.pending)),
                        ("gpu_util", Json::Num(gpu_util)),
                        ("sharing_frac", Json::Num(sharing_frac)),
                    ])
                })
                .collect(),
        );
        obj(vec![
            ("schema", METRICS_SCHEMA.into()),
            ("counters", counters),
            ("histograms", hists),
            ("samples", samples),
        ])
    }

    /// Mid-run checkpoint: write the document as it stands. Rendering is
    /// non-destructive, so recording continues and a later flush or
    /// finish rewrites the file.
    pub fn flush(&self) -> Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        write_file(path, &self.render().to_string())
    }

    pub fn finish(&mut self) -> Result<()> {
        self.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_summary_is_exact_on_raw_samples() {
        let mut m = MetricsSink::new(None, 60.0);
        for i in 1..=20 {
            m.observe("on_event_latency/T", i as f64);
        }
        let doc = m.render();
        let h = doc.get("histograms").unwrap().get("on_event_latency/T").unwrap();
        assert_eq!(h.get("n").unwrap().as_usize(), Some(20));
        assert_eq!(h.get("min_s").unwrap().as_f64(), Some(1.0));
        assert_eq!(h.get("max_s").unwrap().as_f64(), Some(20.0));
        // Ceiling-rank percentiles, same pins as util::bench.
        assert_eq!(h.get("p50_s").unwrap().as_f64(), Some(10.0));
        assert_eq!(h.get("p95_s").unwrap().as_f64(), Some(19.0));
    }

    #[test]
    fn document_is_schema_tagged_and_roundtrips() {
        let mut m = MetricsSink::new(None, 60.0);
        m.add("txn/applied", 2);
        m.sample(0.0, 1, 0, 4, 2, 2);
        let text = m.render().to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("schema").unwrap().as_str(), Some(METRICS_SCHEMA));
        assert_eq!(back.get("counters").unwrap().get("txn/applied").unwrap().as_u64(), Some(2));
        assert_eq!(back.get("samples").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn empty_slice_guards() {
        let mut m = MetricsSink::new(None, 60.0);
        m.sample(0.0, 0, 0, 0, 0, 0); // zero-GPU cluster: no division
        let doc = m.render();
        let s = &doc.get("samples").unwrap().as_arr().unwrap()[0];
        assert_eq!(s.get("gpu_util").unwrap().as_f64(), Some(0.0));
        assert_eq!(s.get("sharing_frac").unwrap().as_f64(), Some(0.0));
    }
}

//! Event-timeline trace sink: Chrome trace format (the JSON the Perfetto
//! UI and `chrome://tracing` load directly) plus a compact JSONL stream
//! for programmatic analysis.
//!
//! Track layout: pid 0 is the engine (event instants), pid 1 is jobs —
//! one thread track per job id, each run rendered as a complete `"X"`
//! span re-segmented at every co-location change so shared intervals are
//! separate slices flagged `args.shared = true` — and pid 2 is the
//! cluster, a `"C"` counter track of busy/shared GPU counts. Timestamps
//! are sim-seconds scaled to the format's microsecond unit; the event
//! array is globally timestamp-sorted at [`TraceSink::finish`].

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::Result;

use crate::jobs::JobId;
use crate::sched_core::Event;
use crate::util::json::Json;

use super::{obj, write_file};

/// Chrome trace timestamps are in microseconds; ours are sim-seconds.
const US: f64 = 1e6;

#[derive(Debug)]
struct OpenSpan {
    start_s: f64,
    shared: bool,
    gpus: usize,
}

#[derive(Debug)]
pub struct TraceSink {
    path: Option<PathBuf>,
    /// Completed Chrome events, tagged with sim-seconds for the final
    /// stable sort (metadata first at t = 0, spans keyed by their start).
    events: Vec<(f64, Json)>,
    open: BTreeMap<JobId, OpenSpan>,
    jsonl: Vec<String>,
    last_counts: Option<(usize, usize)>,
    last_t: f64,
}

impl TraceSink {
    pub fn new(path: Option<PathBuf>) -> Self {
        let mut s = TraceSink {
            path,
            events: Vec::new(),
            open: BTreeMap::new(),
            jsonl: Vec::new(),
            last_counts: None,
            last_t: 0.0,
        };
        for (pid, name) in [(0u64, "engine"), (1, "jobs"), (2, "cluster")] {
            s.events.push((
                0.0,
                obj(vec![
                    ("name", "process_name".into()),
                    ("ph", "M".into()),
                    ("pid", Json::from(pid)),
                    ("tid", Json::from(0u64)),
                    ("args", obj(vec![("name", name.into())])),
                ]),
            ));
        }
        s
    }

    fn line(&mut self, j: Json) {
        self.jsonl.push(j.to_string());
    }

    pub fn engine_event(&mut self, t: f64, ev: Event) {
        self.last_t = self.last_t.max(t);
        let (name, job) = match ev {
            Event::Arrival { job } => ("Arrival", Some(job)),
            Event::Completion { job } => ("Completion", Some(job)),
            Event::RestartEligible { job } => ("RestartEligible", Some(job)),
            Event::Tick => ("Tick", None),
        };
        let mut args = Vec::new();
        if let Some(j) = job {
            args.push(("job", Json::from(j)));
        }
        self.events.push((
            t,
            obj(vec![
                ("name", name.into()),
                ("ph", "i".into()),
                ("ts", Json::Num(t * US)),
                ("pid", Json::from(0u64)),
                ("tid", Json::from(0u64)),
                ("s", "g".into()),
                ("args", obj(args)),
            ]),
        ));
        let mut line = vec![
            ("t", Json::Num(t)),
            ("kind", "event".into()),
            ("event", name.into()),
        ];
        if let Some(j) = job {
            line.push(("job", Json::from(j)));
        }
        self.line(obj(line));
    }

    pub fn job_started(&mut self, t: f64, job: JobId, gpus: usize, shared: bool) {
        self.last_t = self.last_t.max(t);
        self.open.insert(job, OpenSpan { start_s: t, shared, gpus });
        self.line(obj(vec![
            ("t", Json::Num(t)),
            ("kind", "start".into()),
            ("job", Json::from(job)),
            ("gpus", Json::from(gpus)),
            ("shared", Json::from(shared)),
        ]));
    }

    fn span_json(job: JobId, span: &OpenSpan, t_end: f64, end: &str) -> Json {
        obj(vec![
            ("name", Json::Str(format!("job {job}"))),
            ("cat", "job".into()),
            ("ph", "X".into()),
            ("ts", Json::Num(span.start_s * US)),
            ("dur", Json::Num((t_end - span.start_s).max(0.0) * US)),
            ("pid", Json::from(1u64)),
            ("tid", Json::from(job)),
            (
                "args",
                obj(vec![
                    ("gpus", Json::from(span.gpus)),
                    ("shared", Json::from(span.shared)),
                    ("end", end.into()),
                ]),
            ),
        ])
    }

    fn close_span(&mut self, t: f64, job: JobId, end: &str) {
        if let Some(span) = self.open.remove(&job) {
            let json = Self::span_json(job, &span, t, end);
            self.events.push((span.start_s, json));
        }
    }

    pub fn job_stopped(&mut self, t: f64, job: JobId, reason: &str) {
        self.last_t = self.last_t.max(t);
        self.close_span(t, job, reason);
        self.line(obj(vec![
            ("t", Json::Num(t)),
            ("kind", "stop".into()),
            ("job", Json::from(job)),
            ("reason", reason.into()),
        ]));
    }

    /// Re-segment `job`'s open span when its co-location flag actually
    /// flips; no-op otherwise (and for jobs with no open span).
    pub fn job_share_changed(&mut self, t: f64, job: JobId, shared: bool) {
        let Some(span) = self.open.get(&job) else { return };
        if span.shared == shared {
            return;
        }
        self.last_t = self.last_t.max(t);
        let gpus = span.gpus;
        self.close_span(t, job, "share-change");
        self.open.insert(job, OpenSpan { start_s: t, shared, gpus });
        self.line(obj(vec![
            ("t", Json::Num(t)),
            ("kind", "share".into()),
            ("job", Json::from(job)),
            ("shared", Json::from(shared)),
        ]));
    }

    /// Busy/shared GPU counters, change-gated so a quiet cluster emits
    /// nothing.
    pub fn counts(&mut self, t: f64, busy: usize, shared: usize) {
        if self.last_counts == Some((busy, shared)) {
            return;
        }
        self.last_counts = Some((busy, shared));
        self.last_t = self.last_t.max(t);
        self.events.push((
            t,
            obj(vec![
                ("name", "gpu occupancy".into()),
                ("ph", "C".into()),
                ("ts", Json::Num(t * US)),
                ("pid", Json::from(2u64)),
                ("tid", Json::from(0u64)),
                (
                    "args",
                    obj(vec![("busy", Json::from(busy)), ("shared", Json::from(shared))]),
                ),
            ]),
        ));
        self.line(obj(vec![
            ("t", Json::Num(t)),
            ("kind", "counts".into()),
            ("busy", Json::from(busy)),
            ("shared", Json::from(shared)),
        ]));
    }

    /// Mid-run checkpoint (the serve daemon's snapshot cadence and its
    /// graceful-shutdown path): write both artifacts *now*, with any
    /// still-open spans provisionally closed at the last seen time and
    /// flagged `"in-progress"`. Unlike [`TraceSink::finish`] this
    /// mutates nothing — recording continues, and a later flush or
    /// finish atomically rewrites the files with the fuller picture.
    pub fn flush(&self) -> Result<()> {
        let Some(path) = self.path.clone() else { return Ok(()) };
        let mut events = self.events.clone();
        for (&job, span) in &self.open {
            events.push((span.start_s, Self::span_json(job, span, self.last_t, "in-progress")));
        }
        events.sort_by(|a, b| a.0.total_cmp(&b.0));
        let doc = obj(vec![
            ("traceEvents", Json::Arr(events.into_iter().map(|(_, j)| j).collect())),
            ("displayTimeUnit", "ms".into()),
        ]);
        write_file(&path, &doc.to_string())?;
        write_file(&path.with_extension("jsonl"), &(self.jsonl.join("\n") + "\n"))
    }

    /// Close still-open spans (truncated runs) at the last seen time,
    /// globally sort by timestamp, and — if this sink has a path — write
    /// the Chrome JSON plus the sibling `.jsonl` stream.
    pub fn finish(&mut self) -> Result<()> {
        let t_end = self.last_t;
        let open: Vec<JobId> = self.open.keys().copied().collect();
        for job in open {
            self.close_span(t_end, job, "truncated");
        }
        self.events.sort_by(|a, b| a.0.total_cmp(&b.0));
        let Some(path) = self.path.clone() else { return Ok(()) };
        let doc = obj(vec![
            (
                "traceEvents",
                Json::Arr(self.events.iter().map(|(_, j)| j.clone()).collect()),
            ),
            ("displayTimeUnit", "ms".into()),
        ]);
        write_file(&path, &doc.to_string())?;
        write_file(&path.with_extension("jsonl"), &(self.jsonl.join("\n") + "\n"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_segment_on_share_change_and_sort_by_ts() {
        let mut tr = TraceSink::new(None);
        tr.engine_event(0.0, Event::Arrival { job: 0 });
        tr.job_started(0.0, 0, 2, false);
        tr.job_share_changed(5.0, 0, true); // closes solo slice, opens shared
        tr.job_share_changed(5.0, 0, true); // same flag: no-op
        tr.engine_event(9.0, Event::Completion { job: 0 });
        tr.job_stopped(9.0, 0, "finish");
        tr.finish().unwrap();
        // 3 metadata + 1 arrival instant + 2 span slices + 1 completion.
        assert_eq!(tr.events.len(), 7);
        let mut spans = tr.events.iter().filter(|(_, j)| {
            j.get("ph").and_then(|p| p.as_str()) == Some("X")
        });
        let solo = spans.next().unwrap();
        assert_eq!(solo.1.get("args").unwrap().get("shared").unwrap().as_bool(), Some(false));
        assert_eq!(solo.1.get("dur").unwrap().as_f64(), Some(5.0 * US));
        let shared = spans.next().unwrap();
        assert_eq!(shared.1.get("args").unwrap().get("shared").unwrap().as_bool(), Some(true));
        assert_eq!(shared.1.get("args").unwrap().get("end").unwrap().as_str(), Some("finish"));
        // Globally ts-ordered after finish().
        for w in tr.events.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
    }

    #[test]
    fn unfinished_span_is_closed_as_truncated() {
        let mut tr = TraceSink::new(None);
        tr.job_started(1.0, 4, 1, false);
        tr.engine_event(20.0, Event::Tick);
        tr.finish().unwrap();
        let span = tr
            .events
            .iter()
            .find(|(_, j)| j.get("ph").and_then(|p| p.as_str()) == Some("X"))
            .unwrap();
        assert_eq!(span.1.get("args").unwrap().get("end").unwrap().as_str(), Some("truncated"));
        assert_eq!(span.1.get("dur").unwrap().as_f64(), Some(19.0 * US));
    }

    #[test]
    fn counter_track_is_change_gated() {
        let mut tr = TraceSink::new(None);
        tr.counts(0.0, 4, 0);
        tr.counts(1.0, 4, 0); // unchanged: dropped
        tr.counts(2.0, 6, 2);
        let counters = tr
            .events
            .iter()
            .filter(|(_, j)| j.get("ph").and_then(|p| p.as_str()) == Some("C"))
            .count();
        assert_eq!(counters, 2);
    }
}

//! `obskit` — zero-cost-when-off observability for both scheduling
//! backends (DESIGN.md §13): an event-timeline trace in Chrome trace
//! format (Perfetto-viewable) plus a compact JSONL stream, a first-party
//! runtime-metrics registry (counters / histograms / a sim-time sampler —
//! no external deps, per the vendored-only rule), and a scheduler
//! decision-audit log covering every applied or rejected [`Txn`] and
//! SJF-BSBF's per-candidate Algorithm-2 scoring.
//!
//! One [`Obs`] handle threads through `sim::engine` → [`SchedContext`] →
//! policies → `coordinator` → `campaign`. Disabled ([`Obs::disabled`],
//! the default) it is a single `Option` branch per call site — no
//! allocation, no lock, no I/O — and the simulation is bit-identical
//! with or without the handle (gated by the CI determinism + `obs-smoke`
//! legs). Enabled, sinks record in memory and write their artifacts only
//! at [`Obs::finish`]; nothing ever feeds back into the simulation, so
//! sim *results* are identical with sinks on or off — observation is
//! strictly one-way.
//!
//! [`SchedContext`]: crate::sched_core::SchedContext

pub mod audit;
pub mod metrics;
pub mod trace;

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::cluster::GpuId;
use crate::jobs::JobId;
use crate::sched_core::{ApplyReport, Event, Txn};
use crate::util::json::Json;

/// Build a JSON object from `(key, value)` pairs — emitter-side sugar
/// shared by the three sinks.
pub(crate) fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Write `contents` to `path`, creating parent directories as needed.
pub(crate) fn write_file(path: &Path, contents: &str) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    std::fs::write(path, contents).with_context(|| format!("writing {}", path.display()))
}

/// Where each surface writes, and how often the metrics sampler fires.
/// A surface with no path is not armed; all-`None` builds a disabled
/// handle.
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Chrome-trace JSON output (a sibling `.jsonl` stream is written
    /// next to it).
    pub trace: Option<PathBuf>,
    /// Runtime-metrics JSON output ([`metrics::METRICS_SCHEMA`]).
    pub metrics: Option<PathBuf>,
    /// Decision-audit JSONL output (one JSON object per line).
    pub audit: Option<PathBuf>,
    /// Sim-time seconds between metrics samples (default 60).
    pub sample_every_s: f64,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { trace: None, metrics: None, audit: None, sample_every_s: 60.0 }
    }
}

/// One Algorithm-2 candidate evaluation (the SJF-BSBF audit surface):
/// pending `job` considered for co-location on `owner`'s GPUs, with the
/// sweep's verdict.
#[derive(Debug, Clone)]
pub struct Alg2Audit {
    pub job: JobId,
    pub owner: JobId,
    pub accepted: bool,
    /// `"share"` / `"exclusive-preferred"` (Theorem 1 said wait) /
    /// `"memory-infeasible"` (no sub-batch fits Eq. 9) /
    /// `"gate-ablated"` (accepted only because the Theorem-1 gate is
    /// ablated off).
    pub reason: &'static str,
    /// Chosen gradient-accumulation step (sub-batch = B / step), when the
    /// sweep found a feasible configuration.
    pub accum_step: Option<u32>,
    /// Benefit score: the Theorem-1 pairwise JCT of the best sub-batch.
    pub pair_jct_s: Option<f64>,
}

#[derive(Debug)]
struct ObsCore {
    trace: Option<trace::TraceSink>,
    metrics: Option<metrics::MetricsSink>,
    audit: Option<audit::AuditSink>,
}

/// The cloneable sink handle threaded through the backends. Clones share
/// one core (engine, context and campaign runner all record into the
/// same sinks); the disabled handle carries no core at all.
#[derive(Debug, Clone, Default)]
pub struct Obs {
    inner: Option<Arc<Mutex<ObsCore>>>,
}

impl Obs {
    /// The no-op handle: every record call is a single `None` branch.
    pub fn disabled() -> Self {
        Obs { inner: None }
    }

    /// Arm the sinks named by `cfg`; all-`None` yields a disabled handle.
    pub fn new(cfg: ObsConfig) -> Self {
        if cfg.trace.is_none() && cfg.metrics.is_none() && cfg.audit.is_none() {
            return Obs::disabled();
        }
        let core = ObsCore {
            trace: cfg.trace.map(|p| trace::TraceSink::new(Some(p))),
            metrics: cfg
                .metrics
                .map(|p| metrics::MetricsSink::new(Some(p), cfg.sample_every_s)),
            audit: cfg.audit.map(|p| audit::AuditSink::new(Some(p))),
        };
        Obs { inner: Some(Arc::new(Mutex::new(core))) }
    }

    /// All three sinks armed with no output paths — recording costs are
    /// real but [`Obs::finish`] writes nothing. For tests and perfkit's
    /// obs-overhead / latency-histogram measurement.
    pub fn in_memory(sample_every_s: f64) -> Self {
        let core = ObsCore {
            trace: Some(trace::TraceSink::new(None)),
            metrics: Some(metrics::MetricsSink::new(None, sample_every_s)),
            audit: Some(audit::AuditSink::new(None)),
        };
        Obs { inner: Some(Arc::new(Mutex::new(core))) }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    fn with_core<F: FnOnce(&mut ObsCore)>(&self, f: F) {
        if let Some(core) = &self.inner {
            f(&mut core.lock().unwrap());
        }
    }

    // ------------------------------------------------- record: timeline

    /// An engine event delivered to the policy at sim time `t`.
    pub fn engine_event(&self, t: f64, ev: Event) {
        self.with_core(|c| {
            if let Some(m) = &mut c.metrics {
                m.count_event(ev);
            }
            if let Some(tr) = &mut c.trace {
                tr.engine_event(t, ev);
            }
        });
    }

    /// A job's gang started running (opens its trace span).
    pub fn job_started(&self, t: f64, job: JobId, gpus: &[GpuId], shared: bool) {
        self.with_core(|c| {
            if let Some(tr) = &mut c.trace {
                tr.job_started(t, job, gpus.len(), shared);
            }
        });
    }

    /// A running job stopped (`reason`: `"finish"` or `"preempt"`);
    /// closes its trace span.
    pub fn job_stopped(&self, t: f64, job: JobId, reason: &str) {
        self.with_core(|c| {
            if let Some(tr) = &mut c.trace {
                tr.job_stopped(t, job, reason);
            }
        });
    }

    /// A running job's co-location status flipped (a neighbor started on
    /// or left its GPUs); re-segments its open trace span so solo vs
    /// shared intervals are separate, flagged slices.
    pub fn job_share_changed(&self, t: f64, job: JobId, shared: bool) {
        self.with_core(|c| {
            if let Some(tr) = &mut c.trace {
                tr.job_share_changed(t, job, shared);
            }
        });
    }

    /// Cluster occupancy counters for the trace's counter track
    /// (change-gated inside the sink).
    pub fn cluster_counts(&self, t: f64, busy: usize, shared: usize) {
        self.with_core(|c| {
            if let Some(tr) = &mut c.trace {
                tr.counts(t, busy, shared);
            }
        });
    }

    // -------------------------------------------------- record: metrics

    /// One `Policy::on_event` wall-clock latency observation (the §V-4
    /// overhead claim as a recorded distribution).
    pub fn policy_latency(&self, policy: &str, secs: f64) {
        self.with_core(|c| {
            if let Some(m) = &mut c.metrics {
                m.observe(&format!("on_event_latency/{policy}"), secs);
            }
        });
    }

    /// Cadence-gated utilization sample (the sink drops calls before the
    /// next due time).
    pub fn sample(
        &self,
        t: f64,
        busy: usize,
        shared: usize,
        total: usize,
        queue_depth: usize,
        pending: usize,
    ) {
        self.with_core(|c| {
            if let Some(m) = &mut c.metrics {
                m.sample(t, busy, shared, total, queue_depth, pending);
            }
        });
    }

    // ---------------------------------------------------- record: audit

    /// A transaction the backend applied successfully (empty "no action"
    /// transactions are counted but not audit-logged).
    pub fn txn_applied(&self, t: f64, policy: &str, txn: &Txn, report: &ApplyReport) {
        self.with_core(|c| {
            if let Some(m) = &mut c.metrics {
                m.txn_applied(txn, report);
            }
            if let Some(a) = &mut c.audit {
                a.applied(t, policy, txn, report);
            }
        });
    }

    /// A transaction [`SchedContext::apply`] rejected, with the
    /// validation cause (the backend still treats this as fatal).
    ///
    /// [`SchedContext::apply`]: crate::sched_core::SchedContext::apply
    pub fn txn_rejected(&self, t: f64, policy: &str, txn: &Txn, cause: &str) {
        self.with_core(|c| {
            if let Some(m) = &mut c.metrics {
                m.txn_rejected();
            }
            if let Some(a) = &mut c.audit {
                a.rejected(t, policy, txn, cause);
            }
        });
    }

    /// One SJF-BSBF Algorithm-2 candidate-pair evaluation.
    pub fn alg2_candidate(&self, t: f64, a: &Alg2Audit) {
        self.with_core(|c| {
            if let Some(m) = &mut c.metrics {
                m.add(if a.accepted { "alg2/accepted" } else { "alg2/rejected" }, 1);
            }
            if let Some(au) = &mut c.audit {
                au.alg2(t, a);
            }
        });
    }

    /// Free-form policy-side annotation (HOL blocking, queue demotions,
    /// held resizes, …). Callers should gate any message formatting on
    /// [`Obs::is_enabled`] so the disabled path allocates nothing.
    pub fn policy_note(&self, t: f64, policy: &str, msg: &str) {
        self.with_core(|c| {
            if let Some(a) = &mut c.audit {
                a.note(t, policy, msg);
            }
        });
    }

    // ----------------------------------------------------------- output

    /// Raw observation vector of histogram `name` (e.g.
    /// `"on_event_latency/FIFO"`), if the metrics sink is armed and saw
    /// it — perfkit folds these into [`crate::util::bench::BenchStats`].
    pub fn histogram_samples(&self, name: &str) -> Option<Vec<f64>> {
        let core = self.inner.as_ref()?;
        let c = core.lock().unwrap();
        c.metrics.as_ref().and_then(|m| m.samples_of(name))
    }

    /// Current value of counter `name`, if the metrics sink is armed and
    /// the counter was ever incremented.
    pub fn counter(&self, name: &str) -> Option<u64> {
        let core = self.inner.as_ref()?;
        let c = core.lock().unwrap();
        c.metrics.as_ref().and_then(|m| m.counter(name))
    }

    /// The metrics document as it would be written, if the sink is armed.
    pub fn metrics_json(&self) -> Option<Json> {
        let core = self.inner.as_ref()?;
        let c = core.lock().unwrap();
        c.metrics.as_ref().map(|m| m.render())
    }

    /// Mid-run checkpoint: write every armed sink's artifact *as it
    /// stands*, without closing trace spans or stopping recording. This
    /// is the serve daemon's crash-safety valve — called on its snapshot
    /// cadence and from its shutdown path — so a killed process loses at
    /// most one flush interval of observations instead of everything
    /// buffered since the run began (the sinks otherwise write only at
    /// [`Obs::finish`]).
    pub fn flush(&self) -> Result<()> {
        if let Some(core) = &self.inner {
            let c = core.lock().unwrap();
            if let Some(tr) = &c.trace {
                tr.flush()?;
            }
            if let Some(m) = &c.metrics {
                m.flush()?;
            }
            if let Some(a) = &c.audit {
                a.flush()?;
            }
        }
        Ok(())
    }

    /// Close open trace spans and write every armed sink's artifact (a
    /// sink with no path skips the write). Called by the *owner* of the
    /// run — `main.rs` or the campaign runner — never by the engine, so
    /// one handle can span several runs if a caller wants that.
    pub fn finish(&self) -> Result<()> {
        if let Some(core) = &self.inner {
            let mut c = core.lock().unwrap();
            if let Some(tr) = &mut c.trace {
                tr.finish()?;
            }
            if let Some(m) = &mut c.metrics {
                m.finish()?;
            }
            if let Some(a) = &mut c.audit {
                a.finish()?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_handle_is_inert() {
        let obs = Obs::disabled();
        assert!(!obs.is_enabled());
        obs.engine_event(0.0, Event::Tick);
        obs.policy_latency("FIFO", 1e-6);
        obs.sample(0.0, 1, 0, 4, 0, 0);
        obs.job_started(0.0, 3, &[0, 1], false);
        assert_eq!(obs.histogram_samples("on_event_latency/FIFO"), None);
        assert_eq!(obs.counter("events/tick"), None);
        assert!(obs.metrics_json().is_none());
        obs.finish().unwrap();
    }

    #[test]
    fn default_config_builds_disabled() {
        assert!(!Obs::new(ObsConfig::default()).is_enabled());
    }

    #[test]
    fn in_memory_counts_events_and_latencies() {
        let obs = Obs::in_memory(60.0);
        assert!(obs.is_enabled());
        obs.engine_event(0.0, Event::Tick);
        obs.engine_event(1.0, Event::Arrival { job: 0 });
        obs.engine_event(2.0, Event::Completion { job: 0 });
        obs.policy_latency("FIFO", 2e-6);
        obs.policy_latency("FIFO", 3e-6);
        assert_eq!(obs.counter("events/tick"), Some(1));
        assert_eq!(obs.counter("events/arrival"), Some(1));
        assert_eq!(obs.counter("events/completion"), Some(1));
        assert_eq!(obs.histogram_samples("on_event_latency/FIFO").unwrap().len(), 2);
        obs.finish().unwrap(); // no paths: writes nothing
    }

    #[test]
    fn clones_share_one_core() {
        let obs = Obs::in_memory(60.0);
        let clone = obs.clone();
        clone.engine_event(0.0, Event::Tick);
        assert_eq!(obs.counter("events/tick"), Some(1));
    }

    #[test]
    fn sampler_respects_cadence() {
        let obs = Obs::in_memory(10.0);
        obs.sample(0.0, 2, 0, 4, 1, 1); // due (first sample)
        obs.sample(5.0, 2, 0, 4, 1, 1); // early: dropped
        obs.sample(10.0, 3, 2, 4, 0, 0); // due
        obs.sample(10.0, 3, 2, 4, 0, 0); // same instant: dropped
        let doc = obs.metrics_json().unwrap();
        let samples = doc.get("samples").unwrap().as_arr().unwrap();
        assert_eq!(samples.len(), 2);
        let s1 = &samples[1];
        assert_eq!(s1.get("busy_gpus").unwrap().as_usize(), Some(3));
        assert!((s1.get("gpu_util").unwrap().as_f64().unwrap() - 0.75).abs() < 1e-12);
        assert!(
            (s1.get("sharing_frac").unwrap().as_f64().unwrap() - 2.0 / 3.0).abs() < 1e-12
        );
    }

    #[test]
    fn alg2_and_rejection_reach_the_audit_counters() {
        let obs = Obs::in_memory(60.0);
        let mut txn = Txn::new();
        txn.start(0, vec![0], 1);
        obs.txn_applied(1.0, "FIFO", &txn, &ApplyReport { starts: 1, preemptions: 0 });
        obs.txn_rejected(2.0, "FIFO", &txn, "Start(0): job is Running");
        obs.alg2_candidate(
            3.0,
            &Alg2Audit {
                job: 1,
                owner: 0,
                accepted: true,
                reason: "share",
                accum_step: Some(2),
                pair_jct_s: Some(12.5),
            },
        );
        assert_eq!(obs.counter("txn/applied"), Some(1));
        assert_eq!(obs.counter("txn/rejected"), Some(1));
        assert_eq!(obs.counter("txn/starts"), Some(1));
        assert_eq!(obs.counter("alg2/accepted"), Some(1));
    }
}

//! Decision-audit sink: one JSON object per line (JSONL), covering every
//! non-empty transaction the backend applied, every transaction
//! [`SchedContext::apply`] rejected (with the validation cause), every
//! SJF-BSBF Algorithm-2 candidate evaluation, and free-form policy notes
//! (HOL blocking, Tiresias demotions, held elastic resizes).
//!
//! Line kinds: `"apply"`, `"reject"`, `"alg2"`, `"note"` — each with a
//! sim-time `t` and enough structure to reconstruct *why* the schedule
//! looks the way it does without re-running the policy.
//!
//! [`SchedContext::apply`]: crate::sched_core::SchedContext::apply

use std::path::PathBuf;

use anyhow::Result;

use crate::sched_core::{ApplyReport, Decision, Txn};
use crate::util::json::Json;

use super::{obj, write_file, Alg2Audit};

fn ops_json(txn: &Txn) -> Json {
    Json::Arr(
        txn.ops()
            .iter()
            .map(|d| match d {
                Decision::Start { job, gpus, accum_step } => obj(vec![
                    ("op", "start".into()),
                    ("job", Json::from(*job)),
                    ("gpus", Json::Arr(gpus.iter().map(|&g| Json::from(g)).collect())),
                    ("accum_step", Json::from(*accum_step as u64)),
                ]),
                Decision::Preempt { job } => {
                    obj(vec![("op", "preempt".into()), ("job", Json::from(*job))])
                }
            })
            .collect(),
    )
}

#[derive(Debug)]
pub struct AuditSink {
    path: Option<PathBuf>,
    lines: Vec<String>,
}

impl AuditSink {
    pub fn new(path: Option<PathBuf>) -> Self {
        AuditSink { path, lines: Vec::new() }
    }

    fn push(&mut self, j: Json) {
        self.lines.push(j.to_string());
    }

    /// An applied transaction. Empty ("no action") transactions are
    /// skipped — an event-per-line record of inaction would drown the
    /// actual decisions.
    pub fn applied(&mut self, t: f64, policy: &str, txn: &Txn, report: &ApplyReport) {
        if txn.is_empty() {
            return;
        }
        self.push(obj(vec![
            ("t", Json::Num(t)),
            ("kind", "apply".into()),
            ("policy", policy.into()),
            ("starts", Json::from(report.starts)),
            ("preemptions", Json::from(report.preemptions)),
            ("ops", ops_json(txn)),
        ]));
    }

    pub fn rejected(&mut self, t: f64, policy: &str, txn: &Txn, cause: &str) {
        self.push(obj(vec![
            ("t", Json::Num(t)),
            ("kind", "reject".into()),
            ("policy", policy.into()),
            ("cause", cause.into()),
            ("ops", ops_json(txn)),
        ]));
    }

    pub fn alg2(&mut self, t: f64, a: &Alg2Audit) {
        self.push(obj(vec![
            ("t", Json::Num(t)),
            ("kind", "alg2".into()),
            ("job", Json::from(a.job)),
            ("owner", Json::from(a.owner)),
            ("accepted", Json::from(a.accepted)),
            ("reason", a.reason.into()),
            ("accum_step", a.accum_step.map(|s| Json::from(s as u64)).unwrap_or(Json::Null)),
            ("pair_jct_s", a.pair_jct_s.map(Json::Num).unwrap_or(Json::Null)),
        ]));
    }

    pub fn note(&mut self, t: f64, policy: &str, msg: &str) {
        self.push(obj(vec![
            ("t", Json::Num(t)),
            ("kind", "note".into()),
            ("policy", policy.into()),
            ("msg", msg.into()),
        ]));
    }

    pub fn len(&self) -> usize {
        self.lines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }

    /// Mid-run checkpoint: write the lines recorded so far. Recording
    /// continues; a later flush or finish rewrites the file.
    pub fn flush(&self) -> Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        write_file(path, &(self.lines.join("\n") + "\n"))
    }

    pub fn finish(&mut self) -> Result<()> {
        self.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lines_are_parseable_json_and_empty_txns_are_skipped() {
        let mut a = AuditSink::new(None);
        a.applied(0.0, "FIFO", &Txn::new(), &ApplyReport::default());
        assert!(a.is_empty());
        let mut txn = Txn::new();
        txn.start(3, vec![0, 1], 2);
        txn.preempt(7);
        a.applied(1.5, "Tiresias", &txn, &ApplyReport { starts: 1, preemptions: 1 });
        a.rejected(2.0, "Tiresias", &txn, "Start(3): job is Running");
        a.alg2(
            3.0,
            &Alg2Audit {
                job: 5,
                owner: 3,
                accepted: false,
                reason: "memory-infeasible",
                accum_step: None,
                pair_jct_s: None,
            },
        );
        a.note(4.0, "FIFO", "HOL blocked on job 9");
        assert_eq!(a.len(), 4);
        for line in &a.lines {
            let j = Json::parse(line).unwrap();
            assert!(j.get("kind").is_some());
            assert!(j.get("t").is_some());
        }
        let apply = Json::parse(&a.lines[0]).unwrap();
        assert_eq!(apply.get("kind").unwrap().as_str(), Some("apply"));
        let ops = apply.get("ops").unwrap().as_arr().unwrap();
        assert_eq!(ops.len(), 2);
        assert_eq!(ops[0].get("op").unwrap().as_str(), Some("start"));
        assert_eq!(ops[0].get("gpus").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(ops[1].get("op").unwrap().as_str(), Some("preempt"));
        let alg2 = Json::parse(&a.lines[2]).unwrap();
        assert_eq!(alg2.get("accepted").unwrap().as_bool(), Some(false));
        assert_eq!(alg2.get("accum_step"), Some(&Json::Null));
    }
}

//! # wise-share
//!
//! Production-grade reproduction of *"Scheduling Deep Learning Jobs in
//! Multi-Tenant GPU Clusters via Wise Resource Sharing"* (CS.DC 2024):
//! the **SJF-BSBF** scheduler — non-preemptive shortest-job-first with
//! best-sharing-benefit-first GPU co-location, gradient accumulation for
//! memory feasibility, and a closed-form (Theorem 1) share-or-wait decision
//! per job pair.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — cluster substrate, the shared event-driven
//!   scheduling core ([`sched_core`]: typed events, cached scheduling
//!   context, validated transaction layer), discrete-event simulator, seven
//!   scheduling policies (the paper's six plus the k-way `SJF-BSBF-k`
//!   behind a per-cluster share cap C, DESIGN.md §17),
//!   preset-driven workload generation (pluggable
//!   arrival processes + duration estimators, [`jobs::workload`] /
//!   [`jobs::estimate`]), metrics/reporting,
//!   a declarative parallel scenario-sweep engine ([`campaign`]), a
//!   machine-readable bench suite registry with JSON perf reports and
//!   baseline regression gates ([`perfkit`]), and a
//!   physical-mode coordinator that *actually executes* every job's
//!   training iterations via AOT-compiled XLA programs through PJRT
//!   ([`runtime`], [`coordinator`]) — through the *same* `sched_core`
//!   apply path the simulator uses, so sim/physical fidelity is by
//!   construction, not by convention.
//! * **L2** — `python/compile/model.py`: a transformer LM fwd/bwd in JAX
//!   decomposed into `grad_step` / `accum` / `apply` artifacts so the Rust
//!   hot loop owns the gradient-accumulation schedule.
//! * **L1** — `python/compile/kernels/`: Pallas GEMM + flash-attention
//!   kernels (interpret mode) with jnp oracles.
//!
//! See DESIGN.md for the full system inventory and the per-experiment index
//! (every table/figure of the paper mapped to a bench target).

pub mod campaign;
pub mod cluster;
pub mod coordinator;
pub mod jobs;
pub mod obskit;
pub mod pair;
pub mod perf;
pub mod perfkit;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod sched_core;
pub mod serve;
pub mod sim;
pub mod util;

pub use cluster::{AllocView, Cluster, ClusterConfig, ClusterOverlay, Topology};
pub use jobs::{JobRecord, JobSpec, JobState};
pub use perf::interference::InterferenceModel;
pub use obskit::{Obs, ObsConfig};
pub use perf::GangSpan;
pub use sched_core::{Event, Policy, SchedContext, Txn};
pub use sim::engine::run as simulate;

//! `wise-share` — CLI launcher for the SJF-BSBF reproduction.
//!
//! Subcommands:
//! * `simulate`   — run a policy (or all) over a synthetic/loaded trace on
//!                  the simulated cluster; prints paper-style tables.
//! * `campaign`   — run a declarative scenario sweep (policy × load × jobs
//!                  × GPUs × seeds) on a parallel worker pool; prints
//!                  seed-averaged tables with CIs and writes a long CSV.
//! * `bench`      — run the registered perfkit suites (the `cargo bench`
//!                  bodies), emit a schema-versioned JSON report, and
//!                  optionally gate against a recorded baseline (nonzero
//!                  exit on regression). CI's `bench-smoke` entry point.
//! * `physical`   — run the physical-mode coordinator: real PJRT training
//!                  steps on emulated GPUs (requires `make artifacts`).
//! * `serve`      — run the scheduler as a long-lived daemon: live job
//!                  ingestion over a line-JSON protocol, backpressure,
//!                  crash-recovery snapshots (DESIGN.md §14).
//! * `serve-load` — replay a workload preset as live traffic against an
//!                  in-process daemon; reports latency percentiles.
//! * `trace-gen`  — generate and save a Philly-like trace as JSON.
//! * `fit`        — demonstrate the Eq. 3/4 calibration path (Fig. 2 check).
//!
//! Flag parsing is first-party (`Args`) — the vendored crate set has no
//! clap; see DESIGN.md §4.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use wise_share::campaign::{self, CampaignSpec};
use wise_share::cluster::{topology, Cluster, ClusterConfig};
use wise_share::coordinator::{run_physical_obs, write_loss_csv, PhysicalConfig};
use wise_share::obskit::{Obs, ObsConfig};
use wise_share::jobs::estimate::{self, EstimateModel};
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::jobs::workload;
use wise_share::perf::fit::{fit_comp, Sample};
use wise_share::perf::interference::InterferenceModel;
use wise_share::perf::profiles::{ModelKind, WorkloadProfile};
use wise_share::perfkit;
use wise_share::report;
use wise_share::sched::{self, POLICY_NAMES};
use wise_share::serve;
use wise_share::sim::{engine, metrics};

const USAGE: &str = "\
wise-share — SJF-BSBF scheduling reproduction

USAGE:
  wise-share simulate  [--policy NAME|all] [--jobs N] [--seed S] [--trace F]
                       [--cluster physical|simulation | --topology SHAPE]
                       [--max-share C]
                       [--workload PRESET] [--estimator SPEC]
                       [--xi X] [--load L]
                       [--trace-out F] [--metrics-out F] [--audit-out F]
                       [--sample-every SECS]
  wise-share campaign  (--spec FILE | --preset paper) [--threads N]
                       [--csv F] [--trace-dir D] [--metrics-dir D]
                       [--audit-dir D] [--sample-every SECS]
  wise-share bench     [--suite NAMES] [--profile quick|full] [--out F]
                       [--baseline F] [--max-regress PCT] | [--check F]
                       | [--list]
  wise-share serve     [--policy NAME] [--cluster physical|simulation |
                        --topology SHAPE] [--xi X] [--max-pending N]
                       [--time-compression X] [--listen ADDR]
                       [--snapshot PATH [--snapshot-every SECS]]
                       [--resume PATH]
                       [--trace-out F] [--metrics-out F] [--audit-out F]
                       [--sample-every SECS]
  wise-share serve-load [--workload PRESET] [--load X] [--jobs N] [--seed S]
                       [--policy NAME] [--max-pending N]
                       [--cluster physical|simulation | --topology SHAPE]
  wise-share physical  [--policy NAME] [--jobs N] [--seed S]
                       [--iter-scale F] [--compress F] [--loss-csv F]
                       [--artifacts DIR]
                       [--trace-out F] [--metrics-out F] [--audit-out F]
  wise-share trace-gen --out F [--jobs N] [--seed S] [--preset physical|simulation]
                       [--workload PRESET] [--estimator SPEC]
  wise-share fit       [--model NAME]

Topology SHAPEs (named cluster shapes, also usable on the campaign
`topologies` axis): uniform-4x4, uniform-16x4, uniform-16x4-nvlink,
hetero-16x4-2tier.

Workload PRESETs (arrival process x job mix x iteration tail, also usable
on the campaign `workloads` axis): philly-sim, philly-physical,
helios-heavy-tail, small-job-flood.

Estimator SPECs (scheduler-visible duration estimates, also usable on the
campaign `estimators` axis): oracle | noisy:SIGMA[:SEED] | percentile:PCT.

Share cap (DESIGN.md §17): `simulate --max-share C` caps every GPU at C
co-resident jobs (default 2, the paper's pair sharing; also usable on
the campaign `share_caps` axis). C >= 3 only changes schedules under
sharing policies that probe beyond pairs (SJF-FFS, SJF-BSBF-k).

Observability (obskit, DESIGN.md §13): --trace-out writes a
Perfetto-viewable Chrome-trace JSON (plus a sibling .jsonl event stream),
--metrics-out a runtime-metrics JSON (counters, on_event latency
histograms, utilization samples every --sample-every sim-seconds,
default 60), --audit-out a decision-audit JSONL. With `--policy all` the
policy name is inserted before the file extension. The campaign variants
take directories and write one artifact set per run ordinal. Sinks off
(the default) cost nothing and outputs are byte-identical.

Bench SUITE names (comma-separated for --suite; default = all): tables,
figures, ablations, sched_overhead, runtime_hotpath, campaign_throughput,
scale, scale_xl, serve. `--out` writes the schema-versioned JSON perf report;
`--baseline` + `--max-regress` (default 10) gate on a recorded report
with a nonzero exit on regression; `--check F` only validates an emitted
report; `--list` prints the registered suites and profiles.

Serve (DESIGN.md §14): a line-JSON request per stdin line (submit,
cancel, query, advance, snapshot, drain), responses and streamed
started/completed/rejected events on stdout; `--listen ADDR` accepts one
TCP client instead. Time is virtual (moves on `advance`/`drain`) unless
--time-compression X pins it to wall_elapsed*X. --snapshot PATH writes
crash-recovery snapshots every --snapshot-every sim-seconds (default
300) and at exit; `serve --resume PATH` restores one and keeps going.
";

/// Tiny `--key value` flag parser.
struct Args(HashMap<String, String>);

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut m = HashMap::new();
        let mut it = argv.iter();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {a:?}\n{USAGE}"))?;
            let val = it.next().with_context(|| format!("--{key} needs a value"))?;
            m.insert(key.to_string(), val.clone());
        }
        Ok(Args(m))
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.0.get(key).map(String::as_str)
    }

    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {v:?}: {e}")),
        }
    }
}

/// One preset = a cluster shape plus the matching trace-generator shape.
/// The single name → preset table shared by every subcommand that takes a
/// preset (`simulate --cluster`, `trace-gen --preset`), so the names and
/// the error message cannot drift apart again; cluster and trace halves
/// are derived independently, so no caller has to fabricate trace
/// parameters just to look up a cluster.
#[derive(Clone, Copy)]
enum Preset {
    Physical,
    Simulation,
}

impl Preset {
    fn cluster(self) -> ClusterConfig {
        match self {
            Preset::Physical => ClusterConfig::physical(),
            Preset::Simulation => ClusterConfig::simulation(),
        }
    }

    fn trace(self, jobs: usize, seed: u64) -> TraceConfig {
        match self {
            Preset::Physical => TraceConfig::physical(seed),
            Preset::Simulation => TraceConfig::simulation(jobs, seed),
        }
    }
}

fn preset_by_name(name: &str) -> Result<Preset> {
    Ok(match name {
        "physical" => Preset::Physical,
        "simulation" => Preset::Simulation,
        _ => bail!("unknown cluster preset {name:?} (known: physical, simulation)"),
    })
}

/// `path` with `policy` slugged in before the final extension
/// (`out.trace.json` → `out.trace.sjf-bsbf.json`) — how `--policy all`
/// keeps six runs' artifacts apart. `None` passes the path through.
fn with_policy_suffix(path: &str, policy: Option<&str>) -> PathBuf {
    let p = PathBuf::from(path);
    let Some(name) = policy else { return p };
    let slug: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '-' })
        .collect();
    let file = match (p.file_stem().and_then(|s| s.to_str()), p.extension()) {
        (Some(stem), Some(ext)) => format!("{stem}.{slug}.{}", ext.to_string_lossy()),
        (Some(stem), None) => format!("{stem}.{slug}"),
        _ => slug,
    };
    p.with_file_name(file)
}

/// Parse `--{key}` as a strictly positive finite float, rejecting zero,
/// negatives, NaN, and infinities at parse time with the flag named in
/// the error — shared by every interval/factor flag (`--sample-every`,
/// `--load`, `--snapshot-every`, `--time-compression`).
fn positive_f64(args: &Args, key: &str, default: f64) -> Result<f64> {
    let v: f64 = args.parse_or(key, default)?;
    if v <= 0.0 || !v.is_finite() {
        bail!("--{key} {v} must be finite and > 0");
    }
    Ok(v)
}

/// The per-run sink config from `--trace-out` / `--metrics-out` /
/// `--audit-out` / `--sample-every`; `policy` is `Some` only when several
/// policies share the flags (`--policy all`).
fn obs_config(args: &Args, policy: Option<&str>) -> Result<ObsConfig> {
    let sample_every = positive_f64(args, "sample-every", 60.0)?;
    Ok(ObsConfig {
        trace: args.get("trace-out").map(|p| with_policy_suffix(p, policy)),
        metrics: args.get("metrics-out").map(|p| with_policy_suffix(p, policy)),
        audit: args.get("audit-out").map(|p| with_policy_suffix(p, policy)),
        sample_every_s: sample_every,
    })
}

/// Flush `obs` and note each written artifact on stderr, keeping stdout
/// byte-identical to an obs-off run.
fn finish_obs(obs: &Obs, cfg: &ObsConfig) -> Result<()> {
    obs.finish()?;
    for (what, path) in [
        ("chrome trace", &cfg.trace),
        ("runtime metrics", &cfg.metrics),
        ("decision audit", &cfg.audit),
    ] {
        if let Some(p) = path {
            eprintln!("{what} -> {}", p.display());
        }
    }
    Ok(())
}

/// Resolve `--cluster` (flat preset) / `--topology` (named shape) into a
/// concrete cluster; the flags are mutually exclusive.
fn resolve_cluster(args: &Args) -> Result<Cluster> {
    match (args.get("topology"), args.get("cluster")) {
        (Some(_), Some(_)) => bail!("--topology and --cluster are mutually exclusive"),
        (Some(shape), None) => {
            Ok(Cluster::with_topology(topology::by_name_or_err(shape)?))
        }
        (None, name) => {
            Ok(Cluster::new(preset_by_name(name.unwrap_or("simulation"))?.cluster()))
        }
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let mut cluster = resolve_cluster(args)?;
    if let Some(v) = args.get("max-share") {
        let cap: usize =
            v.parse().map_err(|e| anyhow::anyhow!("--max-share {v:?}: {e}"))?;
        if cap == 0 {
            bail!("--max-share 0 must be at least 1");
        }
        cluster.set_max_share(cap);
    }
    let jobs: usize = args.parse_or("jobs", 240)?;
    let seed: u64 = args.parse_or("seed", 1)?;
    let load = positive_f64(args, "load", 1.0)?;
    let jobs_list = match args.get("trace") {
        Some(p) => {
            if args.get("workload").is_some() {
                bail!("--trace and --workload are mutually exclusive");
            }
            let mut loaded = trace::load(std::path::Path::new(p)).context("loading trace")?;
            // Only an explicit --estimator overrides whatever factors the
            // trace file carries.
            if let Some(spec) = args.get("estimator") {
                estimate::materialize(&mut loaded, &EstimateModel::parse(spec)?, seed);
            }
            loaded
        }
        None => {
            let preset = workload::by_name_or_err(args.get("workload").unwrap_or("philly-sim"))?;
            let mut cfg = TraceConfig::from_preset(&preset, jobs, seed);
            cfg.estimator = EstimateModel::parse(args.get("estimator").unwrap_or("oracle"))?;
            cfg.load_factor = load;
            trace::generate(&cfg)
        }
    };
    let xi_model = match args.get("xi") {
        Some(v) => InterferenceModel::with_global(v.parse()?),
        None => InterferenceModel::new(),
    };
    let policy = args.get("policy").unwrap_or("all");
    let names: Vec<String> = if policy == "all" {
        POLICY_NAMES.iter().map(|s| s.to_string()).collect()
    } else {
        vec![policy.to_string()]
    };
    let mut rows = Vec::new();
    for name in &names {
        let mut p =
            sched::by_name(name).with_context(|| format!("unknown policy {name}"))?;
        let ocfg = obs_config(args, (names.len() > 1).then_some(name.as_str()))?;
        let obs = Obs::new(ocfg.clone());
        let out = engine::run_cluster_obs(
            cluster.clone(),
            &jobs_list,
            xi_model.clone(),
            p.as_mut(),
            engine::EngineConfig::default(),
            obs.clone(),
        )?;
        finish_obs(&obs, &ocfg)?;
        let s = metrics::summarize(name, &out.jobs, out.makespan_s);
        let unfinished = if s.all.unfinished > 0 {
            format!(", {} UNFINISHED", s.all.unfinished)
        } else {
            String::new()
        };
        println!(
            "{name}: makespan {:.0}s, avg JCT {:.1}s, {} preemptions, {} policy calls{unfinished}",
            out.makespan_s, s.all.avg_jct_s, out.preemptions, out.policy_calls,
        );
        rows.push(s);
    }
    println!("\n{}", report::table34(&rows));
    Ok(())
}

fn cmd_campaign(args: &Args) -> Result<()> {
    let spec = match (args.get("spec"), args.get("preset")) {
        (Some(path), None) => CampaignSpec::load(&PathBuf::from(path))?,
        (None, Some("paper")) => CampaignSpec::paper_preset(),
        (None, Some(other)) => bail!("unknown preset {other:?} (available: paper)"),
        (Some(_), Some(_)) => bail!("--spec and --preset are mutually exclusive"),
        (None, None) => bail!("campaign needs --spec FILE or --preset paper\n{USAGE}"),
    };
    let threads: usize = args.parse_or("threads", 0)?;
    let sample_every = positive_f64(args, "sample-every", 60.0)?;
    let obs_dirs = campaign::ObsDirs {
        trace_dir: args.get("trace-dir").map(PathBuf::from),
        metrics_dir: args.get("metrics-dir").map(PathBuf::from),
        audit_dir: args.get("audit-dir").map(PathBuf::from),
        sample_every_s: sample_every,
    };
    let points = campaign::expand(&spec)?;
    println!(
        "campaign {:?}: {} runs over {} worker thread(s)",
        spec.name,
        points.len(),
        campaign::resolved_threads(points.len(), threads),
    );
    let res = campaign::execute_matrix_obs(&points, threads, &obs_dirs);
    if obs_dirs.is_enabled() {
        // Artifact notices go to stderr: stdout stays byte-identical to
        // an obs-off campaign (the determinism gate compares it).
        for (what, dir) in [
            ("chrome traces", &obs_dirs.trace_dir),
            ("runtime metrics", &obs_dirs.metrics_dir),
            ("decision audits", &obs_dirs.audit_dir),
        ] {
            if let Some(d) = dir {
                eprintln!("{what} ({} per-run files) -> {}", res.n_runs, d.display());
            }
        }
    }
    print!("{}", campaign::emit::markdown(&spec.name, &res.cells));
    let csv_path = PathBuf::from(args.get("csv").unwrap_or("campaign_results.csv"));
    std::fs::write(&csv_path, campaign::emit::long_csv(&spec.name, &res.cells))
        .with_context(|| format!("writing {}", csv_path.display()))?;
    println!(
        "long-format CSV -> {} ({} runs in {:.1}s wall, {} failed)",
        csv_path.display(),
        res.n_runs,
        res.wall_s,
        res.n_failures
    );
    if res.n_failures > 0 {
        bail!("{} of {} runs failed (see FAILED lines above)", res.n_failures, res.n_runs);
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    if let Some(path) = args.get("check") {
        if args.0.len() > 1 {
            bail!("--check validates an existing report and takes no other flags");
        }
        return perfkit::check_file(std::path::Path::new(path));
    }
    // A silently-dropped typo (`--basline F`) would disable the gate and
    // exit 0 — reject anything but the known flags, like bench_main does.
    for key in args.0.keys() {
        if !["suite", "profile", "out", "baseline", "max-regress"].contains(&key.as_str()) {
            bail!(
                "unknown bench flag --{key} (known: --suite, --profile, --out, \
                 --baseline, --max-regress, --check)"
            );
        }
    }
    let cfg = perfkit::RunConfig {
        suites: args
            .get("suite")
            .map(|s| s.split(',').map(str::to_string).collect())
            .unwrap_or_default(),
        profile: perfkit::Profile::parse(args.get("profile").unwrap_or("full"))?,
        out: args.get("out").map(PathBuf::from),
        baseline: args.get("baseline").map(PathBuf::from),
        max_regress_pct: args.parse_or("max-regress", perfkit::DEFAULT_MAX_REGRESS_PCT)?,
    };
    if cfg.max_regress_pct.is_nan() || cfg.max_regress_pct < 0.0 {
        bail!("--max-regress {} must be a non-negative percentage", cfg.max_regress_pct);
    }
    perfkit::run(&cfg).map(|_| ())
}

fn cmd_physical(args: &Args) -> Result<()> {
    let policy = args.get("policy").unwrap_or("SJF-BSBF").to_string();
    let mut p =
        sched::by_name(&policy).with_context(|| format!("unknown policy {policy}"))?;
    let mut cfg = PhysicalConfig {
        iter_scale: args.parse_or("iter-scale", 0.02)?,
        time_compression: args.parse_or("compress", 120.0)?,
        ..PhysicalConfig::default()
    };
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(dir);
    }
    let mut tcfg = TraceConfig::physical(args.parse_or("seed", 1)?);
    tcfg.n_jobs = args.parse_or("jobs", 8)?;
    let mut jobs_list = trace::generate(&tcfg);
    for j in &mut jobs_list {
        j.gpus = j.gpus.min(cfg.cluster.total_gpus());
    }
    let ocfg = obs_config(args, None)?;
    let obs = Obs::new(ocfg.clone());
    let out =
        run_physical_obs(cfg, &jobs_list, InterferenceModel::new(), p.as_mut(), obs.clone())?;
    finish_obs(&obs, &ocfg)?;
    let summary = metrics::summarize(&policy, &out.jobs, out.makespan_s);
    println!(
        "{policy}: makespan {:.1}s wall, avg JCT {:.1}s, {} PJRT iterations executed",
        out.makespan_s, summary.all.avg_jct_s, out.executed_iters
    );
    println!("{}", report::table2(&[summary]));
    if let Some(path) = args.get("loss-csv") {
        let path = PathBuf::from(path);
        write_loss_csv(&out.loss_curves, &path)?;
        println!("loss curves -> {}", path.display());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let ocfg = obs_config(args, None)?;
    let obs = Obs::new(ocfg.clone());
    let snapshot = args.get("snapshot").map(PathBuf::from);
    // Validate the interval/ratio flags up front (named errors at parse
    // time), before any daemon state exists.
    let snapshot_every_s = positive_f64(args, "snapshot-every", 300.0)?;
    let max_pending: usize = args.parse_or("max-pending", 64)?;
    if max_pending == 0 {
        bail!("--max-pending 0 must be at least 1");
    }
    let time_compression = match args.get("time-compression") {
        None => None,
        Some(_) => Some(positive_f64(args, "time-compression", 1.0)?),
    };
    let daemon = match args.get("resume") {
        Some(path) => {
            // The snapshot pins the scheduling config; accepting these
            // flags alongside --resume would silently ignore them.
            for k in ["policy", "cluster", "topology", "xi", "max-pending", "time-compression"]
            {
                if args.get(k).is_some() {
                    bail!("--{k} conflicts with --resume (the snapshot pins it)");
                }
            }
            serve::Daemon::resume(std::path::Path::new(path), snapshot, obs.clone())?
        }
        None => {
            if args.get("snapshot-every").is_some() && snapshot.is_none() {
                bail!("--snapshot-every requires --snapshot PATH");
            }
            let cfg = serve::ServeConfig {
                policy: args.get("policy").unwrap_or("SJF-BSBF").to_string(),
                cluster: serve::ClusterSpec::from_args(
                    args.get("topology"),
                    args.get("cluster"),
                )?,
                xi_global: match args.get("xi") {
                    Some(v) => {
                        Some(v.parse().map_err(|e| anyhow::anyhow!("--xi {v:?}: {e}"))?)
                    }
                    None => None,
                },
                max_pending,
                time_compression,
                snapshot,
                snapshot_every_s,
                ..serve::ServeConfig::default()
            };
            serve::Daemon::new(cfg, obs.clone())?
        }
    };
    serve::run(daemon, args.get("listen"))?;
    finish_obs(&obs, &ocfg)
}

fn cmd_serve_load(args: &Args) -> Result<()> {
    let cfg = serve::LoadConfig {
        preset: args.get("workload").unwrap_or("philly-sim").to_string(),
        load: positive_f64(args, "load", 1.0)?,
        jobs: args.parse_or("jobs", 96)?,
        seed: args.parse_or("seed", 1)?,
        policy: args.get("policy").unwrap_or("SJF-BSBF").to_string(),
        max_pending: args.parse_or("max-pending", 64)?,
        cluster: serve::ClusterSpec::from_args(args.get("topology"), args.get("cluster"))?,
    };
    if cfg.max_pending == 0 {
        bail!("--max-pending 0 must be at least 1");
    }
    let out = serve::load::run(&cfg, Obs::disabled())?;
    println!("{}", out.summary());
    Ok(())
}

fn cmd_trace_gen(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get("out").context("--out is required")?);
    let seed: u64 = args.parse_or("seed", 1)?;
    let jobs: usize = args.parse_or("jobs", 240)?;
    let mut cfg = match (args.get("workload"), args.get("preset")) {
        (Some(_), Some(_)) => bail!("--workload and --preset are mutually exclusive"),
        (Some(w), None) => TraceConfig::from_preset(&workload::by_name_or_err(w)?, jobs, seed),
        (None, p) => preset_by_name(p.unwrap_or("simulation"))?.trace(jobs, seed),
    };
    // Estimates are trace-time artifacts: baking them in here lets a
    // saved trace replay the exact same mispredictions everywhere.
    cfg.estimator = EstimateModel::parse(args.get("estimator").unwrap_or("oracle"))?;
    let jobs_list = trace::generate(&cfg);
    trace::save(&jobs_list, &out)?;
    println!("wrote {} jobs to {}", jobs_list.len(), out.display());
    Ok(())
}

fn cmd_fit(args: &Args) -> Result<()> {
    let model = args.get("model").unwrap_or("BERT");
    let kind =
        ModelKind::from_name(model).with_context(|| format!("unknown model {model}"))?;
    let prof = WorkloadProfile::get(kind);
    // Synthesize single-GPU samples from the ground-truth profile, then
    // recover α/β — the calibration loop a deployment runs (§IV-B).
    let samples: Vec<Sample> = [1u32, 2, 4, 8, 16, 32]
        .iter()
        .map(|&b| Sample { batch: b as f64, iter_time_s: prof.perf.comp.t_comp(b as f64) })
        .collect();
    let fitted = fit_comp(&samples).context("fit failed")?;
    println!(
        "{}: true α={:.4} β={:.5} | fitted α={:.4} β={:.5}",
        kind.name(),
        prof.perf.comp.alpha,
        prof.perf.comp.beta,
        fitted.alpha,
        fitted.beta
    );
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        println!("{USAGE}");
        return Ok(());
    };
    // `--list` is the one valueless flag; the `--key value` parser would
    // reject it, so dispatch it before Args::parse.
    if cmd == "bench" && rest == ["--list"] {
        print!("{}", perfkit::list());
        return Ok(());
    }
    let args = Args::parse(rest)?;
    match cmd.as_str() {
        "simulate" => cmd_simulate(&args),
        "campaign" => cmd_campaign(&args),
        "bench" => cmd_bench(&args),
        "physical" => cmd_physical(&args),
        "serve" => cmd_serve(&args),
        "serve-load" => cmd_serve_load(&args),
        "trace-gen" => cmd_trace_gen(&args),
        "fit" => cmd_fit(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other}\n{USAGE}"),
    }
}

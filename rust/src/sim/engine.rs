//! The discrete-event engine: advances time between events, integrates job
//! progress at piecewise-constant rates, delivers typed [`Event`]s to the
//! policy, and applies the returned [`Txn`]s through the shared
//! [`sched_core`](crate::sched_core) validation layer.
//!
//! Event selection is O(1) amortized per event: next arrival comes from
//! the context's sorted arrival queue, next completion and next restart
//! eligibility from its lazily invalidated calendar queues — and job
//! progress integrates lazily (settled only on rate transitions), so
//! per-event cost no longer grows with cluster occupancy (DESIGN.md §15).
//!
//! The steady-state loop also allocates nothing per event: the two event
//! vecs below are reused across iterations, the policies' planning views
//! draw from the context's pooled overlay scratch, and the completion
//! sweep reuses a pooled id buffer
//! ([`SchedContext::collect_completions`]).

use anyhow::{bail, Result};

use super::{Event, Policy, SimState};
use crate::cluster::{Cluster, ClusterConfig};
use crate::jobs::{JobRecord, JobSpec};
use crate::obskit::Obs;
use crate::perf::interference::InterferenceModel;
use crate::sched_core::SchedContext;

/// Engine tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Hard wall on simulated time (safety net against livelock).
    pub max_sim_s: f64,
    /// Numeric epsilon for "job finished".
    pub eps_iters: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { max_sim_s: 120.0 * 24.0 * 3600.0, eps_iters: 1e-6 }
    }
}

/// Outcome of a full simulation run.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    pub jobs: Vec<JobRecord>,
    /// Total simulated span from first arrival to last completion.
    pub makespan_s: f64,
    /// Number of policy *passes* delivered. For event-reactive policies
    /// this equals the event count; for policies opting into
    /// [`Policy::coalesce_coincident`] it is smaller, because the tail
    /// of a same-instant batch is absorbed once a pass returns an empty
    /// transaction (see the [`Event`] docs).
    pub policy_calls: u64,
    /// Number of preemptions performed.
    pub preemptions: u64,
    /// GPU-seconds with ≥ 1 resident job over the run (utilization
    /// integral; divide by `total_gpus × makespan_s` for mean GPU util).
    pub busy_gpu_s: f64,
    /// GPU-seconds with ≥ 2 resident jobs (co-located intervals; divide
    /// by `busy_gpu_s` for the sharing fraction).
    pub shared_gpu_s: f64,
    /// Cluster size the integrals are against.
    pub total_gpus: usize,
}

/// Run `policy` over `trace` on a uniform cluster of `cluster_cfg` with
/// interference model `xi`. Jobs must be pre-sorted by arrival
/// (trace::generate is).
pub fn run(
    cluster_cfg: ClusterConfig,
    trace: &[JobSpec],
    xi: InterferenceModel,
    policy: &mut dyn Policy,
) -> Result<SimOutcome> {
    run_with(cluster_cfg, trace, xi, policy, EngineConfig::default())
}

pub fn run_with(
    cluster_cfg: ClusterConfig,
    trace: &[JobSpec],
    xi: InterferenceModel,
    policy: &mut dyn Policy,
    engine_cfg: EngineConfig,
) -> Result<SimOutcome> {
    run_cluster(Cluster::new(cluster_cfg), trace, xi, policy, engine_cfg)
}

/// Run over an explicit (possibly heterogeneous, topology-built)
/// [`Cluster`] — the entry point for named topology shapes
/// (`cluster::topology::by_name`) and the campaign `topologies` axis.
/// `run`/`run_with` are thin uniform-topology wrappers over this.
pub fn run_cluster(
    cluster: Cluster,
    trace: &[JobSpec],
    xi: InterferenceModel,
    policy: &mut dyn Policy,
    engine_cfg: EngineConfig,
) -> Result<SimOutcome> {
    run_cluster_obs(cluster, trace, xi, policy, engine_cfg, Obs::disabled())
}

/// [`run_cluster`] with an observability handle threaded through the
/// engine and the context. With `Obs::disabled()` this *is*
/// `run_cluster` — one `Option` branch per tap, no timing, no
/// allocation; with sinks armed the sim results are still bit-identical
/// (observation is one-way) and the caller owns flushing via
/// [`Obs::finish`].
pub fn run_cluster_obs(
    cluster: Cluster,
    trace: &[JobSpec],
    xi: InterferenceModel,
    policy: &mut dyn Policy,
    engine_cfg: EngineConfig,
    obs: Obs,
) -> Result<SimOutcome> {
    for j in trace {
        if j.gpus > cluster.total_gpus() {
            bail!("job {} requests {} GPUs > cluster {}", j.id, j.gpus, cluster.total_gpus());
        }
        // Memory-aware placement silently skips infeasible jobs per pass,
        // so reject up front any job that can *never* run: even sub-batch
        // 1 (the deepest gradient accumulation) must fit on enough GPUs
        // to host its gang. Otherwise the run would stall quietly instead
        // of diagnosing the trace.
        let floor_gb = j.profile().mem.mem_gb(1.0);
        let hosts = (0..cluster.total_gpus())
            .filter(|&g| cluster.mem_gb(g) + 1e-9 >= floor_gb)
            .count();
        if hosts < j.gpus {
            bail!(
                "job {} needs {:.1} GB per GPU even at sub-batch 1, but only {hosts} of \
                 {} GPUs can hold that (gang of {})",
                j.id,
                floor_gb,
                cluster.total_gpus(),
                j.gpus
            );
        }
    }
    let mut ctx = SchedContext::new(
        cluster,
        trace.iter().cloned().map(JobRecord::new).collect(),
        xi,
    );
    let obs_enabled = obs.is_enabled();
    ctx.set_obs(obs.clone());
    let penalty = policy.preemption_penalty();
    let mut next_tick = policy.tick_interval();
    let mut policy_calls = 0u64;
    let mut preemptions = 0u64;
    // Events that fired at the current instant, in delivery order:
    // completions, then arrivals, then restart eligibilities, then tick.
    let mut events: Vec<Event> = Vec::new();
    let mut clock_events: Vec<Event> = Vec::new();

    loop {
        // ---- choose the next event time (heap peeks, O(log n)) ------------
        let mut t_next = f64::INFINITY;
        if let Some(t) = ctx.next_arrival() {
            t_next = t_next.min(t);
        }
        if let Some(tick) = next_tick {
            t_next = t_next.min(tick);
        }
        if let Some(t) = ctx.next_finish() {
            t_next = t_next.min(t);
        }
        if let Some(t) = ctx.next_restart() {
            t_next = t_next.min(t);
        }
        if !t_next.is_finite() {
            // No arrivals, no running jobs, nothing to wait for.
            if ctx.all_finished() {
                break;
            }
            // Memory-aware placement skips (rather than proposes) jobs an
            // exclusive full-batch start cannot host, so diagnose that
            // case explicitly instead of leaving a bare "deadlock".
            let max_mem = (0..ctx.cluster.total_gpus())
                .map(|g| ctx.cluster.mem_gb(g))
                .fold(0.0f64, f64::max);
            let full_batch_infeasible: Vec<usize> = ctx
                .jobs
                .iter()
                .enumerate()
                .filter(|(_, j)| {
                    j.state != crate::jobs::JobState::Finished
                        && j.spec.profile().mem.mem_gb(j.spec.batch as f64) > max_mem + 1e-9
                })
                .map(|(id, _)| id)
                .collect();
            let hint = if full_batch_infeasible.is_empty() {
                String::new()
            } else {
                format!(
                    "; jobs {full_batch_infeasible:?} cannot fit their full batch on any \
                     GPU — exclusive placement is memory-infeasible for them, only \
                     accumulation-based sharing could run them"
                )
            };
            bail!(
                "deadlock: {} unfinished jobs but no future events (policy never \
                 scheduled them?){hint}",
                ctx.unfinished()
            );
        }
        if t_next > engine_cfg.max_sim_s {
            bail!("simulation exceeded max_sim_s = {}", engine_cfg.max_sim_s);
        }

        // ---- advance: integrate progress, fire arrivals/restarts ----------
        clock_events.clear();
        ctx.advance_sim(t_next, &mut clock_events);

        // ---- completions, then the clock events, then the tick ------------
        events.clear();
        ctx.collect_completions(engine_cfg.eps_iters, &mut events);
        events.append(&mut clock_events);
        if let Some(tick) = next_tick {
            if tick <= ctx.now() + 1e-9 {
                next_tick = Some(tick + policy.tick_interval().unwrap());
                events.push(Event::Tick);
            }
        }
        if events.is_empty() {
            // A finish projection fired but round-off left the job's
            // residual above eps_iters: `collect_completions` already
            // re-projected it from the settled residual, so the next
            // event-selection pass sees a strictly later finish time.
            continue;
        }

        // ---- deliver each event; apply through the shared txn layer -------
        // Under `coalesce_coincident`, once a pass at this instant
        // returns an empty transaction the remaining events of the batch
        // are absorbed without a pass: the policy is a pure decision
        // function of `ctx` alone, and nothing changed since the empty
        // pass, so the skipped passes would have been identical no-ops.
        let coalesce = policy.coalesce_coincident();
        let mut converged = false;
        for &ev in &events {
            if obs_enabled {
                obs.engine_event(ctx.now(), ev);
            }
            if coalesce && converged {
                continue;
            }
            let txn;
            if obs_enabled {
                // Wall-clock the policy pass only when someone is
                // listening: the disabled path must not pay for
                // `Instant::now` syscalls it will never report.
                let t0 = std::time::Instant::now();
                txn = policy.on_event(&ctx, ev);
                obs.policy_latency(policy.name(), t0.elapsed().as_secs_f64());
            } else {
                txn = policy.on_event(&ctx, ev);
            }
            policy_calls += 1;
            if coalesce && txn.is_empty() {
                converged = true;
            }
            match ctx.apply(&txn, penalty) {
                Ok(report) => {
                    if obs_enabled {
                        obs.txn_applied(ctx.now(), policy.name(), &txn, &report);
                    }
                    preemptions += report.preemptions;
                }
                Err(e) => {
                    if obs_enabled {
                        obs.txn_rejected(ctx.now(), policy.name(), &txn, &format!("{e:#}"));
                    }
                    return Err(e);
                }
            }
        }
        if obs_enabled {
            let total = ctx.cluster.total_gpus();
            let busy = total - ctx.cluster.free_count();
            let shared = busy - ctx.cluster.one_job_count();
            obs.cluster_counts(ctx.now(), busy, shared);
            obs.sample(ctx.now(), busy, shared, total, ctx.waiting().len(), ctx.pending().len());
        }

        if ctx.all_finished() {
            break;
        }
    }

    let first_arrival = trace.iter().map(|j| j.arrival_s).fold(f64::INFINITY, f64::min);
    let (busy_gpu_s, shared_gpu_s) = (ctx.busy_gpu_s(), ctx.shared_gpu_s());
    let state: SimState = ctx.into_state();
    let last_finish = state
        .jobs
        .iter()
        .filter_map(|j| j.finish_s)
        .fold(0.0f64, f64::max);
    let total_gpus = state.cluster.total_gpus();
    Ok(SimOutcome {
        jobs: state.jobs,
        makespan_s: (last_finish - first_arrival.min(last_finish)).max(0.0),
        policy_calls,
        preemptions,
        busy_gpu_s,
        shared_gpu_s,
        total_gpus,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::placement;
    use crate::jobs::JobState;
    use crate::perf::profiles::ModelKind;
    use crate::sched_core::Txn;

    /// Minimal exclusive FIFO used to exercise the engine itself.
    struct MiniFifo;
    impl Policy for MiniFifo {
        fn name(&self) -> &'static str {
            "mini-fifo"
        }
        fn on_event(&mut self, ctx: &SchedContext, _ev: Event) -> Txn {
            let mut pending: Vec<usize> = ctx.pending().to_vec();
            pending.sort_by(|&a, &b| {
                ctx.jobs[a].spec.arrival_s.total_cmp(&ctx.jobs[b].spec.arrival_s)
            });
            let mut plan = ctx.overlay();
            let mut txn = Txn::new();
            for id in pending {
                let need = ctx.jobs[id].spec.gpus;
                if let Some(gpus) = placement::consolidated_free(&plan, need) {
                    plan.allocate(id, &gpus);
                    txn.start(id, gpus, 1);
                } else {
                    break; // strict FIFO HOL blocking
                }
            }
            txn
        }
    }

    fn job(id: usize, gpus: usize, iters: u64, arrival: f64) -> JobSpec {
        JobSpec {
            id,
            model: ModelKind::Cifar10,
            gpus,
            iterations: iters,
            batch: 128,
            arrival_s: arrival,
            est_factor: 1.0,
        }
    }

    #[test]
    fn single_job_runs_to_completion() {
        let trace = vec![job(0, 4, 1000, 5.0)];
        let out = run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut MiniFifo,
        )
        .unwrap();
        let j = &out.jobs[0];
        assert_eq!(j.state, JobState::Finished);
        let expect = trace[0].solo_runtime(1);
        let jct = j.jct().unwrap();
        assert!((jct - expect).abs() < 1e-6, "jct={jct} expect={expect}");
        assert_eq!(j.queueing_delay().unwrap(), 0.0);
    }

    #[test]
    fn queueing_accrues_under_contention() {
        // Two 16-GPU jobs: second must wait for the first.
        let trace = vec![job(0, 16, 1000, 0.0), job(1, 16, 1000, 0.0)];
        let out = run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut MiniFifo,
        )
        .unwrap();
        let solo = trace[0].solo_runtime(1);
        let q1 = out.jobs[1].queueing_delay().unwrap();
        assert!((q1 - solo).abs() < 1e-6, "q1={q1} solo={solo}");
        assert!((out.jobs[1].queued_s - solo).abs() < 1e-6);
        assert!((out.makespan_s - 2.0 * solo).abs() < 1e-6);
    }

    #[test]
    fn rejects_oversized_job() {
        let trace = vec![job(0, 64, 10, 0.0)];
        assert!(run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut MiniFifo
        )
        .is_err());
    }

    #[test]
    fn deadlock_detected_for_donothing_policy() {
        struct Nothing;
        impl Policy for Nothing {
            fn name(&self) -> &'static str {
                "nothing"
            }
            fn on_event(&mut self, _: &SchedContext, _: Event) -> Txn {
                Txn::new()
            }
        }
        let trace = vec![job(0, 1, 10, 0.0)];
        let err = run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut Nothing,
        )
        .unwrap_err();
        assert!(err.to_string().contains("deadlock"), "{err}");
    }

    #[test]
    fn bad_decision_rejected() {
        struct DoubleStart;
        impl Policy for DoubleStart {
            fn name(&self) -> &'static str {
                "bad"
            }
            fn on_event(&mut self, ctx: &SchedContext, _: Event) -> Txn {
                let mut txn = Txn::new();
                for &id in ctx.pending() {
                    txn.start(id, vec![0], 1);
                }
                txn.start(0, vec![0], 1);
                txn
            }
        }
        let trace = vec![job(0, 1, 10, 0.0)];
        assert!(run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut DoubleStart
        )
        .is_err());
    }

    #[test]
    fn events_fire_in_documented_order() {
        // Job 1 arrives exactly when job 0 finishes: the policy must see
        // the completion event before the arrival event, both at the same
        // instant, and the state at the completion event must already show
        // job 1 as pending (all transitions precede all deliveries).
        struct Recorder {
            seen: Vec<Event>,
        }
        impl Policy for Recorder {
            fn name(&self) -> &'static str {
                "recorder"
            }
            fn on_event(&mut self, ctx: &SchedContext, ev: Event) -> Txn {
                self.seen.push(ev);
                let mut txn = Txn::new();
                // Exclusive FIFO so the run terminates.
                let mut plan = ctx.overlay();
                for &id in ctx.pending() {
                    if let Some(gpus) =
                        placement::consolidated_free(&plan, ctx.jobs[id].spec.gpus)
                    {
                        plan.allocate(id, &gpus);
                        txn.start(id, gpus, 1);
                    }
                }
                txn
            }
        }
        let solo = job(0, 16, 1000, 0.0).solo_runtime(1);
        let trace = vec![job(0, 16, 1000, 0.0), job(1, 4, 10, solo)];
        let mut rec = Recorder { seen: Vec::new() };
        run(ClusterConfig::physical(), &trace, InterferenceModel::new(), &mut rec)
            .unwrap();
        let c0 = rec
            .seen
            .iter()
            .position(|e| *e == Event::Completion { job: 0 })
            .expect("completion delivered");
        let a1 = rec
            .seen
            .iter()
            .position(|e| *e == Event::Arrival { job: 1 })
            .expect("arrival delivered");
        assert!(c0 < a1, "completion must be delivered before the same-instant arrival");
        assert_eq!(rec.seen[0], Event::Arrival { job: 0 });
    }

    #[test]
    fn preemption_emits_restart_eligible_event() {
        // A policy that preempts job 0 at the first arrival of job 1 and
        // restarts whatever is eligible: the engine must deliver a
        // RestartEligible event exactly one penalty later.
        struct OneShotPreempt {
            fired: bool,
            restart_seen: Option<f64>,
        }
        impl Policy for OneShotPreempt {
            fn name(&self) -> &'static str {
                "one-shot"
            }
            fn preemption_penalty(&self) -> f64 {
                17.0
            }
            fn on_event(&mut self, ctx: &SchedContext, ev: Event) -> Txn {
                let mut txn = Txn::new();
                match ev {
                    Event::RestartEligible { .. } => self.restart_seen = Some(ctx.now()),
                    Event::Arrival { job: 1 } if !self.fired => {
                        self.fired = true;
                        txn.preempt(0);
                        return txn;
                    }
                    _ => {}
                }
                let mut plan = ctx.overlay();
                for &id in ctx.pending() {
                    if let Some(gpus) =
                        placement::consolidated_free(&plan, ctx.jobs[id].spec.gpus)
                    {
                        plan.allocate(id, &gpus);
                        txn.start(id, gpus, 1);
                    }
                }
                txn
            }
        }
        let trace = vec![job(0, 16, 1000, 0.0), job(1, 16, 10, 3.0)];
        let mut p = OneShotPreempt { fired: false, restart_seen: None };
        let out = run(ClusterConfig::physical(), &trace, InterferenceModel::new(), &mut p)
            .unwrap();
        assert_eq!(out.preemptions, 1);
        let t = p.restart_seen.expect("RestartEligible must be delivered");
        assert!((t - 20.0).abs() < 1e-6, "penalty expiry at 3 + 17 s, got {t}");
        assert!(out.jobs.iter().all(|j| j.state == JobState::Finished));
    }

    #[test]
    fn zero_penalty_preempt_fires_restart_eligible_immediately() {
        // A zero-penalty preempt must still deliver RestartEligible (at
        // the preemption instant) — a policy that only reacts to events
        // would otherwise never learn the job is schedulable again and
        // the run would end in a spurious deadlock.
        struct ZeroPenalty {
            preempted: bool,
            restart_at: Option<f64>,
        }
        impl Policy for ZeroPenalty {
            fn name(&self) -> &'static str {
                "zero-penalty"
            }
            fn preemption_penalty(&self) -> f64 {
                0.0
            }
            fn on_event(&mut self, ctx: &SchedContext, ev: Event) -> Txn {
                let mut txn = Txn::new();
                match ev {
                    Event::Arrival { job: 1 } if !self.preempted => {
                        self.preempted = true;
                        txn.preempt(0);
                        return txn; // deliberately restart only on the event
                    }
                    Event::RestartEligible { job: 0 } => {
                        self.restart_at = Some(ctx.now());
                    }
                    _ => {}
                }
                let mut plan = ctx.overlay();
                for &id in ctx.pending() {
                    if let Some(gpus) =
                        placement::consolidated_free(&plan, ctx.jobs[id].spec.gpus)
                    {
                        plan.allocate(id, &gpus);
                        txn.start(id, gpus, 1);
                    }
                }
                txn
            }
        }
        let trace = vec![job(0, 4, 1000, 0.0), job(1, 4, 10, 2.0)];
        let mut p = ZeroPenalty { preempted: false, restart_at: None };
        let out = run(ClusterConfig::physical(), &trace, InterferenceModel::new(), &mut p)
            .unwrap();
        assert_eq!(out.preemptions, 1);
        let t = p
            .restart_at
            .expect("zero-penalty preempt must still fire RestartEligible");
        assert!((t - 2.0).abs() < 1e-9, "expiry at the preemption instant, got {t}");
        assert!(out.jobs.iter().all(|j| j.state == JobState::Finished));
    }
}

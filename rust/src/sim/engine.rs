//! The discrete-event engine: advances time between events, integrates job
//! progress at piecewise-constant rates, applies policy decisions, and
//! enforces cluster/memory invariants on every transition.

use anyhow::{bail, Context, Result};

use super::{Decision, Policy, SimState};
use crate::cluster::{Cluster, ClusterConfig};
use crate::jobs::{JobRecord, JobSpec, JobState};
use crate::perf::interference::InterferenceModel;

/// Engine tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Hard wall on simulated time (safety net against livelock).
    pub max_sim_s: f64,
    /// Numeric epsilon for "job finished".
    pub eps_iters: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { max_sim_s: 120.0 * 24.0 * 3600.0, eps_iters: 1e-6 }
    }
}

/// Outcome of a full simulation run.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    pub jobs: Vec<JobRecord>,
    /// Total simulated span from first arrival to last completion.
    pub makespan_s: f64,
    /// Number of policy invocations (scheduling operations).
    pub policy_calls: u64,
    /// Number of preemptions performed.
    pub preemptions: u64,
}

/// Run `policy` over `trace` on a cluster of `cluster_cfg` with interference
/// model `xi`. Jobs must be pre-sorted by arrival (trace::generate is).
pub fn run(
    cluster_cfg: ClusterConfig,
    trace: &[JobSpec],
    xi: InterferenceModel,
    policy: &mut dyn Policy,
) -> Result<SimOutcome> {
    run_with(cluster_cfg, trace, xi, policy, EngineConfig::default())
}

pub fn run_with(
    cluster_cfg: ClusterConfig,
    trace: &[JobSpec],
    xi: InterferenceModel,
    policy: &mut dyn Policy,
    engine_cfg: EngineConfig,
) -> Result<SimOutcome> {
    for j in trace {
        if j.gpus > cluster_cfg.total_gpus() {
            bail!("job {} requests {} GPUs > cluster {}", j.id, j.gpus, cluster_cfg.total_gpus());
        }
    }
    let mut state = SimState {
        now: 0.0,
        cluster: Cluster::new(cluster_cfg),
        jobs: trace.iter().cloned().map(JobRecord::new).collect(),
        xi,
        not_before: vec![0.0; trace.len()],
        service_gpu_s: vec![0.0; trace.len()],
    };
    let mut arrivals: Vec<usize> = (0..trace.len()).collect();
    arrivals.sort_by(|&a, &b| trace[a].arrival_s.total_cmp(&trace[b].arrival_s));
    let mut next_arrival_idx = 0usize;
    let mut next_tick = policy.tick_interval();
    let mut policy_calls = 0u64;
    let mut preemptions = 0u64;

    loop {
        // ---- choose the next event time -----------------------------------
        let mut t_next = f64::INFINITY;
        if next_arrival_idx < arrivals.len() {
            t_next = t_next.min(trace[arrivals[next_arrival_idx]].arrival_s);
        }
        if let Some(tick) = next_tick {
            t_next = t_next.min(tick);
        }
        for id in state.running() {
            let it = state.effective_iter_time(id);
            let finish = state.now + state.jobs[id].remaining_iters * it;
            t_next = t_next.min(finish);
        }
        for (id, j) in state.jobs.iter().enumerate() {
            if matches!(j.state, JobState::Preempted | JobState::Pending)
                && j.spec.arrival_s <= state.now
                && state.not_before[id] > state.now
            {
                t_next = t_next.min(state.not_before[id]);
            }
        }
        if !t_next.is_finite() {
            // No arrivals, no running jobs, nothing to wait for.
            if state.jobs.iter().all(|j| j.state == JobState::Finished) {
                break;
            }
            bail!(
                "deadlock: {} unfinished jobs but no future events (policy never scheduled them?)",
                state.jobs.iter().filter(|j| j.state != JobState::Finished).count()
            );
        }
        if t_next > engine_cfg.max_sim_s {
            bail!("simulation exceeded max_sim_s = {}", engine_cfg.max_sim_s);
        }

        // ---- integrate progress over [now, t_next] ------------------------
        let dt = t_next - state.now;
        if dt > 0.0 {
            for id in state.running() {
                let it = state.effective_iter_time(id);
                let rec = &mut state.jobs[id];
                rec.remaining_iters = (rec.remaining_iters - dt / it).max(0.0);
                state.service_gpu_s[id] += rec.gpus_held.len() as f64 * dt;
            }
            for j in state.jobs.iter_mut() {
                if matches!(j.state, JobState::Pending | JobState::Preempted)
                    && j.spec.arrival_s <= state.now
                {
                    j.queued_s += dt;
                }
            }
        }
        state.now = t_next;

        // ---- process arrivals ----------------------------------------------
        while next_arrival_idx < arrivals.len()
            && trace[arrivals[next_arrival_idx]].arrival_s <= state.now + 1e-9
        {
            next_arrival_idx += 1;
        }

        // ---- process completions -------------------------------------------
        for id in state.running() {
            if state.jobs[id].remaining_iters <= engine_cfg.eps_iters {
                state.cluster.release(id);
                let rec = &mut state.jobs[id];
                rec.remaining_iters = 0.0;
                rec.state = JobState::Finished;
                rec.finish_s = Some(state.now);
                rec.gpus_held.clear();
            }
        }

        // ---- advance tick clock --------------------------------------------
        if let Some(tick) = next_tick {
            if tick <= state.now + 1e-9 {
                next_tick = Some(tick + policy.tick_interval().unwrap());
            }
        }

        // ---- invoke the policy ---------------------------------------------
        let decisions = policy.schedule(&state);
        policy_calls += 1;
        for d in decisions {
            apply(&mut state, d, policy.preemption_penalty(), &mut preemptions)
                .context("applying policy decision")?;
        }
        debug_assert!(state.cluster.check_invariants().is_ok());

        if state.jobs.iter().all(|j| j.state == JobState::Finished) {
            break;
        }
    }

    let first_arrival = trace.iter().map(|j| j.arrival_s).fold(f64::INFINITY, f64::min);
    let last_finish = state
        .jobs
        .iter()
        .filter_map(|j| j.finish_s)
        .fold(0.0f64, f64::max);
    Ok(SimOutcome {
        jobs: state.jobs,
        makespan_s: (last_finish - first_arrival.min(last_finish)).max(0.0),
        policy_calls,
        preemptions,
    })
}

/// Validate + apply one decision. Errors indicate a buggy policy.
fn apply(
    state: &mut SimState,
    decision: Decision,
    penalty: f64,
    preemptions: &mut u64,
) -> Result<()> {
    match decision {
        Decision::Start { job, gpus, accum_step } => {
            let rec = &state.jobs[job];
            if !matches!(rec.state, JobState::Pending | JobState::Preempted) {
                bail!("Start({job}): job is {:?}", rec.state);
            }
            if rec.spec.arrival_s > state.now + 1e-9 {
                bail!("Start({job}): job has not arrived yet");
            }
            if state.not_before[job] > state.now + 1e-9 {
                bail!("Start({job}): restart penalty until {}", state.not_before[job]);
            }
            if gpus.is_empty() {
                bail!("Start({job}): empty gang");
            }
            if accum_step == 0 || (rec.spec.batch % accum_step != 0 && accum_step != 1) {
                // Powers-of-two sweep guarantees divisibility for p2 batches;
                // reject anything else outright.
                bail!("Start({job}): invalid accumulation step {accum_step}");
            }
            // Memory feasibility on every granted GPU (Eq. 9 + footprint).
            let my_mem =
                rec.spec.profile().mem.mem_gb(rec.spec.batch as f64 / accum_step as f64);
            for &g in &gpus {
                let mut used = my_mem;
                for &other in &state.cluster.slot(g).jobs {
                    let o = &state.jobs[other];
                    used += o
                        .spec
                        .profile()
                        .mem
                        .mem_gb(o.spec.batch as f64 / o.accum_step as f64);
                }
                if used > state.cluster.config.gpu_mem_gb + 1e-9 {
                    bail!("Start({job}): GPU {g} memory over budget ({used:.2} GB)");
                }
            }
            state.cluster.allocate(job, &gpus);
            let rec = &mut state.jobs[job];
            rec.state = JobState::Running;
            rec.accum_step = accum_step;
            rec.gpus_held = gpus;
            if rec.first_start_s.is_none() {
                rec.first_start_s = Some(state.now);
            }
        }
        Decision::Preempt { job } => {
            let rec = &state.jobs[job];
            if rec.state != JobState::Running {
                bail!("Preempt({job}): job is {:?}", rec.state);
            }
            state.cluster.release(job);
            let rec = &mut state.jobs[job];
            rec.state = JobState::Preempted;
            rec.gpus_held.clear();
            state.not_before[job] = state.now + penalty;
            *preemptions += 1;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::placement;
    use crate::perf::profiles::ModelKind;

    /// Minimal exclusive FIFO used to exercise the engine itself.
    struct MiniFifo;
    impl Policy for MiniFifo {
        fn name(&self) -> &'static str {
            "mini-fifo"
        }
        fn schedule(&mut self, state: &SimState) -> Vec<Decision> {
            let mut pending = state.pending();
            pending.sort_by(|&a, &b| {
                state.jobs[a].spec.arrival_s.total_cmp(&state.jobs[b].spec.arrival_s)
            });
            let mut cluster = state.cluster.clone();
            let mut out = Vec::new();
            for id in pending {
                let need = state.jobs[id].spec.gpus;
                if let Some(gpus) = placement::consolidated_free(&cluster, need) {
                    cluster.allocate(id, &gpus);
                    out.push(Decision::Start { job: id, gpus, accum_step: 1 });
                } else {
                    break; // strict FIFO HOL blocking
                }
            }
            out
        }
    }

    fn job(id: usize, gpus: usize, iters: u64, arrival: f64) -> JobSpec {
        JobSpec {
            id,
            model: ModelKind::Cifar10,
            gpus,
            iterations: iters,
            batch: 128,
            arrival_s: arrival,
        }
    }

    #[test]
    fn single_job_runs_to_completion() {
        let trace = vec![job(0, 4, 1000, 5.0)];
        let out = run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut MiniFifo,
        )
        .unwrap();
        let j = &out.jobs[0];
        assert_eq!(j.state, JobState::Finished);
        let expect = trace[0].solo_runtime(1);
        let jct = j.jct().unwrap();
        assert!((jct - expect).abs() < 1e-6, "jct={jct} expect={expect}");
        assert_eq!(j.queueing_delay().unwrap(), 0.0);
    }

    #[test]
    fn queueing_accrues_under_contention() {
        // Two 16-GPU jobs: second must wait for the first.
        let trace = vec![job(0, 16, 1000, 0.0), job(1, 16, 1000, 0.0)];
        let out = run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut MiniFifo,
        )
        .unwrap();
        let solo = trace[0].solo_runtime(1);
        let q1 = out.jobs[1].queueing_delay().unwrap();
        assert!((q1 - solo).abs() < 1e-6, "q1={q1} solo={solo}");
        assert!((out.jobs[1].queued_s - solo).abs() < 1e-6);
        assert!((out.makespan_s - 2.0 * solo).abs() < 1e-6);
    }

    #[test]
    fn rejects_oversized_job() {
        let trace = vec![job(0, 64, 10, 0.0)];
        assert!(run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut MiniFifo
        )
        .is_err());
    }

    #[test]
    fn deadlock_detected_for_donothing_policy() {
        struct Nothing;
        impl Policy for Nothing {
            fn name(&self) -> &'static str {
                "nothing"
            }
            fn schedule(&mut self, _: &SimState) -> Vec<Decision> {
                vec![]
            }
        }
        let trace = vec![job(0, 1, 10, 0.0)];
        let err = run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut Nothing,
        )
        .unwrap_err();
        assert!(err.to_string().contains("deadlock"), "{err}");
    }

    #[test]
    fn bad_decision_rejected() {
        struct DoubleStart;
        impl Policy for DoubleStart {
            fn name(&self) -> &'static str {
                "bad"
            }
            fn schedule(&mut self, state: &SimState) -> Vec<Decision> {
                state
                    .pending()
                    .into_iter()
                    .map(|id| Decision::Start { job: id, gpus: vec![0], accum_step: 1 })
                    .chain(std::iter::once(Decision::Start {
                        job: 0,
                        gpus: vec![0],
                        accum_step: 1,
                    }))
                    .collect()
            }
        }
        let trace = vec![job(0, 1, 10, 0.0)];
        assert!(run(
            ClusterConfig::physical(),
            &trace,
            InterferenceModel::new(),
            &mut DoubleStart
        )
        .is_err());
    }
}

//! Discrete-event simulation of the multi-tenant cluster (paper §VI: "we
//! also implement a simulator to record job events and resource usage",
//! validated within 5% of the physical runs).
//!
//! The engine integrates job progress piecewise: between consecutive events
//! (arrival, finish, policy tick, restart-eligibility) every running job's
//! iteration rate is constant, determined by its gang size, accumulation
//! step and current co-runners (Eq. 7 × ξ). Policies are event handlers
//! over a read-only [`crate::sched_core::SchedContext`] view; the shared
//! [`crate::sched_core`] transaction layer validates and applies their
//! [`Txn`]s — in this engine and in the physical coordinator alike — so
//! scheduling bugs cannot corrupt cluster invariants in either backend.
//!
//! [`SimState`] is the plain world data both backends share: the clock,
//! the cluster occupancy, the job records and the per-job `not_before` /
//! `service_gpu_s` arrays. Scheduling code reads it through
//! `SchedContext` (which `Deref`s to it and adds the incremental caches).

pub mod engine;
pub mod metrics;

pub use engine::{EngineConfig, SimOutcome};

// The scheduling API lives in `sched_core` and is shared with the
// physical coordinator; re-exported here for the simulator-centric
// import paths used across the crate and its examples.
pub use crate::sched_core::{Decision, Event, Policy, SchedContext, Txn};

use crate::cluster::Cluster;
use crate::jobs::{JobId, JobRecord, JobState};
use crate::perf::interference::{Composition, InterferenceModel};

/// The world data shared by the simulator and the physical coordinator.
#[derive(Debug, Clone)]
pub struct SimState {
    pub now: f64,
    pub cluster: Cluster,
    pub jobs: Vec<JobRecord>,
    pub xi: InterferenceModel,
    /// Earliest restart time per job (preemption/migration penalty).
    pub not_before: Vec<f64>,
    /// Cumulative attained service (GPU·seconds) per job — Tiresias' 2D-LAS
    /// priority input. Accrued by both backends (simulated and wall time).
    pub service_gpu_s: Vec<f64>,
}

impl SimState {
    /// Jobs currently eligible for scheduling: arrived, not running, past
    /// their restart penalty.
    ///
    /// O(n) scan. Scheduling code should prefer the incrementally
    /// maintained [`SchedContext::pending`]; this remains as the
    /// reference implementation the caches are checked against.
    pub fn pending(&self) -> Vec<JobId> {
        self.jobs
            .iter()
            .enumerate()
            .filter(|(id, j)| {
                matches!(j.state, JobState::Pending | JobState::Preempted)
                    && j.spec.arrival_s <= self.now + 1e-9
                    && self.not_before[*id] <= self.now + 1e-9
            })
            .map(|(id, _)| id)
            .collect()
    }

    /// O(n) scan; prefer [`SchedContext::running`] in scheduling code.
    pub fn running(&self) -> Vec<JobId> {
        self.jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| j.state == JobState::Running)
            .map(|(id, _)| id)
            .collect()
    }

    /// Effective seconds per *requested-configuration iteration* of a
    /// running job: Eq. 7 on its actual gang width *and placement* (the
    /// [`crate::perf::GangSpan`] of the GPUs it holds — bottleneck link,
    /// slowest member GPU), inflated by the worst co-runner ξ (Eqs. 5/6),
    /// and rescaled for elastic width changes.
    ///
    /// Width rescaling (weak scaling): one data-parallel iteration on `w`
    /// workers processes `w·B` samples, so against the job's requested
    /// `G_k`-GPU configuration it completes `w/G_k` "requested iterations".
    /// For gang-faithful policies `w = G_k` and the factor is 1; the
    /// elastic (Pollux-like) baseline is the only policy that changes `w`.
    ///
    /// O(cluster) per call (co-runner scan + span derivation); the
    /// engine reads it through [`SchedContext::cached_iter_time`], which
    /// memoizes per rate epoch.
    pub fn effective_iter_time(&self, id: JobId) -> f64 {
        let rec = &self.jobs[id];
        debug_assert_eq!(rec.state, JobState::Running);
        let workers = rec.gpus_held.len().max(1);
        let span = self.cluster.span_of(&rec.gpus_held);
        let solo = rec.spec.profile().perf.iter_time_placed(
            rec.spec.batch as f64,
            rec.accum_step,
            workers,
            &span,
        );
        let width_scale = workers as f64 / rec.spec.gpus as f64;
        // k-way co-runner sets compose under the engine-default
        // MaxDegradation rule — bit-identical to the historical
        // max-fold for every set size (DESIGN.md §17).
        let xi = self.xi.xi_set(
            rec.spec.model,
            self.cluster.co_runners(id).iter().map(|&co| self.jobs[co].spec.model),
            Composition::MaxDegradation,
        );
        solo / width_scale * xi
    }
}

//! JCT / queueing / makespan metrics and CDFs — the quantities every table
//! and figure in the paper reports (§VI).


use crate::jobs::JobRecord;
use crate::perf::profiles::ModelKind;

/// Aggregate over one job population slice.
///
/// `n` counts *finished* jobs only — all JCT/queueing statistics are over
/// that population. `unfinished` counts the jobs the slice contained that
/// never reached `Finished` (truncated or saturated runs); a non-zero value
/// means the JCT columns describe a survivor-biased subset.
#[derive(Debug, Clone, Copy, Default)]
pub struct Aggregate {
    pub n: usize,
    pub unfinished: usize,
    pub avg_jct_s: f64,
    pub avg_queue_s: f64,
    pub p50_jct_s: f64,
    pub p90_jct_s: f64,
}

/// Table II / III / IV style summary: all + large (> 4 GPUs) + small jobs.
#[derive(Debug, Clone)]
pub struct Summary {
    pub policy: String,
    pub makespan_s: f64,
    pub all: Aggregate,
    pub large: Aggregate,
    pub small: Aggregate,
}

// Nearest-rank percentile (0.0 on empty) — the definition the paper-table
// goldens were recorded against; delegated to `util::stats`, which pins the
// semantics. Do not swap for the bench-side ceiling-rank variant: the
// Tables II–IV p50/p90 columns depend on nearest-rank for byte parity.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    crate::util::stats::percentile_nearest_rank(sorted, p)
}

fn aggregate<'a>(jobs: impl Iterator<Item = &'a JobRecord>) -> Aggregate {
    let mut jcts = Vec::new();
    let mut queues = Vec::new();
    let mut unfinished = 0usize;
    for j in jobs {
        if let Some(jct) = j.jct() {
            jcts.push(jct);
            queues.push(j.queued_s);
        } else {
            unfinished += 1;
        }
    }
    jcts.sort_by(f64::total_cmp);
    let n = jcts.len();
    if n == 0 {
        return Aggregate { unfinished, ..Aggregate::default() };
    }
    Aggregate {
        n,
        unfinished,
        avg_jct_s: jcts.iter().sum::<f64>() / n as f64,
        avg_queue_s: queues.iter().sum::<f64>() / n as f64,
        p50_jct_s: percentile(&jcts, 0.5),
        p90_jct_s: percentile(&jcts, 0.9),
    }
}

/// Build the Tables-style summary for a finished run.
pub fn summarize(policy: &str, jobs: &[JobRecord], makespan_s: f64) -> Summary {
    Summary {
        policy: policy.to_string(),
        makespan_s,
        all: aggregate(jobs.iter()),
        large: aggregate(jobs.iter().filter(|j| j.spec.is_large())),
        small: aggregate(jobs.iter().filter(|j| !j.spec.is_large())),
    }
}

/// JCT CDF: sorted (jct_seconds, cumulative_fraction) points (Figs. 4a/5a).
pub fn jct_cdf(jobs: &[JobRecord]) -> Vec<(f64, f64)> {
    let mut jcts: Vec<f64> = jobs.iter().filter_map(|j| j.jct()).collect();
    jcts.sort_by(f64::total_cmp);
    let n = jcts.len() as f64;
    jcts.iter().enumerate().map(|(i, &t)| (t, (i + 1) as f64 / n)).collect()
}

/// Fraction of jobs with JCT below `threshold_s` (Fig. 4a-style claims).
pub fn fraction_below(jobs: &[JobRecord], threshold_s: f64) -> f64 {
    let done: Vec<f64> = jobs.iter().filter_map(|j| j.jct()).collect();
    if done.is_empty() {
        return 0.0;
    }
    done.iter().filter(|&&t| t <= threshold_s).count() as f64 / done.len() as f64
}

/// Average queueing delay per workload model (Figs. 4b/5b).
pub fn queueing_by_model(jobs: &[JobRecord]) -> Vec<(ModelKind, f64)> {
    ModelKind::ALL
        .iter()
        .filter_map(|&kind| {
            let slice: Vec<&JobRecord> =
                jobs.iter().filter(|j| j.spec.model == kind).collect();
            if slice.is_empty() {
                None
            } else {
                let avg =
                    slice.iter().map(|j| j.queued_s).sum::<f64>() / slice.len() as f64;
                Some((kind, avg))
            }
        })
        .collect()
}

/// Mean JCT of the fastest `frac` of jobs (paper: "reducing the average JCT
/// of the shortest 40% jobs by 37% than Pollux").
pub fn avg_jct_fastest_fraction(jobs: &[JobRecord], frac: f64) -> f64 {
    let mut jcts: Vec<f64> = jobs.iter().filter_map(|j| j.jct()).collect();
    jcts.sort_by(f64::total_cmp);
    let k = ((jcts.len() as f64 * frac).round() as usize).clamp(1, jcts.len().max(1));
    if jcts.is_empty() {
        return 0.0;
    }
    jcts[..k].iter().sum::<f64>() / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::{JobSpec, JobState};

    fn finished(
        id: usize,
        gpus: usize,
        model: ModelKind,
        arrival: f64,
        start: f64,
        finish: f64,
    ) -> JobRecord {
        let mut r = JobRecord::new(JobSpec {
            id,
            model,
            gpus,
            iterations: 100,
            batch: 8,
            arrival_s: arrival,
            est_factor: 1.0,
        });
        r.state = JobState::Finished;
        r.first_start_s = Some(start);
        r.finish_s = Some(finish);
        r.queued_s = start - arrival;
        r.remaining_iters = 0.0;
        r
    }

    #[test]
    fn summary_splits_large_small() {
        let jobs = vec![
            finished(0, 2, ModelKind::Bert, 0.0, 0.0, 100.0),
            finished(1, 8, ModelKind::YoloV3, 0.0, 50.0, 250.0),
        ];
        let s = summarize("test", &jobs, 250.0);
        assert_eq!(s.all.n, 2);
        assert_eq!(s.large.n, 1);
        assert_eq!(s.small.n, 1);
        assert!((s.all.avg_jct_s - 175.0).abs() < 1e-9);
        assert!((s.large.avg_queue_s - 50.0).abs() < 1e-9);
    }

    #[test]
    fn cdf_monotone_and_normalized() {
        let jobs: Vec<JobRecord> = (0..10)
            .map(|i| finished(i, 1, ModelKind::Ncf, 0.0, 0.0, (i + 1) as f64 * 10.0))
            .collect();
        let cdf = jct_cdf(&jobs);
        assert_eq!(cdf.len(), 10);
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0 && w[0].1 < w[1].1);
        }
        assert!((fraction_below(&jobs, 50.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fastest_fraction_mean() {
        let jobs: Vec<JobRecord> = (0..10)
            .map(|i| finished(i, 1, ModelKind::Ncf, 0.0, 0.0, (i + 1) as f64 * 10.0))
            .collect();
        // fastest 40% = JCTs 10..40 -> mean 25
        assert!((avg_jct_fastest_fraction(&jobs, 0.4) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn queueing_by_model_groups() {
        let jobs = vec![
            finished(0, 1, ModelKind::Bert, 0.0, 10.0, 100.0),
            finished(1, 1, ModelKind::Bert, 0.0, 30.0, 100.0),
            finished(2, 1, ModelKind::Ncf, 0.0, 0.0, 50.0),
        ];
        let by = queueing_by_model(&jobs);
        let bert = by.iter().find(|(k, _)| *k == ModelKind::Bert).unwrap();
        assert!((bert.1 - 20.0).abs() < 1e-9);
        assert_eq!(by.len(), 2);
    }

    #[test]
    fn empty_population_safe() {
        let s = summarize("none", &[], 0.0);
        assert_eq!(s.all.n, 0);
        assert_eq!(s.all.unfinished, 0);
        assert_eq!(jct_cdf(&[]).len(), 0);
        assert_eq!(fraction_below(&[], 10.0), 0.0);
    }

    #[test]
    fn half_finished_population_counts_unfinished() {
        // A truncated run: half the jobs never finished. The JCT stats must
        // cover the survivors only, and the dropped half must be *counted*,
        // not silently vanish (the pre-fix behavior).
        let mut jobs: Vec<JobRecord> = (0..4)
            .map(|i| finished(i, 2, ModelKind::Bert, 0.0, 0.0, (i + 1) as f64 * 100.0))
            .collect();
        for i in 4..8 {
            // Still running at truncation: arrived, started, never finished.
            let mut r = JobRecord::new(JobSpec {
                id: i,
                model: ModelKind::YoloV3,
                gpus: 8,
                iterations: 100,
                batch: 8,
                arrival_s: 0.0,
                est_factor: 1.0,
            });
            r.first_start_s = Some(10.0);
            jobs.push(r);
        }
        let s = summarize("truncated", &jobs, 400.0);
        assert_eq!(s.all.n, 4);
        assert_eq!(s.all.unfinished, 4);
        assert_eq!(s.small.n, 4);
        assert_eq!(s.small.unfinished, 0);
        // The large slice is entirely unfinished: zero stats, full count.
        assert_eq!(s.large.n, 0);
        assert_eq!(s.large.unfinished, 4);
        assert_eq!(s.large.avg_jct_s, 0.0);
        // Survivor stats unchanged by the unfinished population.
        assert!((s.all.avg_jct_s - 250.0).abs() < 1e-9);
    }
}

//! The six DL workload profiles the paper annotates onto the Microsoft
//! trace (BERT, CIFAR10, DeepSpeech2, ImageNet, NCF, YoloV3; §VI-A).
//!
//! Parameters are calibrated so the solo-throughput landscape reproduces the
//! *shapes* of Fig. 2 (measured on 4×4 2080 Ti, 10 Gbps): e.g. BERT scales
//! ~linearly with batch (compute-bound, memory-capped), YoloV3 saturates
//! around batch 16 and hits the network bottleneck past ~12 GPUs, NCF is
//! tiny-message/latency-bound, ImageNet is bandwidth-heavy.


use super::{CommModel, CompModel, MemModel, PerfModel};

/// Which paper workload a job runs (Fig. 2/3 model zoo).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Bert,
    Cifar10,
    DeepSpeech2,
    ImageNet,
    Ncf,
    YoloV3,
}

impl ModelKind {
    pub const ALL: [ModelKind; 6] = [
        ModelKind::Bert,
        ModelKind::Cifar10,
        ModelKind::DeepSpeech2,
        ModelKind::ImageNet,
        ModelKind::Ncf,
        ModelKind::YoloV3,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Bert => "BERT",
            ModelKind::Cifar10 => "CIFAR10",
            ModelKind::DeepSpeech2 => "DeepSpeech2",
            ModelKind::ImageNet => "ImageNet",
            ModelKind::Ncf => "NCF",
            ModelKind::YoloV3 => "YoloV3",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|m| m.name().eq_ignore_ascii_case(s))
    }

    /// Index into [`ModelKind::ALL`] (used by the ξ pair table).
    pub fn index(&self) -> usize {
        Self::ALL.iter().position(|m| m == self).unwrap()
    }
}

/// Static description of one workload: perf + memory + batch ranges.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadProfile {
    pub kind: ModelKind,
    pub perf: PerfModel,
    pub mem: MemModel,
    /// Default user-requested per-GPU batch size.
    pub default_batch: u32,
    /// How compute-saturating the job is on its GPUs in [0, 1]; drives the
    /// default interference table (Fig. 3's spread of ξ).
    pub gpu_intensity: f64,
    /// How much of the NIC the job occupies in [0, 1].
    pub net_intensity: f64,
}

impl WorkloadProfile {
    pub fn get(kind: ModelKind) -> WorkloadProfile {
        // (α_comp s, β_comp s/sample, α_comm s, β_comm s/MB, msg MB, δ,
        //  base GB, GB/sample, default batch, gpu, net)
        let row = match kind {
            // Large model, big messages, compute-bound per sample.
            ModelKind::Bert => {
                (0.020, 0.0300, 0.004, 0.00085, 420.0, 1.6, 4.2, 0.38, 16, 0.95, 0.60)
            }
            // Small convnet: fast iterations, small messages.
            ModelKind::Cifar10 => {
                (0.004, 0.0012, 0.001, 0.00080, 14.0, 1.8, 1.1, 0.025, 128, 0.55, 0.15)
            }
            // RNN: long compute, moderate payload.
            ModelKind::DeepSpeech2 => {
                (0.030, 0.0160, 0.003, 0.00085, 230.0, 1.4, 3.0, 0.30, 20, 0.80, 0.45)
            }
            // ResNet-50-class: bandwidth-heavy, batch-efficient compute.
            ModelKind::ImageNet => {
                (0.012, 0.0048, 0.002, 0.00090, 98.0, 2.2, 2.6, 0.115, 32, 0.85, 0.70)
            }
            // Embedding model: latency-bound, tiny compute per sample.
            ModelKind::Ncf => {
                (0.002, 0.000012, 0.001, 0.00080, 8.0, 1.2, 0.9, 0.0006, 4096, 0.30, 0.10)
            }
            // Detector: saturates ~batch 16, network-bottlenecked ≥ 12 GPUs.
            ModelKind::YoloV3 => {
                (0.018, 0.0125, 0.005, 0.00110, 236.0, 1.3, 3.4, 0.42, 16, 0.90, 0.85)
            }
        };
        WorkloadProfile {
            kind,
            perf: PerfModel {
                comp: CompModel { alpha: row.0, beta: row.1 },
                comm: CommModel { alpha: row.2, beta: row.3 },
                msg_mb: row.4,
                delta: row.5,
            },
            mem: MemModel { base_gb: row.6, per_sample_gb: row.7 },
            default_batch: row.8,
            gpu_intensity: row.9,
            net_intensity: row.10,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_profiles_resolve() {
        for kind in ModelKind::ALL {
            let p = WorkloadProfile::get(kind);
            assert_eq!(p.kind, kind);
            assert!(p.perf.iter_time(p.default_batch as f64, 1, 4) > 0.0);
        }
    }

    #[test]
    fn name_roundtrip() {
        for kind in ModelKind::ALL {
            assert_eq!(ModelKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(ModelKind::from_name("nope"), None);
    }

    #[test]
    fn bert_throughput_rises_with_batch_fig2() {
        // Fig. 2: BERT throughput increases ~linearly in batch across GPU
        // configs (compute-bound in the measured range).
        let p = WorkloadProfile::get(ModelKind::Bert);
        for n in [4usize, 8, 16] {
            let t8 = p.perf.throughput(8.0, 1, n);
            let t16 = p.perf.throughput(16.0, 1, n);
            assert!(t16 > t8 * 1.2, "BERT should gain >20% from batch 8->16");
        }
    }

    #[test]
    fn yolo_network_bottleneck_fig2() {
        // Fig. 2: YoloV3 stops scaling past ~12 GPUs (10 Gbps bottleneck).
        let p = WorkloadProfile::get(ModelKind::YoloV3);
        let eff12 = p.perf.speedup(16.0, 12) / 12.0;
        let eff16 = p.perf.speedup(16.0, 16) / 16.0;
        assert!(eff16 < eff12, "per-GPU efficiency must drop 12->16 GPUs");
        assert!(eff16 < 0.55, "YoloV3 at 16 GPUs should be network-bound");
    }

    #[test]
    fn ncf_per_sample_cost_is_negligible() {
        // NCF is the embedding workload: per-sample compute is orders of
        // magnitude below the vision/NLP models, so its huge default batch
        // still iterates in well under 100 ms.
        let p = WorkloadProfile::get(ModelKind::Ncf);
        assert!(p.perf.comp.beta < 1e-4);
        assert!(p.perf.iter_time(p.default_batch as f64, 1, 4) < 0.1);
    }

    #[test]
    fn memory_fits_solo_on_2080ti() {
        // Every profile must fit its default batch on an 11 GB GPU when
        // running alone (the paper measured them there).
        for kind in ModelKind::ALL {
            let p = WorkloadProfile::get(kind);
            assert!(
                p.mem.mem_gb(p.default_batch as f64) <= 11.0,
                "{} default footprint too big",
                kind.name()
            );
        }
    }
}

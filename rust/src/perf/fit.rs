//! Least-squares fitting of the Eq. 3/4 affine models from throughput
//! samples (paper §IV-B: "By measuring DL job throughput under both sole
//! execution and concurrent execution ... we can fit the time model
//! (Equation (7)) for both cases and naturally infer the interference
//! ratio ξ").
//!
//! This is the calibration path a deployment would run once per model on
//! its own hardware; `wise-share fit` exposes it on the CLI and the Fig. 2
//! bench validates fit quality against the synthetic ground truth.


use super::{CompModel, PerfModel};

/// One throughput observation: iteration time at a per-GPU batch size.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub batch: f64,
    pub iter_time_s: f64,
}

/// Ordinary least squares for `y = alpha + beta * x`.
///
/// Returns `(alpha, beta)`. Requires >= 2 distinct x values.
pub fn ols(xs: &[f64], ys: &[f64]) -> Option<(f64, f64)> {
    if xs.len() != ys.len() || xs.len() < 2 {
        return None;
    }
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    if sxx <= f64::EPSILON {
        return None;
    }
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let beta = sxy / sxx;
    let alpha = my - beta * mx;
    Some((alpha, beta))
}

/// Fit the compute model t_comp(B) = α + β·B from single-GPU samples
/// (no communication term on one worker).
pub fn fit_comp(samples: &[Sample]) -> Option<CompModel> {
    let xs: Vec<f64> = samples.iter().map(|s| s.batch).collect();
    let ys: Vec<f64> = samples.iter().map(|s| s.iter_time_s).collect();
    let (alpha, beta) = ols(&xs, &ys)?;
    Some(CompModel { alpha: alpha.max(0.0), beta: beta.max(0.0) })
}

/// Infer the interference ratio ξ = t_shared / t_solo from paired
/// measurements at identical settings (paper Eq. 5/6 inversion).
pub fn infer_xi(solo_iter_s: &[f64], shared_iter_s: &[f64]) -> Option<f64> {
    if solo_iter_s.is_empty() || solo_iter_s.len() != shared_iter_s.len() {
        return None;
    }
    let ratios: Vec<f64> = solo_iter_s
        .iter()
        .zip(shared_iter_s)
        .filter(|(s, _)| **s > 0.0)
        .map(|(s, sh)| sh / s)
        .collect();
    if ratios.is_empty() {
        return None;
    }
    Some(ratios.iter().sum::<f64>() / ratios.len() as f64)
}

/// Mean relative error of a fitted perf model against observations taken at
/// `(batch, n_workers)` settings — the Fig. 2 "model closely represents the
/// observed data" check.
pub fn relative_error(model: &PerfModel, obs: &[(f64, usize, f64)]) -> f64 {
    if obs.is_empty() {
        return 0.0;
    }
    obs.iter()
        .map(|(batch, n, t_obs)| {
            let t = model.iter_time(*batch, 1, *n);
            (t - t_obs).abs() / t_obs.max(f64::EPSILON)
        })
        .sum::<f64>()
        / obs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::CommModel;

    #[test]
    fn ols_recovers_exact_line() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let ys: Vec<f64> = xs.iter().map(|x| 0.3 + 0.7 * x).collect();
        let (a, b) = ols(&xs, &ys).unwrap();
        assert!((a - 0.3).abs() < 1e-12 && (b - 0.7).abs() < 1e-12);
    }

    #[test]
    fn ols_rejects_degenerate() {
        assert!(ols(&[1.0], &[2.0]).is_none());
        assert!(ols(&[2.0, 2.0], &[1.0, 3.0]).is_none());
        assert!(ols(&[1.0, 2.0], &[1.0]).is_none());
    }

    #[test]
    fn fit_comp_recovers_ground_truth() {
        let truth = CompModel { alpha: 0.015, beta: 0.004 };
        let samples: Vec<Sample> = [2.0, 4.0, 8.0, 16.0, 32.0]
            .iter()
            .map(|&b| Sample { batch: b, iter_time_s: truth.t_comp(b) })
            .collect();
        let fit = fit_comp(&samples).unwrap();
        assert!((fit.alpha - truth.alpha).abs() < 1e-9);
        assert!((fit.beta - truth.beta).abs() < 1e-9);
    }

    #[test]
    fn infer_xi_mean_ratio() {
        let solo = [1.0, 2.0];
        let shared = [1.5, 3.0];
        assert!((infer_xi(&solo, &shared).unwrap() - 1.5).abs() < 1e-12);
        assert!(infer_xi(&[], &[]).is_none());
    }

    #[test]
    fn relative_error_zero_on_self() {
        let m = PerfModel {
            comp: CompModel { alpha: 0.01, beta: 0.002 },
            comm: CommModel { alpha: 0.001, beta: 0.0005 },
            msg_mb: 50.0,
            delta: 2.0,
        };
        let obs: Vec<(f64, usize, f64)> = [(4.0, 1usize), (8.0, 4), (16.0, 8)]
            .iter()
            .map(|&(b, n)| (b, n, m.iter_time(b, 1, n)))
            .collect();
        assert!(relative_error(&m, &obs) < 1e-12);
    }
}

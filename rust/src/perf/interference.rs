//! Interference ratios ξ for GPU-shared job pairs (Eqs. 5/6, Fig. 3) and
//! their k-way composition for sharing sets (DESIGN.md §17).
//!
//! When jobs A and B share a GPU set, each one's iteration time inflates:
//! `t̂ = t · ξ`, ξ ≥ 1. The paper measures ξ per (model, co-runner) pair and
//! observes a spread up to ~6×. We reproduce that landscape with a
//! contention-based default table derived from each profile's GPU / network
//! intensity, and allow (a) explicit per-pair overrides (the interface a
//! real deployment would fit from co-located profiling runs, §IV-B) and
//! (b) a global constant override used by the Fig. 6b sensitivity sweep.
//!
//! With share caps C > 2 a victim can face several aggressors at once;
//! [`InterferenceModel::xi_set`] composes the per-aggressor pair factors
//! under a selectable [`Composition`] rule. Invariants: a composed ξ is
//! ≥ 1, collapses to the single pair factor when there is exactly one
//! aggressor, and never decreases when an aggressor is added (pinned by
//! `rust/tests/share_cap.rs`).

use std::collections::HashMap;


use super::profiles::{ModelKind, WorkloadProfile};

/// Symmetric pair key (ξ is looked up per *victim*, so the map key is the
/// ordered pair (victim, aggressor)).
pub type PairKey = (ModelKind, ModelKind);

/// How per-aggressor pair factors compose into one ξ when a victim shares
/// its GPUs with k > 1 co-runners (DESIGN.md §17).
///
/// Both rules are the identity on a single aggressor, so every pair-model
/// (C = 2) code path is unaffected by the choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Composition {
    /// ξ_set = max over aggressors of the pair factor: contention is a
    /// bottleneck — the victim is slowed by its worst neighbor and the
    /// rest hide behind that stall. This is the engine default and is
    /// bit-for-bit the fold the simulator has always applied to
    /// co-runner sets.
    #[default]
    MaxDegradation,
    /// ξ_set = product over aggressors of the pair factors: each
    /// neighbor's slowdown is independent and multiplicative — the
    /// pessimistic composition for compute-bound victims whose
    /// aggressors contend on disjoint resources.
    PairwiseProduct,
}

#[derive(Debug, Clone, Default)]
pub struct InterferenceModel {
    /// Explicit measured ratios: (victim, aggressor) -> ξ_victim.
    pub overrides: HashMap<String, f64>,
    /// If set, every sharing pair uses this ξ for both jobs (Fig. 6b sweep).
    pub global: Option<f64>,
}

impl InterferenceModel {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_global(xi: f64) -> Self {
        Self { overrides: HashMap::new(), global: Some(xi) }
    }

    fn key(victim: ModelKind, aggressor: ModelKind) -> String {
        format!("{}|{}", victim.name(), aggressor.name())
    }

    /// Register a measured ratio for (victim, aggressor).
    pub fn set(&mut self, victim: ModelKind, aggressor: ModelKind, xi: f64) {
        assert!(xi >= 1.0, "interference ratio must be >= 1");
        self.overrides.insert(Self::key(victim, aggressor), xi);
    }

    /// ξ for `victim` when co-located with `aggressor`.
    ///
    /// Default model: contention on the SM/compute side plus contention on
    /// the NIC, each proportional to the product of the two jobs' demands on
    /// that resource. Calibrated to span ~[1.1, 3.2] for typical pairs with
    /// the worst (two network-heavy detectors) near 6 — matching Fig. 3's
    /// reported range ("up to 6").
    pub fn xi(&self, victim: ModelKind, aggressor: ModelKind) -> f64 {
        if let Some(g) = self.global {
            return g;
        }
        if let Some(&v) = self.overrides.get(&Self::key(victim, aggressor)) {
            return v;
        }
        let v = WorkloadProfile::get(victim);
        let a = WorkloadProfile::get(aggressor);
        // Compute-side slowdown: victim loses the fraction of cycles the
        // aggressor occupies, amplified by how compute-bound the victim is.
        let gpu = 1.0 + 0.45 * v.gpu_intensity * a.gpu_intensity;
        // Network-side slowdown: NIC sharing hits comm-heavy victims hard
        // and super-linearly (congestion) — this is what makes
        // YoloV3-vs-YoloV3 pairs catastrophic in Fig. 3 while most other
        // pairs stay mild (1.1-1.6).
        let net = 1.0 + 4.5 * (v.net_intensity * a.net_intensity).powf(2.2);
        // Iteration time inflates by the max of the two bottlenecks plus a
        // residual coupling term.
        let xi = gpu.max(net) + 0.35 * (gpu.min(net) - 1.0);
        xi.max(1.0)
    }

    /// Both ratios for a sharing pair: (ξ_a, ξ_b).
    pub fn pair(&self, a: ModelKind, b: ModelKind) -> (f64, f64) {
        (self.xi(a, b), self.xi(b, a))
    }

    /// Composed ξ for `victim` sharing with a whole aggressor set
    /// (DESIGN.md §17). An empty set composes to 1 (no inflation); one
    /// aggressor composes to exactly [`InterferenceModel::xi`] under
    /// either rule.
    ///
    /// [`Composition::MaxDegradation`] reproduces, bit for bit, the
    /// `fold(1.0, f64::max)` the simulator has always applied to a
    /// running job's co-runners — that identity is what keeps C = 2
    /// traces byte-identical across the k-way generalization.
    pub fn xi_set<I>(&self, victim: ModelKind, aggressors: I, comp: Composition) -> f64
    where
        I: IntoIterator<Item = ModelKind>,
    {
        let factors = aggressors.into_iter().map(|a| self.xi(victim, a));
        match comp {
            Composition::MaxDegradation => factors.fold(1.0f64, f64::max),
            Composition::PairwiseProduct => factors.fold(1.0f64, |acc, xi| acc * xi),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xi_at_least_one() {
        let m = InterferenceModel::new();
        for a in ModelKind::ALL {
            for b in ModelKind::ALL {
                assert!(m.xi(a, b) >= 1.0);
            }
        }
    }

    #[test]
    fn range_matches_fig3() {
        // Default table must span a wide range with worst cases near 6.
        let m = InterferenceModel::new();
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for a in ModelKind::ALL {
            for b in ModelKind::ALL {
                let xi = m.xi(a, b);
                lo = lo.min(xi);
                hi = hi.max(xi);
            }
        }
        assert!(lo < 1.4, "light pairs should barely interfere: {lo}");
        assert!(hi > 3.0 && hi < 7.0, "worst pair should approach 6: {hi}");
    }

    #[test]
    fn ncf_is_a_polite_neighbor() {
        // NCF (low GPU + net intensity) should hurt others the least.
        let m = InterferenceModel::new();
        let vs_ncf = m.xi(ModelKind::Bert, ModelKind::Ncf);
        let vs_yolo = m.xi(ModelKind::Bert, ModelKind::YoloV3);
        assert!(vs_ncf < vs_yolo);
    }

    #[test]
    fn global_override_wins() {
        let m = InterferenceModel::with_global(1.5);
        for a in ModelKind::ALL {
            for b in ModelKind::ALL {
                assert_eq!(m.xi(a, b), 1.5);
            }
        }
    }

    #[test]
    fn explicit_override_wins_over_default() {
        let mut m = InterferenceModel::new();
        m.set(ModelKind::Bert, ModelKind::Cifar10, 2.75);
        assert_eq!(m.xi(ModelKind::Bert, ModelKind::Cifar10), 2.75);
        // Reverse direction unaffected.
        assert_ne!(m.xi(ModelKind::Cifar10, ModelKind::Bert), 2.75);
    }

    #[test]
    #[should_panic]
    fn rejects_sub_unit_ratio() {
        let mut m = InterferenceModel::new();
        m.set(ModelKind::Bert, ModelKind::Bert, 0.5);
    }

    #[test]
    fn xi_set_collapses_to_pair_factor_for_one_aggressor() {
        let m = InterferenceModel::new();
        for a in ModelKind::ALL {
            for b in ModelKind::ALL {
                let pair = m.xi(a, b);
                for comp in [Composition::MaxDegradation, Composition::PairwiseProduct] {
                    assert_eq!(m.xi_set(a, [b], comp).to_bits(), pair.to_bits());
                }
            }
        }
    }

    #[test]
    fn xi_set_empty_is_unity_and_product_dominates_max() {
        let m = InterferenceModel::new();
        let set = [ModelKind::YoloV3, ModelKind::Bert, ModelKind::Cifar10];
        for comp in [Composition::MaxDegradation, Composition::PairwiseProduct] {
            assert_eq!(m.xi_set(ModelKind::Bert, [], comp), 1.0);
        }
        let mx = m.xi_set(ModelKind::Bert, set, Composition::MaxDegradation);
        let prod = m.xi_set(ModelKind::Bert, set, Composition::PairwiseProduct);
        assert!(mx >= 1.0);
        // Each factor is >= 1, so the product bounds the max from above.
        assert!(prod >= mx);
    }
}

//! Iteration-time / throughput model of a DDL job (paper §IV-A).
//!
//! * GPU compute:  `t_comp(B) = α_comp + β_comp · B`            (Eq. 3)
//! * all-reduce:   `t_comm    = α_comm + β_comm · M`            (Eq. 2/4)
//! * iteration with gradient-accumulation step `s` and compute/comm overlap
//!   degree `δ` (Eq. 7):
//!   `t_iter = (s-1)·t_comp(B/s) + (t_comp(B/s)^δ + t_comm^δ)^(1/δ)`
//! * GPU sharing multiplies iteration time by an interference ratio ξ
//!   (Eqs. 5/6), looked up in [`interference::InterferenceModel`]; sets
//!   of co-runners compose per-pair factors under a selectable
//!   [`interference::Composition`] rule, and [`share_set`] scores adding
//!   a job to an existing sharing set (DESIGN.md §17).
//!
//! All times are seconds (f64); message sizes are MB. Invariants: every
//! Eq. 7 time is positive and monotone in the accumulation step, a
//! reference [`GangSpan`] reproduces the placement-agnostic arithmetic
//! bit-for-bit, and composed ξ is ≥ 1 (DESIGN.md §2, §12, §17).

pub mod fit;
pub mod interference;
pub mod profiles;
pub mod share_set;


/// Placement summary of a gang, derived from where it actually landed on
/// the cluster topology: how many servers it spans, the bottleneck link of
/// its all-reduce path, and the slowest member GPU's compute scale.
///
/// [`GangSpan::reference`] describes the paper's baseline assumption — a
/// sufficient-bandwidth switch (every link at the reference 10 Gbps, zero
/// extra hop latency) over identical reference GPUs — and reproduces the
/// placement-agnostic Eq. 2/4 arithmetic bit-for-bit, which is what keeps
/// uniform-topology simulations byte-identical to the pre-topology model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GangSpan {
    /// Distinct servers spanned (`S(J_k)` in Table I).
    pub nodes: usize,
    /// Bandwidth of the slowest link on the all-reduce path, Gbps.
    pub bandwidth_gbps: f64,
    /// Per-hop latency of that link, seconds.
    pub latency_s: f64,
    /// Compute scale of the slowest member GPU (1.0 = the reference GPU
    /// the Eq. 3 coefficients were calibrated on; 2.0 = twice as fast).
    pub compute_scale: f64,
}

impl GangSpan {
    /// The link bandwidth the Eq. 4 `β_comm` coefficients are calibrated
    /// against (the paper's 10 Gbps testbed NIC).
    pub const REF_BANDWIDTH_GBPS: f64 = 10.0;

    /// The paper's placement-agnostic baseline: one node behind a
    /// reference-bandwidth switch, reference GPUs.
    pub fn reference() -> GangSpan {
        GangSpan {
            nodes: 1,
            bandwidth_gbps: Self::REF_BANDWIDTH_GBPS,
            latency_s: 0.0,
            compute_scale: 1.0,
        }
    }
}

/// Affine GPU-compute model, Eq. 3.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompModel {
    /// Fixed per-iteration overhead (kernel launch, data loading), seconds.
    pub alpha: f64,
    /// Seconds per sample of per-GPU batch.
    pub beta: f64,
}

impl CompModel {
    /// `t_comp(B)` for a per-GPU batch of `b` samples.
    pub fn t_comp(&self, b: f64) -> f64 {
        self.alpha + self.beta * b
    }
}

/// Affine all-reduce model, Eq. 2/4, with a ring-topology node factor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommModel {
    /// Latency term `a` (seconds); grows with participant count.
    pub alpha: f64,
    /// Seconds per MB of gradient payload on the slowest link.
    pub beta: f64,
}

impl CommModel {
    /// `t_comm` for `msg_mb` MB across `n` workers (ring all-reduce transfers
    /// `2(n-1)/n · M` on the bottleneck link; `n = 1` means no comm at all),
    /// under the placement-agnostic reference span (paper Eq. 2/4).
    pub fn t_comm(&self, msg_mb: f64, n: usize) -> f64 {
        self.t_comm_placed(msg_mb, n, &GangSpan::reference())
    }

    /// Locality-true `t_comm`: the payload term is rescaled by the
    /// bottleneck link of the gang's actual span (`β_comm` is calibrated
    /// at [`GangSpan::REF_BANDWIDTH_GBPS`]), and each node boundary on the
    /// ring adds one hop of link latency. A reference span reproduces
    /// [`CommModel::t_comm`]'s arithmetic exactly.
    pub fn t_comm_placed(&self, msg_mb: f64, n: usize, span: &GangSpan) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let ring = 2.0 * (n as f64 - 1.0) / n as f64;
        self.alpha * (n as f64).log2()
            + span.latency_s * span.nodes.saturating_sub(1) as f64
            + self.beta * msg_mb * ring * (GangSpan::REF_BANDWIDTH_GBPS / span.bandwidth_gbps)
    }
}

/// Full per-job performance model (Eq. 7 assembly).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PerfModel {
    pub comp: CompModel,
    pub comm: CommModel,
    /// Gradient payload per all-reduce, MB (model size).
    pub msg_mb: f64,
    /// Compute/communication overlap degree δ ≥ 1 (δ = 1: no overlap, sum;
    /// δ → ∞: perfect overlap, max). Paper §IV-A4, borrowed from Pollux.
    pub delta: f64,
}

impl PerfModel {
    /// Iteration time (seconds) with user batch `batch` per GPU, accumulation
    /// step `s` (sub-batch `batch/s`), over `n_workers` data-parallel GPUs,
    /// under the placement-agnostic reference span.
    ///
    /// Eq. 7: `(s-1)` sub-batch passes back-to-back, the final one overlapped
    /// with the all-reduce to degree δ.
    pub fn iter_time(&self, batch: f64, s: u32, n_workers: usize) -> f64 {
        self.iter_time_placed(batch, s, n_workers, &GangSpan::reference())
    }

    /// Locality-true Eq. 7: compute is scaled by the slowest member GPU,
    /// the all-reduce by the gang's bottleneck link (see
    /// [`CommModel::t_comm_placed`]). A reference span reproduces
    /// [`PerfModel::iter_time`] bit-for-bit.
    pub fn iter_time_placed(
        &self,
        batch: f64,
        s: u32,
        n_workers: usize,
        span: &GangSpan,
    ) -> f64 {
        assert!(s >= 1, "accumulation step must be >= 1");
        let sub = batch / s as f64;
        let tc = self.comp.t_comp(sub) / span.compute_scale;
        let tm = self.comm.t_comm_placed(self.msg_mb, n_workers, span);
        let overlapped = if tm == 0.0 {
            tc
        } else {
            (tc.powf(self.delta) + tm.powf(self.delta)).powf(1.0 / self.delta)
        };
        (s as f64 - 1.0) * tc + overlapped
    }

    /// Throughput in samples/second (Eq. 14: `φ = B / t_iter`), aggregated
    /// over all `n_workers` GPUs.
    pub fn throughput(&self, batch: f64, s: u32, n_workers: usize) -> f64 {
        n_workers as f64 * batch / self.iter_time(batch, s, n_workers)
    }

    /// Speedup of running on `n` workers vs 1 (used by the elastic baseline).
    pub fn speedup(&self, batch: f64, n: usize) -> f64 {
        self.throughput(batch, 1, n) / self.throughput(batch, 1, 1)
    }
}

/// GPU memory footprint model: `mem(b) = base + per_sample · b` (GB).
///
/// This is what makes Algorithm 2's batch halving *necessary*: two co-located
/// jobs must jointly fit in GPU memory, so the new job may have to shrink its
/// sub-batch via gradient accumulation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemModel {
    /// Weights + optimizer state + activations at batch 0, GB.
    pub base_gb: f64,
    /// Activation growth per sample, GB.
    pub per_sample_gb: f64,
}

impl MemModel {
    pub fn mem_gb(&self, sub_batch: f64) -> f64 {
        self.base_gb + self.per_sample_gb * sub_batch
    }

    /// Largest power-of-two sub-batch (≤ `batch`) fitting in `budget_gb`,
    /// or `None` if even sub-batch 1 does not fit.
    pub fn max_sub_batch(&self, batch: u32, budget_gb: f64) -> Option<u32> {
        let mut b = batch.max(1);
        loop {
            if self.mem_gb(b as f64) <= budget_gb {
                return Some(b);
            }
            if b == 1 {
                return None;
            }
            b /= 2;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pm() -> PerfModel {
        PerfModel {
            comp: CompModel { alpha: 0.02, beta: 0.01 },
            comm: CommModel { alpha: 0.002, beta: 0.001 },
            msg_mb: 100.0,
            delta: 2.0,
        }
    }

    #[test]
    fn comp_affine() {
        let c = CompModel { alpha: 0.1, beta: 0.5 };
        assert_eq!(c.t_comp(0.0), 0.1);
        assert_eq!(c.t_comp(4.0), 2.1);
    }

    #[test]
    fn comm_zero_for_single_worker() {
        let c = CommModel { alpha: 0.1, beta: 0.5 };
        assert_eq!(c.t_comm(100.0, 1), 0.0);
        assert!(c.t_comm(100.0, 2) > 0.0);
    }

    #[test]
    fn comm_monotone_in_workers() {
        let c = CommModel { alpha: 0.01, beta: 0.001 };
        let mut prev = 0.0;
        for n in [2usize, 4, 8, 16] {
            let t = c.t_comm(50.0, n);
            assert!(t > prev, "t_comm must grow with workers");
            prev = t;
        }
    }

    #[test]
    fn iter_time_s1_is_overlapped_only() {
        let m = pm();
        let t = m.iter_time(8.0, 1, 4);
        let tc = m.comp.t_comp(8.0);
        let tm = m.comm.t_comm(m.msg_mb, 4);
        let expect = (tc.powf(2.0) + tm.powf(2.0)).sqrt();
        assert!((t - expect).abs() < 1e-12);
    }

    #[test]
    fn iter_time_accumulation_adds_sub_passes() {
        let m = pm();
        // s=2: one extra sub-batch pass of t_comp(B/2).
        let t2 = m.iter_time(8.0, 2, 4);
        let t1_half = m.comp.t_comp(4.0);
        let tm = m.comm.t_comm(m.msg_mb, 4);
        let expect = t1_half + (t1_half.powf(2.0) + tm.powf(2.0)).sqrt();
        assert!((t2 - expect).abs() < 1e-12);
    }

    #[test]
    fn accumulation_overhead_is_alpha_only_when_no_comm() {
        // With n=1 (no comm), accumulation costs exactly (s-1)*alpha extra.
        let m = pm();
        let t1 = m.iter_time(8.0, 1, 1);
        let t4 = m.iter_time(8.0, 4, 1);
        assert!((t4 - t1 - 3.0 * m.comp.alpha).abs() < 1e-12);
    }

    #[test]
    fn overlap_bounds() {
        // δ=1 (sum) is the worst case; large δ approaches max(tc, tm).
        let mut m = pm();
        m.delta = 1.0;
        let sum = m.iter_time(8.0, 1, 8);
        m.delta = 64.0;
        let maxish = m.iter_time(8.0, 1, 8);
        let tc = m.comp.t_comp(8.0);
        let tm = m.comm.t_comm(m.msg_mb, 8);
        assert!((sum - (tc + tm)).abs() < 1e-9);
        assert!(maxish <= sum && maxish >= tc.max(tm) - 1e-9);
    }

    #[test]
    fn throughput_matches_eq14() {
        let m = pm();
        let phi = m.throughput(8.0, 1, 4);
        assert!((phi - 4.0 * 8.0 / m.iter_time(8.0, 1, 4)).abs() < 1e-12);
    }

    #[test]
    fn speedup_sublinear() {
        let m = pm();
        let s8 = m.speedup(8.0, 8);
        assert!(s8 > 1.0 && s8 < 8.0, "comm must make speedup sublinear: {s8}");
    }

    #[test]
    fn reference_span_is_bitwise_identical_to_agnostic_path() {
        // The uniform-topology equivalence guarantee rests on this: the
        // placed path under a reference span must reproduce the paper's
        // placement-agnostic arithmetic exactly, not approximately.
        let m = pm();
        let span = GangSpan::reference();
        for n in [1usize, 2, 4, 8, 16] {
            for s in [1u32, 2, 4] {
                let a = m.iter_time(24.0, s, n);
                let b = m.iter_time_placed(24.0, s, n, &span);
                assert_eq!(a.to_bits(), b.to_bits(), "n={n} s={s}");
            }
            assert_eq!(
                m.comm.t_comm(m.msg_mb, n).to_bits(),
                m.comm.t_comm_placed(m.msg_mb, n, &span).to_bits()
            );
        }
    }

    #[test]
    fn faster_bottleneck_link_shrinks_comm() {
        let m = pm();
        let nvlink = GangSpan {
            nodes: 1,
            bandwidth_gbps: 100.0,
            latency_s: 0.0,
            compute_scale: 1.0,
        };
        let fast = m.comm.t_comm_placed(m.msg_mb, 8, &nvlink);
        let slow = m.comm.t_comm(m.msg_mb, 8);
        assert!(fast < slow, "100 Gbps must beat the 10 Gbps reference");
        // The latency term (alpha) stays; only the payload term scales.
        let payload = slow - m.comm.alpha * 8f64.log2();
        assert!((fast - (slow - 0.9 * payload)).abs() < 1e-12);
    }

    #[test]
    fn node_crossings_add_link_latency() {
        let m = pm();
        let tier = |nodes| GangSpan {
            nodes,
            bandwidth_gbps: 10.0,
            latency_s: 2e-4,
            compute_scale: 1.0,
        };
        let one = m.comm.t_comm_placed(m.msg_mb, 8, &tier(1));
        let four = m.comm.t_comm_placed(m.msg_mb, 8, &tier(4));
        assert!((four - one - 3.0 * 2e-4).abs() < 1e-12);
    }

    #[test]
    fn compute_scale_speeds_up_compute_only() {
        let m = pm();
        let fast_gpu = GangSpan { compute_scale: 2.0, ..GangSpan::reference() };
        let t = m.iter_time_placed(8.0, 1, 1, &fast_gpu);
        // n = 1: no comm, so the iteration is exactly halved.
        assert!((t - m.iter_time(8.0, 1, 1) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn mem_max_sub_batch() {
        let mm = MemModel { base_gb: 4.0, per_sample_gb: 0.5 };
        // budget 11 GB: 4 + 0.5*b <= 11 -> b <= 14 -> largest p2 <= batch.
        assert_eq!(mm.max_sub_batch(16, 11.0), Some(8));
        assert_eq!(mm.max_sub_batch(8, 11.0), Some(8));
        // budget 4.4 GB: 4 + 0.5*b <= 4.4 -> b <= 0.8 -> nothing fits.
        assert_eq!(mm.max_sub_batch(16, 4.4), None);
        // budget 4.6 GB: sub-batch 1 fits.
        assert_eq!(mm.max_sub_batch(16, 4.6), Some(1));
    }
}

//! Iteration-time / throughput model of a DDL job (paper §IV-A).
//!
//! * GPU compute:  `t_comp(B) = α_comp + β_comp · B`            (Eq. 3)
//! * all-reduce:   `t_comm    = α_comm + β_comm · M`            (Eq. 2/4)
//! * iteration with gradient-accumulation step `s` and compute/comm overlap
//!   degree `δ` (Eq. 7):
//!   `t_iter = (s-1)·t_comp(B/s) + (t_comp(B/s)^δ + t_comm^δ)^(1/δ)`
//! * GPU sharing multiplies iteration time by an interference ratio ξ
//!   (Eqs. 5/6), looked up in [`interference::InterferenceModel`].
//!
//! All times are seconds (f64); message sizes are MB.

pub mod fit;
pub mod interference;
pub mod profiles;


/// Affine GPU-compute model, Eq. 3.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompModel {
    /// Fixed per-iteration overhead (kernel launch, data loading), seconds.
    pub alpha: f64,
    /// Seconds per sample of per-GPU batch.
    pub beta: f64,
}

impl CompModel {
    /// `t_comp(B)` for a per-GPU batch of `b` samples.
    pub fn t_comp(&self, b: f64) -> f64 {
        self.alpha + self.beta * b
    }
}

/// Affine all-reduce model, Eq. 2/4, with a ring-topology node factor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommModel {
    /// Latency term `a` (seconds); grows with participant count.
    pub alpha: f64,
    /// Seconds per MB of gradient payload on the slowest link.
    pub beta: f64,
}

impl CommModel {
    /// `t_comm` for `msg_mb` MB across `n` workers (ring all-reduce transfers
    /// `2(n-1)/n · M` on the bottleneck link; `n = 1` means no comm at all).
    pub fn t_comm(&self, msg_mb: f64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let ring = 2.0 * (n as f64 - 1.0) / n as f64;
        self.alpha * (n as f64).log2() + self.beta * msg_mb * ring
    }
}

/// Full per-job performance model (Eq. 7 assembly).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PerfModel {
    pub comp: CompModel,
    pub comm: CommModel,
    /// Gradient payload per all-reduce, MB (model size).
    pub msg_mb: f64,
    /// Compute/communication overlap degree δ ≥ 1 (δ = 1: no overlap, sum;
    /// δ → ∞: perfect overlap, max). Paper §IV-A4, borrowed from Pollux.
    pub delta: f64,
}

impl PerfModel {
    /// Iteration time (seconds) with user batch `batch` per GPU, accumulation
    /// step `s` (sub-batch `batch/s`), over `n_workers` data-parallel GPUs.
    ///
    /// Eq. 7: `(s-1)` sub-batch passes back-to-back, the final one overlapped
    /// with the all-reduce to degree δ.
    pub fn iter_time(&self, batch: f64, s: u32, n_workers: usize) -> f64 {
        assert!(s >= 1, "accumulation step must be >= 1");
        let sub = batch / s as f64;
        let tc = self.comp.t_comp(sub);
        let tm = self.comm.t_comm(self.msg_mb, n_workers);
        let overlapped = if tm == 0.0 {
            tc
        } else {
            (tc.powf(self.delta) + tm.powf(self.delta)).powf(1.0 / self.delta)
        };
        (s as f64 - 1.0) * tc + overlapped
    }

    /// Throughput in samples/second (Eq. 14: `φ = B / t_iter`), aggregated
    /// over all `n_workers` GPUs.
    pub fn throughput(&self, batch: f64, s: u32, n_workers: usize) -> f64 {
        n_workers as f64 * batch / self.iter_time(batch, s, n_workers)
    }

    /// Speedup of running on `n` workers vs 1 (used by the elastic baseline).
    pub fn speedup(&self, batch: f64, n: usize) -> f64 {
        self.throughput(batch, 1, n) / self.throughput(batch, 1, 1)
    }
}

/// GPU memory footprint model: `mem(b) = base + per_sample · b` (GB).
///
/// This is what makes Algorithm 2's batch halving *necessary*: two co-located
/// jobs must jointly fit in GPU memory, so the new job may have to shrink its
/// sub-batch via gradient accumulation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemModel {
    /// Weights + optimizer state + activations at batch 0, GB.
    pub base_gb: f64,
    /// Activation growth per sample, GB.
    pub per_sample_gb: f64,
}

impl MemModel {
    pub fn mem_gb(&self, sub_batch: f64) -> f64 {
        self.base_gb + self.per_sample_gb * sub_batch
    }

    /// Largest power-of-two sub-batch (≤ `batch`) fitting in `budget_gb`,
    /// or `None` if even sub-batch 1 does not fit.
    pub fn max_sub_batch(&self, batch: u32, budget_gb: f64) -> Option<u32> {
        let mut b = batch.max(1);
        loop {
            if self.mem_gb(b as f64) <= budget_gb {
                return Some(b);
            }
            if b == 1 {
                return None;
            }
            b /= 2;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pm() -> PerfModel {
        PerfModel {
            comp: CompModel { alpha: 0.02, beta: 0.01 },
            comm: CommModel { alpha: 0.002, beta: 0.001 },
            msg_mb: 100.0,
            delta: 2.0,
        }
    }

    #[test]
    fn comp_affine() {
        let c = CompModel { alpha: 0.1, beta: 0.5 };
        assert_eq!(c.t_comp(0.0), 0.1);
        assert_eq!(c.t_comp(4.0), 2.1);
    }

    #[test]
    fn comm_zero_for_single_worker() {
        let c = CommModel { alpha: 0.1, beta: 0.5 };
        assert_eq!(c.t_comm(100.0, 1), 0.0);
        assert!(c.t_comm(100.0, 2) > 0.0);
    }

    #[test]
    fn comm_monotone_in_workers() {
        let c = CommModel { alpha: 0.01, beta: 0.001 };
        let mut prev = 0.0;
        for n in [2usize, 4, 8, 16] {
            let t = c.t_comm(50.0, n);
            assert!(t > prev, "t_comm must grow with workers");
            prev = t;
        }
    }

    #[test]
    fn iter_time_s1_is_overlapped_only() {
        let m = pm();
        let t = m.iter_time(8.0, 1, 4);
        let tc = m.comp.t_comp(8.0);
        let tm = m.comm.t_comm(m.msg_mb, 4);
        let expect = (tc.powf(2.0) + tm.powf(2.0)).sqrt();
        assert!((t - expect).abs() < 1e-12);
    }

    #[test]
    fn iter_time_accumulation_adds_sub_passes() {
        let m = pm();
        // s=2: one extra sub-batch pass of t_comp(B/2).
        let t2 = m.iter_time(8.0, 2, 4);
        let t1_half = m.comp.t_comp(4.0);
        let tm = m.comm.t_comm(m.msg_mb, 4);
        let expect = t1_half + (t1_half.powf(2.0) + tm.powf(2.0)).sqrt();
        assert!((t2 - expect).abs() < 1e-12);
    }

    #[test]
    fn accumulation_overhead_is_alpha_only_when_no_comm() {
        // With n=1 (no comm), accumulation costs exactly (s-1)*alpha extra.
        let m = pm();
        let t1 = m.iter_time(8.0, 1, 1);
        let t4 = m.iter_time(8.0, 4, 1);
        assert!((t4 - t1 - 3.0 * m.comp.alpha).abs() < 1e-12);
    }

    #[test]
    fn overlap_bounds() {
        // δ=1 (sum) is the worst case; large δ approaches max(tc, tm).
        let mut m = pm();
        m.delta = 1.0;
        let sum = m.iter_time(8.0, 1, 8);
        m.delta = 64.0;
        let maxish = m.iter_time(8.0, 1, 8);
        let tc = m.comp.t_comp(8.0);
        let tm = m.comm.t_comm(m.msg_mb, 8);
        assert!((sum - (tc + tm)).abs() < 1e-9);
        assert!(maxish <= sum && maxish >= tc.max(tm) - 1e-9);
    }

    #[test]
    fn throughput_matches_eq14() {
        let m = pm();
        let phi = m.throughput(8.0, 1, 4);
        assert!((phi - 4.0 * 8.0 / m.iter_time(8.0, 1, 4)).abs() < 1e-12);
    }

    #[test]
    fn speedup_sublinear() {
        let m = pm();
        let s8 = m.speedup(8.0, 8);
        assert!(s8 > 1.0 && s8 < 8.0, "comm must make speedup sublinear: {s8}");
    }

    #[test]
    fn mem_max_sub_batch() {
        let mm = MemModel { base_gb: 4.0, per_sample_gb: 0.5 };
        // budget 11 GB: 4 + 0.5*b <= 11 -> b <= 14 -> largest p2 <= batch.
        assert_eq!(mm.max_sub_batch(16, 11.0), Some(8));
        assert_eq!(mm.max_sub_batch(8, 11.0), Some(8));
        // budget 4.4 GB: 4 + 0.5*b <= 4.4 -> b <= 0.8 -> nothing fits.
        assert_eq!(mm.max_sub_batch(16, 4.4), None);
        // budget 4.6 GB: sub-batch 1 fits.
        assert_eq!(mm.max_sub_batch(16, 4.6), Some(1));
    }
}

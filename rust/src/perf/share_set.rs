//! k-way sharing sets: Algorithm-2-style marginal-benefit scoring for
//! adding one pending job to an *existing* set of co-residents
//! (DESIGN.md §17).
//!
//! [`crate::pair`] is the paper's exact C = 2 analysis: one running job,
//! one newcomer, Theorem 1 on the two κ endpoints. With a share cap
//! C > 2 the candidate GPU set may already hold up to C − 1 residents,
//! so the score for "add job A here" must account for the whole set:
//! composed interference ([`Composition`]), Eq. 9 memory feasibility
//! over *all* residents, and completion times under a fluid drain where
//! each member de-inflates as its neighbors finish.
//!
//! Invariants:
//! * exactly one resident ⇒ [`share_set_scaling_placed`] delegates to
//!   [`pair::batch_size_scaling_placed`], so the returned verdict, the
//!   sub-batch, and the sort key ([`ShareSetConfig::set_jct`]) are
//!   bit-for-bit the pair path's — this is the hinge of the C = 2
//!   parity guarantee (`rust/tests/share_cap.rs`);
//! * the newcomer's memory budget is the tightest GPU's budget minus
//!   the sum of every resident's footprint (Eq. 9 over the set, not a
//!   pairwise check);
//! * `None` means no sub-batch down to 1 fits next to the residents.

use crate::jobs::JobRecord;
use crate::pair;
use crate::perf::interference::{Composition, InterferenceModel};
use crate::perf::profiles::ModelKind;
use crate::perf::GangSpan;

/// Best configuration for adding one job to a sharing set — the k-way
/// generalization of [`pair::SharingConfig`].
#[derive(Debug, Clone, Copy)]
pub struct ShareSetConfig {
    /// Share now (κ = 0)? False ⇒ the set prefers the newcomer to wait.
    pub share: bool,
    /// Chosen sub-batch `b̄` for the newcomer.
    pub sub_batch: u32,
    /// Accumulation step s = B / b̄.
    pub accum_step: u32,
    /// Best mean completion time of the whole set (newcomer + residents),
    /// measured from now — the Alg. 1 line 14 sort key. Equals
    /// [`pair::SharingConfig::pair_jct`] bit-for-bit at one resident.
    pub set_jct: f64,
    /// Mean set JCT under full overlap (κ = 0).
    pub overlap_avg: f64,
    /// Mean set JCT with the newcomer waiting out every resident.
    pub sequential_avg: f64,
}

impl ShareSetConfig {
    fn from_pair(cfg: pair::SharingConfig) -> Self {
        ShareSetConfig {
            share: cfg.share,
            sub_batch: cfg.sub_batch,
            accum_step: cfg.accum_step,
            set_jct: cfg.pair_jct,
            overlap_avg: cfg.schedule.overlap_avg,
            sequential_avg: cfg.schedule.sequential_avg,
        }
    }
}

/// One member of a fluid-drain evaluation: solo per-iteration time on its
/// own placement plus estimated remaining iterations.
#[derive(Debug, Clone)]
struct SetSide {
    model: ModelKind,
    iter_time: f64,
    iters: f64,
}

/// Fluid drain of a co-located set: every member runs inflated by the
/// composed ξ of the *currently active* others, de-inflating as
/// neighbors depart. Returns each member's finish time from now. With
/// two members this is exactly the drain-first overlap arithmetic of
/// [`pair::best_pair_schedule`].
fn fluid_finish(sides: &[SetSide], xi: &InterferenceModel, comp: Composition) -> Vec<f64> {
    let n = sides.len();
    let mut rem: Vec<f64> = sides.iter().map(|s| s.iters).collect();
    let mut finish = vec![0.0f64; n];
    let mut active: Vec<usize> = (0..n).collect();
    let mut now = 0.0f64;
    while !active.is_empty() {
        let inflated: Vec<f64> = active
            .iter()
            .map(|&j| {
                let others =
                    active.iter().filter(|&&o| o != j).map(|&o| sides[o].model);
                sides[j].iter_time * xi.xi_set(sides[j].model, others, comp)
            })
            .collect();
        // Next departure: earliest index wins ties (deterministic).
        let mut next = 0usize;
        let mut dt = f64::INFINITY;
        for (pos, (&j, &t)) in active.iter().zip(&inflated).enumerate() {
            let left = rem[j] * t;
            if left < dt {
                next = pos;
                dt = left;
            }
        }
        now += dt;
        for (pos, (&j, &t)) in active.iter().zip(&inflated).enumerate() {
            if pos == next {
                rem[j] = 0.0;
                finish[j] = now;
            } else {
                rem[j] -= dt / t;
            }
        }
        active.remove(next);
    }
    finish
}

/// Algorithm 2 generalized to sharing sets: sweep the newcomer's
/// sub-batch over `{B, B/2, …, 1}`, check Eq. 9 over the whole resident
/// set, and score each feasible configuration by the mean completion
/// time of all k + 1 jobs under the better κ endpoint.
///
/// * `residents` — the jobs already on the candidate GPU set (their
///   batches and accumulation steps stay untouched, §V-B3), with
///   `remaining_iters` refreshed by the caller; `resident_spans` are
///   their own placements, index-aligned.
/// * `gang` / `new_span` — the shared GPU set the newcomer would land on.
/// * `gpu_mem_gb` — the tightest shared GPU's budget; residents'
///   footprints are subtracted here (Eq. 9 over the set).
///
/// With exactly one resident this delegates to
/// [`pair::batch_size_scaling_placed`] and is bit-identical to it.
#[allow(clippy::too_many_arguments)]
pub fn share_set_scaling_placed(
    new_job: &JobRecord,
    residents: &[JobRecord],
    gang: usize,
    gpu_mem_gb: f64,
    xi: &InterferenceModel,
    comp: Composition,
    sweep_batches: bool,
    new_span: &GangSpan,
    resident_spans: &[GangSpan],
) -> Option<ShareSetConfig> {
    assert!(!residents.is_empty(), "share-set scoring needs at least one resident");
    assert_eq!(residents.len(), resident_spans.len(), "one span per resident");
    if residents.len() == 1 {
        return pair::batch_size_scaling_placed(
            new_job,
            &residents[0],
            gang,
            gpu_mem_gb,
            xi,
            sweep_batches,
            new_span,
            &resident_spans[0],
        )
        .map(ShareSetConfig::from_pair);
    }

    let new_prof = new_job.spec.profile();
    // Eq. 9 over the set: the newcomer gets what every resident together
    // leaves on the tightest GPU.
    let budget = residents.iter().fold(gpu_mem_gb, |b, r| {
        b - r.spec.profile().mem.mem_gb(r.spec.batch as f64 / r.accum_step as f64)
    });

    let resident_sides: Vec<SetSide> = residents
        .iter()
        .zip(resident_spans)
        .map(|(r, span)| SetSide {
            model: r.spec.model,
            iter_time: r.spec.profile().perf.iter_time_placed(
                r.spec.batch as f64,
                r.accum_step,
                r.spec.gpus,
                span,
            ),
            iters: r.estimated_remaining_iters(),
        })
        .collect();
    // Sequential endpoint: the residents drain among themselves (they
    // interfere with each other whether or not the newcomer joins), and
    // the newcomer starts solo after the last departure.
    let resident_finish = fluid_finish(&resident_sides, xi, comp);
    let last_resident = resident_finish.iter().fold(0.0f64, |a, &b| a.max(b));
    let resident_sum: f64 = resident_finish.iter().sum();

    let mut best: Option<ShareSetConfig> = None;
    let mut b = new_job.spec.batch.max(1);
    loop {
        let s = (new_job.spec.batch as f64 / b as f64).ceil() as u32;
        if new_prof.mem.mem_gb(b as f64) <= budget {
            let new_iter = new_prof.perf.iter_time_placed(
                new_job.spec.batch as f64,
                s,
                gang,
                new_span,
            );
            let mut sides = resident_sides.clone();
            sides.push(SetSide {
                model: new_job.spec.model,
                iter_time: new_iter,
                iters: new_job.estimated_remaining_iters(),
            });
            let finish = fluid_finish(&sides, xi, comp);
            let n = finish.len() as f64;
            let overlap_avg = finish.iter().sum::<f64>() / n;
            let seq_new = last_resident + new_iter * new_job.estimated_remaining_iters();
            let sequential_avg = (resident_sum + seq_new) / n;
            let share = overlap_avg <= sequential_avg;
            let set_jct = overlap_avg.min(sequential_avg);
            let better = match &best {
                None => true,
                Some(cfg) => set_jct < cfg.set_jct,
            };
            if better {
                best = Some(ShareSetConfig {
                    share,
                    sub_batch: b,
                    accum_step: s,
                    set_jct,
                    overlap_avg,
                    sequential_avg,
                });
            }
        }
        if b == 1 || !sweep_batches {
            break;
        }
        b /= 2;
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::{JobRecord, JobSpec};

    fn record(model: ModelKind, gpus: usize, iters: u64, batch: u32) -> JobRecord {
        JobRecord::new(JobSpec {
            id: 0,
            model,
            gpus,
            iterations: iters,
            batch,
            arrival_s: 0.0,
            est_factor: 1.0,
        })
    }

    #[test]
    fn one_resident_is_bitwise_the_pair_path() {
        let new = record(ModelKind::Bert, 4, 500, 16);
        let run = record(ModelKind::Cifar10, 4, 500, 128);
        let xi = InterferenceModel::new();
        let r = GangSpan::reference();
        let set = share_set_scaling_placed(
            &new,
            std::slice::from_ref(&run),
            4,
            11.0,
            &xi,
            Composition::MaxDegradation,
            true,
            &r,
            std::slice::from_ref(&r),
        )
        .unwrap();
        let pair = pair::batch_size_scaling_placed(&new, &run, 4, 11.0, &xi, true, &r, &r)
            .unwrap();
        assert_eq!(set.set_jct.to_bits(), pair.pair_jct.to_bits());
        assert_eq!(set.share, pair.share);
        assert_eq!(set.sub_batch, pair.sub_batch);
        assert_eq!(set.accum_step, pair.accum_step);
    }

    #[test]
    fn memory_budget_sums_over_all_residents() {
        // One CIFAR10 resident (4.3 GB) leaves room for a sub-batched BERT;
        // two of them (8.6 GB) leave less than BERT's 4.2 GB base, so the
        // set check must reject what a pairwise check would admit.
        let new = record(ModelKind::Bert, 4, 500, 16);
        let run = record(ModelKind::Cifar10, 4, 500, 128);
        let xi = InterferenceModel::new();
        let r = GangSpan::reference();
        let one = share_set_scaling_placed(
            &new,
            std::slice::from_ref(&run),
            4,
            11.0,
            &xi,
            Composition::MaxDegradation,
            true,
            &r,
            std::slice::from_ref(&r),
        );
        assert!(one.is_some());
        let residents = [run.clone(), run.clone()];
        let spans = [r, r];
        let two = share_set_scaling_placed(
            &new,
            &residents,
            4,
            11.0,
            &xi,
            Composition::MaxDegradation,
            true,
            &r,
            &spans,
        );
        assert!(two.is_none(), "set budget must reject the third resident");
    }

    #[test]
    fn polite_trio_shares() {
        let new = record(ModelKind::Ncf, 2, 1000, 4096);
        let residents = [
            record(ModelKind::Cifar10, 2, 1000, 128),
            record(ModelKind::Ncf, 2, 1000, 4096),
        ];
        let xi = InterferenceModel::new();
        let r = GangSpan::reference();
        let spans = [r, r];
        let cfg = share_set_scaling_placed(
            &new,
            &residents,
            2,
            11.0,
            &xi,
            Composition::MaxDegradation,
            true,
            &r,
            &spans,
        )
        .unwrap();
        assert!(cfg.share, "{cfg:?}");
    }

    #[test]
    fn heavy_interference_set_declines_to_share() {
        let new = record(ModelKind::Cifar10, 2, 1000, 32);
        let residents = [
            record(ModelKind::Cifar10, 2, 1000, 32),
            record(ModelKind::Cifar10, 2, 1000, 32),
        ];
        let xi = InterferenceModel::with_global(4.0);
        let r = GangSpan::reference();
        let spans = [r, r];
        let cfg = share_set_scaling_placed(
            &new,
            &residents,
            2,
            11.0,
            &xi,
            Composition::MaxDegradation,
            true,
            &r,
            &spans,
        )
        .unwrap();
        assert!(!cfg.share, "{cfg:?}");
    }

    #[test]
    fn product_composition_never_scores_below_max() {
        let new = record(ModelKind::Ncf, 2, 1000, 4096);
        let residents = [
            record(ModelKind::Cifar10, 2, 1000, 128),
            record(ModelKind::Ncf, 2, 1000, 4096),
        ];
        let xi = InterferenceModel::new();
        let r = GangSpan::reference();
        let spans = [r, r];
        let mx = share_set_scaling_placed(
            &new,
            &residents,
            2,
            11.0,
            &xi,
            Composition::MaxDegradation,
            true,
            &r,
            &spans,
        )
        .unwrap();
        let prod = share_set_scaling_placed(
            &new,
            &residents,
            2,
            11.0,
            &xi,
            Composition::PairwiseProduct,
            true,
            &r,
            &spans,
        )
        .unwrap();
        assert!(prod.overlap_avg >= mx.overlap_avg, "{prod:?} vs {mx:?}");
    }
}

//! `serve-load` — replay a workload-v2 preset as *live traffic* against
//! an in-process daemon.
//!
//! Where `simulate` hands the engine the whole trace up front, this
//! driver speaks the protocol: for each generated job it advances the
//! virtual clock to the arrival instant and issues a real `submit` line,
//! then `drain`s. That exercises the admission path (including `busy`
//! backpressure under `--max-pending`), the notification stream, and the
//! request→decision hot path — the same loop a real client would run,
//! which is why the perfkit `serve` suite benches through here.
//!
//! Two latency families come out: *end-to-end sim latency* per completed
//! job (completion instant − submission instant, the client-visible
//! JCT), and *wall-clock decision latency* per submit (how long
//! `handle_line` took, scheduler work included).

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::jobs::trace::{self, TraceConfig};
use crate::jobs::workload;
use crate::obskit::Obs;
use crate::util::json::Json;
use crate::util::stats::percentile_nearest_rank;

use super::proto::jobj;
use super::{ClusterSpec, Daemon, ServeConfig};

#[derive(Debug, Clone)]
pub struct LoadConfig {
    pub preset: String,
    pub load: f64,
    pub jobs: usize,
    pub seed: u64,
    pub policy: String,
    pub max_pending: usize,
    pub cluster: ClusterSpec,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            preset: "philly-sim".to_string(),
            load: 1.0,
            jobs: 96,
            seed: 1,
            policy: "SJF-BSBF".to_string(),
            max_pending: 64,
            cluster: ClusterSpec::Preset("simulation".to_string()),
        }
    }
}

#[derive(Debug, Clone)]
pub struct LoadOutcome {
    pub submitted: usize,
    pub accepted: usize,
    pub rejected_busy: usize,
    pub completed: usize,
    /// Final sim time after drain.
    pub makespan_s: f64,
    /// End-to-end sim latency (completion − submission) percentiles.
    pub latency_mean_s: f64,
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    pub latency_p99_s: f64,
    /// Wall seconds for the whole session and the derived rate.
    pub wall_s: f64,
    pub submissions_per_s: f64,
    /// Raw wall-clock `handle_line` latency per submit, for the perfkit
    /// suite to fold into bench stats.
    pub decision_latencies_s: Vec<f64>,
}

impl LoadOutcome {
    /// The human report `wise-share serve-load` prints.
    pub fn summary(&self) -> String {
        let mut d = self.decision_latencies_s.clone();
        d.sort_by(f64::total_cmp);
        format!(
            "serve-load: {} submitted ({} accepted, {} busy-rejected), {} completed\n\
             sim: makespan {:.0}s, end-to-end latency mean {:.1}s p50 {:.1}s p95 {:.1}s p99 {:.1}s\n\
             wall: {:.2}s for the session, {:.0} submissions/s, \
             decision latency p50 {:.1}us p95 {:.1}us",
            self.submitted,
            self.accepted,
            self.rejected_busy,
            self.completed,
            self.makespan_s,
            self.latency_mean_s,
            self.latency_p50_s,
            self.latency_p95_s,
            self.latency_p99_s,
            self.wall_s,
            self.submissions_per_s,
            percentile_nearest_rank(&d, 0.50) * 1e6,
            percentile_nearest_rank(&d, 0.95) * 1e6,
        )
    }
}

fn scan_events(lines: &[String], completions: &mut BTreeMap<u64, f64>, rejected: &mut usize) {
    for line in lines {
        let Ok(j) = Json::parse(line) else { continue };
        if j.get("type").and_then(|t| t.as_str()) != Some("event") {
            continue;
        }
        match j.get("event").and_then(|e| e.as_str()) {
            Some("completed") => {
                if let (Some(id), Some(t)) =
                    (j.get("id").and_then(|v| v.as_u64()), j.get("t").and_then(|v| v.as_f64()))
                {
                    completions.insert(id, t);
                }
            }
            Some("rejected") => *rejected += 1,
            _ => {}
        }
    }
}

fn response_ok(lines: &[String]) -> bool {
    lines
        .last()
        .and_then(|l| Json::parse(l).ok())
        .and_then(|j| j.get("ok").and_then(|o| o.as_bool()))
        == Some(true)
}

pub fn run(cfg: &LoadConfig, obs: Obs) -> Result<LoadOutcome> {
    if !(cfg.load.is_finite() && cfg.load > 0.0) {
        bail!("--load {} must be finite and > 0", cfg.load);
    }
    let preset = workload::by_name_or_err(&cfg.preset)?;
    let mut tc = TraceConfig::from_preset(&preset, cfg.jobs, cfg.seed);
    tc.load_factor = cfg.load;
    let mut specs = trace::generate(&tc);
    specs.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));

    let scfg = ServeConfig {
        policy: cfg.policy.clone(),
        cluster: cfg.cluster.clone(),
        max_pending: cfg.max_pending,
        ..ServeConfig::default()
    };
    let mut daemon = Daemon::new(scfg, obs)?;
    let wall0 = Instant::now();
    let mut submissions: BTreeMap<u64, f64> = BTreeMap::new();
    let mut completions: BTreeMap<u64, f64> = BTreeMap::new();
    let mut rejected_busy = 0usize;
    let mut accepted = 0usize;
    let mut decision = Vec::with_capacity(specs.len());

    for spec in &specs {
        if spec.arrival_s > daemon.now() + 1e-9 {
            let adv =
                jobj(vec![("op", Json::from("advance")), ("to", Json::Num(spec.arrival_s))])
                    .to_string();
            let out = daemon.handle_line(&adv);
            scan_events(&out.lines, &mut completions, &mut rejected_busy);
        }
        let req = jobj(vec![
            ("op", Json::from("submit")),
            ("id", Json::from(spec.id as u64)),
            ("model", Json::from(spec.model.name())),
            ("gpus", Json::from(spec.gpus)),
            ("iterations", Json::from(spec.iterations)),
            ("batch", Json::from(spec.batch as u64)),
            ("est_factor", Json::Num(spec.est_factor)),
        ])
        .to_string();
        let t0 = Instant::now();
        let out = daemon.handle_line(&req);
        decision.push(t0.elapsed().as_secs_f64());
        scan_events(&out.lines, &mut completions, &mut rejected_busy);
        if response_ok(&out.lines) {
            accepted += 1;
            submissions.insert(spec.id as u64, daemon.now());
        }
    }
    let out = daemon.handle_line("{\"op\":\"drain\"}");
    scan_events(&out.lines, &mut completions, &mut rejected_busy);
    let wall_s = wall0.elapsed().as_secs_f64();

    let mut lat: Vec<f64> = completions
        .iter()
        .filter_map(|(id, &t)| submissions.get(id).map(|&a| t - a))
        .collect();
    lat.sort_by(f64::total_cmp);
    let mean = if lat.is_empty() { 0.0 } else { lat.iter().sum::<f64>() / lat.len() as f64 };
    Ok(LoadOutcome {
        submitted: specs.len(),
        accepted,
        rejected_busy,
        completed: completions.len(),
        makespan_s: daemon.now(),
        latency_mean_s: mean,
        latency_p50_s: percentile_nearest_rank(&lat, 0.50),
        latency_p95_s: percentile_nearest_rank(&lat, 0.95),
        latency_p99_s: percentile_nearest_rank(&lat, 0.99),
        wall_s,
        submissions_per_s: if wall_s > 0.0 { specs.len() as f64 / wall_s } else { 0.0 },
        decision_latencies_s: decision,
    })
}

//! The [`Daemon`]: one live scheduler — a [`SchedContext`] plus a
//! [`Policy`] driven through the shared [`EventPump`] — dispatching
//! line-JSON requests.
//!
//! Everything protocol-visible happens in [`Daemon::handle_line`], which
//! is deliberately I/O-free: it takes one request line and returns the
//! output lines plus an exit flag. The stdin/TCP loops in the parent
//! module, the `serve-load` driver, the perfkit `serve` suite, and the
//! conformance tests all speak to the daemon through this one entry
//! point, so a scripted session produces byte-identical output no matter
//! which front end carried the bytes.
//!
//! Request handling never panics on client input: anything malformed or
//! inapplicable becomes an `"ok": false` response with a machine-readable
//! `code` (see [`super::proto`]).

use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::jobs::{JobId, JobSpec, JobState};
use crate::obskit::Obs;
use crate::perf::interference::InterferenceModel;
use crate::sched::{self, POLICY_NAMES};
use crate::sched_core::{ApplyReport, Decision, EventPump, Policy, PumpHooks, SchedContext, Txn};
use crate::util::json::Json;

use super::proto::{self, Request, SubmitReq};
use super::{snapshot, ServeConfig};

/// The output of one request (or of shutdown): protocol lines in emission
/// order — notifications first, the response last — plus whether the
/// daemon should exit afterwards.
#[derive(Debug, Default)]
pub struct HandleOutcome {
    pub lines: Vec<String>,
    pub exit: bool,
}

/// Pump hook that turns engine transitions into protocol notifications.
/// Lines accumulate here while the pump runs and are drained into the
/// current request's output (or the clock poll's) afterwards.
pub(super) struct Notifier {
    /// Internal dense [`JobId`] → the client's submit id.
    pub(super) int2ext: Vec<u64>,
    pub(super) lines: Vec<String>,
}

impl Notifier {
    pub(super) fn new(int2ext: Vec<u64>) -> Notifier {
        Notifier { int2ext, lines: Vec::new() }
    }
}

impl PumpHooks for Notifier {
    fn completed(&mut self, ctx: &SchedContext, job: JobId) -> Result<()> {
        let rec = &ctx.jobs[job];
        self.lines.push(proto::event_completed(
            ctx.now(),
            self.int2ext[job],
            rec.jct(),
            rec.queued_s,
        ));
        Ok(())
    }

    fn txn_applied(
        &mut self,
        ctx: &SchedContext,
        txn: &Txn,
        _report: &ApplyReport,
    ) -> Result<()> {
        for d in txn.ops() {
            if let Decision::Start { job, gpus, accum_step } = d {
                self.lines.push(proto::event_started(
                    ctx.now(),
                    self.int2ext[*job],
                    gpus,
                    *accum_step,
                ));
            }
        }
        Ok(())
    }
}

pub struct Daemon {
    pub(super) cfg: ServeConfig,
    pub(super) ctx: SchedContext,
    pub(super) policy: Box<dyn Policy>,
    pub(super) pump: EventPump,
    pub(super) notes: Notifier,
    /// Client submit id → internal dense id.
    pub(super) ext2int: BTreeMap<u64, JobId>,
    /// Jobs retired by `cancel` (their `Finished` state is cancellation,
    /// not completion — they never emitted a `completed` event).
    pub(super) cancelled: BTreeSet<JobId>,
    pub(super) draining: bool,
    /// Next sim instant at which the snapshot cadence fires.
    pub(super) next_snapshot_s: f64,
    /// Wall anchor for `--time-compression` mode; set on first poll.
    pub(super) started_wall: Option<Instant>,
}

impl Daemon {
    pub fn new(cfg: ServeConfig, obs: Obs) -> Result<Daemon> {
        if cfg.max_pending == 0 {
            bail!("--max-pending 0 must be at least 1");
        }
        if !(cfg.snapshot_every_s.is_finite() && cfg.snapshot_every_s > 0.0) {
            bail!("--snapshot-every {} must be finite and > 0", cfg.snapshot_every_s);
        }
        if let Some(c) = cfg.time_compression {
            if !(c.is_finite() && c > 0.0) {
                bail!("--time-compression {c} must be finite and > 0");
            }
        }
        let cluster = cfg.cluster.build()?;
        let xi = match cfg.xi_global {
            Some(x) => InterferenceModel::with_global(x),
            None => InterferenceModel::new(),
        };
        let policy = sched::by_name(&cfg.policy).with_context(|| {
            format!("unknown policy {:?} (known: {})", cfg.policy, POLICY_NAMES.join(", "))
        })?;
        let pump = EventPump::new(policy.as_ref());
        let mut ctx = SchedContext::new(cluster, Vec::new(), xi);
        ctx.set_obs(obs);
        let next_snapshot_s = cfg.snapshot_every_s;
        Ok(Daemon {
            cfg,
            ctx,
            policy,
            pump,
            notes: Notifier::new(Vec::new()),
            ext2int: BTreeMap::new(),
            cancelled: BTreeSet::new(),
            draining: false,
            next_snapshot_s,
            started_wall: None,
        })
    }

    /// Restore a daemon from a crash-recovery snapshot (`--resume`).
    /// Policy, cluster, ξ, and limits come from the snapshot; future
    /// snapshots go to `snapshot_to` if given, else back to `path`.
    pub fn resume(
        path: &std::path::Path,
        snapshot_to: Option<std::path::PathBuf>,
        obs: Obs,
    ) -> Result<Daemon> {
        snapshot::resume(path, snapshot_to, obs)
    }

    pub fn now(&self) -> f64 {
        self.ctx.now()
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    // --------------------------------------------------- request entry

    /// Handle one request line; never panics on client input. Empty
    /// lines are ignored (no output).
    pub fn handle_line(&mut self, line: &str) -> HandleOutcome {
        let mut out = HandleOutcome::default();
        let line = line.trim();
        if line.is_empty() {
            return out;
        }
        match proto::parse_request(line) {
            Err(e) => out.lines.push(proto::err_line(&e)),
            Ok(req) => {
                if let Err(e) = self.dispatch(req, &mut out) {
                    // Pump/apply/snapshot failures: surface, keep serving.
                    self.flush_notes(&mut out);
                    out.lines.push(proto::err(None, proto::E_INTERNAL, &format!("{e:#}")));
                }
            }
        }
        out
    }

    /// Wall-clock mode: pin sim time to `wall_elapsed × compression` and
    /// return any notifications that fired. No-op under the virtual
    /// clock.
    pub fn poll_clock(&mut self) -> Result<Vec<String>> {
        let Some(comp) = self.cfg.time_compression else {
            return Ok(Vec::new());
        };
        let t0 = *self.started_wall.get_or_insert_with(Instant::now);
        let target = t0.elapsed().as_secs_f64() * comp;
        if target > self.ctx.now() {
            self.pump_to(target)?;
            self.maybe_snapshot()?;
        }
        Ok(std::mem::take(&mut self.notes.lines))
    }

    /// The non-drain exit path (client EOF, SIGINT/SIGTERM): final
    /// snapshot, flushed obskit sinks, a `shutdown` event. Errors become
    /// protocol lines — the daemon is exiting either way.
    pub fn shutdown(&mut self, reason: &str) -> HandleOutcome {
        let mut out = HandleOutcome { lines: Vec::new(), exit: true };
        self.flush_notes(&mut out);
        if let Err(e) = self.finalize() {
            out.lines.push(proto::err(None, proto::E_INTERNAL, &format!("shutdown: {e:#}")));
        }
        out.lines.push(proto::event_shutdown(self.ctx.now(), reason));
        out
    }

    // ------------------------------------------------------- dispatch

    fn dispatch(&mut self, req: Request, out: &mut HandleOutcome) -> Result<()> {
        match req {
            Request::Submit(s) => self.submit(s, out),
            Request::Cancel { id } => self.cancel(id, out),
            Request::Query { id } => {
                self.query(id, out);
                Ok(())
            }
            Request::Advance { to, dt } => self.advance(to, dt, out),
            Request::Snapshot { path } => {
                self.snapshot_req(path, out);
                Ok(())
            }
            Request::Drain => self.drain(out),
        }
    }

    fn submit(&mut self, s: SubmitReq, out: &mut HandleOutcome) -> Result<()> {
        if self.draining {
            out.lines.push(proto::err(
                Some("submit"),
                proto::E_DRAINING,
                "daemon is draining; no new submissions",
            ));
            return Ok(());
        }
        if self.ext2int.contains_key(&s.id) {
            out.lines.push(proto::err(
                Some("submit"),
                proto::E_DUPLICATE_ID,
                &format!("job id {} was already submitted", s.id),
            ));
            return Ok(());
        }
        if s.gpus == 0 || s.iterations == 0 || s.batch == 0 {
            out.lines.push(proto::err(
                Some("submit"),
                proto::E_BAD_REQUEST,
                "gpus, iterations, and batch must all be > 0",
            ));
            return Ok(());
        }
        if !(s.est_factor.is_finite() && s.est_factor > 0.0) {
            out.lines.push(proto::err(
                Some("submit"),
                proto::E_BAD_REQUEST,
                &format!("est_factor {} must be finite and > 0", s.est_factor),
            ));
            return Ok(());
        }
        let now = self.ctx.now();
        let arrival = match s.arrival_s {
            None => now,
            Some(a) if !a.is_finite() || a < now - 1e-9 => {
                out.lines.push(proto::err(
                    Some("submit"),
                    proto::E_BAD_REQUEST,
                    &format!("arrival_s {a} is in the past (now = {now})"),
                ));
                return Ok(());
            }
            Some(a) => a.max(now),
        };
        // The engine's up-front feasibility screen, per job instead of
        // per trace: a gang that can never place must not sit in the
        // queue forever.
        let total = self.ctx.cluster.total_gpus();
        if s.gpus > total {
            out.lines.push(proto::err(
                Some("submit"),
                proto::E_INFEASIBLE,
                &format!("job wants {} GPUs but the cluster has {total}", s.gpus),
            ));
            return Ok(());
        }
        let spec = JobSpec {
            id: self.ctx.jobs.len(),
            model: s.model,
            gpus: s.gpus,
            iterations: s.iterations,
            batch: s.batch,
            arrival_s: arrival,
            est_factor: s.est_factor,
        };
        let floor_gb = spec.profile().mem.mem_gb(1.0);
        let hosts = (0..total).filter(|&g| self.ctx.cluster.mem_gb(g) + 1e-9 >= floor_gb).count();
        if hosts < s.gpus {
            out.lines.push(proto::err(
                Some("submit"),
                proto::E_INFEASIBLE,
                &format!(
                    "only {hosts} GPUs have the {floor_gb:.1} GB this job needs (wants {})",
                    s.gpus
                ),
            ));
            return Ok(());
        }
        // Backpressure: bound the jobs the scheduler is holding but not
        // running (queued + not-yet-arrived).
        let queued = self.ctx.unfinished() - self.ctx.running().len();
        if queued >= self.cfg.max_pending {
            out.lines.push(proto::event_rejected(now, s.id, proto::E_BUSY));
            out.lines.push(proto::err(
                Some("submit"),
                proto::E_BUSY,
                &format!(
                    "pending queue is full ({queued} >= --max-pending {})",
                    self.cfg.max_pending
                ),
            ));
            return Ok(());
        }
        self.ext2int.insert(s.id, spec.id);
        self.notes.int2ext.push(s.id);
        self.ctx.admit_job(spec);
        // Deliver anything due at this instant (an arrival-now fires its
        // Arrival event and possibly a start before the response).
        self.pump_to(self.ctx.now())?;
        self.maybe_snapshot()?;
        self.flush_notes(out);
        out.lines.push(proto::ok("submit", self.ctx.now(), vec![("id", Json::from(s.id))]));
        Ok(())
    }

    fn cancel(&mut self, ext: u64, out: &mut HandleOutcome) -> Result<()> {
        let Some(&int) = self.ext2int.get(&ext) else {
            out.lines.push(proto::err(
                Some("cancel"),
                proto::E_UNKNOWN_JOB,
                &format!("no job with id {ext}"),
            ));
            return Ok(());
        };
        if self.ctx.jobs[int].state == JobState::Finished {
            let what = if self.cancelled.contains(&int) { "cancelled" } else { "completed" };
            out.lines.push(proto::err(
                Some("cancel"),
                proto::E_FINISHED,
                &format!("job {ext} already {what}"),
            ));
            return Ok(());
        }
        let was_running = self.ctx.jobs[int].state == JobState::Running;
        self.ctx.cancel_job(int);
        self.cancelled.insert(int);
        if was_running {
            // The freed GPUs have no natural event to react to: nudge
            // the policy with one synthetic Tick at the same instant.
            self.pump.kick(&mut self.ctx, self.policy.as_mut(), &mut self.notes)?;
        }
        self.flush_notes(out);
        out.lines.push(proto::ok("cancel", self.ctx.now(), vec![("id", Json::from(ext))]));
        Ok(())
    }

    fn query(&self, id: Option<u64>, out: &mut HandleOutcome) {
        let now = self.ctx.now();
        match id {
            Some(ext) => {
                let Some(&int) = self.ext2int.get(&ext) else {
                    out.lines.push(proto::err(
                        Some("query"),
                        proto::E_UNKNOWN_JOB,
                        &format!("no job with id {ext}"),
                    ));
                    return;
                };
                out.lines.push(proto::ok("query", now, vec![("job", self.job_json(int))]));
            }
            None => {
                let total = self.ctx.cluster.total_gpus();
                let busy = total - self.ctx.cluster.free_count();
                let shared = busy - self.ctx.cluster.one_job_count();
                let completed = self
                    .ctx
                    .jobs
                    .iter()
                    .enumerate()
                    .filter(|(i, r)| {
                        r.state == JobState::Finished && !self.cancelled.contains(i)
                    })
                    .count();
                out.lines.push(proto::ok(
                    "query",
                    now,
                    vec![
                        ("policy", Json::from(self.cfg.policy.as_str())),
                        ("draining", Json::from(self.draining)),
                        ("max_pending", Json::from(self.cfg.max_pending)),
                        ("jobs", Json::from(self.ctx.jobs.len())),
                        ("running", Json::from(self.ctx.running().len())),
                        ("waiting", Json::from(self.ctx.waiting().len())),
                        ("pending", Json::from(self.ctx.pending().len())),
                        ("completed", Json::from(completed)),
                        ("cancelled", Json::from(self.cancelled.len())),
                        ("busy_gpus", Json::from(busy)),
                        ("shared_gpus", Json::from(shared)),
                        ("total_gpus", Json::from(total)),
                        ("busy_gpu_s", Json::Num(self.ctx.busy_gpu_s())),
                        ("shared_gpu_s", Json::Num(self.ctx.shared_gpu_s())),
                        ("policy_calls", Json::from(self.pump.policy_calls())),
                        ("preemptions", Json::from(self.pump.preemptions())),
                    ],
                ));
            }
        }
    }

    fn job_json(&self, int: JobId) -> Json {
        let rec = &self.ctx.jobs[int];
        let status = if self.cancelled.contains(&int) {
            "cancelled"
        } else {
            match rec.state {
                JobState::Pending => "pending",
                JobState::Running => "running",
                JobState::Preempted => "preempted",
                JobState::Finished => "completed",
            }
        };
        proto::jobj(vec![
            ("id", Json::from(self.notes.int2ext[int])),
            ("status", Json::from(status)),
            ("model", Json::from(rec.spec.model.name())),
            ("gpus", Json::from(rec.spec.gpus)),
            ("iterations", Json::from(rec.spec.iterations)),
            ("batch", Json::from(rec.spec.batch as u64)),
            ("arrival_s", Json::Num(rec.spec.arrival_s)),
            ("remaining_iters", Json::Num(self.ctx.remaining_iters(int))),
            ("accum_step", Json::from(rec.accum_step as u64)),
            ("gpus_held", Json::Arr(rec.gpus_held.iter().map(|&g| Json::from(g)).collect())),
            ("first_start_s", opt_num(rec.first_start_s)),
            ("finish_s", opt_num(rec.finish_s)),
            ("queued_s", Json::Num(self.ctx.queued_seconds(int))),
            ("jct_s", opt_num(rec.jct())),
            ("service_gpu_s", Json::Num(self.ctx.attained_service(int))),
        ])
    }

    fn advance(&mut self, to: Option<f64>, dt: Option<f64>, out: &mut HandleOutcome) -> Result<()> {
        if self.cfg.time_compression.is_some() {
            out.lines.push(proto::err(
                Some("advance"),
                proto::E_BAD_REQUEST,
                "advance is only valid under the virtual clock (daemon runs --time-compression)",
            ));
            return Ok(());
        }
        let now = self.ctx.now();
        let target = match (to, dt) {
            (Some(t), None) => t,
            (None, Some(d)) => now + d,
            _ => {
                out.lines.push(proto::err(
                    Some("advance"),
                    proto::E_BAD_REQUEST,
                    "advance needs exactly one of \"to\" or \"dt\"",
                ));
                return Ok(());
            }
        };
        if !target.is_finite() || target < now - 1e-9 {
            out.lines.push(proto::err(
                Some("advance"),
                proto::E_BAD_REQUEST,
                &format!("advance target {target} is before now ({now}) or not finite"),
            ));
            return Ok(());
        }
        if target > self.cfg.max_sim_s {
            out.lines.push(proto::err(
                Some("advance"),
                proto::E_BAD_REQUEST,
                &format!("advance target {target} exceeds the sim horizon {}", self.cfg.max_sim_s),
            ));
            return Ok(());
        }
        self.pump_to(target.max(now))?;
        self.maybe_snapshot()?;
        self.flush_notes(out);
        out.lines.push(proto::ok("advance", self.ctx.now(), vec![]));
        Ok(())
    }

    fn snapshot_req(&mut self, path: Option<String>, out: &mut HandleOutcome) {
        let path = path.map(std::path::PathBuf::from).or_else(|| self.cfg.snapshot.clone());
        let Some(path) = path else {
            out.lines.push(proto::err(
                Some("snapshot"),
                proto::E_BAD_REQUEST,
                "no snapshot path: pass \"path\" or start the daemon with --snapshot PATH",
            ));
            return;
        };
        match snapshot::write(self, &path) {
            Ok(()) => out.lines.push(proto::ok(
                "snapshot",
                self.ctx.now(),
                vec![("path", Json::Str(path.display().to_string()))],
            )),
            Err(e) => {
                out.lines.push(proto::err(Some("snapshot"), proto::E_INTERNAL, &format!("{e:#}")))
            }
        }
    }

    /// Stop admitting, fast-forward the clock until every admitted job
    /// is finished (future arrivals still land and run), write the final
    /// snapshot, flush the sinks, and exit. Works under both clocks —
    /// drain is the "finish what you took and stop" path, so it does not
    /// wait for wall time.
    fn drain(&mut self, out: &mut HandleOutcome) -> Result<()> {
        self.draining = true;
        while !self.ctx.all_finished() {
            let mut t_next = f64::INFINITY;
            let next_finish = self.ctx.next_finish();
            for t in
                [self.ctx.next_arrival(), next_finish, self.ctx.next_restart(), self.pump.next_tick()]
            {
                if let Some(t) = t {
                    if t < t_next {
                        t_next = t;
                    }
                }
            }
            if !t_next.is_finite() {
                self.flush_notes(out);
                out.lines.push(proto::err(
                    Some("drain"),
                    proto::E_DEADLOCK,
                    &format!(
                        "{} unfinished job(s) but no future events — cannot drain",
                        self.ctx.unfinished()
                    ),
                ));
                return self.exit_after_drain(out);
            }
            if t_next > self.cfg.max_sim_s {
                self.flush_notes(out);
                out.lines.push(proto::err(
                    Some("drain"),
                    proto::E_DEADLOCK,
                    &format!(
                        "drain passed the sim horizon ({} s) with {} job(s) unfinished",
                        self.cfg.max_sim_s,
                        self.ctx.unfinished()
                    ),
                ));
                return self.exit_after_drain(out);
            }
            let target = t_next.max(self.ctx.now());
            self.pump_to(target)?;
            self.maybe_snapshot()?;
        }
        self.flush_notes(out);
        let completed = self.ctx.jobs.len() - self.cancelled.len();
        let counts = vec![
            ("completed", Json::from(completed)),
            ("cancelled", Json::from(self.cancelled.len())),
        ];
        if let Err(e) = self.finalize() {
            out.lines.push(proto::err(None, proto::E_INTERNAL, &format!("finalize: {e:#}")));
        }
        out.lines.push(proto::ok("drain", self.ctx.now(), counts));
        out.exit = true;
        Ok(())
    }

    fn exit_after_drain(&mut self, out: &mut HandleOutcome) -> Result<()> {
        if let Err(e) = self.finalize() {
            out.lines.push(proto::err(None, proto::E_INTERNAL, &format!("finalize: {e:#}")));
        }
        out.exit = true;
        Ok(())
    }

    // ------------------------------------------------------ internals

    fn pump_to(&mut self, target: f64) -> Result<()> {
        self.pump.pump_sim(
            &mut self.ctx,
            self.policy.as_mut(),
            target,
            self.cfg.eps_iters,
            &mut self.notes,
        )
    }

    fn flush_notes(&mut self, out: &mut HandleOutcome) {
        out.lines.append(&mut self.notes.lines);
    }

    /// Snapshot cadence: after any clock movement, write the configured
    /// snapshot if the next due instant has passed (and checkpoint the
    /// obskit sinks with it, so a crash loses at most one interval).
    fn maybe_snapshot(&mut self) -> Result<()> {
        let Some(path) = self.cfg.snapshot.clone() else {
            return Ok(());
        };
        if self.ctx.now() + 1e-9 >= self.next_snapshot_s {
            snapshot::write(self, &path)?;
            self.ctx.obs().flush()?;
            self.next_snapshot_s = self.ctx.now() + self.cfg.snapshot_every_s;
        }
        Ok(())
    }

    /// Final snapshot (if configured) + obskit sink flush. The owner of
    /// the [`Obs`] handle (the CLI) still runs `finish` afterwards.
    fn finalize(&mut self) -> Result<()> {
        if let Some(path) = self.cfg.snapshot.clone() {
            snapshot::write(self, &path)?;
        }
        self.ctx.obs().flush()
    }
}

pub(super) fn opt_num(v: Option<f64>) -> Json {
    v.map(Json::Num).unwrap_or(Json::Null)
}

//! servekit — the long-running scheduler daemon (DESIGN.md §14).
//!
//! The batch entry points (`simulate`, `campaign`) own a whole trace up
//! front; `serve` instead keeps the event-driven core — a
//! [`SchedContext`] plus one [`Policy`] driven through the shared
//! [`EventPump`] — alive behind a line-JSON protocol so jobs are
//! *ingested live*: `submit` / `cancel` / `query` / `advance` /
//! `snapshot` / `drain` requests on stdin (or one TCP client with
//! `--listen ADDR`), streamed `started` / `completed` / `rejected`
//! notifications interleaved on the way out.
//!
//! Layout:
//! * [`proto`]    — request parsing, response/event emission, error codes.
//! * [`daemon`]   — the [`Daemon`]: admission control with backpressure,
//!                  request dispatch, the drain loop, graceful shutdown.
//! * [`snapshot`] — crash-recovery snapshots (atomic temp-file rename)
//!                  and `--resume` restore.
//! * [`load`]     — `serve-load`: replays a workload-v2 preset as live
//!                  traffic against an in-process daemon and reports
//!                  end-to-end latency percentiles.
//!
//! Two clocks: by default the daemon is *virtual* — sim time moves only
//! when a client says `advance` (or `drain` fast-forwards to
//! completion), which is what the conformance tests and `serve-load`
//! use, and what makes sessions deterministic. With `--time-compression
//! X` the daemon pins sim time to `wall_elapsed × X` between requests
//! instead, the same compression contract as `physical --compress`.
//!
//! [`SchedContext`]: crate::sched_core::SchedContext
//! [`Policy`]: crate::sched_core::Policy
//! [`EventPump`]: crate::sched_core::EventPump

pub mod daemon;
pub mod load;
pub mod proto;
pub mod snapshot;

pub use daemon::{Daemon, HandleOutcome};
pub use load::{LoadConfig, LoadOutcome};

use std::io::Write as _;
use std::path::PathBuf;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::cluster::{topology, Cluster, ClusterConfig};
use crate::sim::engine::EngineConfig;

/// Which cluster the daemon schedules onto, in a form that can be
/// serialized into a snapshot (`tag`) and rebuilt on resume
/// (`parse_tag` + `build`). Mirrors the `--cluster` / `--topology`
/// flag pair of the batch subcommands.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClusterSpec {
    /// A flat preset name: `"simulation"` (16×4) or `"physical"` (4×4).
    Preset(String),
    /// A named topology shape, e.g. `"uniform-16x4-nvlink"`.
    Topology(String),
}

impl ClusterSpec {
    fn preset_checked(name: &str) -> Result<ClusterSpec> {
        match name {
            "physical" | "simulation" => Ok(ClusterSpec::Preset(name.to_string())),
            other => bail!("unknown cluster preset {other:?} (known: physical, simulation)"),
        }
    }

    /// Resolve the mutually exclusive `--topology` / `--cluster` flags
    /// (same rules as the batch subcommands; default: `simulation`).
    pub fn from_args(topo: Option<&str>, cluster: Option<&str>) -> Result<ClusterSpec> {
        match (topo, cluster) {
            (Some(_), Some(_)) => bail!("--topology and --cluster are mutually exclusive"),
            (Some(shape), None) => {
                topology::by_name_or_err(shape)?; // validate the name now
                Ok(ClusterSpec::Topology(shape.to_string()))
            }
            (None, name) => ClusterSpec::preset_checked(name.unwrap_or("simulation")),
        }
    }

    pub fn build(&self) -> Result<Cluster> {
        match self {
            ClusterSpec::Preset(name) => Ok(Cluster::new(match name.as_str() {
                "physical" => ClusterConfig::physical(),
                "simulation" => ClusterConfig::simulation(),
                other => bail!("unknown cluster preset {other:?}"),
            })),
            ClusterSpec::Topology(shape) => {
                Ok(Cluster::with_topology(topology::by_name_or_err(shape)?))
            }
        }
    }

    /// The snapshot-stable spelling.
    pub fn tag(&self) -> String {
        match self {
            ClusterSpec::Preset(n) => format!("preset:{n}"),
            ClusterSpec::Topology(s) => format!("topology:{s}"),
        }
    }

    pub fn parse_tag(tag: &str) -> Result<ClusterSpec> {
        match tag.split_once(':') {
            Some(("preset", n)) => ClusterSpec::preset_checked(n),
            Some(("topology", s)) => {
                topology::by_name_or_err(s)?;
                Ok(ClusterSpec::Topology(s.to_string()))
            }
            _ => bail!("bad cluster tag {tag:?} (want preset:NAME or topology:SHAPE)"),
        }
    }
}

/// Daemon configuration (the `serve` flags, snapshot-serializable).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub policy: String,
    pub cluster: ClusterSpec,
    /// `Some(ξ)` forces the global interference factor (the `--xi`
    /// flag); `None` uses the calibrated pairwise model.
    pub xi_global: Option<f64>,
    /// Admission-control bound: a submit that would leave more than
    /// this many unfinished-and-not-running jobs is rejected `busy`.
    pub max_pending: usize,
    /// `Some(X)` = wall-clock mode: sim time tracks `wall_elapsed × X`.
    /// `None` = virtual: time moves only on `advance` / `drain`.
    pub time_compression: Option<f64>,
    /// Crash-recovery snapshot path; `None` disables snapshots.
    pub snapshot: Option<PathBuf>,
    /// Snapshot cadence in sim-seconds (checked after each advance).
    pub snapshot_every_s: f64,
    /// Hard sim-time horizon for `drain` (the engine's stall guard).
    pub max_sim_s: f64,
    /// Completion epsilon in iterations (the engine's).
    pub eps_iters: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let e = EngineConfig::default();
        ServeConfig {
            policy: "SJF-BSBF".to_string(),
            cluster: ClusterSpec::Preset("simulation".to_string()),
            xi_global: None,
            max_pending: 64,
            time_compression: None,
            snapshot: None,
            snapshot_every_s: 300.0,
            max_sim_s: e.max_sim_s,
            eps_iters: e.eps_iters,
        }
    }
}

/// SIGINT/SIGTERM latch. No libc in the vendored set, so the handler is
/// registered through the raw C `signal` entry point; the handler only
/// sets an atomic flag, which the serve loop polls between requests.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static SHUTDOWN: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_signal(_sig: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }

    extern "C" {
        // C: void (*signal(int, void (*)(int)))(int). Passing the
        // handler as a typed fn pointer keeps this cast-free; the
        // returned previous handler is opaque to us.
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }

    pub fn pending() -> bool {
        SHUTDOWN.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sig {
    pub fn install() {}
    pub fn pending() -> bool {
        false
    }
}

/// Feed lines from `r` into a channel. A dedicated thread because the
/// raw `signal(2)` registration leaves SA_RESTART semantics in place, so
/// a blocking stdin read would never observe the shutdown latch; the
/// serve loop polls the channel with a short timeout instead.
fn spawn_reader<R>(r: R) -> Receiver<String>
where
    R: std::io::BufRead + Send + 'static,
{
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        for line in r.lines() {
            let Ok(line) = line else { return };
            if tx.send(line).is_err() {
                return;
            }
        }
    });
    rx
}

fn write_lines(w: &mut dyn std::io::Write, lines: &[String]) -> Result<()> {
    for line in lines {
        writeln!(w, "{line}").context("writing a response line")?;
    }
    w.flush().context("flushing responses")
}

fn serve_loop(
    mut daemon: Daemon,
    rx: Receiver<String>,
    mut out: impl std::io::Write,
) -> Result<()> {
    loop {
        if sig::pending() {
            let o = daemon.shutdown("signal");
            write_lines(&mut out, &o.lines)?;
            return Ok(());
        }
        let clock_lines = daemon.poll_clock()?;
        write_lines(&mut out, &clock_lines)?;
        match rx.recv_timeout(Duration::from_millis(5)) {
            Ok(line) => {
                let o = daemon.handle_line(&line);
                write_lines(&mut out, &o.lines)?;
                if o.exit {
                    return Ok(());
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                // Client closed its end (EOF): same graceful path as a
                // signal — final snapshot, flushed sinks, shutdown event.
                let o = daemon.shutdown("eof");
                write_lines(&mut out, &o.lines)?;
                return Ok(());
            }
        }
    }
}

/// Run `daemon` to termination: stdin/stdout line protocol by default,
/// or one accepted TCP client with `listen = Some(addr)`. Returns after
/// `drain`, client EOF, or SIGINT/SIGTERM — all of which write the final
/// snapshot (if configured) and flush the obskit sinks first.
pub fn run(daemon: Daemon, listen: Option<&str>) -> Result<()> {
    sig::install();
    match listen {
        None => {
            let rx = spawn_reader(std::io::BufReader::new(std::io::stdin()));
            serve_loop(daemon, rx, std::io::stdout())
        }
        Some(addr) => {
            let listener = std::net::TcpListener::bind(addr)
                .with_context(|| format!("binding --listen {addr}"))?;
            eprintln!("serve: listening on {}", listener.local_addr()?);
            let (stream, peer) = listener.accept().context("accepting a client")?;
            eprintln!("serve: client {peer} connected");
            let reader = stream.try_clone().context("cloning the client stream")?;
            let rx = spawn_reader(std::io::BufReader::new(reader));
            serve_loop(daemon, rx, stream)
        }
    }
}

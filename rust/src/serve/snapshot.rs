//! Crash-recovery snapshots: the daemon's full protocol-visible state as
//! one schema-versioned JSON document, written atomically (temp file +
//! rename, so a crash mid-write leaves the previous snapshot intact) and
//! restored by `serve --resume`.
//!
//! What round-trips: every job spec and its live fields
//! (state/remaining/penalty/service), the clock, the accounting
//! integrals, the pump's delivery counters and pending tick, the
//! external-id mapping, the cancelled set, and the daemon config. The
//! scheduler caches are *not* serialized —
//! [`SchedContext::from_state`] rebuilds them, and [`util::json`]'s
//! shortest-round-trip float emission makes the restore bit-exact, which
//! is what lets the conformance tests demand byte-identical `query`
//! output across a snapshot → resume cycle.
//!
//! Policy internals (Tiresias queue levels, held SJF-BSBF pairings) are
//! deliberately out of scope: every shipped policy recomputes from
//! context state on the next event, so a resumed run re-converges — the
//! replay-equivalence test in `rust/tests/serve.rs` pins this for the
//! non-preemptive policies.
//!
//! [`SchedContext::from_state`]: crate::sched_core::SchedContext::from_state
//! [`util::json`]: crate::util::json

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::jobs::{JobRecord, JobSpec, JobState};
use crate::obskit::Obs;
use crate::perf::interference::InterferenceModel;
use crate::perf::profiles::ModelKind;
use crate::sched;
use crate::sched_core::{EventPump, SchedContext};
use crate::sim::SimState;
use crate::util::json::Json;

use super::daemon::{opt_num, Daemon, Notifier};
use super::proto::jobj;
use super::{ClusterSpec, ServeConfig};

/// Schema tag of the snapshot document.
pub const SNAPSHOT_SCHEMA: &str = "wise-share-serve-snapshot-v1";

fn state_str(s: JobState) -> &'static str {
    match s {
        JobState::Pending => "pending",
        JobState::Running => "running",
        JobState::Preempted => "preempted",
        JobState::Finished => "finished",
    }
}

fn state_from(s: &str) -> Result<JobState> {
    Ok(match s {
        "pending" => JobState::Pending,
        "running" => JobState::Running,
        "preempted" => JobState::Preempted,
        "finished" => JobState::Finished,
        other => bail!("snapshot names unknown job state {other:?}"),
    })
}

fn render(d: &Daemon) -> Json {
    let jobs = Json::Arr(
        d.ctx
            .jobs
            .iter()
            .enumerate()
            .map(|(id, rec)| {
                jobj(vec![
                    ("ext_id", Json::from(d.notes.int2ext[id])),
                    ("model", Json::from(rec.spec.model.name())),
                    ("gpus", Json::from(rec.spec.gpus)),
                    ("iterations", Json::from(rec.spec.iterations)),
                    ("batch", Json::from(rec.spec.batch as u64)),
                    ("arrival_s", Json::Num(rec.spec.arrival_s)),
                    ("est_factor", Json::Num(rec.spec.est_factor)),
                    ("state", Json::from(state_str(rec.state))),
                    // Accessor reads, not the raw fields: lazily
                    // integrated quantities are folded to `now`, so the
                    // resumed context (which anchors everything at `now`)
                    // continues from exactly what was serialized.
                    ("remaining_iters", Json::Num(d.ctx.remaining_iters(id))),
                    ("accum_step", Json::from(rec.accum_step as u64)),
                    ("first_start_s", opt_num(rec.first_start_s)),
                    ("finish_s", opt_num(rec.finish_s)),
                    ("queued_s", Json::Num(d.ctx.queued_seconds(id))),
                    (
                        "gpus_held",
                        Json::Arr(rec.gpus_held.iter().map(|&g| Json::from(g)).collect()),
                    ),
                    ("not_before", Json::Num(d.ctx.not_before[id])),
                    ("service_gpu_s", Json::Num(d.ctx.attained_service(id))),
                    ("cancelled", Json::from(d.cancelled.contains(&id))),
                ])
            })
            .collect(),
    );
    jobj(vec![
        ("schema", Json::from(SNAPSHOT_SCHEMA)),
        ("policy", Json::from(d.cfg.policy.as_str())),
        ("cluster", Json::Str(d.cfg.cluster.tag())),
        ("xi_global", opt_num(d.cfg.xi_global)),
        ("max_pending", Json::from(d.cfg.max_pending)),
        ("time_compression", opt_num(d.cfg.time_compression)),
        ("snapshot_every_s", Json::Num(d.cfg.snapshot_every_s)),
        ("draining", Json::from(d.draining)),
        ("now", Json::Num(d.ctx.now())),
        ("busy_gpu_s", Json::Num(d.ctx.busy_gpu_s())),
        ("shared_gpu_s", Json::Num(d.ctx.shared_gpu_s())),
        ("policy_calls", Json::from(d.pump.policy_calls())),
        ("preemptions", Json::from(d.pump.preemptions())),
        ("next_tick", opt_num(d.pump.next_tick())),
        ("next_snapshot_s", Json::Num(d.next_snapshot_s)),
        ("jobs", jobs),
    ])
}

/// Atomically write `d`'s snapshot to `path`: the document lands in
/// `<path>.tmp` first and is renamed over the target, so readers (and a
/// crash between the two syscalls) only ever see a complete document.
pub(super) fn write(d: &Daemon, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    let tmp = PathBuf::from(format!("{}.tmp", path.display()));
    fs::write(&tmp, render(d).to_string() + "\n")
        .with_context(|| format!("writing {}", tmp.display()))?;
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))
}

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).with_context(|| format!("snapshot field {key:?} is missing"))
}

fn req_str<'a>(j: &'a Json, key: &str) -> Result<&'a str> {
    req(j, key)?.as_str().with_context(|| format!("snapshot field {key:?} must be a string"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    req(j, key)?.as_f64().with_context(|| format!("snapshot field {key:?} must be a number"))
}

fn req_u64(j: &Json, key: &str) -> Result<u64> {
    req(j, key)?
        .as_u64()
        .with_context(|| format!("snapshot field {key:?} must be a non-negative integer"))
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    req(j, key)?
        .as_usize()
        .with_context(|| format!("snapshot field {key:?} must be a non-negative integer"))
}

fn req_bool(j: &Json, key: &str) -> Result<bool> {
    req(j, key)?.as_bool().with_context(|| format!("snapshot field {key:?} must be a bool"))
}

/// `null` (or absent) → `None`.
fn opt_f64(j: &Json, key: &str) -> Option<f64> {
    j.get(key).and_then(|v| v.as_f64())
}

/// Restore a daemon from the snapshot at `path`. Config (policy,
/// cluster, ξ, limits) is inherited from the document; future snapshots
/// go to `snapshot_to` when given, else back onto `path`, so an
/// untouched `serve --resume PATH` keeps checkpointing where it left
/// off.
pub(super) fn resume(path: &Path, snapshot_to: Option<PathBuf>, obs: Obs) -> Result<Daemon> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading snapshot {}", path.display()))?;
    let j = Json::parse(&text)
        .with_context(|| format!("parsing snapshot {}", path.display()))?;
    match j.get("schema").and_then(|s| s.as_str()) {
        Some(SNAPSHOT_SCHEMA) => {}
        other => bail!(
            "snapshot {}: unsupported schema {other:?} (want {SNAPSHOT_SCHEMA:?})",
            path.display()
        ),
    }
    let cfg = ServeConfig {
        policy: req_str(&j, "policy")?.to_string(),
        cluster: ClusterSpec::parse_tag(req_str(&j, "cluster")?)?,
        xi_global: opt_f64(&j, "xi_global"),
        max_pending: req_usize(&j, "max_pending")?,
        time_compression: opt_f64(&j, "time_compression"),
        snapshot: snapshot_to.or_else(|| Some(path.to_path_buf())),
        snapshot_every_s: req_f64(&j, "snapshot_every_s")?,
        ..ServeConfig::default()
    };
    let mut cluster = cfg.cluster.build()?;
    let jobs_j =
        req(&j, "jobs")?.as_arr().context("snapshot field \"jobs\" must be an array")?;
    let mut jobs: Vec<JobRecord> = Vec::with_capacity(jobs_j.len());
    let mut not_before = Vec::with_capacity(jobs_j.len());
    let mut service_gpu_s = Vec::with_capacity(jobs_j.len());
    let mut int2ext = Vec::with_capacity(jobs_j.len());
    let mut ext2int = BTreeMap::new();
    let mut cancelled = BTreeSet::new();
    for (id, jj) in jobs_j.iter().enumerate() {
        let ctx_of = |e: anyhow::Error| e.context(format!("snapshot job {id}"));
        let ext = req_u64(jj, "ext_id").map_err(ctx_of)?;
        let model_name = req_str(jj, "model")?;
        let model = ModelKind::from_name(model_name)
            .with_context(|| format!("snapshot job {id}: unknown model {model_name:?}"))?;
        let spec = JobSpec {
            id,
            model,
            gpus: req_usize(jj, "gpus")?,
            iterations: req_u64(jj, "iterations")?,
            batch: req_u64(jj, "batch")? as u32,
            arrival_s: req_f64(jj, "arrival_s")?,
            est_factor: req_f64(jj, "est_factor")?,
        };
        let mut rec = JobRecord::new(spec);
        rec.state = state_from(req_str(jj, "state")?)?;
        rec.remaining_iters = req_f64(jj, "remaining_iters")?;
        rec.accum_step = req_u64(jj, "accum_step")? as u32;
        rec.first_start_s = opt_f64(jj, "first_start_s");
        rec.finish_s = opt_f64(jj, "finish_s");
        rec.queued_s = req_f64(jj, "queued_s")?;
        rec.gpus_held = req(jj, "gpus_held")?
            .as_arr()
            .context("gpus_held must be an array")?
            .iter()
            .map(|g| g.as_usize().context("gpus_held entries must be integers"))
            .collect::<Result<Vec<_>>>()?;
        if rec.state == JobState::Running {
            cluster.allocate(id, &rec.gpus_held);
        }
        if req_bool(jj, "cancelled")? {
            cancelled.insert(id);
        }
        not_before.push(req_f64(jj, "not_before")?);
        service_gpu_s.push(req_f64(jj, "service_gpu_s")?);
        if ext2int.insert(ext, id).is_some() {
            bail!("snapshot job {id}: duplicate ext_id {ext}");
        }
        int2ext.push(ext);
        jobs.push(rec);
    }
    let xi = match cfg.xi_global {
        Some(x) => InterferenceModel::with_global(x),
        None => InterferenceModel::new(),
    };
    let state = SimState {
        now: req_f64(&j, "now")?,
        cluster,
        jobs,
        xi,
        not_before,
        service_gpu_s,
    };
    let mut ctx = SchedContext::from_state(state);
    ctx.set_obs(obs);
    ctx.restore_accounting(req_f64(&j, "busy_gpu_s")?, req_f64(&j, "shared_gpu_s")?);
    let policy = sched::by_name(&cfg.policy)
        .with_context(|| format!("snapshot names unknown policy {:?}", cfg.policy))?;
    let mut pump = EventPump::new(policy.as_ref());
    pump.restore(
        req_u64(&j, "policy_calls")?,
        req_u64(&j, "preemptions")?,
        opt_f64(&j, "next_tick"),
    );
    Ok(Daemon {
        cfg,
        ctx,
        policy,
        pump,
        notes: Notifier::new(int2ext),
        ext2int,
        cancelled,
        draining: req_bool(&j, "draining")?,
        next_snapshot_s: req_f64(&j, "next_snapshot_s")?,
        started_wall: None,
    })
}

//! The serve daemon's line-JSON protocol: request parsing and
//! response/notification emission.
//!
//! One request per line on the way in, one JSON document per line on the
//! way out. Every outbound line carries a `"type"`: `"response"` answers
//! exactly one request (`"ok": true` plus op-specific fields, or
//! `"ok": false` with a machine-readable `"code"` and a human `"error"`),
//! `"event"` is a streamed notification (`started` / `completed` /
//! `rejected` / `shutdown`). Within one request's output, notifications
//! are emitted first and the response last, so a client that reads until
//! the response has also seen every event the request caused.
//!
//! Requests (`"op"` selects): `submit` (model/gpus/iterations/batch,
//! optional arrival_s/est_factor, client-chosen numeric `id`), `cancel`,
//! `query` (one job by `id`, or the cluster summary), `advance` (virtual
//! clock only: `dt` or absolute `to`), `snapshot` (optional `path`
//! override), `drain`.

use crate::perf::profiles::ModelKind;
use crate::util::json::Json;

/// Machine-readable error codes (the `"code"` field of a failed
/// response). Pinned by the protocol-conformance tests.
pub const E_PARSE: &str = "parse";
pub const E_UNKNOWN_OP: &str = "unknown-op";
pub const E_BAD_REQUEST: &str = "bad-request";
pub const E_DUPLICATE_ID: &str = "duplicate-id";
pub const E_UNKNOWN_JOB: &str = "unknown-job";
pub const E_FINISHED: &str = "already-finished";
pub const E_BUSY: &str = "busy";
pub const E_INFEASIBLE: &str = "infeasible";
pub const E_DRAINING: &str = "draining";
pub const E_DEADLOCK: &str = "deadlock";
pub const E_INTERNAL: &str = "internal";

/// A parsed request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Submit(SubmitReq),
    Cancel { id: u64 },
    Query { id: Option<u64> },
    Advance { to: Option<f64>, dt: Option<f64> },
    Snapshot { path: Option<String> },
    Drain,
}

/// The body of a `submit` request. `id` is the *client's* job id; the
/// daemon maps it to a dense internal [`crate::jobs::JobId`].
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitReq {
    pub id: u64,
    pub model: ModelKind,
    pub gpus: usize,
    pub iterations: u64,
    pub batch: u32,
    pub arrival_s: Option<f64>,
    pub est_factor: f64,
}

/// A structured protocol error: becomes a failed response line.
#[derive(Debug, Clone)]
pub struct ProtoError {
    pub op: Option<&'static str>,
    pub code: &'static str,
    pub msg: String,
}

impl ProtoError {
    fn new(op: Option<&'static str>, code: &'static str, msg: String) -> ProtoError {
        ProtoError { op, code, msg }
    }
}

/// Build a JSON object from `(key, value)` pairs (keys are emitted in
/// BTreeMap order — deterministic, independent of insertion order).
pub(super) fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn req_u64(j: &Json, op: &'static str, key: &str) -> Result<u64, ProtoError> {
    j.get(key).and_then(|v| v.as_u64()).ok_or_else(|| {
        ProtoError::new(
            Some(op),
            E_BAD_REQUEST,
            format!("{op} needs a non-negative integer {key:?}"),
        )
    })
}

fn opt_f64(j: &Json, op: &'static str, key: &str) -> Result<Option<f64>, ProtoError> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v.as_f64().map(Some).ok_or_else(|| {
            ProtoError::new(Some(op), E_BAD_REQUEST, format!("{op} field {key:?} must be a number"))
        }),
    }
}

/// Parse one request line. Errors carry the machine-readable code the
/// failed response must report.
pub fn parse_request(line: &str) -> Result<Request, ProtoError> {
    let j = Json::parse(line)
        .map_err(|e| ProtoError::new(None, E_PARSE, format!("malformed request JSON: {e:#}")))?;
    let Some(op) = j.get("op").and_then(|o| o.as_str()) else {
        return Err(ProtoError::new(
            None,
            E_PARSE,
            "request has no \"op\" string field".to_string(),
        ));
    };
    match op {
        "submit" => {
            let id = req_u64(&j, "submit", "id")?;
            let Some(model_name) = j.get("model").and_then(|m| m.as_str()) else {
                return Err(ProtoError::new(
                    Some("submit"),
                    E_BAD_REQUEST,
                    "submit needs a \"model\" string".to_string(),
                ));
            };
            let Some(model) = ModelKind::from_name(model_name) else {
                let known: Vec<&str> = ModelKind::ALL.iter().map(|m| m.name()).collect();
                return Err(ProtoError::new(
                    Some("submit"),
                    E_BAD_REQUEST,
                    format!("unknown model {model_name:?} (known: {})", known.join(", ")),
                ));
            };
            let gpus = req_u64(&j, "submit", "gpus")? as usize;
            let iterations = req_u64(&j, "submit", "iterations")?;
            let batch = req_u64(&j, "submit", "batch")?;
            if batch > u32::MAX as u64 {
                return Err(ProtoError::new(
                    Some("submit"),
                    E_BAD_REQUEST,
                    format!("batch {batch} exceeds u32"),
                ));
            }
            let arrival_s = opt_f64(&j, "submit", "arrival_s")?;
            let est_factor = opt_f64(&j, "submit", "est_factor")?.unwrap_or(1.0);
            Ok(Request::Submit(SubmitReq {
                id,
                model,
                gpus,
                iterations,
                batch: batch as u32,
                arrival_s,
                est_factor,
            }))
        }
        "cancel" => Ok(Request::Cancel { id: req_u64(&j, "cancel", "id")? }),
        "query" => {
            let id = match j.get("id") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_u64().ok_or_else(|| {
                    ProtoError::new(
                        Some("query"),
                        E_BAD_REQUEST,
                        "query \"id\" must be a non-negative integer".to_string(),
                    )
                })?),
            };
            Ok(Request::Query { id })
        }
        "advance" => {
            let to = opt_f64(&j, "advance", "to")?;
            let dt = opt_f64(&j, "advance", "dt")?;
            Ok(Request::Advance { to, dt })
        }
        "snapshot" => {
            let path = j.get("path").and_then(|p| p.as_str()).map(str::to_string);
            Ok(Request::Snapshot { path })
        }
        "drain" => Ok(Request::Drain),
        other => Err(ProtoError::new(
            None,
            E_UNKNOWN_OP,
            format!(
                "unknown op {other:?} (known: submit, cancel, query, advance, snapshot, drain)"
            ),
        )),
    }
}

// ----------------------------------------------------------- emission

/// A successful response: `{"type":"response","op":…,"ok":true,"t":…,…}`.
pub fn ok(op: &str, t: f64, extra: Vec<(&str, Json)>) -> String {
    let mut pairs = vec![
        ("type", Json::from("response")),
        ("op", Json::from(op)),
        ("ok", Json::from(true)),
        ("t", Json::Num(t)),
    ];
    pairs.extend(extra);
    jobj(pairs).to_string()
}

/// A failed response with a machine-readable `code`.
pub fn err(op: Option<&str>, code: &str, msg: &str) -> String {
    let mut pairs = vec![
        ("type", Json::from("response")),
        ("ok", Json::from(false)),
        ("code", Json::from(code)),
        ("error", Json::from(msg)),
    ];
    if let Some(op) = op {
        pairs.insert(1, ("op", Json::from(op)));
    }
    jobj(pairs).to_string()
}

pub fn err_line(e: &ProtoError) -> String {
    err(e.op, e.code, &e.msg)
}

/// `started` notification: the policy placed the job.
pub fn event_started(t: f64, ext_id: u64, gpus: &[usize], accum_step: u32) -> String {
    jobj(vec![
        ("type", Json::from("event")),
        ("event", Json::from("started")),
        ("id", Json::from(ext_id)),
        ("t", Json::Num(t)),
        ("gpus", Json::Arr(gpus.iter().map(|&g| Json::from(g)).collect())),
        ("accum_step", Json::from(accum_step as u64)),
    ])
    .to_string()
}

/// `completed` notification: the job ran all its iterations.
pub fn event_completed(t: f64, ext_id: u64, jct_s: Option<f64>, queued_s: f64) -> String {
    jobj(vec![
        ("type", Json::from("event")),
        ("event", Json::from("completed")),
        ("id", Json::from(ext_id)),
        ("t", Json::Num(t)),
        ("jct_s", jct_s.map(Json::Num).unwrap_or(Json::Null)),
        ("queued_s", Json::Num(queued_s)),
    ])
    .to_string()
}

/// `rejected` notification: admission control turned the submit away.
pub fn event_rejected(t: f64, ext_id: u64, code: &str) -> String {
    jobj(vec![
        ("type", Json::from("event")),
        ("event", Json::from("rejected")),
        ("id", Json::from(ext_id)),
        ("t", Json::Num(t)),
        ("code", Json::from(code)),
    ])
    .to_string()
}

/// `shutdown` notification: the daemon is exiting (`reason`: `"signal"`
/// or `"eof"`; a `drain` answers with its response instead).
pub fn event_shutdown(t: f64, reason: &str) -> String {
    jobj(vec![
        ("type", Json::from("event")),
        ("event", Json::from("shutdown")),
        ("reason", Json::from(reason)),
        ("t", Json::Num(t)),
    ])
    .to_string()
}

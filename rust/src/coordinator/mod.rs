//! Physical-mode coordinator: the online scheduling leader that runs a
//! trace for real — every scheduled job's iterations are executed as PJRT
//! train-steps by per-GPU worker threads, while the *same* [`Policy`]
//! implementations used in simulation make the sharing decisions.
//!
//! Since the `sched_core` redesign the coordinator is a thin wall-clock
//! backend over the shared scheduling core: it owns a [`SchedContext`]
//! (the same world view the simulator engine uses), translates wall time
//! into the same typed [`Event`]s (`Arrival`, `Completion`, `Tick`,
//! `RestartEligible`), and applies every policy transaction through the
//! shared, fully validated [`SchedContext::apply`] path. There is no
//! coordinator-local decision handling: an over-memory, double-start or
//! before-arrival decision fails here exactly as it would in simulation
//! (it used to be applied silently). Queueing time and attained service
//! (`service_gpu_s`, Tiresias' 2D-LAS input) accrue continuously through
//! `SchedContext::advance_wall`, matching the engine's accounting.
//!
//! Emulated-cluster semantics (DESIGN.md §3 substitution):
//! * one OS worker thread per "GPU"; a job's gang *reserves* its GPUs for
//!   scheduling purposes, and its compute runs on the gang's lead worker;
//! * C = 2 sharing is physical: the lead worker round-robins one iteration
//!   per co-located job — actual time-slicing, so interference is real
//!   wall-clock contention, not a model;
//! * `PjRtClient` is `!Send` (Rc internals), so each worker owns its own
//!   [`ArtifactSet`] compiled lazily on first use.
//!
//! Wall-clock knobs (`PhysicalConfig`) compress the trace so the 30-job
//! paper workload finishes in minutes while every layer still executes.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::cluster::{Cluster, ClusterConfig, GpuId};
use crate::jobs::{JobId, JobRecord, JobSpec};
use crate::obskit::Obs;
use crate::perf::interference::InterferenceModel;
use crate::runtime::executor::{TrainExecutor, TrainState};
use crate::runtime::ArtifactSet;
use crate::sched_core::{ApplyReport, Decision, EventPump, Policy, PumpHooks, SchedContext, Txn};

/// Physical-run tuning.
#[derive(Debug, Clone)]
pub struct PhysicalConfig {
    pub cluster: ClusterConfig,
    /// Trace arrival seconds are divided by this (e.g. 60 ⇒ a 1-minute gap
    /// becomes 1 s of wall time).
    pub time_compression: f64,
    /// Trace iteration counts are multiplied by this (≤ 1 caps wall time).
    pub iter_scale: f64,
    /// SGD learning rate.
    pub lr: f32,
    /// Artifacts directory.
    pub artifacts_dir: std::path::PathBuf,
    /// Per-GPU batch cap for execution (the emulated GPU is a CPU thread;
    /// the *scheduling* batch/sub-batch still follows the job spec).
    pub exec_batch: u32,
}

impl Default for PhysicalConfig {
    fn default() -> Self {
        PhysicalConfig {
            cluster: ClusterConfig::physical(),
            time_compression: 60.0,
            iter_scale: 0.1,
            lr: 0.5,
            artifacts_dir: ArtifactSet::default_dir(),
            exec_batch: 8,
        }
    }
}

/// One point of a job's training-loss curve.
#[derive(Debug, Clone, Copy)]
pub struct LossPoint {
    pub job: JobId,
    pub step: u64,
    pub loss: f32,
    pub wall_s: f64,
}

/// Final report of a physical run.
#[derive(Debug)]
pub struct PhysicalOutcome {
    pub jobs: Vec<JobRecord>,
    pub makespan_s: f64,
    pub loss_curves: Vec<LossPoint>,
    /// Iterations actually executed through PJRT (across all jobs).
    pub executed_iters: u64,
}

/// What a worker needs to know about an assigned job.
#[derive(Debug, Clone)]
struct Assignment {
    job: JobId,
    /// Execution accumulation step (scheduling decision, Algorithm 2).
    accum_step: u32,
    /// Per-iteration execution batch.
    batch: u32,
    seed: u64,
}

#[derive(Debug)]
struct Progress {
    job: JobId,
    step: u64,
    loss: f32,
}

/// Shared coordinator→worker assignment board.
#[derive(Debug, Default)]
struct Board {
    /// Lead-GPU → jobs it must time-slice.
    lanes: HashMap<GpuId, Vec<Assignment>>,
}

/// The coordinator's [`PumpHooks`]: translate pump-driven transitions
/// into worker lane assignments on the shared board.
struct BoardHooks<'a> {
    board: &'a Arc<Mutex<Board>>,
    exec_batch: u32,
}

impl PumpHooks for BoardHooks<'_> {
    fn completed(&mut self, _ctx: &SchedContext, job: JobId) -> Result<()> {
        let mut b = self.board.lock().unwrap();
        for lane in b.lanes.values_mut() {
            lane.retain(|a| a.job != job);
        }
        Ok(())
    }

    fn txn_applied(&mut self, _ctx: &SchedContext, txn: &Txn, _report: &ApplyReport) -> Result<()> {
        let mut b = self.board.lock().unwrap();
        for d in txn.ops() {
            if let Decision::Start { job, gpus, accum_step } = d {
                b.lanes.entry(gpus[0]).or_default().push(Assignment {
                    job: *job,
                    accum_step: *accum_step,
                    batch: self.exec_batch,
                    seed: *job as u64 * 7919 + 17,
                });
            }
        }
        Ok(())
    }
}

fn worker_loop(
    gpu: GpuId,
    board: Arc<Mutex<Board>>,
    tx: Sender<Progress>,
    cfg: PhysicalConfig,
    stop: Arc<AtomicBool>,
) {
    // Per-worker artifact set (PjRtClient is !Send, so each worker owns a
    // client). `load` only validates + opens the client; each executable
    // compiles lazily on first use, so a lead worker pays for exactly the
    // programs its jobs run (§Perf L3 fix #1 in EXPERIMENTS.md) — critical
    // on the single-core testbed where compile time is serialized.
    let set = ArtifactSet::load(cfg.artifacts_dir.clone())
        .expect("worker failed to load artifacts");
    let mut live: HashMap<JobId, (TrainState, u64)> = HashMap::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let lane: Vec<Assignment> = {
            let b = board.lock().unwrap();
            b.lanes.get(&gpu).cloned().unwrap_or_default()
        };
        if lane.is_empty() {
            std::thread::sleep(std::time::Duration::from_millis(2));
            continue;
        }
        let set_ref = &set;
        // Round-robin: one iteration per co-located job — C=2 time-slicing.
        for a in &lane {
            // Job may have been unassigned meanwhile; cheap check.
            if stop.load(Ordering::Relaxed) {
                return;
            }
            let (state, _) = match live.entry(a.job) {
                std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                std::collections::hash_map::Entry::Vacant(v) => {
                    let exec = TrainExecutor::new(set_ref, a.seed, cfg.lr);
                    match exec.init_state() {
                        Ok(st) => v.insert((st, 0)),
                        Err(e) => {
                            eprintln!("worker {gpu}: init failed: {e:#}");
                            continue;
                        }
                    }
                }
            };
            let mut exec = TrainExecutor::new(set_ref, a.seed ^ state.step, cfg.lr);
            match exec.train_step(state, a.batch, a.accum_step.min(a.batch)) {
                Ok(loss) => {
                    let _ = tx.send(Progress { job: a.job, step: state.step, loss });
                }
                Err(e) => {
                    eprintln!("worker {gpu}: train_step failed for job {}: {e:#}", a.job);
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
            }
        }
        // Drop states of jobs no longer assigned to this lane.
        let assigned: Vec<JobId> = lane.iter().map(|a| a.job).collect();
        live.retain(|j, _| assigned.contains(j));
    }
}

/// Run `trace` physically under `policy`. Non-preemptive policies only
/// (the physical coordinator does not checkpoint parameters on preemption);
/// a transaction containing a `Preempt` is rejected before it is applied.
pub fn run_physical(
    cfg: PhysicalConfig,
    trace: &[JobSpec],
    xi: InterferenceModel,
    policy: &mut dyn Policy,
) -> Result<PhysicalOutcome> {
    run_physical_obs(cfg, trace, xi, policy, Obs::disabled())
}

/// [`run_physical`] with an observability sink attached. The same taps the
/// simulator engine exposes fire here: every delivered event, every applied
/// (or rejected) transaction, and per-event policy wall latency — so the
/// §V-4 overhead claim is measurable on the *physical* backend too, where
/// latency is real wall time, not simulated. The caller owns `obs` and is
/// responsible for calling [`Obs::finish`] afterwards.
pub fn run_physical_obs(
    cfg: PhysicalConfig,
    trace: &[JobSpec],
    xi: InterferenceModel,
    policy: &mut dyn Policy,
    obs: Obs,
) -> Result<PhysicalOutcome> {
    let n_gpus = cfg.cluster.total_gpus();
    let board = Arc::new(Mutex::new(Board::default()));
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx): (Sender<Progress>, Receiver<Progress>) = channel();

    let mut workers = Vec::new();
    for g in 0..n_gpus {
        let board = Arc::clone(&board);
        let stop = Arc::clone(&stop);
        let tx = tx.clone();
        let cfg = cfg.clone();
        workers.push(std::thread::spawn(move || worker_loop(g, board, tx, cfg, stop)));
    }
    drop(tx);

    // The same scheduling context the simulator engine uses — policies and
    // decision validation run identically in both backends.
    let records: Vec<JobRecord> = trace
        .iter()
        .cloned()
        .map(|mut spec| {
            spec.arrival_s /= cfg.time_compression;
            let mut rec = JobRecord::new(spec);
            rec.remaining_iters = (rec.remaining_iters * cfg.iter_scale).max(10.0).round();
            rec
        })
        .collect();
    let mut ctx = SchedContext::new(Cluster::new(cfg.cluster), records, xi);
    ctx.set_obs(obs.clone());
    // Target iteration counts after scaling.
    let targets: Vec<f64> = ctx.jobs.iter().map(|j| j.remaining_iters).collect();
    let mut executed: Vec<u64> = vec![0; trace.len()];
    let mut loss_curves: Vec<LossPoint> = Vec::new();
    let t0 = Instant::now();

    let result = (|| -> Result<()> {
        // Tick cadence follows the compressed trace timeline: arrivals are
        // divided by `time_compression`, so a policy's tick interval is
        // too — a Tick fires after the same amount of *workload* time in
        // both backends, not 60x rarer on the wall clock. Delivery itself
        // (completions → clock events → tick, obs taps, the validated
        // apply path) lives in the shared [`EventPump`], which the serve
        // daemon drives too.
        let mut pump = EventPump::new(policy)
            .with_tick_scale(cfg.time_compression)
            .reject_preempts("physical coordinator supports non-preemptive policies only")
            .apply_context("physical coordinator rejected a policy transaction");
        let mut hooks = BoardHooks { board: &board, exec_batch: cfg.exec_batch };
        loop {
            // Wall clock drives the shared context: queueing time and
            // attained service (Tiresias' 2D-LAS input) accrue here, and
            // arrivals / restart eligibilities fire as typed events.
            pump.begin_wall(&mut ctx, t0.elapsed().as_secs_f64());
            // Apply progress reports from the workers (real execution is
            // what advances remaining_iters in physical mode) before the
            // pump collects completions against them.
            while let Ok(p) = rx.try_recv() {
                if ctx.note_progress(p.job) {
                    executed[p.job] += 1;
                    loss_curves.push(LossPoint {
                        job: p.job,
                        step: p.step,
                        loss: p.loss,
                        wall_s: ctx.now(),
                    });
                }
            }
            // Completions, clock events and the tick are delivered through
            // the shared pump; BoardHooks translates the applied decisions
            // into worker lane assignments. Delivery happens before the
            // all-finished exit so the last job's Completion reaches the
            // policy — the engine's "exactly one Completion per job"
            // guarantee holds in both backends.
            pump.finish_wall(&mut ctx, policy, &mut hooks)?;
            if ctx.all_finished() {
                return Ok(());
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
    })();

    stop.store(true, Ordering::Relaxed);
    for w in workers {
        let _ = w.join();
    }
    result?;

    let makespan = t0.elapsed().as_secs_f64();
    let state = ctx.into_state();
    // Sanity: every job ran its scaled target.
    for (id, rec) in state.jobs.iter().enumerate() {
        debug_assert!(
            executed[id] as f64 >= targets[id] - 0.5,
            "job {id} executed {} of {}",
            executed[id],
            targets[id]
        );
        debug_assert_eq!(rec.state, crate::jobs::JobState::Finished);
    }
    Ok(PhysicalOutcome {
        jobs: state.jobs,
        makespan_s: makespan,
        loss_curves,
        executed_iters: executed.iter().sum(),
    })
}

/// Write loss curves as CSV (`job,step,loss,wall_s`).
pub fn write_loss_csv(points: &[LossPoint], path: &std::path::Path) -> Result<()> {
    let mut out = String::from("job,step,loss,wall_s\n");
    for p in points {
        out.push_str(&format!("{},{},{},{:.3}\n", p.job, p.step, p.loss, p.wall_s));
    }
    std::fs::write(path, out).context("writing loss csv")
}

//! Physical-mode coordinator: the online scheduling leader that runs a
//! trace for real — every scheduled job's iterations are executed as PJRT
//! train-steps by per-GPU worker threads, while the *same* [`Policy`]
//! implementations used in simulation make the sharing decisions.
//!
//! Emulated-cluster semantics (DESIGN.md §3 substitution):
//! * one OS worker thread per "GPU"; a job's gang *reserves* its GPUs for
//!   scheduling purposes, and its compute runs on the gang's lead worker;
//! * C = 2 sharing is physical: the lead worker round-robins one iteration
//!   per co-located job — actual time-slicing, so interference is real
//!   wall-clock contention, not a model;
//! * `PjRtClient` is `!Send` (Rc internals), so each worker owns its own
//!   [`ArtifactSet`] compiled lazily on first use.
//!
//! Wall-clock knobs (`PhysicalConfig`) compress the trace so the 30-job
//! paper workload finishes in minutes while every layer still executes.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::cluster::{Cluster, ClusterConfig, GpuId};
use crate::jobs::{JobId, JobRecord, JobSpec, JobState};
use crate::perf::interference::InterferenceModel;
use crate::runtime::executor::{TrainExecutor, TrainState};
use crate::runtime::ArtifactSet;
use crate::sim::{Decision, Policy, SimState};

/// Physical-run tuning.
#[derive(Debug, Clone)]
pub struct PhysicalConfig {
    pub cluster: ClusterConfig,
    /// Trace arrival seconds are divided by this (e.g. 60 ⇒ a 1-minute gap
    /// becomes 1 s of wall time).
    pub time_compression: f64,
    /// Trace iteration counts are multiplied by this (≤ 1 caps wall time).
    pub iter_scale: f64,
    /// SGD learning rate.
    pub lr: f32,
    /// Artifacts directory.
    pub artifacts_dir: std::path::PathBuf,
    /// Per-GPU batch cap for execution (the emulated GPU is a CPU thread;
    /// the *scheduling* batch/sub-batch still follows the job spec).
    pub exec_batch: u32,
}

impl Default for PhysicalConfig {
    fn default() -> Self {
        PhysicalConfig {
            cluster: ClusterConfig::physical(),
            time_compression: 60.0,
            iter_scale: 0.1,
            lr: 0.5,
            artifacts_dir: ArtifactSet::default_dir(),
            exec_batch: 8,
        }
    }
}

/// One point of a job's training-loss curve.
#[derive(Debug, Clone, Copy)]
pub struct LossPoint {
    pub job: JobId,
    pub step: u64,
    pub loss: f32,
    pub wall_s: f64,
}

/// Final report of a physical run.
#[derive(Debug)]
pub struct PhysicalOutcome {
    pub jobs: Vec<JobRecord>,
    pub makespan_s: f64,
    pub loss_curves: Vec<LossPoint>,
    /// Iterations actually executed through PJRT (across all jobs).
    pub executed_iters: u64,
}

/// What a worker needs to know about an assigned job.
#[derive(Debug, Clone)]
struct Assignment {
    job: JobId,
    /// Execution accumulation step (scheduling decision, Algorithm 2).
    accum_step: u32,
    /// Per-iteration execution batch.
    batch: u32,
    seed: u64,
}

#[derive(Debug)]
struct Progress {
    job: JobId,
    step: u64,
    loss: f32,
}

/// Shared coordinator→worker assignment board.
#[derive(Debug, Default)]
struct Board {
    /// Lead-GPU → jobs it must time-slice.
    lanes: HashMap<GpuId, Vec<Assignment>>,
}

fn worker_loop(
    gpu: GpuId,
    board: Arc<Mutex<Board>>,
    tx: Sender<Progress>,
    cfg: PhysicalConfig,
    stop: Arc<AtomicBool>,
) {
    // Per-worker artifact set (PjRtClient is !Send, so each worker owns a
    // client). `load` only validates + opens the client; each executable
    // compiles lazily on first use, so a lead worker pays for exactly the
    // programs its jobs run (§Perf L3 fix #1 in EXPERIMENTS.md) — critical
    // on the single-core testbed where compile time is serialized.
    let set = ArtifactSet::load(cfg.artifacts_dir.clone())
        .expect("worker failed to load artifacts");
    let mut live: HashMap<JobId, (TrainState, u64)> = HashMap::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let lane: Vec<Assignment> = {
            let b = board.lock().unwrap();
            b.lanes.get(&gpu).cloned().unwrap_or_default()
        };
        if lane.is_empty() {
            std::thread::sleep(std::time::Duration::from_millis(2));
            continue;
        }
        let set_ref = &set;
        // Round-robin: one iteration per co-located job — C=2 time-slicing.
        for a in &lane {
            // Job may have been unassigned meanwhile; cheap check.
            if stop.load(Ordering::Relaxed) {
                return;
            }
            let (state, _) = match live.entry(a.job) {
                std::collections::hash_map::Entry::Occupied(e) => e.into_mut(),
                std::collections::hash_map::Entry::Vacant(v) => {
                    let exec = TrainExecutor::new(set_ref, a.seed, cfg.lr);
                    match exec.init_state() {
                        Ok(st) => v.insert((st, 0)),
                        Err(e) => {
                            eprintln!("worker {gpu}: init failed: {e:#}");
                            continue;
                        }
                    }
                }
            };
            let mut exec = TrainExecutor::new(set_ref, a.seed ^ state.step, cfg.lr);
            match exec.train_step(state, a.batch, a.accum_step.min(a.batch)) {
                Ok(loss) => {
                    let _ = tx.send(Progress { job: a.job, step: state.step, loss });
                }
                Err(e) => {
                    eprintln!("worker {gpu}: train_step failed for job {}: {e:#}", a.job);
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
            }
        }
        // Drop states of jobs no longer assigned to this lane.
        let assigned: Vec<JobId> = lane.iter().map(|a| a.job).collect();
        live.retain(|j, _| assigned.contains(j));
    }
}

/// Run `trace` physically under `policy`. Non-preemptive policies only
/// (the physical coordinator does not checkpoint parameters on preemption).
pub fn run_physical(
    cfg: PhysicalConfig,
    trace: &[JobSpec],
    xi: InterferenceModel,
    policy: &mut dyn Policy,
) -> Result<PhysicalOutcome> {
    let n_gpus = cfg.cluster.total_gpus();
    let board = Arc::new(Mutex::new(Board::default()));
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx): (Sender<Progress>, Receiver<Progress>) = channel();

    let mut workers = Vec::new();
    for g in 0..n_gpus {
        let board = Arc::clone(&board);
        let stop = Arc::clone(&stop);
        let tx = tx.clone();
        let cfg = cfg.clone();
        workers.push(std::thread::spawn(move || worker_loop(g, board, tx, cfg, stop)));
    }
    drop(tx);

    // Coordinator state mirrors the simulator's view so policies run as-is.
    let mut state = SimState {
        now: 0.0,
        cluster: Cluster::new(cfg.cluster),
        jobs: trace
            .iter()
            .cloned()
            .map(|mut spec| {
                spec.arrival_s /= cfg.time_compression;
                let mut rec = JobRecord::new(spec);
                rec.remaining_iters =
                    (rec.remaining_iters * cfg.iter_scale).max(10.0).round();
                rec
            })
            .collect(),
        xi,
        not_before: vec![0.0; trace.len()],
        service_gpu_s: vec![0.0; trace.len()],
    };
    // Target iteration counts after scaling.
    let targets: Vec<f64> = state.jobs.iter().map(|j| j.remaining_iters).collect();
    let mut executed: Vec<u64> = vec![0; trace.len()];
    let mut loss_curves: Vec<LossPoint> = Vec::new();
    let t0 = Instant::now();

    let result = (|| -> Result<()> {
        loop {
            state.now = t0.elapsed().as_secs_f64();
            // Apply progress reports.
            while let Ok(p) = rx.try_recv() {
                let rec = &mut state.jobs[p.job];
                if rec.state == JobState::Running && rec.remaining_iters > 0.0 {
                    rec.remaining_iters -= 1.0;
                    executed[p.job] += 1;
                    loss_curves.push(LossPoint {
                        job: p.job,
                        step: p.step,
                        loss: p.loss,
                        wall_s: state.now,
                    });
                }
            }
            // Completions.
            let mut changed = false;
            for id in state.running() {
                if state.jobs[id].remaining_iters <= 0.0 {
                    state.cluster.release(id);
                    let rec = &mut state.jobs[id];
                    rec.state = JobState::Finished;
                    rec.finish_s = Some(state.now);
                    rec.gpus_held.clear();
                    let mut b = board.lock().unwrap();
                    for lane in b.lanes.values_mut() {
                        lane.retain(|a| a.job != id);
                    }
                    changed = true;
                }
            }
            // Queueing accounting (coarse: updated on each loop pass).
            if state.jobs.iter().all(|j| j.state == JobState::Finished) {
                return Ok(());
            }
            // Scheduling pass.
            let decisions = policy.schedule(&state);
            for d in decisions {
                match d {
                    Decision::Start { job, gpus, accum_step } => {
                        state.cluster.allocate(job, &gpus);
                        let rec = &mut state.jobs[job];
                        rec.state = JobState::Running;
                        rec.accum_step = accum_step;
                        rec.gpus_held = gpus.clone();
                        if rec.first_start_s.is_none() {
                            rec.first_start_s = Some(state.now);
                            rec.queued_s = state.now - rec.spec.arrival_s.max(0.0);
                        }
                        let lead = gpus[0];
                        let mut b = board.lock().unwrap();
                        b.lanes.entry(lead).or_default().push(Assignment {
                            job,
                            accum_step,
                            batch: cfg.exec_batch,
                            seed: job as u64 * 7919 + 17,
                        });
                        changed = true;
                    }
                    Decision::Preempt { .. } => {
                        anyhow::bail!(
                            "physical coordinator supports non-preemptive policies only"
                        );
                    }
                }
            }
            let _ = changed;
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
    })();

    stop.store(true, Ordering::Relaxed);
    for w in workers {
        let _ = w.join();
    }
    result?;

    let makespan = t0.elapsed().as_secs_f64();
    // Sanity: every job ran its scaled target.
    for (id, rec) in state.jobs.iter().enumerate() {
        debug_assert!(
            executed[id] as f64 >= targets[id] - 0.5,
            "job {id} executed {} of {}",
            executed[id],
            targets[id]
        );
        debug_assert_eq!(rec.state, JobState::Finished);
    }
    Ok(PhysicalOutcome {
        jobs: state.jobs,
        makespan_s: makespan,
        loss_curves,
        executed_iters: executed.iter().sum(),
    })
}

/// Write loss curves as CSV (`job,step,loss,wall_s`).
pub fn write_loss_csv(points: &[LossPoint], path: &std::path::Path) -> Result<()> {
    let mut out = String::from("job,step,loss,wall_s\n");
    for p in points {
        out.push_str(&format!("{},{},{},{:.3}\n", p.job, p.step, p.loss, p.wall_s));
    }
    std::fs::write(path, out).context("writing loss csv")
}

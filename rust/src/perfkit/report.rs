//! Machine-readable bench reports: environment capture plus
//! schema-versioned JSON (de)serialization of every suite's
//! [`BenchStats`] through the first-party [`Json`] layer.
//!
//! The emitted document is the `BENCH_<sha>.json` perf-trajectory
//! artifact (DESIGN.md §12): CI's `bench-smoke` job uploads one per push,
//! and `wise-share bench --baseline FILE` gates regressions against one.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::bench::BenchStats;
use crate::util::json::Json;

use super::registry::{CaseStats, Profile, SuiteReport, Throughput};

/// Schema tag of the emitted document. Bump on any column/semantics
/// change — consumers (and [`BenchReport::from_json`]) pin on it instead
/// of guessing from the field set.
pub const SCHEMA: &str = "wise-share-bench-v1";

/// Where a report was measured. Captured at run time, recorded verbatim —
/// comparisons across different environments are the reader's judgment
/// call, but at least the report says so.
#[derive(Debug, Clone, PartialEq)]
pub struct EnvInfo {
    /// Profile the suites ran at (`quick` / `full`). Comparing across
    /// profiles is meaningless and [`super::compare`] rejects it.
    pub profile: String,
    /// Worker threads available to the process.
    pub threads: usize,
    /// Commit under test: `GITHUB_SHA` (Actions) or `GIT_SHA`, if set.
    pub git_sha: Option<String>,
    pub os: String,
}

impl EnvInfo {
    pub fn capture(profile: Profile) -> EnvInfo {
        EnvInfo {
            profile: profile.name().to_string(),
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            git_sha: std::env::var("GITHUB_SHA")
                .ok()
                .or_else(|| std::env::var("GIT_SHA").ok())
                .filter(|s| !s.is_empty()),
            os: std::env::consts::OS.to_string(),
        }
    }
}

/// A full bench run: environment plus one [`SuiteReport`] per suite.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    pub env: EnvInfo,
    pub suites: Vec<SuiteReport>,
}

impl BenchReport {
    /// Total measured cases across non-skipped suites.
    pub fn n_cases(&self) -> usize {
        self.suites.iter().map(|s| s.cases.len()).sum()
    }

    /// Look a case up by `(suite, case-name)`.
    pub fn find(&self, suite: &str, case: &str) -> Option<&CaseStats> {
        self.suites
            .iter()
            .find(|s| s.suite == suite)?
            .cases
            .iter()
            .find(|c| c.stats.name == case)
    }

    /// CI gate on the artifact itself: parseable is not enough — the
    /// report must contain at least one measured case, every stat must be
    /// a finite non-negative ordered quantile set, and case names must be
    /// unique per suite (duplicates would corrupt baseline lookup).
    pub fn check(&self) -> Result<()> {
        if self.suites.is_empty() {
            bail!("bench report has no suites");
        }
        if self.n_cases() == 0 {
            let reasons: Vec<String> = self
                .suites
                .iter()
                .filter_map(|s| s.skipped.as_ref().map(|r| format!("{}: {r}", s.suite)))
                .collect();
            bail!(
                "bench report has no measured cases (skipped suites: {})",
                if reasons.is_empty() { "none".to_string() } else { reasons.join("; ") }
            );
        }
        let mut suite_names = std::collections::BTreeSet::new();
        for s in &self.suites {
            if !suite_names.insert(s.suite.as_str()) {
                bail!("report records suite {:?} twice", s.suite);
            }
            if s.skipped.is_some() && !s.cases.is_empty() {
                bail!("suite {:?} is both skipped and has recorded cases", s.suite);
            }
            let mut seen = std::collections::BTreeSet::new();
            for c in &s.cases {
                let st = &c.stats;
                if st.name.is_empty() {
                    bail!("suite {:?} has a case with an empty name", s.suite);
                }
                if !seen.insert(st.name.as_str()) {
                    bail!("suite {:?} records case {:?} twice", s.suite, st.name);
                }
                let vals = [st.mean_s, st.min_s, st.p50_s, st.p95_s];
                if st.iters == 0 || vals.iter().any(|v| !v.is_finite() || *v < 0.0) {
                    bail!("case {:?} has degenerate stats: {st:?}", st.name);
                }
                if st.min_s > st.p50_s || st.p50_s > st.p95_s {
                    bail!("case {:?} has unordered quantiles: {st:?}", st.name);
                }
                for (key, tol) in
                    [("max_regress_pct", c.max_regress_pct), ("max_drop_pct", c.max_drop_pct)]
                {
                    if let Some(pct) = tol {
                        if !pct.is_finite() || pct < 0.0 {
                            bail!("case {:?} has a degenerate {key} {pct}", st.name);
                        }
                    }
                }
                if let Some(tp) = c.throughput {
                    for (key, v) in
                        [("events_per_s", tp.events_per_s), ("jobs_per_s", tp.jobs_per_s)]
                    {
                        if !v.is_finite() || v <= 0.0 {
                            bail!("case {:?} has degenerate throughput {key} = {v}", st.name);
                        }
                    }
                }
            }
        }
        Ok(())
    }

    // ----------------------------------------------------------- JSON

    pub fn to_json(&self) -> Json {
        let mut doc = BTreeMap::new();
        doc.insert("schema".to_string(), Json::from(SCHEMA));
        let mut env = BTreeMap::new();
        env.insert("profile".to_string(), Json::from(self.env.profile.as_str()));
        env.insert("threads".to_string(), Json::from(self.env.threads));
        env.insert(
            "git_sha".to_string(),
            match &self.env.git_sha {
                Some(sha) => Json::from(sha.as_str()),
                None => Json::Null,
            },
        );
        env.insert("os".to_string(), Json::from(self.env.os.as_str()));
        doc.insert("env".to_string(), Json::Obj(env));
        let suites: Vec<Json> = self
            .suites
            .iter()
            .map(|s| {
                let mut m = BTreeMap::new();
                m.insert("suite".to_string(), Json::from(s.suite.as_str()));
                m.insert(
                    "skipped".to_string(),
                    match &s.skipped {
                        Some(r) => Json::from(r.as_str()),
                        None => Json::Null,
                    },
                );
                let cases: Vec<Json> = s.cases.iter().map(case_to_json).collect();
                m.insert("cases".to_string(), Json::Arr(cases));
                Json::Obj(m)
            })
            .collect();
        doc.insert("suites".to_string(), Json::Arr(suites));
        Json::Obj(doc)
    }

    pub fn from_json(doc: &Json) -> Result<BenchReport> {
        let schema = doc.req("schema")?.as_str().context("schema must be a string")?;
        if schema != SCHEMA {
            bail!("unsupported bench schema {schema:?} (this build reads {SCHEMA:?})");
        }
        let env = doc.req("env")?;
        let env = EnvInfo {
            profile: env
                .req("profile")?
                .as_str()
                .context("env.profile must be a string")?
                .to_string(),
            threads: env
                .req("threads")?
                .as_u64()
                .context("env.threads must be a non-negative integer")?
                as usize,
            git_sha: match env.get("git_sha") {
                None | Some(Json::Null) => None,
                Some(v) => Some(
                    v.as_str().context("env.git_sha must be a string")?.to_string(),
                ),
            },
            os: env.req("os")?.as_str().context("env.os must be a string")?.to_string(),
        };
        let suites = doc
            .req("suites")?
            .as_arr()
            .context("suites must be an array")?
            .iter()
            .map(suite_from_json)
            .collect::<Result<Vec<SuiteReport>>>()?;
        Ok(BenchReport { env, suites })
    }

    pub fn load(path: &Path) -> Result<BenchReport> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench report {}", path.display()))?;
        let doc = Json::parse(&text)
            .with_context(|| format!("parsing bench report {}", path.display()))?;
        Self::from_json(&doc)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing bench report {}", path.display()))
    }
}

fn case_to_json(c: &CaseStats) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::from(c.stats.name.as_str()));
    m.insert("iters".to_string(), Json::from(c.stats.iters));
    m.insert("mean_s".to_string(), Json::Num(c.stats.mean_s));
    m.insert("min_s".to_string(), Json::Num(c.stats.min_s));
    m.insert("p50_s".to_string(), Json::Num(c.stats.p50_s));
    m.insert("p95_s".to_string(), Json::Num(c.stats.p95_s));
    if let Some(pct) = c.max_regress_pct {
        m.insert("max_regress_pct".to_string(), Json::Num(pct));
    }
    if let Some(pct) = c.max_drop_pct {
        m.insert("max_drop_pct".to_string(), Json::Num(pct));
    }
    // Additive fields — readers of the v1 schema that predate throughput
    // metrics simply ignore them, so the tag does not bump.
    if let Some(tp) = c.throughput {
        m.insert("events_per_s".to_string(), Json::Num(tp.events_per_s));
        m.insert("jobs_per_s".to_string(), Json::Num(tp.jobs_per_s));
    }
    Json::Obj(m)
}

fn case_from_json(j: &Json) -> Result<CaseStats> {
    let name = j.req("name")?.as_str().context("case name must be a string")?;
    let num = |key: &str| -> Result<f64> {
        j.req(key)?
            .as_f64()
            .with_context(|| format!("case {name:?}: {key} must be a number"))
    };
    Ok(CaseStats {
        stats: BenchStats {
            name: name.to_string(),
            iters: j
                .req("iters")?
                .as_u64()
                .with_context(|| format!("case {name:?}: iters"))? as usize,
            mean_s: num("mean_s")?,
            min_s: num("min_s")?,
            p50_s: num("p50_s")?,
            p95_s: num("p95_s")?,
        },
        max_regress_pct: match j.get("max_regress_pct") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_f64()
                    .with_context(|| format!("case {name:?}: max_regress_pct"))?,
            ),
        },
        max_drop_pct: match j.get("max_drop_pct") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_f64().with_context(|| format!("case {name:?}: max_drop_pct"))?,
            ),
        },
        throughput: match (j.get("events_per_s"), j.get("jobs_per_s")) {
            (Some(e), Some(c)) => Some(Throughput {
                events_per_s: e
                    .as_f64()
                    .with_context(|| format!("case {name:?}: events_per_s"))?,
                jobs_per_s: c
                    .as_f64()
                    .with_context(|| format!("case {name:?}: jobs_per_s"))?,
            }),
            _ => None,
        },
    })
}

fn suite_from_json(j: &Json) -> Result<SuiteReport> {
    let suite = j.req("suite")?.as_str().context("suite name must be a string")?;
    Ok(SuiteReport {
        suite: suite.to_string(),
        skipped: match j.get("skipped") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_str()
                    .with_context(|| format!("suite {suite:?}: skipped must be a string"))?
                    .to_string(),
            ),
        },
        cases: j
            .req("cases")?
            .as_arr()
            .with_context(|| format!("suite {suite:?}: cases must be an array"))?
            .iter()
            .map(case_from_json)
            .collect::<Result<_>>()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn case(name: &str, min_s: f64) -> CaseStats {
        CaseStats {
            stats: BenchStats {
                name: name.to_string(),
                iters: 5,
                mean_s: min_s * 1.1,
                min_s,
                p50_s: min_s * 1.05,
                p95_s: min_s * 1.2,
            },
            max_regress_pct: None,
            max_drop_pct: None,
            throughput: None,
        }
    }

    fn report() -> BenchReport {
        BenchReport {
            env: EnvInfo {
                profile: "quick".to_string(),
                threads: 8,
                git_sha: Some("abc123".to_string()),
                os: "linux".to_string(),
            },
            suites: vec![
                SuiteReport {
                    suite: "tables".to_string(),
                    skipped: None,
                    cases: vec![case("table2/physical-30-jobs/FIFO", 0.02), {
                        let mut c = case("table2/physical-30-jobs/SJF", 0.018);
                        c.max_regress_pct = Some(25.0);
                        c
                    }],
                },
                SuiteReport {
                    suite: "runtime_hotpath".to_string(),
                    skipped: Some("artifacts not built".to_string()),
                    cases: Vec::new(),
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let rep = report();
        let text = rep.to_json().to_string();
        assert!(text.starts_with('{'));
        let back = BenchReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(rep, back);
        assert_eq!(back.n_cases(), 2);
        assert!(back.find("tables", "table2/physical-30-jobs/SJF").is_some());
        assert_eq!(
            back.find("tables", "table2/physical-30-jobs/SJF")
                .unwrap()
                .max_regress_pct,
            Some(25.0)
        );
        assert!(back.find("tables", "nope").is_none());
        assert!(back.find("runtime_hotpath", "anything").is_none());
    }

    #[test]
    fn schema_tag_is_enforced() {
        let mut doc = report().to_json();
        if let Json::Obj(m) = &mut doc {
            m.insert("schema".to_string(), Json::from("wise-share-bench-v999"));
        }
        let err = BenchReport::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("unsupported bench schema"), "{err}");
        assert!(err.contains(SCHEMA), "{err}");
    }

    #[test]
    fn check_accepts_good_and_rejects_degenerate_reports() {
        report().check().unwrap();
        // Empty / all-skipped reports must fail the CI gate.
        let mut rep = report();
        rep.suites[0].cases.clear();
        let err = rep.check().unwrap_err().to_string();
        assert!(err.contains("no measured cases"), "{err}");
        assert!(err.contains("artifacts not built"), "{err}");
        // Duplicate case names corrupt baseline lookup.
        let mut rep = report();
        let dup = rep.suites[0].cases[0].clone();
        rep.suites[0].cases.push(dup);
        assert!(rep.check().unwrap_err().to_string().contains("twice"));
        // So do duplicate suite names (e.g. a doubled --suite selection).
        let mut rep = report();
        let dup_suite = rep.suites[0].clone();
        rep.suites.push(dup_suite);
        let err = rep.check().unwrap_err().to_string();
        assert!(err.contains("suite \"tables\" twice"), "{err}");
        // Non-finite stats are malformed.
        let mut rep = report();
        rep.suites[0].cases[0].stats.mean_s = f64::NAN;
        assert!(rep.check().is_err());
        // Unordered quantiles are malformed.
        let mut rep = report();
        rep.suites[0].cases[0].stats.p50_s = rep.suites[0].cases[0].stats.p95_s * 2.0;
        assert!(rep.check().unwrap_err().to_string().contains("unordered"));
    }

    #[test]
    fn drop_tolerance_roundtrips_and_validates() {
        let mut rep = report();
        rep.suites[0].cases[0].max_drop_pct = Some(35.0);
        rep.check().unwrap();
        let text = rep.to_json().to_string();
        assert!(text.contains("\"max_drop_pct\""), "{text}");
        let back = BenchReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(rep, back);
        assert_eq!(back.suites[0].cases[0].max_drop_pct, Some(35.0));
        // Absent on the other case (and omitted from its JSON object).
        assert_eq!(back.suites[0].cases[1].max_drop_pct, None);
        // Degenerate values fail the artifact gate.
        for bad in [-5.0, f64::NAN, f64::INFINITY] {
            let mut rep = report();
            rep.suites[0].cases[0].max_drop_pct = Some(bad);
            let err = rep.check().unwrap_err().to_string();
            assert!(err.contains("max_drop_pct"), "{bad}: {err}");
        }
    }

    #[test]
    fn throughput_fields_roundtrip_and_validate() {
        let mut rep = report();
        rep.suites[0].cases[0].throughput =
            Some(Throughput { events_per_s: 250_000.0, jobs_per_s: 1_800.0 });
        rep.check().unwrap();
        let text = rep.to_json().to_string();
        // Additive serialization under the unchanged v1 schema tag.
        assert!(text.contains("\"events_per_s\""), "{text}");
        assert!(text.contains(SCHEMA), "{text}");
        let back = BenchReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(rep, back);
        let tp = back.suites[0].cases[0].throughput.unwrap();
        assert_eq!(tp.events_per_s, 250_000.0);
        assert_eq!(tp.jobs_per_s, 1_800.0);
        // A case without throughput stays None through the roundtrip.
        assert!(back.suites[0].cases[1].throughput.is_none());
        // Degenerate throughput fails the artifact gate.
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let mut rep = report();
            rep.suites[0].cases[0].throughput =
                Some(Throughput { events_per_s: bad, jobs_per_s: 1.0 });
            let err = rep.check().unwrap_err().to_string();
            assert!(err.contains("degenerate throughput"), "{bad}: {err}");
        }
    }

    #[test]
    fn env_capture_reports_this_machine() {
        let env = EnvInfo::capture(Profile::Quick);
        assert_eq!(env.profile, "quick");
        assert!(env.threads >= 1);
        assert!(!env.os.is_empty());
    }
}

//! Baseline comparison: the regression gate behind
//! `wise-share bench --baseline FILE --max-regress PCT`.
//!
//! The gate metric is **`min_s`** — of the four recorded statistics the
//! minimum is the least sensitive to scheduler noise on shared runners
//! (mean and the upper quantiles absorb every descheduling blip), so it
//! is the fairest single number to gate on. Per-case tolerances recorded
//! in the *baseline* override the CLI default, so a recorded baseline
//! pins its own noise allowances (DESIGN.md §12).
//!
//! Cases that record **throughput** metrics (events/sec, jobs/sec — the
//! `scale_xl` suite) additionally gate higher-is-better: a *drop* beyond
//! the tolerance regresses. The drop limit is the baseline case's
//! `max_drop_pct` when recorded, falling back to its `max_regress_pct`,
//! then to the CLI default — so a single-shot case can carry wide
//! wall-clock headroom while its throughput floor stays tight. Only
//! cases where both sides recorded throughput are gated this way — a
//! baseline written before the metrics existed neither gates nor fails.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

use super::registry::Throughput;
use super::report::BenchReport;

/// Outcome of one case's comparison.
#[derive(Debug, Clone, PartialEq)]
pub enum Verdict {
    /// Within tolerance (`delta_pct` may be negative — an improvement).
    Pass { delta_pct: f64 },
    /// `min_s` grew past the tolerance.
    Regress { delta_pct: f64, limit_pct: f64 },
    /// A higher-is-better metric (events/sec or jobs/sec) dropped past
    /// the tolerance. `metric` names the offending one.
    RegressThroughput { metric: &'static str, drop_pct: f64, limit_pct: f64 },
    /// Measured now, absent from the baseline (new case).
    New,
    /// In the baseline, not measured now. Does not fail the gate — quick
    /// and full share no cases and renames surface as Missing+New pairs —
    /// but it is rendered loudly: a silently vanished case would
    /// otherwise pass forever.
    Missing,
}

#[derive(Debug, Clone, PartialEq)]
pub struct CaseVerdict {
    pub suite: String,
    pub name: String,
    pub verdict: Verdict,
}

/// The full comparison, in current-report case order (Missing rows last).
#[derive(Debug, Clone)]
pub struct Comparison {
    pub rows: Vec<CaseVerdict>,
    pub n_passed: usize,
    pub n_regressed: usize,
    pub n_new: usize,
    pub n_missing: usize,
}

/// Compare `current` against `baseline` case-by-case on `min_s`.
///
/// Tolerance per case: the baseline entry's `max_regress_pct` when
/// recorded, else `default_pct`. Suites skipped on either side are
/// excluded from New/Missing accounting (a skip is an environment gap,
/// not a perf change). Profiles must match — quick and full measure
/// different case sets and sizes.
pub fn compare(
    current: &BenchReport,
    baseline: &BenchReport,
    default_pct: f64,
) -> Result<Comparison> {
    if default_pct.is_nan() || default_pct < 0.0 {
        bail!("--max-regress {default_pct} must be a non-negative percentage");
    }
    if current.env.profile != baseline.env.profile {
        bail!(
            "bench profile mismatch: this run is {:?} but the baseline was recorded \
             at {:?} — the profiles measure different case sets",
            current.env.profile,
            baseline.env.profile
        );
    }
    type Entry = (f64, Option<f64>, Option<f64>, Option<Throughput>);
    let index = |rep: &BenchReport| -> BTreeMap<(String, String), Entry> {
        rep.suites
            .iter()
            .filter(|s| s.skipped.is_none())
            .flat_map(|s| {
                s.cases.iter().map(move |c| {
                    (
                        (s.suite.clone(), c.stats.name.clone()),
                        (c.stats.min_s, c.max_regress_pct, c.max_drop_pct, c.throughput),
                    )
                })
            })
            .collect()
    };
    let base = index(baseline);
    let cur = index(current);
    let cur_skipped: Vec<&str> = current
        .suites
        .iter()
        .filter(|s| s.skipped.is_some())
        .map(|s| s.suite.as_str())
        .collect();
    let base_skipped: Vec<&str> = baseline
        .suites
        .iter()
        .filter(|s| s.skipped.is_some())
        .map(|s| s.suite.as_str())
        .collect();

    let mut rows = Vec::new();
    for s in current.suites.iter().filter(|s| s.skipped.is_none()) {
        for c in &s.cases {
            let verdict = match base.get(&(s.suite.clone(), c.stats.name.clone())) {
                None if base_skipped.contains(&s.suite.as_str()) => continue,
                None => Verdict::New,
                Some(&(base_min, base_tol, base_drop_tol, base_tp)) => {
                    let limit_pct = base_tol.unwrap_or(default_pct);
                    let drop_limit_pct = base_drop_tol.or(base_tol).unwrap_or(default_pct);
                    let wall = if base_min <= 0.0 {
                        // A zero-time baseline cannot regress meaningfully
                        // (clock-resolution artifact); pass it.
                        Verdict::Pass { delta_pct: 0.0 }
                    } else {
                        let delta_pct = (c.stats.min_s / base_min - 1.0) * 100.0;
                        if delta_pct > limit_pct {
                            Verdict::Regress { delta_pct, limit_pct }
                        } else {
                            Verdict::Pass { delta_pct }
                        }
                    };
                    // Higher-is-better metrics gate only when both sides
                    // recorded them; the wall-clock verdict wins ties so
                    // at most one row appears per case.
                    match (wall, base_tp, c.throughput) {
                        (Verdict::Pass { delta_pct }, Some(base), Some(cur)) => {
                            let drops = [
                                ("events_per_s", base.events_per_s, cur.events_per_s),
                                ("jobs_per_s", base.jobs_per_s, cur.jobs_per_s),
                            ];
                            let mut v = Verdict::Pass { delta_pct };
                            for (metric, b, c) in drops {
                                if b <= 0.0 {
                                    continue;
                                }
                                let drop_pct = (1.0 - c / b) * 100.0;
                                if drop_pct > drop_limit_pct {
                                    v = Verdict::RegressThroughput {
                                        metric,
                                        drop_pct,
                                        limit_pct: drop_limit_pct,
                                    };
                                    break;
                                }
                            }
                            v
                        }
                        (wall, _, _) => wall,
                    }
                }
            };
            rows.push(CaseVerdict {
                suite: s.suite.clone(),
                name: c.stats.name.clone(),
                verdict,
            });
        }
    }
    for (suite, name) in base.keys() {
        if !cur.contains_key(&(suite.clone(), name.clone()))
            && !cur_skipped.contains(&suite.as_str())
        {
            rows.push(CaseVerdict {
                suite: suite.clone(),
                name: name.clone(),
                verdict: Verdict::Missing,
            });
        }
    }
    let count = |f: fn(&Verdict) -> bool| rows.iter().filter(|r| f(&r.verdict)).count();
    Ok(Comparison {
        n_passed: count(|v| matches!(v, Verdict::Pass { .. })),
        n_regressed: count(|v| {
            matches!(v, Verdict::Regress { .. } | Verdict::RegressThroughput { .. })
        }),
        n_new: count(|v| matches!(v, Verdict::New)),
        n_missing: count(|v| matches!(v, Verdict::Missing)),
        rows,
    })
}

impl Comparison {
    /// One line per case plus a summary line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for r in &self.rows {
            match &r.verdict {
                Verdict::Pass { delta_pct } => writeln!(
                    out,
                    "  PASS    {}/{} ({delta_pct:+.1}% min)",
                    r.suite, r.name
                )
                .unwrap(),
                Verdict::Regress { delta_pct, limit_pct } => writeln!(
                    out,
                    "  REGRESS {}/{} ({delta_pct:+.1}% min > +{limit_pct:.1}% allowed)",
                    r.suite, r.name
                )
                .unwrap(),
                Verdict::RegressThroughput { metric, drop_pct, limit_pct } => writeln!(
                    out,
                    "  REGRESS {}/{} ({metric} dropped {drop_pct:.1}% > {limit_pct:.1}% allowed)",
                    r.suite, r.name
                )
                .unwrap(),
                Verdict::New => {
                    writeln!(out, "  NEW     {}/{} (no baseline entry)", r.suite, r.name)
                        .unwrap()
                }
                Verdict::Missing => writeln!(
                    out,
                    "  MISSING {}/{} (in baseline, not measured now)",
                    r.suite, r.name
                )
                .unwrap(),
            }
        }
        writeln!(
            out,
            "baseline compare: {} passed, {} regressed, {} new, {} missing",
            self.n_passed, self.n_regressed, self.n_new, self.n_missing
        )
        .unwrap();
        out
    }

    /// `Err` (⇒ nonzero process exit) when any case regressed.
    pub fn gate(&self) -> Result<()> {
        if self.n_regressed == 0 {
            return Ok(());
        }
        let offenders: Vec<String> = self
            .rows
            .iter()
            .filter_map(|r| match r.verdict {
                Verdict::Regress { delta_pct, limit_pct } => Some(format!(
                    "{}/{} ({delta_pct:+.1}% > +{limit_pct:.1}%)",
                    r.suite, r.name
                )),
                Verdict::RegressThroughput { metric, drop_pct, limit_pct } => Some(format!(
                    "{}/{} ({metric} -{drop_pct:.1}% > {limit_pct:.1}%)",
                    r.suite, r.name
                )),
                _ => None,
            })
            .collect();
        bail!(
            "{} bench case(s) regressed past the gate: {}",
            self.n_regressed,
            offenders.join(", ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfkit::registry::{CaseStats, SuiteReport};
    use crate::perfkit::report::EnvInfo;
    use crate::util::bench::BenchStats;

    fn case(name: &str, min_s: f64, tol: Option<f64>) -> CaseStats {
        CaseStats {
            stats: BenchStats {
                name: name.to_string(),
                iters: 3,
                mean_s: min_s * 1.1,
                min_s,
                p50_s: min_s * 1.05,
                p95_s: min_s * 1.2,
            },
            max_regress_pct: tol,
            max_drop_pct: None,
            throughput: None,
        }
    }

    fn tp_case(name: &str, min_s: f64, tol: Option<f64>, ev: f64, jo: f64) -> CaseStats {
        let mut c = case(name, min_s, tol);
        c.throughput = Some(crate::perfkit::registry::Throughput {
            events_per_s: ev,
            jobs_per_s: jo,
        });
        c
    }

    fn report(profile: &str, suites: Vec<SuiteReport>) -> BenchReport {
        BenchReport {
            env: EnvInfo {
                profile: profile.to_string(),
                threads: 4,
                git_sha: None,
                os: "linux".to_string(),
            },
            suites,
        }
    }

    fn suite(name: &str, cases: Vec<CaseStats>) -> SuiteReport {
        SuiteReport { suite: name.to_string(), skipped: None, cases }
    }

    #[test]
    fn pass_regress_new_missing_verdicts() {
        let baseline = report(
            "quick",
            vec![suite(
                "s",
                vec![
                    case("a", 1.0, None),
                    case("b", 1.0, Some(50.0)),
                    case("gone", 1.0, None),
                ],
            )],
        );
        let current = report(
            "quick",
            vec![suite(
                "s",
                vec![
                    case("a", 1.05, None),  // +5% <= 10% default: pass
                    case("b", 1.4, None),   // +40% <= per-case 50%: pass
                    case("fresh", 0.5, None), // new
                ],
            )],
        );
        let cmp = compare(&current, &baseline, 10.0).unwrap();
        assert_eq!(cmp.n_passed, 2);
        assert_eq!(cmp.n_regressed, 0);
        assert_eq!(cmp.n_new, 1);
        assert_eq!(cmp.n_missing, 1);
        cmp.gate().unwrap();
        let rendered = cmp.render();
        assert!(rendered.contains("NEW     s/fresh"), "{rendered}");
        assert!(rendered.contains("MISSING s/gone"), "{rendered}");

        // Now regress case `a` past the default and `b` past its own cap.
        let current = report(
            "quick",
            vec![suite("s", vec![case("a", 1.2, None), case("b", 1.6, None)])],
        );
        let cmp = compare(&current, &baseline, 10.0).unwrap();
        assert_eq!(cmp.n_regressed, 2);
        let err = cmp.gate().unwrap_err().to_string();
        assert!(err.contains("s/a"), "{err}");
        assert!(err.contains("s/b"), "{err}");
        assert!(err.contains("+20.0%"), "{err}");
    }

    #[test]
    fn improvements_pass_with_negative_delta() {
        let baseline = report("full", vec![suite("s", vec![case("a", 2.0, None)])]);
        let current = report("full", vec![suite("s", vec![case("a", 1.0, None)])]);
        let cmp = compare(&current, &baseline, 0.0).unwrap();
        assert_eq!(cmp.n_passed, 1);
        assert!(matches!(
            cmp.rows[0].verdict,
            Verdict::Pass { delta_pct } if delta_pct < -49.0
        ));
        cmp.gate().unwrap();
    }

    #[test]
    fn profile_mismatch_is_rejected() {
        let baseline = report("full", vec![suite("s", vec![case("a", 1.0, None)])]);
        let current = report("quick", vec![suite("s", vec![case("a", 1.0, None)])]);
        let err = compare(&current, &baseline, 10.0).unwrap_err().to_string();
        assert!(err.contains("profile mismatch"), "{err}");
    }

    #[test]
    fn skipped_suites_do_not_count_as_new_or_missing() {
        let skipped = SuiteReport {
            suite: "runtime_hotpath".to_string(),
            skipped: Some("no artifacts".to_string()),
            cases: Vec::new(),
        };
        // Baseline measured the suite; current skipped it: not Missing.
        let baseline = report(
            "quick",
            vec![suite("runtime_hotpath", vec![case("a", 1.0, None)])],
        );
        let current = report("quick", vec![skipped.clone()]);
        let cmp = compare(&current, &baseline, 10.0).unwrap();
        assert_eq!(cmp.n_missing, 0);
        // Baseline skipped it; current measured it: not New.
        let cmp = compare(
            &report("quick", vec![suite("runtime_hotpath", vec![case("a", 1.0, None)])]),
            &report("quick", vec![skipped]),
            10.0,
        )
        .unwrap();
        assert_eq!(cmp.n_new, 0);
        assert_eq!(cmp.rows.len(), 0);
    }

    #[test]
    fn zero_time_baseline_cannot_regress() {
        let baseline = report("quick", vec![suite("s", vec![case("a", 0.0, None)])]);
        let current = report("quick", vec![suite("s", vec![case("a", 5.0, None)])]);
        let cmp = compare(&current, &baseline, 10.0).unwrap();
        assert_eq!(cmp.n_regressed, 0);
        assert_eq!(cmp.n_passed, 1);
    }

    #[test]
    fn throughput_drop_gates_higher_is_better() {
        let baseline = report(
            "quick",
            vec![suite("scale_xl", vec![tp_case("xl/a", 1.0, Some(20.0), 100_000.0, 500.0)])],
        );
        // Wall time flat, events/sec down 50% (> 20% tolerance): regress.
        let current = report(
            "quick",
            vec![suite("scale_xl", vec![tp_case("xl/a", 1.0, None, 50_000.0, 500.0)])],
        );
        let cmp = compare(&current, &baseline, 10.0).unwrap();
        assert_eq!(cmp.n_regressed, 1);
        assert!(matches!(
            cmp.rows[0].verdict,
            Verdict::RegressThroughput { metric: "events_per_s", .. }
        ));
        let err = cmp.gate().unwrap_err().to_string();
        assert!(err.contains("events_per_s"), "{err}");
        let rendered = cmp.render();
        assert!(rendered.contains("dropped 50.0%"), "{rendered}");

        // jobs/sec is gated too, independently of events/sec.
        let current = report(
            "quick",
            vec![suite("scale_xl", vec![tp_case("xl/a", 1.0, None, 100_000.0, 100.0)])],
        );
        let cmp = compare(&current, &baseline, 10.0).unwrap();
        assert!(matches!(
            cmp.rows[0].verdict,
            Verdict::RegressThroughput { metric: "jobs_per_s", .. }
        ));

        // A throughput *gain* passes; drops within tolerance pass.
        let current = report(
            "quick",
            vec![suite("scale_xl", vec![tp_case("xl/a", 1.0, None, 150_000.0, 450.0)])],
        );
        let cmp = compare(&current, &baseline, 10.0).unwrap();
        assert_eq!(cmp.n_regressed, 0);
        assert_eq!(cmp.n_passed, 1);
        cmp.gate().unwrap();
    }

    #[test]
    fn per_case_drop_tolerance_overrides_wall_clock_tolerance() {
        // A single-shot case with 80% wall-clock headroom but a tight 20%
        // throughput floor: the drop gate must use max_drop_pct, not
        // max_regress_pct.
        let mut base_case = tp_case("xl/a", 1.0, Some(80.0), 100_000.0, 500.0);
        base_case.max_drop_pct = Some(20.0);
        let baseline = report("quick", vec![suite("scale_xl", vec![base_case])]);

        // 40% events/sec drop: within the 80% wall-clock headroom, past
        // the 20% drop floor — must regress.
        let current = report(
            "quick",
            vec![suite("scale_xl", vec![tp_case("xl/a", 1.0, None, 60_000.0, 500.0)])],
        );
        let cmp = compare(&current, &baseline, 10.0).unwrap();
        assert_eq!(cmp.n_regressed, 1);
        assert!(matches!(
            cmp.rows[0].verdict,
            Verdict::RegressThroughput { metric: "events_per_s", limit_pct, .. }
                if limit_pct == 20.0
        ));

        // 10% drop: within the 20% floor — passes.
        let current = report(
            "quick",
            vec![suite("scale_xl", vec![tp_case("xl/a", 1.0, None, 90_000.0, 500.0)])],
        );
        let cmp = compare(&current, &baseline, 10.0).unwrap();
        assert_eq!(cmp.n_regressed, 0);
        assert_eq!(cmp.n_passed, 1);
        cmp.gate().unwrap();

        // Without max_drop_pct the old fallback chain still applies: the
        // same 40% drop slips under the 80% wall-clock tolerance.
        let baseline = report(
            "quick",
            vec![suite("scale_xl", vec![tp_case("xl/a", 1.0, Some(80.0), 100_000.0, 500.0)])],
        );
        let current = report(
            "quick",
            vec![suite("scale_xl", vec![tp_case("xl/a", 1.0, None, 60_000.0, 500.0)])],
        );
        let cmp = compare(&current, &baseline, 10.0).unwrap();
        assert_eq!(cmp.n_regressed, 0);
        assert_eq!(cmp.n_passed, 1);
    }

    #[test]
    fn throughput_gate_needs_both_sides() {
        // Baseline predates the metrics: a current report that records
        // them neither gates nor fails (and vice versa).
        let old_base =
            report("quick", vec![suite("scale_xl", vec![case("xl/a", 1.0, None)])]);
        let current = report(
            "quick",
            vec![suite("scale_xl", vec![tp_case("xl/a", 1.0, None, 10.0, 1.0)])],
        );
        let cmp = compare(&current, &old_base, 10.0).unwrap();
        assert_eq!(cmp.n_regressed, 0);
        assert_eq!(cmp.n_passed, 1);
        let cmp = compare(&old_base, &current, 10.0).unwrap();
        assert_eq!(cmp.n_regressed, 0);

        // A min_s regression wins over the throughput verdict — one row,
        // the wall-clock one.
        let baseline = report(
            "quick",
            vec![suite("scale_xl", vec![tp_case("xl/a", 1.0, None, 100.0, 10.0)])],
        );
        let current = report(
            "quick",
            vec![suite("scale_xl", vec![tp_case("xl/a", 2.0, None, 1.0, 1.0)])],
        );
        let cmp = compare(&current, &baseline, 10.0).unwrap();
        assert_eq!(cmp.rows.len(), 1);
        assert!(matches!(cmp.rows[0].verdict, Verdict::Regress { .. }));
        assert_eq!(cmp.n_regressed, 1);
    }

    #[test]
    fn degenerate_default_tolerance_is_rejected() {
        let rep = report("quick", vec![suite("s", vec![case("a", 1.0, None)])]);
        assert!(compare(&rep, &rep, -1.0).is_err());
        assert!(compare(&rep, &rep, f64::NAN).is_err());
        assert!(compare(&rep, &rep, 0.0).is_ok());
    }
}

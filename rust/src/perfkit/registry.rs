//! The suite registry: every `cargo bench` target registers its cases
//! here as a [`Suite`], so the `wise-share bench` subcommand (and CI's
//! `bench-smoke` job) can run the exact same code the bench binaries
//! wrap and record the results machine-readably.

use anyhow::{bail, Result};

use crate::util::bench::{bench, bench_once, BenchStats};

use super::suites;

/// How big a suite run should be.
///
/// `Full` is the developer profile — the paper-scale workloads the bench
/// binaries have always run. `Quick` is the CI smoke profile: the same
/// code paths at sizes that finish in seconds, so the perf trajectory
/// gets a data point on every push without monopolizing a runner.
/// Case names embed the sizes that differ, so a quick report is never
/// silently compared against a full baseline case.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    Quick,
    Full,
}

impl Profile {
    pub fn parse(s: &str) -> Result<Profile> {
        match s {
            "quick" => Ok(Profile::Quick),
            "full" => Ok(Profile::Full),
            other => bail!("unknown bench profile {other:?} (known: quick, full)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Profile::Quick => "quick",
            Profile::Full => "full",
        }
    }

    /// Pick a profile-dependent knob (iteration counts, trace sizes).
    pub fn pick<T>(self, quick: T, full: T) -> T {
        match self {
            Profile::Quick => quick,
            Profile::Full => full,
        }
    }
}

/// Higher-is-better throughput metrics a case may record alongside its
/// wall-clock stats — the `scale_xl` suite's first-class gated numbers:
/// a drop in either gates exactly like a latency regression (see
/// [`super::compare`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Throughput {
    /// Engine events processed per second of measured wall time.
    pub events_per_s: f64,
    /// Jobs completed per second of measured wall time.
    pub jobs_per_s: f64,
}

/// One recorded case: the measured stats plus an optional per-case
/// regression tolerance. `None` means the gate's `--max-regress` default
/// applies; suites set an explicit tolerance on wall-clock-noisy cases
/// (e.g. parallel-pool speedups, which vary with the runner's core count).
#[derive(Debug, Clone, PartialEq)]
pub struct CaseStats {
    pub stats: BenchStats,
    pub max_regress_pct: Option<f64>,
    /// Throughput-specific tolerance: the largest events/sec or jobs/sec
    /// *drop* (percent) the gate allows before flagging
    /// `RegressThroughput`. `None` falls back to `max_regress_pct`, then
    /// to the gate's CLI default — so wall-clock-noisy cases can carry a
    /// generous `max_regress_pct` while still gating their throughput
    /// tightly. Serialized additively (`wise-share-bench-v1` unchanged).
    pub max_drop_pct: Option<f64>,
    /// Optional higher-is-better metrics ([`Recorder::throughput`]);
    /// serialized additively in the bench JSON, so the schema stays
    /// `wise-share-bench-v1`-compatible.
    pub throughput: Option<Throughput>,
}

/// Everything one suite produced in one run.
#[derive(Debug, Clone, PartialEq)]
pub struct SuiteReport {
    pub suite: String,
    /// `Some(reason)` when the suite cannot run in this environment (e.g.
    /// PJRT artifacts absent); `cases` is empty then. A skip is not a
    /// failure — the report records it so the gap is visible.
    pub skipped: Option<String>,
    pub cases: Vec<CaseStats>,
}

/// Default regression tolerance stamped on single-sample cases
/// (`iters <= 1`, i.e. `Recorder::once` and 1-iteration benches): one
/// wall-clock sample of a seconds-scale end-to-end run on a shared
/// runner routinely swings past the 10% CLI default, so these cases
/// record their own headroom in the report instead of flaking every
/// quick-profile baseline comparison. `Recorder::tolerance` overrides.
pub const SINGLE_SHOT_TOLERANCE_PCT: f64 = 50.0;

/// Collects [`CaseStats`] as a suite body runs its cases.
pub struct Recorder {
    suite: &'static str,
    cases: Vec<CaseStats>,
}

impl Recorder {
    pub fn new(suite: &'static str) -> Recorder {
        Recorder { suite, cases: Vec::new() }
    }

    fn push(&mut self, stats: BenchStats) -> BenchStats {
        let max_regress_pct =
            if stats.iters <= 1 { Some(SINGLE_SHOT_TOLERANCE_PCT) } else { None };
        self.cases.push(CaseStats {
            stats: stats.clone(),
            max_regress_pct,
            max_drop_pct: None,
            throughput: None,
        });
        stats
    }

    /// Run [`bench`] (warm-up + `iters` timed calls) and record the case.
    pub fn bench<F: FnMut()>(&mut self, name: &str, iters: usize, f: F) -> BenchStats {
        let stats = bench(name, iters, f);
        self.push(stats)
    }

    /// Run [`bench_once`] (one timed call, no warm-up) and record the case.
    pub fn once<F: FnOnce()>(&mut self, name: &str, f: F) -> BenchStats {
        let stats = bench_once(name, f);
        self.push(stats)
    }

    /// Record stats the caller measured itself (e.g. a latency histogram
    /// obskit collected inside an engine run, folded through
    /// [`crate::util::bench::stats_of`]) as a case. Single-sample stats
    /// get the same noise headroom as [`Recorder::once`].
    pub fn record(&mut self, stats: BenchStats) -> BenchStats {
        self.push(stats)
    }

    /// Set the regression tolerance of the most recently recorded case.
    pub fn tolerance(&mut self, max_regress_pct: f64) {
        let case = self
            .cases
            .last_mut()
            .expect("tolerance() must follow a recorded case");
        case.max_regress_pct = Some(max_regress_pct);
    }

    /// Set the throughput-drop tolerance of the most recently recorded
    /// case (see [`CaseStats::max_drop_pct`]).
    pub fn drop_tolerance(&mut self, max_drop_pct: f64) {
        let case = self
            .cases
            .last_mut()
            .expect("drop_tolerance() must follow a recorded case");
        case.max_drop_pct = Some(max_drop_pct);
    }

    /// Attach higher-is-better throughput metrics to the most recently
    /// recorded case (events processed and jobs completed per second of
    /// measured wall time). Gated in [`super::compare`] against the
    /// case's `max_drop_pct` when set, else its wall-clock tolerance.
    pub fn throughput(&mut self, events_per_s: f64, jobs_per_s: f64) {
        let case = self
            .cases
            .last_mut()
            .expect("throughput() must follow a recorded case");
        case.throughput = Some(Throughput { events_per_s, jobs_per_s });
    }

    /// Abandon the suite with a reason (environment cannot run it).
    pub fn skip(self, reason: String) -> SuiteReport {
        SuiteReport { suite: self.suite.to_string(), skipped: Some(reason), cases: Vec::new() }
    }

    pub fn finish(self) -> SuiteReport {
        SuiteReport { suite: self.suite.to_string(), skipped: None, cases: self.cases }
    }
}

/// One registered benchmark suite. `run` executes every case at the given
/// profile; suites that cannot run here return a skipped report instead
/// of failing (see [`Recorder::skip`]).
pub struct Suite {
    pub name: &'static str,
    pub description: &'static str,
    pub run: fn(Profile) -> SuiteReport,
}

/// Registered suite names, in registry (execution) order — one per
/// `cargo bench` target.
pub const SUITE_NAMES: [&str; 9] = [
    "tables",
    "figures",
    "ablations",
    "sched_overhead",
    "runtime_hotpath",
    "campaign_throughput",
    "scale",
    "scale_xl",
    "serve",
];

/// Every registered suite, in [`SUITE_NAMES`] order.
pub fn all() -> Vec<Suite> {
    vec![
        suites::tables::suite(),
        suites::figures::suite(),
        suites::ablations::suite(),
        suites::sched_overhead::suite(),
        suites::runtime_hotpath::suite(),
        suites::campaign_throughput::suite(),
        suites::scale::suite(),
        suites::scale_xl::suite(),
        suites::serve::suite(),
    ]
}

/// Look a suite up by name, with the canonical unknown-name error.
pub fn by_name_or_err(name: &str) -> Result<Suite> {
    all()
        .into_iter()
        .find(|s| s.name == name)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown bench suite {name:?} (known: {})",
                SUITE_NAMES.join(", ")
            )
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_names_and_resolves() {
        let suites = all();
        assert_eq!(suites.len(), SUITE_NAMES.len());
        for (s, name) in suites.iter().zip(SUITE_NAMES) {
            assert_eq!(s.name, name);
            assert!(!s.description.is_empty());
        }
        for name in SUITE_NAMES {
            assert!(by_name_or_err(name).is_ok());
        }
        let err = by_name_or_err("bogus").unwrap_err().to_string();
        assert!(err.contains("unknown bench suite"), "{err}");
        assert!(err.contains("sched_overhead"), "{err}");
    }

    #[test]
    fn recorder_collects_cases_and_tolerances() {
        let mut rec = Recorder::new("demo");
        rec.bench("demo/a", 4, || {
            std::hint::black_box(2 + 2);
        });
        rec.once("demo/b", || {
            std::hint::black_box(3 + 3);
        });
        rec.tolerance(80.0);
        rec.bench("demo/c", 1, || {
            std::hint::black_box(4 + 4);
        });
        let rep = rec.finish();
        assert_eq!(rep.suite, "demo");
        assert!(rep.skipped.is_none());
        assert_eq!(rep.cases.len(), 3);
        assert_eq!(rep.cases[0].stats.name, "demo/a");
        assert_eq!(rep.cases[0].stats.iters, 4);
        // Multi-sample micro-benches gate at the CLI default...
        assert_eq!(rep.cases[0].max_regress_pct, None);
        // ...an explicit tolerance overrides the single-shot stamp...
        assert_eq!(rep.cases[1].max_regress_pct, Some(80.0));
        // ...and single-sample cases carry their own noise headroom.
        assert_eq!(rep.cases[2].max_regress_pct, Some(SINGLE_SHOT_TOLERANCE_PCT));
    }

    #[test]
    fn recorder_skip_produces_empty_report() {
        let rep = Recorder::new("demo").skip("no artifacts".to_string());
        assert_eq!(rep.skipped.as_deref(), Some("no artifacts"));
        assert!(rep.cases.is_empty());
    }

    #[test]
    fn profile_parse_and_pick() {
        assert_eq!(Profile::parse("quick").unwrap(), Profile::Quick);
        assert_eq!(Profile::parse("full").unwrap(), Profile::Full);
        assert!(Profile::parse("fast").is_err());
        assert_eq!(Profile::Quick.pick(1, 3), 1);
        assert_eq!(Profile::Full.pick(1, 3), 3);
        assert_eq!(Profile::Full.name(), "full");
    }
}

//! The shared bench driver: the `wise-share bench` subcommand and every
//! thin `cargo bench` wrapper funnel through [`run`], so a suite measures
//! and records identically no matter which entry point launched it.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::compare::compare;
use super::registry::{self, Profile, Suite};
use super::report::{BenchReport, EnvInfo};

/// Default `--max-regress` gate, percent growth of a case's `min_s`.
pub const DEFAULT_MAX_REGRESS_PCT: f64 = 10.0;

/// One bench invocation, CLI-shaped.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Suites to run; empty ⇒ all registered suites.
    pub suites: Vec<String>,
    pub profile: Profile,
    /// Write the schema-versioned JSON report here.
    pub out: Option<PathBuf>,
    /// Compare against this previously-recorded report and gate.
    pub baseline: Option<PathBuf>,
    pub max_regress_pct: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            suites: Vec::new(),
            profile: Profile::Full,
            out: None,
            baseline: None,
            max_regress_pct: DEFAULT_MAX_REGRESS_PCT,
        }
    }
}

/// Run the selected suites, emit the report, gate against the baseline.
///
/// Ordering matters for CI forensics: the JSON artifact is written
/// *before* the emptiness check and the regression gate run, so a failing
/// job still uploads what it measured.
pub fn run(cfg: &RunConfig) -> Result<BenchReport> {
    let suites: Vec<Suite> = if cfg.suites.is_empty() {
        registry::all()
    } else {
        for (i, n) in cfg.suites.iter().enumerate() {
            if cfg.suites[..i].contains(n) {
                // A doubled selection would record the suite twice and
                // corrupt baseline lookup (duplicate case names).
                bail!("suite {n:?} listed more than once");
            }
        }
        cfg.suites
            .iter()
            .map(|n| registry::by_name_or_err(n))
            .collect::<Result<_>>()?
    };
    let mut reports = Vec::new();
    for s in suites {
        println!("== {} [{}] — {} ==", s.name, cfg.profile.name(), s.description);
        let rep = (s.run)(cfg.profile);
        if let Some(reason) = &rep.skipped {
            println!("SKIPPED {}: {reason}", s.name);
        }
        println!();
        reports.push(rep);
    }
    let report = BenchReport { env: EnvInfo::capture(cfg.profile), suites: reports };
    if let Some(path) = &cfg.out {
        report.save(path)?;
        println!(
            "bench report -> {} ({} cases, profile {}, sha {})",
            path.display(),
            report.n_cases(),
            report.env.profile,
            report.env.git_sha.as_deref().unwrap_or("unset"),
        );
    }
    if report.suites.iter().all(|s| s.skipped.is_some()) {
        // An explicitly-selected suite that cannot run here (e.g.
        // `--suite runtime_hotpath` offline) is a recorded skip, not a
        // failure. CI's artifact gate (`bench --check`) still rejects an
        // all-skipped report where measurements are expected.
        println!("note: every selected suite skipped in this environment — nothing measured");
    } else {
        report.check()?;
    }
    if let Some(base_path) = &cfg.baseline {
        let baseline = BenchReport::load(base_path)?;
        baseline
            .check()
            .with_context(|| format!("baseline {} failed validation", base_path.display()))?;
        let cmp = compare(&report, &baseline, cfg.max_regress_pct)?;
        print!("{}", cmp.render());
        cmp.gate()?;
    }
    Ok(report)
}

/// The `bench --list` text: every registered suite with its description,
/// plus the run profiles. A function (not inlined in main) so the CLI
/// test can pin that the listing and the registry cannot drift apart.
pub fn list() -> String {
    let mut out = String::from("registered bench suites:\n");
    for s in registry::all() {
        out.push_str(&format!("  {:<22} {}\n", s.name, s.description));
    }
    out.push_str("profiles: quick, full (default)\n");
    out
}

/// Validate a previously-emitted report file — CI's malformed/empty gate
/// on the `BENCH_ci.json` artifact.
pub fn check_file(path: &Path) -> Result<()> {
    let report = BenchReport::load(path)?;
    report
        .check()
        .with_context(|| format!("bench report {} failed validation", path.display()))?;
    let skipped = report.suites.iter().filter(|s| s.skipped.is_some()).count();
    println!(
        "OK: {} — {} suites ({} skipped), {} cases, profile {}",
        path.display(),
        report.suites.len(),
        skipped,
        report.n_cases(),
        report.env.profile,
    );
    Ok(())
}

/// Entry point for the thin `cargo bench` wrapper binaries: run one named
/// suite with the perfkit flags passed after `--`, e.g.
/// `cargo bench --bench scale -- --profile quick --out BENCH_scale.json`.
pub fn bench_main(suite: &'static str) -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = RunConfig { suites: vec![suite.to_string()], ..RunConfig::default() };
    let mut it = argv.iter();
    while let Some(flag) = it.next() {
        // Tolerate libtest-style flags cargo may forward to bench targets.
        if flag == "--bench" {
            continue;
        }
        let value = it
            .next()
            .with_context(|| format!("bench flag {flag} needs a value"))?;
        match flag.as_str() {
            "--profile" => cfg.profile = Profile::parse(value)?,
            "--out" => cfg.out = Some(PathBuf::from(value)),
            "--baseline" => cfg.baseline = Some(PathBuf::from(value)),
            "--max-regress" => {
                cfg.max_regress_pct = value
                    .parse()
                    .map_err(|e| anyhow::anyhow!("--max-regress {value:?}: {e}"))?
            }
            other => bail!(
                "unknown bench flag {other:?} (known: --profile, --out, --baseline, \
                 --max-regress)"
            ),
        }
    }
    run(&cfg).map(|_| ())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_suite_is_rejected_before_anything_runs() {
        let cfg = RunConfig { suites: vec!["bogus".to_string()], ..RunConfig::default() };
        let err = run(&cfg).unwrap_err().to_string();
        assert!(err.contains("unknown bench suite"), "{err}");
    }

    #[test]
    fn duplicate_suite_selection_is_rejected() {
        let cfg = RunConfig {
            suites: vec!["scale".to_string(), "scale".to_string()],
            ..RunConfig::default()
        };
        let err = run(&cfg).unwrap_err().to_string();
        assert!(err.contains("listed more than once"), "{err}");
    }

    #[test]
    fn default_config_targets_all_suites_at_full() {
        let cfg = RunConfig::default();
        assert!(cfg.suites.is_empty());
        assert_eq!(cfg.profile, Profile::Full);
        assert_eq!(cfg.max_regress_pct, DEFAULT_MAX_REGRESS_PCT);
        assert!(cfg.out.is_none() && cfg.baseline.is_none());
    }
}

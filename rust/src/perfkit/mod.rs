//! `perfkit` — machine-readable benchmarking: suite registry, environment
//! capture, schema-versioned JSON reports, and baseline regression gates
//! (DESIGN.md §12).
//!
//! Before this subsystem the six `cargo bench` targets printed one-line
//! stats to stdout and the numbers died in scrollback — four PRs in, the
//! repo's perf trajectory was still empty. perfkit turns a bench run into
//! a recorded, gateable artifact:
//!
//! 1. **Registry** ([`registry`]) — each bench target's body lives here as
//!    a [`Suite`] of recorded cases; the `benches/*.rs` files are thin
//!    wrappers over [`bench_main`]. Every suite runs at two [`Profile`]s:
//!    `full` (the paper-scale developer run) and `quick` (the CI smoke
//!    variant that finishes in seconds).
//! 2. **Report** ([`report`]) — [`EnvInfo`] capture (threads, profile,
//!    git SHA from `GITHUB_SHA`/`GIT_SHA`) plus lossless JSON
//!    (de)serialization of every [`crate::util::bench::BenchStats`] under
//!    the [`report::SCHEMA`] tag, via the first-party `util::json`.
//! 3. **Compare** ([`compare`]) — per-case regression verdicts against a
//!    previously-recorded report, gating on `min_s` with per-case
//!    tolerances (and higher-is-better on recorded [`Throughput`]
//!    metrics); [`Comparison::gate`] turns regressions into a nonzero
//!    process exit.
//! 4. **Driver** ([`driver`]) — the shared `wise-share bench` /
//!    `cargo bench` entry point: run suites, write `BENCH_<sha>.json`,
//!    validate (`--check`), and gate (`--baseline --max-regress`).
//!
//! CI runs the quick profile on every push (`bench-smoke` job) and
//! uploads the JSON as a workflow artifact — the perf trajectory the
//! ROADMAP asks the repo to accumulate.

pub mod compare;
pub mod driver;
pub mod registry;
pub mod report;
pub mod suites;

pub use compare::{compare, CaseVerdict, Comparison, Verdict};
pub use driver::{bench_main, check_file, list, run, RunConfig, DEFAULT_MAX_REGRESS_PCT};
pub use registry::{
    all, by_name_or_err, CaseStats, Profile, Recorder, Suite, SuiteReport, Throughput,
    SINGLE_SHOT_TOLERANCE_PCT, SUITE_NAMES,
};
pub use report::{BenchReport, EnvInfo, SCHEMA};

//! `runtime_hotpath` suite — the two hot paths a live run pays for:
//!
//! 1. **Observability overhead** (always measured): the same engine run
//!    three ways — plain `run_cluster`, `run_cluster_obs` with a disabled
//!    handle, and with every in-memory sink armed — pinning obskit's
//!    zero-cost-when-off contract (DESIGN.md §13) as recorded numbers.
//!    The full profile asserts the disabled handle is free (≤5% of the
//!    plain path, i.e. one `Option` branch per tap) and armed sinks stay
//!    under 15% overhead.
//! 2. **PJRT execution** (artifact-gated): compile time, grad_step
//!    latency per micro-batch variant, and full gradient-accumulation
//!    iterations — the L3-side profile used in the §Perf pass
//!    (EXPERIMENTS.md). Requires `make artifacts`; when the artifacts are
//!    absent or the vendored `xla` stub cannot bring a PJRT client up
//!    (every CI runner, see DESIGN.md §4), the PJRT cases are omitted
//!    with a printed note — the obs cases above still land, so the suite
//!    is never skipped outright.

use crate::cluster::{Cluster, ClusterConfig};
use crate::jobs::trace::{self, TraceConfig};
use crate::obskit::Obs;
use crate::perf::interference::InterferenceModel;
use crate::runtime::executor::{TrainExecutor, TrainState};
use crate::runtime::ArtifactSet;
use crate::sched;
use crate::sim::{engine, EngineConfig};

use super::super::registry::{Profile, Recorder, Suite, SuiteReport};

pub fn suite() -> Suite {
    Suite {
        name: "runtime_hotpath",
        description: "obskit overhead + PJRT train-step hot path (PJRT needs `make artifacts`)",
        run,
    }
}

/// One full engine run of `trace` under SJF-BSBF (the policy with the
/// most taps: Algorithm-2 audit lines, share-change trace spans) with the
/// given obs handle.
fn obs_run(trace: &[crate::jobs::JobSpec], obs: Obs) -> f64 {
    let mut p = sched::by_name("SJF-BSBF").expect("registered policy");
    let out = engine::run_cluster_obs(
        Cluster::new(ClusterConfig::simulation()),
        trace,
        InterferenceModel::new(),
        p.as_mut(),
        EngineConfig::default(),
        obs,
    )
    .expect("obs-overhead run");
    out.makespan_s
}

fn run(profile: Profile) -> SuiteReport {
    let mut rec = Recorder::new("runtime_hotpath");

    // ---- obskit overhead: off vs disabled handle vs armed sinks -----------
    let n_jobs = profile.pick(120, 480);
    let obs_trace = trace::generate(&TraceConfig::simulation(n_jobs, 11));
    let iters = profile.pick(2, 4);
    let off = rec.bench(&format!("obs/off/{n_jobs}-jobs"), iters, || {
        let mut p = sched::by_name("SJF-BSBF").expect("registered policy");
        let out = engine::run_cluster(
            Cluster::new(ClusterConfig::simulation()),
            &obs_trace,
            InterferenceModel::new(),
            p.as_mut(),
            EngineConfig::default(),
        )
        .expect("obs-overhead run");
        std::hint::black_box(out.makespan_s);
    });
    rec.tolerance(100.0);
    let disabled = rec.bench(&format!("obs/disabled-handle/{n_jobs}-jobs"), iters, || {
        std::hint::black_box(obs_run(&obs_trace, Obs::disabled()));
    });
    rec.tolerance(100.0);
    let on = rec.bench(&format!("obs/on/{n_jobs}-jobs"), iters, || {
        std::hint::black_box(obs_run(&obs_trace, Obs::in_memory(600.0)));
    });
    rec.tolerance(100.0);
    println!(
        "obs overhead at {n_jobs} jobs: disabled handle {:+.1}%, armed sinks {:+.1}%",
        (disabled.mean_s / off.mean_s.max(1e-12) - 1.0) * 100.0,
        (on.mean_s / off.mean_s.max(1e-12) - 1.0) * 100.0
    );
    if profile == Profile::Full {
        assert!(
            disabled.mean_s <= off.mean_s * 1.05,
            "a disabled Obs handle must be free: {:.4}s vs {:.4}s plain",
            disabled.mean_s,
            off.mean_s
        );
        assert!(
            on.mean_s <= off.mean_s * 1.15,
            "armed in-memory sinks must stay under 15% overhead: {:.4}s vs {:.4}s plain",
            on.mean_s,
            off.mean_s
        );
    }

    // ---- PJRT train-step hot path (artifact-gated) ------------------------
    let dir = ArtifactSet::default_dir();
    if !dir.join("meta.json").exists() {
        println!("note: PJRT cases omitted — artifacts not built (run `make artifacts`)");
        return rec.finish();
    }
    let t0 = std::time::Instant::now();
    let set = match ArtifactSet::load(dir) {
        Ok(set) => set,
        // The offline stub's PJRT client cannot come up; a corrupt
        // artifact set surfaces the same way — the note carries the
        // error so the reader can tell which.
        Err(e) => {
            println!("note: PJRT cases omitted — artifact load failed: {e:#}");
            return rec.finish();
        }
    };
    println!(
        "artifact load+compile (7 executables): {:.2}s (one-off per worker)",
        t0.elapsed().as_secs_f64()
    );
    println!(
        "model: {} params, vocab {}, seq {}",
        set.meta.model.n_params, set.meta.model.vocab, set.meta.model.seq_len
    );

    let mut exec = TrainExecutor::new(&set, 1, 0.1);
    let mut state: TrainState = match exec.init_state() {
        Ok(s) => s,
        Err(e) => {
            println!("note: PJRT cases omitted — PJRT execution unavailable: {e:#}");
            return rec.finish();
        }
    };

    // grad_step latency per compiled micro-batch variant.
    for &mb in &set.meta.micro_batches.clone() {
        let mut st = exec.init_state().expect("init_state succeeded once already");
        rec.bench(&format!("train_step/batch{mb}/s1"), profile.pick(5, 20), || {
            exec.train_step(&mut st, mb, 1).unwrap();
        });
    }

    // Full gradient-accumulation iterations: batch 8 at s = 1, 2, 4, 8.
    for &s in &[1u32, 2, 4, 8] {
        rec.bench(&format!("train_step/batch8/s{s}"), profile.pick(4, 15), || {
            exec.train_step(&mut state, 8, s).unwrap();
        });
    }
    println!(
        "\nnote: s>1 pays (s-1) extra grad_step+accum executions — the Eq. 7\n\
         (s-1)*t_comp(B/s) term the scheduler trades against memory."
    );
    rec.finish()
}

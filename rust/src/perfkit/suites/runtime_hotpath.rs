//! `runtime_hotpath` suite — the PJRT execution hot path the physical
//! coordinator drives: artifact compile time (one-off), grad_step latency
//! per micro-batch variant, and the full gradient-accumulation iteration
//! at several (batch, s) settings.
//!
//! This is the L3-side profile used in the §Perf pass (EXPERIMENTS.md).
//! Requires `make artifacts`; when the artifacts are absent or the
//! vendored `xla` stub cannot bring a PJRT client up (every CI runner,
//! see DESIGN.md §4), the suite reports itself *skipped* instead of
//! failing — same policy as the artifact-dependent tests in `runtime/`.

use crate::runtime::executor::{TrainExecutor, TrainState};
use crate::runtime::ArtifactSet;

use super::super::registry::{Profile, Recorder, Suite, SuiteReport};

pub fn suite() -> Suite {
    Suite {
        name: "runtime_hotpath",
        description: "PJRT train-step hot path (needs `make artifacts`; skips offline)",
        run,
    }
}

fn run(profile: Profile) -> SuiteReport {
    let mut rec = Recorder::new("runtime_hotpath");
    let dir = ArtifactSet::default_dir();
    if !dir.join("meta.json").exists() {
        return rec.skip("artifacts not built (run `make artifacts`)".to_string());
    }
    let t0 = std::time::Instant::now();
    let set = match ArtifactSet::load(dir) {
        Ok(set) => set,
        // The offline stub's PJRT client cannot come up; a corrupt
        // artifact set surfaces the same way — the skip reason carries
        // the error so the reader can tell which.
        Err(e) => return rec.skip(format!("artifact load failed: {e:#}")),
    };
    println!(
        "artifact load+compile (7 executables): {:.2}s (one-off per worker)",
        t0.elapsed().as_secs_f64()
    );
    println!(
        "model: {} params, vocab {}, seq {}",
        set.meta.model.n_params, set.meta.model.vocab, set.meta.model.seq_len
    );

    let mut exec = TrainExecutor::new(&set, 1, 0.1);
    let mut state: TrainState = match exec.init_state() {
        Ok(s) => s,
        Err(e) => return rec.skip(format!("PJRT execution unavailable: {e:#}")),
    };

    // grad_step latency per compiled micro-batch variant.
    for &mb in &set.meta.micro_batches.clone() {
        let mut st = exec.init_state().expect("init_state succeeded once already");
        rec.bench(&format!("train_step/batch{mb}/s1"), profile.pick(5, 20), || {
            exec.train_step(&mut st, mb, 1).unwrap();
        });
    }

    // Full gradient-accumulation iterations: batch 8 at s = 1, 2, 4, 8.
    for &s in &[1u32, 2, 4, 8] {
        rec.bench(&format!("train_step/batch8/s{s}"), profile.pick(4, 15), || {
            exec.train_step(&mut state, 8, s).unwrap();
        });
    }
    println!(
        "\nnote: s>1 pays (s-1) extra grad_step+accum executions — the Eq. 7\n\
         (s-1)*t_comp(B/s) term the scheduler trades against memory."
    );
    rec.finish()
}

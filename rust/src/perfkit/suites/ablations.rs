//! `ablations` suite — SJF-BSBF's three design choices, each disabled in
//! isolation on the contended trace (DESIGN.md per-experiment index):
//!
//! 1. **Theorem-1 gate** off → accept every memory-feasible share.
//! 2. **Batch-size sweep** off → no gradient accumulation.
//! 3. **Benefit sorting** off → arbitrary partner order (Alg. 1 line 14).
//!
//! Quick profile runs 120 jobs (named in the cases) and skips the quality
//! assertion — its 0.98 bound is calibrated on the 240-job trace.

use crate::cluster::ClusterConfig;
use crate::jobs::trace::{self, TraceConfig};
use crate::jobs::JobSpec;
use crate::perf::interference::InterferenceModel;
use crate::sched::SjfBsbf;
use crate::sim::{engine, metrics, Policy};

use super::super::registry::{Profile, Recorder, Suite, SuiteReport};

pub fn suite() -> Suite {
    Suite {
        name: "ablations",
        description: "SJF-BSBF design-choice ablations on the contended trace",
        run,
    }
}

fn run(profile: Profile) -> SuiteReport {
    let mut rec = Recorder::new("ablations");
    let n = profile.pick(120, 240);
    let mut tcfg = TraceConfig::simulation(n, 1);
    tcfg.load_factor = 1.5; // contended: sharing decisions matter
    let jobs = trace::generate(&tcfg);

    println!("SJF-BSBF ablations, {n} jobs @ 1.5x density, 64 GPUs:\n");
    let full = variant(&mut rec, n, "full-paper", SjfBsbf::default(), &jobs);
    let no_gate = variant(
        &mut rec,
        n,
        "no-theorem1-gate",
        SjfBsbf { theorem1_gate: false, ..SjfBsbf::default() },
        &jobs,
    );
    let no_sweep = variant(
        &mut rec,
        n,
        "no-batch-size-sweep",
        SjfBsbf { sweep_batches: false, ..SjfBsbf::default() },
        &jobs,
    );
    let no_sort = variant(
        &mut rec,
        n,
        "no-benefit-sorting",
        SjfBsbf { sort_by_benefit: false, ..SjfBsbf::default() },
        &jobs,
    );

    println!(
        "\ndeltas vs full: gate {:+.1}%, sweep {:+.1}%, sort {:+.1}%",
        (no_gate / full - 1.0) * 100.0,
        (no_sweep / full - 1.0) * 100.0,
        (no_sort / full - 1.0) * 100.0
    );
    if profile == Profile::Full {
        assert!(
            no_gate >= full * 0.98,
            "removing the Theorem-1 gate should not improve BSBF materially"
        );
    }
    rec.finish()
}

fn variant(
    rec: &mut Recorder,
    n_jobs: usize,
    name: &str,
    mut policy: SjfBsbf,
    jobs: &[JobSpec],
) -> f64 {
    let mut avg_jct = 0.0;
    rec.once(&format!("ablations/{n_jobs}-jobs/{name}"), || {
        let out = engine::run(
            ClusterConfig::simulation(),
            jobs,
            InterferenceModel::new(),
            &mut policy as &mut dyn Policy,
        )
        .expect("simulation failed");
        let s = metrics::summarize(name, &out.jobs, out.makespan_s);
        println!(
            "{name:<28} avg JCT {:>7.3} hrs   queue {:>6.3} hrs   makespan {:>7.2} hrs",
            s.all.avg_jct_s / 3600.0,
            s.all.avg_queue_s / 3600.0,
            s.makespan_s / 3600.0
        );
        avg_jct = s.all.avg_jct_s;
    });
    avg_jct
}

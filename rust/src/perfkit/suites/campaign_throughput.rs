//! `campaign_throughput` suite — scenario-sweep throughput on the
//! multi-seed policy matrix shape every table/figure sweep uses:
//!
//! * **per-run-generation** — every run generates its own trace, the
//!   pre-perfkit behavior (`ScenarioSpec::run`).
//! * **shared-trace-serial** — the runner's hot path: one generation per
//!   (cell, seed) group, shared across the policy axis via `Arc`.
//! * **parallel-pool** — the same shared-trace matrix over the worker
//!   pool; on an N-core box this should approach min(N, runs)× serial.
//!
//! Each case re-expands the matrix inside the timed closure so every
//! iteration pays trace generation afresh (shared traces are memoized per
//! expansion — reusing one expansion would time a warm cache only).

use crate::campaign::{self, Axes, CampaignSpec};

use super::super::registry::{Profile, Recorder, Suite, SuiteReport};

pub fn suite() -> Suite {
    Suite {
        name: "campaign_throughput",
        description: "campaign runner: trace sharing + worker-pool speedup",
        run,
    }
}

fn run(profile: Profile) -> SuiteReport {
    let mut rec = Recorder::new("campaign_throughput");
    let (n_jobs, n_seeds): (usize, u64) = profile.pick((30, 2), (120, 6));
    let mut spec = CampaignSpec::new("bench");
    spec.policies = vec!["SJF".to_string(), "SJF-BSBF".to_string()];
    spec.axes = Axes {
        load_factors: vec![1.0],
        job_counts: vec![n_jobs],
        gpu_counts: Vec::new(),
        topologies: Vec::new(),
        workloads: Vec::new(),
        estimators: Vec::new(),
        share_caps: Vec::new(),
        seeds: (1..=n_seeds).collect(),
        jobs_scale_load_baseline: None,
    };
    let tag = format!("2pol-{n_seeds}seeds-{n_jobs}jobs");
    let threads = campaign::default_threads();
    let n_runs = campaign::expand(&spec).expect("valid spec").len();
    println!(
        "matrix: {n_runs} runs (2 policies x {n_seeds} seeds, {n_jobs} jobs), \
         {threads} worker thread(s)"
    );
    let iters = profile.pick(1, 3);

    let per_run = rec.bench(&format!("campaign/per-run-generation/{tag}"), iters, || {
        let points = campaign::expand(&spec).expect("valid spec");
        for p in &points {
            p.scenario.run().expect("run succeeded");
        }
    });
    let serial = rec.bench(&format!("campaign/shared-trace-serial/{tag}"), iters, || {
        let points = campaign::expand(&spec).expect("valid spec");
        let out = campaign::run_serial(&points);
        assert!(out.iter().all(|o| o.summary.is_ok()));
    });
    let parallel = rec.bench(&format!("campaign/parallel-pool/{tag}"), iters, || {
        let points = campaign::expand(&spec).expect("valid spec");
        let out = campaign::run_parallel(&points, threads);
        assert!(out.iter().all(|o| o.summary.is_ok()));
    });
    // Worker-pool wall time varies with the runner's core count — give
    // the case headroom so a 2-core CI box doesn't trip the default gate.
    rec.tolerance(100.0);
    println!(
        "trace-sharing speedup: {:.2}x (per-run mean {:.3}s -> shared mean {:.3}s)",
        per_run.mean_s / serial.mean_s.max(1e-12),
        per_run.mean_s,
        serial.mean_s
    );
    println!(
        "parallel speedup: {:.2}x (serial mean {:.3}s -> parallel mean {:.3}s)",
        serial.mean_s / parallel.mean_s.max(1e-12),
        serial.mean_s,
        parallel.mean_s
    );
    rec.finish()
}

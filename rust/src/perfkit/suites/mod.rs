//! The registered benchmark suites — one module per `cargo bench` target.
//!
//! Each module exposes `suite() -> Suite`; the suite body is the code the
//! corresponding `benches/*.rs` wrapper used to contain, parameterized by
//! [`super::registry::Profile`] so CI can run a seconds-scale smoke
//! variant of the exact same cases (`quick`) while developers keep the
//! paper-scale runs (`full`). Case names embed any size that differs
//! between profiles, so quick and full reports never alias in a baseline
//! comparison.

pub mod ablations;
pub mod campaign_throughput;
pub mod figures;
pub mod runtime_hotpath;
pub mod scale;
pub mod scale_xl;
pub mod sched_overhead;
pub mod serve;
pub mod tables;

//! `sched_overhead` suite — the paper's §V-4 claim: "the overhead of
//! periodically scheduling those waiting jobs is negligible, averaging
//! below 0.02 seconds for each operation" on a 16-GPU cluster.
//!
//! Measures one SJF-BSBF event pass (full Algorithm 1 incl. Algorithm 2
//! sweeps and Theorem-1 evaluations) on a *busy* cluster for the paper's
//! 16-GPU testbed and the 64-GPU simulation cluster, the decision kernel
//! and Algorithm 2 in isolation, plus the `sched_core` machinery at
//! scale: heap-vs-rescan next-event selection, the cached estimate table,
//! clone-vs-overlay planning views, and end-to-end event-loop throughput.
//! The quick profile shrinks the at-scale contexts (sizes are in the case
//! names); the §V-4 assertion only arms in the full profile — CI smoke
//! runners are too noisy to gate a 20 ms wall bound on.

use crate::cluster::{AllocView, Cluster, ClusterConfig};
use crate::jobs::trace::{self, TraceConfig};
use crate::jobs::{JobRecord, JobState};
use crate::obskit::Obs;
use crate::pair::{batch_size_scaling, best_pair_schedule, PairSide};
use crate::perf::interference::InterferenceModel;
use crate::perf::profiles::ModelKind;
use crate::sched::{self, SjfBsbf};
use crate::sim::{engine, EngineConfig, Event, Policy, SchedContext, SimState};
use crate::util::bench::stats_of;

use super::super::registry::{Profile, Recorder, Suite, SuiteReport};

pub fn suite() -> Suite {
    Suite {
        name: "sched_overhead",
        description: "scheduling-pass cost (paper §V-4) + sched_core machinery at scale",
        run,
    }
}

/// Build a saturated world: every GPU busy with one job + `n_pending`
/// waiting jobs, so a scheduling pass exercises the full sharing search.
fn busy_state(cluster_cfg: ClusterConfig, n_pending: usize) -> SimState {
    let total = cluster_cfg.total_gpus();
    let n_running = total / 4; // 4-GPU gangs fill every slot with one job
    let trace_cfg = TraceConfig::simulation(n_running + n_pending, 9);
    let mut jobs: Vec<JobRecord> = trace::generate(&trace_cfg)
        .into_iter()
        .map(JobRecord::new)
        .collect();
    let mut cluster = Cluster::new(cluster_cfg);
    for (i, job) in jobs.iter_mut().enumerate().take(n_running) {
        job.spec.gpus = 4;
        let gpus: Vec<usize> = (i * 4..i * 4 + 4).collect();
        cluster.allocate(i, &gpus);
        job.state = JobState::Running;
        job.gpus_held = gpus;
        job.spec.arrival_s = 0.0;
    }
    for job in jobs.iter_mut().skip(n_running) {
        job.spec.arrival_s = 0.0; // all pending now
        job.spec.gpus = job.spec.gpus.min(total);
    }
    let n = jobs.len();
    SimState {
        now: 1.0,
        cluster,
        jobs,
        xi: InterferenceModel::new(),
        not_before: vec![0.0; n],
        service_gpu_s: vec![0.0; n],
    }
}

fn run(profile: Profile) -> SuiteReport {
    let mut rec = Recorder::new("sched_overhead");

    // The decision kernel: one Theorem-1 evaluation.
    rec.bench("theorem1/single-pair", profile.pick(2_000, 10_000), || {
        let s = best_pair_schedule(
            PairSide { iter_time: 0.21, iters: 4000.0, xi: 1.4 },
            PairSide { iter_time: 0.35, iters: 9000.0, xi: 1.7 },
        );
        std::hint::black_box(s.avg_jct);
    });

    // Algorithm 2: full sub-batch sweep for one candidate pair.
    let new = JobRecord::new(crate::jobs::JobSpec {
        id: 0,
        model: ModelKind::Bert,
        gpus: 4,
        iterations: 2000,
        batch: 16,
        arrival_s: 0.0,
        est_factor: 1.0,
    });
    let running = JobRecord::new(crate::jobs::JobSpec {
        id: 1,
        model: ModelKind::Cifar10,
        gpus: 4,
        iterations: 8000,
        batch: 128,
        arrival_s: 0.0,
        est_factor: 1.0,
    });
    let xi = InterferenceModel::new();
    rec.bench(
        "algorithm2/batch-size-scaling",
        profile.pick(2_000, 10_000),
        || {
            std::hint::black_box(batch_size_scaling(&new, &running, 4, 11.0, &xi));
        },
    );

    // Full Algorithm 1 pass on the paper's 16-GPU testbed (§V-4 claim),
    // delivered through the event API against a prebuilt SchedContext.
    let ctx16 = SchedContext::from_state(busy_state(ClusterConfig::physical(), 8));
    let mut policy = SjfBsbf::default();
    let stats = rec.bench("sjf-bsbf/event-pass/16-gpu-busy", profile.pick(50, 200), || {
        std::hint::black_box(policy.on_event(&ctx16, Event::Tick));
    });
    if profile == Profile::Full {
        assert!(
            stats.mean_s < 0.02,
            "paper claims < 0.02 s per scheduling op; measured {:.4}s",
            stats.mean_s
        );
        println!(
            "PASS: {:.3} ms mean < 20 ms (paper's §V-4 bound)",
            stats.mean_s * 1e3
        );
    }

    // And on the 64-GPU simulation cluster with a deep queue.
    let ctx64 = SchedContext::from_state(busy_state(ClusterConfig::simulation(), 32));
    let mut policy = SjfBsbf::default();
    rec.bench("sjf-bsbf/event-pass/64-gpu-busy", profile.pick(25, 100), || {
        std::hint::black_box(policy.on_event(&ctx64, Event::Tick));
    });

    // ---- heap vs rescan: next-event selection at scale --------------------
    // Full: 2048 running 4-GPU jobs on an 8192-GPU cluster (quick: 256 on
    // 1024). The old engine found the next completion by rescanning every
    // running job per event; the context's finish-time min-heap answers
    // the same query from its top.
    let servers = profile.pick(256, 2048);
    let huge = ClusterConfig {
        servers,
        gpus_per_server: 4,
        gpu_mem_gb: 11.0,
        max_share: 2,
    };
    let mut ctx = SchedContext::from_state(busy_state(huge, 0));
    let n_running = ctx.running().len();
    let heap = rec.bench(
        &format!("event-select/heap/{n_running}-running"),
        profile.pick(2_000, 10_000),
        || {
            std::hint::black_box(ctx.next_finish());
        },
    );
    // The pre-redesign per-event scan, reproduced over the same context
    // (few iterations: one pass walks every running job's whole gang
    // neighbourhood, which is exactly why the engine no longer does it).
    let state = ctx.state();
    let rescan = rec.bench(
        &format!("event-select/rescan/{n_running}-running"),
        profile.pick(20, 50),
        || {
            let mut t_next = f64::INFINITY;
            for &id in state.running().iter() {
                let it = state.effective_iter_time(id);
                let finish = state.now + state.jobs[id].remaining_iters * it;
                t_next = t_next.min(finish);
            }
            std::hint::black_box(t_next);
        },
    );
    println!(
        "event-loop speedup: heap next-event is {:.0}x faster than the old \
         O(running) rescan at {} running jobs",
        rescan.mean_s / heap.mean_s.max(1e-12),
        n_running
    );

    // ---- estimate cache vs recompute: the SJF-family sort key -------------
    // Every SJF-family pass reads the estimated remaining runtime O(n log n)
    // times. The context caches the per-iteration factor
    // (iter_time(accum) × est_factor), so the key is one multiply; the
    // recompute case walks the workload profile on every read — what a
    // cache-less policy would pay.
    let ids: Vec<usize> = ctx.running().to_vec();
    let cached = rec.bench(
        &format!("estimate/cached/{n_running}-running"),
        profile.pick(500, 2_000),
        || {
            let mut acc = 0.0;
            for &id in &ids {
                acc += ctx.estimated_remaining(id);
            }
            std::hint::black_box(acc);
        },
    );
    let recompute = rec.bench(
        &format!("estimate/recompute/{n_running}-running"),
        profile.pick(50, 200),
        || {
            let mut acc = 0.0;
            for &id in &ids {
                let j = &ctx.jobs[id];
                acc += j.spec.estimated_iter_time(j.accum_step) * j.remaining_iters;
            }
            std::hint::black_box(acc);
        },
    );
    println!(
        "estimate-key speedup: the cached table is {:.0}x cheaper than the \
         per-read profile walk at {} running jobs",
        recompute.mean_s / cached.mean_s.max(1e-12),
        ids.len()
    );

    // ---- clone vs overlay: the policy planning view -----------------------
    // Every full-pass policy plans hypothetical placements per event. The
    // old way deep-copied the cluster (one heap allocation per GPU slot);
    // the context's overlay records deltas over a borrow with pooled
    // scratch. Both cases acquire the view, read the occupancy classes and
    // hypothetically place one 4-gang — the per-event pattern.
    let big = ClusterConfig {
        servers: profile.pick(128, 512),
        gpus_per_server: 4,
        gpu_mem_gb: 11.0,
        max_share: 2,
    };
    let n_gpus = big.total_gpus();
    let ctx2k = SchedContext::from_state(busy_state(big, 64));
    let one_job_target = ctx2k.cluster.one_job_gpus()[0..4].to_vec();
    let clone_stats = rec.bench(
        &format!("plan-view/clone/{n_gpus}-gpus"),
        profile.pick(100, 300),
        || {
            let mut cluster = ctx2k.cluster.clone();
            cluster.allocate(usize::MAX, &one_job_target);
            std::hint::black_box((cluster.free_count(), cluster.one_job_count()));
        },
    );
    let overlay_stats = rec.bench(
        &format!("plan-view/overlay/{n_gpus}-gpus"),
        profile.pick(5_000, 20_000),
        || {
            let mut plan = ctx2k.overlay();
            plan.allocate(usize::MAX, &one_job_target);
            std::hint::black_box((plan.free_count(), plan.one_job_count()));
        },
    );
    println!(
        "plan-view speedup: overlay is {:.0}x cheaper than a full cluster \
         clone at {} GPUs",
        clone_stats.mean_s / overlay_stats.mean_s.max(1e-12),
        n_gpus
    );

    // ---- end-to-end event loop on a large trace ---------------------------
    // Jobs through the full engine under exclusive SJF (cheap policy, so
    // the engine's event machinery dominates): records absolute event-loop
    // throughput for the redesigned engine.
    let n_jobs = profile.pick(256, 2048);
    let big_trace = trace::generate(&TraceConfig::simulation(n_jobs, 5));
    let mut calls = 0u64;
    let full = rec.bench(
        &format!("engine/event-loop/{n_jobs}-jobs"),
        profile.pick(2, 3),
        || {
            let mut p = sched::by_name("SJF").unwrap();
            let out = engine::run(
                ClusterConfig::simulation(),
                &big_trace,
                InterferenceModel::new(),
                p.as_mut(),
            )
            .expect("large-trace run");
            calls = out.policy_calls;
            std::hint::black_box(out.makespan_s);
        },
    );
    println!(
        "engine/event-loop/{n_jobs}-jobs: {} events per run, {:.0} events/s",
        calls,
        calls as f64 / full.mean_s.max(1e-12)
    );

    // ---- per-policy on_event latency distributions (obskit) ---------------
    // The §V-4 overhead claim for *every* policy, not just SJF-BSBF: run
    // the full engine with an in-memory obs handle and fold the recorded
    // `on_event_latency/<policy>` histogram — one wall-clock sample per
    // engine event, exactly what the coordinator would pay live — into a
    // bench case. Tolerance is generous: these are single-run wall-clock
    // latencies, not tight micro-bench loops.
    let n_lat_jobs = profile.pick(60, 240);
    let lat_trace = trace::generate(&TraceConfig::simulation(n_lat_jobs, 7));
    for name in sched::POLICY_NAMES {
        let obs = Obs::in_memory(3600.0);
        let mut p = sched::by_name(name).expect("registered policy");
        engine::run_cluster_obs(
            Cluster::new(ClusterConfig::simulation()),
            &lat_trace,
            InterferenceModel::new(),
            p.as_mut(),
            EngineConfig::default(),
            obs.clone(),
        )
        .expect("obs-instrumented run");
        let samples = obs
            .histogram_samples(&format!("on_event_latency/{name}"))
            .expect("engine recorded a latency histogram for every policy");
        assert!(!samples.is_empty(), "{name}: empty on_event latency histogram");
        let stats = stats_of(
            &format!("on-event-latency/{name}/{n_lat_jobs}-jobs"),
            samples,
        );
        println!("{}", stats.report());
        rec.record(stats);
        rec.tolerance(400.0);
    }

    rec.finish()
}

//! `figures` suite — regenerates every *figure* in the paper's evaluation
//! and times each section end-to-end (single timed pass per section —
//! these are whole-sweep regenerations, long enough to be stable):
//!
//! * **Fig. 2** — solo throughput vs (GPUs, batch) + §IV-B fit fidelity.
//! * **Fig. 3** — paired throughput / ξ landscape vs CIFAR10.
//! * **Fig. 4a/4b** — physical-workload JCT CDF + queueing by model.
//! * **Fig. 5a/5b** — simulation JCT CDF + queueing (full profile).
//! * **Fig. 6a**   — avg JCT vs workload intensity (full profile).
//! * **Fig. 6b**   — avg JCT vs injected ξ (full profile).
//!
//! Output: CSV series (`name,x,y`) ready to plot, plus shape checks.

use crate::cluster::ClusterConfig;
use crate::jobs::trace::{self, TraceConfig};
use crate::perf::fit;
use crate::perf::interference::InterferenceModel;
use crate::perf::profiles::{ModelKind, WorkloadProfile};
use crate::report::csv_series;
use crate::sched::{self, POLICY_NAMES};
use crate::sim::{engine, metrics};

use super::super::registry::{Profile, Recorder, Suite, SuiteReport};

pub fn suite() -> Suite {
    Suite {
        name: "figures",
        description: "paper Figs. 2-6 as CSV series, timing each regeneration",
        run,
    }
}

fn run(profile: Profile) -> SuiteReport {
    let mut rec = Recorder::new("figures");
    rec.once("figures/fig2-solo-throughput", fig2);
    rec.once("figures/fig3-xi-landscape", fig3);
    rec.once("figures/fig4-physical-cdf", || {
        fig45("fig4", ClusterConfig::physical(), &TraceConfig::physical(1));
    });
    if profile == Profile::Full {
        rec.once("figures/fig5-sim-240-cdf", || {
            fig45("fig5", ClusterConfig::simulation(), &TraceConfig::simulation(240, 1));
        });
        rec.once("figures/fig6a-intensity-sweep", fig6a);
        rec.once("figures/fig6b-xi-sweep", fig6b);
    }
    rec.finish()
}

fn fig2() {
    println!("# Fig. 2: solo throughput (samples/s) vs batch, per model x GPUs");
    for kind in ModelKind::ALL {
        let prof = WorkloadProfile::get(kind);
        for n in [1usize, 4, 8, 16] {
            let pts: Vec<(f64, f64)> = [4u32, 8, 16, 32, 64]
                .iter()
                .filter(|&&b| prof.mem.mem_gb(b as f64) <= 11.0)
                .map(|&b| (b as f64, prof.perf.throughput(b as f64, 1, n)))
                .collect();
            print!("{}", csv_series(&format!("fig2,{},{}gpu", kind.name(), n), &pts));
        }
        // §IV-B fidelity: fit Eq. 3 from the profile's own samples.
        let samples: Vec<fit::Sample> = [2u32, 4, 8, 16]
            .iter()
            .map(|&b| fit::Sample {
                batch: b as f64,
                iter_time_s: prof.perf.comp.t_comp(b as f64),
            })
            .collect();
        let fitted = fit::fit_comp(&samples).unwrap();
        let obs: Vec<(f64, usize, f64)> = [(4.0, 4usize), (8.0, 8), (16.0, 16)]
            .iter()
            .map(|&(b, n)| (b, n, prof.perf.iter_time(b, 1, n)))
            .collect();
        let err = fit::relative_error(&prof.perf, &obs);
        println!(
            "# fit {}: alpha {:.4} beta {:.5} (rel-err vs profile {:.2}%)",
            kind.name(),
            fitted.alpha,
            fitted.beta,
            err * 100.0
        );
    }
}

fn fig3() {
    println!("\n# Fig. 3: xi landscape for pairs vs CIFAR10 (and worst-case table)");
    let xi = InterferenceModel::new();
    let pts: Vec<(f64, f64)> = ModelKind::ALL
        .iter()
        .enumerate()
        .map(|(i, &kind)| (i as f64, xi.xi(kind, ModelKind::Cifar10)))
        .collect();
    print!("{}", csv_series("fig3,vs-cifar10", &pts));
    let mut worst: f64 = 0.0;
    for a in ModelKind::ALL {
        for b in ModelKind::ALL {
            worst = worst.max(xi.xi(a, b));
        }
    }
    println!("# worst pair xi = {worst:.2} (paper: ratios range up to ~6)");
}

fn fig45(label: &str, cluster: ClusterConfig, tcfg: &TraceConfig) {
    println!("\n# {label}: JCT CDF (a) + queueing by model (b)");
    let jobs = trace::generate(tcfg);
    for name in POLICY_NAMES {
        let mut p = sched::by_name(name).unwrap();
        let out = engine::run(cluster, &jobs, InterferenceModel::new(), p.as_mut())
            .expect("simulation failed");
        let cdf = metrics::jct_cdf(&out.jobs);
        let step = (cdf.len() / 16).max(1);
        let pts: Vec<(f64, f64)> = cdf.iter().step_by(step).copied().collect();
        print!("{}", csv_series(&format!("{label}a,{name}"), &pts));
        let by: Vec<(f64, f64)> = metrics::queueing_by_model(&out.jobs)
            .iter()
            .enumerate()
            .map(|(i, (_, q))| (i as f64, *q))
            .collect();
        print!("{}", csv_series(&format!("{label}b,{name}"), &by));
    }
}

fn fig6a() {
    println!("\n# Fig. 6a: avg JCT (hrs) vs workload intensity");
    for name in POLICY_NAMES {
        let mut pts = Vec::new();
        for scale in [0.5, 1.0, 1.5, 2.0] {
            let n_jobs = (240.0 * scale) as usize;
            let mut tcfg = TraceConfig::simulation(n_jobs, 1);
            tcfg.load_factor = scale;
            let jobs = trace::generate(&tcfg);
            let mut p = sched::by_name(name).unwrap();
            let out = engine::run(
                ClusterConfig::simulation(),
                &jobs,
                InterferenceModel::new(),
                p.as_mut(),
            )
            .expect("simulation failed");
            let s = metrics::summarize(name, &out.jobs, out.makespan_s);
            pts.push((n_jobs as f64, s.all.avg_jct_s / 3600.0));
        }
        print!("{}", csv_series(&format!("fig6a,{name}"), &pts));
    }
}

fn fig6b() {
    println!("\n# Fig. 6b: avg JCT (hrs) vs injected xi, sharing policies");
    let jobs = trace::generate(&TraceConfig::simulation(240, 1));
    let mut ffs_at_20 = 0.0;
    let mut bsbf_at_20 = 0.0;
    for name in ["SJF-FFS", "SJF-BSBF"] {
        let mut pts = Vec::new();
        for xi in [1.0, 1.25, 1.5, 1.75, 2.0] {
            let mut p = sched::by_name(name).unwrap();
            let out = engine::run(
                ClusterConfig::simulation(),
                &jobs,
                InterferenceModel::with_global(xi),
                p.as_mut(),
            )
            .expect("simulation failed");
            let s = metrics::summarize(name, &out.jobs, out.makespan_s);
            pts.push((xi, s.all.avg_jct_s / 3600.0));
            if xi == 2.0 {
                if name == "SJF-FFS" {
                    ffs_at_20 = s.all.avg_jct_s;
                } else {
                    bsbf_at_20 = s.all.avg_jct_s;
                }
            }
        }
        print!("{}", csv_series(&format!("fig6b,{name}"), &pts));
    }
    println!(
        "# shape check @ xi=2.0: BSBF/FFS = {:.3} (paper: BSBF 8-13% lower)",
        bsbf_at_20 / ffs_at_20
    );
}

//! `tables` suite — regenerates every *table* in the paper's evaluation
//! (§VI), timing each per-policy simulation:
//!
//! * **Table II**  — 30-job physical workload on 4x4 GPUs (simulated here;
//!   the PJRT-executing variant is `examples/physical_cluster.rs`).
//! * **Table III** — 240-job simulation: all/large/small JCT + queueing
//!   (120 jobs in the quick profile; the case name carries the size).
//! * **Table IV**  — 480-job simulation at 2x arrival density (full only).

use crate::cluster::ClusterConfig;
use crate::jobs::trace::{self, TraceConfig};
use crate::perf::interference::InterferenceModel;
use crate::report;
use crate::sched::{self, POLICY_NAMES};
use crate::sim::{engine, metrics};

use super::super::registry::{Profile, Recorder, Suite, SuiteReport};

pub fn suite() -> Suite {
    Suite {
        name: "tables",
        description: "paper Tables II-IV, timing each per-policy simulation",
        run,
    }
}

fn run(profile: Profile) -> SuiteReport {
    let mut rec = Recorder::new("tables");
    let iters = profile.pick(1, 3);
    table(
        &mut rec,
        iters,
        "table2/physical-30-jobs",
        ClusterConfig::physical(),
        &TraceConfig::physical(1),
        true,
    );
    let n3 = profile.pick(120, 240);
    table(
        &mut rec,
        iters,
        &format!("table3/sim-{n3}-jobs"),
        ClusterConfig::simulation(),
        &TraceConfig::simulation(n3, 1),
        false,
    );
    if profile == Profile::Full {
        let mut t4 = TraceConfig::simulation(480, 1);
        t4.load_factor = 2.0;
        table(
            &mut rec,
            iters,
            "table4/sim-480-jobs-2x",
            ClusterConfig::simulation(),
            &t4,
            false,
        );
    }
    rec.finish()
}

fn table(
    rec: &mut Recorder,
    iters: usize,
    label: &str,
    cluster: ClusterConfig,
    tcfg: &TraceConfig,
    table2_style: bool,
) {
    let jobs = trace::generate(tcfg);
    let mut rows = Vec::new();
    for name in POLICY_NAMES {
        let mut summary = None;
        rec.bench(&format!("{label}/{name}"), iters, || {
            let mut p = sched::by_name(name).unwrap();
            let out = engine::run(cluster, &jobs, InterferenceModel::new(), p.as_mut())
                .expect("simulation failed");
            summary = Some(metrics::summarize(name, &out.jobs, out.makespan_s));
        });
        rows.push(summary.unwrap());
    }
    println!("\n=== {label} ===");
    if table2_style {
        println!("{}", report::table2(&rows));
    } else {
        println!("{}", report::table34(&rows));
    }
}

//! `scale_xl` suite — the million-job event core, gated on throughput.
//!
//! Where the `scale` suite answers "does the engine keep up at Philly
//! trace sizes", this one pins the asymptotics: the lazy-integration +
//! calendar-queue event core (DESIGN.md §15) is what makes a 1M-job /
//! 100k-GPU trace tractable at all, and these cases are the regression
//! net around it. Events/sec and jobs/sec are recorded as first-class
//! metrics ([`Recorder::throughput`]) and gated higher-is-better by
//! `bench --baseline` alongside the wall-clock minimum, so an accidental
//! return to per-event O(running) sweeps fails CI instead of silently
//! tripling the smoke job's runtime.
//!
//! Tiers:
//! * `quick` — a 100k-job SJF run on 4096 uniform GPUs plus a modest
//!   SJF-BSBF case (sharing keeps Alg. 1's quadratic pending scan in the
//!   loop). Seconds-scale; CI's `scale-smoke` leg runs it on every push.
//! * `full` — the headline: 1M jobs over 100k GPUs (25k uniform
//!   4-GPU servers), single timed pass. Minutes-scale; developers run it
//!   before touching the event core.
//!
//! Trace generation is untimed; the recorded region is the engine run
//! only, so the numbers isolate event dispatch + policy calls.

use crate::cluster::{Cluster, ClusterConfig};
use crate::jobs::trace::{self, TraceConfig};
use crate::jobs::workload;
use crate::perf::interference::InterferenceModel;
use crate::sched;
use crate::sim::{engine, EngineConfig};

use super::super::registry::{Profile, Recorder, Suite, SuiteReport};

pub fn suite() -> Suite {
    Suite {
        name: "scale_xl",
        description: "100k-1M-job traces; events/s + jobs/s gated as first-class metrics",
        run,
    }
}

fn uniform(servers: usize) -> Cluster {
    Cluster::new(ClusterConfig {
        servers,
        gpus_per_server: 4,
        gpu_mem_gb: 11.0,
        max_share: 2,
    })
}

/// One xl case: generate the preset trace (untimed), run the policy
/// through the full engine (timed), record events/s + jobs/s.
fn case(
    rec: &mut Recorder,
    policy: &str,
    shape: &str,
    cluster: Cluster,
    preset: &str,
    n_jobs: usize,
) {
    let cfg = TraceConfig::from_preset(
        &workload::by_name(preset).expect("registry preset"),
        n_jobs,
        1,
    );
    let jobs = trace::generate(&cfg);
    let name = format!("scale_xl/{}/{shape}/{n_jobs}-{preset}", policy.to_lowercase());
    let mut events = 0u64;
    let stats = rec.once(&name, || {
        let mut p = sched::by_name(policy).expect("registry policy");
        let out = engine::run_cluster(
            cluster,
            &jobs,
            InterferenceModel::new(),
            p.as_mut(),
            EngineConfig::default(),
        )
        .expect("scale_xl run");
        events = out.policy_calls;
        std::hint::black_box(out.makespan_s);
    });
    let wall = stats.mean_s.max(1e-12);
    let events_per_s = events as f64 / wall;
    let jobs_per_s = n_jobs as f64 / wall;
    rec.throughput(events_per_s, jobs_per_s);
    println!("  {name}: {events} events, {events_per_s:.0} events/s, {jobs_per_s:.0} jobs/s");
}

fn run(profile: Profile) -> SuiteReport {
    let mut rec = Recorder::new("scale_xl");
    match profile {
        Profile::Quick => {
            // CI tier: 100k jobs over 4096 GPUs exercises the calendar
            // queue's rebuild path and the lazy ledger at real depth while
            // staying seconds-scale.
            case(
                &mut rec,
                "SJF",
                "uniform-1024x4",
                uniform(1024),
                "small-job-flood",
                100_000,
            );
            // Sharing machinery at depth: overlays + pairwise search keep
            // the reproject/settle path hot (bounded size — Alg. 1 is
            // quadratic in the pending queue).
            case(
                &mut rec,
                "SJF-BSBF",
                "uniform-64x4",
                uniform(64),
                "small-job-flood",
                5_000,
            );
        }
        Profile::Full => {
            // The headline case: 1M jobs on a 100k-GPU datacenter.
            case(
                &mut rec,
                "SJF",
                "uniform-25000x4",
                uniform(25_000),
                "small-job-flood",
                1_000_000,
            );
        }
    }
    rec.finish()
}

//! `scale_xl` suite — the million-job event core, gated on throughput.
//!
//! Where the `scale` suite answers "does the engine keep up at Philly
//! trace sizes", this one pins the asymptotics: the lazy-integration +
//! calendar-queue event core (DESIGN.md §15) is what makes a 1M-job /
//! 100k-GPU trace tractable at all, and these cases are the regression
//! net around it. Events/sec and jobs/sec are recorded as first-class
//! metrics ([`Recorder::throughput`]) and gated higher-is-better by
//! `bench --baseline` alongside the wall-clock minimum, so an accidental
//! return to per-event O(running) sweeps fails CI instead of silently
//! tripling the smoke job's runtime.
//!
//! Tiers:
//! * `quick` — a 100k-job SJF run on 4096 uniform GPUs plus a modest
//!   SJF-BSBF case (sharing keeps Alg. 1's quadratic pending scan in the
//!   loop), and `-backlog` variants of both with arrivals squeezed so the
//!   pending queue holds essentially the whole trace at once — the
//!   incremental pending order / placement free-index hot regime. Backlog
//!   cases gate their events/s with a per-case throughput-drop floor
//!   ([`Recorder::drop_tolerance`]) and record the mean policy-pass
//!   latency as a `<case>/pass` companion. Seconds-scale; CI's
//!   `scale-smoke` leg runs it on every push.
//! * `full` — the headline: 1M jobs over 100k GPUs (25k uniform
//!   4-GPU servers), single timed pass. Minutes-scale; developers run it
//!   before touching the event core.
//!
//! Trace generation is untimed; the recorded region is the engine run
//! only, so the numbers isolate event dispatch + policy calls.

use crate::cluster::{Cluster, ClusterConfig};
use crate::jobs::trace::{self, TraceConfig};
use crate::jobs::workload;
use crate::perf::interference::InterferenceModel;
use crate::sched;
use crate::sim::{engine, EngineConfig};
use crate::util::bench::stats_of;

use super::super::registry::{Profile, Recorder, Suite, SuiteReport};

pub fn suite() -> Suite {
    Suite {
        name: "scale_xl",
        description: "100k-1M-job traces; events/s + jobs/s gated as first-class metrics",
        run,
    }
}

fn uniform(servers: usize) -> Cluster {
    Cluster::new(ClusterConfig {
        servers,
        gpus_per_server: 4,
        gpu_mem_gb: 11.0,
        max_share: 2,
    })
}

/// One xl case: generate the preset trace (untimed), run the policy
/// through the full engine (timed), record events/s + jobs/s.
///
/// `squeeze` divides the preset's mean interarrival. At 1.0 the preset
/// shape is untouched; large values pile essentially the whole trace
/// into a deep pending backlog behind a saturated cluster — the regime
/// the incremental pending order, the placement free-index, and
/// coincident-batch delivery exist for. Backlog cases (`squeeze > 1`,
/// named `...-backlog`) carry a tighter throughput-drop floor than their
/// single-shot wall-clock headroom, and record the mean policy-pass
/// latency as a companion `<name>/pass` case.
fn case(
    rec: &mut Recorder,
    policy: &str,
    shape: &str,
    cluster: Cluster,
    preset: &str,
    n_jobs: usize,
    squeeze: f64,
) {
    let mut wl = workload::by_name(preset).expect("registry preset");
    wl.mean_interarrival_s /= squeeze;
    let cfg = TraceConfig::from_preset(&wl, n_jobs, 1);
    let jobs = trace::generate(&cfg);
    let suffix = if squeeze > 1.0 { "-backlog" } else { "" };
    let name =
        format!("scale_xl/{}/{shape}/{n_jobs}-{preset}{suffix}", policy.to_lowercase());
    let mut events = 0u64;
    let stats = rec.once(&name, || {
        let mut p = sched::by_name(policy).expect("registry policy");
        let out = engine::run_cluster(
            cluster,
            &jobs,
            InterferenceModel::new(),
            p.as_mut(),
            EngineConfig::default(),
        )
        .expect("scale_xl run");
        events = out.policy_calls;
        std::hint::black_box(out.makespan_s);
    });
    let wall = stats.mean_s.max(1e-12);
    let events_per_s = events as f64 / wall;
    let jobs_per_s = n_jobs as f64 / wall;
    rec.throughput(events_per_s, jobs_per_s);
    println!("  {name}: {events} events, {events_per_s:.0} events/s, {jobs_per_s:.0} jobs/s");
    if squeeze > 1.0 {
        // Throughput floors are the backlog cases' contract: wide
        // single-shot wall-clock headroom, but an events/s collapse past
        // this fails the gate (the inert-gate fix in perfkit::compare).
        rec.drop_tolerance(60.0);
        let pass_s = wall / events.max(1) as f64;
        rec.record(stats_of(&format!("{name}/pass"), vec![pass_s]));
        // Derived single-sample latency: generous headroom, it exists as
        // a recorded trajectory number, not a tight gate.
        rec.tolerance(200.0);
        println!("  {name}/pass: {:.1} us mean policy-pass latency", pass_s * 1e6);
    }
}

fn run(profile: Profile) -> SuiteReport {
    let mut rec = Recorder::new("scale_xl");
    match profile {
        Profile::Quick => {
            // CI tier: 100k jobs over 4096 GPUs exercises the calendar
            // queue's rebuild path and the lazy ledger at real depth while
            // staying seconds-scale.
            case(
                &mut rec,
                "SJF",
                "uniform-1024x4",
                uniform(1024),
                "small-job-flood",
                100_000,
                1.0,
            );
            // Sharing machinery at depth: overlays + pairwise search keep
            // the reproject/settle path hot (bounded size — Alg. 1 is
            // quadratic in the pending queue).
            case(
                &mut rec,
                "SJF-BSBF",
                "uniform-64x4",
                uniform(64),
                "small-job-flood",
                5_000,
                1.0,
            );
            // Backlog tier: arrivals squeezed ~1000x, so essentially the
            // whole trace is pending behind a saturated cluster. This is
            // the incremental-pending-order + free-index regime; before
            // those, every policy pass re-sorted ~50k pending jobs and
            // rescanned 1024 servers, and these cases took minutes.
            case(
                &mut rec,
                "SJF",
                "uniform-1024x4",
                uniform(1024),
                "small-job-flood",
                50_000,
                1000.0,
            );
            // BSBF's Alg. 1 line-9 gate is O(1) per candidate but the
            // candidate scan is O(pending) per transitional pass, so the
            // backlog variant stays on the small cluster at bounded size.
            case(
                &mut rec,
                "SJF-BSBF",
                "uniform-64x4",
                uniform(64),
                "small-job-flood",
                5_000,
                1000.0,
            );
        }
        Profile::Full => {
            // The headline case: 1M jobs on a 100k-GPU datacenter.
            case(
                &mut rec,
                "SJF",
                "uniform-25000x4",
                uniform(25_000),
                "small-job-flood",
                1_000_000,
                1.0,
            );
        }
    }
    rec.finish()
}

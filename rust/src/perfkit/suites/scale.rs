//! `scale` suite — event-loop throughput at datacenter trace scale.
//!
//! The paper's own evaluation stops at 240-480 jobs on 64 GPUs, but the
//! clusters it cites (Philly, Helios) run thousands of GPUs and tens of
//! thousands of jobs; the ROADMAP's north star is "as fast as the
//! hardware allows". This suite drives the simulator across that gap:
//! `helios-heavy-tail` and `small-job-flood` traces of 10k-20k jobs over
//! uniform and two-tier heterogeneous topologies up to 4096 GPUs (full
//! profile), with a seconds-scale smoke variant (1k-2k jobs, 64-256
//! GPUs) that CI's `bench-smoke` job runs on every push. Single timed
//! pass per case — the runs are long enough to be stable; trace
//! generation happens outside the timed region so the numbers isolate
//! the engine.

use crate::cluster::topology::{self, GpuType, LinkTier, ServerSpec, Topology};
use crate::cluster::{Cluster, ClusterConfig};
use crate::jobs::trace::{self, TraceConfig};
use crate::jobs::workload;
use crate::perf::interference::InterferenceModel;
use crate::sched;
use crate::sim::{engine, EngineConfig};

use super::super::registry::{Profile, Recorder, Suite, SuiteReport};

pub fn suite() -> Suite {
    Suite {
        name: "scale",
        description: "10k-20k-job traces on up to 4096-GPU (hetero) topologies",
        run,
    }
}

/// The `hetero-16x4-2tier` shape scaled out: half reference servers, half
/// newer-generation (2x memory, 1.6x compute), NVLink-class intra-node
/// links, 10 Gbps + 20 µs between nodes.
fn hetero_two_tier(servers: usize) -> Topology {
    Topology::new(
        (0..servers)
            .map(|s| ServerSpec {
                gpus: 4,
                gpu: if s < servers / 2 {
                    GpuType::reference()
                } else {
                    GpuType { mem_gb: 22.0, compute_scale: 1.6 }
                },
            })
            .collect(),
        LinkTier { bandwidth_gbps: 100.0, latency_s: 0.0 },
        LinkTier { bandwidth_gbps: 10.0, latency_s: 20e-6 },
        2,
    )
}

fn uniform(servers: usize) -> Cluster {
    Cluster::new(ClusterConfig {
        servers,
        gpus_per_server: 4,
        gpu_mem_gb: 11.0,
        max_share: 2,
    })
}

/// One scale case: generate the preset trace (untimed), run the policy
/// through the full engine (timed), report events/s.
fn case(
    rec: &mut Recorder,
    policy: &str,
    shape: &str,
    cluster: Cluster,
    preset: &str,
    n_jobs: usize,
) {
    let cfg = TraceConfig::from_preset(
        &workload::by_name(preset).expect("registry preset"),
        n_jobs,
        1,
    );
    let jobs = trace::generate(&cfg);
    let name = format!("scale/{}/{shape}/{n_jobs}-{preset}", policy.to_lowercase());
    let mut events = 0u64;
    let stats = rec.once(&name, || {
        let mut p = sched::by_name(policy).expect("registry policy");
        let out = engine::run_cluster(
            cluster,
            &jobs,
            InterferenceModel::new(),
            p.as_mut(),
            EngineConfig::default(),
        )
        .expect("scale run");
        events = out.policy_calls;
        std::hint::black_box(out.makespan_s);
    });
    println!(
        "  {name}: {events} events, {:.0} events/s",
        events as f64 / stats.mean_s.max(1e-12)
    );
}

fn run(profile: Profile) -> SuiteReport {
    let mut rec = Recorder::new("scale");
    match profile {
        Profile::Quick => {
            // The CI smoke tier: same presets and shapes, seconds-scale.
            case(
                &mut rec,
                "SJF",
                "uniform-16x4",
                Cluster::new(ClusterConfig::simulation()),
                "helios-heavy-tail",
                1_000,
            );
            case(
                &mut rec,
                "SJF",
                "hetero-16x4-2tier",
                Cluster::with_topology(
                    topology::by_name("hetero-16x4-2tier").expect("named shape"),
                ),
                "helios-heavy-tail",
                1_000,
            );
            case(
                &mut rec,
                "SJF",
                "uniform-64x4",
                uniform(64),
                "small-job-flood",
                2_000,
            );
        }
        Profile::Full => {
            case(
                &mut rec,
                "SJF",
                "uniform-1024x4",
                uniform(1024),
                "helios-heavy-tail",
                10_000,
            );
            case(
                &mut rec,
                "SJF",
                "hetero-1024x4-2tier",
                Cluster::with_topology(hetero_two_tier(1024)),
                "helios-heavy-tail",
                10_000,
            );
            case(
                &mut rec,
                "SJF",
                "uniform-1024x4",
                uniform(1024),
                "small-job-flood",
                20_000,
            );
            // The sharing machinery at scale: BSBF's pairwise search on a
            // contended flood (bounded size — Alg. 1 is quadratic in the
            // pending queue).
            case(
                &mut rec,
                "SJF-BSBF",
                "uniform-64x4",
                uniform(64),
                "small-job-flood",
                2_000,
            );
        }
    }
    rec.finish()
}

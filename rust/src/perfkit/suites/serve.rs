//! `serve` suite — the daemon's request hot path, measured against an
//! in-process daemon (no pipes, no process spawn: the numbers are the
//! scheduler's, not the OS's).
//!
//! * **session** — one full `serve-load` session (advance + submit per
//!   job, then drain) end to end: the submissions/sec figure.
//! * **submit-latency** — the per-request wall cost of `handle_line` on
//!   a submit (parse → admission → arrival delivery → policy decision),
//!   folded from the session's raw per-submit timings so the percentiles
//!   describe real traffic, not a warm single request replayed.

use crate::obskit::Obs;
use crate::serve::{load, LoadConfig};
use crate::util::bench::stats_of;

use super::super::registry::{Profile, Recorder, Suite, SuiteReport};

pub fn suite() -> Suite {
    Suite {
        name: "serve",
        description: "daemon ingestion: submissions/sec + request->decision latency",
        run,
    }
}

fn run(profile: Profile) -> SuiteReport {
    let mut rec = Recorder::new("serve");
    let jobs = profile.pick(96, 512);
    let cfg = LoadConfig { jobs, ..LoadConfig::default() };
    let mut outcome = None;
    rec.once(&format!("serve/session/{jobs}jobs"), || {
        outcome = Some(load::run(&cfg, Obs::disabled()).expect("serve-load session"));
    });
    let outcome = outcome.expect("session ran");
    println!(
        "session: {} jobs in {:.2}s wall = {:.0} submissions/s ({} completed, {} busy)",
        outcome.submitted,
        outcome.wall_s,
        outcome.submissions_per_s,
        outcome.completed,
        outcome.rejected_busy,
    );
    rec.record(stats_of(
        &format!("serve/submit-latency/{jobs}jobs"),
        outcome.decision_latencies_s.clone(),
    ));
    rec.finish()
}

//! Workload v2: pluggable arrival processes and named job-mix presets
//! (DESIGN.md §11 covers the workload/estimator subsystem).
//!
//! The paper evaluates SJF-BSBF on one Philly-scaled Poisson trace, but
//! real multi-tenant clusters exhibit diurnal and bursty arrival patterns
//! (Jeon et al., "Analysis of Large-Scale Multi-Tenant GPU Clusters"; Hu
//! et al., "Characterization and Prediction of Deep Learning Workloads").
//! This module factors the arrival process out of the generator:
//!
//! * [`ArrivalProcess`] — how inter-arrival gaps are drawn: `Poisson`
//!   (homogeneous, the paper's setting), `Diurnal` (sinusoid-modulated
//!   rate, sampled by Lewis thinning) or `Bursty` (on/off MMPP: the rate
//!   switches between a hot and a cold level at exponentially distributed
//!   phase changes).
//! * [`ArrivalSampler`] — the stateful sampler driving one trace. The
//!   `Poisson` arm consumes exactly one exponential draw per arrival
//!   from the caller's [`Rng`] stream — byte-identical to the pre-v2
//!   generator; the inhomogeneous arms run on their own salted stream
//!   (their draw count varies with the load factor, and leaking that
//!   into the shared stream would make job bodies load-dependent).
//! * [`WorkloadPreset`] — a named composition of arrival process ×
//!   GPU-demand buckets × iteration tail ([`PRESET_NAMES`]). The
//!   `philly-sim` / `philly-physical` presets reproduce the old
//!   `TraceConfig::simulation` / `::physical` shapes exactly.

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// How inter-arrival gaps are drawn. All variants share the same *mean*
/// rate knob (the trace's `load_factor / mean_interarrival_s`); the
/// process shapes how that rate is spread over time.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson arrivals (exponential gaps) — today's paper
    /// setting. Exactly one RNG draw per arrival.
    Poisson,
    /// Inhomogeneous Poisson with rate `λ(t) = λ·(1 + a·sin(2πt/T))`,
    /// sampled by Lewis thinning against the peak rate `λ·(1 + a)`. The
    /// long-run mean rate is exactly `λ` (the sinusoid integrates to 0).
    Diurnal { period_s: f64, amplitude: f64 },
    /// On/off Markov-modulated Poisson process: the rate alternates
    /// between `λ·on_factor` (hot) and `λ·off_factor` (cold) phases with
    /// exponentially distributed durations. Long-run mean rate is
    /// `λ·(mean_on_s·on_factor + mean_off_s·off_factor) /
    /// (mean_on_s + mean_off_s)`.
    Bursty { mean_on_s: f64, mean_off_s: f64, on_factor: f64, off_factor: f64 },
}

impl ArrivalProcess {
    /// Reject degenerate parameterizations up front (a zero-rate process
    /// would stall the sampler; an amplitude ≥ 1 makes the thinning rate
    /// negative).
    pub fn validate(&self) -> Result<()> {
        match *self {
            ArrivalProcess::Poisson => Ok(()),
            ArrivalProcess::Diurnal { period_s, amplitude } => {
                if period_s <= 0.0 || !period_s.is_finite() {
                    bail!("diurnal period {period_s} must be finite and > 0");
                }
                if !(0.0..1.0).contains(&amplitude) {
                    bail!("diurnal amplitude {amplitude} must be in [0, 1)");
                }
                Ok(())
            }
            ArrivalProcess::Bursty { mean_on_s, mean_off_s, on_factor, off_factor } => {
                for (name, v) in [("mean_on_s", mean_on_s), ("mean_off_s", mean_off_s)] {
                    if v <= 0.0 || !v.is_finite() {
                        bail!("bursty {name} {v} must be finite and > 0");
                    }
                }
                for (name, v) in [("on_factor", on_factor), ("off_factor", off_factor)] {
                    if v < 0.0 || !v.is_finite() {
                        bail!("bursty {name} {v} must be finite and >= 0");
                    }
                }
                if on_factor == 0.0 && off_factor == 0.0 {
                    bail!("bursty process with both factors 0 never produces arrivals");
                }
                Ok(())
            }
        }
    }

    /// Long-run mean arrival rate as a multiple of the base rate λ
    /// (1.0 for `Poisson` and `Diurnal`; the phase-weighted factor mean
    /// for `Bursty`). The statistical property tests pin the empirical
    /// mean inter-arrival gap against `1 / (λ · mean_rate_factor())`.
    pub fn mean_rate_factor(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson | ArrivalProcess::Diurnal { .. } => 1.0,
            ArrivalProcess::Bursty { mean_on_s, mean_off_s, on_factor, off_factor } => {
                (mean_on_s * on_factor + mean_off_s * off_factor)
                    / (mean_on_s + mean_off_s)
            }
        }
    }
}

/// Stream-splitting constant for the inhomogeneous arrival machinery:
/// thinning rejections and phase flips consume a *variable* number of
/// draws, so they run on their own salted stream — the caller's stream
/// then sees a fixed draw pattern per job and trace bodies stay
/// invariant under `load_factor` for every process.
const ARRIVAL_STREAM_SALT: u64 = 0xA221_7A15_5EED_5000;

/// Stateful arrival-time sampler for one trace. Returns *absolute*
/// arrival times, strictly advancing from 0. Deterministic per seed; the
/// bursty phase machine and the diurnal thinning loop keep all their
/// state here, so the sampler is the single owner of "where we are on
/// the arrival timeline".
///
/// RNG discipline: the `Poisson` arm draws exactly one exponential from
/// the *caller's* stream per arrival — byte-identical to the pre-v2
/// generator. `Diurnal`/`Bursty` draw a load-dependent number of values
/// (thinning rejections, phase boundaries), so they use the sampler's
/// own salted stream instead; the caller's stream never observes them.
#[derive(Debug, Clone)]
pub struct ArrivalSampler {
    process: ArrivalProcess,
    t: f64,
    /// Bursty phase state: currently in the hot phase? (initialized on
    /// the first draw so construction stays RNG-free).
    on: bool,
    phase_end: Option<f64>,
    /// Dedicated salted stream for the inhomogeneous arms; `None` for
    /// `Poisson`, which stays on the caller's stream (byte parity).
    own_rng: Option<Rng>,
}

impl ArrivalSampler {
    /// Build a sampler for one trace. `seed` should be the trace seed;
    /// it feeds the salted private stream of the inhomogeneous arms.
    ///
    /// Panics on a degenerate process (zero-rate bursty, amplitude ≥ 1)
    /// — `generate` is an infallible API, and spinning forever would be
    /// the alternative; the campaign/CLI layers reject such configs with
    /// proper errors before ever getting here.
    pub fn new(process: ArrivalProcess, seed: u64) -> Self {
        process.validate().expect("invalid arrival process");
        let own_rng = match process {
            ArrivalProcess::Poisson => None,
            _ => Some(Rng::seed_from_u64(seed ^ ARRIVAL_STREAM_SALT)),
        };
        ArrivalSampler { process, t: 0.0, on: true, phase_end: None, own_rng }
    }

    /// Draw the next arrival time at base rate `rate` (arrivals/second —
    /// already includes the trace's load factor). `rng` is the caller's
    /// stream; only the `Poisson` arm consumes from it.
    ///
    /// Panics on a non-positive rate — the Poisson arm would panic in
    /// `Rng::exp` anyway (the pre-v2 behavior), and the bursty arm would
    /// otherwise flip phases forever without producing an arrival.
    pub fn next_arrival(&mut self, rng: &mut Rng, rate: f64) -> f64 {
        assert!(rate > 0.0, "arrival rate must be > 0, got {rate}");
        match self.process {
            ArrivalProcess::Poisson => {
                self.t += rng.exp(rate);
                self.t
            }
            ArrivalProcess::Diurnal { period_s, amplitude } => {
                let rng = self.own_rng.as_mut().expect("diurnal sampler owns a stream");
                // Lewis thinning against the peak rate: candidate gaps at
                // λ_max, accepted with probability λ(t)/λ_max.
                let rate_max = rate * (1.0 + amplitude);
                loop {
                    self.t += rng.exp(rate_max);
                    let phase = self.t / period_s * std::f64::consts::TAU;
                    let rate_t = rate * (1.0 + amplitude * phase.sin());
                    if rng.f64() * rate_max <= rate_t {
                        return self.t;
                    }
                }
            }
            ArrivalProcess::Bursty { mean_on_s, mean_off_s, on_factor, off_factor } => {
                let rng = self.own_rng.as_mut().expect("bursty sampler owns a stream");
                let mut phase_end = match self.phase_end {
                    Some(end) => end,
                    None => self.t + rng.exp(1.0 / mean_on_s),
                };
                loop {
                    let rate_now = rate * if self.on { on_factor } else { off_factor };
                    if rate_now > 0.0 {
                        let dt = rng.exp(rate_now);
                        if self.t + dt <= phase_end {
                            self.t += dt;
                            self.phase_end = Some(phase_end);
                            return self.t;
                        }
                    }
                    // No arrival before the phase flips; jump to the
                    // boundary (valid by memorylessness) and re-draw in
                    // the next phase.
                    self.t = phase_end;
                    self.on = !self.on;
                    let mean = if self.on { mean_on_s } else { mean_off_s };
                    phase_end = self.t + rng.exp(1.0 / mean);
                }
            }
        }
    }
}

/// A named workload shape: arrival process × GPU-demand buckets ×
/// iteration tail. [`crate::jobs::trace::TraceConfig::from_preset`]
/// turns one into a runnable trace config.
#[derive(Debug, Clone)]
pub struct WorkloadPreset {
    pub name: &'static str,
    pub arrival: ArrivalProcess,
    /// Mean inter-arrival gap at load factor 1, seconds.
    pub mean_interarrival_s: f64,
    /// GPU-demand buckets `(gpus, weight)`; empty ⇒ the physical 2:1
    /// small:large split (exactly the paper's 20/10 mix at 30 jobs).
    pub gpu_buckets: Vec<(usize, f64)>,
    /// Iteration-count clip range of the log-normal tail.
    pub iter_range: (u64, u64),
    /// σ of the underlying normal of the iteration tail (the μ is tied
    /// to the range floor, see `trace::generate`).
    pub iter_sigma: f64,
}

impl WorkloadPreset {
    /// Largest gang the preset's demand mix can request — what a cluster
    /// must be able to host for every generated trace to be runnable.
    pub fn max_gang(&self) -> usize {
        self.gpu_buckets.iter().map(|b| b.0).max().unwrap_or(16)
    }
}

/// Preset names, CLI/campaign-facing, in registry order.
pub const PRESET_NAMES: [&str; 4] =
    ["philly-sim", "philly-physical", "helios-heavy-tail", "small-job-flood"];

/// Look up a workload preset by name.
///
/// * `philly-sim` — the paper's 240-job simulation shape: Poisson
///   arrivals every 30 s, the Philly GPU mix, iterations 500–50k
///   (σ = 1.2). Byte-identical to the pre-v2 `TraceConfig::simulation`.
/// * `philly-physical` — the 30-job testbed shape: Poisson every 60 s,
///   the 20-small/10-large split (a 2:1 ratio at other job counts),
///   iterations 100–5000.
/// * `helios-heavy-tail` — Helios-style datacenter: diurnal arrivals
///   (24 h period, 0.8 amplitude), demand skewed to single-node jobs
///   with a fatter iteration tail (σ = 1.8, cap 200k).
/// * `small-job-flood` — bursty hyperparameter-sweep traffic: on/off
///   MMPP (hot 30 min at 2.5×, cold 60 min at 0.25×, mean rate exactly
///   1×), 1–4 GPU jobs only, short iterations.
pub fn by_name(name: &str) -> Option<WorkloadPreset> {
    Some(match name {
        "philly-sim" => WorkloadPreset {
            name: "philly-sim",
            arrival: ArrivalProcess::Poisson,
            mean_interarrival_s: 30.0,
            gpu_buckets: vec![
                (1, 0.30),
                (2, 0.25),
                (4, 0.19),
                (8, 0.14),
                (12, 0.06),
                (16, 0.06),
            ],
            iter_range: (500, 50_000),
            iter_sigma: 1.2,
        },
        "philly-physical" => WorkloadPreset {
            name: "philly-physical",
            arrival: ArrivalProcess::Poisson,
            mean_interarrival_s: 60.0,
            gpu_buckets: Vec::new(), // explicit 20/10 split in the generator
            iter_range: (100, 5000),
            iter_sigma: 1.2,
        },
        "helios-heavy-tail" => WorkloadPreset {
            name: "helios-heavy-tail",
            arrival: ArrivalProcess::Diurnal { period_s: 86_400.0, amplitude: 0.8 },
            mean_interarrival_s: 30.0,
            gpu_buckets: vec![
                (1, 0.45),
                (2, 0.20),
                (4, 0.15),
                (8, 0.10),
                (12, 0.05),
                (16, 0.05),
            ],
            iter_range: (500, 200_000),
            iter_sigma: 1.8,
        },
        "small-job-flood" => WorkloadPreset {
            name: "small-job-flood",
            arrival: ArrivalProcess::Bursty {
                mean_on_s: 1800.0,
                mean_off_s: 3600.0,
                on_factor: 2.5,
                off_factor: 0.25,
            },
            mean_interarrival_s: 8.0,
            gpu_buckets: vec![(1, 0.60), (2, 0.30), (4, 0.10)],
            iter_range: (100, 5_000),
            iter_sigma: 0.9,
        },
        _ => return None,
    })
}

/// [`by_name`] with the unified unknown-preset error (same discipline as
/// `topology::by_name_or_err`): every CLI/campaign/test site reports the
/// same message with the known names listed.
pub fn by_name_or_err(name: &str) -> Result<WorkloadPreset> {
    by_name(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown workload preset {name:?} (known: {})",
            PRESET_NAMES.join(", ")
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_preset_name_resolves_and_validates() {
        for name in PRESET_NAMES {
            let p = by_name(name).unwrap_or_else(|| panic!("missing preset {name}"));
            assert_eq!(p.name, name);
            p.arrival.validate().unwrap();
            assert!(p.mean_interarrival_s > 0.0);
            assert!(p.iter_range.0 >= 1 && p.iter_range.1 > p.iter_range.0);
            assert!(p.iter_sigma > 0.0);
        }
        assert!(by_name("bogus").is_none());
        let err = by_name_or_err("bogus").unwrap_err().to_string();
        assert!(err.contains("unknown workload preset"), "{err}");
        assert!(err.contains("philly-sim"), "{err}");
    }

    #[test]
    fn small_job_flood_mean_rate_factor_is_one() {
        // Hot/cold factors are weighted to a mean of exactly 1× so the
        // preset's nominal mean inter-arrival gap is honest.
        let p = by_name("small-job-flood").unwrap();
        assert!((p.arrival.mean_rate_factor() - 1.0).abs() < 1e-12);
        assert_eq!(p.max_gang(), 4);
    }

    #[test]
    fn validate_rejects_degenerate_processes() {
        assert!(ArrivalProcess::Diurnal { period_s: 0.0, amplitude: 0.5 }
            .validate()
            .is_err());
        assert!(ArrivalProcess::Diurnal { period_s: 100.0, amplitude: 1.0 }
            .validate()
            .is_err());
        assert!(ArrivalProcess::Bursty {
            mean_on_s: 10.0,
            mean_off_s: 10.0,
            on_factor: 0.0,
            off_factor: 0.0
        }
        .validate()
        .is_err());
        assert!(ArrivalProcess::Bursty {
            mean_on_s: -1.0,
            mean_off_s: 10.0,
            on_factor: 1.0,
            off_factor: 0.0
        }
        .validate()
        .is_err());
        assert!(ArrivalProcess::Poisson.validate().is_ok());
    }

    #[test]
    #[should_panic(expected = "invalid arrival process")]
    fn sampler_rejects_degenerate_process_instead_of_spinning() {
        // generate() is infallible, so a zero-rate bursty config must
        // panic with the validation message at sampler construction —
        // the alternative is an infinite phase-flip loop.
        let _ = ArrivalSampler::new(
            ArrivalProcess::Bursty {
                mean_on_s: 10.0,
                mean_off_s: 10.0,
                on_factor: 0.0,
                off_factor: 0.0,
            },
            1,
        );
    }

    #[test]
    fn inhomogeneous_sampler_leaves_caller_stream_untouched() {
        // Diurnal/bursty arms draw a load-dependent number of values, so
        // they must run on their own salted stream: the caller's stream
        // position after n arrivals is identical to never sampling at
        // all — which is what keeps trace bodies load-invariant.
        for process in [
            ArrivalProcess::Diurnal { period_s: 1000.0, amplitude: 0.8 },
            ArrivalProcess::Bursty {
                mean_on_s: 50.0,
                mean_off_s: 200.0,
                on_factor: 4.0,
                off_factor: 0.25,
            },
        ] {
            let mut rng = Rng::seed_from_u64(9);
            let mut s = ArrivalSampler::new(process, 9);
            for _ in 0..50 {
                s.next_arrival(&mut rng, 0.1);
            }
            let mut untouched = Rng::seed_from_u64(9);
            assert_eq!(rng.next_u64(), untouched.next_u64());
        }
    }

    #[test]
    fn poisson_sampler_consumes_one_draw_per_arrival() {
        // The byte-parity contract: the Poisson arm must reproduce the
        // pre-v2 generator's single `rng.exp(rate)` per arrival exactly.
        let mut rng_a = Rng::seed_from_u64(42);
        let mut rng_b = Rng::seed_from_u64(42);
        let mut sampler = ArrivalSampler::new(ArrivalProcess::Poisson, 42);
        let mut t = 0.0;
        for _ in 0..100 {
            t += rng_b.exp(1.0 / 30.0);
            let got = sampler.next_arrival(&mut rng_a, 1.0 / 30.0);
            assert_eq!(got.to_bits(), t.to_bits());
        }
    }

    #[test]
    fn samplers_are_deterministic_and_strictly_increasing() {
        for process in [
            ArrivalProcess::Poisson,
            ArrivalProcess::Diurnal { period_s: 1000.0, amplitude: 0.8 },
            ArrivalProcess::Bursty {
                mean_on_s: 50.0,
                mean_off_s: 200.0,
                on_factor: 4.0,
                off_factor: 0.25,
            },
        ] {
            let sample = |seed: u64| {
                let mut rng = Rng::seed_from_u64(seed);
                let mut s = ArrivalSampler::new(process.clone(), seed);
                (0..200).map(|_| s.next_arrival(&mut rng, 0.1)).collect::<Vec<f64>>()
            };
            let a = sample(7);
            let b = sample(7);
            assert_eq!(a, b, "{process:?} must be deterministic per seed");
            assert_ne!(a, sample(8), "{process:?} must vary across seeds");
            for w in a.windows(2) {
                assert!(w[1] > w[0], "{process:?} arrivals must strictly increase");
            }
        }
    }
}

//! DDL job model (paper Table I notation).
//!
//! A job `J_k` is characterized by requested GPU count `G_k`, training
//! iterations `I_k`, per-GPU mini-batch `B_k`, arrival time `a_k`, and the
//! workload profile that supplies its Eq. 3/4/7 performance model. Gang
//! scheduling: all `G_k` GPUs start together and are held until completion
//! (non-preemptive policies) or until the policy explicitly preempts.

pub mod estimate;
pub mod trace;
pub mod workload;

use crate::perf::profiles::{ModelKind, WorkloadProfile};

/// Dense job identifier (index into the simulation's job table).
pub type JobId = usize;

/// Immutable job description, as submitted by the tenant.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: JobId,
    /// Workload profile (decides perf + memory models).
    pub model: ModelKind,
    /// Requested number of GPUs `G_k` (gang width).
    pub gpus: usize,
    /// Total training iterations `I_k`.
    pub iterations: u64,
    /// User-requested per-GPU mini-batch `B_k` (convergence-defining; never
    /// changed — only split into sub-batches via gradient accumulation).
    pub batch: u32,
    /// Arrival time `a_k`, seconds from horizon start.
    pub arrival_s: f64,
    /// Scheduler-visible duration estimate as a multiple of the true solo
    /// runtime, materialized at trace time by a
    /// [`estimate::EstimateModel`]. `1.0` is the oracle (the paper's
    /// setting); policies rank on `truth × est_factor`, the engine always
    /// completes jobs on the truth.
    pub est_factor: f64,
}

impl JobSpec {
    pub fn profile(&self) -> WorkloadProfile {
        WorkloadProfile::get(self.model)
    }

    /// Solo iteration time on `self.gpus` workers with accumulation step `s`.
    pub fn iter_time(&self, s: u32) -> f64 {
        self.profile().perf.iter_time(self.batch as f64, s, self.gpus)
    }

    /// Total solo execution time `L_k = t_iter · I_k` at accumulation `s`.
    pub fn solo_runtime(&self, s: u32) -> f64 {
        self.iter_time(s) * self.iterations as f64
    }

    /// The solo iteration time the *scheduler believes in*:
    /// `t_iter · est_factor`. Bit-identical to [`JobSpec::iter_time`]
    /// under the oracle (`× 1.0` is IEEE-exact).
    pub fn estimated_iter_time(&self, s: u32) -> f64 {
        self.iter_time(s) * self.est_factor
    }

    /// Paper §VI job-size taxonomy: jobs requesting more than 4 GPUs are
    /// "large" (Tables III/IV split rows on this).
    pub fn is_large(&self) -> bool {
        self.gpus > 4
    }
}

/// Scheduler-facing lifecycle state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Waiting in the pending queue.
    Pending,
    /// Running on its gang (possibly sharing GPUs).
    Running,
    /// Preempted by a preemptive policy; will re-queue.
    Preempted,
    /// All iterations done.
    Finished,
}

/// Mutable per-job runtime record tracked by the simulator / coordinator.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub spec: JobSpec,
    pub state: JobState,
    /// Iterations still to run (fractional while integrating progress).
    pub remaining_iters: f64,
    /// Accumulation step `s` currently in force (sub-batch = B/s).
    pub accum_step: u32,
    /// First time the job started running (for queueing-delay metrics).
    pub first_start_s: Option<f64>,
    /// Completion timestamp `T_k`.
    pub finish_s: Option<f64>,
    /// Cumulative seconds spent in `Pending`/`Preempted` while submitted.
    pub queued_s: f64,
    /// GPUs currently held (empty unless Running).
    pub gpus_held: Vec<crate::cluster::GpuId>,
}

impl JobRecord {
    pub fn new(spec: JobSpec) -> Self {
        let iters = spec.iterations as f64;
        JobRecord {
            spec,
            state: JobState::Pending,
            remaining_iters: iters,
            accum_step: 1,
            first_start_s: None,
            finish_s: None,
            queued_s: 0.0,
            gpus_held: Vec::new(),
        }
    }

    /// True remaining solo runtime `L_k` — the oracle SJF priority key
    /// (what the pre-estimator policies ranked on; kept as the reference
    /// the estimate caches are integrity-checked against).
    pub fn remaining_solo_runtime(&self) -> f64 {
        self.spec.iter_time(self.accum_step) * self.remaining_iters
    }

    /// Remaining iterations as the scheduler *estimates* them —
    /// Algorithm 2's pair-JCT inputs under misprediction. Equal to the
    /// truth bit-for-bit under the oracle.
    pub fn estimated_remaining_iters(&self) -> f64 {
        self.remaining_iters * self.spec.est_factor
    }

    /// Estimated remaining solo runtime — the SJF-family priority key
    /// (`estimated_iter_time · remaining_iters`). Policies should prefer
    /// the cached
    /// [`SchedContext::estimated_remaining`](crate::sched_core::SchedContext::estimated_remaining).
    pub fn estimated_remaining_runtime(&self) -> f64 {
        self.spec.estimated_iter_time(self.accum_step) * self.remaining_iters
    }

    /// Job completion time `T_k - a_k` (requires finished).
    pub fn jct(&self) -> Option<f64> {
        self.finish_s.map(|f| f - self.spec.arrival_s)
    }

    /// Queueing delay: first start − arrival (∞-safe: None until started).
    pub fn queueing_delay(&self) -> Option<f64> {
        self.first_start_s.map(|s| s - self.spec.arrival_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> JobSpec {
        JobSpec {
            id: 0,
            model: ModelKind::Cifar10,
            gpus: 4,
            iterations: 1000,
            batch: 128,
            arrival_s: 10.0,
            est_factor: 1.0,
        }
    }

    #[test]
    fn solo_runtime_scales_with_iterations() {
        let s = spec();
        assert!((s.solo_runtime(1) - s.iter_time(1) * 1000.0).abs() < 1e-9);
    }

    #[test]
    fn accumulation_never_speeds_up_solo() {
        // Sub-batching adds (s-1) α overheads; solo runtime must not drop.
        let s = spec();
        assert!(s.solo_runtime(2) >= s.solo_runtime(1));
        assert!(s.solo_runtime(4) >= s.solo_runtime(2));
    }

    #[test]
    fn large_job_taxonomy() {
        let mut s = spec();
        assert!(!s.is_large());
        s.gpus = 8;
        assert!(s.is_large());
        s.gpus = 5;
        assert!(s.is_large());
    }

    #[test]
    fn oracle_estimates_are_bit_identical_to_truth() {
        let mut r = JobRecord::new(spec());
        r.remaining_iters = 437.5;
        r.accum_step = 2;
        assert_eq!(
            r.estimated_remaining_runtime().to_bits(),
            r.remaining_solo_runtime().to_bits()
        );
        assert_eq!(r.estimated_remaining_iters().to_bits(), r.remaining_iters.to_bits());
    }

    #[test]
    fn est_factor_scales_the_estimate_not_the_truth() {
        let mut s = spec();
        s.est_factor = 2.0;
        let r = JobRecord::new(s);
        assert!((r.estimated_remaining_runtime() - 2.0 * r.remaining_solo_runtime()).abs() < 1e-9);
        assert!((r.estimated_remaining_iters() - 2.0 * r.remaining_iters).abs() < 1e-9);
        // The truth is untouched: the engine completes on real iterations.
        assert_eq!(r.remaining_iters, 1000.0);
    }

    #[test]
    fn record_lifecycle_metrics() {
        let mut r = JobRecord::new(spec());
        assert_eq!(r.state, JobState::Pending);
        assert!(r.jct().is_none());
        r.first_start_s = Some(25.0);
        r.finish_s = Some(125.0);
        assert_eq!(r.queueing_delay(), Some(15.0));
        assert_eq!(r.jct(), Some(115.0));
    }
}

//! Workload traces (paper §VI-A): Philly-like synthetic generation plus
//! JSON load/store.
//!
//! The paper scales the Microsoft trace [Jeon et al.] to two settings we
//! reproduce:
//!
//! * **physical**: 30 jobs on 16 GPUs — 20 jobs ≤ 8 GPUs, 10 jobs with 12
//!   or 16 GPUs; iterations in [100, 5000].
//! * **simulation**: 240 jobs (and 480 / load-scaled variants) sampled from
//!   the busiest period, annotated with the six Pollux task profiles.
//!
//! Generation is fully deterministic per seed (splitmix64).

use anyhow::{Context, Result};

use super::JobSpec;
use crate::perf::profiles::{ModelKind, WorkloadProfile};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Parameters of the Philly-like generator.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub n_jobs: usize,
    pub seed: u64,
    /// Mean inter-arrival gap in seconds (Poisson arrivals ⇒ Exp gaps).
    pub mean_interarrival_s: f64,
    /// GPU-demand buckets `(gpus, weight)` — defaults mirror the Philly mix.
    pub gpu_buckets: Vec<(usize, f64)>,
    /// Iteration count range (heavy-tailed), paper: [100, 5000].
    pub iter_range: (u64, u64),
    /// Load multiplier for the Fig. 6a sweep: scales arrival *frequency*.
    pub load_factor: f64,
}

impl TraceConfig {
    /// 240-job simulation default (busiest-period density: ~2 arrivals/min).
    pub fn simulation(n_jobs: usize, seed: u64) -> Self {
        TraceConfig {
            n_jobs,
            seed,
            mean_interarrival_s: 30.0,
            gpu_buckets: vec![
                (1, 0.30),
                (2, 0.25),
                (4, 0.19),
                (8, 0.14),
                (12, 0.06),
                (16, 0.06),
            ],
            // Pollux-scale jobs: median ~5k iterations (tens of minutes),
            // heavy tail to 50k — the busiest-period overload the paper
            // simulates (Tables III/IV report JCTs of 1-7.5 *hours*).
            iter_range: (500, 50_000),
            load_factor: 1.0,
        }
    }

    /// The 30-job physical workload (20 small ≤ 8 GPUs, 10 large 12/16).
    pub fn physical(seed: u64) -> Self {
        TraceConfig {
            n_jobs: 30,
            seed,
            mean_interarrival_s: 60.0,
            gpu_buckets: vec![], // physical uses the explicit 20/10 split
            iter_range: (100, 5000),
            load_factor: 1.0,
        }
    }
}

/// Deterministically generate a trace.
pub fn generate(cfg: &TraceConfig) -> Vec<JobSpec> {
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let rate = cfg.load_factor / cfg.mean_interarrival_s.max(1e-9);
    // Heavy-tailed iteration counts clipped to the paper's range: most jobs
    // are short, a long tail runs to the cap (Philly's signature shape).
    let (lo, hi) = cfg.iter_range;
    let mu = ((lo * 10) as f64).ln();
    let sigma = 1.2;

    let mut t = 0.0f64;
    let mut jobs = Vec::with_capacity(cfg.n_jobs);
    for id in 0..cfg.n_jobs {
        t += rng.exp(rate);
        let gpus = if cfg.gpu_buckets.is_empty() {
            // physical split: ids 0..20 small, 20..30 large
            if id < 20 {
                *rng.choose(&[1usize, 2, 4, 8])
            } else {
                *rng.choose(&[12usize, 16])
            }
        } else {
            sample_bucket(&cfg.gpu_buckets, &mut rng)
        };
        let model = *rng.choose(&ModelKind::ALL);
        let iterations = (rng.lognormal(mu, sigma) as u64).clamp(lo, hi);
        let batch = sample_batch(model, &mut rng);
        jobs.push(JobSpec { id, model, gpus, iterations, batch, arrival_s: t });
    }
    jobs
}

/// Per-model batch choice: the profile default, occasionally halved/doubled
/// (tenants pick different effective batches; Fig. 2's B sweep). Tenants
/// size their batch to the GPU: the draw is clamped so the job fits an
/// 11 GB device when running alone (the paper measured all jobs solo).
fn sample_batch(model: ModelKind, rng: &mut Rng) -> u32 {
    let prof = WorkloadProfile::get(model);
    let base = prof.default_batch;
    let want = match rng.index(4) {
        0 => (base / 2).max(1),
        3 => base * 2,
        _ => base,
    };
    prof.mem.max_sub_batch(want, 11.0).unwrap_or(1)
}

fn sample_bucket(buckets: &[(usize, f64)], rng: &mut Rng) -> usize {
    let total: f64 = buckets.iter().map(|b| b.1).sum();
    let mut x = rng.f64() * total;
    for &(gpus, w) in buckets {
        if x < w {
            return gpus;
        }
        x -= w;
    }
    buckets.last().unwrap().0
}

// ------------------------------------------------------------ JSON I/O

fn spec_to_json(j: &JobSpec) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("id".into(), Json::from(j.id));
    m.insert("model".into(), Json::from(j.model.name()));
    m.insert("gpus".into(), Json::from(j.gpus));
    m.insert("iterations".into(), Json::Num(j.iterations as f64));
    m.insert("batch".into(), Json::Num(j.batch as f64));
    m.insert("arrival_s".into(), Json::Num(j.arrival_s));
    Json::Obj(m)
}

fn spec_from_json(j: &Json) -> Result<JobSpec> {
    let name = j.req("model")?.as_str().context("model must be a string")?;
    Ok(JobSpec {
        id: j.req("id")?.as_usize().context("id")?,
        model: ModelKind::from_name(name)
            .with_context(|| format!("unknown model {name:?}"))?,
        gpus: j.req("gpus")?.as_usize().context("gpus")?,
        iterations: j.req("iterations")?.as_f64().context("iterations")? as u64,
        batch: j.req("batch")?.as_f64().context("batch")? as u32,
        arrival_s: j.req("arrival_s")?.as_f64().context("arrival_s")?,
    })
}

/// Save a trace as JSON.
pub fn save(jobs: &[JobSpec], path: &std::path::Path) -> Result<()> {
    let doc = Json::Arr(jobs.iter().map(spec_to_json).collect());
    std::fs::write(path, doc.to_string()).context("writing trace")
}

/// Load a trace from JSON.
pub fn load(path: &std::path::Path) -> Result<Vec<JobSpec>> {
    let text = std::fs::read_to_string(path).context("reading trace")?;
    let doc = Json::parse(&text)?;
    doc.as_arr()
        .context("trace must be a JSON array")?
        .iter()
        .map(spec_from_json)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fingerprint(jobs: &[JobSpec]) -> String {
        jobs.iter()
            .map(|j| {
                format!(
                    "{}:{}:{}:{}:{}:{:.3}",
                    j.id,
                    j.model.name(),
                    j.gpus,
                    j.iterations,
                    j.batch,
                    j.arrival_s
                )
            })
            .collect::<Vec<_>>()
            .join("|")
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = TraceConfig::simulation(50, 7);
        assert_eq!(fingerprint(&generate(&cfg)), fingerprint(&generate(&cfg)));
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(&TraceConfig::simulation(50, 1));
        let b = generate(&TraceConfig::simulation(50, 2));
        assert_ne!(fingerprint(&a), fingerprint(&b));
    }

    #[test]
    fn arrivals_monotone_and_iters_in_range() {
        let jobs = generate(&TraceConfig::simulation(200, 3));
        assert_eq!(jobs.len(), 200);
        let mut prev = 0.0;
        for j in &jobs {
            assert!(j.arrival_s >= prev);
            prev = j.arrival_s;
            assert!((500..=50_000).contains(&j.iterations));
            assert!(j.gpus >= 1 && j.gpus <= 16);
        }
    }

    #[test]
    fn physical_trace_has_paper_size_mix() {
        let jobs = generate(&TraceConfig::physical(11));
        assert_eq!(jobs.len(), 30);
        let large = jobs.iter().filter(|j| j.gpus >= 12).count();
        assert_eq!(large, 10, "paper: 10 jobs at 12 or 16 GPUs");
        assert!(jobs.iter().take(20).all(|j| j.gpus <= 8));
    }

    #[test]
    fn load_factor_compresses_arrivals() {
        let mut cfg = TraceConfig::simulation(100, 5);
        let base_span = generate(&cfg).last().unwrap().arrival_s;
        cfg.load_factor = 2.0;
        let dense_span = generate(&cfg).last().unwrap().arrival_s;
        assert!(dense_span < base_span, "2x load must compress the horizon");
    }

    #[test]
    fn load_factor_scales_arrival_density_monotonically() {
        // Same seed ⇒ the same uniform draws; the Exp inverse transform
        // then divides every gap by the rate, so the horizon must shrink
        // monotonically — and exactly proportionally — as load rises.
        let span = |load: f64| {
            let mut cfg = TraceConfig::simulation(64, 17);
            cfg.load_factor = load;
            generate(&cfg).last().unwrap().arrival_s
        };
        let loads = [0.5, 1.0, 2.0, 4.0];
        let spans: Vec<f64> = loads.iter().map(|&l| span(l)).collect();
        for w in spans.windows(2) {
            assert!(w[1] < w[0], "higher load must compress arrivals: {spans:?}");
        }
        // Doubling load twice (0.5 -> 2.0) quarters the horizon; the ratio
        // is exact because scaling by powers of two commutes with IEEE
        // rounding.
        let ratio = spans[0] / spans[2];
        assert!((ratio - 4.0).abs() < 1e-9, "span must scale as 1/load, got {ratio}");
        // Only arrival times move: the rest of the trace is load-invariant.
        let mut dense = TraceConfig::simulation(64, 17);
        dense.load_factor = 4.0;
        let a = generate(&TraceConfig::simulation(64, 17));
        let b = generate(&dense);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.model, y.model);
            assert_eq!(x.gpus, y.gpus);
            assert_eq!(x.iterations, y.iterations);
            assert_eq!(x.batch, y.batch);
        }
    }

    #[test]
    fn json_roundtrip() {
        let dir = std::env::temp_dir().join(format!("wise-share-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let jobs = generate(&TraceConfig::simulation(20, 9));
        save(&jobs, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(fingerprint(&jobs), fingerprint(&back));
        std::fs::remove_dir_all(&dir).ok();
    }
}

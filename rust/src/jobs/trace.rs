//! Workload traces (paper §VI-A): synthetic generation from named
//! [`workload`] presets plus JSON load/store.
//!
//! The paper scales the Microsoft trace [Jeon et al.] to two settings we
//! reproduce:
//!
//! * **physical**: 30 jobs on 16 GPUs — 20 jobs ≤ 8 GPUs, 10 jobs with 12
//!   or 16 GPUs; iterations in [100, 5000].
//! * **simulation**: 240 jobs (and 480 / load-scaled variants) sampled from
//!   the busiest period, annotated with the six Pollux task profiles.
//!
//! Since workload v2 the generator is preset-driven: a
//! [`workload::WorkloadPreset`] composes the arrival process (Poisson /
//! diurnal / bursty), the GPU-demand buckets and the iteration tail, and
//! an [`estimate::EstimateModel`] materializes per-job duration-estimate
//! factors after the trace body is drawn (from a separate RNG stream, so
//! the estimator never perturbs the trace itself). The old constructors
//! are thin preset calls: `TraceConfig::simulation` ≡ `philly-sim` with
//! the oracle estimator, byte-identical to the pre-v2 generator.
//!
//! Generation is fully deterministic per seed (splitmix64).

use anyhow::{bail, Context, Result};

use super::estimate::{self, EstimateModel};
use super::workload::{ArrivalProcess, ArrivalSampler, WorkloadPreset};
use super::JobSpec;
use crate::perf::profiles::{ModelKind, WorkloadProfile};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Parameters of the preset-driven generator.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub n_jobs: usize,
    pub seed: u64,
    /// Mean inter-arrival gap in seconds at load factor 1.
    pub mean_interarrival_s: f64,
    /// Arrival process shaping how that mean rate is spread over time.
    pub arrival: ArrivalProcess,
    /// GPU-demand buckets `(gpus, weight)` — defaults mirror the Philly mix.
    pub gpu_buckets: Vec<(usize, f64)>,
    /// Iteration count range (heavy-tailed), paper: [100, 5000].
    pub iter_range: (u64, u64),
    /// σ of the log-normal iteration tail (1.2 = the Philly shape).
    pub iter_sigma: f64,
    /// Load multiplier for the Fig. 6a sweep. Scales arrival *frequency
    /// only*: job bodies (model, gpus, iterations, batch, est_factor)
    /// are untouched at any load — the same jobs arrive denser, pinned
    /// for every preset by `load_factor_leaves_job_bodies_invariant`.
    /// Under `Poisson` every inter-arrival gap shrinks by exactly
    /// `1/load_factor`; under `Diurnal`/`Bursty` the *instantaneous
    /// rate* scales while the diurnal period and burst phase durations
    /// stay wall-clock (a denser trace crosses fewer cycles — arrival
    /// machinery therefore runs on its own RNG stream, see
    /// [`ArrivalSampler`]).
    pub load_factor: f64,
    /// Duration-estimate model materialized into [`JobSpec::est_factor`]
    /// after generation (the oracle leaves every factor at exactly 1.0).
    pub estimator: EstimateModel,
}

impl TraceConfig {
    /// Build a trace config from a named workload preset with the oracle
    /// estimator (override `estimator` / `load_factor` afterwards).
    pub fn from_preset(preset: &WorkloadPreset, n_jobs: usize, seed: u64) -> Self {
        TraceConfig {
            n_jobs,
            seed,
            mean_interarrival_s: preset.mean_interarrival_s,
            arrival: preset.arrival.clone(),
            gpu_buckets: preset.gpu_buckets.clone(),
            iter_range: preset.iter_range,
            iter_sigma: preset.iter_sigma,
            load_factor: 1.0,
            estimator: EstimateModel::Oracle,
        }
    }

    /// 240-job simulation default (busiest-period density: ~2 arrivals/min)
    /// — a thin call to the `philly-sim` preset.
    ///
    /// Pollux-scale jobs: median ~5k iterations (tens of minutes), heavy
    /// tail to 50k — the busiest-period overload the paper simulates
    /// (Tables III/IV report JCTs of 1-7.5 *hours*).
    pub fn simulation(n_jobs: usize, seed: u64) -> Self {
        Self::from_preset(
            &super::workload::by_name("philly-sim").expect("registry preset"),
            n_jobs,
            seed,
        )
    }

    /// The 30-job physical workload (20 small ≤ 8 GPUs, 10 large 12/16)
    /// — a thin call to the `philly-physical` preset.
    pub fn physical(seed: u64) -> Self {
        Self::from_preset(
            &super::workload::by_name("philly-physical").expect("registry preset"),
            30,
            seed,
        )
    }
}

/// Deterministically generate a trace.
pub fn generate(cfg: &TraceConfig) -> Vec<JobSpec> {
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let rate = cfg.load_factor / cfg.mean_interarrival_s.max(1e-9);
    let mut arrivals = ArrivalSampler::new(cfg.arrival.clone(), cfg.seed);
    // Heavy-tailed iteration counts clipped to the preset's range: most
    // jobs are short, a long tail runs to the cap (Philly's signature
    // shape).
    let (lo, hi) = cfg.iter_range;
    let mu = ((lo * 10) as f64).ln();
    let sigma = cfg.iter_sigma;

    let mut jobs = Vec::with_capacity(cfg.n_jobs);
    for id in 0..cfg.n_jobs {
        let t = arrivals.next_arrival(&mut rng, rate);
        let gpus = if cfg.gpu_buckets.is_empty() {
            // Physical split, scaled proportionally: the first 2/3 of
            // jobs are small (≤ 8 GPUs), the rest large (12/16) — at the
            // paper's 30 jobs that is exactly the documented 20/10 mix
            // (ids 0..20 small, 20..30 large, byte-identical to the
            // pre-preset generator); other sizes keep the 2:1 ratio
            // instead of silently flooding the tail with large gangs.
            if id < cfg.n_jobs * 2 / 3 {
                *rng.choose(&[1usize, 2, 4, 8])
            } else {
                *rng.choose(&[12usize, 16])
            }
        } else {
            sample_bucket(&cfg.gpu_buckets, &mut rng)
        };
        let model = *rng.choose(&ModelKind::ALL);
        let iterations = (rng.lognormal(mu, sigma) as u64).clamp(lo, hi);
        let batch = sample_batch(model, &mut rng);
        jobs.push(JobSpec {
            id,
            model,
            gpus,
            iterations,
            batch,
            arrival_s: t,
            est_factor: 1.0,
        });
    }
    // Estimates draw from their own salted stream (or none at all), so
    // the trace body above is estimator-invariant.
    estimate::materialize(&mut jobs, &cfg.estimator, cfg.seed);
    jobs
}

/// Per-model batch choice: the profile default, occasionally halved/doubled
/// (tenants pick different effective batches; Fig. 2's B sweep). Tenants
/// size their batch to the GPU: the draw is clamped so the job fits an
/// 11 GB device when running alone (the paper measured all jobs solo).
fn sample_batch(model: ModelKind, rng: &mut Rng) -> u32 {
    let prof = WorkloadProfile::get(model);
    let base = prof.default_batch;
    let want = match rng.index(4) {
        0 => (base / 2).max(1),
        3 => base * 2,
        _ => base,
    };
    prof.mem.max_sub_batch(want, 11.0).unwrap_or(1)
}

fn sample_bucket(buckets: &[(usize, f64)], rng: &mut Rng) -> usize {
    let total: f64 = buckets.iter().map(|b| b.1).sum();
    let mut x = rng.f64() * total;
    for &(gpus, w) in buckets {
        if x < w {
            return gpus;
        }
        x -= w;
    }
    buckets.last().unwrap().0
}

// ------------------------------------------------------------ JSON I/O

fn spec_to_json(j: &JobSpec) -> Json {
    let mut m = std::collections::BTreeMap::new();
    m.insert("id".into(), Json::from(j.id));
    m.insert("model".into(), Json::from(j.model.name()));
    m.insert("gpus".into(), Json::from(j.gpus));
    m.insert("iterations".into(), Json::Num(j.iterations as f64));
    m.insert("batch".into(), Json::Num(j.batch as f64));
    m.insert("arrival_s".into(), Json::Num(j.arrival_s));
    // Oracle traces serialize exactly as before workload v2; only a
    // materialized estimate error adds the field.
    if j.est_factor != 1.0 {
        m.insert("est_factor".into(), Json::Num(j.est_factor));
    }
    Json::Obj(m)
}

fn spec_from_json(j: &Json) -> Result<JobSpec> {
    let name = j.req("model")?.as_str().context("model must be a string")?;
    let est_factor = match j.get("est_factor") {
        None | Some(Json::Null) => 1.0,
        Some(v) => v.as_f64().context("est_factor")?,
    };
    Ok(JobSpec {
        id: j.req("id")?.as_usize().context("id")?,
        model: ModelKind::from_name(name)
            .with_context(|| format!("unknown model {name:?}"))?,
        gpus: j.req("gpus")?.as_usize().context("gpus")?,
        iterations: j.req("iterations")?.as_f64().context("iterations")? as u64,
        batch: j.req("batch")?.as_f64().context("batch")? as u32,
        arrival_s: j.req("arrival_s")?.as_f64().context("arrival_s")?,
        est_factor,
    })
}

/// Save a trace as JSON.
pub fn save(jobs: &[JobSpec], path: &std::path::Path) -> Result<()> {
    let doc = Json::Arr(jobs.iter().map(spec_to_json).collect());
    std::fs::write(path, doc.to_string()).context("writing trace")
}

/// Load a trace from JSON, rejecting traces the simulator would silently
/// mis-handle: arrivals must be monotone non-decreasing in file order,
/// and every job needs at least one iteration, one GPU, a positive batch
/// and a positive finite estimate factor. Errors name the offending job.
pub fn load(path: &std::path::Path) -> Result<Vec<JobSpec>> {
    let text = std::fs::read_to_string(path).context("reading trace")?;
    let doc = Json::parse(&text)?;
    let jobs: Vec<JobSpec> = doc
        .as_arr()
        .context("trace must be a JSON array")?
        .iter()
        .map(spec_from_json)
        .collect::<Result<_>>()?;
    let mut prev: Option<&JobSpec> = None;
    for j in &jobs {
        if j.iterations == 0 {
            bail!("job {}: zero iterations (the job would never finish)", j.id);
        }
        if j.gpus == 0 {
            bail!("job {}: zero GPU demand (an empty gang is unschedulable)", j.id);
        }
        if j.batch == 0 {
            bail!("job {}: zero batch size", j.id);
        }
        if !j.arrival_s.is_finite() || j.arrival_s < 0.0 {
            bail!("job {}: arrival {} must be finite and >= 0", j.id, j.arrival_s);
        }
        if !j.est_factor.is_finite() || j.est_factor <= 0.0 {
            bail!(
                "job {}: est_factor {} must be finite and > 0",
                j.id,
                j.est_factor
            );
        }
        if let Some(p) = prev {
            if j.arrival_s < p.arrival_s {
                bail!(
                    "job {} arrives at {} before its predecessor job {} at {} — \
                     traces must be sorted by arrival",
                    j.id,
                    j.arrival_s,
                    p.id,
                    p.arrival_s
                );
            }
        }
        prev = Some(j);
    }
    Ok(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fingerprint(jobs: &[JobSpec]) -> String {
        jobs.iter()
            .map(|j| {
                format!(
                    "{}:{}:{}:{}:{}:{:.3}:{}",
                    j.id,
                    j.model.name(),
                    j.gpus,
                    j.iterations,
                    j.batch,
                    j.arrival_s,
                    j.est_factor
                )
            })
            .collect::<Vec<_>>()
            .join("|")
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = TraceConfig::simulation(50, 7);
        assert_eq!(fingerprint(&generate(&cfg)), fingerprint(&generate(&cfg)));
    }

    #[test]
    fn different_seeds_differ() {
        let a = generate(&TraceConfig::simulation(50, 1));
        let b = generate(&TraceConfig::simulation(50, 2));
        assert_ne!(fingerprint(&a), fingerprint(&b));
    }

    #[test]
    fn arrivals_monotone_and_iters_in_range() {
        let jobs = generate(&TraceConfig::simulation(200, 3));
        assert_eq!(jobs.len(), 200);
        let mut prev = 0.0;
        for j in &jobs {
            assert!(j.arrival_s >= prev);
            prev = j.arrival_s;
            assert!((500..=50_000).contains(&j.iterations));
            assert!(j.gpus >= 1 && j.gpus <= 16);
            assert_eq!(j.est_factor, 1.0, "default estimator is the oracle");
        }
    }

    #[test]
    fn physical_trace_has_paper_size_mix() {
        let jobs = generate(&TraceConfig::physical(11));
        assert_eq!(jobs.len(), 30);
        let large = jobs.iter().filter(|j| j.gpus >= 12).count();
        assert_eq!(large, 10, "paper: 10 jobs at 12 or 16 GPUs");
        assert!(jobs.iter().take(20).all(|j| j.gpus <= 8));
    }

    #[test]
    fn physical_split_scales_proportionally_with_job_count() {
        // The 20/10 paper mix generalizes as a 2:1 small:large ratio, so
        // `--workload philly-physical --jobs 240` keeps the documented
        // shape instead of flooding the tail with 12/16-GPU gangs.
        let cfg = TraceConfig::from_preset(
            &crate::jobs::workload::by_name("philly-physical").unwrap(),
            60,
            7,
        );
        let jobs = generate(&cfg);
        let large = jobs.iter().filter(|j| j.gpus >= 12).count();
        assert_eq!(large, 20, "2:1 ratio at 60 jobs = 40 small / 20 large");
        assert!(jobs.iter().take(40).all(|j| j.gpus <= 8));
    }

    #[test]
    fn preset_constructors_are_thin_preset_calls() {
        let via_ctor = generate(&TraceConfig::simulation(40, 5));
        let via_preset = generate(&TraceConfig::from_preset(
            &crate::jobs::workload::by_name("philly-sim").unwrap(),
            40,
            5,
        ));
        assert_eq!(fingerprint(&via_ctor), fingerprint(&via_preset));
        let phys_ctor = generate(&TraceConfig::physical(5));
        let phys_preset = generate(&TraceConfig::from_preset(
            &crate::jobs::workload::by_name("philly-physical").unwrap(),
            30,
            5,
        ));
        assert_eq!(fingerprint(&phys_ctor), fingerprint(&phys_preset));
    }

    #[test]
    fn every_preset_generates_runnable_traces() {
        for name in crate::jobs::workload::PRESET_NAMES {
            let preset = crate::jobs::workload::by_name(name).unwrap();
            let cfg = TraceConfig::from_preset(&preset, 60, 3);
            let jobs = generate(&cfg);
            assert_eq!(jobs.len(), 60, "{name}");
            let mut prev = 0.0;
            for j in &jobs {
                assert!(j.arrival_s >= prev, "{name}: arrivals must be monotone");
                prev = j.arrival_s;
                assert!(j.iterations >= 1 && j.gpus >= 1 && j.batch >= 1, "{name}");
                assert!(j.gpus <= preset.max_gang(), "{name}");
            }
        }
    }

    #[test]
    fn load_factor_compresses_arrivals() {
        let mut cfg = TraceConfig::simulation(100, 5);
        let base_span = generate(&cfg).last().unwrap().arrival_s;
        cfg.load_factor = 2.0;
        let dense_span = generate(&cfg).last().unwrap().arrival_s;
        assert!(dense_span < base_span, "2x load must compress the horizon");
    }

    #[test]
    fn load_factor_scales_arrival_density_monotonically() {
        // Same seed ⇒ the same uniform draws; the Exp inverse transform
        // then divides every gap by the rate, so the horizon must shrink
        // monotonically — and exactly proportionally — as load rises.
        let span = |load: f64| {
            let mut cfg = TraceConfig::simulation(64, 17);
            cfg.load_factor = load;
            generate(&cfg).last().unwrap().arrival_s
        };
        let loads = [0.5, 1.0, 2.0, 4.0];
        let spans: Vec<f64> = loads.iter().map(|&l| span(l)).collect();
        for w in spans.windows(2) {
            assert!(w[1] < w[0], "higher load must compress arrivals: {spans:?}");
        }
        // Doubling load twice (0.5 -> 2.0) quarters the horizon; the ratio
        // is exact because scaling by powers of two commutes with IEEE
        // rounding.
        let ratio = spans[0] / spans[2];
        assert!((ratio - 4.0).abs() < 1e-9, "span must scale as 1/load, got {ratio}");
    }

    #[test]
    fn load_factor_leaves_job_bodies_invariant() {
        // The satellite pin: `load_factor` scales arrival *frequency*
        // only. Job bodies — model, gpus, iterations, batch, est_factor
        // — must be identical at any load, for every preset (the sampler
        // may consume extra draws for thinning/phases, but the same
        // draws at every load).
        for name in crate::jobs::workload::PRESET_NAMES {
            let preset = crate::jobs::workload::by_name(name).unwrap();
            let mut base = TraceConfig::from_preset(&preset, 48, 17);
            base.estimator = EstimateModel::Noisy { factor_sigma: 0.5, seed: 0 };
            let mut dense = base.clone();
            dense.load_factor = 4.0;
            let a = generate(&base);
            let b = generate(&dense);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.model, y.model, "{name}");
                assert_eq!(x.gpus, y.gpus, "{name}");
                assert_eq!(x.iterations, y.iterations, "{name}");
                assert_eq!(x.batch, y.batch, "{name}");
                assert_eq!(
                    x.est_factor.to_bits(),
                    y.est_factor.to_bits(),
                    "{name}: estimates must be load-invariant"
                );
            }
        }
    }

    #[test]
    fn estimator_leaves_trace_body_invariant() {
        // Materializing estimates must not perturb arrivals or bodies:
        // the noisy stream is salted away from the generator's.
        let mut cfg = TraceConfig::simulation(50, 9);
        let oracle = generate(&cfg);
        cfg.estimator = EstimateModel::Noisy { factor_sigma: 1.0, seed: 3 };
        let noisy = generate(&cfg);
        for (a, b) in oracle.iter().zip(&noisy) {
            assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
            assert_eq!(a.model, b.model);
            assert_eq!(a.gpus, b.gpus);
            assert_eq!(a.iterations, b.iterations);
            assert_eq!(a.batch, b.batch);
        }
        assert!(noisy.iter().any(|j| j.est_factor != 1.0));
    }

    #[test]
    fn json_roundtrip() {
        let dir = std::env::temp_dir().join(format!("wise-share-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let jobs = generate(&TraceConfig::simulation(20, 9));
        save(&jobs, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(fingerprint(&jobs), fingerprint(&back));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn json_roundtrip_preserves_estimates() {
        let dir = std::env::temp_dir().join(format!("wise-share-est-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let mut cfg = TraceConfig::simulation(20, 9);
        cfg.estimator = EstimateModel::Noisy { factor_sigma: 0.8, seed: 1 };
        let jobs = generate(&cfg);
        save(&jobs, &path).unwrap();
        let back = load(&path).unwrap();
        for (a, b) in jobs.iter().zip(&back) {
            assert_eq!(a.est_factor.to_bits(), b.est_factor.to_bits());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    fn write_trace(doc: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "wise-share-load-test-{}-{}",
            std::process::id(),
            doc.len()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        std::fs::write(&path, doc).unwrap();
        path
    }

    #[test]
    fn load_rejects_malformed_traces_with_named_job() {
        let job = |id: usize, iters: u64, gpus: usize, arrival: f64| {
            format!(
                r#"{{"id": {id}, "model": "CIFAR10", "gpus": {gpus},
                    "iterations": {iters}, "batch": 32, "arrival_s": {arrival}}}"#
            )
        };
        // Non-monotone arrivals: the error must name both jobs.
        let p = write_trace(&format!("[{}, {}]", job(0, 100, 1, 50.0), job(1, 100, 1, 10.0)));
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("job 1") && err.contains("job 0"), "{err}");
        assert!(err.contains("sorted by arrival"), "{err}");
        // Zero iterations.
        let p = write_trace(&format!("[{}]", job(3, 0, 1, 0.0)));
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("job 3") && err.contains("zero iterations"), "{err}");
        // Zero GPU demand.
        let p = write_trace(&format!("[{}]", job(4, 100, 0, 0.0)));
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("job 4") && err.contains("zero GPU demand"), "{err}");
        // Degenerate estimate factor.
        let p = write_trace(
            r#"[{"id": 5, "model": "CIFAR10", "gpus": 1, "iterations": 10,
                 "batch": 32, "arrival_s": 0.0, "est_factor": 0.0}]"#,
        );
        let err = load(&p).unwrap_err().to_string();
        assert!(err.contains("job 5") && err.contains("est_factor"), "{err}");
    }
}

//! Duration-estimator layer: what the scheduler *believes* a job's
//! runtime is (DESIGN.md §11 covers the workload/estimator subsystem).
//!
//! Every SJF-family policy in the paper ranks on the oracle remaining
//! solo runtime `L_k`, but production schedulers only ever see
//! *estimates* (Tiresias runs without any; Helios/3Sigma-style systems
//! predict from history). An [`EstimateModel`] is materialized per job at
//! trace time into [`JobSpec::est_factor`] — the scheduler-visible
//! duration as a multiple of the truth — and the policies rank on
//! `estimate = truth × est_factor` via
//! [`SchedContext::estimated_remaining`](crate::sched_core::SchedContext::estimated_remaining),
//! while the simulation engine keeps completing jobs on their *true*
//! iteration counts. `Oracle` (factor exactly 1.0) reproduces the
//! pre-estimator behavior bit-for-bit.

use anyhow::{bail, Context, Result};

use super::JobSpec;
use crate::util::rng::Rng;

/// Stream-splitting constant: the noisy estimator draws from its own RNG
/// stream so materializing estimates never perturbs the arrival/body
/// draws of the trace itself.
const EST_STREAM_SALT: u64 = 0xE571_AA7E_0DD5_EEDD;

/// How per-job duration estimates are produced.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum EstimateModel {
    /// Perfect information: `est_factor = 1.0` exactly (the paper's
    /// setting; golden-parity guaranteed).
    #[default]
    Oracle,
    /// Multiplicative log-normal error: `est_factor = exp(σ·N(0,1))` per
    /// job, σ = `factor_sigma`. `seed` offsets the error stream so two
    /// campaigns can draw independent errors over the same trace.
    Noisy { factor_sigma: f64, seed: u64 },
    /// History-based predictor à la Tiresias/Helios: a job's estimate is
    /// the `pct`-th percentile of the true durations of *previously
    /// arrived* jobs with the same model kind (falling back to the
    /// all-models history, and to the oracle for the cold-start job with
    /// no history at all).
    Percentile { pct: f64 },
}

impl EstimateModel {
    /// Parse a CLI/campaign estimator spec:
    /// `oracle` | `noisy:SIGMA[:SEED]` | `percentile:PCT`.
    pub fn parse(spec: &str) -> Result<EstimateModel> {
        let mut parts = spec.split(':');
        let kind = parts.next().unwrap_or("");
        let model = match kind {
            "oracle" => EstimateModel::Oracle,
            "noisy" => {
                let sigma: f64 = parts
                    .next()
                    .context("noisy estimator needs a sigma: noisy:SIGMA[:SEED]")?
                    .parse()
                    .context("noisy sigma must be a number")?;
                let seed: u64 = match parts.next() {
                    None => 0,
                    Some(s) => s.parse().context("noisy seed must be an integer")?,
                };
                EstimateModel::Noisy { factor_sigma: sigma, seed }
            }
            "percentile" => {
                let pct: f64 = parts
                    .next()
                    .context("percentile estimator needs a percentile: percentile:PCT")?
                    .parse()
                    .context("percentile must be a number")?;
                EstimateModel::Percentile { pct }
            }
            other => bail!(
                "unknown estimator {other:?} (known: oracle, noisy:SIGMA[:SEED], \
                 percentile:PCT)"
            ),
        };
        if let Some(extra) = parts.next() {
            bail!("trailing estimator component {extra:?} in {spec:?}");
        }
        model.validate()?;
        Ok(model)
    }

    /// Canonical spec string — the inverse of [`EstimateModel::parse`].
    /// Campaign cell keys and CSV rows use this, so `noisy:0.50` and
    /// `noisy:0.5` land in the same cell.
    pub fn spec_string(&self) -> String {
        match self {
            EstimateModel::Oracle => "oracle".to_string(),
            EstimateModel::Noisy { factor_sigma, seed: 0 } => {
                format!("noisy:{factor_sigma}")
            }
            EstimateModel::Noisy { factor_sigma, seed } => {
                format!("noisy:{factor_sigma}:{seed}")
            }
            EstimateModel::Percentile { pct } => format!("percentile:{pct}"),
        }
    }

    pub fn validate(&self) -> Result<()> {
        match *self {
            EstimateModel::Oracle => Ok(()),
            EstimateModel::Noisy { factor_sigma, .. } => {
                if factor_sigma < 0.0 || !factor_sigma.is_finite() {
                    bail!("noisy sigma {factor_sigma} must be finite and >= 0");
                }
                Ok(())
            }
            EstimateModel::Percentile { pct } => {
                if !(0.0..=100.0).contains(&pct) {
                    bail!("percentile {pct} must be in [0, 100]");
                }
                Ok(())
            }
        }
    }
}

/// Materialize per-job estimate factors in place. Jobs must be in
/// arrival order (the percentile predictor's "history" is every job that
/// arrived before). Deterministic per `(model, trace_seed)`; the noisy
/// stream is salted so it is independent of the generator's own draws.
pub fn materialize(jobs: &mut [JobSpec], model: &EstimateModel, trace_seed: u64) {
    match *model {
        EstimateModel::Oracle => {
            // Explicit reset: re-materializing a loaded noisy trace with
            // the oracle must restore perfect information.
            for j in jobs {
                j.est_factor = 1.0;
            }
        }
        EstimateModel::Noisy { factor_sigma, seed } => {
            let mut rng = Rng::seed_from_u64(trace_seed ^ seed.rotate_left(32) ^ EST_STREAM_SALT);
            for j in jobs {
                j.est_factor = rng.lognormal(0.0, factor_sigma);
            }
        }
        EstimateModel::Percentile { pct } => {
            // Sorted histories maintained incrementally (one per model
            // kind + a global fallback): each job is a binary-search
            // insert and an O(1) percentile read, instead of
            // re-filtering and re-sorting the whole past per job.
            let mut by_model: Vec<(crate::perf::profiles::ModelKind, Vec<f64>)> =
                Vec::new();
            let mut global: Vec<f64> = Vec::with_capacity(jobs.len());
            for j in jobs.iter_mut() {
                let truth = j.solo_runtime(1);
                let mi = by_model.iter().position(|(m, _)| *m == j.model);
                let hist: &Vec<f64> = match mi {
                    Some(i) if !by_model[i].1.is_empty() => &by_model[i].1,
                    _ => &global,
                };
                if hist.is_empty() {
                    j.est_factor = 1.0; // cold start: no history at all
                } else {
                    let idx = (pct / 100.0 * (hist.len() - 1) as f64).round() as usize;
                    j.est_factor = hist[idx] / truth;
                }
                let mi = mi.unwrap_or_else(|| {
                    by_model.push((j.model, Vec::new()));
                    by_model.len() - 1
                });
                let slot = &mut by_model[mi].1;
                slot.insert(slot.partition_point(|&x| x < truth), truth);
                global.insert(global.partition_point(|&x| x < truth), truth);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::profiles::ModelKind;

    fn spec(id: usize, model: ModelKind, iters: u64) -> JobSpec {
        JobSpec {
            id,
            model,
            gpus: 4,
            iterations: iters,
            batch: 32,
            arrival_s: id as f64,
            est_factor: 1.0,
        }
    }

    #[test]
    fn parse_roundtrips_canonical_specs() {
        for s in ["oracle", "noisy:0.5", "noisy:1.5:7", "percentile:50", "percentile:90"] {
            let m = EstimateModel::parse(s).unwrap();
            assert_eq!(m.spec_string(), s, "canonical form must roundtrip");
            assert_eq!(EstimateModel::parse(&m.spec_string()).unwrap(), m);
        }
        // Non-canonical numerics normalize into the same cell.
        assert_eq!(
            EstimateModel::parse("noisy:0.50").unwrap().spec_string(),
            "noisy:0.5"
        );
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for s in [
            "",
            "magic",
            "noisy",
            "noisy:abc",
            "noisy:-0.5",
            "noisy:0.5:x",
            "noisy:0.5:1:2",
            "percentile",
            "percentile:101",
            "percentile:-1",
        ] {
            assert!(EstimateModel::parse(s).is_err(), "{s:?} must be rejected");
        }
    }

    #[test]
    fn oracle_resets_factors_to_exactly_one() {
        let mut jobs: Vec<JobSpec> = (0..10).map(|i| spec(i, ModelKind::Cifar10, 1000)).collect();
        materialize(&mut jobs, &EstimateModel::Noisy { factor_sigma: 1.0, seed: 0 }, 3);
        assert!(jobs.iter().any(|j| j.est_factor != 1.0));
        materialize(&mut jobs, &EstimateModel::Oracle, 3);
        assert!(jobs.iter().all(|j| j.est_factor.to_bits() == 1.0f64.to_bits()));
    }

    #[test]
    fn noisy_is_deterministic_and_seed_sensitive() {
        let fresh = || (0..50).map(|i| spec(i, ModelKind::Bert, 500)).collect::<Vec<_>>();
        let run = |est_seed: u64, trace_seed: u64| {
            let mut jobs = fresh();
            materialize(
                &mut jobs,
                &EstimateModel::Noisy { factor_sigma: 0.7, seed: est_seed },
                trace_seed,
            );
            jobs.iter().map(|j| j.est_factor).collect::<Vec<_>>()
        };
        assert_eq!(run(0, 1), run(0, 1));
        assert_ne!(run(0, 1), run(1, 1), "estimator seed must shift the error stream");
        assert_ne!(run(0, 1), run(0, 2), "trace seed must shift the error stream");
        assert!(run(0, 1).iter().all(|&f| f > 0.0 && f.is_finite()));
    }

    #[test]
    fn noisy_error_grows_with_sigma() {
        let mean_abs_log = |sigma: f64| {
            let mut jobs: Vec<JobSpec> = (0..2000).map(|i| spec(i, ModelKind::Ncf, 100)).collect();
            materialize(&mut jobs, &EstimateModel::Noisy { factor_sigma: sigma, seed: 0 }, 9);
            jobs.iter().map(|j| j.est_factor.ln().abs()).sum::<f64>() / jobs.len() as f64
        };
        let (a, b, c) = (mean_abs_log(0.25), mean_abs_log(0.5), mean_abs_log(1.0));
        assert!(a < b && b < c, "error must grow with sigma: {a} {b} {c}");
        // σ = 0 is the oracle, exactly.
        assert_eq!(mean_abs_log(0.0), 0.0);
    }

    #[test]
    fn percentile_predicts_from_same_model_history() {
        // Three CIFAR jobs with known runtimes, then a fourth: its p50
        // estimate must be the median of the first three true durations.
        let mut jobs = vec![
            spec(0, ModelKind::Cifar10, 1000),
            spec(1, ModelKind::Cifar10, 3000),
            spec(2, ModelKind::Cifar10, 2000),
            spec(3, ModelKind::Cifar10, 500),
        ];
        let truths: Vec<f64> = jobs.iter().map(|j| j.solo_runtime(1)).collect();
        materialize(&mut jobs, &EstimateModel::Percentile { pct: 50.0 }, 1);
        assert_eq!(jobs[0].est_factor, 1.0, "cold start is the oracle");
        // Job 3's history median is the 2000-iteration job's duration.
        let expect = truths[2] / truths[3];
        assert!((jobs[3].est_factor - expect).abs() < 1e-12, "{}", jobs[3].est_factor);
    }

    #[test]
    fn percentile_falls_back_to_global_history() {
        let mut jobs = vec![
            spec(0, ModelKind::Cifar10, 1000),
            spec(1, ModelKind::Bert, 500), // no BERT history: global fallback
        ];
        let truths: Vec<f64> = jobs.iter().map(|j| j.solo_runtime(1)).collect();
        materialize(&mut jobs, &EstimateModel::Percentile { pct: 50.0 }, 1);
        let expect = truths[0] / truths[1];
        assert!((jobs[1].est_factor - expect).abs() < 1e-12);
    }
}

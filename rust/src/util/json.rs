//! Minimal JSON parser + emitter.
//!
//! Covers the full JSON grammar needed by the AOT ABI (`artifacts/meta.json`
//! written by python) and the trace files: objects, arrays, strings with
//! escapes, numbers, booleans, null. No serde in the vendored crate set —
//! this is the first-party substitute, fuzz-tested in `util::prop`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------- accessors
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required ABI fields.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).with_context(|| format!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Exact non-negative integer accessor: rejects fractional and negative
    /// numbers rather than truncating (a 1.5 in a seed list is a typo, not
    /// a request for seed 1). Bounded at 2^53 — beyond that the f64 carrier
    /// has already lost integer precision, so "exact" cannot be honored.
    pub fn as_u64(&self) -> Option<u64> {
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < MAX_EXACT => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ------------------------------------------------------------ parsing
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // ----------------------------------------------------------- emitting
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(out, "{}", *x as i64).unwrap();
                } else {
                    write!(out, "{x}").unwrap();
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            write!(out, "\\u{:04x}", c as u32).unwrap()
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for emitters.
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", c as char, self.pos)
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().context("unexpected end of input")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at byte {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().context("unterminated string")? {
                b'"' => {
                    self.pos += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.pos += 1;
                    match self.peek().context("bad escape")? {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .context("short \\u escape")?;
                            let code =
                                u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            s.push(char::from_u32(code).context("bad codepoint")?);
                            self.pos += 4;
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                    self.pos += 1;
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| format!("bad number {text:?}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta_like_document() {
        let text = r#"{
          "model": {"vocab": 512, "seq_len": 64},
          "param_names": ["tok_emb", "pos_emb"],
          "micro_batches": [1, 2, 4, 8],
          "nested": {"a": [true, false, null], "b": -1.5e3}
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.req("model").unwrap().req("vocab").unwrap().as_usize(), Some(512));
        assert_eq!(j.get("param_names").unwrap().as_arr().unwrap().len(), 2);
        let nested = j.get("nested").unwrap();
        assert_eq!(nested.get("b").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(nested.get("a").unwrap().as_arr().unwrap()[2], Json::Null);
    }

    #[test]
    fn roundtrip_via_emitter() {
        let text = r#"{"a":[1,2.5,"x\"y\\z"],"b":{"c":true,"d":null},"e":-7}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""line\nbreak\tand A""#).unwrap();
        assert_eq!(j.as_str(), Some("line\nbreak\tand A"));
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ☃"));
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn integer_emission_is_exact() {
        assert_eq!(Json::Num(240.0).to_string(), "240");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 42, "b": true, "s": "x"}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_u64(), Some(42));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("s").unwrap().as_bool(), None);
        assert_eq!(j.get("n").unwrap().as_bool(), None);
        // Exactness: no truncation, no negative wraparound, no values the
        // f64 carrier cannot represent exactly.
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(0.0).as_u64(), Some(0));
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_u64(), None); // 2^53
        assert_eq!(Json::Num(9_007_199_254_740_991.0).as_u64(), Some(9_007_199_254_740_991));
        assert_eq!(Json::from(7u64), Json::Num(7.0));
        assert_eq!(Json::from(false), Json::Bool(false));
    }
}

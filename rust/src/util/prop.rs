//! Property-testing helper (proptest is not in the vendored crate set):
//! runs a property over `n` deterministically-generated random cases and
//! reports the seed of the first failing case so it can be replayed.

use super::rng::Rng;

/// Run `prop(rng)` for `n` cases with per-case seeds derived from `seed`.
/// Panics with the failing case seed on the first failure.
pub fn forall<F: FnMut(&mut Rng) -> std::result::Result<(), String>>(
    name: &str,
    seed: u64,
    n: usize,
    mut prop: F,
) {
    for case in 0..n {
        let case_seed = seed.wrapping_mul(0x100000001B3).wrapping_add(case as u64);
        let mut rng = Rng::seed_from_u64(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed at case {case} (seed {case_seed}): {msg}");
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("unit-interval", 1, 256, |rng| {
            let x = rng.f64();
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn reports_failing_seed() {
        forall("always-fails-eventually", 2, 64, |rng| {
            let x = rng.f64();
            prop_assert!(x < 0.9, "got {x}");
            Ok(())
        });
    }
}

//! First-party substrates that keep the build fully offline: deterministic
//! RNG + distributions, a JSON parser/emitter, a micro benchmark harness,
//! and a property-testing helper. (The build environment vendors only the
//! `xla` dependency tree; see DESIGN.md §4.)

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

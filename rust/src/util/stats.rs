//! Shared percentile/quantile primitives.
//!
//! The repo grew three percentile implementations with *different* —
//! deliberately different — semantics: `sim/metrics.rs` used nearest-rank
//! interpolation on the (n−1)-scaled index (what the paper-table pins were
//! recorded against), `util/bench.rs` used ceiling rank (exact on quantile
//! boundaries for timing samples), and the campaign aggregator summarized
//! via Welford streams with no percentile at all. This module is the single
//! home for both sample-percentile definitions; callers delegate here and
//! pick the semantics they were pinned against. Neither function is a
//! drop-in for the other — see `nearest_vs_ceiling_divergence` below for
//! the smallest sample on which they disagree.

/// Percentile by *nearest rank on the (n−1)-scaled index*:
/// `sorted[round((n-1)·p)]`. Returns 0.0 for an empty sample.
///
/// This is the historical `sim::metrics` definition. The paper-table
/// goldens (Tables II–IV p50/p90 JCT columns) were recorded against it, so
/// its behavior — including the 0.0-on-empty convention — is pinned for
/// byte parity and must not be "fixed" to another definition.
pub fn percentile_nearest_rank(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Percentile by *ceiling rank*: the smallest value whose 1-based rank `r`
/// satisfies `r >= p·n`. Panics on an empty sample or `p` outside [0, 1].
///
/// This is the `util::bench` definition used for timing distributions: it
/// is exact on quantile boundaries and never overshoots (n = 20, p = 0.95
/// picks the 19th value, not the max).
pub fn percentile_ceiling_rank(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of an empty sample");
    assert!((0.0..=1.0).contains(&p), "percentile {p} outside [0, 1]");
    let rank = (sorted.len() as f64 * p).ceil().max(1.0) as usize;
    sorted[rank.min(sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_pins() {
        // The historical sim::metrics behavior, pinned.
        let ten: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile_nearest_rank(&ten, 0.5), 6.0); // round(9·0.5)=5 -> 6.0
        assert_eq!(percentile_nearest_rank(&ten, 0.9), 9.0); // round(9·0.9)=8 -> 9.0
        assert_eq!(percentile_nearest_rank(&ten, 0.0), 1.0);
        assert_eq!(percentile_nearest_rank(&ten, 1.0), 10.0);
        assert_eq!(percentile_nearest_rank(&[42.0], 0.5), 42.0);
        assert_eq!(percentile_nearest_rank(&[], 0.5), 0.0); // empty -> 0.0, by contract
    }

    #[test]
    fn ceiling_rank_pins() {
        // The util::bench behavior, pinned (mirrors the bench-side test).
        let twenty: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        assert_eq!(percentile_ceiling_rank(&twenty, 0.95), 19.0);
        assert_eq!(percentile_ceiling_rank(&twenty, 0.50), 10.0);
        assert_eq!(percentile_ceiling_rank(&twenty, 1.0), 20.0);
        assert_eq!(percentile_ceiling_rank(&twenty, 0.0), 1.0);
        assert_eq!(percentile_ceiling_rank(&[7.0], 0.95), 7.0);
    }

    #[test]
    fn nearest_vs_ceiling_divergence() {
        // The smallest interesting sample on which the two definitions
        // disagree — the reason they cannot be merged into one function.
        let s = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_nearest_rank(&s, 0.5), 3.0); // round(3·0.5)=2 -> 3.0
        assert_eq!(percentile_ceiling_rank(&s, 0.5), 2.0); // ceil(4·0.5)=2 -> 2.0
    }

    #[test]
    #[should_panic(expected = "empty sample")]
    fn ceiling_rank_rejects_empty() {
        percentile_ceiling_rank(&[], 0.5);
    }
}

//! Micro benchmark harness for the `cargo bench` targets (criterion is not
//! in the vendored crate set). Reports min/mean/p50/p95 over timed
//! iterations after a warm-up pass, in criterion-like one-line format.
//!
//! The machine-readable side — suite registry, schema-versioned JSON
//! reports, baseline regression gates — lives in [`crate::perfkit`]; this
//! module stays the dependency-free timing core both share.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<4} mean={} min={} p50={} p95={}",
            self.name,
            self.iters,
            fmt_s(self.mean_s),
            fmt_s(self.min_s),
            fmt_s(self.p50_s),
            fmt_s(self.p95_s)
        )
    }
}

fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Percentile of an ascending-sorted sample by *ceiling rank*: the
/// smallest value whose 1-based rank `r` satisfies `r >= p·n`.
///
/// The old `(n as f64 * p) as usize` index truncated toward zero, which
/// for small samples lands below the requested percentile (n = 20,
/// p = 0.95 indexed the 20th value — the max — instead of the 19th).
/// Ceiling rank is exact on quantile boundaries and never overshoots.
/// The shared implementation lives in [`crate::util::stats`].
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    super::stats::percentile_ceiling_rank(sorted, p)
}

/// Sort `times` and fold them into a [`BenchStats`]. Public so callers
/// that collect their own timing samples (e.g. obskit's per-policy
/// `on_event` latency histograms feeding perfkit) can reuse the exact
/// bench-side summary semantics.
pub fn stats_of(name: &str, mut times: Vec<f64>) -> BenchStats {
    times.sort_by(f64::total_cmp);
    BenchStats {
        name: name.to_string(),
        iters: times.len(),
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
        min_s: times[0],
        p50_s: percentile(&times, 0.50),
        p95_s: percentile(&times, 0.95),
    }
}

/// Time `f` for at least `min_iters` iterations (and at least one), after
/// one warm-up call. Prints and returns the stats.
pub fn bench<F: FnMut()>(name: &str, min_iters: usize, mut f: F) -> BenchStats {
    f(); // warm-up
    let mut times = Vec::with_capacity(min_iters.max(1));
    for _ in 0..min_iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let stats = stats_of(name, times);
    println!("{}", stats.report());
    stats
}

/// Time a single call of `f` — no warm-up, one timed run. For end-to-end
/// cases (whole-table regeneration, 10k-job simulations) where a warm-up
/// pass would double the cost and the run is long enough to be stable.
pub fn bench_once<F: FnOnce()>(name: &str, f: F) -> BenchStats {
    let t0 = Instant::now();
    f();
    let stats = stats_of(name, vec![t0.elapsed().as_secs_f64()]);
    println!("{}", stats.report());
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench("noop", 16, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.p95_s);
        assert_eq!(s.iters, 16);
    }

    #[test]
    fn bench_once_records_single_iteration() {
        let s = bench_once("noop-once", || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.iters, 1);
        assert_eq!(s.min_s, s.mean_s);
        assert_eq!(s.p50_s, s.p95_s);
    }

    #[test]
    fn p95_uses_ceiling_rank_on_20_samples() {
        // The satellite pin: for 1..=20, p95 by ceiling rank is the 19th
        // value (rank ceil(20 · 0.95) = 19), not the 20th the truncating
        // index returned.
        let samples: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        assert_eq!(percentile(&samples, 0.95), 19.0);
        assert_eq!(percentile(&samples, 0.50), 10.0);
        assert_eq!(percentile(&samples, 1.0), 20.0);
        assert_eq!(percentile(&samples, 0.0), 1.0);
        // Small-n edges: a single sample is every percentile.
        assert_eq!(percentile(&[7.0], 0.95), 7.0);
        assert_eq!(percentile(&[1.0, 2.0], 0.95), 2.0);
        // Just over a rank boundary rounds *up* to the next value.
        let ten: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile(&ten, 0.95), 10.0);
        assert_eq!(percentile(&ten, 0.90), 9.0);
    }

    #[test]
    fn formats_scale() {
        assert!(fmt_s(2.0).ends_with('s'));
        assert!(fmt_s(0.002).ends_with("ms"));
        assert!(fmt_s(2e-6).ends_with("µs"));
    }
}

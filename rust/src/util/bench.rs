//! Micro benchmark harness for the `cargo bench` targets (criterion is not
//! in the vendored crate set). Reports min/mean/p50/p95 over timed
//! iterations after a warm-up pass, in criterion-like one-line format.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<4} mean={} min={} p50={} p95={}",
            self.name,
            self.iters,
            fmt_s(self.mean_s),
            fmt_s(self.min_s),
            fmt_s(self.p50_s),
            fmt_s(self.p95_s)
        )
    }
}

fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Time `f` for at least `min_iters` iterations (and at least one), after
/// one warm-up call. Prints and returns the stats.
pub fn bench<F: FnMut()>(name: &str, min_iters: usize, mut f: F) -> BenchStats {
    f(); // warm-up
    let mut times = Vec::with_capacity(min_iters.max(1));
    for _ in 0..min_iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(f64::total_cmp);
    let stats = BenchStats {
        name: name.to_string(),
        iters: times.len(),
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
        min_s: times[0],
        p50_s: times[times.len() / 2],
        p95_s: times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)],
    };
    println!("{}", stats.report());
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench("noop", 16, || { std::hint::black_box(1 + 1); });
        assert!(s.min_s <= s.p50_s && s.p50_s <= s.p95_s);
        assert_eq!(s.iters, 16);
    }

    #[test]
    fn formats_scale() {
        assert!(fmt_s(2.0).ends_with('s'));
        assert!(fmt_s(0.002).ends_with("ms"));
        assert!(fmt_s(2e-6).ends_with("µs"));
    }
}

//! Deterministic pseudo-random generator + the distributions the trace
//! generator needs (uniform, exponential, log-normal, normal).
//!
//! splitmix64 core: tiny, well-tested avalanche constants, reproducible
//! across platforms — all the simulator needs. Every stream is fully
//! determined by its seed, which is what makes traces and tests replayable.

/// splitmix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [lo, hi) — hi exclusive, requires hi > lo.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "empty range");
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform integer in [lo, hi) as i64.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo, "empty range");
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0, "empty choice");
        (self.next_u64() % n as u64) as usize
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Exponential with rate λ (mean 1/λ), via inverse transform.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with parameters (mu, sigma) of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(
            (0..4).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Rng::seed_from_u64(4);
        for _ in 0..10_000 {
            let x = r.range_u64(5, 12);
            assert!((5..12).contains(&x));
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::seed_from_u64(5);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exp(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(6);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::seed_from_u64(7);
        let n = 50_001;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(3.0, 1.0)).collect();
        xs.sort_by(f64::total_cmp);
        let median = xs[n / 2];
        assert!((median - 3.0f64.exp()).abs() < 1.0, "median={median}");
    }

    #[test]
    fn choose_covers_all_elements() {
        let mut r = Rng::seed_from_u64(8);
        let xs = [1, 2, 3, 4];
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[*r.choose(&xs) as usize - 1] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}

//! [`PendingOrder`]: incrementally maintained orderings of the eligible
//! pending set, so a policy pass iterates candidates in priority order
//! without re-sorting the backlog per event (DESIGN.md §16 covers the
//! policy-pass hot path this index serves).
//!
//! Two orderings cover all seven shipped policies:
//!
//! * **by estimate** — `(total_cmp(estimated_remaining), id)` ascending:
//!   the shared SJF-family key (SJF, SJF-FFS, SJF-BSBF, SJF-BSBF-k) and
//!   the within-queue order Tiresias admits in.
//! * **by arrival** — `(total_cmp(arrival_s), id)` ascending: FIFO's
//!   head-of-line order and the Tiresias tie-break.
//!
//! Both keys are **frozen while a job is pending**, which is what makes
//! the index sound: `estimated_remaining` reads
//! `est_rate × remaining_iters`, where `est_rate` only changes on a
//! `Start` (it is a function of the accumulation step) and a pending
//! job's lazy `remaining_iters` is bit-stable between events (its
//! integration rate is the ∞ sentinel, so the closed form collapses to
//! the stored field — see `ledger`). Arrival times never change. The
//! index therefore updates only at the pending-set membership sites in
//! `context`/`txn`, and `SchedContext::cache_integrity` cross-checks it
//! against a full re-sort.
//!
//! Keys are stored as sign-flipped IEEE-754 bit patterns
//! ([`key_bits`]), a monotone bijection with `f64::total_cmp` — the
//! `BTreeSet` order is exactly the order the eager `sort_by` produced,
//! including for `-0.0`/`NaN` corner values.
//!
//! One subtlety pins the stored-key design: `apply_start` refreshes
//! `est_rate` (new accumulation step) *before* removing the job from the
//! pending set, so removal by recomputed key would miss the entry.
//! [`PendingOrder::remove`] therefore removes by the key the job was
//! inserted with (`est_key`), never by recomputation.

use std::collections::BTreeSet;

use crate::jobs::JobId;

/// Monotone u64 encoding of an `f64`: `a.total_cmp(&b) == key_bits(a)
/// .cmp(&key_bits(b))` for all values, NaNs and signed zeros included.
pub(super) fn key_bits(x: f64) -> u64 {
    let b = x.to_bits();
    if b & (1 << 63) != 0 {
        !b
    } else {
        b | (1 << 63)
    }
}

/// Ordered views of the eligible pending set. Membership and both
/// orderings are maintained at the same sites that mutate
/// `SchedContext::pending`; `by_arrival` is the membership source of
/// truth (insert/remove are idempotent, mirroring the sorted-Vec set
/// helpers they ride along with).
#[derive(Debug, Clone, Default)]
pub struct PendingOrder {
    /// `(key_bits(estimated_remaining at insert), id)` ascending.
    by_estimate: BTreeSet<(u64, JobId)>,
    /// `(key_bits(arrival_s), id)` ascending.
    by_arrival: BTreeSet<(u64, JobId)>,
    /// The estimate key each pending job was inserted under — removal
    /// must use this, not a recomputation (see the module docs).
    est_key: Vec<u64>,
}

impl PendingOrder {
    /// Empty order sized for `n` jobs (no job pending yet).
    pub fn with_jobs(n: usize) -> Self {
        PendingOrder {
            by_estimate: BTreeSet::new(),
            by_arrival: BTreeSet::new(),
            est_key: vec![0; n],
        }
    }

    /// Register one more job id (live ingestion); it is not pending.
    pub(super) fn grow(&mut self) {
        self.est_key.push(0);
    }

    /// Index `id` as pending under the given keys. No-op if present
    /// (zero-penalty preempts insert eagerly and again on the queued
    /// `RestartEligible` pop, exactly like `set_insert`).
    pub(super) fn insert(&mut self, id: JobId, estimate: f64, arrival_s: f64) {
        if self.by_arrival.insert((key_bits(arrival_s), id)) {
            let k = key_bits(estimate);
            self.est_key[id] = k;
            self.by_estimate.insert((k, id));
        }
    }

    /// Drop `id` from the order. No-op if absent.
    pub(super) fn remove(&mut self, id: JobId, arrival_s: f64) {
        if self.by_arrival.remove(&(key_bits(arrival_s), id)) {
            self.by_estimate.remove(&(self.est_key[id], id));
        }
    }

    pub fn len(&self) -> usize {
        self.by_arrival.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_arrival.is_empty()
    }

    /// Pending ids ascending by `(estimated_remaining, id)` — the
    /// SJF-family candidate order, without the per-pass re-sort.
    pub fn iter_by_estimate(&self) -> impl Iterator<Item = JobId> + '_ {
        self.by_estimate.iter().map(|&(_, id)| id)
    }

    /// Pending ids ascending by `(arrival_s, id)` — FIFO's head-of-line
    /// order and the Tiresias within-queue order.
    pub fn iter_by_arrival(&self) -> impl Iterator<Item = JobId> + '_ {
        self.by_arrival.iter().map(|&(_, id)| id)
    }

    /// The estimate key `id` is currently indexed under (integrity
    /// checks only — meaningless for non-pending ids).
    pub(super) fn est_key(&self, id: JobId) -> u64 {
        self.est_key[id]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_bits_orders_like_total_cmp() {
        let vals = [
            f64::NEG_INFINITY,
            -1.5,
            -0.0,
            0.0,
            1e-300,
            42.0,
            f64::INFINITY,
            f64::NAN,
            -f64::NAN,
        ];
        for &a in &vals {
            for &b in &vals {
                assert_eq!(
                    a.total_cmp(&b),
                    key_bits(a).cmp(&key_bits(b)),
                    "key_bits must order {a} vs {b} like total_cmp"
                );
            }
        }
    }

    #[test]
    fn insert_remove_idempotent_and_ordered() {
        let mut o = PendingOrder::with_jobs(4);
        o.insert(2, 5.0, 1.0);
        o.insert(0, 9.0, 3.0);
        o.insert(1, 5.0, 2.0);
        o.insert(2, 7.0, 1.0); // duplicate: ignored, keys unchanged
        assert_eq!(o.len(), 3);
        assert_eq!(o.iter_by_estimate().collect::<Vec<_>>(), vec![1, 2, 0]);
        assert_eq!(o.iter_by_arrival().collect::<Vec<_>>(), vec![2, 1, 0]);
        o.remove(3, 0.0); // absent: no-op
        o.remove(1, 2.0);
        o.remove(1, 2.0);
        assert_eq!(o.iter_by_estimate().collect::<Vec<_>>(), vec![2, 0]);
        assert!(!o.is_empty());
    }

    #[test]
    fn removal_survives_key_drift() {
        // The apply_start hazard: the live estimate changed after insert;
        // removal must still find the entry via the stored key.
        let mut o = PendingOrder::with_jobs(1);
        o.insert(0, 10.0, 0.5);
        o.remove(0, 0.5);
        assert!(o.is_empty());
        assert_eq!(o.iter_by_estimate().count(), 0);
    }
}

//! [`SchedContext`]: the world view shared by both scheduling backends.
//!
//! Owns the [`SimState`] plus incrementally-maintained index caches so
//! that (a) policies read the pending/running sets as slices instead of
//! re-allocating `Vec`s per call, and (b) the engine selects its next
//! event from min-heaps in O(log n) instead of rescanning every running
//! job per event. All mutation goes through the methods here and through
//! [`SchedContext::apply`](super::txn) — the caches can never drift from
//! the state they index.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::ops::Deref;

use crate::cluster::overlay::OverlayPool;
use crate::cluster::{Cluster, ClusterOverlay};
use crate::jobs::{JobId, JobRecord, JobSpec, JobState};
use crate::obskit::Obs;
use crate::perf::interference::InterferenceModel;
use crate::sim::SimState;

use super::Event;

/// Eligibility slack shared with the legacy `SimState` scans: a time `t`
/// counts as reached once `now + EPS >= t`.
pub(super) const T_EPS: f64 = 1e-9;

/// Total-order wrapper so event times can live in a [`BinaryHeap`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub(super) struct OrdF64(pub f64);

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Insert into a sorted id set (no-op if present).
pub(super) fn set_insert(v: &mut Vec<JobId>, id: JobId) {
    if let Err(i) = v.binary_search(&id) {
        v.insert(i, id);
    }
}

/// Remove from a sorted id set (no-op if absent).
pub(super) fn set_remove(v: &mut Vec<JobId>, id: JobId) {
    if let Ok(i) = v.binary_search(&id) {
        v.remove(i);
    }
}

/// Estimated solo seconds per iteration of a job at its current
/// accumulation step — the cached factor of
/// [`SchedContext::estimated_remaining`]. Bit-identical to the plain
/// iteration time under the oracle (`est_factor == 1.0`).
pub(super) fn est_rate_of(rec: &JobRecord) -> f64 {
    rec.spec.estimated_iter_time(rec.accum_step)
}

/// Sort an arrival queue by (arrival, id) descending, so the next arrival
/// pops from the back and simultaneous arrivals pop in ascending id order.
fn sort_arrivals_desc(state: &SimState, ids: &mut [JobId]) {
    ids.sort_by(|&a, &b| {
        let (aa, ab) = (state.jobs[a].spec.arrival_s, state.jobs[b].spec.arrival_s);
        ab.total_cmp(&aa).then(b.cmp(&a))
    });
}

/// The read view handed to policies and the single mutation path shared
/// by the simulator engine and the physical coordinator.
///
/// Derefs to [`SimState`] for read access to jobs, cluster, interference
/// model, `not_before` and `service_gpu_s`; the state itself is private
/// so every transition flows through the validated methods below.
#[derive(Debug, Clone)]
pub struct SchedContext {
    pub(super) state: SimState,
    /// Eligible pending set: arrived, `Pending`/`Preempted`, past any
    /// restart penalty. Sorted ascending by id.
    pub(super) pending: Vec<JobId>,
    /// Running set, sorted ascending by id.
    pub(super) running: Vec<JobId>,
    /// Waiting set (queue-time accrual): arrived and `Pending`/
    /// `Preempted`, *including* jobs still under a restart penalty.
    pub(super) waiting: Vec<JobId>,
    /// Jobs not yet arrived, sorted by (arrival, id) descending so the
    /// next arrival pops from the back.
    pub(super) future_arrivals: Vec<JobId>,
    /// Min-heap of `(not_before, job)` restart-penalty expiries.
    pub(super) restart_heap: BinaryHeap<Reverse<(OrdF64, JobId)>>,
    /// Min-heap of `(projected finish, job, epoch)`; entries whose epoch
    /// is stale (the job's progress rate changed since) are skipped.
    pub(super) finish_heap: BinaryHeap<Reverse<(OrdF64, JobId, u64)>>,
    /// Per-job rate epoch, bumped whenever the job's iteration rate
    /// changes (start, preempt, finish, or a co-runner change).
    pub(super) rate_epoch: Vec<u64>,
    /// Count of `Finished` jobs (O(1) `all_finished`).
    pub(super) finished: usize,
    /// Whether finish projections are maintained. True under the
    /// simulated clock; the first `advance_wall` call turns it off —
    /// projections are simulated-time quantities, meaningless against
    /// the wall clock, and the coordinator never consults them.
    pub(super) project_finishes: bool,
    /// Placement-resolved effective iteration time per job, memoized as
    /// `(rate epoch at computation, seconds)`; a stale epoch means
    /// invalid. Start/preempt/finish and co-runner changes bump
    /// `rate_epoch`, so invalidation rides the existing plumbing.
    iter_cache: Vec<(u64, f64)>,
    /// Estimated solo seconds/iteration per job at its current
    /// accumulation step (`iter_time(accum) × est_factor`), maintained
    /// eagerly: it only changes when a `Start` sets a new accumulation
    /// step, so `estimated_remaining` — the SJF-family sort key, read
    /// O(n log n) times per event — is a single multiply instead of a
    /// profile walk (`estimate/*` in `cargo bench --bench
    /// sched_overhead`).
    pub(super) est_rate: Vec<f64>,
    /// Scratch-buffer pool for [`SchedContext::overlay`] planning views.
    overlay_pool: OverlayPool,
    /// Pooled id buffer for [`SchedContext::collect_completions`] — with
    /// the overlay pool and the engine's reused event vecs, this was the
    /// event loop's last steady-state per-event allocation.
    completions_scratch: Vec<JobId>,
    /// Observability handle (disabled by default — a single `None`
    /// branch per tap; see [`SchedContext::set_obs`]). Recording is
    /// strictly one-way: it never mutates sim state, RNG, or ordering.
    pub(super) obs: Obs,
    /// GPU-seconds with ≥ 1 resident job, integrated in `advance` (two
    /// O(1) occupancy reads per step, so it is always on) — drives the
    /// utilization columns in campaign CSV v3 and the obskit sampler.
    busy_gpu_s: f64,
    /// GPU-seconds with ≥ 2 resident jobs (co-located intervals).
    shared_gpu_s: f64,
}

impl Deref for SchedContext {
    type Target = SimState;

    fn deref(&self) -> &SimState {
        &self.state
    }
}

impl SchedContext {
    /// Fresh context at `now = 0` over unstarted (all-`Pending`) job
    /// records. Every job — including those arriving at `t = 0` — is a
    /// *future* arrival: its `Arrival` event fires on the first
    /// `advance_*` call that reaches its arrival time, so backends see
    /// one event per job, always.
    pub fn new(cluster: Cluster, jobs: Vec<JobRecord>, xi: InterferenceModel) -> Self {
        debug_assert!(jobs.iter().all(|j| j.state == JobState::Pending));
        let n = jobs.len();
        let state = SimState {
            now: 0.0,
            cluster,
            jobs,
            xi,
            not_before: vec![0.0; n],
            service_gpu_s: vec![0.0; n],
        };
        let mut future_arrivals: Vec<JobId> = (0..n).collect();
        sort_arrivals_desc(&state, &mut future_arrivals);
        let est_rate = state.jobs.iter().map(est_rate_of).collect();
        SchedContext {
            state,
            pending: Vec::new(),
            running: Vec::new(),
            waiting: Vec::new(),
            future_arrivals,
            restart_heap: BinaryHeap::new(),
            finish_heap: BinaryHeap::new(),
            rate_epoch: vec![0; n],
            finished: 0,
            project_finishes: true,
            iter_cache: vec![(u64::MAX, 0.0); n],
            est_rate,
            overlay_pool: OverlayPool::default(),
            completions_scratch: Vec::new(),
            obs: Obs::disabled(),
            busy_gpu_s: 0.0,
            shared_gpu_s: 0.0,
        }
    }

    /// Build a context over an arbitrary world snapshot (tests, benches,
    /// synthetic mid-simulation states), rebuilding every cache. Unlike
    /// [`SchedContext::new`], jobs whose arrival time has already passed
    /// are indexed as pending/waiting immediately — no `Arrival` events
    /// fire for them.
    pub fn from_state(state: SimState) -> Self {
        let n = state.jobs.len();
        let est_rate = state.jobs.iter().map(est_rate_of).collect();
        let mut ctx = SchedContext {
            state,
            pending: Vec::new(),
            running: Vec::new(),
            waiting: Vec::new(),
            future_arrivals: Vec::new(),
            restart_heap: BinaryHeap::new(),
            finish_heap: BinaryHeap::new(),
            rate_epoch: vec![0; n],
            finished: 0,
            project_finishes: true,
            iter_cache: vec![(u64::MAX, 0.0); n],
            est_rate,
            overlay_pool: OverlayPool::default(),
            completions_scratch: Vec::new(),
            obs: Obs::disabled(),
            busy_gpu_s: 0.0,
            shared_gpu_s: 0.0,
        };
        let now = ctx.state.now;
        for id in 0..n {
            let rec = &ctx.state.jobs[id];
            match rec.state {
                JobState::Running => ctx.running.push(id),
                JobState::Finished => ctx.finished += 1,
                JobState::Pending | JobState::Preempted => {
                    if rec.spec.arrival_s <= now + T_EPS {
                        ctx.waiting.push(id);
                        if ctx.state.not_before[id] <= now + T_EPS {
                            ctx.pending.push(id);
                        } else {
                            ctx.restart_heap
                                .push(Reverse((OrdF64(ctx.state.not_before[id]), id)));
                        }
                    } else {
                        ctx.future_arrivals.push(id);
                    }
                }
            }
        }
        let mut future = std::mem::take(&mut ctx.future_arrivals);
        sort_arrivals_desc(&ctx.state, &mut future);
        ctx.future_arrivals = future;
        let running = ctx.running.clone();
        for id in running {
            ctx.reproject(id);
        }
        ctx
    }

    /// Consume the context, returning the final world state.
    pub fn into_state(self) -> SimState {
        self.state
    }

    /// Attach an observability handle (disabled by default). Clones share
    /// the handle's sinks with the caller; recording is one-way and never
    /// affects scheduling, integration, or event ordering.
    pub fn set_obs(&mut self, obs: Obs) {
        self.obs = obs;
    }

    pub fn obs(&self) -> &Obs {
        &self.obs
    }

    /// GPU-seconds with at least one resident job, integrated by the
    /// `advance_*` path over the whole run so far.
    pub fn busy_gpu_s(&self) -> f64 {
        self.busy_gpu_s
    }

    /// GPU-seconds with at least two resident jobs (shared intervals).
    pub fn shared_gpu_s(&self) -> f64 {
        self.shared_gpu_s
    }

    pub fn state(&self) -> &SimState {
        &self.state
    }

    pub fn now(&self) -> f64 {
        self.state.now
    }

    /// Jobs currently eligible for scheduling (arrived, not running, past
    /// their restart penalty), ascending by id. Maintained incrementally —
    /// no allocation, no scan.
    pub fn pending(&self) -> &[JobId] {
        &self.pending
    }

    /// Running jobs, ascending by id. Maintained incrementally.
    pub fn running(&self) -> &[JobId] {
        &self.running
    }

    /// Arrived jobs accruing queueing delay (eligible or penalty-held).
    pub fn waiting(&self) -> &[JobId] {
        &self.waiting
    }

    /// Borrow a hypothetical-allocation planning view over the cluster.
    ///
    /// This is what a full-pass policy uses instead of
    /// `ctx.cluster.clone()`: reads fall through to the live occupancy,
    /// tentative `allocate`/`release` calls are recorded as deltas, and
    /// the scratch buffers are pooled on the context so steady-state
    /// acquisition allocates nothing (`plan-view/*` in
    /// `cargo bench --bench sched_overhead`).
    pub fn overlay(&self) -> ClusterOverlay<'_> {
        self.overlay_pool.acquire(&self.state.cluster)
    }

    /// Placement-resolved effective iteration time of a *running* job
    /// ([`SimState::effective_iter_time`]), memoized per rate epoch: the
    /// O(cluster) co-runner/span derivation runs once per rate change
    /// (start, preempt, finish, co-runner change) instead of once per
    /// event.
    pub fn cached_iter_time(&mut self, id: JobId) -> f64 {
        let epoch = self.rate_epoch[id];
        let (cached_epoch, cached) = self.iter_cache[id];
        if cached_epoch == epoch {
            return cached;
        }
        let t = self.state.effective_iter_time(id);
        self.iter_cache[id] = (epoch, t);
        t
    }

    /// The scheduler's *belief* about `id`'s remaining solo runtime:
    /// `iter_time(accum) × est_factor × remaining_iters` — the
    /// SJF-family priority key under the duration-estimator layer.
    /// Under the oracle (`est_factor == 1.0`) this is bit-identical to
    /// [`JobRecord::remaining_solo_runtime`]; under `Noisy`/`Percentile`
    /// estimators it is what the policies mis-rank on while the engine
    /// keeps completing jobs on their true iteration counts.
    ///
    /// O(1): the per-iteration factor is cached on the context and only
    /// changes when a `Start` sets a new accumulation step.
    pub fn estimated_remaining(&self, id: JobId) -> f64 {
        self.est_rate[id] * self.state.jobs[id].remaining_iters
    }

    pub fn all_finished(&self) -> bool {
        self.finished == self.state.jobs.len()
    }

    pub fn unfinished(&self) -> usize {
        self.state.jobs.len() - self.finished
    }

    // ---------------------------------------------- next-event queries

    /// Earliest future arrival, if any.
    pub fn next_arrival(&self) -> Option<f64> {
        self.future_arrivals.last().map(|&id| self.state.jobs[id].spec.arrival_s)
    }

    /// Earliest restart-penalty expiry among preempted jobs, if any.
    pub fn next_restart(&self) -> Option<f64> {
        self.restart_heap.peek().map(|&Reverse((OrdF64(t), _))| t)
    }

    /// Earliest projected completion among running jobs, if any.
    ///
    /// O(log n) amortized: the heap holds one live entry per running job
    /// (re-pushed whenever a rate changes); stale entries are popped here.
    /// Simulated-clock backends only — after the first `advance_wall`
    /// call projections are no longer maintained and this returns `None`
    /// (wall-mode completions come from real execution progress).
    pub fn next_finish(&mut self) -> Option<f64> {
        while let Some(&Reverse((OrdF64(t), id, epoch))) = self.finish_heap.peek() {
            if epoch == self.rate_epoch[id] {
                return Some(t);
            }
            let _ = self.finish_heap.pop();
        }
        None
    }

    // ------------------------------------------------ time advancement

    /// Simulator clock: advance to `t`, integrating job progress at the
    /// piecewise-constant Eq. 7 × ξ rates, accruing `service_gpu_s` and
    /// `queued_s`, and firing `Arrival`/`RestartEligible` events due by
    /// `t` into `events`.
    pub fn advance_sim(&mut self, t: f64, events: &mut Vec<Event>) {
        self.advance(t, true, events);
    }

    /// Wall clock (physical coordinator): advance to `t`, accruing
    /// `service_gpu_s` and `queued_s` and firing events — but *not*
    /// integrating `remaining_iters`, which real execution drives through
    /// [`SchedContext::note_progress`].
    pub fn advance_wall(&mut self, t: f64, events: &mut Vec<Event>) {
        // Wall mode never consults next_finish(); stop maintaining (and
        // accumulating) simulated-time projections from here on.
        self.project_finishes = false;
        self.finish_heap.clear();
        self.advance(t, false, events);
    }

    fn advance(&mut self, t: f64, integrate: bool, events: &mut Vec<Event>) {
        let dt = t - self.state.now;
        if dt > 0.0 {
            // Occupancy is piecewise-constant between events, so the
            // utilization integrals are two O(1) multiplies per step.
            let total = self.state.cluster.total_gpus();
            let busy = total - self.state.cluster.free_count();
            let shared = busy - self.state.cluster.one_job_count();
            self.busy_gpu_s += busy as f64 * dt;
            self.shared_gpu_s += shared as f64 * dt;
            // Take the sets out so the loop can mutate `state` freely; the
            // transitions below never touch them mid-loop.
            let running = std::mem::take(&mut self.running);
            for &id in &running {
                if integrate {
                    let it = self.cached_iter_time(id);
                    let rec = &mut self.state.jobs[id];
                    rec.remaining_iters = (rec.remaining_iters - dt / it).max(0.0);
                }
                let held = self.state.jobs[id].gpus_held.len() as f64;
                self.state.service_gpu_s[id] += held * dt;
            }
            self.running = running;
            let waiting = std::mem::take(&mut self.waiting);
            for &id in &waiting {
                self.state.jobs[id].queued_s += dt;
            }
            self.waiting = waiting;
        }
        self.state.now = t;

        while let Some(&id) = self.future_arrivals.last() {
            if self.state.jobs[id].spec.arrival_s > t + T_EPS {
                break;
            }
            self.future_arrivals.pop();
            set_insert(&mut self.waiting, id);
            set_insert(&mut self.pending, id);
            events.push(Event::Arrival { job: id });
        }
        while let Some(&Reverse((OrdF64(nb), id))) = self.restart_heap.peek() {
            if nb > t + T_EPS {
                break;
            }
            self.restart_heap.pop();
            // Guards: the job may have restarted meanwhile (zero-penalty
            // preempt + same-transaction start), or this entry may be
            // stale because a newer preemption pushed a later expiry.
            if matches!(self.state.jobs[id].state, JobState::Pending | JobState::Preempted)
                && self.state.not_before[id] <= t + T_EPS
            {
                set_insert(&mut self.pending, id);
                events.push(Event::RestartEligible { job: id });
            }
        }
    }

    // ------------------------------------------------ completion path

    /// Finish every running job whose `remaining_iters <= eps`, firing a
    /// `Completion` event per job (ascending id). Shared by the engine
    /// (`eps = eps_iters`) and the coordinator (`eps = 0`). The id buffer
    /// is pooled on the context (taken out while `finish_job` mutates the
    /// sets, put back after), so the steady-state event loop allocates
    /// nothing here.
    pub fn collect_completions(&mut self, eps: f64, events: &mut Vec<Event>) {
        let mut done = std::mem::take(&mut self.completions_scratch);
        done.clear();
        done.extend(
            self.running
                .iter()
                .copied()
                .filter(|&id| self.state.jobs[id].remaining_iters <= eps),
        );
        for &id in &done {
            self.finish_job(id);
            events.push(Event::Completion { job: id });
        }
        self.completions_scratch = done;
    }

    /// Engine helper for floating-point finish-projection stalls.
    ///
    /// A projected completion can fire while integration leaves a
    /// residual just above the engine's `eps_iters` (at large `now` the
    /// round-off of `now + remaining·t_iter` undershoots by up to
    /// ~ulp(now)/2). The projection was pushed once and nothing bumps the
    /// job's rate epoch, so without intervention the next-event time is
    /// pinned at `now` forever. For every live heap entry not strictly in
    /// the future this either (a) re-pushes a fresh projection from the
    /// current residual when that lands strictly after `now` — the
    /// per-event recomputation the old rescan engine got for free — or
    /// (b) completes the job through the normal completion path when the
    /// residual's runtime is below f64 resolution at `now`, firing its
    /// `Completion` into `events`.
    pub fn resolve_finish_stall(&mut self, events: &mut Vec<Event>) {
        while let Some(t) = self.next_finish() {
            if t > self.state.now {
                break;
            }
            let Some(&std::cmp::Reverse((_, id, _))) = self.finish_heap.peek() else {
                break;
            };
            let rem_t = self.state.jobs[id].remaining_iters * self.cached_iter_time(id);
            if self.state.now + rem_t > self.state.now {
                self.reproject(id);
            } else {
                self.finish_job(id);
                events.push(Event::Completion { job: id });
            }
        }
    }

    fn finish_job(&mut self, id: JobId) {
        self.retire_running(id, "finish");
    }

    /// Shared teardown for a running job leaving the cluster for good —
    /// natural completion (`reason = "finish"`) or a daemon-side cancel
    /// (`reason = "cancel"`). Releases its GPUs, marks it `Finished`,
    /// and reprojects any co-runners now running faster.
    fn retire_running(&mut self, id: JobId, reason: &'static str) {
        let co = self.state.cluster.co_runners(id);
        self.state.cluster.release(id);
        let rec = &mut self.state.jobs[id];
        rec.remaining_iters = 0.0;
        rec.state = JobState::Finished;
        rec.finish_s = Some(self.state.now);
        rec.gpus_held.clear();
        set_remove(&mut self.running, id);
        self.finished += 1;
        self.rate_epoch[id] += 1;
        if self.obs.is_enabled() {
            self.obs.job_stopped(self.state.now, id, reason);
            for &c in &co {
                let still_shared = !self.state.cluster.co_runners(c).is_empty();
                self.obs.job_share_changed(self.state.now, c, still_shared);
            }
        }
        for c in co {
            self.reproject(c);
        }
    }

    // ------------------------------------------------ live ingestion

    /// Live ingestion (the serve daemon): append one more job to the
    /// world mid-run and index it as a future arrival. The spec's `id`
    /// must be the next dense [`JobId`] (`jobs.len()` before the call) —
    /// the daemon owns the external-id ↔ dense-id mapping. The job's
    /// `Arrival` event fires on the first `advance_*` call that reaches
    /// `spec.arrival_s`, exactly as for jobs present at construction.
    pub fn admit_job(&mut self, spec: JobSpec) -> JobId {
        let id = self.state.jobs.len();
        debug_assert_eq!(spec.id, id, "admitted specs carry the next dense id");
        debug_assert!(
            spec.arrival_s >= self.state.now - T_EPS,
            "admitted arrivals must not predate now"
        );
        let rec = JobRecord::new(spec);
        self.est_rate.push(est_rate_of(&rec));
        self.rate_epoch.push(0);
        self.iter_cache.push((u64::MAX, 0.0));
        self.state.not_before.push(0.0);
        self.state.service_gpu_s.push(0.0);
        self.state.jobs.push(rec);
        // `future_arrivals` is sorted by (arrival, id) descending and pops
        // from the back. The new id is the largest so far, so among equal
        // arrival times it belongs at the *front* of the run (pops last —
        // simultaneous arrivals keep firing in ascending id order).
        let arrival = self.state.jobs[id].spec.arrival_s;
        let pos = self.future_arrivals.partition_point(|&e| {
            self.state.jobs[e].spec.arrival_s.total_cmp(&arrival)
                == std::cmp::Ordering::Greater
        });
        self.future_arrivals.insert(pos, id);
        id
    }

    /// Live cancellation (the serve daemon): withdraw `id` from the
    /// system. A running job is torn down through the shared retire path
    /// (GPUs released, co-runners reprojected); a queued or not-yet-
    /// arrived job is simply removed from its queues. Either way the
    /// record ends `Finished` with `finish_s = now`. Returns `false`
    /// (and changes nothing) if the job is already finished.
    pub fn cancel_job(&mut self, id: JobId) -> bool {
        match self.state.jobs[id].state {
            JobState::Finished => false,
            JobState::Running => {
                self.retire_running(id, "cancel");
                true
            }
            JobState::Pending | JobState::Preempted => {
                set_remove(&mut self.pending, id);
                set_remove(&mut self.waiting, id);
                if let Some(pos) = self.future_arrivals.iter().position(|&e| e == id) {
                    self.future_arrivals.remove(pos);
                }
                // Any restart_heap entry is left in place: the pop path
                // skips entries whose job is no longer Pending/Preempted.
                let rec = &mut self.state.jobs[id];
                rec.state = JobState::Finished;
                rec.remaining_iters = 0.0;
                rec.finish_s = Some(self.state.now);
                self.finished += 1;
                self.rate_epoch[id] += 1;
                if self.obs.is_enabled() {
                    self.obs.job_stopped(self.state.now, id, "cancel");
                }
                true
            }
        }
    }

    /// Snapshot restore (the serve daemon's `--resume`): reinstate the
    /// utilization integrals that [`SchedContext::from_state`] cannot
    /// derive from the world state alone.
    pub fn restore_accounting(&mut self, busy_gpu_s: f64, shared_gpu_s: f64) {
        self.busy_gpu_s = busy_gpu_s;
        self.shared_gpu_s = shared_gpu_s;
    }

    /// Physical mode: record one really-executed iteration of `job`.
    /// Returns false (and changes nothing) if the job is not running or
    /// already done — late progress reports from a worker are dropped,
    /// exactly as before.
    pub fn note_progress(&mut self, job: JobId) -> bool {
        let Some(rec) = self.state.jobs.get_mut(job) else { return false };
        if rec.state == JobState::Running && rec.remaining_iters > 0.0 {
            rec.remaining_iters -= 1.0;
            true
        } else {
            false
        }
    }

    // ------------------------------------------------ cache plumbing

    /// Invalidate `id`'s finish projection (and its cached iteration
    /// time, via the epoch bump) and, if it is running, push a fresh
    /// projection at the current rate.
    pub(super) fn reproject(&mut self, id: JobId) {
        self.rate_epoch[id] += 1;
        if self.project_finishes && self.state.jobs[id].state == JobState::Running {
            let t = self.state.now
                + self.state.jobs[id].remaining_iters * self.cached_iter_time(id);
            self.finish_heap.push(Reverse((OrdF64(t), id, self.rate_epoch[id])));
        }
    }

    /// Debug check: the incremental caches must agree with a fresh scan
    /// of the state (used under `debug_assert!` after every apply).
    pub fn cache_integrity(&self) -> Result<(), String> {
        if self.pending != self.state.pending() {
            return Err(format!(
                "pending cache {:?} != scan {:?}",
                self.pending,
                self.state.pending()
            ));
        }
        if self.running != self.state.running() {
            return Err(format!(
                "running cache {:?} != scan {:?}",
                self.running,
                self.state.running()
            ));
        }
        let waiting: Vec<JobId> = self
            .state
            .jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| {
                matches!(j.state, JobState::Pending | JobState::Preempted)
                    && j.spec.arrival_s <= self.state.now + T_EPS
            })
            .map(|(id, _)| id)
            .collect();
        if self.waiting != waiting {
            return Err(format!(
                "waiting cache {:?} != scan {waiting:?}",
                self.waiting
            ));
        }
        let finished =
            self.state.jobs.iter().filter(|j| j.state == JobState::Finished).count();
        if finished != self.finished {
            return Err(format!("finished {} != scan {finished}", self.finished));
        }
        for (id, rec) in self.state.jobs.iter().enumerate() {
            let fresh = est_rate_of(rec);
            if self.est_rate[id].to_bits() != fresh.to_bits() {
                return Err(format!(
                    "est_rate cache for job {id} is {} but recomputes to {fresh}",
                    self.est_rate[id]
                ));
            }
        }
        Ok(())
    }
}

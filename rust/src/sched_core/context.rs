//! [`SchedContext`]: the world view shared by both scheduling backends.
//!
//! Owns the [`SimState`] plus incrementally-maintained index caches so
//! that (a) policies read the pending/running sets as slices instead of
//! re-allocating `Vec`s per call, and (b) the engine selects its next
//! event from calendar queues in O(1) amortized instead of rescanning
//! every running job per event. All mutation goes through the methods
//! here and through [`SchedContext::apply`](super::txn) — the caches can
//! never drift from the state they index.
//!
//! Since the million-job event core rework (DESIGN.md §15) the per-job
//! progress quantities are **lazily integrated**: `advance` no longer
//! sweeps the running/waiting sets, it only moves the clock and fires due
//! events. `remaining_iters`, `service_gpu_s` and `queued_s` are settled
//! on rate transitions via the [`ProgressLedger`] anchors and read
//! through the closed-form accessors ([`SchedContext::remaining_iters`],
//! [`SchedContext::attained_service`], [`SchedContext::queued_seconds`]).
//! Reading the raw `SimState` fields of a *running* (or waiting) job
//! through `Deref` yields the value at its last settle, not at `now` —
//! in-tree consumers go through the accessors.

use std::ops::Deref;

use crate::cluster::overlay::OverlayPool;
use crate::cluster::{Cluster, ClusterOverlay};
use crate::jobs::{JobId, JobRecord, JobSpec, JobState};
use crate::obskit::Obs;
use crate::perf::interference::InterferenceModel;
use crate::sim::SimState;

use super::calendar::CalendarQueue;
use super::ledger::{EagerReference, ProgressLedger};
use super::order::{key_bits, PendingOrder};
use super::Event;

/// Eligibility slack shared with the legacy `SimState` scans: a time `t`
/// counts as reached once `now + EPS >= t`.
pub(super) const T_EPS: f64 = 1e-9;

/// Insert into a sorted id set (no-op if present).
pub(super) fn set_insert(v: &mut Vec<JobId>, id: JobId) {
    if let Err(i) = v.binary_search(&id) {
        v.insert(i, id);
    }
}

/// Remove from a sorted id set (no-op if absent).
pub(super) fn set_remove(v: &mut Vec<JobId>, id: JobId) {
    if let Ok(i) = v.binary_search(&id) {
        v.remove(i);
    }
}

/// Estimated solo seconds per iteration of a job at its current
/// accumulation step — the cached factor of
/// [`SchedContext::estimated_remaining`]. Bit-identical to the plain
/// iteration time under the oracle (`est_factor == 1.0`).
pub(super) fn est_rate_of(rec: &JobRecord) -> f64 {
    rec.spec.estimated_iter_time(rec.accum_step)
}

/// Sort an arrival queue by (arrival, id) descending, so the next arrival
/// pops from the back and simultaneous arrivals pop in ascending id order.
fn sort_arrivals_desc(state: &SimState, ids: &mut [JobId]) {
    ids.sort_by(|&a, &b| {
        let (aa, ab) = (state.jobs[a].spec.arrival_s, state.jobs[b].spec.arrival_s);
        ab.total_cmp(&aa).then(b.cmp(&a))
    });
}

/// Float agreement for the eager reference sweep: lazy settling and the
/// eager per-event loops differ only in summation order, so they agree to
/// accumulated round-off, not bitwise.
fn close(lazy: f64, eager: f64) -> bool {
    (lazy - eager).abs() <= 1e-6 + 1e-9 * eager.abs()
}

/// The read view handed to policies and the single mutation path shared
/// by the simulator engine and the physical coordinator.
///
/// Derefs to [`SimState`] for read access to jobs, cluster, interference
/// model and `not_before`; the state itself is private so every
/// transition flows through the validated methods below. For the lazily
/// integrated quantities of live jobs, use the accessors
/// ([`SchedContext::remaining_iters`] & friends), not the raw fields.
#[derive(Debug, Clone)]
pub struct SchedContext {
    pub(super) state: SimState,
    /// Eligible pending set: arrived, `Pending`/`Preempted`, past any
    /// restart penalty. Sorted ascending by id.
    pub(super) pending: Vec<JobId>,
    /// Ordered views of `pending` — by `(estimated_remaining, id)` and by
    /// `(arrival_s, id)` — maintained at the same membership sites
    /// ([`SchedContext::pending_insert`]/[`SchedContext::pending_remove`])
    /// so policy passes iterate candidates without re-sorting the
    /// backlog. See [`super::order`] for the key-stability argument.
    pub(super) order: PendingOrder,
    /// Running set, sorted ascending by id.
    pub(super) running: Vec<JobId>,
    /// Waiting set (queue-time accrual): arrived and `Pending`/
    /// `Preempted`, *including* jobs still under a restart penalty.
    pub(super) waiting: Vec<JobId>,
    /// Jobs not yet arrived, sorted by (arrival, id) descending so the
    /// next arrival pops from the back.
    pub(super) future_arrivals: Vec<JobId>,
    /// Calendar queue of `(not_before, job)` restart-penalty expiries.
    pub(super) restart_q: CalendarQueue<JobId>,
    /// Calendar queue of `(projected finish, (job, epoch))`; entries whose
    /// epoch is stale (the job's rate changed since) are skipped.
    pub(super) finish_q: CalendarQueue<(JobId, u64)>,
    /// The lazy-integration anchors + per-job rate caches (SoA hot
    /// fields; see the module docs of [`super::ledger`]).
    pub(super) ledger: ProgressLedger,
    /// Count of `Finished` jobs (O(1) `all_finished`).
    pub(super) finished: usize,
    /// Whether the simulated clock is driving progress: finish
    /// projections are maintained and `remaining_iters` integrates at the
    /// Eq. 7 × ξ rates. True until the first `advance_wall` call —
    /// projections are simulated-time quantities, meaningless against
    /// the wall clock, where real execution reports progress via
    /// [`SchedContext::note_progress`].
    pub(super) project_finishes: bool,
    /// Scratch-buffer pool for [`SchedContext::overlay`] planning views.
    overlay_pool: OverlayPool,
    /// Pooled id buffer for [`SchedContext::collect_completions`] — with
    /// the overlay pool and the engine's reused event vecs, this was the
    /// event loop's last steady-state per-event allocation.
    completions_scratch: Vec<JobId>,
    /// Observability handle (disabled by default — a single `None`
    /// branch per tap; see [`SchedContext::set_obs`]). Recording is
    /// strictly one-way: it never mutates sim state, RNG, or ordering.
    pub(super) obs: Obs,
    /// GPU-seconds with ≥ 1 resident job, integrated in `advance` (two
    /// O(1) occupancy reads per step, so it is always on) — drives the
    /// utilization columns in campaign CSV v3 and the obskit sampler.
    busy_gpu_s: f64,
    /// GPU-seconds with ≥ 2 resident jobs (co-located intervals).
    shared_gpu_s: f64,
    /// When armed ([`SchedContext::verify_against_eager_reference`]),
    /// every `advance` replays the pre-ledger eager sweeps over shadow
    /// vectors and asserts the lazy closed forms agree. Verification
    /// only — `None` on every production path.
    eager_ref: Option<Box<EagerReference>>,
}

impl Deref for SchedContext {
    type Target = SimState;

    fn deref(&self) -> &SimState {
        &self.state
    }
}

impl SchedContext {
    /// Fresh context at `now = 0` over unstarted (all-`Pending`) job
    /// records. Every job — including those arriving at `t = 0` — is a
    /// *future* arrival: its `Arrival` event fires on the first
    /// `advance_*` call that reaches its arrival time, so backends see
    /// one event per job, always.
    pub fn new(cluster: Cluster, jobs: Vec<JobRecord>, xi: InterferenceModel) -> Self {
        debug_assert!(jobs.iter().all(|j| j.state == JobState::Pending));
        let n = jobs.len();
        let state = SimState {
            now: 0.0,
            cluster,
            jobs,
            xi,
            not_before: vec![0.0; n],
            service_gpu_s: vec![0.0; n],
        };
        let mut future_arrivals: Vec<JobId> = (0..n).collect();
        sort_arrivals_desc(&state, &mut future_arrivals);
        let ledger = ProgressLedger::new(&state.jobs, 0.0);
        SchedContext {
            state,
            pending: Vec::new(),
            order: PendingOrder::with_jobs(n),
            running: Vec::new(),
            waiting: Vec::new(),
            future_arrivals,
            restart_q: CalendarQueue::new(),
            finish_q: CalendarQueue::new(),
            ledger,
            finished: 0,
            project_finishes: true,
            overlay_pool: OverlayPool::default(),
            completions_scratch: Vec::new(),
            obs: Obs::disabled(),
            busy_gpu_s: 0.0,
            shared_gpu_s: 0.0,
            eager_ref: None,
        }
    }

    /// Build a context over an arbitrary world snapshot (tests, benches,
    /// synthetic mid-simulation states), rebuilding every cache. Unlike
    /// [`SchedContext::new`], jobs whose arrival time has already passed
    /// are indexed as pending/waiting immediately — no `Arrival` events
    /// fire for them. The stored per-job quantities are taken as settled
    /// at `state.now` (anchors start here).
    pub fn from_state(state: SimState) -> Self {
        let n = state.jobs.len();
        let now = state.now;
        let ledger = ProgressLedger::new(&state.jobs, now);
        let mut ctx = SchedContext {
            state,
            pending: Vec::new(),
            order: PendingOrder::with_jobs(n),
            running: Vec::new(),
            waiting: Vec::new(),
            future_arrivals: Vec::new(),
            restart_q: CalendarQueue::new(),
            finish_q: CalendarQueue::new(),
            ledger,
            finished: 0,
            project_finishes: true,
            overlay_pool: OverlayPool::default(),
            completions_scratch: Vec::new(),
            obs: Obs::disabled(),
            busy_gpu_s: 0.0,
            shared_gpu_s: 0.0,
            eager_ref: None,
        };
        for id in 0..n {
            match ctx.state.jobs[id].state {
                JobState::Running => ctx.running.push(id),
                JobState::Finished => ctx.finished += 1,
                JobState::Pending | JobState::Preempted => {
                    if ctx.state.jobs[id].spec.arrival_s <= now + T_EPS {
                        ctx.waiting.push(id);
                        ctx.ledger.wait_since[id] = now;
                        if ctx.state.not_before[id] <= now + T_EPS {
                            ctx.pending_insert(id);
                        } else {
                            ctx.restart_q.push(ctx.state.not_before[id], id);
                        }
                    } else {
                        ctx.future_arrivals.push(id);
                    }
                }
            }
        }
        let mut future = std::mem::take(&mut ctx.future_arrivals);
        sort_arrivals_desc(&ctx.state, &mut future);
        ctx.future_arrivals = future;
        let running = ctx.running.clone();
        for id in running {
            ctx.reproject(id);
        }
        ctx
    }

    /// Consume the context, returning the final world state with every
    /// lazily-integrated quantity settled at `now`.
    pub fn into_state(mut self) -> SimState {
        self.settle_all();
        self.state
    }

    /// Attach an observability handle (disabled by default). Clones share
    /// the handle's sinks with the caller; recording is one-way and never
    /// affects scheduling, integration, or event ordering.
    pub fn set_obs(&mut self, obs: Obs) {
        self.obs = obs;
    }

    pub fn obs(&self) -> &Obs {
        &self.obs
    }

    /// GPU-seconds with at least one resident job, integrated by the
    /// `advance_*` path over the whole run so far.
    pub fn busy_gpu_s(&self) -> f64 {
        self.busy_gpu_s
    }

    /// GPU-seconds with at least two resident jobs (shared intervals).
    pub fn shared_gpu_s(&self) -> f64 {
        self.shared_gpu_s
    }

    pub fn state(&self) -> &SimState {
        &self.state
    }

    pub fn now(&self) -> f64 {
        self.state.now
    }

    /// Jobs currently eligible for scheduling (arrived, not running, past
    /// their restart penalty), ascending by id. Maintained incrementally —
    /// no allocation, no scan.
    pub fn pending(&self) -> &[JobId] {
        &self.pending
    }

    /// Pending ids ascending by `(estimated_remaining, id)` — the shared
    /// SJF-family candidate order, read from the incrementally maintained
    /// [`PendingOrder`] instead of a per-pass re-sort. Identical (to the
    /// element) to sorting [`SchedContext::pending`] by
    /// `estimated_remaining(a).total_cmp(..).then(a.cmp(&b))`.
    pub fn pending_by_estimate(&self) -> impl Iterator<Item = JobId> + '_ {
        self.order.iter_by_estimate()
    }

    /// Pending ids ascending by `(arrival_s, id)` — FIFO's head-of-line
    /// order and the Tiresias within-queue order, maintained
    /// incrementally.
    pub fn pending_by_arrival(&self) -> impl Iterator<Item = JobId> + '_ {
        self.order.iter_by_arrival()
    }

    /// Insert `id` into the eligible pending set and both of its ordered
    /// views. Idempotent, like the sorted-set helper it wraps. The
    /// estimate key is captured here; it is bit-stable for as long as the
    /// job stays pending (see [`super::order`]).
    pub(super) fn pending_insert(&mut self, id: JobId) {
        set_insert(&mut self.pending, id);
        let est = self.estimated_remaining(id);
        self.order.insert(id, est, self.state.jobs[id].spec.arrival_s);
    }

    /// Remove `id` from the eligible pending set and its ordered views.
    /// Idempotent. Uses the stored insertion key, so it is safe to call
    /// after `est_rate` has already been refreshed for a start.
    pub(super) fn pending_remove(&mut self, id: JobId) {
        set_remove(&mut self.pending, id);
        self.order.remove(id, self.state.jobs[id].spec.arrival_s);
    }

    /// Running jobs, ascending by id. Maintained incrementally.
    pub fn running(&self) -> &[JobId] {
        &self.running
    }

    /// Arrived jobs accruing queueing delay (eligible or penalty-held).
    pub fn waiting(&self) -> &[JobId] {
        &self.waiting
    }

    /// Borrow a hypothetical-allocation planning view over the cluster.
    ///
    /// This is what a full-pass policy uses instead of
    /// `ctx.cluster.clone()`: reads fall through to the live occupancy,
    /// tentative `allocate`/`release` calls are recorded as deltas, and
    /// the scratch buffers are pooled on the context so steady-state
    /// acquisition allocates nothing (`plan-view/*` in
    /// `cargo bench --bench sched_overhead`).
    pub fn overlay(&self) -> ClusterOverlay<'_> {
        self.overlay_pool.acquire(&self.state.cluster)
    }

    /// Placement-resolved effective iteration time of a *running* job
    /// ([`SimState::effective_iter_time`]), memoized per rate epoch: the
    /// O(cluster) co-runner/span derivation runs once per rate change
    /// (start, preempt, finish, co-runner change) instead of once per
    /// event.
    pub fn cached_iter_time(&mut self, id: JobId) -> f64 {
        let epoch = self.ledger.epoch[id];
        let (cached_epoch, cached) = self.ledger.iter_cache[id];
        if cached_epoch == epoch {
            return cached;
        }
        let t = self.state.effective_iter_time(id);
        self.ledger.iter_cache[id] = (epoch, t);
        t
    }

    // ------------------------------------------- lazy-quantity accessors

    /// `id`'s true remaining iterations at `now`.
    ///
    /// Closed-form lazy read: the stored `remaining_iters` is the value at
    /// the job's last settle; a running (sim-mode) job extrapolates down
    /// its current rate from there. For every non-integrating job the
    /// sentinel rate (∞) makes this bit-identical to the stored field —
    /// the SJF-family sort over *pending* jobs reads exactly what the
    /// eager core read.
    pub fn remaining_iters(&self, id: JobId) -> f64 {
        let dt = self.state.now - self.ledger.anchor_s[id];
        (self.state.jobs[id].remaining_iters - dt / self.ledger.iter_s[id]).max(0.0)
    }

    /// `id`'s true attained GPU service (GPU-seconds) at `now` — the
    /// Tiresias queue-demotion key. Lazy over the settle anchor; exact
    /// passthrough for jobs holding no GPUs.
    pub fn attained_service(&self, id: JobId) -> f64 {
        let dt = self.state.now - self.ledger.anchor_s[id];
        self.state.service_gpu_s[id] + self.state.jobs[id].gpus_held.len() as f64 * dt
    }

    /// `id`'s true accrued queueing delay (seconds) at `now`. Lazy over
    /// the waiting-entry instant; exact passthrough when not waiting.
    pub fn queued_seconds(&self, id: JobId) -> f64 {
        let since = self.ledger.wait_since[id];
        let base = self.state.jobs[id].queued_s;
        if since.is_finite() { base + (self.state.now - since) } else { base }
    }

    /// The scheduler's *belief* about `id`'s remaining solo runtime:
    /// `iter_time(accum) × est_factor × remaining_iters` — the
    /// SJF-family priority key under the duration-estimator layer.
    /// Under the oracle (`est_factor == 1.0`) this is bit-identical to
    /// [`JobRecord::remaining_solo_runtime`]; under `Noisy`/`Percentile`
    /// estimators it is what the policies mis-rank on while the engine
    /// keeps completing jobs on their true iteration counts.
    ///
    /// O(1): the per-iteration factor is cached on the context and only
    /// changes when a `Start` sets a new accumulation step.
    pub fn estimated_remaining(&self, id: JobId) -> f64 {
        self.ledger.est_rate[id] * self.remaining_iters(id)
    }

    pub fn all_finished(&self) -> bool {
        self.finished == self.state.jobs.len()
    }

    pub fn unfinished(&self) -> usize {
        self.state.jobs.len() - self.finished
    }

    // ------------------------------------------------- settle machinery

    /// Fold `id`'s lazily-integrated progress and service into the stored
    /// fields and move its anchor to `now`. Exact no-op (bitwise) for
    /// jobs that are not integrating and hold no GPUs — see the sentinel
    /// table in [`super::ledger`]. Must run *before* any transition that
    /// changes the job's rate or gang (the old values parameterize the
    /// interval being folded).
    pub(super) fn settle_job(&mut self, id: JobId) {
        let dt = self.state.now - self.ledger.anchor_s[id];
        let rec = &mut self.state.jobs[id];
        rec.remaining_iters = (rec.remaining_iters - dt / self.ledger.iter_s[id]).max(0.0);
        self.state.service_gpu_s[id] += rec.gpus_held.len() as f64 * dt;
        self.ledger.anchor_s[id] = self.state.now;
    }

    /// Fold `id`'s accrued queueing delay and stop the accrual (the job
    /// is leaving the waiting set: start or cancel).
    pub(super) fn settle_wait(&mut self, id: JobId) {
        let since = self.ledger.wait_since[id];
        if since.is_finite() {
            self.state.jobs[id].queued_s += self.state.now - since;
            self.ledger.wait_since[id] = f64::NAN;
        }
    }

    /// Settle every job at `now` (progress, service, and queueing — jobs
    /// still waiting keep accruing from a refreshed anchor). Used when
    /// the raw `SimState` must be externally consistent: `into_state` and
    /// the sim→wall mode switch.
    pub(super) fn settle_all(&mut self) {
        for id in 0..self.state.jobs.len() {
            self.settle_job(id);
            let since = self.ledger.wait_since[id];
            if since.is_finite() {
                self.state.jobs[id].queued_s += self.state.now - since;
                self.ledger.wait_since[id] = self.state.now;
            }
        }
    }

    // ---------------------------------------------- next-event queries

    /// Earliest future arrival, if any.
    pub fn next_arrival(&self) -> Option<f64> {
        self.future_arrivals.last().map(|&id| self.state.jobs[id].spec.arrival_s)
    }

    /// Earliest restart-penalty expiry among preempted jobs, if any.
    pub fn next_restart(&mut self) -> Option<f64> {
        self.restart_q.peek().map(|(t, _)| t)
    }

    /// Earliest projected completion among running jobs, if any.
    ///
    /// O(1) amortized: the calendar queue holds one live entry per
    /// running job (re-pushed whenever a rate changes); stale entries are
    /// popped here. Simulated-clock backends only — after the first
    /// `advance_wall` call projections are no longer maintained and this
    /// returns `None` (wall-mode completions come from real execution
    /// progress).
    pub fn next_finish(&mut self) -> Option<f64> {
        while let Some((t, (id, epoch))) = self.finish_q.peek() {
            if epoch == self.ledger.epoch[id] {
                return Some(t);
            }
            let _ = self.finish_q.pop();
        }
        None
    }

    // ------------------------------------------------ time advancement

    /// Simulator clock: advance to `t` and fire `Arrival`/
    /// `RestartEligible` events due by `t` into `events`. Job progress at
    /// the piecewise-constant Eq. 7 × ξ rates, `service_gpu_s` and
    /// `queued_s` all integrate lazily — no per-job work happens here.
    pub fn advance_sim(&mut self, t: f64, events: &mut Vec<Event>) {
        self.advance(t, events);
    }

    /// Wall clock (physical coordinator): advance to `t`, firing due
    /// events. `remaining_iters` does *not* integrate in wall mode —
    /// real execution drives it through [`SchedContext::note_progress`];
    /// service and queueing accrue lazily exactly as in sim mode.
    pub fn advance_wall(&mut self, t: f64, events: &mut Vec<Event>) {
        if self.project_finishes {
            // First wall jump: fold any simulated-rate progress accrued
            // so far, then stop integrating and drop the projections —
            // they are simulated-time quantities the coordinator never
            // consults.
            self.settle_all();
            self.project_finishes = false;
            for r in self.ledger.iter_s.iter_mut() {
                *r = f64::INFINITY;
            }
            self.finish_q.clear();
            self.eager_ref = None;
        }
        self.advance(t, events);
    }

    fn advance(&mut self, t: f64, events: &mut Vec<Event>) {
        let dt = t - self.state.now;
        if dt > 0.0 {
            // Occupancy is piecewise-constant between events, so the
            // utilization integrals are two O(1) multiplies per step.
            let total = self.state.cluster.total_gpus();
            let busy = total - self.state.cluster.free_count();
            let shared = busy - self.state.cluster.one_job_count();
            self.busy_gpu_s += busy as f64 * dt;
            self.shared_gpu_s += shared as f64 * dt;
        }
        if self.eager_ref.is_some() {
            self.eager_reference_step(dt);
        }
        self.state.now = t;

        while let Some(&id) = self.future_arrivals.last() {
            if self.state.jobs[id].spec.arrival_s > t + T_EPS {
                break;
            }
            self.future_arrivals.pop();
            set_insert(&mut self.waiting, id);
            self.pending_insert(id);
            // Queue-time accrual starts at the event instant, exactly as
            // the eager per-advance loop did.
            self.ledger.wait_since[id] = t;
            events.push(Event::Arrival { job: id });
        }
        while let Some((nb, id)) = self.restart_q.peek() {
            if nb > t + T_EPS {
                break;
            }
            self.restart_q.pop();
            // Guards: the job may have restarted meanwhile (zero-penalty
            // preempt + same-transaction start), or this entry may be
            // stale because a newer preemption pushed a later expiry.
            if matches!(self.state.jobs[id].state, JobState::Pending | JobState::Preempted)
                && self.state.not_before[id] <= t + T_EPS
            {
                self.pending_insert(id);
                events.push(Event::RestartEligible { job: id });
            }
        }
        if self.eager_ref.is_some() {
            self.eager_reference_verify();
        }
    }

    // ------------------------------------------------ completion path

    /// Finish every running job due to complete by `now`, firing a
    /// `Completion` event per job in **ascending id order** (pinned by an
    /// explicit sort — under the calendar queue the drain surfaces jobs
    /// in projected-finish order, not id order). Shared by the engine
    /// (`eps = eps_iters`) and the coordinator (`eps = 0`). The id buffer
    /// is pooled on the context, so the steady-state event loop allocates
    /// nothing here.
    ///
    /// Sim mode drains due finish projections: each due job settles, and
    /// either completes (residual ≤ eps) or — when round-off left the
    /// residual above eps — re-projects from the settled residual, the
    /// per-event refresh the old rescan engine got for free. A residual
    /// whose runtime is below f64 resolution at `now` completes rather
    /// than stall the clock. Wall mode keeps the O(running) scan:
    /// progress arrives from real execution, there are no projections.
    pub fn collect_completions(&mut self, eps: f64, events: &mut Vec<Event>) {
        let mut done = std::mem::take(&mut self.completions_scratch);
        done.clear();
        if self.project_finishes {
            let now = self.state.now;
            loop {
                let Some((t, (id, epoch))) = self.finish_q.peek() else { break };
                if t > now + T_EPS {
                    break;
                }
                self.finish_q.pop();
                if epoch != self.ledger.epoch[id] {
                    continue; // stale projection: the rate changed since
                }
                debug_assert_eq!(self.state.jobs[id].state, JobState::Running);
                self.settle_job(id);
                let rem = self.state.jobs[id].remaining_iters;
                if rem <= eps {
                    done.push(id);
                    continue;
                }
                let t2 = now + rem * self.ledger.iter_s[id];
                if t2 > now {
                    // Same epoch: the entry just consumed was the only
                    // live one, this refresh replaces it.
                    self.finish_q.push(t2, (id, epoch));
                } else {
                    done.push(id); // below clock resolution at `now`
                }
            }
        } else {
            done.extend(
                self.running
                    .iter()
                    .copied()
                    .filter(|&id| self.state.jobs[id].remaining_iters <= eps),
            );
        }
        done.sort_unstable();
        for &id in &done {
            self.finish_job(id);
            events.push(Event::Completion { job: id });
        }
        self.completions_scratch = done;
    }

    fn finish_job(&mut self, id: JobId) {
        self.retire_running(id, "finish");
    }

    /// Shared teardown for a running job leaving the cluster for good —
    /// natural completion (`reason = "finish"`) or a daemon-side cancel
    /// (`reason = "cancel"`). Settles, releases its GPUs, marks it
    /// `Finished`, and reprojects any co-runners now running faster.
    fn retire_running(&mut self, id: JobId, reason: &'static str) {
        self.settle_job(id);
        let co = self.state.cluster.co_runners(id);
        self.state.cluster.release(id);
        let rec = &mut self.state.jobs[id];
        rec.remaining_iters = 0.0;
        rec.state = JobState::Finished;
        rec.finish_s = Some(self.state.now);
        rec.gpus_held.clear();
        set_remove(&mut self.running, id);
        self.finished += 1;
        self.ledger.epoch[id] += 1;
        self.ledger.iter_s[id] = f64::INFINITY;
        if self.obs.is_enabled() {
            self.obs.job_stopped(self.state.now, id, reason);
            for &c in &co {
                let still_shared = !self.state.cluster.co_runners(c).is_empty();
                self.obs.job_share_changed(self.state.now, c, still_shared);
            }
        }
        for c in co {
            self.reproject(c);
        }
    }

    // ------------------------------------------------ live ingestion

    /// Live ingestion (the serve daemon): append one more job to the
    /// world mid-run and index it as a future arrival. The spec's `id`
    /// must be the next dense [`JobId`] (`jobs.len()` before the call) —
    /// the daemon owns the external-id ↔ dense-id mapping. The job's
    /// `Arrival` event fires on the first `advance_*` call that reaches
    /// `spec.arrival_s`, exactly as for jobs present at construction.
    pub fn admit_job(&mut self, spec: JobSpec) -> JobId {
        let id = self.state.jobs.len();
        debug_assert_eq!(spec.id, id, "admitted specs carry the next dense id");
        debug_assert!(
            spec.arrival_s >= self.state.now - T_EPS,
            "admitted arrivals must not predate now"
        );
        let rec = JobRecord::new(spec);
        self.ledger.push_job(&rec, self.state.now);
        self.order.grow();
        if let Some(r) = self.eager_ref.as_mut() {
            r.remaining.push(rec.remaining_iters);
            r.service.push(0.0);
            r.queued.push(0.0);
        }
        self.state.not_before.push(0.0);
        self.state.service_gpu_s.push(0.0);
        self.state.jobs.push(rec);
        // `future_arrivals` is sorted by (arrival, id) descending and pops
        // from the back. The new id is the largest so far, so among equal
        // arrival times it belongs at the *front* of the run (pops last —
        // simultaneous arrivals keep firing in ascending id order).
        let arrival = self.state.jobs[id].spec.arrival_s;
        let pos = self.future_arrivals.partition_point(|&e| {
            self.state.jobs[e].spec.arrival_s.total_cmp(&arrival)
                == std::cmp::Ordering::Greater
        });
        self.future_arrivals.insert(pos, id);
        id
    }

    /// Live cancellation (the serve daemon): withdraw `id` from the
    /// system. A running job is torn down through the shared retire path
    /// (GPUs released, co-runners reprojected); a queued or not-yet-
    /// arrived job is simply removed from its queues. Either way the
    /// record ends `Finished` with `finish_s = now`. Returns `false`
    /// (and changes nothing) if the job is already finished.
    pub fn cancel_job(&mut self, id: JobId) -> bool {
        match self.state.jobs[id].state {
            JobState::Finished => false,
            JobState::Running => {
                self.retire_running(id, "cancel");
                true
            }
            JobState::Pending | JobState::Preempted => {
                self.settle_wait(id);
                self.pending_remove(id);
                set_remove(&mut self.waiting, id);
                if let Some(pos) = self.future_arrivals.iter().position(|&e| e == id) {
                    self.future_arrivals.remove(pos);
                }
                // Any restart_q entry is left in place: the pop path
                // skips entries whose job is no longer Pending/Preempted.
                let rec = &mut self.state.jobs[id];
                rec.state = JobState::Finished;
                rec.remaining_iters = 0.0;
                rec.finish_s = Some(self.state.now);
                self.finished += 1;
                self.ledger.epoch[id] += 1;
                if self.obs.is_enabled() {
                    self.obs.job_stopped(self.state.now, id, "cancel");
                }
                true
            }
        }
    }

    /// Snapshot restore (the serve daemon's `--resume`): reinstate the
    /// utilization integrals that [`SchedContext::from_state`] cannot
    /// derive from the world state alone.
    pub fn restore_accounting(&mut self, busy_gpu_s: f64, shared_gpu_s: f64) {
        self.busy_gpu_s = busy_gpu_s;
        self.shared_gpu_s = shared_gpu_s;
    }

    /// Physical mode: record one really-executed iteration of `job`.
    /// Returns false (and changes nothing) if the job is not running or
    /// already done — late progress reports from a worker are dropped,
    /// exactly as before. (Wall mode never integrates `remaining_iters`,
    /// so the stored field is live here — no settle needed.)
    pub fn note_progress(&mut self, job: JobId) -> bool {
        let Some(rec) = self.state.jobs.get_mut(job) else { return false };
        if rec.state == JobState::Running && rec.remaining_iters > 0.0 {
            rec.remaining_iters -= 1.0;
            true
        } else {
            false
        }
    }

    // ------------------------------------------------ cache plumbing

    /// Settle `id` at its outgoing rate, invalidate its finish projection
    /// (and its cached iteration time, via the epoch bump) and, if it is
    /// running under the simulated clock, record the incoming integration
    /// rate and push a fresh projection.
    pub(super) fn reproject(&mut self, id: JobId) {
        self.settle_job(id);
        self.ledger.epoch[id] += 1;
        if self.project_finishes && self.state.jobs[id].state == JobState::Running {
            let it = self.cached_iter_time(id);
            self.ledger.iter_s[id] = it;
            let t = self.state.now + self.state.jobs[id].remaining_iters * it;
            self.finish_q.push(t, (id, self.ledger.epoch[id]));
        } else {
            self.ledger.iter_s[id] = f64::INFINITY;
        }
    }

    // --------------------------------------- eager reference (verify)

    /// Arm the eager reference sweep: from here on, every `advance`
    /// replays the pre-ledger O(running)+O(waiting) per-event integration
    /// loops over shadow vectors and panics if the lazy closed forms
    /// disagree beyond accumulated round-off. Verification harness for
    /// tests (`tests/event_core.rs` drives the six-policy golden traces
    /// under it) — never enabled on production paths, and dropped on the
    /// switch to wall mode (the sweep checks simulated integration).
    pub fn verify_against_eager_reference(&mut self) {
        let n = self.state.jobs.len();
        self.eager_ref = Some(Box::new(EagerReference {
            remaining: (0..n).map(|id| self.remaining_iters(id)).collect(),
            service: (0..n).map(|id| self.attained_service(id)).collect(),
            queued: (0..n).map(|id| self.queued_seconds(id)).collect(),
        }));
    }

    /// The old eager sweep, verbatim, over the shadow vectors.
    fn eager_reference_step(&mut self, dt: f64) {
        let Some(mut r) = self.eager_ref.take() else { return };
        if dt > 0.0 {
            let running = std::mem::take(&mut self.running);
            for &id in &running {
                let it = self.cached_iter_time(id);
                r.remaining[id] = (r.remaining[id] - dt / it).max(0.0);
                let held = self.state.jobs[id].gpus_held.len() as f64;
                r.service[id] += held * dt;
            }
            self.running = running;
            for &id in &self.waiting {
                r.queued[id] += dt;
            }
        }
        self.eager_ref = Some(r);
    }

    fn eager_reference_verify(&mut self) {
        let Some(r) = self.eager_ref.take() else { return };
        for &id in &self.running {
            let lazy = self.remaining_iters(id);
            assert!(
                close(lazy, r.remaining[id]),
                "lazy remaining_iters({id}) = {lazy} diverged from eager sweep {} at t = {}",
                r.remaining[id],
                self.state.now
            );
            let lazy = self.attained_service(id);
            assert!(
                close(lazy, r.service[id]),
                "lazy attained_service({id}) = {lazy} diverged from eager sweep {} at t = {}",
                r.service[id],
                self.state.now
            );
        }
        for &id in &self.waiting {
            let lazy = self.queued_seconds(id);
            assert!(
                close(lazy, r.queued[id]),
                "lazy queued_seconds({id}) = {lazy} diverged from eager sweep {} at t = {}",
                r.queued[id],
                self.state.now
            );
        }
        self.eager_ref = Some(r);
    }

    /// Debug check: the incremental caches must agree with a fresh scan
    /// of the state (used under `debug_assert!` after every apply).
    pub fn cache_integrity(&self) -> Result<(), String> {
        if self.pending != self.state.pending() {
            return Err(format!(
                "pending cache {:?} != scan {:?}",
                self.pending,
                self.state.pending()
            ));
        }
        // The pending order must equal a full re-sort of the pending set
        // on freshly computed keys — the eager derivation the index
        // replaced — and every stored estimate key must still match a
        // recomputation (the frozen-while-pending argument, enforced).
        let mut by_est = self.pending.clone();
        by_est.sort_by(|&a, &b| {
            self.estimated_remaining(a)
                .total_cmp(&self.estimated_remaining(b))
                .then(a.cmp(&b))
        });
        let got: Vec<JobId> = self.order.iter_by_estimate().collect();
        if got != by_est {
            return Err(format!(
                "pending order (by estimate) {got:?} != re-sort {by_est:?}"
            ));
        }
        let mut by_arr = self.pending.clone();
        by_arr.sort_by(|&a, &b| {
            self.state.jobs[a]
                .spec
                .arrival_s
                .total_cmp(&self.state.jobs[b].spec.arrival_s)
                .then(a.cmp(&b))
        });
        let got: Vec<JobId> = self.order.iter_by_arrival().collect();
        if got != by_arr {
            return Err(format!(
                "pending order (by arrival) {got:?} != re-sort {by_arr:?}"
            ));
        }
        for &id in &self.pending {
            let fresh = key_bits(self.estimated_remaining(id));
            if self.order.est_key(id) != fresh {
                return Err(format!(
                    "pending order key for job {id} drifted: stored {:#x}, \
                     recomputes to {fresh:#x}",
                    self.order.est_key(id)
                ));
            }
        }
        if self.running != self.state.running() {
            return Err(format!(
                "running cache {:?} != scan {:?}",
                self.running,
                self.state.running()
            ));
        }
        let waiting: Vec<JobId> = self
            .state
            .jobs
            .iter()
            .enumerate()
            .filter(|(_, j)| {
                matches!(j.state, JobState::Pending | JobState::Preempted)
                    && j.spec.arrival_s <= self.state.now + T_EPS
            })
            .map(|(id, _)| id)
            .collect();
        if self.waiting != waiting {
            return Err(format!(
                "waiting cache {:?} != scan {waiting:?}",
                self.waiting
            ));
        }
        let finished =
            self.state.jobs.iter().filter(|j| j.state == JobState::Finished).count();
        if finished != self.finished {
            return Err(format!("finished {} != scan {finished}", self.finished));
        }
        for (id, rec) in self.state.jobs.iter().enumerate() {
            let fresh = est_rate_of(rec);
            if self.ledger.est_rate[id].to_bits() != fresh.to_bits() {
                return Err(format!(
                    "est_rate cache for job {id} is {} but recomputes to {fresh}",
                    self.ledger.est_rate[id]
                ));
            }
            // Ledger invariants (the eager cross-check of the lazy core):
            // a job integrates iff it is running under the simulated
            // clock, and the recorded rate must be the placement-resolved
            // iteration time, to the bit.
            let integrating = self.project_finishes && rec.state == JobState::Running;
            if integrating != self.ledger.iter_s[id].is_finite() {
                return Err(format!(
                    "job {id} ({:?}) has iter_s = {} but integrating = {integrating}",
                    rec.state, self.ledger.iter_s[id]
                ));
            }
            if integrating {
                let fresh = self.state.effective_iter_time(id);
                if self.ledger.iter_s[id].to_bits() != fresh.to_bits() {
                    return Err(format!(
                        "job {id} integrates at {} but placement resolves to {fresh}",
                        self.ledger.iter_s[id]
                    ));
                }
            }
            let in_waiting = self.waiting.binary_search(&id).is_ok();
            if in_waiting != self.ledger.wait_since[id].is_finite() {
                return Err(format!(
                    "job {id} wait_since = {} but waiting-set membership = {in_waiting}",
                    self.ledger.wait_since[id]
                ));
            }
            if self.ledger.anchor_s[id] > self.state.now + T_EPS {
                return Err(format!(
                    "job {id} anchored at {} which is after now = {}",
                    self.ledger.anchor_s[id], self.state.now
                ));
            }
        }
        Ok(())
    }
}

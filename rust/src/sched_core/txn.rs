//! The validated transaction layer: [`Txn`] is what a policy returns,
//! [`SchedContext::apply`] is the single place — for the simulator *and*
//! the physical coordinator — where decisions are checked against every
//! scheduling invariant and turned into state transitions.
//!
//! Invariants enforced per [`Decision::Start`]:
//! * the job id exists and is `Pending`/`Preempted` (state machine),
//! * the job has arrived (`arrival_s <= now`),
//! * any restart penalty has expired (`not_before <= now`),
//! * the gang is non-empty, in range, duplicate-free, and every granted
//!   GPU has a free share slot (Eq. 9's C cap) not already held by the
//!   job,
//! * the accumulation step divides the batch (or is 1),
//! * the Eq. 9 memory budget holds on every granted GPU given all
//!   co-residents' sub-batches.
//!
//! Per [`Decision::Preempt`]: the job must be `Running`; it re-queues
//! with `not_before = now + penalty`.
//!
//! Decisions apply sequentially: each is validated against the state left
//! by the previous ones, so a transaction that double-starts a job or
//! overfills a GPU fails on the offending decision with the cluster in a
//! consistent (partially-applied) state — the backend treats any error as
//! a fatal policy bug, exactly as the old engine did.

use anyhow::{bail, Context, Result};

use crate::cluster::GpuId;
use crate::jobs::{JobId, JobState};

use super::context::{set_insert, set_remove, SchedContext, T_EPS};

/// Scheduling action requested by a policy.
#[derive(Debug, Clone)]
pub enum Decision {
    /// Gang-start a pending/preempted job on explicit GPUs with the given
    /// gradient-accumulation step (sub-batch = B / accum_step).
    Start { job: JobId, gpus: Vec<GpuId>, accum_step: u32 },
    /// Preempt a running job (preemptive policies only); it re-queues and
    /// may not restart before `now + penalty` (checkpoint/restore cost).
    Preempt { job: JobId },
}

/// An ordered batch of decisions produced by one [`super::Policy::on_event`]
/// call. Built with [`Txn::start`]/[`Txn::preempt`]; applied — and only
/// applied — through [`SchedContext::apply`].
#[derive(Debug, Clone, Default)]
pub struct Txn {
    ops: Vec<Decision>,
}

impl Txn {
    pub fn new() -> Self {
        Txn { ops: Vec::new() }
    }

    pub fn start(&mut self, job: JobId, gpus: Vec<GpuId>, accum_step: u32) {
        self.ops.push(Decision::Start { job, gpus, accum_step });
    }

    pub fn preempt(&mut self, job: JobId) {
        self.ops.push(Decision::Preempt { job });
    }

    pub fn ops(&self) -> &[Decision] {
        &self.ops
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// Whether any decision preempts — the physical coordinator rejects
    /// such transactions up front (it cannot checkpoint parameters).
    pub fn has_preempt(&self) -> bool {
        self.ops.iter().any(|d| matches!(d, Decision::Preempt { .. }))
    }
}

/// What a successfully applied transaction did.
#[derive(Debug, Clone, Copy, Default)]
pub struct ApplyReport {
    pub starts: u64,
    pub preemptions: u64,
}

impl SchedContext {
    /// Validate and apply `txn`, decision by decision. Errors indicate a
    /// buggy policy; the offending decision is *not* applied.
    ///
    /// This is the only write path for policy decisions in both backends
    /// — the simulator engine and the physical coordinator call exactly
    /// this method, so a malformed decision is rejected identically in
    /// simulation and in physical mode.
    pub fn apply(&mut self, txn: &Txn, penalty: f64) -> Result<ApplyReport> {
        let mut report = ApplyReport::default();
        for d in txn.ops() {
            self.apply_one(d, penalty, &mut report)
                .context("applying policy decision")?;
        }
        debug_assert!(self.state.cluster.check_invariants().is_ok());
        debug_assert!(self.cache_integrity().is_ok(), "{:?}", self.cache_integrity());
        Ok(report)
    }

    fn apply_one(
        &mut self,
        decision: &Decision,
        penalty: f64,
        report: &mut ApplyReport,
    ) -> Result<()> {
        match decision {
            Decision::Start { job, gpus, accum_step } => {
                self.apply_start(*job, gpus, *accum_step)?;
                report.starts += 1;
            }
            Decision::Preempt { job } => {
                self.apply_preempt(*job, penalty)?;
                report.preemptions += 1;
            }
        }
        Ok(())
    }

    fn apply_start(&mut self, job: JobId, gpus: &[GpuId], accum_step: u32) -> Result<()> {
        let now = self.state.now;
        let Some(rec) = self.state.jobs.get(job) else {
            bail!("Start({job}): unknown job id");
        };
        if !matches!(rec.state, JobState::Pending | JobState::Preempted) {
            bail!("Start({job}): job is {:?}", rec.state);
        }
        if rec.spec.arrival_s > now + T_EPS {
            bail!("Start({job}): job has not arrived yet");
        }
        if self.state.not_before[job] > now + T_EPS {
            bail!("Start({job}): restart penalty until {}", self.state.not_before[job]);
        }
        if gpus.is_empty() {
            bail!("Start({job}): empty gang");
        }
        for (i, &g) in gpus.iter().enumerate() {
            if g >= self.state.cluster.total_gpus() {
                bail!("Start({job}): GPU {g} out of range");
            }
            if gpus[..i].contains(&g) {
                bail!("Start({job}): GPU {g} granted twice in one gang");
            }
            let slot = self.state.cluster.slot(g);
            if slot.jobs.contains(&job) {
                bail!("Start({job}): job already holds GPU {g}");
            }
            if slot.jobs.len() >= self.state.cluster.config.max_share {
                bail!(
                    "Start({job}): GPU {g} over share capacity C = {}",
                    self.state.cluster.config.max_share
                );
            }
        }
        if accum_step == 0 || (rec.spec.batch % accum_step != 0 && accum_step != 1) {
            // Powers-of-two sweep guarantees divisibility for p2 batches;
            // reject anything else outright.
            bail!("Start({job}): invalid accumulation step {accum_step}");
        }
        // Memory feasibility on every granted GPU (Eq. 9 + footprint),
        // against the *per-type* budget of that specific GPU — on a
        // heterogeneous topology different gang members may have
        // different capacities.
        let my_mem =
            rec.spec.profile().mem.mem_gb(rec.spec.batch as f64 / accum_step as f64);
        for &g in gpus {
            let mut used = my_mem;
            for &other in &self.state.cluster.slot(g).jobs {
                let o = &self.state.jobs[other];
                used += o
                    .spec
                    .profile()
                    .mem
                    .mem_gb(o.spec.batch as f64 / o.accum_step as f64);
            }
            if used > self.state.cluster.mem_gb(g) + 1e-9 {
                bail!("Start({job}): GPU {g} memory over budget ({used:.2} GB)");
            }
        }
        // Settle the outgoing (no-op) rates and close out queue-time
        // accrual *before* the transition mutates the gang or state — the
        // old values parameterize the interval being folded.
        self.settle_job(job);
        self.settle_wait(job);
        self.state.cluster.allocate(job, gpus);
        let rec = &mut self.state.jobs[job];
        rec.state = JobState::Running;
        rec.accum_step = accum_step;
        rec.gpus_held = gpus.to_vec();
        // The estimated per-iteration rate depends on the accumulation
        // step; a Start is the only place that changes it.
        self.ledger.est_rate[job] = super::context::est_rate_of(rec);
        if rec.first_start_s.is_none() {
            rec.first_start_s = Some(now);
        }
        // Ordered-view removal must come through `pending_remove`: the
        // estimate key was refreshed above, so the index is dropped by
        // its stored insertion key, not a recomputation.
        self.pending_remove(job);
        set_remove(&mut self.waiting, job);
        set_insert(&mut self.running, job);
        self.reproject(job);
        let co = self.state.cluster.co_runners(job);
        if self.obs.is_enabled() {
            self.obs.job_started(now, job, gpus, !co.is_empty());
            // Co-residents just gained a neighbor: their sharing
            // intervals re-segment as shared from here.
            for &c in &co {
                self.obs.job_share_changed(now, c, true);
            }
        }
        for c in co {
            self.reproject(c);
        }
        Ok(())
    }

    fn apply_preempt(&mut self, job: JobId, penalty: f64) -> Result<()> {
        let Some(rec) = self.state.jobs.get(job) else {
            bail!("Preempt({job}): unknown job id");
        };
        if rec.state != JobState::Running {
            bail!("Preempt({job}): job is {:?}", rec.state);
        }
        let co = self.state.cluster.co_runners(job);
        // Fold the progress and service accrued at the outgoing rate
        // before the gang is torn down.
        self.settle_job(job);
        self.state.cluster.release(job);
        let rec = &mut self.state.jobs[job];
        rec.state = JobState::Preempted;
        rec.gpus_held.clear();
        let not_before = self.state.now + penalty;
        self.state.not_before[job] = not_before;
        set_remove(&mut self.running, job);
        set_insert(&mut self.waiting, job);
        self.ledger.wait_since[job] = self.state.now;
        self.ledger.epoch[job] += 1;
        self.ledger.iter_s[job] = f64::INFINITY;
        if not_before <= self.state.now + T_EPS {
            // Zero (or sub-epsilon) penalty: immediately schedulable again
            // — including by a later decision in this same transaction.
            // The sentinel rate is already in place, so the ordered view
            // indexes the settled (frozen) estimate.
            self.pending_insert(job);
        }
        // Always queue the expiry so the backend delivers the documented
        // RestartEligible event (immediately, for a zero penalty — the
        // pop's state guard drops it if the job restarted in the
        // meantime). Without this a zero-penalty preempt would re-queue
        // the job silently and, with no other events due, the engine
        // would report a deadlock on a well-behaved workload.
        self.restart_q.push(not_before, job);
        if self.obs.is_enabled() {
            self.obs.job_stopped(self.state.now, job, "preempt");
            for &c in &co {
                let still_shared = !self.state.cluster.co_runners(c).is_empty();
                self.obs.job_share_changed(self.state.now, c, still_shared);
            }
        }
        for c in co {
            self.reproject(c);
        }
        Ok(())
    }
}

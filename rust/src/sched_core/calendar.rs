//! [`CalendarQueue`] — a bucketed timer wheel for the event core's
//! finish-projection and restart-expiry queues (DESIGN.md §15 covers
//! the million-job event core this queue serves).
//!
//! A classic calendar queue (Brown '88) beats a binary heap under heavy
//! traffic because the common operations touch one bucket instead of a
//! log-depth path: a push lands in the bucket covering its timestamp
//! (O(1) amortized), and pops drain the front bucket, which is sorted
//! on demand. With N live timers spread over the span the wheel covers,
//! both operations are O(1) amortized versus the heap's O(log N) — and
//! the bucket layout keeps coincident-timestamp entries physically
//! adjacent, so the engine's batched delivery of same-instant events is
//! a linear walk rather than N interleaved heap pops.
//!
//! Design points, in order of subtlety:
//!
//! * **Total order.** Entries are `(f64 time, P payload)` and pop in
//!   ascending `(total_cmp(time), P)` order — exactly the order the
//!   `BinaryHeap<Reverse<(OrdF64, ..)>>`s this replaces produced, which
//!   `tests/event_core.rs` pins property-test-style against a reference
//!   heap. Duplicate entries are allowed (a job preempted twice at the
//!   same instant pushes two identical expiries, just as the heap did).
//! * **Front-bucket laziness.** Only the bucket currently being drained
//!   is ever sorted (descending, so the minimum pops from the back);
//!   pushes into later buckets are plain appends. A push into the front
//!   bucket binary-inserts when the bucket is already sorted, else it
//!   appends and re-flags the bucket for sorting.
//! * **Overflow + rebuild.** Entries beyond the wheel's horizon go to an
//!   overflow list. When the wheel drains into the overflow's span, or
//!   the overflow outgrows half the queue, the whole queue rebuilds its
//!   bucket geometry from the live entries: bucket count is the next
//!   power of two of the population (clamped to [16, 4096]) and the
//!   width divides the live span evenly. All geometry is derived from
//!   *content only* — no clocks, no capacities — so two runs with the
//!   same push/pop sequence produce bit-identical pop streams, which is
//!   what the threads-1-vs-8 determinism CI leg relies on.
//! * **Past-due pushes.** A push at `t < base` (the engine's `T_EPS`
//!   slack can produce these) clamps into the front bucket; the sort
//!   before the next pop still surfaces it in correct order relative to
//!   everything else in that bucket.

use std::cmp::Ordering;
use std::collections::VecDeque;

/// Ascending `(time, payload)` entry order; times via `total_cmp` so the
/// order is total even for non-finite junk (which callers never push).
fn cmp_entries<P: Ord>(a: &(f64, P), b: &(f64, P)) -> Ordering {
    a.0.total_cmp(&b.0).then_with(|| a.1.cmp(&b.1))
}

/// See the module docs. `P` is the payload carried next to the timestamp
/// and the tie-break key among equal times.
#[derive(Debug, Clone)]
pub struct CalendarQueue<P> {
    /// `ring[i]` covers `[base + i*width, base + (i+1)*width)`.
    ring: VecDeque<Vec<(f64, P)>>,
    /// Start of the front bucket's span.
    base: f64,
    /// Bucket width in seconds (> 0 always).
    width: f64,
    /// Whether `ring[0]` is sorted descending (min at the back).
    front_sorted: bool,
    /// Entries at or beyond the wheel horizon, unordered.
    overflow: Vec<(f64, P)>,
    /// Minimum time in `overflow` (`INFINITY` when empty) — lets bucket
    /// rotation skip the overflow scan entirely when nothing is due.
    overflow_min: f64,
    /// Total live entries (ring + overflow).
    len: usize,
}

impl<P: Ord + Copy> Default for CalendarQueue<P> {
    fn default() -> Self {
        Self::new()
    }
}

impl<P: Ord + Copy> CalendarQueue<P> {
    pub fn new() -> Self {
        CalendarQueue {
            ring: VecDeque::new(),
            base: 0.0,
            width: 1.0,
            front_sorted: true,
            overflow: Vec::new(),
            overflow_min: f64::INFINITY,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn clear(&mut self) {
        self.ring.clear();
        self.overflow.clear();
        self.overflow_min = f64::INFINITY;
        self.front_sorted = true;
        self.len = 0;
    }

    /// Insert `(t, p)`. `t` must not be NaN (event times are arithmetic
    /// over finite inputs; this is a debug assertion, not a runtime gate).
    pub fn push(&mut self, t: f64, p: P) {
        debug_assert!(!t.is_nan(), "calendar queue entries need a real time");
        self.len += 1;
        if self.ring.is_empty() {
            // First entry (or first after clear): seed the geometry.
            self.rebuild_from(vec![(t, p)]);
            return;
        }
        if t < self.base {
            // Past-due push: clamp into the front bucket; ordering is
            // restored by the sort before the next pop.
            self.push_front_bucket((t, p));
            return;
        }
        let idx = ((t - self.base) / self.width) as usize;
        if idx == 0 {
            self.push_front_bucket((t, p));
        } else if idx < self.ring.len() {
            self.ring[idx].push((t, p));
        } else {
            self.overflow_min = self.overflow_min.min(t);
            self.overflow.push((t, p));
            // Overflow pressure: the geometry no longer matches where the
            // entries actually live — re-derive it from the population.
            if self.overflow.len() > self.len / 2 + 64 {
                self.rebuild_all();
            }
        }
    }

    /// Earliest `(time, payload)` without removing it.
    pub fn peek(&mut self) -> Option<(f64, P)> {
        self.settle_front()?;
        self.ring[0].last().copied()
    }

    /// Remove and return the earliest `(time, payload)`.
    pub fn pop(&mut self) -> Option<(f64, P)> {
        self.settle_front()?;
        self.len -= 1;
        self.ring[0].pop()
    }

    // ------------------------------------------------------- internals

    fn push_front_bucket(&mut self, e: (f64, P)) {
        let front = &mut self.ring[0];
        if self.front_sorted {
            // Keep the descending sort: insert after every entry greater
            // than `e`, so the minimum stays at the back.
            let pos = front.partition_point(|x| cmp_entries(x, &e) == Ordering::Greater);
            front.insert(pos, e);
        } else {
            front.push(e);
        }
    }

    /// Make `ring[0]` the non-empty, sorted bucket holding the global
    /// minimum. Returns `None` iff the queue is empty.
    fn settle_front(&mut self) -> Option<()> {
        if self.len == 0 {
            return None;
        }
        loop {
            // Pull overflow entries due within the front bucket's span.
            let horizon = self.base + self.width;
            if self.overflow_min < horizon {
                let mut i = 0;
                while i < self.overflow.len() {
                    if self.overflow[i].0 < horizon {
                        let e = self.overflow.swap_remove(i);
                        self.ring[0].push(e);
                        self.front_sorted = false;
                    } else {
                        i += 1;
                    }
                }
                self.overflow_min =
                    self.overflow.iter().fold(f64::INFINITY, |m, e| m.min(e.0));
            }
            if !self.ring[0].is_empty() {
                if !self.front_sorted {
                    self.ring[0].sort_by(|a, b| cmp_entries(b, a));
                    self.front_sorted = true;
                }
                return Some(());
            }
            if self.len == self.overflow.len() {
                // The wheel is fully drained and everything live sits in
                // the overflow: re-derive the geometry around it.
                self.rebuild_all();
                continue;
            }
            // Rotate: the front bucket is empty but a later one is not.
            let empty = self.ring.pop_front().expect("ring is never empty here");
            self.ring.push_back(empty);
            self.base += self.width;
            self.front_sorted = true; // an empty bucket is trivially sorted
        }
    }

    fn rebuild_all(&mut self) {
        let mut all: Vec<(f64, P)> = Vec::with_capacity(self.len);
        for b in self.ring.iter_mut() {
            all.append(b);
        }
        all.append(&mut self.overflow);
        self.overflow_min = f64::INFINITY;
        self.rebuild_from(all);
    }

    /// Re-derive bucket geometry from `entries` (the full live set) and
    /// distribute them. Deterministic in content only.
    fn rebuild_from(&mut self, entries: Vec<(f64, P)>) {
        debug_assert_eq!(entries.len() + self.overflow.len(), self.len);
        let nb = entries.len().next_power_of_two().clamp(16, 4096);
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for e in &entries {
            lo = lo.min(e.0);
            hi = hi.max(e.0);
        }
        let span = hi - lo;
        let mut width = span / nb as f64;
        if !(width > 0.0) || !width.is_finite() {
            // Empty span (all entries coincident) or an underflowed
            // quotient: any positive width is correct, 1 s is neutral.
            width = if span > 0.0 { span } else { 1.0 };
        }
        self.base = lo;
        self.width = width;
        self.ring.clear();
        self.ring.resize(nb, Vec::new());
        self.front_sorted = true;
        for (t, p) in entries {
            // `hi` itself maps to index nb; clamp the distribution — every
            // entry here is inside [lo, hi] by construction.
            let idx = (((t - lo) / width) as usize).min(nb - 1);
            if idx == 0 {
                self.push_front_bucket((t, p));
            } else {
                self.ring[idx].push((t, p));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    /// Reference min-order via the heap the calendar replaces.
    #[derive(Default)]
    struct RefHeap {
        heap: BinaryHeap<Reverse<(u64, usize)>>,
    }

    impl RefHeap {
        // total_cmp order == integer order of the sign-adjusted bit
        // pattern; tests only push non-negative times, where the raw
        // bit pattern suffices.
        fn push(&mut self, t: f64, p: usize) {
            self.heap.push(Reverse((t.to_bits(), p)));
        }
        fn pop(&mut self) -> Option<(f64, usize)> {
            self.heap.pop().map(|Reverse((b, p))| (f64::from_bits(b), p))
        }
    }

    #[test]
    fn drains_in_time_then_payload_order() {
        let mut q = CalendarQueue::new();
        for (t, p) in [(5.0, 1), (1.0, 9), (5.0, 0), (3.0, 4), (1.0, 2)] {
            q.push(t, p);
        }
        let mut got = Vec::new();
        while let Some(e) = q.pop() {
            got.push(e);
        }
        assert_eq!(got, vec![(1.0, 2), (1.0, 9), (3.0, 4), (5.0, 0), (5.0, 1)]);
        assert!(q.is_empty());
    }

    #[test]
    fn peek_matches_pop_and_len_tracks() {
        let mut q = CalendarQueue::new();
        q.push(2.0, 7usize);
        q.push(0.5, 3usize);
        assert_eq!(q.len(), 2);
        assert_eq!(q.peek(), Some((0.5, 3)));
        assert_eq!(q.pop(), Some((0.5, 3)));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some((2.0, 7)));
        assert_eq!(q.pop(), None);
        assert_eq!(q.peek(), None);
    }

    #[test]
    fn past_due_push_still_pops_first() {
        let mut q = CalendarQueue::new();
        // Establish geometry well past zero, then push an earlier entry.
        for i in 0..100usize {
            q.push(1000.0 + i as f64, i);
        }
        q.pop();
        q.push(1.0, 777usize);
        assert_eq!(q.pop(), Some((1.0, 777)));
    }

    #[test]
    fn duplicates_are_kept() {
        let mut q = CalendarQueue::new();
        q.push(4.0, 2usize);
        q.push(4.0, 2usize);
        assert_eq!(q.pop(), Some((4.0, 2)));
        assert_eq!(q.pop(), Some((4.0, 2)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn clear_resets_to_empty() {
        let mut q = CalendarQueue::new();
        for i in 0..50usize {
            q.push(i as f64 * 3.3, i);
        }
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
        q.push(1.0, 1usize);
        assert_eq!(q.pop(), Some((1.0, 1)));
    }

    #[test]
    fn prop_matches_reference_heap_under_random_interleaving() {
        forall("calendar-vs-heap", 0xCA1E, 64, |rng| {
            let mut cal = CalendarQueue::new();
            let mut heap = RefHeap::default();
            // Mixed time scales: sub-second jitter, minutes, and
            // week-scale outliers that force overflow + rebuild.
            for step in 0..400 {
                if rng.f64() < 0.65 || cal.is_empty() {
                    let t = match rng.index(3) {
                        0 => rng.f64(),
                        1 => rng.f64() * 600.0,
                        _ => rng.f64() * 604_800.0,
                    };
                    let p = rng.index(64);
                    cal.push(t, p);
                    heap.push(t, p);
                } else {
                    let got = cal.pop();
                    let want = heap.pop();
                    if got != want {
                        return Err(format!(
                            "step {step}: calendar popped {got:?}, heap {want:?}"
                        ));
                    }
                }
            }
            while let Some(want) = heap.pop() {
                let got = cal.pop();
                if got != Some(want) {
                    return Err(format!("drain: calendar {got:?} != heap {want:?}"));
                }
            }
            if !cal.is_empty() {
                return Err(format!("{} entries left after drain", cal.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_coincident_timestamps_pop_in_payload_order() {
        forall("calendar-coincident", 0xBEEF, 32, |rng| {
            let mut cal = CalendarQueue::new();
            let t = rng.f64() * 1e5;
            let n = 2 + rng.index(30);
            let mut payloads: Vec<usize> = (0..n).collect();
            // Push in a shuffled order; pops must come back ascending.
            for i in (1..n).rev() {
                payloads.swap(i, rng.index(i + 1));
            }
            for &p in &payloads {
                cal.push(t, p);
            }
            for want in 0..n {
                let got = cal.pop();
                if got != Some((t, want)) {
                    return Err(format!("expected ({t}, {want}), got {got:?}"));
                }
            }
            Ok(())
        });
    }
}

//! `sched_core` — the one event-driven scheduling API shared by the
//! simulator ([`crate::sim::engine`]) and the physical coordinator
//! ([`crate::coordinator`]).
//!
//! The paper's validation story (§VI: simulator within 5% of the physical
//! testbed) only holds if both backends run the *same* scheduling core.
//! This module is that core, split into three pieces:
//!
//! * **[`Event`]** — what happened: a job [`Event::Arrival`], a job
//!   [`Event::Completion`], a preempted job becoming
//!   [`Event::RestartEligible`] again, or a periodic [`Event::Tick`].
//!   Backends translate their native notion of time (simulated event time
//!   vs wall clock) into this one vocabulary.
//! * **[`SchedContext`]** — the read view handed to policies. It owns the
//!   world state ([`crate::sim::SimState`], reachable via `Deref`) plus
//!   *incrementally maintained* index caches: the eligible-pending set,
//!   the running set, the waiting set (queue-time accrual), and
//!   calendar queues ([`calendar::CalendarQueue`]) of projected finish
//!   times and restart-penalty expiries. Policies read `ctx.pending()` /
//!   `ctx.running()` as slices instead of re-deriving them with an O(n)
//!   scan per call; the engine picks its next event in O(1) amortized,
//!   and per-job progress integrates lazily (settled only on rate
//!   transitions — see DESIGN.md §15), so event cost no longer grows
//!   with cluster occupancy.
//! * **[`Txn`]** — the write path. A policy returns a transaction of
//!   [`Decision`]s from [`Policy::on_event`]; [`SchedContext::apply`] is
//!   the *single* place that validates (gang non-empty and within share
//!   capacity, accumulation-step divisibility, Eq. 9 memory budget, job
//!   state machine, arrival and `not_before` gates) and applies them —
//!   for both backends. A buggy policy gets an error, never corrupted
//!   cluster state, in simulation and in physical mode alike.
//!
//! See DESIGN.md "§9 sched_core — writing a policy" for the authoring
//! guide and the exact guarantees.

pub mod calendar;
pub mod context;
mod ledger;
pub mod order;
pub mod pump;
pub mod txn;

pub use context::SchedContext;
pub use order::PendingOrder;
pub use pump::{EventPump, NoHooks, PumpHooks};
pub use txn::{ApplyReport, Decision, Txn};

use crate::jobs::JobId;

/// What the backend observed since the last policy invocation.
/// Simultaneous events (e.g. two arrivals at the same instant) are
/// ordered completions first, then arrivals, then restart eligibilities,
/// then the tick, and every event in the batch is delivered at the same
/// `ctx.now()` with the ledger fully settled — the first policy pass of
/// a batch already sees the whole coincident world.
///
/// **Coincident-batch delivery** depends on
/// [`Policy::coalesce_coincident`]. Event-reactive policies (the
/// default) get one `on_event` call per event, as always. Full-pass
/// policies that opt in get one call for the *first* event of a
/// same-instant batch and further calls only while their transactions
/// keep doing work: once a pass returns an empty [`Txn`], the remaining
/// events of that batch are absorbed without a pass — for a pure
/// decision function that ignores the event payload, those passes would
/// have been byte-identical no-ops (same instant, unchanged state).
/// `SimOutcome::policy_calls` and `EventPump::policy_calls` count
/// delivered *passes*, so they shrink under coalescing even though every
/// event still fires its observability tap and pump completion hook.
///
/// An event describes what *happened*, not what is actionable now: a
/// transaction applied by an earlier same-instant delivery may already
/// have started the subject of a queued `Arrival`/`RestartEligible`.
/// Before issuing a `Start`, always confirm the job is still in
/// [`SchedContext::pending`] (the full-pass policies in `sched/` get
/// this for free by planning from `ctx.pending()` on every call).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// `job` arrived and joined the eligible pending set (at delivery
    /// time it may already have been started by an earlier same-instant
    /// transaction — re-check [`SchedContext::pending`]).
    Arrival { job: JobId },
    /// `job` finished all its iterations; its GPUs are free again.
    Completion { job: JobId },
    /// `job`'s restart penalty expired and it rejoined the pending set
    /// (same caveat as `Arrival`: it may have been restarted by an
    /// earlier same-instant transaction).
    RestartEligible { job: JobId },
    /// Periodic invocation, fired every [`Policy::tick_interval`] seconds.
    Tick,
}

/// A scheduling policy: a named, stateful event handler.
///
/// `on_event` must be a *pure decision function* of `(self, ctx, ev)`:
/// it reads the world through `ctx` and returns a [`Txn`] of decisions,
/// which the backend validates and applies through the shared
/// [`SchedContext::apply`] path. Policies never mutate the world directly,
/// so a scheduling bug cannot corrupt cluster invariants in either
/// backend.
pub trait Policy {
    fn name(&self) -> &'static str;

    /// Handle one event. Return an empty [`Txn`] to do nothing.
    fn on_event(&mut self, ctx: &SchedContext, ev: Event) -> Txn;

    /// Periodic invocation interval, e.g. for Tiresias/elastic
    /// reallocation. `None` (default) means event-driven only.
    fn tick_interval(&self) -> Option<f64> {
        None
    }

    /// Opt in to coincident-batch delivery (see the [`Event`] docs): when
    /// true, the backend may absorb the tail of a same-instant event
    /// batch once a pass returns an empty [`Txn`]. Only sound for
    /// policies whose `on_event` is a full pass that ignores the event
    /// payload — i.e. a pure decision function of `ctx` alone — which is
    /// exactly what makes the skipped passes provable no-ops. Default
    /// `false`: one call per event, the historical contract.
    fn coalesce_coincident(&self) -> bool {
        false
    }

    /// Seconds a preempted job loses before it can restart.
    fn preemption_penalty(&self) -> f64 {
        30.0
    }
}

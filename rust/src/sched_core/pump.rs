//! [`EventPump`] — the event-delivery driver shared by the wall-clock
//! backends: the physical coordinator and the serve daemon.
//!
//! The simulator engine ([`crate::sim::engine`]) owns a batch run from
//! first arrival to last completion, so it keeps its own closed loop.
//! The coordinator and the daemon instead advance *incrementally* — to
//! the current wall instant, or to a client-requested virtual instant —
//! and must interleave delivery with external input (worker progress
//! reports, protocol requests). This type factors the part they must
//! agree on with the engine for the fidelity story to hold: the
//! completions → arrivals/restarts → tick delivery order at an instant,
//! the obskit taps around each delivery, and the single validated
//! [`SchedContext::apply`] path for every policy transaction.
//!
//! Two advancement styles:
//! * [`EventPump::begin_wall`] + [`EventPump::finish_wall`] — one jump to
//!   a wall instant (the coordinator: real execution drives progress via
//!   [`SchedContext::note_progress`] between the two calls, then
//!   completions are collected at the jumped-to time).
//! * [`EventPump::pump_sim`] — event-boundary stepping to a target
//!   simulated instant (the daemon's virtual clock: progress integrates
//!   at piecewise-constant rates, so the pump must stop at every rate
//!   change exactly as the engine does).
//!
//! Backend-specific reactions (the coordinator's assignment board, the
//! daemon's notification stream) hang off [`PumpHooks`].

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::jobs::JobId;

use super::{ApplyReport, Event, Policy, SchedContext, Txn};

/// Backend reactions to pump-driven transitions. Hooks fire *after* the
/// corresponding state transition has been applied to the context and
/// may fail, which aborts the pump call.
pub trait PumpHooks {
    /// `job` just finished (its GPUs are released). Fires before the
    /// `Completion` event is delivered to the policy.
    fn completed(&mut self, _ctx: &SchedContext, _job: JobId) -> Result<()> {
        Ok(())
    }

    /// `txn` was validated and applied. Fires once per delivered event
    /// whose transaction applied cleanly (including empty transactions).
    fn txn_applied(
        &mut self,
        _ctx: &SchedContext,
        _txn: &Txn,
        _report: &ApplyReport,
    ) -> Result<()> {
        Ok(())
    }
}

/// The no-reaction hook set.
pub struct NoHooks;

impl PumpHooks for NoHooks {}

/// See the module docs. One pump instance lives as long as its backend
/// run: it owns the tick cadence and the delivery counters.
pub struct EventPump {
    /// Tick period in backend clock seconds (already divided by any
    /// time compression by [`EventPump::with_tick_scale`]).
    tick_every: Option<f64>,
    next_tick: Option<f64>,
    penalty: f64,
    /// When set, a transaction containing a `Preempt` is rejected with
    /// this message before it reaches `apply` (the physical coordinator
    /// cannot checkpoint parameters).
    reject_preempts: Option<&'static str>,
    /// When set, apply errors are wrapped with this context string.
    apply_context: Option<&'static str>,
    events: Vec<Event>,
    clock_events: Vec<Event>,
    policy_calls: u64,
    preemptions: u64,
}

impl EventPump {
    /// A pump for `policy`: tick cadence and preemption penalty are read
    /// once here (they are `&self` constants on every shipped policy).
    pub fn new(policy: &dyn Policy) -> EventPump {
        let tick = policy.tick_interval();
        EventPump {
            tick_every: tick,
            next_tick: tick,
            penalty: policy.preemption_penalty(),
            reject_preempts: None,
            apply_context: None,
            events: Vec::new(),
            clock_events: Vec::new(),
            policy_calls: 0,
            preemptions: 0,
        }
    }

    /// Divide the tick cadence by `scale` (the coordinator's
    /// `time_compression`: arrivals are compressed onto the wall clock,
    /// so ticks must be too — a Tick fires after the same amount of
    /// *workload* time in both backends).
    pub fn with_tick_scale(mut self, scale: f64) -> EventPump {
        self.tick_every = self.tick_every.map(|t| t / scale);
        self.next_tick = self.tick_every;
        self
    }

    /// Reject preempting transactions with `msg` (see field docs).
    pub fn reject_preempts(mut self, msg: &'static str) -> EventPump {
        self.reject_preempts = Some(msg);
        self
    }

    /// Wrap apply errors with `msg` (see field docs).
    pub fn apply_context(mut self, msg: &'static str) -> EventPump {
        self.apply_context = Some(msg);
        self
    }

    /// Policy *passes* delivered so far — the same count the engine's
    /// `SimOutcome::policy_calls` reports: one per event for
    /// event-reactive policies, fewer under
    /// [`Policy::coalesce_coincident`] (the tail of a same-instant batch
    /// is absorbed once a pass returns an empty transaction).
    pub fn policy_calls(&self) -> u64 {
        self.policy_calls
    }

    /// Preemptions applied so far.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Next pending tick instant, if the policy ticks.
    pub fn next_tick(&self) -> Option<f64> {
        self.next_tick
    }

    /// Snapshot restore: reinstate the delivery counters and the pending
    /// tick instant exactly as serialized.
    pub fn restore(&mut self, policy_calls: u64, preemptions: u64, next_tick: Option<f64>) {
        self.policy_calls = policy_calls;
        self.preemptions = preemptions;
        if self.tick_every.is_some() {
            self.next_tick = next_tick;
        }
    }

    // ------------------------------------------------- wall-clock jump

    /// Phase 1 of a wall-clock iteration: jump the context to wall
    /// instant `t`, buffering any arrivals/restart eligibilities that
    /// became due. The caller applies external progress (worker reports)
    /// between this and [`EventPump::finish_wall`], so completions are
    /// collected against up-to-date `remaining_iters`.
    pub fn begin_wall(&mut self, ctx: &mut SchedContext, t: f64) {
        self.clock_events.clear();
        ctx.advance_wall(t, &mut self.clock_events);
    }

    /// Phase 2: collect completions at the jumped-to instant, then
    /// deliver completions → buffered clock events → tick, applying each
    /// returned transaction through the shared validated path.
    pub fn finish_wall(
        &mut self,
        ctx: &mut SchedContext,
        policy: &mut dyn Policy,
        hooks: &mut dyn PumpHooks,
    ) -> Result<()> {
        self.events.clear();
        ctx.collect_completions(0.0, &mut self.events);
        let mut clock = std::mem::take(&mut self.clock_events);
        self.events.append(&mut clock);
        self.clock_events = clock;
        self.queue_due_tick(ctx.now());
        self.deliver(ctx, policy, hooks)
    }

    // -------------------------------------------- simulated-clock step

    /// Advance the context's *simulated* clock to `target`, stopping at
    /// every event boundary (arrival, projected finish, restart expiry,
    /// tick) on the way — the engine's event-selection loop, bounded by
    /// `target` instead of by all-finished. Progress integrates at
    /// piecewise-constant rates; `eps_iters` is the engine's completion
    /// epsilon. `target == ctx.now()` still runs one delivery pass, so
    /// events due exactly *now* (a just-admitted arrival) fire.
    pub fn pump_sim(
        &mut self,
        ctx: &mut SchedContext,
        policy: &mut dyn Policy,
        target: f64,
        eps_iters: f64,
        hooks: &mut dyn PumpHooks,
    ) -> Result<()> {
        loop {
            let now = ctx.now();
            let mut t_next = target;
            for t in [ctx.next_arrival(), ctx.next_finish(), ctx.next_restart(), self.next_tick]
            {
                if let Some(t) = t {
                    if t < t_next {
                        t_next = t;
                    }
                }
            }
            // Due-but-undelivered events can sit at or before `now`
            // (restored snapshots, zero-penalty restarts): clamp so the
            // clock never moves backwards.
            let t_next = t_next.max(now);
            self.clock_events.clear();
            ctx.advance_sim(t_next, &mut self.clock_events);
            self.events.clear();
            ctx.collect_completions(eps_iters, &mut self.events);
            let mut clock = std::mem::take(&mut self.clock_events);
            self.events.append(&mut clock);
            self.clock_events = clock;
            self.queue_due_tick(ctx.now());
            // A delivery pass with no events is fine: a due finish
            // projection whose residual round-off left above eps was
            // re-projected inside `collect_completions`, so the next
            // event-selection pass sees a strictly later finish time.
            self.deliver(ctx, policy, hooks)?;
            if ctx.now() + 1e-9 >= target {
                return Ok(());
            }
        }
    }

    /// Deliver one synthetic `Tick` immediately — the daemon's nudge
    /// after a cancel frees GPUs without any natural event to react to.
    pub fn kick(
        &mut self,
        ctx: &mut SchedContext,
        policy: &mut dyn Policy,
        hooks: &mut dyn PumpHooks,
    ) -> Result<()> {
        self.events.clear();
        self.events.push(Event::Tick);
        self.deliver(ctx, policy, hooks)
    }

    // ------------------------------------------------------- internals

    fn queue_due_tick(&mut self, now: f64) {
        if let Some(tick) = self.next_tick {
            if tick <= now + 1e-9 {
                self.next_tick = Some(tick + self.tick_every.unwrap());
                self.events.push(Event::Tick);
            }
        }
    }

    /// The shared delivery body — identical to the engine's: obs taps
    /// around each event, policy latency timed only when someone
    /// listens, every transaction through [`SchedContext::apply`], and
    /// the same coincident-batch coalescing rule (once a pass returns an
    /// empty transaction, the rest of the batch is absorbed without a
    /// pass — completion hooks and obs taps still fire per event).
    fn deliver(
        &mut self,
        ctx: &mut SchedContext,
        policy: &mut dyn Policy,
        hooks: &mut dyn PumpHooks,
    ) -> Result<()> {
        let events = std::mem::take(&mut self.events);
        let obs = ctx.obs().clone();
        let obs_enabled = obs.is_enabled();
        let coalesce = policy.coalesce_coincident();
        let mut converged = false;
        let result = (|| -> Result<()> {
            for &ev in &events {
                if let Event::Completion { job } = ev {
                    hooks.completed(ctx, job)?;
                }
                if obs_enabled {
                    obs.engine_event(ctx.now(), ev);
                }
                if coalesce && converged {
                    continue;
                }
                let txn;
                if obs_enabled {
                    let t0 = Instant::now();
                    txn = policy.on_event(ctx, ev);
                    obs.policy_latency(policy.name(), t0.elapsed().as_secs_f64());
                } else {
                    txn = policy.on_event(ctx, ev);
                }
                self.policy_calls += 1;
                if coalesce && txn.is_empty() {
                    converged = true;
                }
                if let Some(msg) = self.reject_preempts {
                    if txn.has_preempt() {
                        if obs_enabled {
                            obs.txn_rejected(ctx.now(), policy.name(), &txn, msg);
                        }
                        bail!(msg);
                    }
                }
                match ctx.apply(&txn, self.penalty) {
                    Ok(report) => {
                        if obs_enabled {
                            obs.txn_applied(ctx.now(), policy.name(), &txn, &report);
                        }
                        self.preemptions += report.preemptions;
                        hooks.txn_applied(ctx, &txn, &report)?;
                    }
                    Err(e) => {
                        if obs_enabled {
                            obs.txn_rejected(ctx.now(), policy.name(), &txn, &format!("{e:#}"));
                        }
                        return match self.apply_context {
                            Some(c) => Err(e).context(c),
                            None => Err(e),
                        };
                    }
                }
            }
            Ok(())
        })();
        if obs_enabled && !events.is_empty() {
            let total = ctx.cluster.total_gpus();
            let busy = total - ctx.cluster.free_count();
            let shared = busy - ctx.cluster.one_job_count();
            obs.cluster_counts(ctx.now(), busy, shared);
            obs.sample(ctx.now(), busy, shared, total, ctx.waiting().len(), ctx.pending().len());
        }
        self.events = events;
        result
    }
}

//! [`ProgressLedger`] — the SoA hot-field store behind lazy progress
//! integration (DESIGN.md §15).
//!
//! The eager core walked every running job on every `advance` to
//! integrate `remaining_iters`/`service_gpu_s` and every waiting job to
//! accrue `queued_s` — O(occupancy) per event, the term that made sim
//! cost quadratic in trace size. The ledger replaces the sweep with
//! epoch-anchored accounting: each job carries the instant it was last
//! *settled* (`anchor_s`) and its current integration rate (`iter_s`),
//! and the true value of any lazy quantity at `now` is a closed-form
//! read:
//!
//! ```text
//! remaining(now)  = remaining_at_anchor - (now - anchor) / iter_s
//! service(now)    = service_at_anchor   + gpus_held × (now - anchor)
//! queued(now)     = queued_at_anchor    + (now - wait_since)   [waiting]
//! ```
//!
//! Jobs are *settled* (the closed form folded into the stored value and
//! the anchor moved to `now`) only on transitions that change their rate:
//! start, preempt, completion, a co-runner change, cancel. Between
//! transitions nothing touches them — `advance` is O(1) + due events.
//!
//! The sentinel encodings make the lazy reads **bit-exact** for every job
//! whose quantity is not currently integrating, so hot paths like the
//! SJF sort over pending jobs read exactly the stored field:
//!
//! * `iter_s = ∞` ⇒ `(now - anchor)/∞ == 0.0` and `x - 0.0 == x` for
//!   every non-negative `x`: a non-running (or wall-mode) job's
//!   `remaining_iters` passes through untouched.
//! * `gpus_held.is_empty()` ⇒ `0.0 × dt == 0.0` and `x + 0.0 == x`: a
//!   non-running job's `service_gpu_s` passes through untouched.
//! * `wait_since = NaN` ⇒ the waiting term is skipped entirely: a
//!   non-waiting job's `queued_s` passes through untouched.
//!
//! This struct also absorbs the per-job caches the context already kept
//! (`epoch`, the memoized placement-resolved iteration time, the
//! estimated solo rate) so the hot per-job metadata lives in six dense
//! parallel vectors instead of being scattered across `JobRecord`s —
//! the completion path and the policy sort no longer drag whole records
//! (spec, gang vector, timestamps) through cache to read one f64.

use crate::jobs::JobRecord;

use super::context::est_rate_of;

/// See the module docs. All fields are parallel, indexed by [`crate::jobs::JobId`].
#[derive(Debug, Clone)]
pub(super) struct ProgressLedger {
    /// Instant each job was last settled.
    pub anchor_s: Vec<f64>,
    /// Effective seconds/iteration while integrating; `INFINITY` when the
    /// job is not integrating (not running, or wall mode).
    pub iter_s: Vec<f64>,
    /// Instant the job (re)joined the waiting set; `NaN` when not waiting.
    pub wait_since: Vec<f64>,
    /// Rate epoch, bumped whenever the job's iteration rate changes
    /// (start, preempt, finish, or a co-runner change). Stamped into
    /// finish-queue entries so stale projections are skippable.
    pub epoch: Vec<u64>,
    /// Placement-resolved effective iteration time, memoized as
    /// `(epoch at computation, seconds)`; a stale epoch means invalid.
    pub iter_cache: Vec<(u64, f64)>,
    /// Estimated solo seconds/iteration at the current accumulation step
    /// (`iter_time(accum) × est_factor`) — the cached factor of the
    /// SJF-family sort key. Only a `Start` changes it.
    pub est_rate: Vec<f64>,
}

impl ProgressLedger {
    pub fn new(jobs: &[JobRecord], now: f64) -> ProgressLedger {
        let n = jobs.len();
        ProgressLedger {
            anchor_s: vec![now; n],
            iter_s: vec![f64::INFINITY; n],
            wait_since: vec![f64::NAN; n],
            epoch: vec![0; n],
            iter_cache: vec![(u64::MAX, 0.0); n],
            est_rate: jobs.iter().map(est_rate_of).collect(),
        }
    }

    /// Append slots for a job admitted mid-run (the serve daemon).
    pub fn push_job(&mut self, rec: &JobRecord, now: f64) {
        self.anchor_s.push(now);
        self.iter_s.push(f64::INFINITY);
        self.wait_since.push(f64::NAN);
        self.epoch.push(0);
        self.iter_cache.push((u64::MAX, 0.0));
        self.est_rate.push(est_rate_of(rec));
    }
}

/// Shadow state for the **eager reference sweep** — the verification mode
/// behind [`super::SchedContext::verify_against_eager_reference`]. When
/// armed, every `advance` replays the pre-ledger per-event integration
/// loops over these vectors (the exact arithmetic the O(running) sweep
/// used) and asserts the lazy closed forms agree within float tolerance.
/// The two schemes differ only in summation order, so agreement is tight
/// but not bitwise; `tests/event_core.rs` runs full six-policy golden
/// traces under this cross-check.
#[derive(Debug, Clone)]
pub(super) struct EagerReference {
    pub remaining: Vec<f64>,
    pub service: Vec<f64>,
    pub queued: Vec<f64>,
}

//! [`FreeIndex`]: a bucketed free-capacity index over servers, so the
//! placement strategies ([`super::placement`]) iterate only servers that
//! can actually contribute GPUs to a gang — and bail in O(1) when none
//! can — instead of scoring every server per candidate (DESIGN.md §16
//! covers the policy-pass hot path this index serves).
//!
//! Three structures, all maintained incrementally at the same site that
//! updates the per-server free counters (`on_load_change` in the live
//! [`super::Cluster`] and the [`super::ClusterOverlay`] planning view):
//!
//! * **buckets** — `buckets[k]` holds the servers with exactly `k` free
//!   GPUs, each bucket sorted ascending by server index. Consolidated
//!   placement walks `buckets[need]` (exact fits) then the remaining
//!   buckets from `max_free` down — precisely the
//!   [`super::placement::server_score`] order restricted to servers with
//!   free capacity, so the chosen gangs are byte-identical to the former
//!   full sort (memory-ineligible servers sit in the buckets too, but
//!   the shared `take_free` walk skips them exactly as the sort-based
//!   order had them skipped).
//! * **nonempty** — servers with at least one free GPU, ascending: the
//!   first-fit iteration order.
//! * **per-tier free totals** — free GPUs grouped by server GPU-memory
//!   capacity (servers are internally homogeneous), so the eligible-free
//!   sum that gates a placement (`Σ eligible_free < need → None`) is a
//!   walk over the handful of distinct capacities instead of every
//!   server.
//!
//! `PartialEq` + [`FreeIndex::build`] give the invariant check: the
//! incrementally maintained index must equal a from-scratch rebuild
//! ([`super::Cluster::check_invariants`], exercised by the randomized
//! property tests).

use super::topology::Topology;

/// Memory-eligibility slack shared with the placement walk: a server
/// whose per-GPU budget is within this of the requirement qualifies.
pub(super) const MEM_EPS: f64 = 1e-9;

/// Bucketed free-count index over servers. See the module docs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FreeIndex {
    /// `buckets[k]`: servers with exactly `k` free GPUs, ascending.
    /// `buckets[0]` is kept empty — fully busy servers are unindexed.
    buckets: Vec<Vec<usize>>,
    /// Largest `k` with a non-empty bucket (0 when the cluster is full).
    max_free: usize,
    /// Servers with at least one free GPU, ascending.
    nonempty: Vec<usize>,
    /// Distinct per-GPU memory capacities, descending.
    tier_mem: Vec<f64>,
    /// Free-GPU total per capacity tier (same indexing as `tier_mem`).
    tier_free: Vec<usize>,
    /// Capacity tier of each server.
    tier_of: Vec<usize>,
}

impl FreeIndex {
    /// Build from scratch over a topology and its per-server free counts
    /// (construction and the invariant cross-check).
    pub fn build(topology: &Topology, free_per_server: &[usize]) -> Self {
        let n = topology.n_servers();
        debug_assert_eq!(n, free_per_server.len());
        let widest = (0..n).map(|s| topology.server(s).gpus).max().unwrap_or(0);
        let mut tier_mem: Vec<f64> =
            (0..n).map(|s| topology.server(s).gpu.mem_gb).collect();
        tier_mem.sort_by(|a, b| b.total_cmp(a));
        tier_mem.dedup();
        let mut idx = FreeIndex {
            buckets: vec![Vec::new(); widest + 1],
            max_free: 0,
            nonempty: Vec::new(),
            tier_free: vec![0; tier_mem.len()],
            tier_of: (0..n)
                .map(|s| {
                    let mem = topology.server(s).gpu.mem_gb;
                    tier_mem.iter().position(|&m| m == mem).expect("tier exists")
                })
                .collect(),
            tier_mem,
        };
        for (s, &free) in free_per_server.iter().enumerate() {
            let t = idx.tier_of[s];
            idx.tier_free[t] += free;
            if free > 0 {
                idx.buckets[free].push(s);
                idx.nonempty.push(s);
                idx.max_free = idx.max_free.max(free);
            }
        }
        idx
    }

    /// Incremental update: server `s` went from `old` to `new` free GPUs.
    pub fn server_free_changed(&mut self, s: usize, old: usize, new: usize) {
        if old == new {
            return;
        }
        if old > 0 {
            let b = &mut self.buckets[old];
            if let Ok(i) = b.binary_search(&s) {
                b.remove(i);
            }
        }
        if new > 0 {
            let b = &mut self.buckets[new];
            if let Err(i) = b.binary_search(&s) {
                b.insert(i, s);
            }
        }
        if old == 0 {
            if let Err(i) = self.nonempty.binary_search(&s) {
                self.nonempty.insert(i, s);
            }
        } else if new == 0 {
            if let Ok(i) = self.nonempty.binary_search(&s) {
                self.nonempty.remove(i);
            }
        }
        let t = self.tier_of[s];
        self.tier_free[t] -= old;
        self.tier_free[t] += new;
        if new > self.max_free {
            self.max_free = new;
        } else {
            while self.max_free > 0 && self.buckets[self.max_free].is_empty() {
                self.max_free -= 1;
            }
        }
    }

    /// Overwrite from another index, reusing this one's allocations (the
    /// overlay pool resets its scratch index from the live cluster's on
    /// every acquire).
    pub fn copy_from(&mut self, other: &FreeIndex) {
        self.buckets.clone_from(&other.buckets);
        self.max_free = other.max_free;
        self.nonempty.clone_from(&other.nonempty);
        self.tier_mem.clone_from(&other.tier_mem);
        self.tier_free.clone_from(&other.tier_free);
        self.tier_of.clone_from(&other.tier_of);
    }

    /// Largest free count of any server (0 when the cluster is full).
    pub fn max_free(&self) -> usize {
        self.max_free
    }

    /// Servers with exactly `k` free GPUs, ascending. Empty slice for
    /// any `k` beyond the widest server (or `k == 0`).
    pub fn bucket(&self, k: usize) -> &[usize] {
        self.buckets.get(k).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Servers with at least one free GPU, ascending — the first-fit
    /// iteration order.
    pub fn nonempty(&self) -> &[usize] {
        &self.nonempty
    }

    /// Total free GPUs on servers whose per-GPU memory budget holds
    /// `mem_gb`. O(tiers) — the O(1) bail for infeasible placements.
    pub fn eligible_total(&self, mem_gb: f64) -> usize {
        self.tier_mem
            .iter()
            .zip(&self.tier_free)
            .take_while(|(&m, _)| m + MEM_EPS >= mem_gb)
            .map(|(_, &f)| f)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::topology;

    fn uniform() -> Topology {
        Topology::from_config(&crate::cluster::ClusterConfig::physical())
    }

    #[test]
    fn build_indexes_fresh_cluster() {
        let topo = uniform();
        let idx = FreeIndex::build(&topo, &[4, 4, 4, 4]);
        assert_eq!(idx.max_free(), 4);
        assert_eq!(idx.bucket(4), &[0, 1, 2, 3]);
        assert!(idx.bucket(3).is_empty());
        assert!(idx.bucket(99).is_empty());
        assert_eq!(idx.nonempty(), &[0, 1, 2, 3]);
        assert_eq!(idx.eligible_total(11.0), 16);
        assert_eq!(idx.eligible_total(20.0), 0);
    }

    #[test]
    fn incremental_matches_rebuild() {
        let topo = uniform();
        let mut free = [4usize, 4, 4, 4];
        let mut idx = FreeIndex::build(&topo, &free);
        // Drain server 1, partially fill 0 and 3, then refill 1.
        let steps: &[(usize, usize)] = &[(1, 0), (0, 2), (3, 1), (1, 4), (0, 0)];
        for &(s, to) in steps {
            let old = free[s];
            free[s] = to;
            idx.server_free_changed(s, old, to);
            assert_eq!(idx, FreeIndex::build(&topo, &free), "after {s} -> {to}");
        }
        assert_eq!(idx.max_free(), 4);
        assert_eq!(idx.bucket(4), &[1]);
        assert_eq!(idx.bucket(1), &[3]);
        assert_eq!(idx.nonempty(), &[1, 3]);
    }

    #[test]
    fn full_cluster_bails_o1() {
        let topo = uniform();
        let mut idx = FreeIndex::build(&topo, &[0, 0, 0, 0]);
        assert_eq!(idx.max_free(), 0);
        assert!(idx.nonempty().is_empty());
        assert_eq!(idx.eligible_total(0.0), 0);
        idx.server_free_changed(2, 0, 1);
        assert_eq!(idx.max_free(), 1);
        assert_eq!(idx.nonempty(), &[2]);
    }

    #[test]
    fn tiers_gate_by_memory() {
        // hetero-16x4-2tier: servers 0..8 carry 11 GB GPUs, 8..16 carry
        // 22 GB, 4 GPUs each.
        let topo = topology::by_name("hetero-16x4-2tier").unwrap();
        let free: Vec<usize> = vec![4; 16];
        let mut idx = FreeIndex::build(&topo, &free);
        assert_eq!(idx.eligible_total(15.0), 32);
        assert_eq!(idx.eligible_total(11.0), 64);
        assert_eq!(idx.eligible_total(22.1), 0);
        idx.server_free_changed(9, 4, 1);
        assert_eq!(idx.eligible_total(15.0), 29);
        assert_eq!(idx, FreeIndex::build(&topo, &[4, 4, 4, 4, 4, 4, 4, 4, 4, 1, 4, 4, 4, 4, 4, 4]));
    }

    #[test]
    fn copy_from_round_trips() {
        let topo = uniform();
        let mut a = FreeIndex::build(&topo, &[4, 4, 4, 4]);
        let b = FreeIndex::build(&topo, &[0, 2, 4, 1]);
        a.copy_from(&b);
        assert_eq!(a, b);
    }
}

//! Gang placement: pick which physical GPUs a job gets.
//!
//! Alg. 1 line 7 — "select the top-G_k GPUs in G_free to make them as
//! consolidated on the nodes as possible". Consolidation minimizes the
//! number of servers spanned (fewer inter-node all-reduce hops).

use super::{Cluster, GpuId};

/// Choose `need` free GPUs, preferring servers with the most free GPUs so
/// gangs span as few nodes as possible; within a server, lowest index first.
/// Returns `None` if not enough free GPUs exist.
pub fn consolidated_free(cluster: &Cluster, need: usize) -> Option<Vec<GpuId>> {
    let free = cluster.free_gpus();
    if free.len() < need {
        return None;
    }
    // Bucket free GPUs per server.
    let mut per_server: Vec<Vec<GpuId>> = vec![Vec::new(); cluster.config.servers];
    for g in free {
        per_server[cluster.server_of(g)].push(g);
    }
    // Exact fit first: a server whose free count equals `need` avoids
    // fragmenting a bigger block. Then fullest-first.
    let mut order: Vec<usize> = (0..per_server.len()).collect();
    order.sort_by_key(|&s| {
        let n = per_server[s].len();
        let exact = n == need;
        // exact fits first, then descending size, then server index
        (if exact { 0usize } else { 1 }, usize::MAX - n, s)
    });
    let mut out = Vec::with_capacity(need);
    for s in order {
        for &g in &per_server[s] {
            if out.len() == need {
                return Some(out);
            }
            out.push(g);
        }
        if out.len() == need {
            return Some(out);
        }
    }
    if out.len() == need {
        Some(out)
    } else {
        None
    }
}

/// First-fit over free GPUs in index order (the FIFO/Tiresias default and
/// the baseline the consolidation tests compare against).
pub fn first_fit_free(cluster: &Cluster, need: usize) -> Option<Vec<GpuId>> {
    let free = cluster.free_gpus();
    if free.len() < need {
        None
    } else {
        Some(free[..need].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    #[test]
    fn consolidates_on_one_server_when_possible() {
        let mut c = Cluster::new(ClusterConfig::physical());
        // Occupy half of server 0.
        c.allocate(9, &[0, 1]);
        let got = consolidated_free(&c, 4).unwrap();
        assert_eq!(c.servers_spanned(&got), 1, "got {got:?}");
    }

    #[test]
    fn prefers_exact_fit_server() {
        let mut c = Cluster::new(ClusterConfig::physical());
        // Server 0: 2 free; server 1: 4 free. Need 2 -> take server 0's
        // remainder, leaving server 1's block intact for a 4-gang.
        c.allocate(9, &[0, 1]);
        let got = consolidated_free(&c, 2).unwrap();
        assert_eq!(got, vec![2, 3]);
    }

    #[test]
    fn spans_servers_only_when_forced() {
        let mut c = Cluster::new(ClusterConfig::physical());
        c.allocate(9, &[0, 4, 8, 12]); // one GPU taken on every server
        let got = consolidated_free(&c, 6).unwrap();
        assert_eq!(got.len(), 6);
        assert_eq!(c.servers_spanned(&got), 2);
    }

    #[test]
    fn insufficient_returns_none() {
        let mut c = Cluster::new(ClusterConfig::physical());
        for j in 0..8 {
            c.allocate(j, &[2 * j, 2 * j + 1]);
        }
        assert!(consolidated_free(&c, 1).is_none());
        assert!(first_fit_free(&c, 1).is_none());
    }

    #[test]
    fn first_fit_takes_lowest_indices() {
        let mut c = Cluster::new(ClusterConfig::physical());
        c.allocate(9, &[0]);
        assert_eq!(first_fit_free(&c, 3).unwrap(), vec![1, 2, 3]);
    }
}

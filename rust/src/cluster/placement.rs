//! Gang placement: pick which physical GPUs a job gets.
//!
//! Alg. 1 line 7 — "select the top-G_k GPUs in G_free to make them as
//! consolidated on the nodes as possible". Consolidation minimizes the
//! number of servers spanned — and, on a topology with a fast intra-node
//! tier, keeps the gang's all-reduce on the fast links
//! ([`crate::perf::GangSpan`]).
//!
//! Both strategies are generic over [`AllocView`], so they run unchanged
//! against the live [`crate::cluster::Cluster`] and a policy's
//! [`crate::cluster::ClusterOverlay`] plan, and both are assembled by the
//! same server-ordered [`take_free`] walk. Since the free-capacity index
//! ([`crate::cluster::FreeIndex`]) neither strategy visits every server:
//! consolidated walks the index buckets in exactly the [`server_score`]
//! order (exact fits, then fullest-first), first-fit walks the nonempty
//! servers in index order, and both bail O(1) — via the per-memory-tier
//! free totals — when no combination of servers can host `need` GPUs.
//! The `*_mem` variants additionally skip GPUs whose per-type memory
//! budget cannot hold `mem_gb` (a no-op on uniform topologies, where
//! every GPU has the reference budget).

use super::{AllocView, GpuId};

/// The shared span score of a candidate server for hosting (part of) a
/// `need`-GPU gang: exact fits first (a server whose eligible free count
/// equals `need` avoids fragmenting a bigger block), then fullest-first
/// (fewest servers spanned), then server index for determinism. Lower
/// sorts earlier.
pub fn server_score(eligible_free: usize, need: usize, server: usize) -> (usize, usize, usize) {
    (usize::from(eligible_free != need), usize::MAX - eligible_free, server)
}

/// Eligible free GPUs on a server: its free count if the server's GPU
/// type can hold `mem_gb`, else 0 (servers are internally homogeneous).
fn eligible_free<V: AllocView>(view: &V, server: usize, mem_gb: f64) -> usize {
    if view.topology().server(server).gpu.mem_gb + 1e-9 >= mem_gb {
        view.server_free(server)
    } else {
        0
    }
}

/// Shared gang assembly: walk `servers` in the given order, scanning each
/// server's GPU range ascending, taking free GPUs whose memory budget
/// holds `mem_gb`, until `need` are collected.
fn take_free<V: AllocView>(
    view: &V,
    need: usize,
    servers: impl Iterator<Item = usize>,
    mem_gb: f64,
) -> Option<Vec<GpuId>> {
    let mut out = Vec::with_capacity(need);
    if need == 0 {
        return Some(out);
    }
    for s in servers {
        if eligible_free(view, s, mem_gb) == 0 {
            continue;
        }
        for g in view.topology().server_range(s) {
            if view.load(g) == 0 {
                out.push(g);
                if out.len() == need {
                    return Some(out);
                }
            }
        }
    }
    None
}

/// Choose `need` free GPUs, preferring servers with the most free GPUs so
/// gangs span as few nodes as possible; within a server, lowest index first.
/// Returns `None` if not enough free GPUs exist.
pub fn consolidated_free<V: AllocView>(view: &V, need: usize) -> Option<Vec<GpuId>> {
    consolidated_free_mem(view, need, 0.0)
}

/// [`consolidated_free`] restricted to GPUs whose memory budget holds
/// `mem_gb` (the job's solo footprint) — the heterogeneity-safe variant
/// every policy uses for exclusive starts.
pub fn consolidated_free_mem<V: AllocView>(
    view: &V,
    need: usize,
    mem_gb: f64,
) -> Option<Vec<GpuId>> {
    let idx = view.free_index();
    if idx.eligible_total(mem_gb) < need {
        return None;
    }
    // The bucketed walk reproduces the former
    // `sort_by_key(server_score)` order over every server that can
    // contribute: exact-fit servers first (ascending index), then the
    // rest fullest-first. Memory-ineligible servers still sit in the
    // buckets — `take_free` skips them, exactly as the sort had them
    // ranked last and skipped. Fully busy servers are simply absent.
    let order = idx.bucket(need).iter().copied().chain(
        (1..=idx.max_free())
            .rev()
            .filter(|&k| k != need)
            .flat_map(|k| idx.bucket(k).iter().copied()),
    );
    take_free(view, need, order, mem_gb)
}

/// First-fit over free GPUs in index order (the baseline the consolidation
/// tests compare against).
pub fn first_fit_free<V: AllocView>(view: &V, need: usize) -> Option<Vec<GpuId>> {
    first_fit_free_mem(view, need, 0.0)
}

/// [`first_fit_free`] restricted to GPUs whose memory budget holds `mem_gb`.
/// Walks only servers with free GPUs (the index's nonempty list, in
/// server order — the same taken sequence as the full `0..n_servers`
/// walk) and bails O(1) when the eligible total cannot cover `need`.
pub fn first_fit_free_mem<V: AllocView>(
    view: &V,
    need: usize,
    mem_gb: f64,
) -> Option<Vec<GpuId>> {
    let idx = view.free_index();
    if idx.eligible_total(mem_gb) < need {
        return None;
    }
    take_free(view, need, idx.nonempty().iter().copied(), mem_gb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{topology, Cluster, ClusterConfig};

    #[test]
    fn consolidates_on_one_server_when_possible() {
        let mut c = Cluster::new(ClusterConfig::physical());
        // Occupy half of server 0.
        c.allocate(9, &[0, 1]);
        let got = consolidated_free(&c, 4).unwrap();
        assert_eq!(c.servers_spanned(&got), 1, "got {got:?}");
    }

    #[test]
    fn prefers_exact_fit_server() {
        let mut c = Cluster::new(ClusterConfig::physical());
        // Server 0: 2 free; server 1: 4 free. Need 2 -> take server 0's
        // remainder, leaving server 1's block intact for a 4-gang.
        c.allocate(9, &[0, 1]);
        let got = consolidated_free(&c, 2).unwrap();
        assert_eq!(got, vec![2, 3]);
    }

    #[test]
    fn spans_servers_only_when_forced() {
        let mut c = Cluster::new(ClusterConfig::physical());
        c.allocate(9, &[0, 4, 8, 12]); // one GPU taken on every server
        let got = consolidated_free(&c, 6).unwrap();
        assert_eq!(got.len(), 6);
        assert_eq!(c.servers_spanned(&got), 2);
    }

    #[test]
    fn insufficient_returns_none() {
        let mut c = Cluster::new(ClusterConfig::physical());
        for j in 0..8 {
            c.allocate(j, &[2 * j, 2 * j + 1]);
        }
        assert!(consolidated_free(&c, 1).is_none());
        assert!(first_fit_free(&c, 1).is_none());
    }

    #[test]
    fn first_fit_takes_lowest_indices() {
        let mut c = Cluster::new(ClusterConfig::physical());
        c.allocate(9, &[0]);
        assert_eq!(first_fit_free(&c, 3).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn mem_filter_skips_small_gpu_servers() {
        // hetero-16x4-2tier: servers 0..8 carry 11 GB GPUs, 8..16 carry
        // 22 GB. A 15 GB job can only land on the big-memory half.
        let c = Cluster::with_topology(topology::by_name("hetero-16x4-2tier").unwrap());
        let got = consolidated_free_mem(&c, 4, 15.0).unwrap();
        assert!(got.iter().all(|&g| c.mem_gb(g) >= 15.0), "got {got:?}");
        assert_eq!(c.servers_spanned(&got), 1);
        let ff = first_fit_free_mem(&c, 2, 15.0).unwrap();
        assert_eq!(ff, vec![32, 33], "first fit starts at the first 22 GB GPU");
        // Asking for more big GPUs than exist fails even though small
        // ones are free.
        assert!(consolidated_free_mem(&c, 33, 15.0).is_none());
        // With no memory requirement the whole cluster is eligible.
        assert!(consolidated_free_mem(&c, 33, 0.0).is_some());
    }

    #[test]
    fn mem_filter_is_a_noop_on_uniform_topologies() {
        let mut c = Cluster::new(ClusterConfig::physical());
        c.allocate(9, &[0, 1]);
        for need in [1usize, 2, 4, 6] {
            assert_eq!(
                consolidated_free(&c, need),
                consolidated_free_mem(&c, need, 10.9),
                "need {need}"
            );
            assert_eq!(
                first_fit_free(&c, need),
                first_fit_free_mem(&c, need, 10.9),
                "need {need}"
            );
        }
    }

    #[test]
    fn gang_span_reports_topology_tier() {
        let c = Cluster::with_topology(topology::by_name("uniform-16x4-nvlink").unwrap());
        assert_eq!(c.span_of(&[0, 1, 2, 3]).bandwidth_gbps, 100.0);
        assert_eq!(c.span_of(&[0, 4]).bandwidth_gbps, 10.0);
    }
}

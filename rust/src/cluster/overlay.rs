//! Hypothetical-allocation overlay: the planning view policies use
//! instead of deep-copying the cluster.
//!
//! A full-pass policy plans a whole transaction per event: it tentatively
//! places job after job, letting each placement constrain the next. The
//! old way was `ctx.cluster.clone()` — one heap allocation per GPU slot,
//! per policy, per event. A [`ClusterOverlay`] borrows the live
//! [`Cluster`] read-only and records only the deltas (hypothetical gangs
//! and releases), with per-server occupancy counters copied once; its
//! scratch buffers live in an [`OverlayPool`] owned by the scheduling
//! context, so steady-state acquisition allocates nothing at all
//! (`cargo bench --bench sched_overhead`, `plan-view/*`).
//!
//! The overlay implements the same [`AllocView`] the live cluster does,
//! so `placement::*` runs unchanged over either — and produces the same
//! GPU orderings a mutated clone would, which is what keeps the policy
//! refactor byte-identical (pinned by `rust/tests/topology.rs`).

use std::cell::RefCell;

use crate::jobs::JobId;

use super::{AllocView, Cluster, FreeIndex, GpuId, Topology};

/// Reusable scratch buffers of one overlay (cleared between uses).
#[derive(Debug, Default, Clone)]
struct OverlayBufs {
    /// Hypothetically granted jobs per GPU (on top of the base cluster).
    extra: Vec<Vec<JobId>>,
    /// GPUs with a non-empty `extra` entry (for O(touched) cleanup).
    touched: Vec<GpuId>,
    /// Jobs hypothetically released from their base-cluster GPUs, kept
    /// sorted so membership checks on the read path are O(log k).
    released: Vec<JobId>,
    /// Per-server free counts (the only per-server class placement
    /// consults — [`AllocView::server_free`]); the one-job class is
    /// tracked as a cluster-wide total only.
    free_per_server: Vec<usize>,
    /// Bucketed free-capacity index, reset from the live cluster's on
    /// acquire and maintained in lockstep with `free_per_server`.
    free_index: FreeIndex,
}

/// Pool of [`OverlayBufs`], owned by the scheduling context. Cloning a
/// pool yields an empty one (scratch is never shared between contexts).
#[derive(Debug, Default)]
pub struct OverlayPool {
    bufs: RefCell<Vec<OverlayBufs>>,
}

impl Clone for OverlayPool {
    fn clone(&self) -> Self {
        OverlayPool::default()
    }
}

impl OverlayPool {
    /// Borrow `base` into a fresh overlay, reusing pooled scratch buffers
    /// when available.
    pub fn acquire<'a>(&'a self, base: &'a Cluster) -> ClusterOverlay<'a> {
        let mut bufs = self.bufs.borrow_mut().pop().unwrap_or_default();
        bufs.extra.resize(base.total_gpus(), Vec::new());
        let topo = base.topology();
        bufs.free_per_server.clear();
        bufs.free_per_server.extend((0..topo.n_servers()).map(|s| base.server_free(s)));
        bufs.free_index.copy_from(AllocView::free_index(base));
        ClusterOverlay {
            base,
            pool: self,
            bufs,
            free_count: base.free_count(),
            one_job_count: base.one_job_count(),
        }
    }
}

/// A borrowed planning view over a [`Cluster`]: reads fall through to the
/// base state, hypothetical [`ClusterOverlay::allocate`] /
/// [`ClusterOverlay::release`] calls are recorded as deltas. Dropped
/// overlays return their scratch to the pool.
#[derive(Debug)]
pub struct ClusterOverlay<'a> {
    base: &'a Cluster,
    pool: &'a OverlayPool,
    bufs: OverlayBufs,
    free_count: usize,
    one_job_count: usize,
}

impl ClusterOverlay<'_> {
    fn is_released(&self, job: JobId) -> bool {
        self.bufs.released.binary_search(&job).is_ok()
    }

    fn base_load(&self, gpu: GpuId) -> usize {
        let jobs = &self.base.slot(gpu).jobs;
        if self.bufs.released.is_empty() {
            jobs.len()
        } else {
            jobs.iter().filter(|&&j| !self.is_released(j)).count()
        }
    }

    /// Whether `job` holds `gpu` in this view (base or hypothetical).
    pub fn holds(&self, gpu: GpuId, job: JobId) -> bool {
        (self.base.slot(gpu).jobs.contains(&job) && !self.is_released(job))
            || self.bufs.extra[gpu].contains(&job)
    }

    fn on_load_change(&mut self, gpu: GpuId, old: usize, new: usize) {
        let s = self.base.topology().server_of(gpu);
        if old == 0 || new == 0 {
            let prev = self.bufs.free_per_server[s];
            if old == 0 {
                self.bufs.free_per_server[s] -= 1;
                self.free_count -= 1;
            }
            if new == 0 {
                self.bufs.free_per_server[s] += 1;
                self.free_count += 1;
            }
            let cur = self.bufs.free_per_server[s];
            self.bufs.free_index.server_free_changed(s, prev, cur);
        }
        if old == 1 {
            self.one_job_count -= 1;
        }
        if new == 1 {
            self.one_job_count += 1;
        }
    }

    /// Hypothetically grant `gpus` to `job` (same panics as
    /// [`Cluster::allocate`]: the plan must respect the share cap).
    pub fn allocate(&mut self, job: JobId, gpus: &[GpuId]) {
        for &g in gpus {
            let before = self.load(g);
            assert!(
                before < self.base.config.max_share,
                "GPU {g} over-shared in plan: + job {job}"
            );
            assert!(!self.holds(g, job), "job {job} already on GPU {g} in plan");
            if self.bufs.extra[g].is_empty() {
                self.bufs.touched.push(g);
            }
            self.bufs.extra[g].push(job);
            self.on_load_change(g, before, before + 1);
        }
    }

    /// Hypothetically release every GPU held by `job` — base-held gangs
    /// (a planned preemption) and plan-granted ones alike.
    pub fn release(&mut self, job: JobId) {
        let already = self.is_released(job);
        let mut found_base = false;
        for g in 0..self.base.total_gpus() {
            let on_base = !already && self.base.slot(g).jobs.contains(&job);
            let on_extra = self.bufs.extra[g].contains(&job);
            if !(on_base || on_extra) {
                continue;
            }
            let before = self.load(g);
            if on_extra {
                self.bufs.extra[g].retain(|&j| j != job);
            }
            found_base |= on_base;
            // A job never holds the same GPU twice, so the drop is 1.
            self.on_load_change(g, before, before - 1);
        }
        if found_base {
            if let Err(i) = self.bufs.released.binary_search(&job) {
                self.bufs.released.insert(i, job);
            }
        }
    }
}

impl AllocView for ClusterOverlay<'_> {
    fn topology(&self) -> &Topology {
        self.base.topology()
    }

    fn max_share(&self) -> usize {
        self.base.config.max_share
    }

    fn load(&self, gpu: GpuId) -> usize {
        self.base_load(gpu) + self.bufs.extra[gpu].len()
    }

    fn owner(&self, gpu: GpuId) -> Option<JobId> {
        // Base residents first, then plan grants — the same order a
        // mutated clone's slot vector would hold.
        self.base
            .slot(gpu)
            .jobs
            .iter()
            .find(|&&j| !self.is_released(j))
            .copied()
            .or_else(|| self.bufs.extra[gpu].first().copied())
    }

    fn residents(&self, gpu: GpuId) -> Vec<JobId> {
        // Same order a mutated clone would hold: surviving base
        // residents, then plan grants.
        self.base
            .slot(gpu)
            .jobs
            .iter()
            .filter(|&&j| !self.is_released(j))
            .chain(self.bufs.extra[gpu].iter())
            .copied()
            .collect()
    }

    fn free_count(&self) -> usize {
        self.free_count
    }

    fn one_job_count(&self) -> usize {
        self.one_job_count
    }

    fn server_free(&self, server: usize) -> usize {
        self.bufs.free_per_server[server]
    }

    fn free_index(&self) -> &FreeIndex {
        &self.bufs.free_index
    }
}

impl Drop for ClusterOverlay<'_> {
    fn drop(&mut self) {
        for &g in &self.bufs.touched {
            self.bufs.extra[g].clear();
        }
        self.bufs.touched.clear();
        self.bufs.released.clear();
        self.pool.bufs.borrow_mut().push(std::mem::take(&mut self.bufs));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    fn base() -> Cluster {
        let mut c = Cluster::new(ClusterConfig::physical());
        c.allocate(1, &[0, 1, 2, 3]);
        c.allocate(2, &[2, 3]);
        c
    }

    #[test]
    fn overlay_mirrors_base_reads() {
        let c = base();
        let pool = OverlayPool::default();
        let view = pool.acquire(&c);
        assert_eq!(view.free_count(), c.free_count());
        assert_eq!(view.one_job_count(), c.one_job_count());
        assert_eq!(view.free_gpus(), c.free_gpus());
        assert_eq!(view.one_job_gpus(), c.one_job_gpus());
        assert_eq!(view.owner(0), Some(1));
        assert_eq!(view.load(2), 2);
    }

    #[test]
    fn hypothetical_allocate_matches_a_mutated_clone() {
        let c = base();
        let mut clone = c.clone();
        let pool = OverlayPool::default();
        let mut view = pool.acquire(&c);
        for (job, gpus) in [(7usize, vec![4, 5, 0]), (8, vec![4, 6])] {
            clone.allocate(job, &gpus);
            view.allocate(job, &gpus);
        }
        assert_eq!(view.free_gpus(), clone.free_gpus());
        assert_eq!(view.one_job_gpus(), clone.one_job_gpus());
        assert_eq!(view.free_count(), clone.free_count());
        assert_eq!(view.one_job_count(), clone.one_job_count());
        for g in 0..c.total_gpus() {
            assert_eq!(view.load(g), clone.load(g), "gpu {g}");
            assert_eq!(view.owner(g), clone.slot(g).jobs.first().copied(), "gpu {g}");
            assert_eq!(view.residents(g), clone.slot(g).jobs, "gpu {g}");
        }
        // The base cluster is untouched.
        drop(view);
        assert_eq!(c.free_count(), 12);
        c.check_invariants().unwrap();
    }

    #[test]
    fn hypothetical_release_matches_a_mutated_clone() {
        let c = base();
        let mut clone = c.clone();
        let pool = OverlayPool::default();
        let mut view = pool.acquire(&c);
        clone.release(1);
        view.release(1);
        // Also release a job granted inside the plan.
        clone.allocate(9, &[0, 1]);
        view.allocate(9, &[0, 1]);
        clone.release(9);
        view.release(9);
        assert_eq!(view.free_gpus(), clone.free_gpus());
        assert_eq!(view.one_job_gpus(), clone.one_job_gpus());
        for g in 0..c.total_gpus() {
            assert_eq!(view.load(g), clone.load(g), "gpu {g}");
            assert_eq!(view.owner(g), clone.slot(g).jobs.first().copied(), "gpu {g}");
            assert_eq!(view.residents(g), clone.slot(g).jobs, "gpu {g}");
        }
    }

    #[test]
    fn pool_recycles_buffers_clean() {
        let c = base();
        let pool = OverlayPool::default();
        {
            let mut view = pool.acquire(&c);
            view.allocate(42, &[8, 9]);
            view.release(1);
        }
        // Second acquisition must see a pristine view of the base.
        let view = pool.acquire(&c);
        assert_eq!(view.load(8), 0);
        assert_eq!(view.owner(0), Some(1));
        assert_eq!(view.free_count(), c.free_count());
        assert_eq!(view.one_job_count(), c.one_job_count());
    }

    #[test]
    #[should_panic(expected = "over-shared in plan")]
    fn plan_respects_share_cap() {
        let c = base();
        let pool = OverlayPool::default();
        let mut view = pool.acquire(&c);
        view.allocate(7, &[2]); // GPU 2 already holds jobs 1 and 2
    }
}

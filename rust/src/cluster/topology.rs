//! Cluster topology: which servers exist, what GPUs they carry, and how
//! they are linked.
//!
//! The paper models `|S|` identical servers behind a sufficient-bandwidth
//! switch, so its Eq. 2/4 comm cost ignores where a gang actually lands.
//! Real multi-tenant clusters are neither flat nor homogeneous (Jeon et
//! al.; Gao & Hu et al.): locality and GPU generation dominate JCT. A
//! [`Topology`] describes servers with a per-server [`GpuType`] (memory +
//! compute scale) and two [`LinkTier`]s — intra-node and inter-node — and
//! derives a [`GangSpan`] from any concrete placement, which the perf
//! layer turns into locality-true Eq. 2/4/7 times.
//!
//! **Uniform-topology equivalence guarantee**: a topology built by
//! [`Topology::from_config`] / [`Topology::uniform`] uses the reference
//! GPU (11 GB, scale 1.0) and the reference link on *both* tiers, so every
//! span it produces reproduces the paper's placement-agnostic arithmetic
//! bit-for-bit — simulations over such a topology are byte-identical to
//! the pre-topology model (pinned by `rust/tests/topology.rs`).

use crate::perf::GangSpan;

use super::{ClusterConfig, GpuId};

/// One link class: all links of a tier share bandwidth and latency.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkTier {
    pub bandwidth_gbps: f64,
    /// Per-hop latency, seconds.
    pub latency_s: f64,
}

impl LinkTier {
    /// The paper's baseline link: the 10 Gbps NIC the Eq. 4 coefficients
    /// are calibrated on, with no modelled hop latency.
    pub fn reference() -> LinkTier {
        LinkTier { bandwidth_gbps: GangSpan::REF_BANDWIDTH_GBPS, latency_s: 0.0 }
    }
}

/// GPU hardware class of one server (servers are internally homogeneous).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuType {
    /// Device memory budget, GB (Eq. 9's per-GPU capacity).
    pub mem_gb: f64,
    /// Compute speed relative to the reference GPU the Eq. 3 coefficients
    /// were calibrated on (2080 Ti): 1.0 = reference, 2.0 = twice as fast.
    pub compute_scale: f64,
}

impl GpuType {
    /// The paper's testbed GPU: 2080 Ti, 11 GB, the calibration baseline.
    pub fn reference() -> GpuType {
        GpuType { mem_gb: 11.0, compute_scale: 1.0 }
    }
}

/// One server: a GPU count and the type all its GPUs share.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServerSpec {
    pub gpus: usize,
    pub gpu: GpuType,
}

/// The full cluster shape. GPU ids are flat and dense: server `s` owns the
/// contiguous range [`Topology::server_range`], in server order.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    servers: Vec<ServerSpec>,
    /// Links between GPUs of the same server (NVLink/PCIe class).
    pub intra: LinkTier,
    /// Links between servers (NIC/switch class).
    pub inter: LinkTier,
    /// Max co-located jobs per GPU (paper: C = 2).
    pub max_share: usize,
    /// `offsets[s]` = first GPU id of server `s`; last entry = total GPUs.
    offsets: Vec<usize>,
}

/// Named topology shapes usable on the campaign `topologies` axis and the
/// CLI `--topology` flag. `uniform-*` shapes keep the paper's flat model;
/// the `hetero-*` shape mixes GPU generations and link tiers.
pub const SHAPE_NAMES: [&str; 4] =
    ["uniform-4x4", "uniform-16x4", "uniform-16x4-nvlink", "hetero-16x4-2tier"];

/// [`by_name`] as a `Result`, with the one canonical unknown-shape error
/// (listing the known shapes) shared by every call site — CLI flag,
/// campaign validation and scenario construction alike.
pub fn by_name_or_err(name: &str) -> anyhow::Result<Topology> {
    by_name(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown topology shape {name:?} (known: {})",
            SHAPE_NAMES.join(", ")
        )
    })
}

/// Resolve a named shape (see [`SHAPE_NAMES`]).
pub fn by_name(name: &str) -> Option<Topology> {
    Some(match name {
        // The paper's 4-server physical testbed.
        "uniform-4x4" => Topology::from_config(&ClusterConfig::physical()),
        // The paper's 16-server simulation cluster.
        "uniform-16x4" => Topology::from_config(&ClusterConfig::simulation()),
        // Same shape, but consolidation pays: NVLink-class intra-node
        // links, reference 10 Gbps between nodes.
        "uniform-16x4-nvlink" => {
            let mut t = Topology::from_config(&ClusterConfig::simulation());
            t.intra = LinkTier { bandwidth_gbps: 100.0, latency_s: 0.0 };
            t
        }
        // Two generations: 8 reference servers plus 8 newer servers with
        // twice the memory and 1.6x compute, NVLink intra, 10 Gbps inter
        // with a modelled 20 µs hop latency.
        "hetero-16x4-2tier" => Topology::new(
            (0..16)
                .map(|s| ServerSpec {
                    gpus: 4,
                    gpu: if s < 8 {
                        GpuType::reference()
                    } else {
                        GpuType { mem_gb: 22.0, compute_scale: 1.6 }
                    },
                })
                .collect(),
            LinkTier { bandwidth_gbps: 100.0, latency_s: 0.0 },
            LinkTier { bandwidth_gbps: 10.0, latency_s: 20e-6 },
            2,
        ),
        _ => return None,
    })
}

impl Topology {
    pub fn new(
        servers: Vec<ServerSpec>,
        intra: LinkTier,
        inter: LinkTier,
        max_share: usize,
    ) -> Topology {
        assert!(!servers.is_empty(), "topology needs at least one server");
        assert!(
            servers.iter().all(|s| s.gpus >= 1),
            "every server must carry at least one GPU"
        );
        assert!(
            servers.iter().all(|s| s.gpu.compute_scale > 0.0 && s.gpu.mem_gb > 0.0),
            "GPU compute scale and memory must be positive"
        );
        assert!(
            intra.bandwidth_gbps > 0.0 && inter.bandwidth_gbps > 0.0,
            "link bandwidth must be positive"
        );
        assert!(max_share >= 1, "share cap must be at least 1");
        let mut offsets = Vec::with_capacity(servers.len() + 1);
        let mut total = 0;
        for s in &servers {
            offsets.push(total);
            total += s.gpus;
        }
        offsets.push(total);
        Topology { servers, intra, inter, max_share, offsets }
    }

    /// A flat cluster of identical reference-linked servers — the paper's
    /// model, as a (degenerate) topology.
    pub fn uniform(servers: usize, gpus_per_server: usize, mem_gb: f64) -> Topology {
        Topology::new(
            vec![
                ServerSpec {
                    gpus: gpus_per_server,
                    gpu: GpuType { mem_gb, compute_scale: 1.0 },
                };
                servers
            ],
            LinkTier::reference(),
            LinkTier::reference(),
            2,
        )
    }

    /// The uniform topology a flat [`ClusterConfig`] describes. Goes
    /// through [`Topology::new`] so the construction invariants (positive
    /// shapes, `max_share >= 1`) hold on this path too.
    pub fn from_config(cfg: &ClusterConfig) -> Topology {
        Topology::new(
            vec![
                ServerSpec {
                    gpus: cfg.gpus_per_server,
                    gpu: GpuType { mem_gb: cfg.gpu_mem_gb, compute_scale: 1.0 },
                };
                cfg.servers
            ],
            LinkTier::reference(),
            LinkTier::reference(),
            cfg.max_share,
        )
    }

    /// Flat summary of this topology for call sites that still speak
    /// [`ClusterConfig`]: exact for uniform topologies; for heterogeneous
    /// ones `gpus_per_server` is the widest server and `gpu_mem_gb` the
    /// *smallest* (most conservative) GPU.
    pub fn summary_config(&self) -> ClusterConfig {
        ClusterConfig {
            servers: self.servers.len(),
            gpus_per_server: self.servers.iter().map(|s| s.gpus).max().unwrap_or(0),
            gpu_mem_gb: self
                .servers
                .iter()
                .map(|s| s.gpu.mem_gb)
                .fold(f64::INFINITY, f64::min),
            max_share: self.max_share,
        }
    }

    pub fn n_servers(&self) -> usize {
        self.servers.len()
    }

    pub fn total_gpus(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    pub fn server(&self, s: usize) -> &ServerSpec {
        &self.servers[s]
    }

    /// The contiguous GPU-id range of server `s`.
    pub fn server_range(&self, s: usize) -> std::ops::Range<GpuId> {
        self.offsets[s]..self.offsets[s + 1]
    }

    /// Which server a GPU lives on. O(log servers); exact for ragged
    /// per-server GPU counts (unlike the old `gpu / gpus_per_server`).
    pub fn server_of(&self, gpu: GpuId) -> usize {
        debug_assert!(gpu < self.total_gpus(), "GPU {gpu} out of range");
        match self.offsets.binary_search(&gpu) {
            Ok(s) => s,
            Err(i) => i - 1,
        }
    }

    /// Memory budget of one GPU, GB.
    pub fn mem_gb(&self, gpu: GpuId) -> f64 {
        self.servers[self.server_of(gpu)].gpu.mem_gb
    }

    /// Compute scale of one GPU.
    pub fn compute_scale(&self, gpu: GpuId) -> f64 {
        self.servers[self.server_of(gpu)].gpu.compute_scale
    }

    /// Derive the [`GangSpan`] of a concrete placement: distinct servers
    /// spanned, the bottleneck link tier (inter-node as soon as more than
    /// one server is involved), and the slowest member GPU's compute
    /// scale. An empty set yields the reference span.
    pub fn span_of(&self, gpus: &[GpuId]) -> GangSpan {
        if gpus.is_empty() {
            return GangSpan::reference();
        }
        let mut nodes: Vec<usize> = gpus.iter().map(|&g| self.server_of(g)).collect();
        nodes.sort_unstable();
        nodes.dedup();
        let tier = if nodes.len() > 1 { &self.inter } else { &self.intra };
        let compute_scale = nodes
            .iter()
            .map(|&s| self.servers[s].gpu.compute_scale)
            .fold(f64::INFINITY, f64::min);
        GangSpan {
            nodes: nodes.len(),
            bandwidth_gbps: tier.bandwidth_gbps,
            latency_s: tier.latency_s,
            compute_scale,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_matches_config_summary_exactly() {
        let cfg = ClusterConfig::simulation();
        let t = Topology::from_config(&cfg);
        assert_eq!(t.n_servers(), 16);
        assert_eq!(t.total_gpus(), 64);
        let back = t.summary_config();
        assert_eq!(back.servers, cfg.servers);
        assert_eq!(back.gpus_per_server, cfg.gpus_per_server);
        assert_eq!(back.gpu_mem_gb, cfg.gpu_mem_gb);
        assert_eq!(back.max_share, cfg.max_share);
    }

    #[test]
    fn uniform_span_is_reference_on_both_tiers() {
        let t = Topology::from_config(&ClusterConfig::physical());
        for gpus in [vec![0, 1, 2, 3], vec![0, 4, 8, 12], vec![3, 4]] {
            let span = t.span_of(&gpus);
            assert_eq!(span.bandwidth_gbps, GangSpan::REF_BANDWIDTH_GBPS);
            assert_eq!(span.latency_s, 0.0);
            assert_eq!(span.compute_scale, 1.0);
        }
        assert_eq!(t.span_of(&[0, 1, 2, 3]).nodes, 1);
        assert_eq!(t.span_of(&[0, 4, 8, 12]).nodes, 4);
        assert_eq!(t.span_of(&[]).nodes, 1);
    }

    #[test]
    fn server_of_handles_ragged_servers() {
        let t = Topology::new(
            vec![
                ServerSpec { gpus: 2, gpu: GpuType::reference() },
                ServerSpec { gpus: 5, gpu: GpuType::reference() },
                ServerSpec { gpus: 1, gpu: GpuType::reference() },
            ],
            LinkTier::reference(),
            LinkTier::reference(),
            2,
        );
        assert_eq!(t.total_gpus(), 8);
        let servers: Vec<usize> = (0..8).map(|g| t.server_of(g)).collect();
        assert_eq!(servers, vec![0, 0, 1, 1, 1, 1, 1, 2]);
        assert_eq!(t.server_range(1), 2..7);
    }

    #[test]
    fn hetero_shape_mixes_tiers_and_types() {
        let t = by_name("hetero-16x4-2tier").unwrap();
        assert_eq!(t.total_gpus(), 64);
        assert_eq!(t.mem_gb(0), 11.0);
        assert_eq!(t.mem_gb(63), 22.0);
        // Single fast-tier node: NVLink intra, min compute scale 1.6.
        let fast = t.span_of(&[32, 33, 34, 35]);
        assert_eq!(fast.nodes, 1);
        assert_eq!(fast.bandwidth_gbps, 100.0);
        assert_eq!(fast.compute_scale, 1.6);
        // Crossing generations: inter tier, slowest GPU wins.
        let mixed = t.span_of(&[0, 32]);
        assert_eq!(mixed.nodes, 2);
        assert_eq!(mixed.bandwidth_gbps, 10.0);
        assert_eq!(mixed.latency_s, 20e-6);
        assert_eq!(mixed.compute_scale, 1.0);
    }

    #[test]
    fn every_named_shape_resolves() {
        for name in SHAPE_NAMES {
            let t = by_name(name).unwrap_or_else(|| panic!("missing shape {name}"));
            assert!(t.total_gpus() >= 16, "{name} too small for a 16-gang");
        }
        assert!(by_name("bogus").is_none());
    }
}

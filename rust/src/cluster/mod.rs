//! Multi-tenant GPU cluster substrate (paper §IV): servers of GPUs on a
//! [`topology::Topology`] — per-server GPU type (memory + compute scale)
//! and two link tiers. The paper's own model (`|S|` identical servers
//! behind a sufficient-bandwidth switch) is the uniform special case a
//! flat [`ClusterConfig`] constructs. A GPU may hold at most `C` jobs
//! (Eq. 9; the paper evaluates C = 2, and `max_share` keeps that
//! default, but the cap is configurable — k-way sharing sets with
//! C ∈ {3, 4} are DESIGN.md §17). Gang allocation/release is atomic
//! (Eqs. 8, 10–12).
//!
//! Occupancy classes (free / one-job / schedulable) are maintained
//! incrementally per server on every allocate/release, so policy passes
//! read them in O(1) instead of rescanning every slot; [`AllocView`] is
//! the read interface shared by the live [`Cluster`] and the hypothetical
//! [`overlay::ClusterOverlay`] planning view.

pub mod free_index;
pub mod overlay;
pub mod placement;
pub mod topology;

pub use free_index::FreeIndex;
pub use overlay::ClusterOverlay;
pub use topology::Topology;

use crate::jobs::JobId;
use crate::perf::GangSpan;

/// Flat GPU identifier: dense over servers in topology order.
pub type GpuId = usize;

/// Flat (uniform) cluster shape + per-GPU capacities. Still the common
/// currency of call sites that sweep cluster *sizes*; a richer shape is a
/// [`topology::Topology`].
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    pub servers: usize,
    pub gpus_per_server: usize,
    /// GPU memory budget, GB (2080 Ti = 11 GB in the paper's testbed).
    pub gpu_mem_gb: f64,
    /// Max co-located jobs per GPU (paper: C = 2).
    pub max_share: usize,
}

impl ClusterConfig {
    /// The paper's physical testbed: 4 servers × 4 GPUs.
    pub fn physical() -> Self {
        ClusterConfig { servers: 4, gpus_per_server: 4, gpu_mem_gb: 11.0, max_share: 2 }
    }

    /// The paper's simulation cluster: 16 servers × 4 GPUs.
    pub fn simulation() -> Self {
        ClusterConfig { servers: 16, gpus_per_server: 4, gpu_mem_gb: 11.0, max_share: 2 }
    }

    pub fn total_gpus(&self) -> usize {
        self.servers * self.gpus_per_server
    }
}

/// One GPU's live occupancy.
#[derive(Debug, Clone, Default)]
pub struct GpuSlot {
    /// Jobs currently holding this GPU (len ≤ max_share).
    pub jobs: Vec<JobId>,
}

/// Read-only occupancy view shared by the live [`Cluster`] and the
/// hypothetical [`overlay::ClusterOverlay`]: placement strategies
/// ([`placement`]) are generic over it, so policies plan against an
/// overlay with exactly the code that also queries the real cluster.
pub trait AllocView {
    fn topology(&self) -> &Topology;
    /// Max co-located jobs per GPU (Eq. 9's C).
    fn max_share(&self) -> usize;
    /// Occupancy count of one GPU.
    fn load(&self, gpu: GpuId) -> usize;
    /// First job on a GPU, if any — the sharing-partner lookup for
    /// one-job GPUs (`G_OJ`, Alg. 1 line 5).
    fn owner(&self, gpu: GpuId) -> Option<JobId>;
    /// Every job on a GPU, in slot order (base residents before plan
    /// grants on an overlay — the order a mutated clone's slot vector
    /// would hold). The k-way sharing-set lookup (DESIGN.md §17); with
    /// C = 2 a shareable GPU has exactly one resident and this is
    /// `owner` as a one-element vector.
    fn residents(&self, gpu: GpuId) -> Vec<JobId>;
    /// Total GPUs holding no job. O(1).
    fn free_count(&self) -> usize;
    /// Total GPUs holding exactly one job. O(1).
    fn one_job_count(&self) -> usize;
    /// Free GPUs on one server. O(1).
    fn server_free(&self, server: usize) -> usize;
    /// The bucketed free-capacity index ([`free_index`]) — servers
    /// grouped by free count, plus per-memory-tier free totals — that
    /// lets [`placement`] iterate only servers able to host a gang and
    /// bail O(1) when none can. Maintained incrementally alongside the
    /// per-server free counters.
    fn free_index(&self) -> &FreeIndex;

    fn total_gpus(&self) -> usize {
        self.topology().total_gpus()
    }

    fn server_of(&self, gpu: GpuId) -> usize {
        self.topology().server_of(gpu)
    }

    /// Memory budget of one GPU, GB (per-type under heterogeneity).
    fn mem_gb(&self, gpu: GpuId) -> f64 {
        self.topology().mem_gb(gpu)
    }

    /// Placement summary of a GPU set (see [`Topology::span_of`]).
    fn span_of(&self, gpus: &[GpuId]) -> GangSpan {
        self.topology().span_of(gpus)
    }

    /// GPUs holding no job, ordered by (server, index) — placement picks
    /// prefixes of this to consolidate gangs (Alg. 1 line 7).
    fn free_gpus(&self) -> Vec<GpuId> {
        (0..self.total_gpus()).filter(|&g| self.load(g) == 0).collect()
    }

    /// GPUs holding exactly one job — the sharing candidates `G_OJ`
    /// (Alg. 1 line 5).
    fn one_job_gpus(&self) -> Vec<GpuId> {
        (0..self.total_gpus()).filter(|&g| self.load(g) == 1).collect()
    }

    /// GPUs holding at least one job but with a free share slot — the
    /// k-way sharing candidates (DESIGN.md §17). With C = 2 only
    /// load-1 GPUs qualify, so this is exactly
    /// [`AllocView::one_job_gpus`], in the same order.
    fn shareable_gpus(&self) -> Vec<GpuId> {
        let cap = self.max_share();
        (0..self.total_gpus())
            .filter(|&g| {
                let load = self.load(g);
                load >= 1 && load < cap
            })
            .collect()
    }
}

/// Live cluster state: who holds which GPU.
#[derive(Debug, Clone)]
pub struct Cluster {
    /// Flat summary shape. Exact for uniform topologies; conservative
    /// (widest server, smallest GPU) for heterogeneous ones.
    pub config: ClusterConfig,
    topology: Topology,
    slots: Vec<GpuSlot>,
    // Incrementally maintained occupancy classes (checked against a
    // from-scratch rescan by `check_invariants` and the property tests).
    free_per_server: Vec<usize>,
    one_job_per_server: Vec<usize>,
    n_free: usize,
    n_one_job: usize,
    n_schedulable: usize,
    /// Bucketed free-capacity index, updated in lockstep with
    /// `free_per_server` (see [`free_index`]).
    free_index: FreeIndex,
}

impl Cluster {
    /// A uniform cluster — the paper's model, byte-compatible with the
    /// pre-topology behavior.
    pub fn new(config: ClusterConfig) -> Self {
        let mut cluster = Self::with_topology(Topology::from_config(&config));
        cluster.config = config; // keep the caller's exact summary
        cluster
    }

    /// A cluster over an arbitrary (possibly heterogeneous) topology.
    pub fn with_topology(topology: Topology) -> Self {
        let config = topology.summary_config();
        let total = topology.total_gpus();
        let free_per_server: Vec<usize> =
            (0..topology.n_servers()).map(|s| topology.server(s).gpus).collect();
        let free_index = FreeIndex::build(&topology, &free_per_server);
        Cluster {
            config,
            slots: vec![GpuSlot::default(); total],
            free_per_server,
            one_job_per_server: vec![0; topology.n_servers()],
            n_free: total,
            n_one_job: 0,
            n_schedulable: total,
            free_index,
            topology,
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topology
    }

    /// Override the share cap C (Eq. 9) — the k-way sharing knob
    /// (DESIGN.md §17, `simulate --max-share`, campaign `share_caps`
    /// axis). Works on any occupancy state: the schedulable count is
    /// recomputed against the new cap.
    pub fn set_max_share(&mut self, cap: usize) {
        assert!(cap >= 1, "share cap C must be >= 1");
        self.config.max_share = cap;
        self.n_schedulable = self.slots.iter().filter(|s| s.jobs.len() < cap).count();
    }

    /// Builder form of [`Cluster::set_max_share`].
    pub fn with_max_share(mut self, cap: usize) -> Self {
        self.set_max_share(cap);
        self
    }

    pub fn server_of(&self, gpu: GpuId) -> usize {
        self.topology.server_of(gpu)
    }

    pub fn slot(&self, gpu: GpuId) -> &GpuSlot {
        &self.slots[gpu]
    }

    pub fn total_gpus(&self) -> usize {
        self.slots.len()
    }

    /// GPUs holding no job, ordered by (server, index). Delegates to the
    /// [`AllocView`] default so the class definition lives in one place.
    pub fn free_gpus(&self) -> Vec<GpuId> {
        AllocView::free_gpus(self)
    }

    /// GPUs holding exactly one job (`G_OJ`, Alg. 1 line 5). Delegates to
    /// the [`AllocView`] default.
    pub fn one_job_gpus(&self) -> Vec<GpuId> {
        AllocView::one_job_gpus(self)
    }

    /// Count of free GPUs — maintained incrementally, O(1).
    pub fn free_count(&self) -> usize {
        self.n_free
    }

    /// Count of one-job GPUs — maintained incrementally, O(1).
    pub fn one_job_count(&self) -> usize {
        self.n_one_job
    }

    /// Free GPUs on one server — maintained incrementally, O(1).
    pub fn server_free(&self, server: usize) -> usize {
        self.free_per_server[server]
    }

    /// One-job GPUs on one server — maintained incrementally, O(1).
    pub fn server_one_job(&self, server: usize) -> usize {
        self.one_job_per_server[server]
    }

    /// Memory budget of one GPU, GB.
    pub fn mem_gb(&self, gpu: GpuId) -> f64 {
        self.topology.mem_gb(gpu)
    }

    /// Placement summary of a GPU set (see [`Topology::span_of`]).
    pub fn span_of(&self, gpus: &[GpuId]) -> GangSpan {
        self.topology.span_of(gpus)
    }

    /// Occupancy count per GPU.
    pub fn load(&self, gpu: GpuId) -> usize {
        self.slots[gpu].jobs.len()
    }

    /// Number of GPUs with at least one free share slot — maintained
    /// incrementally, O(1).
    pub fn schedulable_gpus(&self) -> usize {
        self.n_schedulable
    }

    fn on_load_change(&mut self, gpu: GpuId, old: usize, new: usize) {
        let s = self.topology.server_of(gpu);
        if old == 0 || new == 0 {
            let prev = self.free_per_server[s];
            if old == 0 {
                self.free_per_server[s] -= 1;
                self.n_free -= 1;
            }
            if new == 0 {
                self.free_per_server[s] += 1;
                self.n_free += 1;
            }
            self.free_index.server_free_changed(s, prev, self.free_per_server[s]);
        }
        if old == 1 {
            self.one_job_per_server[s] -= 1;
            self.n_one_job -= 1;
        }
        if new == 1 {
            self.one_job_per_server[s] += 1;
            self.n_one_job += 1;
        }
        let cap = self.config.max_share;
        if old >= cap && new < cap {
            self.n_schedulable += 1;
        }
        if old < cap && new >= cap {
            self.n_schedulable -= 1;
        }
    }

    /// Atomically grant `gpus` to `job` (gang allocation). Panics on a slot
    /// overflow — callers must have validated share capacity (Eq. 9).
    pub fn allocate(&mut self, job: JobId, gpus: &[GpuId]) {
        for &g in gpus {
            let before = self.slots[g].jobs.len();
            assert!(
                before < self.config.max_share,
                "GPU {g} over-shared: {:?} + job {job}",
                self.slots[g].jobs
            );
            assert!(!self.slots[g].jobs.contains(&job), "job {job} already on GPU {g}");
            self.slots[g].jobs.push(job);
            self.on_load_change(g, before, before + 1);
        }
    }

    /// Atomically release every GPU held by `job` (gang release).
    pub fn release(&mut self, job: JobId) {
        for g in 0..self.slots.len() {
            let before = self.slots[g].jobs.len();
            self.slots[g].jobs.retain(|&j| j != job);
            let after = self.slots[g].jobs.len();
            if after != before {
                self.on_load_change(g, before, after);
            }
        }
    }

    /// All jobs co-located with `job` anywhere on its gang.
    pub fn co_runners(&self, job: JobId) -> Vec<JobId> {
        let mut out: Vec<JobId> = self
            .slots
            .iter()
            .filter(|s| s.jobs.contains(&job))
            .flat_map(|s| s.jobs.iter().copied())
            .filter(|&j| j != job)
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// GPUs held by `job`.
    pub fn gpus_of(&self, job: JobId) -> Vec<GpuId> {
        (0..self.slots.len()).filter(|&g| self.slots[g].jobs.contains(&job)).collect()
    }

    /// Distinct servers spanned by a GPU set (`S(J_k)` in Table I).
    pub fn servers_spanned(&self, gpus: &[GpuId]) -> usize {
        let mut servers: Vec<usize> = gpus.iter().map(|&g| self.server_of(g)).collect();
        servers.sort_unstable();
        servers.dedup();
        servers.len()
    }

    /// Invariant check used by property tests: no slot over capacity, no
    /// duplicate job entries on a slot, and every incrementally maintained
    /// occupancy count agreeing with a from-scratch rescan.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (g, slot) in self.slots.iter().enumerate() {
            if slot.jobs.len() > self.config.max_share {
                return Err(format!("GPU {g} holds {} jobs", slot.jobs.len()));
            }
            let mut uniq = slot.jobs.clone();
            uniq.sort_unstable();
            uniq.dedup();
            if uniq.len() != slot.jobs.len() {
                return Err(format!("GPU {g} duplicate job entries"));
            }
        }
        let free = self.free_gpus();
        let one_job = self.one_job_gpus();
        if free.len() != self.n_free {
            return Err(format!("free count {} != rescan {}", self.n_free, free.len()));
        }
        if one_job.len() != self.n_one_job {
            return Err(format!(
                "one-job count {} != rescan {}",
                self.n_one_job,
                one_job.len()
            ));
        }
        if free.iter().any(|g| one_job.contains(g)) {
            return Err("free and one-job sets overlap".to_string());
        }
        let schedulable = self
            .slots
            .iter()
            .filter(|s| s.jobs.len() < self.config.max_share)
            .count();
        if schedulable != self.n_schedulable {
            return Err(format!(
                "schedulable count {} != rescan {schedulable}",
                self.n_schedulable
            ));
        }
        for s in 0..self.topology.n_servers() {
            let range = self.topology.server_range(s);
            let f = free.iter().filter(|&&g| range.contains(&g)).count();
            let o = one_job.iter().filter(|&&g| range.contains(&g)).count();
            if f != self.free_per_server[s] || o != self.one_job_per_server[s] {
                return Err(format!(
                    "server {s} counts (free {}, one-job {}) != rescan ({f}, {o})",
                    self.free_per_server[s], self.one_job_per_server[s]
                ));
            }
        }
        let rebuilt = FreeIndex::build(&self.topology, &self.free_per_server);
        if self.free_index != rebuilt {
            return Err(format!(
                "free index {:?} != rebuild {rebuilt:?}",
                self.free_index
            ));
        }
        Ok(())
    }
}

impl AllocView for Cluster {
    fn topology(&self) -> &Topology {
        &self.topology
    }

    fn max_share(&self) -> usize {
        self.config.max_share
    }

    fn load(&self, gpu: GpuId) -> usize {
        self.slots[gpu].jobs.len()
    }

    fn owner(&self, gpu: GpuId) -> Option<JobId> {
        self.slots[gpu].jobs.first().copied()
    }

    fn residents(&self, gpu: GpuId) -> Vec<JobId> {
        self.slots[gpu].jobs.clone()
    }

    fn free_count(&self) -> usize {
        self.n_free
    }

    fn one_job_count(&self) -> usize {
        self.n_one_job
    }

    fn server_free(&self, server: usize) -> usize {
        self.free_per_server[server]
    }

    fn free_index(&self) -> &FreeIndex {
        &self.free_index
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::physical())
    }

    #[test]
    fn fresh_cluster_all_free() {
        let c = cluster();
        assert_eq!(c.free_gpus().len(), 16);
        assert_eq!(c.free_count(), 16);
        assert_eq!(c.one_job_gpus().len(), 0);
        assert_eq!(c.one_job_count(), 0);
        assert_eq!(c.schedulable_gpus(), 16);
    }

    #[test]
    fn allocate_release_roundtrip() {
        let mut c = cluster();
        c.allocate(7, &[0, 1, 2, 3]);
        assert_eq!(c.free_gpus().len(), 12);
        assert_eq!(c.free_count(), 12);
        assert_eq!(c.one_job_gpus(), vec![0, 1, 2, 3]);
        assert_eq!(c.one_job_count(), 4);
        assert_eq!(c.server_free(0), 0);
        assert_eq!(c.server_one_job(0), 4);
        assert_eq!(c.gpus_of(7), vec![0, 1, 2, 3]);
        c.release(7);
        assert_eq!(c.free_gpus().len(), 16);
        assert_eq!(c.free_count(), 16);
        c.check_invariants().unwrap();
    }

    #[test]
    fn sharing_two_jobs_per_gpu() {
        let mut c = cluster();
        c.allocate(1, &[0, 1]);
        c.allocate(2, &[0, 1]);
        assert_eq!(c.load(0), 2);
        assert_eq!(c.co_runners(1), vec![2]);
        assert_eq!(c.co_runners(2), vec![1]);
        assert!(c.one_job_gpus().is_empty());
        assert_eq!(c.one_job_count(), 0);
        assert_eq!(c.schedulable_gpus(), 14);
        c.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "over-shared")]
    fn c2_cap_enforced() {
        let mut c = cluster();
        c.allocate(1, &[0]);
        c.allocate(2, &[0]);
        c.allocate(3, &[0]); // Eq. 9 violation with C = 2
    }

    #[test]
    #[should_panic(expected = "already on")]
    fn no_duplicate_grant() {
        let mut c = cluster();
        c.allocate(1, &[0]);
        c.allocate(1, &[0]);
    }

    #[test]
    fn servers_spanned_counts_distinct() {
        let c = cluster();
        assert_eq!(c.servers_spanned(&[0, 1, 2, 3]), 1);
        assert_eq!(c.servers_spanned(&[0, 4, 8, 12]), 4);
        assert_eq!(c.servers_spanned(&[3, 4]), 2);
    }

    #[test]
    fn partial_share_overlap() {
        // Job 2 shares only part of job 1's gang (paper allows partial
        // sharing: "fully or partially share the same set of GPUs").
        let mut c = cluster();
        c.allocate(1, &[0, 1, 2, 3]);
        c.allocate(2, &[2, 3, 4, 5]);
        assert_eq!(c.co_runners(1), vec![2]);
        assert_eq!(c.one_job_gpus(), vec![0, 1, 4, 5]);
        assert_eq!(c.one_job_count(), 4);
        c.check_invariants().unwrap();
    }

    #[test]
    fn raised_share_cap_admits_k_way_sets() {
        let mut c = cluster().with_max_share(3);
        c.allocate(1, &[0]);
        c.allocate(2, &[0]);
        c.allocate(3, &[0]); // third resident is legal at C = 3
        assert_eq!(c.load(0), 3);
        assert_eq!(c.residents(0), vec![1, 2, 3]);
        // GPU 0 is full; the free GPUs hold no job, so nothing is shareable.
        assert!(c.shareable_gpus().is_empty());
        c.allocate(4, &[1]);
        c.allocate(5, &[1]);
        assert_eq!(c.shareable_gpus(), vec![1]); // 2 residents < C = 3
        c.check_invariants().unwrap();
    }

    #[test]
    fn set_max_share_recomputes_schedulable() {
        let mut c = cluster();
        c.allocate(1, &[0]);
        c.allocate(2, &[0]); // GPU 0 full at C = 2
        assert_eq!(c.schedulable_gpus(), 15);
        c.set_max_share(3);
        assert_eq!(c.schedulable_gpus(), 16);
        c.check_invariants().unwrap();
        c.set_max_share(2);
        assert_eq!(c.schedulable_gpus(), 15);
        c.check_invariants().unwrap();
    }

    #[test]
    fn shareable_matches_one_job_gpus_at_c2() {
        let mut c = cluster();
        c.allocate(1, &[0, 1, 2, 3]);
        c.allocate(2, &[2, 3]);
        assert_eq!(c.shareable_gpus(), c.one_job_gpus());
        assert_eq!(c.residents(2), vec![1, 2]);
        assert_eq!(c.residents(4), Vec::<usize>::new());
    }

    #[test]
    fn heterogeneous_cluster_exposes_per_gpu_budgets() {
        let c = Cluster::with_topology(topology::by_name("hetero-16x4-2tier").unwrap());
        assert_eq!(c.total_gpus(), 64);
        assert_eq!(c.mem_gb(0), 11.0);
        assert_eq!(c.mem_gb(32), 22.0);
        // The summary config is conservative: smallest GPU wins.
        assert_eq!(c.config.gpu_mem_gb, 11.0);
        assert_eq!(c.span_of(&[0, 1]).bandwidth_gbps, 100.0);
        c.check_invariants().unwrap();
    }
}

//! Multi-tenant GPU cluster substrate (paper §IV): `|S|` servers with `|N|`
//! identical GPUs evenly distributed, interconnected through a
//! sufficient-bandwidth switch. A GPU may hold at most `C` jobs (Eq. 9;
//! the paper fixes C = 2 after observing that 3-way sharing is never
//! beneficial). Gang allocation/release is atomic (Eqs. 8, 10–12).

pub mod placement;


use crate::jobs::JobId;

/// Flat GPU identifier: `server * gpus_per_server + local_index`.
pub type GpuId = usize;

/// Cluster shape + per-GPU capacities.
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    pub servers: usize,
    pub gpus_per_server: usize,
    /// GPU memory budget, GB (2080 Ti = 11 GB in the paper's testbed).
    pub gpu_mem_gb: f64,
    /// Max co-located jobs per GPU (paper: C = 2).
    pub max_share: usize,
}

impl ClusterConfig {
    /// The paper's physical testbed: 4 servers × 4 GPUs.
    pub fn physical() -> Self {
        ClusterConfig { servers: 4, gpus_per_server: 4, gpu_mem_gb: 11.0, max_share: 2 }
    }

    /// The paper's simulation cluster: 16 servers × 4 GPUs.
    pub fn simulation() -> Self {
        ClusterConfig { servers: 16, gpus_per_server: 4, gpu_mem_gb: 11.0, max_share: 2 }
    }

    pub fn total_gpus(&self) -> usize {
        self.servers * self.gpus_per_server
    }
}

/// One GPU's live occupancy.
#[derive(Debug, Clone, Default)]
pub struct GpuSlot {
    /// Jobs currently holding this GPU (len ≤ max_share).
    pub jobs: Vec<JobId>,
}

/// Live cluster state: who holds which GPU.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub config: ClusterConfig,
    slots: Vec<GpuSlot>,
}

impl Cluster {
    pub fn new(config: ClusterConfig) -> Self {
        Cluster { config, slots: vec![GpuSlot::default(); config.total_gpus()] }
    }

    pub fn server_of(&self, gpu: GpuId) -> usize {
        gpu / self.config.gpus_per_server
    }

    pub fn slot(&self, gpu: GpuId) -> &GpuSlot {
        &self.slots[gpu]
    }

    pub fn total_gpus(&self) -> usize {
        self.slots.len()
    }

    /// GPUs holding no job, ordered by (server, index) — placement picks
    /// prefixes of this to consolidate gangs (Alg. 1 line 7).
    pub fn free_gpus(&self) -> Vec<GpuId> {
        (0..self.slots.len()).filter(|&g| self.slots[g].jobs.is_empty()).collect()
    }

    /// GPUs holding exactly one job — the sharing candidates `G_OJ`
    /// (Alg. 1 line 5).
    pub fn one_job_gpus(&self) -> Vec<GpuId> {
        (0..self.slots.len()).filter(|&g| self.slots[g].jobs.len() == 1).collect()
    }

    /// Occupancy count per GPU.
    pub fn load(&self, gpu: GpuId) -> usize {
        self.slots[gpu].jobs.len()
    }

    /// Number of GPUs with at least one free share slot.
    pub fn schedulable_gpus(&self) -> usize {
        self.slots.iter().filter(|s| s.jobs.len() < self.config.max_share).count()
    }

    /// Atomically grant `gpus` to `job` (gang allocation). Panics on a slot
    /// overflow — callers must have validated share capacity (Eq. 9).
    pub fn allocate(&mut self, job: JobId, gpus: &[GpuId]) {
        for &g in gpus {
            let slot = &mut self.slots[g];
            assert!(
                slot.jobs.len() < self.config.max_share,
                "GPU {g} over-shared: {:?} + job {job}",
                slot.jobs
            );
            assert!(!slot.jobs.contains(&job), "job {job} already on GPU {g}");
            slot.jobs.push(job);
        }
    }

    /// Atomically release every GPU held by `job` (gang release).
    pub fn release(&mut self, job: JobId) {
        for slot in &mut self.slots {
            slot.jobs.retain(|&j| j != job);
        }
    }

    /// All jobs co-located with `job` anywhere on its gang.
    pub fn co_runners(&self, job: JobId) -> Vec<JobId> {
        let mut out: Vec<JobId> = self
            .slots
            .iter()
            .filter(|s| s.jobs.contains(&job))
            .flat_map(|s| s.jobs.iter().copied())
            .filter(|&j| j != job)
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// GPUs held by `job`.
    pub fn gpus_of(&self, job: JobId) -> Vec<GpuId> {
        (0..self.slots.len()).filter(|&g| self.slots[g].jobs.contains(&job)).collect()
    }

    /// Distinct servers spanned by a GPU set (`S(J_k)` in Table I).
    pub fn servers_spanned(&self, gpus: &[GpuId]) -> usize {
        let mut servers: Vec<usize> = gpus.iter().map(|&g| self.server_of(g)).collect();
        servers.sort_unstable();
        servers.dedup();
        servers.len()
    }

    /// Invariant check used by property tests: no slot over capacity, no
    /// duplicate job entries on a slot.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (g, slot) in self.slots.iter().enumerate() {
            if slot.jobs.len() > self.config.max_share {
                return Err(format!("GPU {g} holds {} jobs", slot.jobs.len()));
            }
            let mut uniq = slot.jobs.clone();
            uniq.sort_unstable();
            uniq.dedup();
            if uniq.len() != slot.jobs.len() {
                return Err(format!("GPU {g} duplicate job entries"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::physical())
    }

    #[test]
    fn fresh_cluster_all_free() {
        let c = cluster();
        assert_eq!(c.free_gpus().len(), 16);
        assert_eq!(c.one_job_gpus().len(), 0);
        assert_eq!(c.schedulable_gpus(), 16);
    }

    #[test]
    fn allocate_release_roundtrip() {
        let mut c = cluster();
        c.allocate(7, &[0, 1, 2, 3]);
        assert_eq!(c.free_gpus().len(), 12);
        assert_eq!(c.one_job_gpus(), vec![0, 1, 2, 3]);
        assert_eq!(c.gpus_of(7), vec![0, 1, 2, 3]);
        c.release(7);
        assert_eq!(c.free_gpus().len(), 16);
        c.check_invariants().unwrap();
    }

    #[test]
    fn sharing_two_jobs_per_gpu() {
        let mut c = cluster();
        c.allocate(1, &[0, 1]);
        c.allocate(2, &[0, 1]);
        assert_eq!(c.load(0), 2);
        assert_eq!(c.co_runners(1), vec![2]);
        assert_eq!(c.co_runners(2), vec![1]);
        assert!(c.one_job_gpus().is_empty());
        c.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "over-shared")]
    fn c2_cap_enforced() {
        let mut c = cluster();
        c.allocate(1, &[0]);
        c.allocate(2, &[0]);
        c.allocate(3, &[0]); // Eq. 9 violation with C = 2
    }

    #[test]
    #[should_panic(expected = "already on")]
    fn no_duplicate_grant() {
        let mut c = cluster();
        c.allocate(1, &[0]);
        c.allocate(1, &[0]);
    }

    #[test]
    fn servers_spanned_counts_distinct() {
        let c = cluster();
        assert_eq!(c.servers_spanned(&[0, 1, 2, 3]), 1);
        assert_eq!(c.servers_spanned(&[0, 4, 8, 12]), 4);
        assert_eq!(c.servers_spanned(&[3, 4]), 2);
    }

    #[test]
    fn partial_share_overlap() {
        // Job 2 shares only part of job 1's gang (paper allows partial
        // sharing: "fully or partially share the same set of GPUs").
        let mut c = cluster();
        c.allocate(1, &[0, 1, 2, 3]);
        c.allocate(2, &[2, 3, 4, 5]);
        assert_eq!(c.co_runners(1), vec![2]);
        assert_eq!(c.one_job_gpus(), vec![0, 1, 4, 5]);
        c.check_invariants().unwrap();
    }
}

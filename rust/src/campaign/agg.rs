//! Streaming per-cell aggregation over the seed axis.
//!
//! Each sweep cell (policy × cluster × jobs × load) accumulates its
//! per-seed run summaries into Welford [`Stream`]s — mean / sample std /
//! min / max plus a normal-approximation 95% confidence interval — without
//! ever storing the raw per-run results, so memory stays O(cells) no
//! matter how many seeds a campaign sweeps.

use std::collections::HashMap;

use crate::sim::metrics::{Aggregate, Summary};

use super::runner::RunOutcome;
use super::spec::RunResult;
use super::sweep::CellKey;

/// Welford online accumulator.
#[derive(Debug, Clone)]
pub struct Stream {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Stream {
    fn default() -> Self {
        Stream { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
}

impl Stream {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample standard deviation (n−1 denominator); 0 below two samples.
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Half-width of the normal-approximation 95% CI of the mean
    /// (`1.96·s/√n`); 0 below two samples. Bootstrap-free on purpose: seeds
    /// are cheap, resampling is not.
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            1.96 * self.std() / (self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Streams for one population slice (all / large / small jobs).
#[derive(Debug, Clone, Default)]
pub struct SliceAgg {
    pub avg_jct_s: Stream,
    pub avg_queue_s: Stream,
    pub p50_jct_s: Stream,
    pub p90_jct_s: Stream,
    /// Jobs the run left unfinished in this slice (survivorship signal —
    /// the JCT streams above cover finished jobs only).
    pub unfinished: Stream,
}

impl SliceAgg {
    fn push(&mut self, a: &Aggregate) {
        // The unfinished count is meaningful even when the slice finished
        // nothing (everything-unfinished is exactly the case it exists to
        // expose), so it streams unconditionally.
        self.unfinished.push(a.unfinished as f64);
        // An empty slice (e.g. a seed that drew no large jobs) reports
        // Aggregate::default(); averaging its placeholder zeros in would
        // bias the slice stats, so such seeds are excluded — the stream's
        // own n() records how many seeds actually contributed.
        if a.n == 0 {
            return;
        }
        self.avg_jct_s.push(a.avg_jct_s);
        self.avg_queue_s.push(a.avg_queue_s);
        self.p50_jct_s.push(a.p50_jct_s);
        self.p90_jct_s.push(a.p90_jct_s);
    }

    /// Seed-averaged aggregate; `n` carries the seed count (not job count).
    fn mean_aggregate(&self) -> Aggregate {
        Aggregate {
            n: self.avg_jct_s.n() as usize,
            avg_jct_s: self.avg_jct_s.mean(),
            avg_queue_s: self.avg_queue_s.mean(),
            p50_jct_s: self.p50_jct_s.mean(),
            p90_jct_s: self.p90_jct_s.mean(),
            unfinished: self.unfinished.mean().round() as usize,
        }
    }
}

/// All statistics for one sweep cell.
#[derive(Debug, Clone)]
pub struct CellAgg {
    pub key: CellKey,
    pub makespan_s: Stream,
    /// Mean GPU utilization per seed (busy / capacity over the makespan).
    pub gpu_util: Stream,
    /// Fraction of busy GPU-time spent co-located, per seed.
    pub sharing_frac: Stream,
    pub all: SliceAgg,
    pub large: SliceAgg,
    pub small: SliceAgg,
    /// `(ordinal, seed, error)` for runs in this cell that failed.
    pub errors: Vec<(usize, u64, String)>,
}

impl CellAgg {
    fn new(key: CellKey) -> Self {
        CellAgg {
            key,
            makespan_s: Stream::default(),
            gpu_util: Stream::default(),
            sharing_frac: Stream::default(),
            all: SliceAgg::default(),
            large: SliceAgg::default(),
            small: SliceAgg::default(),
            errors: Vec::new(),
        }
    }

    /// Number of successfully aggregated seeds.
    pub fn seeds(&self) -> u64 {
        self.makespan_s.n()
    }

    fn push_result(&mut self, r: &RunResult) {
        let s = &r.summary;
        self.makespan_s.push(s.makespan_s);
        self.gpu_util.push(r.gpu_util);
        self.sharing_frac.push(r.sharing_frac);
        self.all.push(&s.all);
        self.large.push(&s.large);
        self.small.push(&s.small);
    }

    /// Seed-averaged [`Summary`], directly feedable to
    /// [`crate::report::table34`] / [`crate::report::table2`].
    pub fn mean_summary(&self) -> Summary {
        Summary {
            policy: self.key.policy.clone(),
            makespan_s: self.makespan_s.mean(),
            all: self.all.mean_aggregate(),
            large: self.large.mean_aggregate(),
            small: self.small.mean_aggregate(),
        }
    }
}

/// Consumes [`RunOutcome`]s one at a time (streaming — outcomes can be fed
/// as workers produce them) and groups them into cells in first-appearance
/// order, which for ordered outcome streams equals expansion order.
#[derive(Debug, Default)]
pub struct Aggregator {
    cells: Vec<CellAgg>,
    index: HashMap<CellKey, usize>,
}

impl Aggregator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, outcome: &RunOutcome) {
        let i = match self.index.get(&outcome.cell) {
            Some(&i) => i,
            None => {
                let i = self.cells.len();
                self.index.insert(outcome.cell.clone(), i);
                self.cells.push(CellAgg::new(outcome.cell.clone()));
                i
            }
        };
        match &outcome.summary {
            Ok(r) => self.cells[i].push_result(r),
            Err(e) => {
                self.cells[i].errors.push((outcome.ordinal, outcome.seed, e.clone()))
            }
        }
    }

    /// Cells in first-appearance order.
    pub fn finish(self) -> Vec<CellAgg> {
        self.cells
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(policy: &str) -> CellKey {
        CellKey {
            topology: "uniform-16x4".to_string(),
            workload: "philly-sim".to_string(),
            estimator: "oracle".to_string(),
            total_gpus: 64,
            n_jobs: 240,
            load_milli: 1000,
            share_cap: 2,
            policy: policy.into(),
        }
    }

    fn outcome(policy: &str, seed: u64, jct: f64) -> RunOutcome {
        let agg = Aggregate {
            n: 10,
            avg_jct_s: jct,
            avg_queue_s: jct / 4.0,
            p50_jct_s: jct * 0.8,
            p90_jct_s: jct * 2.0,
            unfinished: 1,
        };
        RunOutcome {
            ordinal: seed as usize,
            cell: key(policy),
            seed,
            summary: Ok(RunResult {
                summary: Summary {
                    policy: policy.into(),
                    makespan_s: 3.0 * jct,
                    all: agg,
                    large: agg,
                    small: agg,
                },
                gpu_util: 0.5,
                sharing_frac: 0.25,
            }),
        }
    }

    #[test]
    fn welford_matches_closed_form() {
        let mut s = Stream::default();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.n(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Sample variance of this classic set is 32/7.
        assert!((s.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert!(s.ci95() > 0.0);
    }

    #[test]
    fn constant_stream_has_zero_spread() {
        let mut s = Stream::default();
        for _ in 0..5 {
            s.push(3.25);
        }
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.ci95(), 0.0);
        assert_eq!(s.min(), s.max());
    }

    #[test]
    fn empty_stream_is_safe() {
        let s = Stream::default();
        assert_eq!(s.n(), 0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
    }

    #[test]
    fn groups_by_cell_in_first_appearance_order() {
        let mut agg = Aggregator::new();
        agg.push(&outcome("FIFO", 1, 100.0));
        agg.push(&outcome("SJF", 1, 50.0));
        agg.push(&outcome("FIFO", 2, 140.0));
        let cells = agg.finish();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].key.policy, "FIFO");
        assert_eq!(cells[0].seeds(), 2);
        assert_eq!(cells[1].seeds(), 1);
        assert!((cells[0].all.avg_jct_s.mean() - 120.0).abs() < 1e-12);
        let mean = cells[0].mean_summary();
        assert_eq!(mean.policy, "FIFO");
        assert!((mean.makespan_s - 360.0).abs() < 1e-12);
        // Run-level utilization figures stream per seed alongside JCT.
        assert_eq!(cells[0].gpu_util.n(), 2);
        assert!((cells[0].gpu_util.mean() - 0.5).abs() < 1e-12);
        assert!((cells[0].sharing_frac.mean() - 0.25).abs() < 1e-12);
        assert_eq!(mean.all.unfinished, 1);
    }

    #[test]
    fn errors_collect_per_cell() {
        let mut agg = Aggregator::new();
        agg.push(&outcome("FIFO", 1, 100.0));
        agg.push(&RunOutcome {
            ordinal: 7,
            cell: key("FIFO"),
            seed: 2,
            summary: Err("boom".to_string()),
        });
        let cells = agg.finish();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].seeds(), 1);
        assert_eq!(cells[0].errors, vec![(7, 2, "boom".to_string())]);
    }
}

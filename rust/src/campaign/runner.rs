//! Parallel campaign runner: fan the run matrix out over a `std::thread`
//! worker pool.
//!
//! Every [`RunPoint`] runs independently (fresh policy, own cluster
//! state), so runs are embarrassingly parallel. The trace is the one
//! shared input: points that differ only on the policy axis read the
//! same lazily-generated [`super::sweep::SharedTrace`] — one generation
//! per (cell, seed) group instead of one per run, and since generation
//! is a pure function of the config the shared bytes are identical no
//! matter which worker generates first. Workers pull the next un-started
//! point from a shared atomic cursor and write the outcome into that
//! point's dedicated slot — results therefore come back **in expansion
//! order regardless of completion order**, which is what makes parallel
//! output byte-identical to a serial run of the same matrix.
//!
//! Failures (a policy refusing to schedule, a livelocked run hitting
//! `max_sim_s`) are captured per-run as strings instead of aborting the
//! campaign; the aggregator reports them per cell.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::sim::metrics::Summary;

use super::sweep::{CellKey, RunPoint};

/// The result of one run, tagged with its matrix position.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub ordinal: usize,
    pub cell: CellKey,
    pub seed: u64,
    pub summary: Result<Summary, String>,
}

fn run_one(point: &RunPoint) -> RunOutcome {
    RunOutcome {
        ordinal: point.ordinal,
        cell: point.cell.clone(),
        seed: point.scenario.trace.seed,
        summary: point
            .scenario
            .run_with_trace(point.trace.jobs())
            .map_err(|e| e.to_string()),
    }
}

/// Number of workers to use when the caller passes 0 ("auto").
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The worker count [`run_parallel`] will actually use for a matrix of
/// `n_points` when asked for `requested` threads (0 ⇒ auto) — exposed so
/// status output can match the runner exactly.
pub fn resolved_threads(n_points: usize, requested: usize) -> usize {
    let t = if requested == 0 { default_threads() } else { requested };
    t.clamp(1, n_points.max(1))
}

/// Run the matrix on the calling thread, in expansion order — the old
/// hand-rolled sweep loop, kept as the reference implementation the
/// parallel runner is property-tested against (and benchmarked against in
/// `benches/campaign_throughput.rs`).
pub fn run_serial(points: &[RunPoint]) -> Vec<RunOutcome> {
    points.iter().map(run_one).collect()
}

/// Run the matrix over `threads` workers (0 ⇒ [`default_threads`]).
/// Returns outcomes in expansion order.
pub fn run_parallel(points: &[RunPoint], threads: usize) -> Vec<RunOutcome> {
    let threads = resolved_threads(points.len(), threads);
    if threads <= 1 {
        return run_serial(points);
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<RunOutcome>>> =
        points.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= points.len() {
                    break;
                }
                *slots[i].lock().unwrap() = Some(run_one(&points[i]));
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every slot filled by a worker"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::spec::{Axes, CampaignSpec};
    use crate::campaign::sweep::expand;
    use crate::cluster::ClusterConfig;

    fn points() -> Vec<RunPoint> {
        let mut spec = CampaignSpec::new("t");
        spec.cluster = ClusterConfig::physical();
        spec.policies = vec!["FIFO".to_string()];
        spec.axes = Axes {
            load_factors: vec![1.0],
            job_counts: vec![10],
            gpu_counts: Vec::new(),
            topologies: Vec::new(),
            workloads: Vec::new(),
            estimators: Vec::new(),
            seeds: vec![1, 2, 3, 4],
            jobs_scale_load_baseline: None,
        };
        expand(&spec).unwrap()
    }

    #[test]
    fn parallel_preserves_expansion_order() {
        let pts = points();
        let out = run_parallel(&pts, 4);
        assert_eq!(out.len(), pts.len());
        for (i, o) in out.iter().enumerate() {
            assert_eq!(o.ordinal, i);
            assert_eq!(o.seed, pts[i].scenario.trace.seed);
            assert!(o.summary.is_ok(), "{:?}", o.summary);
        }
    }

    #[test]
    fn oversubscribed_pool_clamps_to_matrix() {
        let pts = points();
        let out = run_parallel(&pts, 64);
        assert_eq!(out.len(), pts.len());
    }

    #[test]
    fn failures_are_captured_not_fatal() {
        let mut pts = points();
        // Sabotage one run: an unknown policy fails at construction time.
        pts[1].scenario.policy = "Bogus".to_string();
        let out = run_parallel(&pts, 2);
        assert!(out[1].summary.is_err());
        // The rest of the matrix must still complete.
        assert!(out[0].summary.is_ok());
        assert!(out[2].summary.is_ok());
        assert!(out[3].summary.is_ok());
    }
}

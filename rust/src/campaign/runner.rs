//! Parallel campaign runner: fan the run matrix out over a `std::thread`
//! worker pool.
//!
//! Every [`RunPoint`] runs independently (fresh policy, own cluster
//! state), so runs are embarrassingly parallel. The trace is the one
//! shared input: points that differ only on the policy axis read the
//! same lazily-generated [`super::sweep::SharedTrace`] — one generation
//! per (cell, seed) group instead of one per run, and since generation
//! is a pure function of the config the shared bytes are identical no
//! matter which worker generates first. Workers pull the next un-started
//! point from a shared atomic cursor and write the outcome into that
//! point's dedicated slot — results therefore come back **in expansion
//! order regardless of completion order**, which is what makes parallel
//! output byte-identical to a serial run of the same matrix.
//!
//! Failures (a policy refusing to schedule, a livelocked run hitting
//! `max_sim_s`) are captured per-run as strings instead of aborting the
//! campaign; the aggregator reports them per cell.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::obskit::{Obs, ObsConfig};

use super::spec::RunResult;
use super::sweep::{CellKey, RunPoint};

/// Per-run observability artifact directories for a campaign. Each armed
/// directory receives one file per run, named by the run's matrix
/// ordinal (`run-00042.trace.json` / `.metrics.json` / `.audit.jsonl`)
/// so artifacts line up with the expansion order no matter which worker
/// produced them. All-`None` (the default) arms nothing and the runner
/// behaves exactly as before.
#[derive(Debug, Clone, Default)]
pub struct ObsDirs {
    pub trace_dir: Option<PathBuf>,
    pub metrics_dir: Option<PathBuf>,
    pub audit_dir: Option<PathBuf>,
    /// Sim-time metrics-sampling cadence, seconds (0 ⇒ obskit default).
    pub sample_every_s: f64,
}

impl ObsDirs {
    pub fn is_enabled(&self) -> bool {
        self.trace_dir.is_some() || self.metrics_dir.is_some() || self.audit_dir.is_some()
    }

    /// The per-run sink configuration for matrix position `ordinal`.
    pub fn for_run(&self, ordinal: usize) -> ObsConfig {
        let mut cfg = ObsConfig::default();
        if self.sample_every_s > 0.0 {
            cfg.sample_every_s = self.sample_every_s;
        }
        cfg.trace =
            self.trace_dir.as_ref().map(|d| d.join(format!("run-{ordinal:05}.trace.json")));
        cfg.metrics = self
            .metrics_dir
            .as_ref()
            .map(|d| d.join(format!("run-{ordinal:05}.metrics.json")));
        cfg.audit =
            self.audit_dir.as_ref().map(|d| d.join(format!("run-{ordinal:05}.audit.jsonl")));
        cfg
    }
}

/// The result of one run, tagged with its matrix position.
#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub ordinal: usize,
    pub cell: CellKey,
    pub seed: u64,
    pub summary: Result<RunResult, String>,
}

fn run_one(point: &RunPoint, obs_dirs: &ObsDirs) -> RunOutcome {
    let obs = Obs::new(obs_dirs.for_run(point.ordinal));
    let mut result = point
        .scenario
        .run_with_trace_obs(point.trace.jobs(), obs.clone())
        .map_err(|e| e.to_string());
    if let Err(e) = obs.finish() {
        // Artifact I/O failure must not masquerade as a sim failure, but
        // silently dropping it would defeat the audit trail — surface it
        // on the run unless the run already failed for a real reason.
        if result.is_ok() {
            result = Err(format!("writing observability artifacts: {e:#}"));
        }
    }
    RunOutcome {
        ordinal: point.ordinal,
        cell: point.cell.clone(),
        seed: point.scenario.trace.seed,
        summary: result,
    }
}

/// Number of workers to use when the caller passes 0 ("auto").
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The worker count [`run_parallel`] will actually use for a matrix of
/// `n_points` when asked for `requested` threads (0 ⇒ auto) — exposed so
/// status output can match the runner exactly.
pub fn resolved_threads(n_points: usize, requested: usize) -> usize {
    let t = if requested == 0 { default_threads() } else { requested };
    t.clamp(1, n_points.max(1))
}

/// Run the matrix on the calling thread, in expansion order — the old
/// hand-rolled sweep loop, kept as the reference implementation the
/// parallel runner is property-tested against (and benchmarked against in
/// `benches/campaign_throughput.rs`).
pub fn run_serial(points: &[RunPoint]) -> Vec<RunOutcome> {
    run_serial_obs(points, &ObsDirs::default())
}

/// [`run_serial`] with per-run observability artifacts.
pub fn run_serial_obs(points: &[RunPoint], obs_dirs: &ObsDirs) -> Vec<RunOutcome> {
    points.iter().map(|p| run_one(p, obs_dirs)).collect()
}

/// Run the matrix over `threads` workers (0 ⇒ [`default_threads`]).
/// Returns outcomes in expansion order.
pub fn run_parallel(points: &[RunPoint], threads: usize) -> Vec<RunOutcome> {
    run_parallel_obs(points, threads, &ObsDirs::default())
}

/// [`run_parallel`] with per-run observability artifacts. Each run arms
/// its own sinks (one set of files per matrix ordinal), so workers never
/// contend on a shared sink and the parallel == serial byte-identity of
/// the campaign outputs is unaffected.
pub fn run_parallel_obs(
    points: &[RunPoint],
    threads: usize,
    obs_dirs: &ObsDirs,
) -> Vec<RunOutcome> {
    let threads = resolved_threads(points.len(), threads);
    if threads <= 1 {
        return run_serial_obs(points, obs_dirs);
    }
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<RunOutcome>>> =
        points.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= points.len() {
                    break;
                }
                *slots[i].lock().unwrap() = Some(run_one(&points[i], obs_dirs));
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every slot filled by a worker"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::spec::{Axes, CampaignSpec};
    use crate::campaign::sweep::expand;
    use crate::cluster::ClusterConfig;

    fn points() -> Vec<RunPoint> {
        let mut spec = CampaignSpec::new("t");
        spec.cluster = ClusterConfig::physical();
        spec.policies = vec!["FIFO".to_string()];
        spec.axes = Axes {
            load_factors: vec![1.0],
            job_counts: vec![10],
            gpu_counts: Vec::new(),
            topologies: Vec::new(),
            workloads: Vec::new(),
            estimators: Vec::new(),
            share_caps: Vec::new(),
            seeds: vec![1, 2, 3, 4],
            jobs_scale_load_baseline: None,
        };
        expand(&spec).unwrap()
    }

    #[test]
    fn parallel_preserves_expansion_order() {
        let pts = points();
        let out = run_parallel(&pts, 4);
        assert_eq!(out.len(), pts.len());
        for (i, o) in out.iter().enumerate() {
            assert_eq!(o.ordinal, i);
            assert_eq!(o.seed, pts[i].scenario.trace.seed);
            assert!(o.summary.is_ok(), "{:?}", o.summary);
        }
    }

    #[test]
    fn oversubscribed_pool_clamps_to_matrix() {
        let pts = points();
        let out = run_parallel(&pts, 64);
        assert_eq!(out.len(), pts.len());
    }

    #[test]
    fn obs_dirs_name_artifacts_by_ordinal() {
        let dirs = ObsDirs {
            trace_dir: Some(PathBuf::from("t")),
            metrics_dir: Some(PathBuf::from("m")),
            audit_dir: None,
            sample_every_s: 0.0,
        };
        assert!(dirs.is_enabled());
        let cfg = dirs.for_run(42);
        assert_eq!(cfg.trace.unwrap(), PathBuf::from("t/run-00042.trace.json"));
        assert_eq!(cfg.metrics.unwrap(), PathBuf::from("m/run-00042.metrics.json"));
        assert!(cfg.audit.is_none());
        // 0 keeps the obskit default cadence.
        assert_eq!(cfg.sample_every_s, 60.0);
        assert!(!ObsDirs::default().is_enabled());
    }

    #[test]
    fn failures_are_captured_not_fatal() {
        let mut pts = points();
        // Sabotage one run: an unknown policy fails at construction time.
        pts[1].scenario.policy = "Bogus".to_string();
        let out = run_parallel(&pts, 2);
        assert!(out[1].summary.is_err());
        // The rest of the matrix must still complete.
        assert!(out[0].summary.is_ok());
        assert!(out[2].summary.is_ok());
        assert!(out[3].summary.is_ok());
    }
}

//! Cartesian sweep expansion: resolve a [`CampaignSpec`] into an ordered,
//! deterministic run matrix.
//!
//! Axis nesting order (outer → inner): cluster shape (topology or GPU
//! count) → workload preset → estimator → job count → load factor →
//! share cap → policy → seed. The order is part of the subsystem's contract — run
//! ordinals are stable across processes, results are reported in
//! expansion order regardless of which worker finished first, and cells
//! (everything but the seed) appear in first-occurrence order in every
//! emitter.

use std::sync::{Arc, OnceLock};

use anyhow::{bail, Result};

use crate::cluster::{topology, ClusterConfig};
use crate::jobs::estimate::EstimateModel;
use crate::jobs::trace::{self, TraceConfig};
use crate::jobs::workload;
use crate::jobs::JobSpec;

use super::spec::{CampaignSpec, ScenarioSpec};

/// Aggregation cell coordinates: one point of the sweep with the seed axis
/// projected out. `load_milli` keeps the key `Eq`/`Hash`-able; the factor
/// is quantized to 1/1000 *before* being handed to the trace generator, so
/// the key is exact, not a lossy rendering.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CellKey {
    /// Cluster shape name: a named topology from the `topologies` axis,
    /// or `uniform-{servers}x{gpus_per_server}` for flat-config cells.
    pub topology: String,
    /// Workload preset name (`philly-sim` when the axis is unset).
    pub workload: String,
    /// Canonical estimator spec string (`oracle` when the axis is unset).
    pub estimator: String,
    pub total_gpus: usize,
    pub n_jobs: usize,
    /// Effective load factor × 1000.
    pub load_milli: u64,
    /// Share cap C the run's cluster enforces (the `share_caps` axis, or
    /// the resolved cluster's own `max_share` when the axis is unset).
    pub share_cap: usize,
    pub policy: String,
}

impl CellKey {
    pub fn load_factor(&self) -> f64 {
        self.load_milli as f64 / 1000.0
    }

    /// The non-policy coordinates — emitters group cells on this.
    pub fn scenario_coords(&self) -> (&str, &str, &str, usize, usize, u64, usize) {
        (
            &self.topology,
            &self.workload,
            &self.estimator,
            self.total_gpus,
            self.n_jobs,
            self.load_milli,
            self.share_cap,
        )
    }
}

/// The cell name of a uniform (flat-config) cluster shape.
pub fn uniform_shape_name(cluster: &ClusterConfig) -> String {
    format!("uniform-{}x{}", cluster.servers, cluster.gpus_per_server)
}

/// A lazily-generated trace shared by every run point of one cell group
/// — the points that differ only on the policy axis (same shape,
/// workload, estimator, job count, load and seed all see the exact same
/// jobs). Before this existed the runner regenerated the identical trace
/// once per policy in every cell: the campaign's single biggest
/// redundant cost (`campaign/per-run-generation` vs
/// `campaign/shared-trace-serial` in `cargo bench --bench
/// campaign_throughput`).
///
/// Generation is deferred to first use, so [`expand`] stays a cheap
/// metadata pass. `trace::generate` is a pure function of the config, so
/// whichever worker wins the `OnceLock` race produces identical bytes —
/// the parallel == serial byte-identity guarantee is unaffected.
///
/// Memory trade-off, deliberate: generated traces stay resident until
/// the run matrix itself drops (a `OnceLock` cannot be emptied through
/// shared refs), where the old per-run generation peaked at O(workers)
/// live traces. A `JobSpec` is ~100 bytes, so even a hundred 20k-job
/// cell groups hold ~200 MB — acceptable for the sweeps this subsystem
/// targets; revisit with a countdown-and-free scheme if campaigns ever
/// sweep thousands of distinct datacenter-scale trace groups.
#[derive(Debug)]
pub struct SharedTrace {
    cfg: TraceConfig,
    jobs: OnceLock<Vec<JobSpec>>,
}

impl SharedTrace {
    pub fn new(cfg: TraceConfig) -> SharedTrace {
        SharedTrace { cfg, jobs: OnceLock::new() }
    }

    /// The generated trace; the first caller generates, everyone after
    /// reuses.
    pub fn jobs(&self) -> &[JobSpec] {
        self.jobs.get_or_init(|| trace::generate(&self.cfg))
    }

    pub fn config(&self) -> &TraceConfig {
        &self.cfg
    }

    /// Whether any caller has forced generation yet (expansion must not).
    pub fn is_generated(&self) -> bool {
        self.jobs.get().is_some()
    }
}

/// One entry of the expanded run matrix.
#[derive(Debug, Clone)]
pub struct RunPoint {
    /// Position in the matrix (0-based, expansion order).
    pub ordinal: usize,
    pub cell: CellKey,
    pub scenario: ScenarioSpec,
    /// The cell group's shared trace (see [`SharedTrace`]); identical to
    /// `trace::generate(&scenario.trace)`, generated at most once per
    /// group. The runner reads this; `scenario` stays self-contained for
    /// standalone [`ScenarioSpec::run`] callers.
    pub trace: Arc<SharedTrace>,
}

/// One resolved point of the cluster-shape axis.
struct ShapeVariant {
    /// `Some(name)` for topology-axis cells, `None` for flat configs.
    topology: Option<String>,
    cluster: ClusterConfig,
    name: String,
    total_gpus: usize,
}

/// Expand a validated spec into its full run matrix. Two calls over the
/// same spec yield identical matrices; duplicates only occur when an axis
/// itself lists duplicate values (legal — repeating a seed is how the
/// zero-variance property test exercises aggregation).
pub fn expand(spec: &CampaignSpec) -> Result<Vec<RunPoint>> {
    spec.validate()?;
    let variants: Vec<ShapeVariant> = if !spec.axes.topologies.is_empty() {
        spec.axes
            .topologies
            .iter()
            .map(|name| {
                let t = topology::by_name(name).expect("validated topology name");
                ShapeVariant {
                    topology: Some(name.clone()),
                    cluster: t.summary_config(),
                    name: name.clone(),
                    total_gpus: t.total_gpus(),
                }
            })
            .collect()
    } else {
        let gpu_counts = if spec.axes.gpu_counts.is_empty() {
            vec![spec.cluster.total_gpus()]
        } else {
            spec.axes.gpu_counts.clone()
        };
        gpu_counts
            .iter()
            .map(|&gpus| {
                let cluster = ClusterConfig {
                    servers: gpus / spec.cluster.gpus_per_server,
                    ..spec.cluster
                };
                ShapeVariant {
                    topology: None,
                    name: uniform_shape_name(&cluster),
                    cluster,
                    total_gpus: gpus,
                }
            })
            .collect()
    };
    // Workload axis: resolved presets (default = the paper shape). A
    // non-empty axis supersedes the spec-level trace overrides — the
    // preset *is* the trace shape.
    let explicit_workloads = !spec.axes.workloads.is_empty();
    let presets: Vec<workload::WorkloadPreset> = if explicit_workloads {
        spec.axes
            .workloads
            .iter()
            .map(|name| workload::by_name_or_err(name))
            .collect::<Result<_>>()?
    } else {
        vec![workload::by_name("philly-sim").expect("registry preset")]
    };
    // Estimator axis: parsed once, keyed by the canonical spec string so
    // differently-spelled equal specs land in the same cell.
    let estimators: Vec<(String, EstimateModel)> = if spec.axes.estimators.is_empty() {
        vec![("oracle".to_string(), EstimateModel::Oracle)]
    } else {
        let parsed: Vec<(String, EstimateModel)> = spec
            .axes
            .estimators
            .iter()
            .map(|s| EstimateModel::parse(s).map(|m| (m.spec_string(), m)))
            .collect::<Result<_>>()?;
        // Distinct spellings that canonicalize to the same estimator
        // would silently merge into one cell with an inflated seed count
        // (deflating the CIs) — same policy as the load-quantization
        // collision check below. Literal duplicates stay legal, like
        // duplicated seeds.
        for i in 0..parsed.len() {
            for j in 0..i {
                if parsed[i].0 == parsed[j].0
                    && spec.axes.estimators[i] != spec.axes.estimators[j]
                {
                    bail!(
                        "campaign {:?}: estimators {:?} and {:?} both canonicalize \
                         to {:?} — they would merge into one cell",
                        spec.name,
                        spec.axes.estimators[j],
                        spec.axes.estimators[i],
                        parsed[i].0
                    );
                }
            }
        }
        parsed
    };
    // Quantize the load axis once per job count — the result (and the
    // distinctness validation: distinct axis values must stay distinct
    // after quantization, or two cells would silently merge, shrinking
    // the CIs) is identical for every shape/workload/estimator cell.
    let mut load_grid: Vec<Vec<u64>> = Vec::with_capacity(spec.axes.job_counts.len());
    for &n_jobs in &spec.axes.job_counts {
        let mut seen_millis: Vec<(u64, f64)> = Vec::new();
        for &load in &spec.axes.load_factors {
            let effective = match spec.axes.jobs_scale_load_baseline {
                Some(base) => load * n_jobs as f64 / base as f64,
                None => load,
            };
            let load_milli = (effective * 1000.0).round() as u64;
            if load_milli == 0 {
                bail!(
                    "campaign {:?}: effective load factor {effective} at {n_jobs} jobs \
                     quantizes to 0 (minimum representable is 0.001)",
                    spec.name
                );
            }
            if let Some((_, prev)) =
                seen_millis.iter().find(|(m, p)| *m == load_milli && *p != load)
            {
                bail!(
                    "campaign {:?}: load factors {prev} and {load} both quantize to \
                     {} (1/1000 resolution)",
                    spec.name,
                    load_milli as f64 / 1000.0
                );
            }
            seen_millis.push((load_milli, load));
        }
        load_grid.push(seen_millis.into_iter().map(|(m, _)| m).collect());
    }
    // Share-cap axis: `None` keeps each resolved cluster's own cap (the
    // paper's C = 2 everywhere), so an unset axis leaves existing matrices
    // byte-identical.
    let share_caps: Vec<Option<usize>> = if spec.axes.share_caps.is_empty() {
        vec![None]
    } else {
        spec.axes.share_caps.iter().map(|&c| Some(c)).collect()
    };
    let mut points = Vec::new();
    for variant in &variants {
        let cluster = variant.cluster;
        for preset in &presets {
            for (est_name, est_model) in &estimators {
                for (ji, &n_jobs) in spec.axes.job_counts.iter().enumerate() {
                    for &load_milli in &load_grid[ji] {
                        let quantized = load_milli as f64 / 1000.0;
                        // The trace is policy- and cap-invariant: build one
                        // config (and one lazily-shared generation) per
                        // seed, reused across the whole cap × policy block
                        // below.
                        let seed_traces: Vec<Arc<SharedTrace>> = spec
                            .axes
                            .seeds
                            .iter()
                            .map(|&seed| {
                                let mut trace = TraceConfig::from_preset(preset, n_jobs, seed);
                                if !explicit_workloads {
                                    // Back-compat: spec-level trace knobs
                                    // apply on the default preset only.
                                    trace.mean_interarrival_s = spec.mean_interarrival_s;
                                    trace.iter_range = spec.iter_range;
                                }
                                trace.estimator = est_model.clone();
                                trace.load_factor = quantized;
                                Arc::new(SharedTrace::new(trace))
                            })
                            .collect();
                        for &share_cap in &share_caps {
                            for policy in &spec.policies {
                                let cell = CellKey {
                                    topology: variant.name.clone(),
                                    workload: preset.name.to_string(),
                                    estimator: est_name.clone(),
                                    total_gpus: variant.total_gpus,
                                    n_jobs,
                                    load_milli,
                                    share_cap: share_cap
                                        .unwrap_or(variant.cluster.max_share),
                                    policy: policy.clone(),
                                };
                                for shared in &seed_traces {
                                    points.push(RunPoint {
                                        ordinal: points.len(),
                                        cell: cell.clone(),
                                        scenario: ScenarioSpec {
                                            policy: policy.clone(),
                                            cluster,
                                            topology: variant.topology.clone(),
                                            share_cap,
                                            trace: shared.config().clone(),
                                            xi_global: spec.xi_global,
                                            max_sim_s: spec.max_sim_s,
                                        },
                                        trace: shared.clone(),
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::spec::Axes;

    fn spec() -> CampaignSpec {
        let mut s = CampaignSpec::new("t");
        s.policies = vec!["FIFO".to_string(), "SJF".to_string()];
        s.axes = Axes {
            load_factors: vec![0.5, 1.0],
            job_counts: vec![30, 60],
            gpu_counts: vec![32, 64],
            topologies: Vec::new(),
            workloads: Vec::new(),
            estimators: Vec::new(),
            share_caps: Vec::new(),
            seeds: vec![1, 2, 3],
            jobs_scale_load_baseline: None,
        };
        s
    }

    #[test]
    fn matrix_size_is_axis_product() {
        let pts = expand(&spec()).unwrap();
        assert_eq!(pts.len(), 2 * 2 * 2 * 2 * 3);
        for (i, p) in pts.iter().enumerate() {
            assert_eq!(p.ordinal, i);
        }
    }

    #[test]
    fn nesting_order_gpus_jobs_load_policy_seed() {
        let pts = expand(&spec()).unwrap();
        // Innermost axis: seeds vary fastest.
        assert_eq!(pts[0].scenario.trace.seed, 1);
        assert_eq!(pts[1].scenario.trace.seed, 2);
        assert_eq!(pts[2].scenario.trace.seed, 3);
        // Then policy.
        assert_eq!(pts[0].cell.policy, "FIFO");
        assert_eq!(pts[3].cell.policy, "SJF");
        // Outermost: GPU count flips halfway through.
        assert_eq!(pts[0].cell.total_gpus, 32);
        assert_eq!(pts[pts.len() - 1].cell.total_gpus, 64);
        // Cluster shape follows the GPU axis (gpus_per_server fixed at 4).
        assert_eq!(pts[0].scenario.cluster.servers, 8);
        assert_eq!(pts[pts.len() - 1].scenario.cluster.servers, 16);
        // Flat configs are named by their uniform shape.
        assert_eq!(pts[0].cell.topology, "uniform-8x4");
        assert_eq!(pts[pts.len() - 1].cell.topology, "uniform-16x4");
    }

    #[test]
    fn topology_axis_expands_per_shape() {
        let mut s = spec();
        s.axes.gpu_counts = Vec::new();
        s.axes.topologies =
            vec!["uniform-16x4".to_string(), "hetero-16x4-2tier".to_string()];
        let pts = expand(&s).unwrap();
        // 2 topologies x 2 jobs x 2 loads x 2 policies x 3 seeds.
        assert_eq!(pts.len(), 2 * 2 * 2 * 2 * 3);
        assert_eq!(pts[0].cell.topology, "uniform-16x4");
        assert_eq!(pts[0].scenario.topology.as_deref(), Some("uniform-16x4"));
        let last = &pts[pts.len() - 1];
        assert_eq!(last.cell.topology, "hetero-16x4-2tier");
        assert_eq!(last.scenario.topology.as_deref(), Some("hetero-16x4-2tier"));
        assert!(pts.iter().all(|p| p.cell.total_gpus == 64));
        // The summary cluster is conservative for the hetero shape.
        assert_eq!(last.scenario.cluster.gpu_mem_gb, 11.0);
    }

    #[test]
    fn default_axes_use_paper_workload_and_oracle() {
        let pts = expand(&spec()).unwrap();
        assert!(pts.iter().all(|p| p.cell.workload == "philly-sim"));
        assert!(pts.iter().all(|p| p.cell.estimator == "oracle"));
        assert!(pts
            .iter()
            .all(|p| p.scenario.trace.estimator
                == crate::jobs::estimate::EstimateModel::Oracle));
    }

    #[test]
    fn workload_and_estimator_axes_expand() {
        let mut s = spec();
        s.axes.gpu_counts = Vec::new();
        s.axes.workloads = vec!["philly-sim".to_string(), "small-job-flood".to_string()];
        // Non-canonical spelling must still land in the canonical cell.
        s.axes.estimators = vec!["oracle".to_string(), "noisy:0.50".to_string()];
        let pts = expand(&s).unwrap();
        // 2 workloads x 2 estimators x 2 jobs x 2 loads x 2 policies x 3 seeds.
        assert_eq!(pts.len(), 2 * 2 * 2 * 2 * 2 * 3);
        assert_eq!(pts[0].cell.workload, "philly-sim");
        assert_eq!(pts[0].cell.estimator, "oracle");
        let last = &pts[pts.len() - 1];
        assert_eq!(last.cell.workload, "small-job-flood");
        assert_eq!(last.cell.estimator, "noisy:0.5");
        // The preset shapes the trace: flood arrives every 8 s in bursts,
        // with its own demand mix — not the spec-level overrides.
        assert_eq!(last.scenario.trace.mean_interarrival_s, 8.0);
        assert_eq!(last.scenario.trace.iter_range, (100, 5_000));
        assert!(matches!(
            last.scenario.trace.arrival,
            crate::jobs::workload::ArrivalProcess::Bursty { .. }
        ));
        assert_eq!(
            last.scenario.trace.estimator,
            crate::jobs::estimate::EstimateModel::Noisy { factor_sigma: 0.5, seed: 0 }
        );
        // Workload is outer to estimator: the first half of the matrix is
        // all philly-sim.
        assert!(pts[..pts.len() / 2].iter().all(|p| p.cell.workload == "philly-sim"));
    }

    #[test]
    fn unset_share_cap_axis_keeps_cluster_cap() {
        let pts = expand(&spec()).unwrap();
        assert!(pts.iter().all(|p| p.cell.share_cap == 2));
        assert!(pts.iter().all(|p| p.scenario.share_cap.is_none()));
    }

    #[test]
    fn share_cap_axis_expands_and_shares_traces() {
        let mut s = spec();
        s.axes.gpu_counts = Vec::new();
        s.axes.share_caps = vec![2, 3];
        let pts = expand(&s).unwrap();
        // 2 caps x 2 jobs x 2 loads x 2 policies x 3 seeds.
        assert_eq!(pts.len(), 2 * 2 * 2 * 2 * 3);
        // Cap is outer to policy, inner to load: first policy block is
        // C = 2, the next C = 3 over the same (jobs, load) cell.
        assert_eq!(pts[0].cell.share_cap, 2);
        assert_eq!(pts[0].scenario.share_cap, Some(2));
        assert_eq!(pts[6].cell.share_cap, 3);
        assert_eq!(pts[6].scenario.share_cap, Some(3));
        assert_eq!(pts[0].cell.n_jobs, pts[6].cell.n_jobs);
        assert_eq!(pts[0].cell.load_milli, pts[6].cell.load_milli);
        // The trace is cap-invariant: same (seed, cell group) carries the
        // same Arc across both caps and both policies.
        assert!(Arc::ptr_eq(&pts[0].trace, &pts[3].trace));
        assert!(Arc::ptr_eq(&pts[0].trace, &pts[6].trace));
        assert!(Arc::ptr_eq(&pts[0].trace, &pts[9].trace));
        assert!(!Arc::ptr_eq(&pts[0].trace, &pts[1].trace));
    }

    #[test]
    fn estimator_spellings_that_merge_cells_are_rejected() {
        let mut s = spec();
        s.axes.estimators = vec!["noisy:0.5".to_string(), "noisy:0.50".to_string()];
        let err = expand(&s).unwrap_err().to_string();
        assert!(err.contains("canonicalize"), "{err}");
        // Literal duplicates stay legal (like duplicated seeds).
        s.axes.estimators = vec!["noisy:0.5".to_string(), "noisy:0.5".to_string()];
        assert!(expand(&s).is_ok());
    }

    #[test]
    fn load_scaling_with_jobs_baseline() {
        let mut s = spec();
        s.axes.gpu_counts = Vec::new();
        s.axes.load_factors = vec![1.0];
        s.axes.jobs_scale_load_baseline = Some(60);
        let pts = expand(&s).unwrap();
        let l30 = pts.iter().find(|p| p.cell.n_jobs == 30).unwrap();
        let l60 = pts.iter().find(|p| p.cell.n_jobs == 60).unwrap();
        assert_eq!(l30.cell.load_factor(), 0.5);
        assert_eq!(l60.cell.load_factor(), 1.0);
        assert_eq!(l30.scenario.trace.load_factor, 0.5);
    }

    #[test]
    fn policy_axis_shares_one_lazy_trace_per_seed() {
        let pts = expand(&spec()).unwrap();
        // Expansion stays a cheap metadata pass: nothing generated yet.
        assert!(pts.iter().all(|p| !p.trace.is_generated()));
        // Innermost nesting is policy -> seed (2 policies x 3 seeds): the
        // same (cell group, seed) recurs at a stride of 3 and must carry
        // the same Arc; different seeds and different loads must not.
        assert!(Arc::ptr_eq(&pts[0].trace, &pts[3].trace));
        assert!(!Arc::ptr_eq(&pts[0].trace, &pts[1].trace));
        assert!(!Arc::ptr_eq(&pts[0].trace, &pts[6].trace));
        // The shared config is exactly the scenario's own trace config.
        assert_eq!(pts[0].trace.config().seed, pts[0].scenario.trace.seed);
        assert_eq!(
            pts[0].trace.config().load_factor,
            pts[0].scenario.trace.load_factor
        );
        // First use generates; the bytes match an independent generation
        // of the scenario config (sharing is pure memoization).
        let shared = pts[0].trace.jobs();
        assert!(pts[0].trace.is_generated());
        assert!(!pts[1].trace.is_generated());
        let fresh = trace::generate(&pts[0].scenario.trace);
        assert_eq!(shared.len(), fresh.len());
        for (a, b) in shared.iter().zip(&fresh) {
            assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
            assert_eq!(a.iterations, b.iterations);
            assert_eq!(a.gpus, b.gpus);
        }
    }

    #[test]
    fn expansion_is_deterministic() {
        let a = expand(&spec()).unwrap();
        let b = expand(&spec()).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.cell, y.cell);
            assert_eq!(x.scenario.trace.seed, y.scenario.trace.seed);
            assert_eq!(x.scenario.trace.load_factor, y.scenario.trace.load_factor);
        }
    }
}

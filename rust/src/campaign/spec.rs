//! Declarative campaign specifications: what to sweep, over which cluster,
//! trace shape, interference model and engine limits — loadable from JSON
//! (via the first-party [`Json`] parser) or built programmatically.
//!
//! A [`CampaignSpec`] describes a whole sweep; the expander
//! ([`super::sweep::expand`]) resolves it into an ordered list of
//! [`ScenarioSpec`]s, each one a fully-determined single simulation run
//! (policy × cluster shape × workload preset × estimator × job count ×
//! load factor × seed).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::cluster::{topology, Cluster, ClusterConfig};
use crate::jobs::estimate::EstimateModel;
use crate::jobs::trace::{self, TraceConfig};
use crate::jobs::workload;
use crate::jobs::JobSpec;
use crate::obskit::Obs;
use crate::perf::interference::InterferenceModel;
use crate::sched;
use crate::sim::metrics::{self, Summary};
use crate::sim::{engine, EngineConfig};
use crate::util::json::Json;

/// The axes of the cartesian sweep (paper Tables II–IV + Fig. 6a are all
/// points on these axes).
#[derive(Debug, Clone)]
pub struct Axes {
    /// Arrival-density multipliers (Fig. 6a's workload-intensity axis).
    pub load_factors: Vec<f64>,
    /// Trace sizes (number of jobs sampled from the busiest period).
    pub job_counts: Vec<usize>,
    /// Cluster sizes in total GPUs; empty ⇒ use the spec's base cluster.
    /// Each entry must be a multiple of the base `gpus_per_server`.
    pub gpu_counts: Vec<usize>,
    /// Named cluster shapes ([`topology::SHAPE_NAMES`]) to sweep; empty ⇒
    /// the uniform base cluster. A topology fixes the whole cluster shape,
    /// so this axis is mutually exclusive with `gpu_counts`.
    pub topologies: Vec<String>,
    /// Named workload presets ([`workload::PRESET_NAMES`]) to sweep;
    /// empty ⇒ `philly-sim` (the paper shape). A preset fixes the whole
    /// trace shape (arrival process, GPU mix, iteration tail), so a
    /// non-empty axis supersedes the spec's `mean_interarrival_s` /
    /// `iter_range` (JSON specs reject the combination outright).
    pub workloads: Vec<String>,
    /// Duration-estimator specs ([`EstimateModel::parse`]: `oracle`,
    /// `noisy:SIGMA[:SEED]`, `percentile:PCT`) to sweep; empty ⇒ the
    /// oracle. Cell keys carry the canonical spec string.
    pub estimators: Vec<String>,
    /// Share caps C (max co-located jobs per GPU, DESIGN.md §17) to
    /// sweep; empty ⇒ the base cluster's `max_share` (the paper's C = 2).
    /// Applies on top of the resolved cluster shape, named topologies
    /// included.
    pub share_caps: Vec<usize>,
    /// Trace seeds; aggregation (mean/std/CI) runs across this axis.
    pub seeds: Vec<u64>,
    /// If `Some(baseline)`, each run's effective load factor is further
    /// multiplied by `n_jobs / baseline` — the paper's "arrival density
    /// scales with job count" convention (Fig. 6a, Table IV = 480 jobs at
    /// 2× the 240-job baseline density).
    pub jobs_scale_load_baseline: Option<usize>,
}

/// A declarative scenario sweep: base configuration plus [`Axes`].
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    pub name: String,
    /// Base cluster shape; the `gpu_counts` axis rescales `servers` while
    /// keeping `gpus_per_server`, memory and the share cap fixed.
    pub cluster: ClusterConfig,
    /// Mean inter-arrival gap of the Philly-like generator, seconds.
    pub mean_interarrival_s: f64,
    /// Iteration-count range of the generator (heavy-tailed, clipped).
    pub iter_range: (u64, u64),
    /// `Some(ξ)` injects a constant interference ratio for every sharing
    /// pair (the Fig. 6b sensitivity axis); `None` uses the default model.
    pub xi_global: Option<f64>,
    /// Engine wall on simulated time (safety net against livelock).
    pub max_sim_s: f64,
    /// Policies to run (paper names, see [`sched::POLICY_NAMES`]).
    pub policies: Vec<String>,
    pub axes: Axes,
}

impl CampaignSpec {
    /// A single-cell campaign over the paper's simulation defaults:
    /// 16×4 cluster, 240-job trace shape, one seed — callers then override
    /// policies and axes.
    pub fn new(name: &str) -> CampaignSpec {
        let base = TraceConfig::simulation(240, 1);
        CampaignSpec {
            name: name.to_string(),
            cluster: ClusterConfig::simulation(),
            mean_interarrival_s: base.mean_interarrival_s,
            iter_range: base.iter_range,
            xi_global: None,
            max_sim_s: EngineConfig::default().max_sim_s,
            policies: Vec::new(),
            axes: Axes {
                load_factors: vec![1.0],
                job_counts: vec![240],
                gpu_counts: Vec::new(),
                topologies: Vec::new(),
                workloads: Vec::new(),
                estimators: Vec::new(),
                share_caps: Vec::new(),
                seeds: vec![1],
                jobs_scale_load_baseline: None,
            },
        }
    }

    /// The paper grid: all six policies × {120, 240, 360, 480} jobs with
    /// arrival density scaled by job count × 3 seeds on the 64-GPU
    /// simulation cluster. The (240, ×1) cell reproduces Table III, the
    /// (480, ×2) cell Table IV, and the whole job-count row Fig. 6a.
    pub fn paper_preset() -> CampaignSpec {
        let mut spec = CampaignSpec::new("paper");
        spec.policies =
            sched::PAPER_POLICY_NAMES.iter().map(|s| s.to_string()).collect();
        spec.axes = Axes {
            load_factors: vec![1.0],
            job_counts: vec![120, 240, 360, 480],
            gpu_counts: Vec::new(),
            topologies: Vec::new(),
            workloads: Vec::new(),
            estimators: Vec::new(),
            share_caps: Vec::new(),
            seeds: vec![1, 2, 3],
            jobs_scale_load_baseline: Some(240),
        };
        spec
    }

    /// Parse a spec from a JSON document. Missing optional fields fall back
    /// to the [`CampaignSpec::new`] defaults; `policies` and `axes` are
    /// required. See README.md for the schema and a worked example.
    pub fn from_json(doc: &Json) -> Result<CampaignSpec> {
        let name = match doc.get("name") {
            None | Some(Json::Null) => "campaign",
            Some(v) => v.as_str().context("name must be a string")?,
        };
        let mut spec = CampaignSpec::new(name);
        if let Some(c) = doc.get("cluster") {
            spec.cluster = ClusterConfig {
                servers: c
                    .req("servers")?
                    .as_u64()
                    .context("servers must be a non-negative integer")?
                    as usize,
                gpus_per_server: c
                    .req("gpus_per_server")?
                    .as_u64()
                    .context("gpus_per_server must be a non-negative integer")?
                    as usize,
                gpu_mem_gb: opt_f64(c, "gpu_mem_gb")?.unwrap_or(spec.cluster.gpu_mem_gb),
                max_share: opt_usize(c, "max_share")?.unwrap_or(spec.cluster.max_share),
            };
        }
        if let Some(t) = doc.get("trace") {
            spec.mean_interarrival_s =
                opt_f64(t, "mean_interarrival_s")?.unwrap_or(spec.mean_interarrival_s);
            spec.iter_range = (
                opt_u64(t, "iter_lo")?.unwrap_or(spec.iter_range.0),
                opt_u64(t, "iter_hi")?.unwrap_or(spec.iter_range.1),
            );
        }
        spec.xi_global = opt_f64(doc, "xi_global")?;
        spec.max_sim_s = opt_f64(doc, "max_sim_s")?.unwrap_or(spec.max_sim_s);
        spec.policies = doc
            .req("policies")?
            .as_arr()
            .context("policies must be an array")?
            .iter()
            .map(|p| p.as_str().map(str::to_string).context("policy names must be strings"))
            .collect::<Result<Vec<String>>>()?;
        let axes = doc.req("axes")?;
        spec.axes = Axes {
            load_factors: f64_list(axes, "load_factors", vec![1.0])?,
            job_counts: usize_list(axes, "job_counts", vec![240])?,
            gpu_counts: usize_list(axes, "gpu_counts", Vec::new())?,
            topologies: str_list(axes, "topologies", Vec::new())?,
            workloads: str_list(axes, "workloads", Vec::new())?,
            estimators: str_list(axes, "estimators", Vec::new())?,
            share_caps: usize_list(axes, "share_caps", Vec::new())?,
            seeds: u64_list(axes, "seeds", vec![1])?,
            jobs_scale_load_baseline: opt_usize(axes, "scale_load_with_jobs")?,
        };
        // A named topology fixes the whole cluster shape; accepting an
        // explicit cluster block alongside would silently ignore it
        // (max_share, memory, shape), so reject the combination outright
        // — same policy as the gpu_counts conflict in validate().
        if !matches!(doc.get("cluster"), None | Some(Json::Null))
            && !spec.axes.topologies.is_empty()
        {
            bail!(
                "campaign {:?}: the cluster block and the topologies axis are \
                 mutually exclusive (a named topology fixes the whole cluster shape)",
                spec.name
            );
        }
        // Same policy for workloads: a preset fixes the whole trace shape
        // (arrival process, GPU mix, iteration tail), so an explicit
        // trace block alongside would be silently ignored.
        if !matches!(doc.get("trace"), None | Some(Json::Null))
            && !spec.axes.workloads.is_empty()
        {
            bail!(
                "campaign {:?}: the trace block and the workloads axis are \
                 mutually exclusive (a workload preset fixes the whole trace shape)",
                spec.name
            );
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Load and validate a spec from a JSON file.
    pub fn load(path: &Path) -> Result<CampaignSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading campaign spec {}", path.display()))?;
        let doc = Json::parse(&text).context("parsing campaign spec")?;
        Self::from_json(&doc)
    }

    /// Check that every axis is non-empty and every value can actually run.
    pub fn validate(&self) -> Result<()> {
        if self.policies.is_empty() {
            bail!("campaign {:?}: no policies", self.name);
        }
        for p in &self.policies {
            if sched::by_name(p).is_none() {
                bail!(
                    "campaign {:?}: unknown policy {p:?} (known: {})",
                    self.name,
                    sched::POLICY_NAMES.join(", ")
                );
            }
        }
        let a = &self.axes;
        if a.load_factors.is_empty() || a.job_counts.is_empty() || a.seeds.is_empty() {
            bail!("campaign {:?}: load_factors, job_counts and seeds must be non-empty", self.name);
        }
        for &n in &a.job_counts {
            if n == 0 {
                bail!("campaign {:?}: job counts must be > 0", self.name);
            }
        }
        for &l in &a.load_factors {
            if !(l > 0.0) || !l.is_finite() {
                bail!("campaign {:?}: load factor {l} must be finite and > 0", self.name);
            }
        }
        if let Some(x) = self.xi_global {
            if !(x >= 1.0) {
                bail!("campaign {:?}: xi_global {x} must be >= 1.0", self.name);
            }
        }
        if let Some(0) = a.jobs_scale_load_baseline {
            bail!("campaign {:?}: scale_load_with_jobs baseline must be > 0", self.name);
        }
        if self.cluster.servers == 0 || self.cluster.gpus_per_server == 0 {
            bail!("campaign {:?}: degenerate cluster shape", self.name);
        }
        if self.cluster.max_share == 0 {
            bail!("campaign {:?}: max_share must be >= 1", self.name);
        }
        for &c in &a.share_caps {
            if c == 0 {
                bail!("campaign {:?}: share caps must be >= 1", self.name);
            }
        }
        for name in &self.axes.workloads {
            workload::by_name_or_err(name)
                .with_context(|| format!("campaign {:?}", self.name))?;
        }
        for spec in &self.axes.estimators {
            EstimateModel::parse(spec).with_context(|| {
                format!("campaign {:?}: estimator {spec:?}", self.name)
            })?;
        }
        // Every swept cluster shape must be able to host the largest gang
        // any swept workload mix can request (the engine rejects
        // oversized jobs outright). The default philly-sim mix goes up
        // to 16 GPUs; a small-job preset relaxes the floor.
        let min_gpus = if self.axes.workloads.is_empty() {
            16
        } else {
            self.axes
                .workloads
                .iter()
                .map(|name| {
                    workload::by_name(name).expect("validated above").max_gang()
                })
                .max()
                .unwrap_or(16)
        };
        if !a.topologies.is_empty() {
            // A named topology fixes the whole shape — rescaling it by a
            // GPU count has no defined meaning.
            if !a.gpu_counts.is_empty() {
                bail!(
                    "campaign {:?}: the topologies and gpu_counts axes are mutually exclusive",
                    self.name
                );
            }
            for name in &a.topologies {
                let t = topology::by_name_or_err(name)
                    .with_context(|| format!("campaign {:?}", self.name))?;
                if t.total_gpus() < min_gpus {
                    bail!(
                        "campaign {:?}: topology {name:?} ({} GPUs) cannot host the \
                         trace's largest gang ({min_gpus})",
                        self.name,
                        t.total_gpus()
                    );
                }
            }
            return Ok(());
        }
        let sizes: Vec<usize> = if a.gpu_counts.is_empty() {
            vec![self.cluster.total_gpus()]
        } else {
            a.gpu_counts.clone()
        };
        for g in sizes {
            if g % self.cluster.gpus_per_server != 0 {
                bail!(
                    "campaign {:?}: {g} GPUs is not a multiple of gpus_per_server {}",
                    self.name,
                    self.cluster.gpus_per_server
                );
            }
            if g < min_gpus {
                bail!(
                    "campaign {:?}: {g} GPUs cannot host the trace's largest gang ({min_gpus})",
                    self.name
                );
            }
        }
        Ok(())
    }
}

/// One fully-resolved run: everything [`ScenarioSpec::run`] needs to
/// deterministically reproduce a single simulation, independently of any
/// other run — which is what makes the campaign embarrassingly parallel.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub policy: String,
    /// Uniform cluster shape; used when `topology` is `None`, a summary
    /// otherwise.
    pub cluster: ClusterConfig,
    /// Named topology shape ([`topology::by_name`]) overriding `cluster`.
    pub topology: Option<String>,
    /// Share-cap override (the `share_caps` axis); `None` keeps the
    /// resolved cluster's own `max_share`.
    pub share_cap: Option<usize>,
    pub trace: TraceConfig,
    pub xi_global: Option<f64>,
    pub max_sim_s: f64,
}

/// One run's [`Summary`] plus the run-level utilization figures the
/// campaign CSV reports (schema v3), both derived from the engine's
/// always-on busy/shared GPU-second integrals.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub summary: Summary,
    /// Mean GPU utilization: busy GPU-seconds / (total GPUs × makespan);
    /// 0 for a degenerate (empty) run.
    pub gpu_util: f64,
    /// Fraction of busy GPU-time spent co-located: shared GPU-seconds /
    /// busy GPU-seconds; 0 when nothing ran.
    pub sharing_frac: f64,
}

impl ScenarioSpec {
    /// The cluster this scenario runs on.
    pub fn build_cluster(&self) -> Result<Cluster> {
        let cluster = match &self.topology {
            Some(name) => Cluster::with_topology(topology::by_name_or_err(name)?),
            None => Cluster::new(self.cluster),
        };
        Ok(match self.share_cap {
            Some(cap) => cluster.with_max_share(cap),
            None => cluster,
        })
    }

    /// Generate the trace, construct a fresh policy, and simulate.
    pub fn run(&self) -> Result<Summary> {
        self.run_with_trace(&trace::generate(&self.trace))
    }

    /// [`ScenarioSpec::run`] over a pre-generated trace — the campaign
    /// runner's hot path, where one generation is shared across the
    /// policy axis ([`super::sweep::SharedTrace`]). `jobs` must equal
    /// `trace::generate(&self.trace)`: sharing is pure memoization, so
    /// the campaign's parallel == serial byte-identity guarantee (and
    /// every golden test) is unaffected. Policy and cluster are still
    /// constructed fresh per run.
    pub fn run_with_trace(&self, jobs: &[JobSpec]) -> Result<Summary> {
        Ok(self.run_with_trace_obs(jobs, Obs::disabled())?.summary)
    }

    /// [`ScenarioSpec::run_with_trace`] with an observability sink
    /// attached and the run-level utilization figures returned alongside
    /// the summary. A disabled `obs` is free; the caller owns the handle
    /// and is responsible for [`Obs::finish`].
    pub fn run_with_trace_obs(&self, jobs: &[JobSpec], obs: Obs) -> Result<RunResult> {
        let mut policy = sched::by_name(&self.policy)
            .with_context(|| format!("unknown policy {:?}", self.policy))?;
        let xi = match self.xi_global {
            Some(x) => InterferenceModel::with_global(x),
            None => InterferenceModel::new(),
        };
        let engine_cfg = EngineConfig { max_sim_s: self.max_sim_s, ..EngineConfig::default() };
        let cluster = self.build_cluster()?;
        let out =
            engine::run_cluster_obs(cluster, jobs, xi, policy.as_mut(), engine_cfg, obs)
                .with_context(|| {
                    format!(
                        "policy {} on {} jobs (seed {}, load x{})",
                        self.policy, self.trace.n_jobs, self.trace.seed, self.trace.load_factor
                    )
                })?;
        let capacity = out.total_gpus as f64 * out.makespan_s;
        let gpu_util = if capacity > 0.0 { out.busy_gpu_s / capacity } else { 0.0 };
        let sharing_frac =
            if out.busy_gpu_s > 0.0 { out.shared_gpu_s / out.busy_gpu_s } else { 0.0 };
        Ok(RunResult {
            summary: metrics::summarize(&self.policy, &out.jobs, out.makespan_s),
            gpu_util,
            sharing_frac,
        })
    }
}

// ---------------------------------------------------- JSON field helpers

fn opt_f64(j: &Json, key: &str) -> Result<Option<f64>> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(
            v.as_f64().with_context(|| format!("{key} must be a number"))?,
        )),
    }
}

fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>> {
    Ok(opt_u64(j, key)?.map(|x| x as usize))
}

fn opt_u64(j: &Json, key: &str) -> Result<Option<u64>> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(
            v.as_u64()
                .with_context(|| format!("{key} must be a non-negative integer"))?,
        )),
    }
}

fn f64_list(j: &Json, key: &str, default: Vec<f64>) -> Result<Vec<f64>> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v
            .as_arr()
            .with_context(|| format!("{key} must be an array"))?
            .iter()
            .map(|x| x.as_f64().with_context(|| format!("{key} entries must be numbers")))
            .collect(),
    }
}

fn usize_list(j: &Json, key: &str, default: Vec<usize>) -> Result<Vec<usize>> {
    Ok(u64_list(j, key, default.iter().map(|&x| x as u64).collect())?
        .into_iter()
        .map(|x| x as usize)
        .collect())
}

fn u64_list(j: &Json, key: &str, default: Vec<u64>) -> Result<Vec<u64>> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v
            .as_arr()
            .with_context(|| format!("{key} must be an array"))?
            .iter()
            .map(|x| {
                x.as_u64()
                    .with_context(|| format!("{key} entries must be non-negative integers"))
            })
            .collect(),
    }
}

fn str_list(j: &Json, key: &str, default: Vec<String>) -> Result<Vec<String>> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v
            .as_arr()
            .with_context(|| format!("{key} must be an array"))?
            .iter()
            .map(|x| {
                x.as_str()
                    .map(str::to_string)
                    .with_context(|| format!("{key} entries must be strings"))
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_validates() {
        let spec = CampaignSpec::paper_preset();
        spec.validate().unwrap();
        assert_eq!(spec.policies.len(), 6);
        assert_eq!(spec.axes.job_counts, vec![120, 240, 360, 480]);
        assert_eq!(spec.axes.jobs_scale_load_baseline, Some(240));
    }

    #[test]
    fn validate_rejects_unknown_policy() {
        let mut spec = CampaignSpec::new("x");
        spec.policies = vec!["NoSuchPolicy".to_string()];
        assert!(spec.validate().is_err());
    }

    #[test]
    fn validate_rejects_empty_axes() {
        let mut spec = CampaignSpec::new("x");
        spec.policies = vec!["FIFO".to_string()];
        spec.axes.seeds = Vec::new();
        assert!(spec.validate().is_err());
    }

    #[test]
    fn validate_rejects_tiny_cluster() {
        let mut spec = CampaignSpec::new("x");
        spec.policies = vec!["FIFO".to_string()];
        spec.axes.gpu_counts = vec![8]; // cannot host a 16-GPU gang
        assert!(spec.validate().is_err());
    }

    #[test]
    fn validate_topology_axis() {
        let mut spec = CampaignSpec::new("x");
        spec.policies = vec!["FIFO".to_string()];
        spec.axes.topologies = vec!["uniform-16x4".to_string()];
        spec.validate().unwrap();
        // Unknown shape names are rejected with the known list.
        spec.axes.topologies = vec!["no-such-shape".to_string()];
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("unknown topology shape"), "{err}");
        assert!(err.contains("uniform-16x4"), "{err}");
        // A topology fixes the shape: combining with gpu_counts is an error.
        spec.axes.topologies = vec!["uniform-16x4".to_string()];
        spec.axes.gpu_counts = vec![64];
        assert!(spec.validate().is_err());
    }

    #[test]
    fn validate_workloads_and_estimators_axes() {
        let mut spec = CampaignSpec::new("x");
        spec.policies = vec!["FIFO".to_string()];
        spec.axes.workloads = vec!["small-job-flood".to_string()];
        spec.axes.estimators = vec!["noisy:0.5".to_string(), "percentile:90".to_string()];
        spec.validate().unwrap();
        // A small-job preset (max gang 4) relaxes the 16-GPU floor.
        spec.axes.gpu_counts = vec![8];
        spec.validate().unwrap();
        spec.axes.gpu_counts.clear();
        // Unknown names/specs are rejected with the known lists.
        spec.axes.workloads = vec!["no-such-workload".to_string()];
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("unknown workload preset"), "{err}");
        assert!(err.contains("philly-sim"), "{err}");
        spec.axes.workloads = vec!["philly-sim".to_string()];
        spec.axes.estimators = vec!["noisy".to_string()];
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("noisy estimator needs a sigma"), "{err}");
    }

    #[test]
    fn validate_share_caps_axis() {
        let mut spec = CampaignSpec::new("x");
        spec.policies = vec!["SJF-BSBF-k".to_string()];
        spec.axes.share_caps = vec![2, 3, 4];
        spec.validate().unwrap();
        spec.axes.share_caps = vec![0];
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("share caps must be >= 1"), "{err}");
    }

    #[test]
    fn scenario_share_cap_overrides_cluster() {
        use crate::cluster::AllocView;
        let scenario = ScenarioSpec {
            policy: "SJF-FFS".to_string(),
            cluster: ClusterConfig::physical(),
            topology: None,
            share_cap: Some(3),
            trace: TraceConfig::simulation(8, 3),
            xi_global: None,
            max_sim_s: EngineConfig::default().max_sim_s,
        };
        let cluster = scenario.build_cluster().unwrap();
        assert_eq!(cluster.max_share(), 3);
        // Topology-resolved clusters honor the override too.
        let topo = ScenarioSpec {
            topology: Some("hetero-16x4-2tier".to_string()),
            ..scenario
        };
        assert_eq!(topo.build_cluster().unwrap().max_share(), 3);
    }

    #[test]
    fn scenario_run_produces_summary() {
        let scenario = ScenarioSpec {
            policy: "FIFO".to_string(),
            cluster: ClusterConfig::physical(),
            topology: None,
            share_cap: None,
            trace: TraceConfig::simulation(12, 3),
            xi_global: None,
            max_sim_s: EngineConfig::default().max_sim_s,
        };
        let s = scenario.run().unwrap();
        assert_eq!(s.policy, "FIFO");
        assert_eq!(s.all.n, 12);
        assert!(s.all.avg_jct_s > 0.0);
    }

    #[test]
    fn scenario_obs_run_reports_utilization() {
        let scenario = ScenarioSpec {
            policy: "FIFO".to_string(),
            cluster: ClusterConfig::physical(),
            topology: None,
            share_cap: None,
            trace: TraceConfig::simulation(12, 3),
            xi_global: None,
            max_sim_s: EngineConfig::default().max_sim_s,
        };
        let jobs = trace::generate(&scenario.trace);
        let r = scenario.run_with_trace_obs(&jobs, Obs::disabled()).unwrap();
        assert!(r.gpu_util > 0.0 && r.gpu_util <= 1.0, "gpu_util {}", r.gpu_util);
        assert!(
            (0.0..=1.0).contains(&r.sharing_frac),
            "sharing_frac {}",
            r.sharing_frac
        );
        // FIFO never shares GPUs, so every busy GPU-second is exclusive.
        assert_eq!(r.sharing_frac, 0.0);
        // The observed summary matches the plain path exactly.
        let plain = scenario.run_with_trace(&jobs).unwrap();
        assert_eq!(plain.all.n, r.summary.all.n);
        assert_eq!(plain.makespan_s, r.summary.makespan_s);
    }

    #[test]
    fn scenario_with_topology_builds_that_cluster() {
        let scenario = ScenarioSpec {
            policy: "FIFO".to_string(),
            cluster: ClusterConfig::physical(),
            topology: Some("hetero-16x4-2tier".to_string()),
            share_cap: None,
            trace: TraceConfig::simulation(8, 3),
            xi_global: None,
            max_sim_s: EngineConfig::default().max_sim_s,
        };
        let cluster = scenario.build_cluster().unwrap();
        assert_eq!(cluster.total_gpus(), 64);
        assert_eq!(cluster.mem_gb(63), 22.0);
        let s = scenario.run().unwrap();
        assert_eq!(s.all.n, 8);
    }
}

//! Campaign result emitters: the paper-style markdown tables (seed-averaged
//! [`crate::report::table34`] blocks plus a confidence-interval table per
//! scenario) and a long-format CSV — one row per (cell, slice, metric) —
//! ready for pandas / gnuplot.

use std::fmt::Write as _;

use crate::report;
use crate::sim::metrics::Summary;

use super::agg::{CellAgg, Stream};

/// CSV schema version comment, emitted as the file's first line. The
/// row/column set has changed four times (topology in the cluster-v2
/// PR, workload/estimator in workload v2, the per-cell `gpu_util` /
/// `sharing_frac` / `unfinished` rows in obskit, the `share_cap` column
/// of the k-way sharing axis — DESIGN.md §17), so consumers pin on
/// this instead of guessing from the shape; bump it whenever it changes.
pub const CSV_SCHEMA: &str = "# schema: v4";

/// Long-format CSV header.
pub const CSV_HEADER: &str = "campaign,topology,workload,estimator,gpus,jobs,load,\
                              share_cap,policy,slice,metric,seeds,mean,std,min,max,ci95";

/// One `(slice, metric)` CSV row per statistic of every cell, in cell
/// (expansion) order. Time metrics are in seconds; `gpu_util`,
/// `sharing_frac` and `unfinished` are a [0,1] ratio, a [0,1] ratio and
/// a job count respectively. The first line is the [`CSV_SCHEMA`]
/// comment (pandas: `read_csv(..., comment='#')`).
pub fn long_csv(campaign: &str, cells: &[CellAgg]) -> String {
    let mut out = String::new();
    writeln!(out, "{CSV_SCHEMA}").unwrap();
    writeln!(out, "{CSV_HEADER}").unwrap();
    for c in cells {
        let base = format!(
            "{campaign},{},{},{},{},{},{},{},{}",
            c.key.topology,
            c.key.workload,
            c.key.estimator,
            c.key.total_gpus,
            c.key.n_jobs,
            c.key.load_factor(),
            c.key.share_cap,
            c.key.policy
        );
        let mut row = |slice: &str, metric: &str, s: &Stream| {
            writeln!(
                out,
                "{base},{slice},{metric},{},{:.6},{:.6},{:.6},{:.6},{:.6}",
                s.n(),
                s.mean(),
                s.std(),
                s.min(),
                s.max(),
                s.ci95()
            )
            .unwrap();
        };
        for (slice, agg) in [("all", &c.all), ("large", &c.large), ("small", &c.small)] {
            row(slice, "avg_jct_s", &agg.avg_jct_s);
            row(slice, "p50_jct_s", &agg.p50_jct_s);
            row(slice, "p90_jct_s", &agg.p90_jct_s);
            row(slice, "avg_queue_s", &agg.avg_queue_s);
        }
        row("all", "makespan_s", &c.makespan_s);
        row("all", "gpu_util", &c.gpu_util);
        row("all", "sharing_frac", &c.sharing_frac);
        row("all", "unfinished", &c.all.unfinished);
    }
    out
}

/// Markdown report: cells grouped per scenario (topology × workload ×
/// estimator × GPUs × jobs × load), each group rendered as a
/// seed-averaged Table III/IV block followed by a 95% CI table, with any
/// per-run failures listed underneath — a topology/workload/estimator-
/// axis campaign therefore reports one block per swept shape.
pub fn markdown(campaign: &str, cells: &[CellAgg]) -> String {
    let mut out = String::new();
    let mut i = 0;
    while i < cells.len() {
        let coords = cells[i].key.scenario_coords();
        let mut j = i;
        while j < cells.len() && cells[j].key.scenario_coords() == coords {
            j += 1;
        }
        let group = &cells[i..j];
        let k = &group[0].key;
        // Per-policy success counts can differ (failed runs drop out), so
        // the header reports the scenario's max; the CI table has the
        // exact per-policy counts.
        let seeds = group.iter().map(CellAgg::seeds).max().unwrap_or(0);
        writeln!(
            out,
            "### {campaign}: {}, {} GPUs, {} jobs, load x{}, C={}, {} workload, \
             {} estimates ({seeds} seed(s))\n",
            k.topology,
            k.total_gpus,
            k.n_jobs,
            k.load_factor(),
            k.share_cap,
            k.workload,
            k.estimator,
        )
        .unwrap();
        // Cells with zero successful runs would render as a (winning!)
        // 0.00-hour row — keep them out of the tables; their failures are
        // listed below.
        let ok: Vec<&CellAgg> = group.iter().filter(|c| c.seeds() > 0).collect();
        if ok.is_empty() {
            out.push_str("_no successful runs in this scenario_\n");
        } else {
            let rows: Vec<Summary> = ok.iter().map(|c| c.mean_summary()).collect();
            out.push_str(&report::table34(&rows));
            out.push('\n');
            let header: Vec<String> =
                ["Policy", "Avg JCT (hrs)", "±95% CI", "Makespan (hrs)", "±95% CI", "Seeds"]
                    .iter()
                    .map(|s| s.to_string())
                    .collect();
            let ci_rows: Vec<Vec<String>> = ok
                .iter()
                .map(|c| {
                    vec![
                        c.key.policy.clone(),
                        format!("{:.2}", c.all.avg_jct_s.mean() / 3600.0),
                        format!("{:.3}", c.all.avg_jct_s.ci95() / 3600.0),
                        format!("{:.2}", c.makespan_s.mean() / 3600.0),
                        format!("{:.3}", c.makespan_s.ci95() / 3600.0),
                        format!("{}", c.seeds()),
                    ]
                })
                .collect();
            out.push_str(&report::markdown_table(&header, &ci_rows));
            // Survivorship warning: the JCT rows above cover finished
            // jobs only, so a cell that left jobs unfinished is not
            // directly comparable and must say so.
            for c in &ok {
                let worst = c.all.unfinished.max();
                if worst > 0.0 {
                    writeln!(
                        out,
                        "\n**{}: up to {worst:.0} job(s) unfinished at \
                         makespan — JCT averages cover finished jobs only.**",
                        c.key.policy
                    )
                    .unwrap();
                }
            }
        }
        for c in group {
            for (ordinal, seed, err) in &c.errors {
                writeln!(
                    out,
                    "- FAILED run #{ordinal} ({}, seed {seed}): {err}",
                    c.key.policy
                )
                .unwrap();
            }
        }
        out.push('\n');
        i = j;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::agg::Aggregator;
    use crate::campaign::runner::RunOutcome;
    use crate::campaign::spec::RunResult;
    use crate::campaign::sweep::CellKey;
    use crate::sim::metrics::Aggregate;

    fn cells_with_unfinished(unfinished: usize) -> Vec<CellAgg> {
        let mut agg = Aggregator::new();
        for (policy, ord) in [("FIFO", 0usize), ("SJF-BSBF", 1)] {
            for seed in [1u64, 2] {
                let a = Aggregate {
                    n: 10,
                    avg_jct_s: 3600.0 * (1.0 + seed as f64),
                    avg_queue_s: 600.0,
                    p50_jct_s: 3000.0,
                    p90_jct_s: 9000.0,
                    unfinished,
                };
                agg.push(&RunOutcome {
                    ordinal: ord * 2 + seed as usize - 1,
                    cell: CellKey {
                        topology: "uniform-16x4".to_string(),
                        workload: "philly-sim".to_string(),
                        estimator: "oracle".to_string(),
                        total_gpus: 64,
                        n_jobs: 240,
                        load_milli: 1500,
                        share_cap: 2,
                        policy: policy.to_string(),
                    },
                    seed,
                    summary: Ok(RunResult {
                        summary: Summary {
                            policy: policy.to_string(),
                            makespan_s: 7200.0,
                            all: a,
                            large: a,
                            small: a,
                        },
                        gpu_util: 0.8,
                        sharing_frac: 0.1,
                    }),
                });
            }
        }
        agg.finish()
    }

    fn cells() -> Vec<CellAgg> {
        cells_with_unfinished(0)
    }

    #[test]
    fn csv_is_long_format_with_header() {
        let csv = long_csv("demo", &cells());
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], CSV_SCHEMA, "schema comment must be the first line");
        assert_eq!(lines[1], CSV_HEADER);
        // 2 cells x (3 slices x 4 metrics + makespan + gpu_util +
        // sharing_frac + unfinished) = 32 data rows.
        assert_eq!(lines.len(), 2 + 2 * 16);
        assert!(lines[2].starts_with(
            "demo,uniform-16x4,philly-sim,oracle,64,240,1.5,2,FIFO,all,avg_jct_s,2,"
        ));
        assert!(csv.contains("SJF-BSBF,all,makespan_s"));
        assert!(csv.contains("FIFO,all,gpu_util,2,0.800000"));
        assert!(csv.contains("FIFO,all,sharing_frac,2,0.100000"));
        assert!(csv.contains("FIFO,all,unfinished,2,0.000000"));
    }

    #[test]
    fn markdown_groups_and_reports_ci() {
        let md = markdown("demo", &cells());
        assert!(md.contains(
            "### demo: uniform-16x4, 64 GPUs, 240 jobs, load x1.5, C=2, philly-sim \
             workload, oracle estimates (2 seed(s))"
        ));
        // One table34 block: both policies appear in the JCT rows.
        assert!(md.contains("| Average JCT | FIFO |"));
        assert!(md.contains("| Average JCT | SJF-BSBF |"));
        // CI table header and a CI value: mean JCT = 2.5h, ci95 > 0.
        assert!(md.contains("±95% CI"));
        assert!(md.contains("| FIFO | 2.50 |"));
        assert!(!md.contains("FAILED"));
        // No unfinished jobs anywhere: no survivorship warning.
        assert!(!md.contains("unfinished"));
    }

    #[test]
    fn markdown_warns_on_unfinished_jobs() {
        let md = markdown("demo", &cells_with_unfinished(3));
        assert!(
            md.contains("FIFO: up to 3 job(s) unfinished at makespan"),
            "{md}"
        );
    }

    #[test]
    fn markdown_lists_failures() {
        let mut agg = Aggregator::new();
        agg.push(&RunOutcome {
            ordinal: 4,
            cell: CellKey {
                topology: "uniform-16x4".to_string(),
                workload: "philly-sim".to_string(),
                estimator: "oracle".to_string(),
                total_gpus: 64,
                n_jobs: 120,
                load_milli: 500,
                share_cap: 2,
                policy: "FIFO".to_string(),
            },
            seed: 9,
            summary: Err("deadlock".to_string()),
        });
        let md = markdown("demo", &agg.finish());
        assert!(md.contains("FAILED run #4 (FIFO, seed 9): deadlock"));
    }
}

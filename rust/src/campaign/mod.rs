//! `campaign` — declarative, parallel scenario sweeps with streaming
//! aggregation (DESIGN.md §8).
//!
//! The paper's headline results (Tables II–IV, Fig. 6) are all *sweeps*:
//! policy × load factor × trace size × seed. This subsystem makes those
//! sweeps first-class instead of hand-rolled loops:
//!
//! 1. **Spec** ([`spec`]) — a declarative [`CampaignSpec`] (cluster, trace
//!    shape, interference model, engine limits, policy list, sweep axes —
//!    including a `topologies` axis of named cluster shapes (DESIGN.md
//!    §10) and `workloads` / `estimators` axes of named workload presets
//!    and duration-estimator specs (DESIGN.md §11)), loadable from JSON
//!    via the first-party parser.
//! 2. **Sweep** ([`sweep`]) — cartesian expansion into a deterministic,
//!    ordered run matrix of self-contained [`ScenarioSpec`]s, each point
//!    carrying its cell group's lazily-generated [`SharedTrace`] (one
//!    trace generation per (cell, seed) group, reused across the whole
//!    policy axis).
//! 3. **Runner** ([`runner`]) — a `std::thread` worker pool; runs are
//!    embarrassingly parallel (fresh policy + cluster per run, shared
//!    immutable trace) and outcomes return in expansion order regardless
//!    of completion order.
//! 4. **Aggregation** ([`agg`]) — streaming Welford statistics per sweep
//!    cell over the seed axis: mean/std/min/max + normal-approx 95% CIs
//!    for avg/p50/p90 JCT, queueing delay and makespan.
//! 5. **Emitters** ([`emit`]) — the existing `report` markdown tables
//!    (seed-averaged) plus a long-format CSV.
//!
//! Entry points: `wise-share campaign --spec FILE` / `--preset paper` on
//! the CLI, or [`execute`] / [`execute_serial`] from code (see
//! `examples/large_scale_sim.rs` and `examples/workload_sweep.rs`).

pub mod agg;
pub mod emit;
pub mod runner;
pub mod spec;
pub mod sweep;

pub use agg::{Aggregator, CellAgg, SliceAgg, Stream};
pub use runner::{
    default_threads, resolved_threads, run_parallel, run_parallel_obs, run_serial,
    run_serial_obs, ObsDirs, RunOutcome,
};
pub use spec::{Axes, CampaignSpec, RunResult, ScenarioSpec};
pub use sweep::{expand, CellKey, RunPoint, SharedTrace};

use anyhow::Result;

/// Aggregated output of a whole campaign.
#[derive(Debug)]
pub struct CampaignResult {
    /// Per-cell statistics, in expansion order.
    pub cells: Vec<CellAgg>,
    /// Total runs in the matrix.
    pub n_runs: usize,
    /// Runs that errored (their cells list the details).
    pub n_failures: usize,
    /// Wall-clock spent running the matrix, seconds.
    pub wall_s: f64,
}

fn aggregate(n_runs: usize, outcomes: Vec<RunOutcome>, wall_s: f64) -> CampaignResult {
    let mut agg = Aggregator::new();
    let mut n_failures = 0;
    for o in &outcomes {
        if o.summary.is_err() {
            n_failures += 1;
        }
        agg.push(o);
    }
    CampaignResult { cells: agg.finish(), n_runs, n_failures, wall_s }
}

/// Run an already-expanded matrix in parallel (`threads` = 0 ⇒ auto) and
/// aggregate — for callers that need the [`RunPoint`]s themselves (e.g. to
/// report the matrix size before the run starts).
pub fn execute_matrix(points: &[RunPoint], threads: usize) -> CampaignResult {
    execute_matrix_obs(points, threads, &ObsDirs::default())
}

/// [`execute_matrix`] with per-run observability artifacts written into
/// the directories named by `obs_dirs` (one file per matrix ordinal).
pub fn execute_matrix_obs(
    points: &[RunPoint],
    threads: usize,
    obs_dirs: &ObsDirs,
) -> CampaignResult {
    let t0 = std::time::Instant::now();
    let outcomes = run_parallel_obs(points, threads, obs_dirs);
    aggregate(points.len(), outcomes, t0.elapsed().as_secs_f64())
}

/// Expand, run in parallel (`threads` = 0 ⇒ auto), aggregate.
pub fn execute(spec: &CampaignSpec, threads: usize) -> Result<CampaignResult> {
    let points = expand(spec)?;
    Ok(execute_matrix(&points, threads))
}

/// Expand, run serially on the calling thread, aggregate. The reference
/// path the parallel runner is property-tested against.
pub fn execute_serial(spec: &CampaignSpec) -> Result<CampaignResult> {
    let points = expand(spec)?;
    let t0 = std::time::Instant::now();
    let outcomes = run_serial(&points);
    Ok(aggregate(points.len(), outcomes, t0.elapsed().as_secs_f64()))
}

//! Campaign subsystem correctness (the properties the subsystem is allowed
//! to be trusted on):
//!
//! * sweep expansion is deterministic and duplicate-free,
//! * the parallel runner's output is byte-identical to serial execution of
//!   the same matrix (every emitter, every cell),
//! * aggregation over identical seeds yields exactly zero variance,
//! * JSON specs parse into the same matrices as programmatic ones.

use std::collections::HashSet;
use std::sync::Arc;

use wise_share::campaign::{self, Axes, CampaignSpec, RunPoint};
use wise_share::cluster::ClusterConfig;
use wise_share::prop_assert;
use wise_share::util::json::Json;
use wise_share::util::prop::forall;

/// A cheap campaign: 16-GPU cluster (the simulation trace never requests
/// more than 16 GPUs, so every job still fits), small traces.
fn small_spec(policies: &[&str], job_counts: Vec<usize>, seeds: Vec<u64>) -> CampaignSpec {
    let mut spec = CampaignSpec::new("test");
    spec.cluster = ClusterConfig::physical();
    spec.policies = policies.iter().map(|s| s.to_string()).collect();
    spec.axes = Axes {
        load_factors: vec![1.0],
        job_counts,
        gpu_counts: Vec::new(),
        topologies: Vec::new(),
        workloads: Vec::new(),
        estimators: Vec::new(),
        share_caps: Vec::new(),
        seeds,
        jobs_scale_load_baseline: None,
    };
    spec
}

fn fingerprints(points: &[RunPoint]) -> Vec<String> {
    points
        .iter()
        .map(|p| format!("{}|{:?}|{}", p.ordinal, p.cell, p.scenario.trace.seed))
        .collect()
}

#[test]
fn expansion_deterministic_and_duplicate_free() {
    let spec = CampaignSpec::paper_preset();
    let a = campaign::expand(&spec).unwrap();
    let b = campaign::expand(&spec).unwrap();
    // 4 job counts x 1 load x 6 policies x 3 seeds.
    assert_eq!(a.len(), 4 * 6 * 3);
    assert_eq!(fingerprints(&a), fingerprints(&b));
    let uniq: HashSet<String> = fingerprints(&a)
        .into_iter()
        .map(|fp| fp.splitn(2, '|').nth(1).unwrap().to_string())
        .collect();
    assert_eq!(uniq.len(), a.len(), "duplicate (cell, seed) run points");
    for (i, p) in a.iter().enumerate() {
        assert_eq!(p.ordinal, i, "ordinals must be dense expansion positions");
    }
}

#[test]
fn prop_expansion_matrix_size_and_uniqueness() {
    forall("expansion-matrix", 0xCA, 32, |rng| {
        let base = rng.next_u64() % 1_000_000;
        let seeds: Vec<u64> = (0..1 + rng.index(3)).map(|i| base + i as u64).collect();
        let jobs: Vec<usize> = [16usize, 24, 40][..1 + rng.index(3)].to_vec();
        let loads: Vec<f64> = [0.75, 1.5][..1 + rng.index(2)].to_vec();
        let pols: Vec<&str> = ["FIFO", "SJF"][..1 + rng.index(2)].to_vec();
        let mut spec = small_spec(&pols, jobs.clone(), seeds.clone());
        spec.axes.load_factors = loads.clone();
        let pts = campaign::expand(&spec).map_err(|e| e.to_string())?;
        prop_assert!(
            pts.len() == jobs.len() * loads.len() * pols.len() * seeds.len(),
            "matrix size {} != axis product",
            pts.len()
        );
        let uniq: HashSet<String> = pts
            .iter()
            .map(|p| format!("{:?}|{}", p.cell, p.scenario.trace.seed))
            .collect();
        prop_assert!(uniq.len() == pts.len(), "duplicates in expansion");
        Ok(())
    });
}

#[test]
fn parallel_runner_matches_serial_byte_identical() {
    let spec = small_spec(&["FIFO", "SJF"], vec![24], vec![1, 2, 3]);
    let serial = campaign::execute_serial(&spec).unwrap();
    let parallel = campaign::execute(&spec, 4).unwrap();
    assert_eq!(serial.n_runs, 6);
    assert_eq!(serial.n_failures, 0);
    assert_eq!(parallel.n_failures, 0);
    assert_eq!(
        campaign::emit::long_csv(&spec.name, &serial.cells),
        campaign::emit::long_csv(&spec.name, &parallel.cells),
        "parallel CSV must be byte-identical to serial"
    );
    assert_eq!(
        campaign::emit::markdown(&spec.name, &serial.cells),
        campaign::emit::markdown(&spec.name, &parallel.cells),
        "parallel markdown must be byte-identical to serial"
    );
}

#[test]
fn shared_trace_results_byte_identical_to_per_run_generation() {
    // The trace-sharing hot path (one generation per (cell, seed) group,
    // reused across the policy axis) must be a pure memoization: every
    // emitter's output matches running each scenario standalone, where
    // the trace is regenerated per run.
    let spec = small_spec(&["FIFO", "SJF", "SJF-BSBF"], vec![20], vec![1, 2]);
    let pts = campaign::expand(&spec).unwrap();
    assert_eq!(pts.len(), 6);
    // Policy-axis neighbours of the same seed share one Arc; seeds don't.
    assert!(Arc::ptr_eq(&pts[0].trace, &pts[2].trace));
    assert!(Arc::ptr_eq(&pts[0].trace, &pts[4].trace));
    assert!(!Arc::ptr_eq(&pts[0].trace, &pts[1].trace));
    // Expansion must not have generated anything yet.
    assert!(pts.iter().all(|p| !p.trace.is_generated()));

    let shared = campaign::execute_matrix(&pts, 4);
    assert_eq!(shared.n_failures, 0);
    assert!(pts.iter().all(|p| p.trace.is_generated()));

    let mut agg = campaign::Aggregator::new();
    for p in &pts {
        // Regenerate the trace per run (what the shared path memoizes).
        let jobs = wise_share::jobs::trace::generate(&p.scenario.trace);
        agg.push(&campaign::RunOutcome {
            ordinal: p.ordinal,
            cell: p.cell.clone(),
            seed: p.scenario.trace.seed,
            summary: p
                .scenario
                .run_with_trace_obs(&jobs, wise_share::Obs::disabled())
                .map_err(|e| e.to_string()),
        });
    }
    let per_run = agg.finish();
    assert_eq!(
        campaign::emit::long_csv(&spec.name, &shared.cells),
        campaign::emit::long_csv(&spec.name, &per_run),
        "shared-trace CSV must be byte-identical to per-run generation"
    );
    assert_eq!(
        campaign::emit::markdown(&spec.name, &shared.cells),
        campaign::emit::markdown(&spec.name, &per_run),
        "shared-trace markdown must be byte-identical to per-run generation"
    );
}

#[test]
fn identical_seeds_aggregate_with_zero_variance() {
    // Duplicating a seed on the axis is legal and must collapse to zero
    // spread — same spec ⇒ same trace ⇒ same simulation, exactly.
    let spec = small_spec(&["SJF-BSBF"], vec![20], vec![7, 7, 7]);
    let res = campaign::execute(&spec, 2).unwrap();
    assert_eq!(res.n_runs, 3);
    assert_eq!(res.n_failures, 0);
    assert_eq!(res.cells.len(), 1);
    let c = &res.cells[0];
    assert_eq!(c.seeds(), 3);
    let streams = [
        &c.makespan_s,
        &c.all.avg_jct_s,
        &c.all.avg_queue_s,
        &c.all.p50_jct_s,
        &c.all.p90_jct_s,
        &c.large.avg_jct_s,
        &c.small.avg_jct_s,
    ];
    for s in streams {
        assert_eq!(s.std(), 0.0, "identical seeds must have zero std");
        assert_eq!(s.ci95(), 0.0, "identical seeds must have zero CI");
        assert_eq!(s.min(), s.max(), "identical seeds must have min == max");
    }
    assert!(c.makespan_s.mean() > 0.0);
}

#[test]
fn distinct_seeds_actually_spread() {
    // The dual of the zero-variance property: different seeds produce
    // different traces, so the spread must be strictly positive.
    let spec = small_spec(&["FIFO"], vec![20], vec![1, 2, 3]);
    let res = campaign::execute(&spec, 0).unwrap();
    assert_eq!(res.cells.len(), 1);
    assert!(res.cells[0].all.avg_jct_s.std() > 0.0);
}

#[test]
fn spec_parses_from_json_and_expands() {
    let text = r#"{
      "name": "mini",
      "cluster": {"servers": 4, "gpus_per_server": 4},
      "trace": {"mean_interarrival_s": 12.5, "iter_lo": 100, "iter_hi": 900},
      "xi_global": 1.5,
      "policies": ["FIFO", "SJF-BSBF"],
      "axes": {
        "load_factors": [0.5, 1.0],
        "job_counts": [16],
        "seeds": [1, 2],
        "scale_load_with_jobs": 16
      }
    }"#;
    let spec = CampaignSpec::from_json(&Json::parse(text).unwrap()).unwrap();
    assert_eq!(spec.name, "mini");
    assert_eq!(spec.cluster.total_gpus(), 16);
    assert_eq!(spec.mean_interarrival_s, 12.5);
    assert_eq!(spec.iter_range, (100, 900));
    assert_eq!(spec.xi_global, Some(1.5));
    assert_eq!(spec.axes.jobs_scale_load_baseline, Some(16));
    let pts = campaign::expand(&spec).unwrap();
    assert_eq!(pts.len(), 2 * 2 * 2);
    // 16 jobs on a 16-job baseline: load factors pass through unchanged.
    assert_eq!(pts[0].cell.load_factor(), 0.5);
    assert_eq!(pts[0].scenario.trace.mean_interarrival_s, 12.5);
    assert_eq!(pts[0].scenario.xi_global, Some(1.5));
}

#[test]
fn spec_validation_rejects_bad_inputs() {
    let mut spec = small_spec(&["FIFO"], vec![16], vec![1]);
    spec.policies = vec!["NoSuchPolicy".to_string()];
    assert!(campaign::expand(&spec).is_err());

    let mut spec = small_spec(&["FIFO"], vec![16], vec![1]);
    spec.axes.load_factors = Vec::new();
    assert!(campaign::expand(&spec).is_err());

    let mut spec = small_spec(&["FIFO"], vec![16], vec![1]);
    spec.axes.gpu_counts = vec![13]; // not a multiple of gpus_per_server
    assert!(campaign::expand(&spec).is_err());

    let mut spec = small_spec(&["FIFO"], vec![16], vec![1]);
    spec.xi_global = Some(0.5); // interference ratios are >= 1
    assert!(campaign::expand(&spec).is_err());

    let spec = small_spec(&["FIFO"], vec![0], vec![1]); // empty trace
    assert!(campaign::expand(&spec).is_err());

    let mut spec = small_spec(&["FIFO"], vec![16], vec![1]);
    spec.axes.load_factors = vec![1e-5]; // quantizes to a 0 load cell
    assert!(campaign::expand(&spec).is_err());

    let mut spec = small_spec(&["FIFO"], vec![16], vec![1]);
    spec.axes.load_factors = vec![1.0, 1.0004]; // merge under 1/1000 quantization
    assert!(campaign::expand(&spec).is_err());

    // A wrongly-typed field must error, not silently disappear.
    let text = r#"{
      "policies": ["FIFO"],
      "axes": {"job_counts": [16], "seeds": [1], "scale_load_with_jobs": "240"}
    }"#;
    assert!(CampaignSpec::from_json(&Json::parse(text).unwrap()).is_err());
}

#[test]
fn topology_axis_produces_per_shape_cells() {
    // Two named shapes, one small trace: the campaign must expand one
    // cell per (topology, policy), run both end to end, and report them
    // as separate rows/blocks in every emitter.
    let mut spec = small_spec(&["SJF"], vec![16], vec![1]);
    spec.axes.topologies =
        vec!["uniform-4x4".to_string(), "hetero-16x4-2tier".to_string()];
    let res = campaign::execute(&spec, 0).unwrap();
    assert_eq!(res.n_runs, 2);
    assert_eq!(res.n_failures, 0, "{:?}", res.cells.iter().map(|c| &c.errors).collect::<Vec<_>>());
    assert_eq!(res.cells.len(), 2);
    assert_eq!(res.cells[0].key.topology, "uniform-4x4");
    assert_eq!(res.cells[0].key.total_gpus, 16);
    assert_eq!(res.cells[1].key.topology, "hetero-16x4-2tier");
    assert_eq!(res.cells[1].key.total_gpus, 64);
    let md = campaign::emit::markdown(&spec.name, &res.cells);
    assert!(md.contains("### test: uniform-4x4, 16 GPUs"), "{md}");
    assert!(md.contains("### test: hetero-16x4-2tier, 64 GPUs"), "{md}");
    let csv = campaign::emit::long_csv(&spec.name, &res.cells);
    assert!(
        csv.lines()
            .any(|l| l.starts_with("test,hetero-16x4-2tier,philly-sim,oracle,64,16,1,2,SJF,")),
        "{csv}"
    );
}

#[test]
fn topologies_axis_parses_from_json_and_rejects_unknown_shapes() {
    let text = r#"{
      "name": "shapes",
      "policies": ["FIFO"],
      "axes": {
        "job_counts": [16],
        "seeds": [1],
        "topologies": ["uniform-16x4", "uniform-16x4-nvlink"]
      }
    }"#;
    let spec = CampaignSpec::from_json(&Json::parse(text).unwrap()).unwrap();
    assert_eq!(spec.axes.topologies.len(), 2);
    let pts = campaign::expand(&spec).unwrap();
    assert_eq!(pts.len(), 2);
    assert_eq!(pts[0].cell.topology, "uniform-16x4");
    assert_eq!(pts[1].cell.topology, "uniform-16x4-nvlink");

    let bad = r#"{
      "policies": ["FIFO"],
      "axes": {"job_counts": [16], "seeds": [1], "topologies": ["atlantis"]}
    }"#;
    let err = CampaignSpec::from_json(&Json::parse(bad).unwrap())
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown topology shape"), "{err}");

    // An explicit cluster block would be silently ignored by a topology
    // axis, so the combination is rejected.
    let conflict = r#"{
      "policies": ["FIFO"],
      "cluster": {"servers": 16, "gpus_per_server": 4, "max_share": 1},
      "axes": {"job_counts": [16], "seeds": [1], "topologies": ["uniform-16x4"]}
    }"#;
    let err = CampaignSpec::from_json(&Json::parse(conflict).unwrap())
        .unwrap_err()
        .to_string();
    assert!(err.contains("mutually exclusive"), "{err}");
}

#[test]
fn csv_carries_schema_v4_header() {
    // The row/column set has changed four times (topology, then
    // workload/estimator, then the obskit utilization rows, then the
    // share_cap column) — downstream consumers pin on the schema comment,
    // so its presence and position are part of the emitter's contract.
    let spec = small_spec(&["FIFO"], vec![12], vec![1]);
    let res = campaign::execute(&spec, 0).unwrap();
    let csv = campaign::emit::long_csv(&spec.name, &res.cells);
    let mut lines = csv.lines();
    assert_eq!(lines.next(), Some("# schema: v4"));
    assert_eq!(lines.next(), Some(campaign::emit::CSV_HEADER));
    assert!(campaign::emit::CSV_HEADER.starts_with("campaign,topology,workload,estimator,"));
    assert!(campaign::emit::CSV_HEADER.contains(",share_cap,policy,"));
    // The v3 rows are present for every cell.
    for metric in ["gpu_util", "sharing_frac", "unfinished"] {
        assert!(
            csv.lines().any(|l| l.contains(&format!(",all,{metric},"))),
            "missing {metric} row in:\n{csv}"
        );
    }
}

#[test]
fn workloads_and_estimators_axes_run_end_to_end() {
    // A bursty small-job preset under a noisy estimator: the campaign
    // must expand one cell per (workload, estimator), run end to end on
    // the 16-GPU cluster (flood gangs are ≤ 4 GPUs) and report the new
    // coordinates in every emitter.
    let mut spec = small_spec(&["SJF-BSBF"], vec![24], vec![1]);
    spec.axes.workloads = vec!["small-job-flood".to_string()];
    spec.axes.estimators = vec!["oracle".to_string(), "noisy:1.0".to_string()];
    let res = campaign::execute(&spec, 0).unwrap();
    assert_eq!(res.n_runs, 2);
    assert_eq!(
        res.n_failures,
        0,
        "{:?}",
        res.cells.iter().map(|c| &c.errors).collect::<Vec<_>>()
    );
    assert_eq!(res.cells.len(), 2);
    assert_eq!(res.cells[0].key.workload, "small-job-flood");
    assert_eq!(res.cells[0].key.estimator, "oracle");
    assert_eq!(res.cells[1].key.estimator, "noisy:1");
    let md = campaign::emit::markdown(&spec.name, &res.cells);
    assert!(md.contains("small-job-flood workload"), "{md}");
    assert!(md.contains("oracle estimates"), "{md}");
    assert!(md.contains("noisy:1 estimates"), "{md}");
    let csv = campaign::emit::long_csv(&spec.name, &res.cells);
    assert!(
        csv.lines()
            .any(|l| l.starts_with("test,uniform-4x4,small-job-flood,noisy:1,16,24,1,2,SJF-BSBF,")),
        "{csv}"
    );
}

#[test]
fn workloads_axis_parses_from_json_and_rejects_conflicts() {
    let text = r#"{
      "name": "mix",
      "policies": ["FIFO"],
      "axes": {
        "job_counts": [16],
        "seeds": [1],
        "workloads": ["philly-sim", "helios-heavy-tail"],
        "estimators": ["oracle", "percentile:50"]
      }
    }"#;
    let spec = CampaignSpec::from_json(&Json::parse(text).unwrap()).unwrap();
    assert_eq!(spec.axes.workloads.len(), 2);
    assert_eq!(spec.axes.estimators.len(), 2);
    let pts = campaign::expand(&spec).unwrap();
    assert_eq!(pts.len(), 2 * 2);
    assert_eq!(pts[0].cell.workload, "philly-sim");
    assert_eq!(pts[3].cell.workload, "helios-heavy-tail");
    assert_eq!(pts[3].cell.estimator, "percentile:50");

    // Unknown preset names are rejected with the known list.
    let bad = r#"{
      "policies": ["FIFO"],
      "axes": {"job_counts": [16], "seeds": [1], "workloads": ["atlantis"]}
    }"#;
    let err = CampaignSpec::from_json(&Json::parse(bad).unwrap())
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown workload preset"), "{err}");

    // Malformed estimator specs are rejected at parse time.
    let bad_est = r#"{
      "policies": ["FIFO"],
      "axes": {"job_counts": [16], "seeds": [1], "estimators": ["noisy:x"]}
    }"#;
    assert!(CampaignSpec::from_json(&Json::parse(bad_est).unwrap()).is_err());

    // A trace block would be silently ignored by a workloads axis, so
    // the combination is rejected (same policy as cluster/topologies).
    let conflict = r#"{
      "policies": ["FIFO"],
      "trace": {"mean_interarrival_s": 10.0},
      "axes": {"job_counts": [16], "seeds": [1], "workloads": ["philly-sim"]}
    }"#;
    let err = CampaignSpec::from_json(&Json::parse(conflict).unwrap())
        .unwrap_err()
        .to_string();
    assert!(err.contains("mutually exclusive"), "{err}");
}

#[test]
fn paper_preset_covers_tables_and_fig6a() {
    let spec = CampaignSpec::paper_preset();
    let pts = campaign::expand(&spec).unwrap();
    assert_eq!(pts.len(), 4 * 6 * 3);
    assert!(pts.iter().all(|p| p.cell.total_gpus == 64));
    // Table III cell: 240 jobs at x1 density; Table IV: 480 jobs at x2.
    assert!(pts.iter().any(|p| p.cell.n_jobs == 240 && p.cell.load_factor() == 1.0));
    assert!(pts.iter().any(|p| p.cell.n_jobs == 480 && p.cell.load_factor() == 2.0));
    // Fig. 6a light-load end: 120 jobs at x0.5.
    assert!(pts.iter().any(|p| p.cell.n_jobs == 120 && p.cell.load_factor() == 0.5));
}

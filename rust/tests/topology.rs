//! Cluster-v2 back-compat and placement properties.
//!
//! * **Uniform-topology golden test**: a `Topology` built from the flat
//!   `ClusterConfig::simulation()` must yield *byte-identical*
//!   `SimOutcome`s to the flat-config path for all seven policies on the
//!   240-job paper trace — the refactor's equivalence guarantee (the
//!   placed Eq. 2/4/7 arithmetic reproduces the placement-agnostic
//!   formulas bit-for-bit under reference tiers, and the overlay planning
//!   view reproduces the old clone-based policy passes exactly).
//! * **Placement properties**: a gang never spans more servers than
//!   necessary when one server can host it; the incrementally maintained
//!   free/one-job occupancy classes stay disjoint and agree with a
//!   from-scratch rescan under random allocate/release churn; the overlay
//!   planning view agrees with a mutated clone under random plan ops.
//! * **Heterogeneity**: gang span measurably changes pair-JCT estimates,
//!   and heterogeneous campaign cells simulate end to end.

use wise_share::cluster::topology::{self, Topology};
use wise_share::cluster::{placement, AllocView, Cluster, ClusterConfig};
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::jobs::JobState;
use wise_share::pair::batch_size_scaling_placed;
use wise_share::perf::interference::InterferenceModel;
use wise_share::perf::profiles::ModelKind;
use wise_share::prop_assert;
use wise_share::sched::{self, POLICY_NAMES};
use wise_share::sim::engine::{self, EngineConfig, SimOutcome};
use wise_share::util::prop::forall;

/// Every observable of an outcome, with f64s captured as raw bits so the
/// comparison is byte-exact, not epsilon-close.
fn fingerprint(out: &SimOutcome) -> Vec<(u64, u64, u64, u64, u32, Vec<usize>, u8)> {
    out.jobs
        .iter()
        .map(|j| {
            (
                j.finish_s.unwrap_or(f64::NAN).to_bits(),
                j.first_start_s.unwrap_or(f64::NAN).to_bits(),
                j.queued_s.to_bits(),
                j.remaining_iters.to_bits(),
                j.accum_step,
                j.gpus_held.clone(),
                match j.state {
                    JobState::Pending => 0,
                    JobState::Running => 1,
                    JobState::Preempted => 2,
                    JobState::Finished => 3,
                },
            )
        })
        .collect()
}

#[test]
fn golden_uniform_topology_is_byte_identical_for_all_policies() {
    let jobs = trace::generate(&TraceConfig::simulation(240, 1));
    for name in POLICY_NAMES {
        let mut p1 = sched::by_name(name).unwrap();
        let flat = engine::run(
            ClusterConfig::simulation(),
            &jobs,
            InterferenceModel::new(),
            p1.as_mut(),
        )
        .unwrap();
        let mut p2 = sched::by_name(name).unwrap();
        let topo = engine::run_cluster(
            Cluster::with_topology(Topology::uniform(16, 4, 11.0)),
            &jobs,
            InterferenceModel::new(),
            p2.as_mut(),
            EngineConfig::default(),
        )
        .unwrap();
        assert_eq!(
            flat.makespan_s.to_bits(),
            topo.makespan_s.to_bits(),
            "{name}: makespan diverged"
        );
        assert_eq!(flat.policy_calls, topo.policy_calls, "{name}: policy calls");
        assert_eq!(flat.preemptions, topo.preemptions, "{name}: preemptions");
        assert_eq!(fingerprint(&flat), fingerprint(&topo), "{name}: job records diverged");
    }
}

#[test]
fn named_uniform_shape_matches_flat_config_too() {
    // The registry's "uniform-16x4" is the same topology `from_config`
    // builds — one 60-job spot check through SJF-BSBF.
    let jobs = trace::generate(&TraceConfig::simulation(60, 7));
    let mut p1 = sched::by_name("SJF-BSBF").unwrap();
    let flat = engine::run(
        ClusterConfig::simulation(),
        &jobs,
        InterferenceModel::new(),
        p1.as_mut(),
    )
    .unwrap();
    let mut p2 = sched::by_name("SJF-BSBF").unwrap();
    let named = engine::run_cluster(
        Cluster::with_topology(topology::by_name("uniform-16x4").unwrap()),
        &jobs,
        InterferenceModel::new(),
        p2.as_mut(),
        EngineConfig::default(),
    )
    .unwrap();
    assert_eq!(fingerprint(&flat), fingerprint(&named));
}

#[test]
fn hetero_topology_simulates_end_to_end() {
    // Every policy completes the trace on the heterogeneous 2-tier shape
    // (per-type memory budgets + spans threaded through perf and apply).
    let jobs = trace::generate(&TraceConfig::simulation(40, 3));
    for name in POLICY_NAMES {
        let mut p = sched::by_name(name).unwrap();
        let out = engine::run_cluster(
            Cluster::with_topology(topology::by_name("hetero-16x4-2tier").unwrap()),
            &jobs,
            InterferenceModel::new(),
            p.as_mut(),
            EngineConfig::default(),
        )
        .unwrap_or_else(|e| panic!("{name} on hetero topology: {e:#}"));
        for j in &out.jobs {
            assert_eq!(j.state, JobState::Finished, "{name}: job {} unfinished", j.spec.id);
        }
    }
}

#[test]
fn gang_span_changes_pair_jct_estimates() {
    let topo = topology::by_name("hetero-16x4-2tier").unwrap();
    let mk = |id, model, batch| {
        wise_share::jobs::JobRecord::new(wise_share::jobs::JobSpec {
            id,
            model,
            gpus: 4,
            iterations: 2000,
            batch,
            arrival_s: 0.0,
            est_factor: 1.0,
        })
    };
    let running = mk(0, ModelKind::ImageNet, 32);
    let newcomer = mk(1, ModelKind::Ncf, 4096);
    let xi = InterferenceModel::new();
    let consolidated = topo.span_of(&[0, 1, 2, 3]);
    let scattered = topo.span_of(&[0, 4, 8, 12]);
    assert_eq!(consolidated.nodes, 1);
    assert_eq!(scattered.nodes, 4);
    let close = batch_size_scaling_placed(
        &newcomer, &running, 4, 11.0, &xi, true, &consolidated, &consolidated,
    )
    .unwrap();
    let far = batch_size_scaling_placed(
        &newcomer, &running, 4, 11.0, &xi, true, &scattered, &scattered,
    )
    .unwrap();
    assert!(
        close.pair_jct < far.pair_jct,
        "consolidated estimate {:.1}s must beat scattered {:.1}s",
        close.pair_jct,
        far.pair_jct
    );
}

#[test]
fn prop_gang_never_spans_more_servers_than_necessary() {
    forall("placement-minimal-span", 0x705, 128, |rng| {
        // Random occupancy on a random uniform shape.
        let servers = 2 + rng.index(6);
        let per = 2 + rng.index(4);
        let mut cluster =
            Cluster::with_topology(Topology::uniform(servers, per, 11.0));
        let mut job = 0usize;
        for g in 0..cluster.total_gpus() {
            if rng.f64() < 0.45 {
                cluster.allocate(1000 + job, &[g]);
                job += 1;
            }
        }
        let need = 1 + rng.index(per);
        let single_fits =
            (0..servers).any(|s| cluster.server_free(s) >= need);
        match placement::consolidated_free(&cluster, need) {
            Some(gpus) => {
                prop_assert!(gpus.len() == need, "wrong gang size");
                if single_fits {
                    prop_assert!(
                        cluster.servers_spanned(&gpus) == 1,
                        "gang {gpus:?} spans {} servers although one server \
                         has {need} free GPUs",
                        cluster.servers_spanned(&gpus)
                    );
                }
            }
            None => {
                prop_assert!(
                    cluster.free_count() < need,
                    "placement failed with {} >= {need} free GPUs",
                    cluster.free_count()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_occupancy_classes_match_rescan_under_churn() {
    forall("occupancy-incremental", 0x0CC, 96, |rng| {
        let topo = if rng.f64() < 0.5 {
            Topology::uniform(4, 4, 11.0)
        } else {
            topology::by_name("hetero-16x4-2tier").unwrap()
        };
        let mut cluster = Cluster::with_topology(topo);
        let mut live: Vec<usize> = Vec::new();
        for op in 0..60 {
            if !live.is_empty() && rng.f64() < 0.4 {
                let job = live.swap_remove(rng.index(live.len()));
                cluster.release(job);
            } else {
                let want = 1 + rng.index(4);
                let candidates: Vec<usize> = (0..cluster.total_gpus())
                    .filter(|&g| cluster.load(g) < 2)
                    .collect();
                if candidates.len() < want {
                    continue;
                }
                let job = 1000 + op;
                cluster.allocate(job, &candidates[..want]);
                live.push(job);
            }
            // The incremental counts must agree with a from-scratch
            // rescan, and the classes must be disjoint.
            cluster.check_invariants().map_err(|e| format!("op {op}: {e}"))?;
            let free = cluster.free_gpus();
            let one_job = cluster.one_job_gpus();
            prop_assert!(
                cluster.free_count() == free.len()
                    && cluster.one_job_count() == one_job.len(),
                "op {op}: counts diverged from rescan"
            );
            prop_assert!(
                free.iter().all(|g| !one_job.contains(g)),
                "op {op}: free and one-job sets overlap"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_overlay_plan_matches_mutated_clone() {
    forall("overlay-vs-clone", 0x0E1, 64, |rng| {
        let mut base = Cluster::new(ClusterConfig::physical());
        for (job, g) in (0..cluster_prefill(rng)).zip(0..16) {
            base.allocate(500 + job, &[g]);
        }
        let state = wise_share::sim::SimState {
            now: 0.0,
            cluster: base,
            jobs: Vec::new(),
            xi: InterferenceModel::new(),
            not_before: Vec::new(),
            service_gpu_s: Vec::new(),
        };
        let ctx = wise_share::sched_core::SchedContext::from_state(state);
        let mut clone = ctx.cluster.clone();
        let mut plan = ctx.overlay();
        for op in 0..24 {
            if rng.f64() < 0.3 {
                // Release a random known job (base-held or plan-held).
                let job = if rng.f64() < 0.5 { 500 + rng.index(16) } else { 2000 + op };
                clone.release(job);
                plan.release(job);
            } else {
                let want = 1 + rng.index(3);
                let candidates: Vec<usize> =
                    (0..clone.total_gpus()).filter(|&g| clone.load(g) < 2).collect();
                if candidates.len() < want {
                    continue;
                }
                let job = 2000 + op;
                clone.allocate(job, &candidates[..want]);
                plan.allocate(job, &candidates[..want]);
            }
            for g in 0..clone.total_gpus() {
                prop_assert!(
                    plan.load(g) == clone.load(g),
                    "op {op}: load(gpu {g}) {} != clone {}",
                    plan.load(g),
                    clone.load(g)
                );
                prop_assert!(
                    plan.owner(g) == clone.slot(g).jobs.first().copied(),
                    "op {op}: owner(gpu {g}) diverged"
                );
            }
            prop_assert!(
                plan.free_count() == clone.free_count()
                    && plan.one_job_count() == clone.one_job_count(),
                "op {op}: counts diverged"
            );
            prop_assert!(
                plan.free_gpus() == clone.free_gpus()
                    && plan.one_job_gpus() == clone.one_job_gpus(),
                "op {op}: class lists diverged"
            );
        }
        Ok(())
    });
}

fn cluster_prefill(rng: &mut wise_share::util::rng::Rng) -> usize {
    rng.index(10)
}

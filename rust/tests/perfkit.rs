//! perfkit end-to-end: the properties the bench/regression toolkit is
//! trusted on (DESIGN.md §12):
//!
//! * a recorded report survives the JSON file round-trip losslessly and
//!   passes its own `check()` (the CI artifact gate),
//! * baseline comparison distinguishes pass / regress / new / missing and
//!   `gate()` turns regressions into hard errors,
//! * malformed or wrong-schema report files are rejected at load,
//! * a real registered suite (the cheap `figures` quick profile) runs end
//!   to end and produces a valid, serializable report.

use std::path::PathBuf;

use wise_share::perfkit::{self, BenchReport, EnvInfo, Profile, Recorder, SuiteReport};
use wise_share::util::bench::BenchStats;
use wise_share::util::json::Json;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("wise-share-perfkit-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn case(name: &str, min_s: f64, tol: Option<f64>) -> perfkit::CaseStats {
    perfkit::CaseStats {
        stats: BenchStats {
            name: name.to_string(),
            iters: 3,
            mean_s: min_s * 1.1,
            min_s,
            p50_s: min_s * 1.05,
            p95_s: min_s * 1.2,
        },
        max_regress_pct: tol,
        max_drop_pct: None,
        throughput: None,
    }
}

fn tp_case(
    name: &str,
    min_s: f64,
    tol: Option<f64>,
    drop_tol: Option<f64>,
    events_per_s: f64,
    jobs_per_s: f64,
) -> perfkit::CaseStats {
    let mut c = case(name, min_s, tol);
    c.max_drop_pct = drop_tol;
    c.throughput = Some(perfkit::Throughput { events_per_s, jobs_per_s });
    c
}

fn report(profile: &str, cases: Vec<perfkit::CaseStats>) -> BenchReport {
    BenchReport {
        env: EnvInfo {
            profile: profile.to_string(),
            threads: 4,
            git_sha: Some("deadbeef".to_string()),
            os: "linux".to_string(),
        },
        suites: vec![SuiteReport { suite: "s".to_string(), skipped: None, cases }],
    }
}

#[test]
fn recorded_report_roundtrips_through_a_file() {
    let mut rec = Recorder::new("synthetic");
    rec.bench("synthetic/noop", 8, || {
        std::hint::black_box(1 + 1);
    });
    rec.once("synthetic/once", || {
        std::hint::black_box(2 + 2);
    });
    rec.tolerance(75.0);
    let rep = BenchReport {
        env: EnvInfo::capture(Profile::Quick),
        suites: vec![
            rec.finish(),
            SuiteReport {
                suite: "absent".to_string(),
                skipped: Some("environment lacks it".to_string()),
                cases: Vec::new(),
            },
        ],
    };
    rep.check().unwrap();
    let path = tmp("roundtrip.json");
    rep.save(&path).unwrap();
    let back = BenchReport::load(&path).unwrap();
    assert_eq!(rep, back);
    assert_eq!(back.n_cases(), 2);
    assert_eq!(
        back.find("synthetic", "synthetic/once").unwrap().max_regress_pct,
        Some(75.0)
    );
    assert_eq!(back.suites[1].skipped.as_deref(), Some("environment lacks it"));
    perfkit::check_file(&path).unwrap();
}

#[test]
fn baseline_gate_passes_within_and_fails_past_tolerance() {
    let baseline = report(
        "full",
        vec![case("a", 1.0, None), case("noisy", 1.0, Some(60.0)), case("gone", 1.0, None)],
    );
    // +5% on the default gate, +50% under a 60% per-case tolerance, one
    // new case, one missing case: all pass.
    let current = report(
        "full",
        vec![case("a", 1.05, None), case("noisy", 1.5, None), case("fresh", 0.1, None)],
    );
    let cmp = perfkit::compare(&current, &baseline, 10.0).unwrap();
    assert_eq!(
        (cmp.n_passed, cmp.n_regressed, cmp.n_new, cmp.n_missing),
        (2, 0, 1, 1)
    );
    cmp.gate().unwrap();
    // +25% against the 10% default: gate errors and names the case.
    let current = report("full", vec![case("a", 1.25, None)]);
    let cmp = perfkit::compare(&current, &baseline, 10.0).unwrap();
    assert_eq!(cmp.n_regressed, 1);
    let err = cmp.gate().unwrap_err().to_string();
    assert!(err.contains("s/a"), "{err}");
    assert!(err.contains("regressed past the gate"), "{err}");
    // Profiles must match: a quick report cannot gate a full baseline.
    let quick = report("quick", vec![case("a", 1.0, None)]);
    assert!(perfkit::compare(&quick, &baseline, 10.0).is_err());
}

#[test]
fn throughput_gate_honors_per_case_drop_tolerance() {
    // Baseline: wide 80% wall-clock headroom (single-shot noise), tight
    // 25% throughput floor — the scale_xl backlog cases' shape.
    let baseline = report(
        "quick",
        vec![tp_case("xl/backlog", 10.0, Some(80.0), Some(25.0), 200_000.0, 1_000.0)],
    );

    // Within the floor (-10% events/sec): Pass, gate clean.
    let current = report(
        "quick",
        vec![tp_case("xl/backlog", 10.0, None, None, 180_000.0, 1_000.0)],
    );
    let cmp = perfkit::compare(&current, &baseline, 10.0).unwrap();
    assert_eq!((cmp.n_passed, cmp.n_regressed), (1, 0));
    assert!(matches!(cmp.rows[0].verdict, perfkit::Verdict::Pass { .. }));
    cmp.gate().unwrap();

    // Past the floor (-40%) but well inside the 80% wall-clock headroom:
    // RegressThroughput at the 25% drop limit, and the gate errors.
    let current = report(
        "quick",
        vec![tp_case("xl/backlog", 12.0, None, None, 120_000.0, 1_000.0)],
    );
    let cmp = perfkit::compare(&current, &baseline, 10.0).unwrap();
    assert_eq!(cmp.n_regressed, 1);
    assert!(matches!(
        cmp.rows[0].verdict,
        perfkit::Verdict::RegressThroughput { metric: "events_per_s", limit_pct, .. }
            if limit_pct == 25.0
    ));
    let err = cmp.gate().unwrap_err().to_string();
    assert!(err.contains("xl/backlog"), "{err}");
    assert!(err.contains("events_per_s"), "{err}");

    // Round-trip preserves the drop tolerance, so a saved baseline file
    // gates identically to the in-memory one.
    let path = tmp("drop-tol.json");
    baseline.save(&path).unwrap();
    let back = BenchReport::load(&path).unwrap();
    assert_eq!(back, baseline);
    let cmp = perfkit::compare(&current, &back, 10.0).unwrap();
    assert_eq!(cmp.n_regressed, 1);
}

#[test]
fn malformed_report_files_are_rejected() {
    // Truncated JSON.
    let path = tmp("truncated.json");
    std::fs::write(&path, "{\"schema\": \"wise-share-bench-v1\", \"env\"").unwrap();
    assert!(BenchReport::load(&path).is_err());
    assert!(perfkit::check_file(&path).is_err());
    // Valid JSON, wrong schema tag.
    let path = tmp("wrong-schema.json");
    std::fs::write(&path, "{\"schema\": \"somebody-elses-v7\", \"suites\": []}").unwrap();
    let err = BenchReport::load(&path).unwrap_err().to_string();
    assert!(err.contains("unsupported bench schema"), "{err}");
    // Valid schema, no measured cases: loads, but fails the check gate.
    let empty = BenchReport {
        env: EnvInfo::capture(Profile::Quick),
        suites: vec![SuiteReport {
            suite: "s".to_string(),
            skipped: Some("nothing ran".to_string()),
            cases: Vec::new(),
        }],
    };
    let path = tmp("empty.json");
    empty.save(&path).unwrap();
    assert!(BenchReport::load(&path).is_ok());
    // `{:#}` renders the whole anyhow chain — the root cause names the
    // emptiness, the outer context names the file.
    let err = format!("{:#}", perfkit::check_file(&path).unwrap_err());
    assert!(err.contains("no measured cases"), "{err}");
    assert!(err.contains("failed validation"), "{err}");
}

#[test]
fn emitted_json_is_schema_tagged_and_parseable_standalone() {
    let rep = report("quick", vec![case("a", 0.5, None)]);
    let text = rep.to_json().to_string();
    let doc = Json::parse(&text).unwrap();
    assert_eq!(doc.req("schema").unwrap().as_str(), Some(perfkit::SCHEMA));
    let suites = doc.req("suites").unwrap().as_arr().unwrap();
    assert_eq!(suites.len(), 1);
    let c = &suites[0].req("cases").unwrap().as_arr().unwrap()[0];
    assert_eq!(c.req("name").unwrap().as_str(), Some("a"));
    assert_eq!(c.req("min_s").unwrap().as_f64(), Some(0.5));
}

#[test]
fn bench_list_prints_every_registered_suite_and_exits_zero() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_wise-share"))
        .args(["bench", "--list"])
        .output()
        .expect("spawning wise-share");
    assert!(out.status.success(), "bench --list must exit 0");
    let text = String::from_utf8_lossy(&out.stdout);
    for name in perfkit::SUITE_NAMES {
        assert!(text.contains(name), "suite {name:?} missing from:\n{text}");
    }
    assert!(text.contains("profiles: quick, full"), "{text}");
    // The in-process view agrees with the CLI.
    assert_eq!(text.into_owned(), perfkit::list());
}

#[test]
fn figures_quick_suite_runs_and_records() {
    // The cheapest real suite: Figs. 2/3 are closed-form, Fig. 4 is the
    // 30-job physical trace. Proves a registered suite body runs end to
    // end through the same entry the bench binaries and CI use.
    let suite = perfkit::by_name_or_err("figures").unwrap();
    let rep = (suite.run)(Profile::Quick);
    assert!(rep.skipped.is_none());
    let names: Vec<&str> = rep.cases.iter().map(|c| c.stats.name.as_str()).collect();
    assert_eq!(
        names,
        vec![
            "figures/fig2-solo-throughput",
            "figures/fig3-xi-landscape",
            "figures/fig4-physical-cdf"
        ]
    );
    let full = BenchReport { env: EnvInfo::capture(Profile::Quick), suites: vec![rep] };
    full.check().unwrap();
    // And it is self-comparable: a report gates cleanly against itself.
    let cmp = perfkit::compare(&full, &full, 0.0).unwrap();
    assert_eq!(cmp.n_regressed, 0);
    cmp.gate().unwrap();
}

//! Workload-v2 back-compat and arrival-process/estimator properties.
//!
//! * **Golden parity**: the preset-driven generator must reproduce the
//!   pre-v2 generator *byte-for-byte* on the default (`philly-sim`
//!   Poisson × oracle) path — pinned against a frozen inline copy of the
//!   old generator body — and all seven policies must produce
//!   byte-identical outcomes on the 240-job/64-GPU paper trace whether
//!   the oracle or a zero-sigma noisy estimator materialized the
//!   estimates (the estimator plumbing is live either way; `σ = 0` means
//!   `est_factor = exp(0) = 1.0` exactly).
//! * **Statistical properties**: per arrival process, the empirical mean
//!   inter-arrival gap matches the configured rate, sampling is
//!   deterministic per seed, and the diurnal process actually peaks and
//!   troughs at the configured amplitude.
//! * **Estimator liveness**: heavy estimate noise must *change*
//!   scheduling outcomes (the policies really do rank on estimates), and
//!   the context's estimate cache is bit-identical to the truth under
//!   the oracle.

use wise_share::cluster::{Cluster, ClusterConfig};
use wise_share::jobs::estimate::EstimateModel;
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::jobs::workload::{ArrivalProcess, ArrivalSampler};
use wise_share::jobs::{JobRecord, JobSpec, JobState};
use wise_share::perf::interference::InterferenceModel;
use wise_share::perf::profiles::{ModelKind, WorkloadProfile};
use wise_share::sched::{self, POLICY_NAMES};
use wise_share::sched_core::SchedContext;
use wise_share::sim::engine::{self, SimOutcome};
use wise_share::util::rng::Rng;

// ------------------------------------------------------- golden parity

/// Frozen copy of the pre-workload-v2 generator body (the single
/// hard-coded Poisson generator this PR refactored away), kept verbatim
/// so the preset path is pinned against the original bit-for-bit — the
/// same discipline as the cluster-v2 uniform-topology golden test.
fn legacy_generate(
    n_jobs: usize,
    seed: u64,
    mean_interarrival_s: f64,
    gpu_buckets: &[(usize, f64)],
    iter_range: (u64, u64),
    load_factor: f64,
) -> Vec<JobSpec> {
    fn sample_batch(model: ModelKind, rng: &mut Rng) -> u32 {
        let prof = WorkloadProfile::get(model);
        let base = prof.default_batch;
        let want = match rng.index(4) {
            0 => (base / 2).max(1),
            3 => base * 2,
            _ => base,
        };
        prof.mem.max_sub_batch(want, 11.0).unwrap_or(1)
    }
    fn sample_bucket(buckets: &[(usize, f64)], rng: &mut Rng) -> usize {
        let total: f64 = buckets.iter().map(|b| b.1).sum();
        let mut x = rng.f64() * total;
        for &(gpus, w) in buckets {
            if x < w {
                return gpus;
            }
            x -= w;
        }
        buckets.last().unwrap().0
    }
    let mut rng = Rng::seed_from_u64(seed);
    let rate = load_factor / mean_interarrival_s.max(1e-9);
    let (lo, hi) = iter_range;
    let mu = ((lo * 10) as f64).ln();
    let sigma = 1.2;
    let mut t = 0.0f64;
    let mut jobs = Vec::with_capacity(n_jobs);
    for id in 0..n_jobs {
        t += rng.exp(rate);
        let gpus = if gpu_buckets.is_empty() {
            if id < 20 {
                *rng.choose(&[1usize, 2, 4, 8])
            } else {
                *rng.choose(&[12usize, 16])
            }
        } else {
            sample_bucket(gpu_buckets, &mut rng)
        };
        let model = *rng.choose(&ModelKind::ALL);
        let iterations = (rng.lognormal(mu, sigma) as u64).clamp(lo, hi);
        let batch = sample_batch(model, &mut rng);
        jobs.push(JobSpec {
            id,
            model,
            gpus,
            iterations,
            batch,
            arrival_s: t,
            est_factor: 1.0,
        });
    }
    jobs
}

fn assert_traces_bit_identical(a: &[JobSpec], b: &[JobSpec], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: length");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{label}");
        assert_eq!(x.model, y.model, "{label} job {}", x.id);
        assert_eq!(x.gpus, y.gpus, "{label} job {}", x.id);
        assert_eq!(x.iterations, y.iterations, "{label} job {}", x.id);
        assert_eq!(x.batch, y.batch, "{label} job {}", x.id);
        assert_eq!(
            x.arrival_s.to_bits(),
            y.arrival_s.to_bits(),
            "{label} job {}: arrival bits",
            x.id
        );
        assert_eq!(
            x.est_factor.to_bits(),
            y.est_factor.to_bits(),
            "{label} job {}: est_factor bits",
            x.id
        );
    }
}

#[test]
fn golden_preset_generator_matches_frozen_legacy_generator() {
    // The philly-sim simulation shape, across sizes and seeds.
    let philly_buckets: Vec<(usize, f64)> =
        vec![(1, 0.30), (2, 0.25), (4, 0.19), (8, 0.14), (12, 0.06), (16, 0.06)];
    for (n, seed) in [(240usize, 1u64), (64, 17), (480, 3)] {
        let new = trace::generate(&TraceConfig::simulation(n, seed));
        let old = legacy_generate(n, seed, 30.0, &philly_buckets, (500, 50_000), 1.0);
        assert_traces_bit_identical(&new, &old, "simulation");
    }
    // The physical 30-job shape (empty buckets -> 20/10 split).
    let new = trace::generate(&TraceConfig::physical(11));
    let old = legacy_generate(30, 11, 60.0, &[], (100, 5000), 1.0);
    assert_traces_bit_identical(&new, &old, "physical");
    // Load scaling rides the same single exp draw per arrival.
    let mut dense = TraceConfig::simulation(100, 5);
    dense.load_factor = 2.0;
    let new = trace::generate(&dense);
    let old = legacy_generate(100, 5, 30.0, &philly_buckets, (500, 50_000), 2.0);
    assert_traces_bit_identical(&new, &old, "simulation x2 load");
}

/// Every observable of an outcome, f64s as raw bits — byte-exact, not
/// epsilon-close.
fn fingerprint(out: &SimOutcome) -> Vec<(u64, u64, u64, u64, u32, Vec<usize>, u8)> {
    out.jobs
        .iter()
        .map(|j| {
            (
                j.finish_s.unwrap_or(f64::NAN).to_bits(),
                j.first_start_s.unwrap_or(f64::NAN).to_bits(),
                j.queued_s.to_bits(),
                j.remaining_iters.to_bits(),
                j.accum_step,
                j.gpus_held.clone(),
                match j.state {
                    JobState::Pending => 0,
                    JobState::Running => 1,
                    JobState::Preempted => 2,
                    JobState::Finished => 3,
                },
            )
        })
        .collect()
}

#[test]
fn golden_oracle_run_is_byte_identical_for_all_policies() {
    // Oracle vs a zero-sigma noisy estimator on the 240-job/64-GPU paper
    // trace: est_factor = exp(0·N) = 1.0 exactly, so although the noisy
    // materialization path runs, every policy must produce byte-identical
    // per-job outcomes — the workload-v2 equivalence guarantee.
    let oracle_jobs = trace::generate(&TraceConfig::simulation(240, 1));
    let mut noisy_cfg = TraceConfig::simulation(240, 1);
    noisy_cfg.estimator = EstimateModel::Noisy { factor_sigma: 0.0, seed: 0 };
    let noisy_jobs = trace::generate(&noisy_cfg);
    assert_traces_bit_identical(&oracle_jobs, &noisy_jobs, "sigma-0 trace");
    for name in POLICY_NAMES {
        let mut p1 = sched::by_name(name).unwrap();
        let a = engine::run(
            ClusterConfig::simulation(),
            &oracle_jobs,
            InterferenceModel::new(),
            p1.as_mut(),
        )
        .unwrap();
        let mut p2 = sched::by_name(name).unwrap();
        let b = engine::run(
            ClusterConfig::simulation(),
            &noisy_jobs,
            InterferenceModel::new(),
            p2.as_mut(),
        )
        .unwrap();
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{name}: makespan");
        assert_eq!(a.policy_calls, b.policy_calls, "{name}: policy calls");
        assert_eq!(a.preemptions, b.preemptions, "{name}: preemptions");
        assert_eq!(fingerprint(&a), fingerprint(&b), "{name}: job records diverged");
    }
}

#[test]
fn estimated_remaining_is_bit_identical_to_truth_under_oracle() {
    let jobs: Vec<JobRecord> = trace::generate(&TraceConfig::simulation(60, 7))
        .into_iter()
        .map(JobRecord::new)
        .collect();
    let expect: Vec<u64> = jobs.iter().map(|j| j.remaining_solo_runtime().to_bits()).collect();
    let ctx = SchedContext::new(
        Cluster::new(ClusterConfig::simulation()),
        jobs,
        InterferenceModel::new(),
    );
    for (id, bits) in expect.iter().enumerate() {
        assert_eq!(ctx.estimated_remaining(id).to_bits(), *bits, "job {id}");
    }
}

#[test]
fn heavy_estimate_noise_changes_scheduling_outcomes() {
    // The dual of the parity test: the estimator layer must be *live* —
    // with σ = 2 the SJF ranking shuffles and outcomes must diverge from
    // the oracle run of the same trace (completion dynamics still run on
    // the truth, so only the ranking changed).
    let oracle_jobs = trace::generate(&TraceConfig::simulation(60, 7));
    let mut noisy_cfg = TraceConfig::simulation(60, 7);
    noisy_cfg.estimator = EstimateModel::Noisy { factor_sigma: 2.0, seed: 0 };
    let noisy_jobs = trace::generate(&noisy_cfg);
    let run = |jobs: &[JobSpec]| {
        let mut p = sched::by_name("SJF").unwrap();
        engine::run(
            ClusterConfig::physical(),
            jobs,
            InterferenceModel::new(),
            p.as_mut(),
        )
        .unwrap()
    };
    let a = run(&oracle_jobs);
    let b = run(&noisy_jobs);
    assert_ne!(
        fingerprint(&a),
        fingerprint(&b),
        "sigma=2 noise must change SJF's schedule"
    );
    // The truth still drives completions: every job finishes either way.
    for out in [&a, &b] {
        assert!(out.jobs.iter().all(|j| j.state == JobState::Finished));
    }
}

// --------------------------------------- arrival-process statistics

fn arrivals(process: ArrivalProcess, rate: f64, n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::seed_from_u64(seed);
    let mut sampler = ArrivalSampler::new(process, seed);
    (0..n).map(|_| sampler.next_arrival(&mut rng, rate)).collect()
}

#[test]
fn empirical_mean_interarrival_matches_configured_rate() {
    let cases: [(ArrivalProcess, f64, f64); 3] = [
        // (process, base mean gap, relative tolerance)
        (ArrivalProcess::Poisson, 30.0, 0.03),
        (ArrivalProcess::Diurnal { period_s: 5000.0, amplitude: 0.8 }, 10.0, 0.05),
        // Hot 5x for 100 s, cold 0x for 400 s: phase-weighted mean rate
        // is exactly 1x the base (100·5 / 500); MMPP clustering inflates
        // the variance, hence the looser tolerance.
        (
            ArrivalProcess::Bursty {
                mean_on_s: 100.0,
                mean_off_s: 400.0,
                on_factor: 5.0,
                off_factor: 0.0,
            },
            20.0,
            0.10,
        ),
    ];
    for (process, mean_gap, tol) in cases {
        assert!((process.mean_rate_factor() - 1.0).abs() < 1e-12);
        let n = 20_000;
        let ts = arrivals(process.clone(), 1.0 / mean_gap, n, 0xA221);
        let empirical = ts.last().unwrap() / n as f64;
        assert!(
            (empirical - mean_gap).abs() / mean_gap < tol,
            "{process:?}: empirical mean gap {empirical:.2}s vs configured {mean_gap}s"
        );
    }
}

#[test]
fn samplers_are_deterministic_per_seed() {
    for process in [
        ArrivalProcess::Poisson,
        ArrivalProcess::Diurnal { period_s: 2000.0, amplitude: 0.6 },
        ArrivalProcess::Bursty {
            mean_on_s: 60.0,
            mean_off_s: 120.0,
            on_factor: 3.0,
            off_factor: 0.5,
        },
    ] {
        let a = arrivals(process.clone(), 0.05, 500, 42);
        let b = arrivals(process.clone(), 0.05, 500, 42);
        assert_eq!(
            a.iter().map(|t| t.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|t| t.to_bits()).collect::<Vec<_>>(),
            "{process:?} must replay bit-identically per seed"
        );
        let c = arrivals(process, 0.05, 500, 43);
        assert_ne!(a, c, "different seeds must diverge");
    }
}

#[test]
fn diurnal_peaks_and_troughs_at_configured_amplitude() {
    // λ(t) = λ·(1 + 0.8·sin(2πt/T)): the quarter-period around the crest
    // (phase 0.125..0.375) averages 1 + 0.9·0.8 ≈ 1.72×, the one around
    // the trough ≈ 0.28× — a ~6x density ratio. Assert a conservative 2.5x
    // so seed luck cannot flake the test.
    let period = 5000.0;
    let ts = arrivals(
        ArrivalProcess::Diurnal { period_s: period, amplitude: 0.8 },
        0.1,
        30_000,
        0xD1,
    );
    let (mut peak, mut trough) = (0usize, 0usize);
    for t in &ts {
        let phase = (t / period).fract();
        if (0.125..0.375).contains(&phase) {
            peak += 1;
        } else if (0.625..0.875).contains(&phase) {
            trough += 1;
        }
    }
    assert!(
        peak as f64 > 2.5 * trough as f64,
        "peak quarter ({peak}) must be much denser than trough quarter ({trough})"
    );
    // And the troughs are not empty: the rate floor is 0.2λ, not 0.
    assert!(trough > 0);
}

#[test]
fn bursty_arrivals_cluster_more_than_poisson() {
    // MMPP gaps are over-dispersed: their coefficient of variation must
    // exceed the exponential's CV of 1 (hot bursts + long cold silences).
    let gaps = |process: ArrivalProcess| -> Vec<f64> {
        let ts = arrivals(process, 0.05, 20_000, 0xB5);
        let mut prev = 0.0;
        ts.iter()
            .map(|&t| {
                let g = t - prev;
                prev = t;
                g
            })
            .collect()
    };
    let cv = |xs: &[f64]| {
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        var.sqrt() / mean
    };
    let poisson_cv = cv(&gaps(ArrivalProcess::Poisson));
    let bursty_cv = cv(&gaps(ArrivalProcess::Bursty {
        mean_on_s: 100.0,
        mean_off_s: 400.0,
        on_factor: 5.0,
        off_factor: 0.0,
    }));
    assert!((poisson_cv - 1.0).abs() < 0.05, "exponential CV ~ 1, got {poisson_cv}");
    assert!(
        bursty_cv > 1.2,
        "MMPP gaps must be over-dispersed: CV {bursty_cv} vs Poisson {poisson_cv}"
    );
}

// ---------------------------------------------------- estimator sweeps

#[test]
fn percentile_estimator_runs_all_policies_end_to_end() {
    // The history-based predictor must produce finite positive factors
    // and a complete simulation for every policy on a contended trace.
    let mut cfg = TraceConfig::simulation(40, 3);
    cfg.estimator = EstimateModel::Percentile { pct: 50.0 };
    let jobs = trace::generate(&cfg);
    assert!(jobs.iter().all(|j| j.est_factor.is_finite() && j.est_factor > 0.0));
    assert!(jobs.iter().any(|j| j.est_factor != 1.0), "history must bite");
    for name in POLICY_NAMES {
        let mut p = sched::by_name(name).unwrap();
        let out = engine::run(
            ClusterConfig::simulation(),
            &jobs,
            InterferenceModel::new(),
            p.as_mut(),
        )
        .unwrap_or_else(|e| panic!("{name} under percentile estimates: {e:#}"));
        for j in &out.jobs {
            assert_eq!(j.state, JobState::Finished, "{name}: job {} unfinished", j.spec.id);
        }
    }
}

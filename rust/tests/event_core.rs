//! The event core's replacement guarantees (DESIGN.md §15): the
//! calendar queue pops in exactly the `(time, payload)` order the old
//! binary heaps produced, the lazy progress ledger agrees with an eager
//! per-event integration sweep, and batched same-instant completions are
//! delivered in ascending job-id order.
//!
//! The heavyweight check is the six-policy golden run: the paper-scale
//! 240-job / 64-GPU trace through the batch engine and, independently,
//! through the incremental [`EventPump`] with the eager reference shadow
//! armed (every `advance` re-derives progress the pre-§15 way and panics
//! past float tolerance). Both runs must agree *bitwise* on every job
//! field — a within-binary determinism pin, deliberately not a
//! cross-toolchain one (IEEE-754 ordering differs between the lazy
//! closed form and sequential subtraction, which is why the shadow
//! verifies within tolerance while the two *lazy* runs must be exact).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use wise_share::cluster::{Cluster, ClusterConfig};
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::jobs::{JobRecord, JobSpec, JobState};
use wise_share::perf::interference::InterferenceModel;
use wise_share::perf::profiles::ModelKind;
use wise_share::prop_assert;
use wise_share::sched::{self, POLICY_NAMES};
use wise_share::sched_core::calendar::CalendarQueue;
use wise_share::sched_core::{Event, EventPump, NoHooks, Policy, SchedContext, Txn};
use wise_share::sim::engine;
use wise_share::util::prop::forall;
use wise_share::util::rng::Rng;

// ---------------------------------------------------------------- calendar

/// The calendar queue must reproduce the pop stream of the
/// `BinaryHeap<Reverse<..>>`s it replaced, under randomized interleavings
/// of pushes (mostly forward in time, sometimes past-due, with frequent
/// coincident timestamps to exercise the payload tie-break) and pops.
#[test]
fn prop_calendar_queue_matches_reference_heap_order() {
    forall("calendar-vs-heap", 0xCA1E17DA, 64, |rng: &mut Rng| {
        let mut cal: CalendarQueue<usize> = CalendarQueue::new();
        // Times are non-negative finite, so the bit pattern orders like
        // the number and the Reverse<(u64, usize)> heap is a faithful
        // (t, payload) min-heap reference.
        let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
        let mut clock = 0.0f64;
        for step in 0..400 {
            if rng.f64() < 0.6 {
                // Integer-valued times collide often (tie-break coverage);
                // ~1 in 10 pushes lands behind the current front (the
                // engine's T_EPS slack produces these).
                let t = if rng.f64() < 0.1 {
                    (clock - 3.0).max(0.0).floor()
                } else {
                    (clock + rng.f64() * 50.0).floor()
                };
                let payload = rng.index(16);
                cal.push(t, payload);
                heap.push(Reverse((t.to_bits(), payload)));
            } else {
                let got = cal.pop();
                let want = heap.pop().map(|Reverse((b, p))| (f64::from_bits(b), p));
                prop_assert!(
                    got == want,
                    "step {step}: calendar popped {got:?}, heap {want:?}"
                );
                if let Some((t, _)) = got {
                    clock = clock.max(t);
                }
            }
            clock += rng.f64() * 4.0;
            prop_assert!(
                cal.len() == heap.len(),
                "step {step}: len {} vs {}",
                cal.len(),
                heap.len()
            );
        }
        // Drain both completely: the tails must agree too (overflow
        // entries rebuild into the wheel as it empties).
        while let Some(Reverse((b, p))) = heap.pop() {
            let want = Some((f64::from_bits(b), p));
            let got = cal.pop();
            prop_assert!(got == want, "drain: calendar popped {got:?}, heap {want:?}");
        }
        prop_assert!(cal.pop().is_none(), "calendar outlived the reference heap");
        Ok(())
    });
}

// ----------------------------------------------------- golden equivalence

/// Paper-scale golden runs for all seven policies: the batch engine and the
/// incremental pump — with the eager reference shadow re-deriving every
/// quantity the pre-lazy way — must agree bitwise on every job field.
#[test]
fn six_policy_golden_runs_agree_engine_vs_pump_with_eager_shadow() {
    let trace_jobs = trace::generate(&TraceConfig::simulation(240, 17));
    for name in POLICY_NAMES {
        let mut p = sched::by_name(name).unwrap();
        let out = engine::run(
            ClusterConfig::simulation(),
            &trace_jobs,
            InterferenceModel::new(),
            p.as_mut(),
        )
        .unwrap_or_else(|e| panic!("{name}: engine run failed: {e:#}"));
        let last_finish = out
            .jobs
            .iter()
            .filter_map(|j| j.finish_s)
            .fold(0.0f64, f64::max);

        let mut p2 = sched::by_name(name).unwrap();
        let mut ctx = SchedContext::new(
            Cluster::new(ClusterConfig::simulation()),
            trace_jobs.iter().cloned().map(JobRecord::new).collect(),
            InterferenceModel::new(),
        );
        // Every advance now replays the eager per-event sweep and panics
        // if the lazy ledger drifts past float tolerance.
        ctx.verify_against_eager_reference();
        let mut pump = EventPump::new(p2.as_ref());
        pump.pump_sim(&mut ctx, p2.as_mut(), last_finish, 1e-6, &mut NoHooks)
            .unwrap_or_else(|e| panic!("{name}: pump run failed: {e:#}"));

        assert!(ctx.all_finished(), "{name}: pump left jobs unfinished");
        assert_eq!(out.policy_calls, pump.policy_calls(), "{name}: event counts");
        assert_eq!(out.preemptions, pump.preemptions(), "{name}: preemptions");
        assert_eq!(
            out.busy_gpu_s.to_bits(),
            ctx.busy_gpu_s().to_bits(),
            "{name}: busy integral"
        );
        for (a, b) in out.jobs.iter().zip(ctx.jobs.iter()) {
            let id = a.spec.id;
            assert_eq!(a.state, b.state, "{name}: job {id} state");
            assert_eq!(
                a.remaining_iters.to_bits(),
                b.remaining_iters.to_bits(),
                "{name}: job {id} remaining ({} vs {})",
                a.remaining_iters,
                b.remaining_iters
            );
            assert_eq!(
                a.queued_s.to_bits(),
                b.queued_s.to_bits(),
                "{name}: job {id} queued ({} vs {})",
                a.queued_s,
                b.queued_s
            );
            assert_eq!(
                a.finish_s.map(f64::to_bits),
                b.finish_s.map(f64::to_bits),
                "{name}: job {id} finish ({:?} vs {:?})",
                a.finish_s,
                b.finish_s
            );
            assert_eq!(
                a.first_start_s.map(f64::to_bits),
                b.first_start_s.map(f64::to_bits),
                "{name}: job {id} first start"
            );
            assert_eq!(a.accum_step, b.accum_step, "{name}: job {id} accum step");
        }
        ctx.cache_integrity()
            .unwrap_or_else(|e| panic!("{name}: {e}"));
    }
}

// ------------------------------------------------- batched delivery pin

/// Forces the historical one-call-per-event contract: delegates
/// everything to the wrapped policy but keeps the default
/// `coalesce_coincident = false`, so the engine may not absorb any
/// same-instant batch tail.
struct PerEventDelivery(Box<dyn Policy>);

impl Policy for PerEventDelivery {
    fn name(&self) -> &'static str {
        self.0.name()
    }
    fn on_event(&mut self, ctx: &SchedContext, ev: Event) -> Txn {
        self.0.on_event(ctx, ev)
    }
    fn tick_interval(&self) -> Option<f64> {
        self.0.tick_interval()
    }
    fn preemption_penalty(&self) -> f64 {
        self.0.preemption_penalty()
    }
}

/// Coincident-batch coalescing is an optimization, not a semantics
/// change: for all seven policies on the paper-scale golden trace, the
/// batched run and a forced per-event run must agree bitwise on every
/// job field — only the number of delivered passes may shrink.
#[test]
fn coalesced_batch_delivery_matches_per_event_delivery() {
    let trace_jobs = trace::generate(&TraceConfig::simulation(240, 17));
    for name in POLICY_NAMES {
        let mut batched = sched::by_name(name).unwrap();
        let out_b = engine::run(
            ClusterConfig::simulation(),
            &trace_jobs,
            InterferenceModel::new(),
            batched.as_mut(),
        )
        .unwrap_or_else(|e| panic!("{name}: batched run failed: {e:#}"));
        let mut per_event = PerEventDelivery(sched::by_name(name).unwrap());
        let out_e = engine::run(
            ClusterConfig::simulation(),
            &trace_jobs,
            InterferenceModel::new(),
            &mut per_event,
        )
        .unwrap_or_else(|e| panic!("{name}: per-event run failed: {e:#}"));
        assert!(
            out_b.policy_calls <= out_e.policy_calls,
            "{name}: coalescing cannot add passes ({} vs {})",
            out_b.policy_calls,
            out_e.policy_calls
        );
        assert_eq!(out_b.preemptions, out_e.preemptions, "{name}: preemptions");
        assert_eq!(
            out_b.busy_gpu_s.to_bits(),
            out_e.busy_gpu_s.to_bits(),
            "{name}: busy integral"
        );
        for (a, b) in out_b.jobs.iter().zip(out_e.jobs.iter()) {
            let id = a.spec.id;
            assert_eq!(a.state, b.state, "{name}: job {id} state");
            assert_eq!(
                a.remaining_iters.to_bits(),
                b.remaining_iters.to_bits(),
                "{name}: job {id} remaining"
            );
            assert_eq!(
                a.queued_s.to_bits(),
                b.queued_s.to_bits(),
                "{name}: job {id} queued"
            );
            assert_eq!(
                a.finish_s.map(f64::to_bits),
                b.finish_s.map(f64::to_bits),
                "{name}: job {id} finish"
            );
            assert_eq!(
                a.first_start_s.map(f64::to_bits),
                b.first_start_s.map(f64::to_bits),
                "{name}: job {id} first start"
            );
            assert_eq!(a.accum_step, b.accum_step, "{name}: job {id} accum step");
        }
    }
}

/// A guaranteed-coincident scenario pins the actual saving: three
/// identical jobs arrive at t=0 (one batch of three arrivals) and finish
/// at the same projected instant (one batch of three completions). SJF
/// starts all three on the first arrival pass, converges on the second,
/// and absorbs the third; the completion batch converges on its first
/// (empty) pass. 3 delivered passes for 6 events.
#[test]
fn coalescing_absorbs_tail_of_coincident_batches() {
    let specs: Vec<JobSpec> = (0..3)
        .map(|id| JobSpec {
            id,
            model: ModelKind::Cifar10,
            gpus: 1,
            iterations: 50,
            batch: 128,
            arrival_s: 0.0,
            est_factor: 1.0,
        })
        .collect();
    let mut p = sched::by_name("SJF").unwrap();
    let out = engine::run(
        ClusterConfig::simulation(),
        &specs,
        InterferenceModel::new(),
        p.as_mut(),
    )
    .unwrap();
    assert!(out.jobs.iter().all(|j| j.state == JobState::Finished));
    assert_eq!(
        out.policy_calls, 3,
        "6 coincident events must coalesce into 3 delivered passes"
    );
    // The forced per-event run still gets one call per event.
    let mut per_event = PerEventDelivery(sched::by_name("SJF").unwrap());
    let out_e = engine::run(
        ClusterConfig::simulation(),
        &specs,
        InterferenceModel::new(),
        &mut per_event,
    )
    .unwrap();
    assert_eq!(out_e.policy_calls, 6, "per-event delivery must not coalesce");
}

// --------------------------------------------- pending order vs re-sort

/// The incrementally maintained pending order must equal a full re-sort
/// of `ctx.pending()` — by `(estimated_remaining, id)` and by
/// `(arrival_s, id)` — at every step of random contended traces under
/// random policies (starts, completions, preemptions, restarts all churn
/// the index).
#[test]
fn prop_pending_order_matches_full_resort() {
    forall("pending-order-vs-resort", 0x9E4D, 12, |rng: &mut Rng| {
        let n_jobs = 20 + rng.index(30);
        let seed = rng.index(1 << 16) as u64;
        let trace_jobs = trace::generate(&TraceConfig::simulation(n_jobs, seed));
        let name = POLICY_NAMES[rng.index(POLICY_NAMES.len())];
        let mut p = sched::by_name(name).unwrap();
        let mut ctx = SchedContext::new(
            Cluster::new(ClusterConfig::simulation()),
            trace_jobs.iter().cloned().map(JobRecord::new).collect(),
            InterferenceModel::new(),
        );
        let mut pump = EventPump::new(p.as_ref());
        let horizon = 120.0 * 24.0 * 3600.0;
        let mut t = 0.0;
        while !ctx.all_finished() && t < horizon {
            t = (t + 6.0 * 3600.0).min(horizon);
            pump.pump_sim(&mut ctx, p.as_mut(), t, 1e-6, &mut NoHooks)
                .map_err(|e| format!("{name}/{n_jobs}j/{seed}: {e:#}"))?;
            let got: Vec<_> = ctx.pending_by_estimate().collect();
            let mut want = ctx.pending().to_vec();
            want.sort_by(|&a, &b| {
                ctx.estimated_remaining(a)
                    .total_cmp(&ctx.estimated_remaining(b))
                    .then(a.cmp(&b))
            });
            prop_assert!(
                got == want,
                "{name}/{n_jobs}j/{seed} t={t}: by-estimate {got:?} != re-sort {want:?}"
            );
            let got: Vec<_> = ctx.pending_by_arrival().collect();
            let mut want = ctx.pending().to_vec();
            want.sort_by(|&a, &b| {
                ctx.jobs[a]
                    .spec
                    .arrival_s
                    .total_cmp(&ctx.jobs[b].spec.arrival_s)
                    .then(a.cmp(&b))
            });
            prop_assert!(
                got == want,
                "{name}/{n_jobs}j/{seed} t={t}: by-arrival {got:?} != re-sort {want:?}"
            );
        }
        prop_assert!(ctx.all_finished(), "{name}/{n_jobs}j/{seed}: unfinished");
        ctx.cache_integrity().map_err(|e| format!("{name}: {e}"))?;
        Ok(())
    });
}

// --------------------------------------------------- completion ordering

fn tiny_jobs() -> Vec<JobRecord> {
    (0..3)
        .map(|id| {
            JobRecord::new(JobSpec {
                id,
                model: ModelKind::Cifar10,
                gpus: 1,
                iterations: 50,
                batch: 128,
                arrival_s: 0.0,
                est_factor: 1.0,
            })
        })
        .collect()
}

#[test]
fn coincident_sim_completions_deliver_in_ascending_id_order() {
    let mut ctx = SchedContext::new(
        Cluster::new(ClusterConfig::simulation()),
        tiny_jobs(),
        InterferenceModel::new(),
    );
    // Start identical jobs in scrambled order so their (coincident)
    // finish projections enter the queue out of id order.
    let mut ev = Vec::new();
    ctx.advance_sim(0.0, &mut ev);
    assert_eq!(ev.len(), 3, "all three arrive at t=0");
    let mut txn = Txn::new();
    txn.start(2, vec![0], 1);
    txn.start(0, vec![1], 1);
    txn.start(1, vec![2], 1);
    ctx.apply(&txn, 30.0).unwrap();
    // All three project the same finish instant (identical spec, solo,
    // same width): one batched pop, ascending ids.
    let t = ctx.next_finish().expect("three projections queued");
    ev.clear();
    ctx.advance_sim(t, &mut ev);
    assert!(ev.is_empty(), "no arrivals/restarts at the finish instant");
    ctx.collect_completions(1e-6, &mut ev);
    assert_eq!(
        ev,
        vec![
            Event::Completion { job: 0 },
            Event::Completion { job: 1 },
            Event::Completion { job: 2 },
        ],
        "completions must be delivered ascending by id"
    );
    for j in &ctx.jobs {
        assert_eq!(j.state, JobState::Finished);
    }
    ctx.cache_integrity().unwrap();
}

#[test]
fn coincident_wall_completions_deliver_in_ascending_id_order() {
    let mut ctx = SchedContext::new(
        Cluster::new(ClusterConfig::simulation()),
        tiny_jobs(),
        InterferenceModel::new(),
    );
    let mut ev = Vec::new();
    ctx.advance_wall(0.0, &mut ev);
    assert_eq!(ev.len(), 3);
    let mut txn = Txn::new();
    txn.start(2, vec![0], 1);
    txn.start(0, vec![1], 1);
    txn.start(1, vec![2], 1);
    ctx.apply(&txn, 30.0).unwrap();
    // Wall mode: external progress reports retire iterations; report them
    // in scrambled order too, so the running-set scan order (insertion
    // order 2,0,1) is what the explicit sort has to correct.
    for _ in 0..50 {
        for job in [2, 0, 1] {
            ctx.note_progress(job);
        }
    }
    ev.clear();
    ctx.collect_completions(0.0, &mut ev);
    assert_eq!(
        ev,
        vec![
            Event::Completion { job: 0 },
            Event::Completion { job: 1 },
            Event::Completion { job: 2 },
        ],
        "wall-mode completions must be delivered ascending by id"
    );
    ctx.cache_integrity().unwrap();
}

/// Randomized lazy-vs-eager agreement beyond the golden trace: short
/// contended traces, random policy, eager shadow armed — the shadow
/// panics inside `advance` on divergence, so surviving the run *is* the
/// assertion; the explicit checks here pin completion of the workload.
#[test]
fn prop_lazy_ledger_matches_eager_reference_on_random_traces() {
    forall("lazy-vs-eager", 0x1ED6E4, 12, |rng: &mut Rng| {
        let n_jobs = 20 + rng.index(30);
        let seed = rng.index(1 << 16) as u64;
        let trace_jobs = trace::generate(&TraceConfig::simulation(n_jobs, seed));
        let name = POLICY_NAMES[rng.index(POLICY_NAMES.len())];
        let mut p = sched::by_name(name).unwrap();
        let mut ctx = SchedContext::new(
            Cluster::new(ClusterConfig::simulation()),
            trace_jobs.iter().cloned().map(JobRecord::new).collect(),
            InterferenceModel::new(),
        );
        ctx.verify_against_eager_reference();
        let mut pump = EventPump::new(p.as_ref());
        // Advance in bounded steps: pumping straight to the horizon would
        // deliver every periodic tick between the last completion and the
        // horizon for tick policies.
        let horizon = 120.0 * 24.0 * 3600.0;
        let mut t = 0.0;
        while !ctx.all_finished() && t < horizon {
            t = (t + 6.0 * 3600.0).min(horizon);
            pump.pump_sim(&mut ctx, p.as_mut(), t, 1e-6, &mut NoHooks)
                .map_err(|e| format!("{name}/{n_jobs}j/{seed}: {e:#}"))?;
        }
        prop_assert!(
            ctx.all_finished(),
            "{name}/{n_jobs}j/{seed}: jobs left unfinished"
        );
        ctx.cache_integrity().map_err(|e| format!("{name}: {e}"))?;
        Ok(())
    });
}

//! Cross-backend conformance: `sim::engine` and the physical coordinator
//! share ONE decision-validation/apply path (`sched_core`'s
//! `SchedContext::apply`). These tests build the context exactly the way
//! each backend does — simulated clock via `advance_sim`, wall clock via
//! `advance_wall` — and assert that the same malformed transactions are
//! rejected with *identical* errors through both, and that valid
//! transactions leave both in identical scheduling states.
//!
//! This pins the fix for the old coordinator bypass, where physical-mode
//! `Start` decisions were applied with no validation at all (over-memory
//! and double-start decisions went through silently while the simulator
//! would bail).

use wise_share::cluster::{Cluster, ClusterConfig};
use wise_share::jobs::{JobRecord, JobSpec, JobState};
use wise_share::perf::interference::InterferenceModel;
use wise_share::perf::profiles::ModelKind;
use wise_share::prop_assert;
use wise_share::sched_core::{SchedContext, Txn};
use wise_share::util::prop::forall;
use wise_share::util::rng::Rng;

fn spec(id: usize, model: ModelKind, iters: u64, batch: u32, arrival: f64) -> JobSpec {
    JobSpec { id, model, gpus: 1, iterations: iters, batch, arrival_s: arrival, est_factor: 1.0 }
}

/// The conformance workload (16-GPU physical cluster):
/// * job 0 — YoloV3@16 (10.1 GB), running on GPU 0: any co-location is
///   memory-infeasible; re-starting it is a state-machine violation;
/// * job 1 — YoloV3@16, pending: the probe most malformed txns target;
/// * job 2 — arrives at t = 100, far in the future;
/// * job 3 — preempted at t = 1 with a 30 s penalty: `not_before = 31`;
/// * jobs 4/5 — NCF@4096 (3.4 GB each), sharing GPU 8: the C = 2 slot cap;
/// * job 6 — NCF@4096, pending: the share-capacity probe.
fn jobs() -> Vec<JobRecord> {
    vec![
        spec(0, ModelKind::YoloV3, 500, 16, 0.0),
        spec(1, ModelKind::YoloV3, 500, 16, 0.0),
        spec(2, ModelKind::Cifar10, 500, 128, 100.0),
        spec(3, ModelKind::Cifar10, 500, 128, 0.0),
        spec(4, ModelKind::Ncf, 500, 4096, 0.0),
        spec(5, ModelKind::Ncf, 500, 4096, 0.0),
        spec(6, ModelKind::Ncf, 500, 4096, 0.0),
    ]
    .into_iter()
    .map(JobRecord::new)
    .collect()
}

/// Build the workload's context the way one backend does: the simulator
/// advances the simulated clock (`advance_sim`), the coordinator the wall
/// clock (`advance_wall`). Everything downstream — validation, caches,
/// transitions — is the shared code under test.
fn make_ctx(wall_clock: bool) -> SchedContext {
    let mut ctx = SchedContext::new(
        Cluster::new(ClusterConfig::physical()),
        jobs(),
        InterferenceModel::new(),
    );
    let mut events = Vec::new();
    if wall_clock {
        ctx.advance_wall(1.0, &mut events);
    } else {
        ctx.advance_sim(1.0, &mut events);
    }
    assert_eq!(events.len(), 6, "jobs 0,1,3..6 arrive by t=1");
    let mut setup = Txn::new();
    setup.start(0, vec![0], 1);
    setup.start(3, vec![4], 1);
    setup.start(4, vec![8], 1);
    setup.start(5, vec![8], 1);
    ctx.apply(&setup, 30.0).expect("setup starts are valid");
    let mut preempt = Txn::new();
    preempt.preempt(3);
    ctx.apply(&preempt, 30.0).expect("setup preempt is valid");
    ctx
}

/// The malformed-transaction catalogue. Every case must be rejected — and
/// rejected identically — by both backends.
fn malformed(case: usize) -> (&'static str, Txn) {
    let mut txn = Txn::new();
    let name = match case {
        0 => {
            txn.start(1, vec![], 1);
            "empty gang"
        }
        1 => {
            // Second YoloV3@16 next to the first: 20.2 GB on an 11 GB GPU.
            txn.start(1, vec![0], 1);
            "memory over budget"
        }
        2 => {
            txn.start(2, vec![12], 1);
            "start before arrival"
        }
        3 => {
            txn.start(3, vec![12], 1);
            "start during restart penalty"
        }
        4 => {
            txn.start(0, vec![12], 1);
            "double start (job already running)"
        }
        5 => {
            txn.start(1, vec![12], 0);
            "zero accumulation step"
        }
        6 => {
            txn.start(1, vec![12], 3);
            "accumulation step does not divide batch"
        }
        7 => {
            txn.start(99, vec![12], 1);
            "unknown job id"
        }
        8 => {
            txn.start(1, vec![999], 1);
            "GPU out of range"
        }
        9 => {
            txn.start(1, vec![12, 12], 1);
            "duplicate GPU in gang"
        }
        10 => {
            // GPU 8 already holds jobs 4 and 5 (C = 2).
            txn.start(6, vec![8], 1);
            "share capacity exceeded"
        }
        11 => {
            txn.preempt(1);
            "preempt a non-running job"
        }
        _ => unreachable!("unknown case {case}"),
    };
    (name, txn)
}

const N_CASES: usize = 12;

#[test]
fn every_malformed_txn_rejected_identically() {
    for case in 0..N_CASES {
        let (name, txn) = malformed(case);
        let sim_err = make_ctx(false)
            .apply(&txn, 30.0)
            .expect_err(name)
            .to_string();
        let wall_err = make_ctx(true)
            .apply(&txn, 30.0)
            .expect_err(name)
            .to_string();
        assert_eq!(
            sim_err, wall_err,
            "{name}: backends must reject with the same error"
        );
        assert!(
            sim_err.contains("applying policy decision"),
            "{name}: error must come from the shared apply path: {sim_err}"
        );
    }
}

#[test]
fn prop_malformed_rejection_is_backend_invariant() {
    // Randomized interleavings: a random malformed case, optionally after
    // extra *valid* work, still fails identically through both backends.
    forall("cross-backend-reject", 0xCBu64, 128, |rng: &mut Rng| {
        let case = rng.index(N_CASES);
        let start_probe_first = rng.f64() < 0.5 && !matches!(case, 1 | 4..=10);
        let run = |wall: bool| -> Result<String, String> {
            let mut ctx = make_ctx(wall);
            if start_probe_first {
                // Valid prefix: start job 6 exclusively on a free GPU.
                let mut ok = Txn::new();
                ok.start(6, vec![13], 1);
                ctx.apply(&ok, 30.0).map_err(|e| format!("valid prefix failed: {e}"))?;
            }
            let (_, txn) = malformed(case);
            match ctx.apply(&txn, 30.0) {
                Ok(_) => Err("malformed txn was accepted".to_string()),
                Err(e) => Ok(e.to_string()),
            }
        };
        let sim = run(false)?;
        let wall = run(true)?;
        prop_assert!(
            sim == wall,
            "case {case}: sim rejected with {sim:?}, coordinator with {wall:?}"
        );
        Ok(())
    });
}

#[test]
fn valid_txn_applies_identically_across_backends() {
    let mut sim = make_ctx(false);
    let mut wall = make_ctx(true);
    let mut txn = Txn::new();
    txn.start(1, vec![12], 1);
    txn.start(6, vec![13], 1);
    for ctx in [&mut sim, &mut wall] {
        let report = ctx.apply(&txn, 30.0).unwrap();
        assert_eq!(report.starts, 2);
        assert_eq!(report.preemptions, 0);
    }
    assert_eq!(sim.pending(), wall.pending());
    assert_eq!(sim.running(), wall.running());
    for id in 0..sim.jobs.len() {
        assert_eq!(sim.jobs[id].state, wall.jobs[id].state, "job {id}");
        assert_eq!(sim.jobs[id].gpus_held, wall.jobs[id].gpus_held, "job {id}");
        assert_eq!(sim.jobs[id].first_start_s, wall.jobs[id].first_start_s, "job {id}");
        assert_eq!(sim.jobs[id].accum_step, wall.jobs[id].accum_step, "job {id}");
    }
    sim.cache_integrity().unwrap();
    wall.cache_integrity().unwrap();
}

#[test]
fn coordinator_style_context_tracks_service_and_queueing() {
    // The two physical-mode accounting fixes: attained service accrues for
    // running jobs (Tiresias' 2D-LAS input is no longer frozen at 0) and
    // queueing time accrues continuously for every waiting job.
    let mut ctx = make_ctx(true);
    let mut events = Vec::new();
    ctx.advance_wall(11.0, &mut events);
    assert!(events.is_empty(), "no arrivals between t=1 and t=11");
    // Job 0 ran on 1 GPU for 10 s of wall time. (Service and queueing are
    // lazily integrated — the accessors fold them to `now`.)
    assert!((ctx.attained_service(0) - 10.0).abs() < 1e-9);
    // Jobs 4/5 share GPU 8 — each held one GPU for 10 s.
    assert!((ctx.attained_service(4) - 10.0).abs() < 1e-9);
    // Pending job 1 and penalty-held job 3 both queued over [1, 11] — the
    // engine's continuous accrual, not the old first-start snapshot.
    assert!((ctx.queued_seconds(1) - 10.0).abs() < 1e-9, "{}", ctx.queued_seconds(1));
    assert!((ctx.queued_seconds(3) - 10.0).abs() < 1e-9, "{}", ctx.queued_seconds(3));
    // Job 2 has not arrived: no queueing yet.
    assert_eq!(ctx.queued_seconds(2), 0.0);
    // Advancing past the penalty fires RestartEligible for job 3, past the
    // arrival fires Arrival for job 2 — wall mode uses the same event
    // plumbing as the simulator.
    ctx.advance_wall(150.0, &mut events);
    use wise_share::sched_core::Event;
    assert!(events.contains(&Event::RestartEligible { job: 3 }));
    assert!(events.contains(&Event::Arrival { job: 2 }));
    assert!(ctx.pending().contains(&2) && ctx.pending().contains(&3));
    // Wall mode never integrates remaining_iters — real execution does
    // (the accessor is a bit-exact passthrough of the stored field here).
    assert_eq!(ctx.remaining_iters(0), 500.0);
    assert_eq!(ctx.jobs[0].remaining_iters, 500.0);
    assert_eq!(ctx.jobs[0].state, JobState::Running);
}

#[test]
fn wall_progress_drives_completion_through_shared_path() {
    let mut ctx = make_ctx(true);
    for _ in 0..500 {
        assert!(ctx.note_progress(0));
    }
    assert!(!ctx.note_progress(0), "no more iterations to report");
    let mut events = Vec::new();
    ctx.collect_completions(0.0, &mut events);
    use wise_share::sched_core::Event;
    assert_eq!(events, vec![Event::Completion { job: 0 }]);
    assert_eq!(ctx.jobs[0].state, JobState::Finished);
    assert!(ctx.jobs[0].gpus_held.is_empty());
    assert!(!ctx.running().contains(&0));
    ctx.cache_integrity().unwrap();
}

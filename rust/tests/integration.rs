//! Cross-module integration tests: full simulator runs per policy over
//! generated traces, asserting global invariants and the paper's headline
//! orderings on contended workloads.

use wise_share::cluster::ClusterConfig;
use wise_share::jobs::trace::{self, TraceConfig};
use wise_share::jobs::JobState;
use wise_share::perf::interference::InterferenceModel;
use wise_share::sched::{self, POLICY_NAMES};
use wise_share::sim::{engine, metrics};

fn run(
    policy: &str,
    n_jobs: usize,
    seed: u64,
    load: f64,
    xi: InterferenceModel,
) -> (engine::SimOutcome, metrics::Summary) {
    let mut tcfg = TraceConfig::simulation(n_jobs, seed);
    tcfg.load_factor = load;
    let jobs = trace::generate(&tcfg);
    let mut p = sched::by_name(policy).unwrap();
    let out = engine::run(ClusterConfig::simulation(), &jobs, xi, p.as_mut()).unwrap();
    let s = metrics::summarize(policy, &out.jobs, out.makespan_s);
    (out, s)
}

#[test]
fn every_policy_completes_every_job() {
    for name in POLICY_NAMES {
        let (out, _) = run(name, 80, 3, 1.0, InterferenceModel::new());
        for j in &out.jobs {
            assert_eq!(j.state, JobState::Finished, "{name}: job {} unfinished", j.spec.id);
            assert!(j.finish_s.unwrap() >= j.spec.arrival_s);
            assert!(j.remaining_iters == 0.0);
        }
    }
}

#[test]
fn jct_never_beats_solo_runtime_for_gang_faithful_policies() {
    // A job can never finish faster than its solo runtime on its requested
    // gang (non-elastic policies run it at exactly that width).
    for name in ["FIFO", "SJF", "Tiresias", "SJF-FFS", "SJF-BSBF", "SJF-BSBF-k"] {
        let (out, _) = run(name, 60, 5, 1.0, InterferenceModel::new());
        for j in &out.jobs {
            let solo = j.spec.solo_runtime(1);
            let jct = j.jct().unwrap();
            assert!(
                jct >= solo * 0.999,
                "{name}: job {} jct {jct:.1} < solo {solo:.1}",
                j.spec.id
            );
        }
    }
}

#[test]
fn queueing_delay_consistent_with_first_start() {
    for name in ["FIFO", "SJF", "SJF-BSBF"] {
        let (out, _) = run(name, 60, 7, 1.0, InterferenceModel::new());
        for j in &out.jobs {
            // Non-preemptive: cumulative queued time == first-start delay.
            let qd = j.queueing_delay().unwrap();
            assert!(
                (j.queued_s - qd).abs() < 1e-6,
                "{name}: job {} queued_s {} vs delay {}",
                j.spec.id,
                j.queued_s,
                qd
            );
        }
    }
}

#[test]
fn headline_orderings_hold_under_contention() {
    // Table III/IV shape on a contended 160-job workload: SJF-BSBF beats
    // FIFO, Tiresias and SJF-FFS on average JCT; FIFO is the worst of the
    // non-preemptive policies; sharing policies have the lowest queueing.
    let xi = InterferenceModel::new;
    let (_, fifo) = run("FIFO", 160, 1, 1.5, xi());
    let (_, sjf) = run("SJF", 160, 1, 1.5, xi());
    let (_, tiresias) = run("Tiresias", 160, 1, 1.5, xi());
    let (_, ffs) = run("SJF-FFS", 160, 1, 1.5, xi());
    let (_, bsbf) = run("SJF-BSBF", 160, 1, 1.5, xi());

    assert!(bsbf.all.avg_jct_s < fifo.all.avg_jct_s, "BSBF must beat FIFO");
    assert!(bsbf.all.avg_jct_s < tiresias.all.avg_jct_s, "BSBF must beat Tiresias");
    assert!(bsbf.all.avg_jct_s < ffs.all.avg_jct_s, "BSBF must beat blind sharing");
    assert!(
        bsbf.all.avg_queue_s <= sjf.all.avg_queue_s * 1.05,
        "sharing must not queue more than exclusive SJF: {} vs {}",
        bsbf.all.avg_queue_s,
        sjf.all.avg_queue_s
    );
}

#[test]
fn fig6b_mechanism_low_xi_equalizes_sharing_policies() {
    // At xi = 1.0 sharing is free: BSBF accepts every share like FFS and
    // the two coincide (paper Fig. 6b, xi <= 1.25 regime).
    let (_, ffs) = run("SJF-FFS", 100, 2, 1.0, InterferenceModel::with_global(1.0));
    let (_, bsbf) = run("SJF-BSBF", 100, 2, 1.0, InterferenceModel::with_global(1.0));
    let ratio = bsbf.all.avg_jct_s / ffs.all.avg_jct_s;
    assert!(
        (0.9..=1.1).contains(&ratio),
        "at xi=1 the policies should coincide, ratio {ratio}"
    );
}

#[test]
fn fig6b_mechanism_high_xi_separates_sharing_policies() {
    // At xi = 2.0 blind sharing hurts; BSBF must be strictly better.
    let (_, ffs) = run("SJF-FFS", 100, 2, 1.5, InterferenceModel::with_global(2.0));
    let (_, bsbf) = run("SJF-BSBF", 100, 2, 1.5, InterferenceModel::with_global(2.0));
    assert!(
        bsbf.all.avg_jct_s < ffs.all.avg_jct_s,
        "BSBF {:.0}s must beat FFS {:.0}s at xi=2",
        bsbf.all.avg_jct_s,
        ffs.all.avg_jct_s
    );
}

#[test]
fn sharing_respects_c2_and_memory_throughout() {
    // Stress run with the sharing policies; the engine asserts invariants
    // at every event (debug builds) — here we re-validate at the end and
    // make sure sharing actually happened (accum_step > 1 somewhere or
    // queueing below exclusive SJF).
    let (out, bsbf) = run("SJF-BSBF", 120, 4, 2.0, InterferenceModel::new());
    let (_, sjf) = run("SJF", 120, 4, 2.0, InterferenceModel::new());
    assert!(
        bsbf.all.avg_queue_s < sjf.all.avg_queue_s,
        "sharing should reduce queueing under overload"
    );
    // accum steps are always powers that divide the batch
    for j in &out.jobs {
        assert!(j.accum_step >= 1);
        assert_eq!(j.spec.batch % j.accum_step, 0, "{:?}", j);
    }
}

#[test]
fn trace_load_save_roundtrip_through_simulation() {
    let dir = std::env::temp_dir().join(format!("ws-int-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    let jobs = trace::generate(&TraceConfig::simulation(40, 11));
    trace::save(&jobs, &path).unwrap();
    let loaded = trace::load(&path).unwrap();
    let mut p1 = sched::by_name("SJF-BSBF").unwrap();
    let mut p2 = sched::by_name("SJF-BSBF").unwrap();
    let a = engine::run(ClusterConfig::simulation(), &jobs, InterferenceModel::new(), p1.as_mut())
        .unwrap();
    let b =
        engine::run(ClusterConfig::simulation(), &loaded, InterferenceModel::new(), p2.as_mut())
            .unwrap();
    assert_eq!(a.makespan_s, b.makespan_s, "simulation must be reproducible through JSON I/O");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn deterministic_simulation_same_seed_same_result() {
    let (a, _) = run("SJF-BSBF", 60, 13, 1.0, InterferenceModel::new());
    let (b, _) = run("SJF-BSBF", 60, 13, 1.0, InterferenceModel::new());
    assert_eq!(a.makespan_s, b.makespan_s);
    for (x, y) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(x.finish_s, y.finish_s);
    }
}

#[test]
fn preemptive_policies_preempt_and_recover() {
    let (out, _) = run("Tiresias", 100, 1, 2.0, InterferenceModel::new());
    assert!(out.preemptions > 0, "overloaded Tiresias must preempt");
    for j in &out.jobs {
        assert_eq!(j.state, JobState::Finished);
    }
    let (out, _) = run("Pollux", 100, 1, 2.0, InterferenceModel::new());
    assert!(out.preemptions > 0, "overloaded elastic must reallocate");
    for j in &out.jobs {
        assert_eq!(j.state, JobState::Finished);
    }
}
